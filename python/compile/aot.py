"""AOT lowering: JAX → HLO **text** artifacts for the Rust PJRT runtime.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/README.md and gen_hlo.py there.

Run once via ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Outputs:
    trainstep.hlo.txt      — train_step (see model.py for the arg order)
    forest_b1.hlo.txt      — forest_predict at batch 1
    forest_b256.hlo.txt    — forest_predict at batch 256
    manifest.json          — shapes/arg orders consumed by rust/src/runtime
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train_step() -> str:
    specs = model.train_step_specs()
    return to_hlo_text(jax.jit(model.train_step).lower(*specs))


def lower_forest(batch: int) -> str:
    specs = model.forest_specs(batch)
    return to_hlo_text(jax.jit(model.forest_predict).lower(*specs))


def manifest() -> dict:
    c1, c2, c3 = model.CHANNELS
    return {
        "num_features": model.NUM_FEATURES,
        "forest": {
            "trees": model.FOREST_TREES,
            "nodes": model.FOREST_NODES,
            "depth": model.FOREST_DEPTH,
            "batches": list(model.FOREST_BATCHES),
            "args": ["x", "feature", "threshold", "left", "right", "value"],
        },
        "train_step": {
            "batch": model.TRAIN_BATCH,
            "image": [model.IMG_C, model.IMG_HW, model.IMG_HW],
            "classes": model.NUM_CLASSES,
            "channels": [c1, c2, c3],
            "args": [
                "w1", "b1", "w2", "b2", "w3", "b3", "wf", "bf", "x", "y", "lr",
            ],
            "outputs": [
                "w1", "b1", "w2", "b2", "w3", "b3", "wf", "bf", "loss",
            ],
        },
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    jobs = [
        ("trainstep.hlo.txt", lower_train_step),
        ("forest_b1.hlo.txt", lambda: lower_forest(1)),
        ("forest_b256.hlo.txt", lambda: lower_forest(256)),
    ]
    for name, fn in jobs:
        path = os.path.join(args.out, name)
        text = fn()
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest(), f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
