"""L1 Pallas random-forest inference kernel — the request-path hot spot.

The paper's case study predicts Γ/γ/φ for >=50,000 evolutionary-search
candidates with "0.1s and 2MB ... simply ... the inference of a random
forest model" (Sec. 6.4). Here that inference runs as an XLA-compiled
Pallas kernel invoked from the Rust coordinator.

Layout (matching ``Forest::to_tensors`` in rust/src/forest/mod.rs):
every tree is padded to ``n_nodes`` slots; leaves carry ``threshold=+inf``
and self-referential children, so a fixed-depth traversal loop

    idx <- where(x[feature[idx]] <= threshold[idx], left[idx], right[idx])

is a no-op once a leaf is reached. The kernel vectorises the loop over a
(trees × batch) lattice of cursors; depth iterations of gathers replace the
pointer-chasing of a scalar traversal — the TPU-style formulation of a
decision forest (gathers stream from VMEM-resident node arrays).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _forest_kernel(x_ref, feat_ref, thr_ref, left_ref, right_ref, val_ref, o_ref, *, depth):
    x = x_ref[...]  # (B, F)
    feat = feat_ref[...]  # (T, N) int32
    thr = thr_ref[...]  # (T, N) f32
    left = left_ref[...]  # (T, N) int32
    right = right_ref[...]  # (T, N) int32
    val = val_ref[...]  # (T, N) f32
    t, _ = feat.shape
    b = x.shape[0]

    idx = jnp.zeros((t, b), dtype=jnp.int32)
    for _ in range(depth):
        node_feat = jnp.take_along_axis(feat, idx, axis=1)  # (T, B)
        node_thr = jnp.take_along_axis(thr, idx, axis=1)  # (T, B)
        # x-value per (tree, sample): gather feature columns per sample.
        xv = jnp.take_along_axis(x, node_feat.T, axis=1).T  # (T, B)
        go_left = xv <= node_thr
        nl = jnp.take_along_axis(left, idx, axis=1)
        nr = jnp.take_along_axis(right, idx, axis=1)
        idx = jnp.where(go_left, nl, nr)
    leaf_vals = jnp.take_along_axis(val, idx, axis=1)  # (T, B)
    o_ref[...] = jnp.mean(leaf_vals, axis=0).astype(o_ref.dtype)


def forest_predict(x, feature, threshold, left, right, value, *, depth: int):
    """Batched forest regression.

    x: (B, F) f32 — feature rows.
    feature/left/right: (T, N) i32; threshold/value: (T, N) f32.
    depth: traversal iterations (>= max tree depth; extra iterations are
    no-ops thanks to leaf self-loops).
    Returns (B,) f32 predictions (mean over trees).
    """
    b, _ = x.shape
    t, n = feature.shape
    kernel = functools.partial(_forest_kernel, depth=depth)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0, 0)),
            pl.BlockSpec((t, n), lambda i: (0, 0)),
            pl.BlockSpec((t, n), lambda i: (0, 0)),
            pl.BlockSpec((t, n), lambda i: (0, 0)),
            pl.BlockSpec((t, n), lambda i: (0, 0)),
            pl.BlockSpec((t, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=INTERPRET,
    )(x, feature, threshold, left, right, value)
