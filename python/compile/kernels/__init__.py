"""L1 Pallas kernels (build-time only; lowered into the L2 HLO artifacts)."""

from . import conv2d, forest, ref  # noqa: F401
