"""L1 Pallas convolution kernels — the paper's Eqs. 1-3 as TPU-style
tiled im2col → matmul kernels.

Hardware adaptation (DESIGN.md §2): where cuDNN's GEMM convolution stages
im2col patches in a per-threadblock shared-memory workspace, here BlockSpec
stages one sample's feature map into VMEM per grid step and the patch
matrix feeds an MXU-shaped matmul. Three kernels cover the three training
convolutions:

- ``conv2d_fwd``   — Eq.1: ``y = x * w``
- backward-data    — Eq.2: ``dL/dx = dL/dy * rot180(w)`` (the same forward
  kernel applied to the padded output gradient and the rotated, transposed
  weights — exactly the identity the paper states)
- ``conv2d_bwd_w`` — Eq.3: ``dL/dw = x * dL/dy`` (im2col^T matmul with a
  cross-grid accumulator)

``conv2d`` wires them into a ``jax.custom_vjp`` so the L2 training graph
differentiates through the Pallas ops. All kernels run ``interpret=True``
(CPU PJRT cannot execute Mosaic custom-calls); on a real TPU the same
BlockSpecs bound the VMEM working set — see DESIGN.md §8 for the estimate.

Restrictions (documented, asserted): square spatial dims, stride >= 1 for
forward, stride == 1 for the backward pass (the L2 model downsamples with
pooling, as LeNet/VGG-style nets do).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Flip to False to debug kernels outside pallas. interpret=True is REQUIRED
# for CPU-PJRT execution of the lowered HLO (see /opt/xla-example/README.md).
INTERPRET = True


def _out_spatial(ip: int, k: int, s: int, p: int) -> int:
    """The paper's op_l = 1 + floor((ip + 2p - k) / s)."""
    return 1 + (ip + 2 * p - k) // s


def _im2col(x, k: int, s: int, oh: int, ow: int):
    """(C, H, W) → (C*k*k, oh*ow), C-major then (di, dj) — matching
    w.reshape(N, C*k*k)."""
    c = x.shape[0]
    patches = jnp.stack(
        [
            x[:, di : di + s * oh : s, dj : dj + s * ow : s]
            for di in range(k)
            for dj in range(k)
        ],
        axis=1,
    )  # (C, k*k, oh, ow)
    return patches.reshape(c * k * k, oh * ow)


def _fwd_kernel(x_ref, w_ref, o_ref, *, k, s, oh, ow):
    """One sample: im2col then an MXU-shaped matmul (N×CKK @ CKK×OHW)."""
    x = x_ref[0]  # (C, Hp, Wp) — pre-padded
    w = w_ref[...]  # (N, C, k, k)
    n = w.shape[0]
    cols = _im2col(x, k, s, oh, ow)  # (C*k*k, oh*ow)
    wmat = w.reshape(n, -1)  # (N, C*k*k)
    acc = jnp.dot(wmat, cols, preferred_element_type=jnp.float32)
    o_ref[0] = acc.reshape(n, oh, ow).astype(o_ref.dtype)


def conv2d_fwd(x, w, *, stride: int = 1, padding: int = 0):
    """Eq.1 forward conv. x: (B, C, H, W), w: (N, C, k, k) → (B, N, OH, OW)."""
    b, c, h, wd = x.shape
    n, cw, k, k2 = w.shape
    assert k == k2 and c == cw and h == wd, (x.shape, w.shape)
    oh = _out_spatial(h, k, stride, padding)
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    hp = h + 2 * padding
    kernel = functools.partial(_fwd_kernel, k=k, s=stride, oh=oh, ow=oh)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, c, hp, hp), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((n, c, k, k), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, oh, oh), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, oh, oh), x.dtype),
        interpret=INTERPRET,
    )(xp, w)


def _bwd_w_kernel(x_ref, dy_ref, o_ref, *, k, oh, ow):
    """Eq.3 for one sample, accumulated across the batch grid dimension:
    dw += dy_mat @ im2col(x)^T."""
    i = pl.program_id(0)
    x = x_ref[0]  # (C, Hp, Wp)
    dy = dy_ref[0]  # (N, oh, ow)
    n = dy.shape[0]
    cols = _im2col(x, k, 1, oh, ow)  # (C*k*k, oh*ow)
    dy_mat = dy.reshape(n, -1)  # (N, oh*ow)
    contrib = jnp.dot(dy_mat, cols.T, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += contrib.reshape(o_ref.shape).astype(o_ref.dtype)


def conv2d_bwd_w(x, dy, *, kernel_size: int, padding: int = 0):
    """Eq.3: dL/dw = x * dL/dy (stride-1). Returns (N, C, k, k)."""
    b, c, h, _ = x.shape
    _, n, oh, ow = dy.shape
    k = kernel_size
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    hp = h + 2 * padding
    kern = functools.partial(_bwd_w_kernel, k=k, oh=oh, ow=ow)
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, c, hp, hp), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, n, oh, ow), lambda i: (i, 0, 0, 0)),
        ],
        # All grid steps map to the same output block → accumulation.
        out_specs=pl.BlockSpec((n, c, k, k), lambda i: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, k, k), x.dtype),
        interpret=INTERPRET,
    )(xp, dy)


def conv2d_bwd_x(dy, w, *, padding: int):
    """Eq.2: dL/dx = dL/dy * rot180(w) — the forward Pallas kernel applied
    to the re-padded output gradient with rotated/transposed weights."""
    k = w.shape[2]
    w_rot = jnp.flip(w, axis=(2, 3)).transpose(1, 0, 2, 3)  # (C, N, k, k)
    return conv2d_fwd(dy, w_rot, stride=1, padding=k - 1 - padding)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def conv2d(x, w, stride: int = 1, padding: int = 0):
    """Differentiable Pallas convolution (NCHW, square, no bias)."""
    return conv2d_fwd(x, w, stride=stride, padding=padding)


def _conv2d_fwd_rule(x, w, stride, padding):
    return conv2d_fwd(x, w, stride=stride, padding=padding), (x, w)


def _conv2d_bwd_rule(stride, padding, res, dy):
    assert stride == 1, "backward pass implemented for stride-1 convs"
    x, w = res
    dx = conv2d_bwd_x(dy, w, padding=padding)
    dw = conv2d_bwd_w(x, dy, kernel_size=w.shape[2], padding=padding)
    return dx, dw


conv2d.defvjp(_conv2d_fwd_rule, _conv2d_bwd_rule)
