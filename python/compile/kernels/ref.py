"""Pure-jnp/numpy correctness oracles for the Pallas kernels.

Every kernel in this package has a reference here; pytest asserts
``assert_allclose(kernel, ref)`` across a hypothesis-driven sweep of
shapes. The references deliberately use an entirely different formulation
(lax.conv_general_dilated; scalar tree walks) so agreement is meaningful.
"""

import jax
import jax.numpy as jnp
import numpy as np


def conv2d_ref(x, w, *, stride: int = 1, padding: int = 0):
    """Reference Eq.1 via lax.conv_general_dilated (NCHW / OIHW)."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv2d_bwd_ref(x, w, dy, *, stride: int = 1, padding: int = 0):
    """Reference (dx, dw) via jax autodiff of the reference conv."""

    def f(x_, w_):
        return conv2d_ref(x_, w_, stride=stride, padding=padding)

    _, vjp = jax.vjp(f, x, w)
    return vjp(dy)


def forest_predict_ref(x, feature, threshold, left, right, value, *, depth: int):
    """Scalar (numpy) traversal of the padded forest arrays."""
    x = np.asarray(x)
    feature = np.asarray(feature)
    threshold = np.asarray(threshold)
    left = np.asarray(left)
    right = np.asarray(right)
    value = np.asarray(value)
    b = x.shape[0]
    t = feature.shape[0]
    out = np.zeros(b, dtype=np.float64)
    for bi in range(b):
        acc = 0.0
        for ti in range(t):
            idx = 0
            for _ in range(depth):
                f = feature[ti, idx]
                if np.float32(x[bi, f]) <= threshold[ti, idx]:
                    idx = left[ti, idx]
                else:
                    idx = right[ti, idx]
            acc += float(value[ti, idx])
        out[bi] = acc / t
    return jnp.asarray(out, dtype=jnp.float32)
