"""L2 JAX compute graphs (build-time only).

Two graphs are AOT-lowered to HLO text and executed from the Rust
coordinator via PJRT:

1. ``train_step`` — a full fwd+bwd+SGD training step of a small CNN whose
   convolutions are the L1 Pallas kernels (Eqs. 1-3 of the paper). This is
   the workload the end-to-end example (`examples/train_cnn.rs`) drives to
   prove the three layers compose: real training, real loss curve, Python
   never on the request path.
2. ``forest_predict`` — batched random-forest regression over the padded
   tree tensors exported by the Rust trainer; the hot path of the OFA
   evolutionary search (Sec. 6.4).

Everything is shape-static: the constants below define the artifact shapes
and are mirrored in ``artifacts/manifest.json`` for the Rust runtime.
"""

import jax
import jax.numpy as jnp

from .kernels.conv2d import conv2d
from .kernels.forest import forest_predict as _forest_kernel

# ---------------- artifact shape constants ----------------

# Training demo: 10-class classification of 3x32x32 synthetic images.
TRAIN_BATCH = 64
IMG_C, IMG_HW, NUM_CLASSES = 3, 32, 10
CHANNELS = (16, 32, 32)

# Forest artifact shapes (Rust pads fitted forests to these).
NUM_FEATURES = 57
FOREST_TREES = 64
FOREST_NODES = 2048
FOREST_DEPTH = 16
FOREST_BATCHES = (1, 256)


# ---------------- tiny CNN ----------------

def init_params(seed: int = 0):
    """He-initialised parameter tuple (w1,b1,w2,b2,w3,b3,wf,bf)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    c1, c2, c3 = CHANNELS

    def he(key, shape, fan_in):
        return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)

    w1 = he(ks[0], (c1, IMG_C, 3, 3), IMG_C * 9)
    w2 = he(ks[1], (c2, c1, 3, 3), c1 * 9)
    w3 = he(ks[2], (c3, c2, 3, 3), c2 * 9)
    wf = he(ks[3], (c3, NUM_CLASSES), c3)
    return (
        w1,
        jnp.zeros((c1,), jnp.float32),
        w2,
        jnp.zeros((c2,), jnp.float32),
        w3,
        jnp.zeros((c3,), jnp.float32),
        wf,
        jnp.zeros((NUM_CLASSES,), jnp.float32),
    )


def _maxpool2(x):
    """2x2 max pool via reshape (differentiable, no conv dependency)."""
    b, c, h, w = x.shape
    return x.reshape(b, c, h // 2, 2, w // 2, 2).max(axis=(3, 5))


def forward(params, x):
    """CNN forward pass; every conv is the L1 Pallas kernel."""
    w1, b1, w2, b2, w3, b3, wf, bf = params
    h = conv2d(x, w1, 1, 1) + b1[None, :, None, None]
    h = jax.nn.relu(h)
    h = _maxpool2(h)  # 16x16
    h = conv2d(h, w2, 1, 1) + b2[None, :, None, None]
    h = jax.nn.relu(h)
    h = _maxpool2(h)  # 8x8
    h = conv2d(h, w3, 1, 1) + b3[None, :, None, None]
    h = jax.nn.relu(h)
    h = h.mean(axis=(2, 3))  # GAP → (B, c3)
    return h @ wf + bf  # logits (B, classes)


def loss_fn(params, x, y):
    """Softmax cross-entropy against integer labels."""
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()


def train_step(*args):
    """One SGD step. args = (*params, x, y, lr) → (*new_params, loss).

    Positional flat signature so the HLO artifact has a stable, documented
    parameter order for the Rust runtime.
    """
    params = args[:8]
    x, y, lr = args[8], args[9], args[10]
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return (*new_params, loss)


def train_step_specs():
    """ShapeDtypeStructs matching ``train_step``'s signature."""
    f32, i32 = jnp.float32, jnp.int32
    c1, c2, c3 = CHANNELS
    sds = jax.ShapeDtypeStruct
    return (
        sds((c1, IMG_C, 3, 3), f32),
        sds((c1,), f32),
        sds((c2, c1, 3, 3), f32),
        sds((c2,), f32),
        sds((c3, c2, 3, 3), f32),
        sds((c3,), f32),
        sds((c3, NUM_CLASSES), f32),
        sds((NUM_CLASSES,), f32),
        sds((TRAIN_BATCH, IMG_C, IMG_HW, IMG_HW), f32),
        sds((TRAIN_BATCH,), i32),
        sds((), f32),
    )


# ---------------- forest inference graph ----------------

def forest_predict(x, feature, threshold, left, right, value):
    """Batched forest regression via the L1 Pallas traversal kernel."""
    return _forest_kernel(
        x, feature, threshold, left, right, value, depth=FOREST_DEPTH
    )


def forest_specs(batch: int):
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    tn = (FOREST_TREES, FOREST_NODES)
    return (
        sds((batch, NUM_FEATURES), f32),
        sds(tn, i32),
        sds(tn, f32),
        sds(tn, i32),
        sds(tn, i32),
        sds(tn, f32),
    )
