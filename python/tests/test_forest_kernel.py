"""L1 forest traversal kernel vs scalar numpy oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.forest import forest_predict
from compile.kernels.ref import forest_predict_ref


def _random_valid_forest(rng, trees, depth, features):
    """Build a random *well-formed* padded forest: a complete binary tree
    truncated at `depth`, leaves self-looping with +inf thresholds — the
    exact layout Forest::to_tensors emits on the Rust side."""
    n_nodes = 2 ** depth - 1
    feat = np.zeros((trees, n_nodes), np.int32)
    thr = np.full((trees, n_nodes), np.float32(np.inf))
    left = np.zeros((trees, n_nodes), np.int32)
    right = np.zeros((trees, n_nodes), np.int32)
    val = np.zeros((trees, n_nodes), np.float32)
    for t in range(trees):
        for i in range(n_nodes):
            l, r = 2 * i + 1, 2 * i + 2
            is_leaf = l >= n_nodes or rng.random() < 0.25
            if is_leaf:
                feat[t, i] = 0
                thr[t, i] = np.inf
                left[t, i] = right[t, i] = i
                val[t, i] = rng.normal()
            else:
                feat[t, i] = rng.integers(0, features)
                thr[t, i] = rng.normal()
                left[t, i], right[t, i] = l, r
                val[t, i] = rng.normal()
    return feat, thr, left, right, val


@settings(max_examples=12, deadline=None)
@given(
    st.integers(1, 6),  # trees
    st.integers(2, 5),  # depth
    st.integers(1, 8),  # batch
    st.integers(1, 7),  # features
    st.integers(0, 1000),  # seed
)
def test_kernel_matches_scalar_oracle(trees, depth, batch, features, seed):
    rng = np.random.default_rng(seed)
    feat, thr, left, right, val = _random_valid_forest(rng, trees, depth, features)
    x = rng.normal(size=(batch, features)).astype(np.float32)
    got = forest_predict(
        jnp.asarray(x),
        jnp.asarray(feat),
        jnp.asarray(thr),
        jnp.asarray(left),
        jnp.asarray(right),
        jnp.asarray(val),
        depth=depth,
    )
    want = forest_predict_ref(x, feat, thr, left, right, val, depth=depth)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_extra_depth_iterations_stable():
    rng = np.random.default_rng(7)
    feat, thr, left, right, val = _random_valid_forest(rng, 4, 4, 5)
    x = rng.normal(size=(6, 5)).astype(np.float32)
    args = [jnp.asarray(a) for a in (x, feat, thr, left, right, val)]
    out4 = forest_predict(*args, depth=4)
    out9 = forest_predict(*args, depth=9)
    np.testing.assert_allclose(out4, out9, rtol=0, atol=0)


def test_single_leaf_forest_predicts_constant():
    trees, n = 3, 4
    feat = np.zeros((trees, n), np.int32)
    thr = np.full((trees, n), np.float32(np.inf))
    left = np.tile(np.arange(n, dtype=np.int32), (trees, 1))
    right = left.copy()
    val = np.zeros((trees, n), np.float32)
    val[:, 0] = [1.0, 2.0, 3.0]
    x = np.zeros((5, 2), np.float32)
    out = forest_predict(
        jnp.asarray(x),
        jnp.asarray(feat),
        jnp.asarray(thr),
        jnp.asarray(left),
        jnp.asarray(right),
        jnp.asarray(val),
        depth=3,
    )
    np.testing.assert_allclose(out, np.full(5, 2.0, np.float32))
