"""L2 graph tests: CNN shapes, training-step descent, forest graph."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def _synthetic_batch(seed):
    """Linearly separable-ish synthetic classification batch: class k gets
    a distinctive channel/quadrant mean shift."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, model.NUM_CLASSES, size=model.TRAIN_BATCH)
    x = rng.normal(scale=0.5, size=(model.TRAIN_BATCH, model.IMG_C, model.IMG_HW, model.IMG_HW))
    for i, label in enumerate(y):
        c = label % model.IMG_C
        q = label // model.IMG_C
        x[i, c, (q % 2) * 16 : (q % 2) * 16 + 16, :] += 1.5
    return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)


def test_forward_shapes():
    params = model.init_params(0)
    x, _ = _synthetic_batch(0)
    logits = model.forward(params, x)
    assert logits.shape == (model.TRAIN_BATCH, model.NUM_CLASSES)
    assert bool(jnp.isfinite(logits).all())


def test_loss_decreases_over_steps():
    params = model.init_params(1)
    step = jax.jit(model.train_step)
    lr = jnp.float32(0.1)
    losses = []
    for i in range(12):
        x, y = _synthetic_batch(i % 4)
        out = step(*params, x, y, lr)
        params = out[:8]
        losses.append(float(out[8]))
    assert losses[-1] < losses[0] * 0.8, f"no descent: {losses}"


def test_train_step_specs_match_signature():
    specs = model.train_step_specs()
    assert len(specs) == 11
    params = model.init_params(2)
    for p, s in zip(params, specs[:8]):
        assert p.shape == s.shape and p.dtype == s.dtype


def test_forest_graph_shapes():
    b = 4
    rng = np.random.default_rng(3)
    tn = (model.FOREST_TREES, model.FOREST_NODES)
    # trivial single-leaf forests
    feat = jnp.zeros(tn, jnp.int32)
    thr = jnp.full(tn, jnp.inf, jnp.float32)
    idx = jnp.tile(jnp.arange(model.FOREST_NODES, dtype=jnp.int32), (model.FOREST_TREES, 1))
    val = jnp.zeros(tn, jnp.float32).at[:, 0].set(5.0)
    x = jnp.asarray(rng.normal(size=(b, model.NUM_FEATURES)), jnp.float32)
    out = model.forest_predict(x, feat, thr, idx, idx, val)
    assert out.shape == (b,)
    np.testing.assert_allclose(out, np.full(b, 5.0, np.float32))
