"""AOT lowering tests: artifacts are valid HLO text with the documented
parameter orders, and the manifest matches the model constants."""

import json

from compile import aot, model


def test_train_step_lowers_to_hlo_text():
    text = aot.lower_train_step()
    assert "ENTRY" in text
    assert "HloModule" in text
    # 11 parameters (8 weights + x + y + lr)
    assert text.count("parameter(") >= 11


def test_forest_lowers_for_both_batches():
    for b in model.FOREST_BATCHES:
        text = aot.lower_forest(b)
        assert "ENTRY" in text
        assert f"f32[{b},{model.NUM_FEATURES}]" in text


def test_manifest_consistent():
    m = aot.manifest()
    assert m["num_features"] == model.NUM_FEATURES
    assert m["forest"]["trees"] == model.FOREST_TREES
    assert m["forest"]["nodes"] == model.FOREST_NODES
    assert len(m["train_step"]["args"]) == 11
    assert m["train_step"]["outputs"][-1] == "loss"
    # must be json-serialisable
    json.dumps(m)
