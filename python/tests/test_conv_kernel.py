"""L1 conv kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes/strides/paddings/dtypes for Eq.1 and checks the
custom_vjp backward kernels (Eqs. 2-3) against jax autodiff of the
reference convolution.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conv2d import conv2d, conv2d_bwd_w, conv2d_bwd_x, conv2d_fwd
from compile.kernels.ref import conv2d_bwd_ref, conv2d_ref

shape_params = st.tuples(
    st.integers(1, 3),  # batch
    st.integers(1, 5),  # in channels
    st.integers(1, 6),  # out channels
    st.sampled_from([1, 3, 5]),  # kernel
    st.integers(5, 12),  # spatial
)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(shape_params, st.sampled_from([1, 2]), st.booleans())
def test_fwd_matches_reference(params, stride, same_pad):
    b, c, n, k, hw = params
    padding = k // 2 if same_pad else 0
    if hw + 2 * padding < k:
        return
    x = _rand(b * 7 + k, (b, c, hw, hw))
    w = _rand(n * 13 + hw, (n, c, k, k))
    got = conv2d_fwd(x, w, stride=stride, padding=padding)
    want = conv2d_ref(x, w, stride=stride, padding=padding)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(shape_params)
def test_custom_vjp_matches_autodiff(params):
    b, c, n, k, hw = params
    padding = k // 2
    x = _rand(b + 17, (b, c, hw, hw))
    w = _rand(n + 29, (n, c, k, k))

    def f(x_, w_):
        return (conv2d(x_, w_, 1, padding) ** 2).sum()

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)

    def fr(x_, w_):
        return (conv2d_ref(x_, w_, stride=1, padding=padding) ** 2).sum()

    gxr, gwr = jax.grad(fr, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, gxr, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gw, gwr, rtol=1e-3, atol=1e-3)


def test_bwd_kernels_match_reference_vjp():
    x = _rand(1, (2, 3, 9, 9))
    w = _rand(2, (4, 3, 3, 3))
    dy = _rand(3, (2, 4, 9, 9))
    dxr, dwr = conv2d_bwd_ref(x, w, dy, stride=1, padding=1)
    dx = conv2d_bwd_x(dy, w, padding=1)
    dw = conv2d_bwd_w(x, dy, kernel_size=3, padding=1)
    np.testing.assert_allclose(dx, dxr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dw, dwr, rtol=1e-4, atol=1e-4)


def test_1x1_conv_is_channel_mix():
    x = _rand(4, (1, 3, 4, 4))
    w = _rand(5, (2, 3, 1, 1))
    got = conv2d_fwd(x, w)
    want = jnp.einsum("bchw,nc->bnhw", x, w[:, :, 0, 0])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_stride_reduces_spatial():
    x = _rand(6, (1, 2, 8, 8))
    w = _rand(7, (2, 2, 3, 3))
    y = conv2d_fwd(x, w, stride=2, padding=1)
    assert y.shape == (1, 2, 4, 4)


def test_bwd_requires_stride_1():
    x = _rand(8, (1, 2, 8, 8))
    w = _rand(9, (2, 2, 3, 3))

    def f(x_, w_):
        return conv2d(x_, w_, 2, 1).sum()

    with pytest.raises(AssertionError):
        jax.grad(f)(x, w)


def test_jit_compatible():
    x = _rand(10, (2, 3, 8, 8))
    w = _rand(11, (4, 3, 3, 3))
    eager = conv2d_fwd(x, w, stride=1, padding=1)
    jitted = jax.jit(lambda a, b: conv2d_fwd(a, b, stride=1, padding=1))(x, w)
    np.testing.assert_allclose(eager, jitted, rtol=1e-5, atol=1e-5)
