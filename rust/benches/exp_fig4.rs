//! Regenerates Fig. 4 (E5): basis-of-networks generalisation.

use perf4sight::device::Simulator;
use perf4sight::experiments::fig4;

fn main() {
    let sim = Simulator::tx2();
    let report = fig4::run(&sim, 0x716_4);
    fig4::print(&report);
}
