//! Regenerates Table 2 (E7): the on-device OFA case study with
//! evolutionary search under constraints and the naive-vs-model
//! search-time comparison.

use perf4sight::device::Simulator;
use perf4sight::experiments::{ofa_models, table2};
use perf4sight::ofa::EsConfig;

fn main() {
    let sim = Simulator::tx2();
    // 100 sampled sub-networks as in the paper; full ES is 100×500 — the
    // paper's ≥50,000 samples.
    let models = ofa_models::run(&sim, 100, 0x7ab1e2);
    ofa_models::print(&models.report);
    let es = EsConfig::default();
    let report = table2::run(&sim, &models, &es);
    table2::print(&report);
}
