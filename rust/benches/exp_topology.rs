//! Regenerates the Sec. 6.2 (E3) 100-strategy MobileNetV2 topology study.

use perf4sight::device::Simulator;
use perf4sight::experiments::topology;

fn main() {
    let sim = Simulator::tx2();
    let report = topology::run(&sim, 100, 0x6_2);
    topology::print(&report);
}
