//! Hot-path micro-benchmarks (the §Perf instrumentation): feature
//! extraction, forest prediction (native and through the XLA artifact),
//! simulator evaluation, pruning, and a full ES iteration. These are the
//! operations the OFA search executes ≥50,000 times.
//!
//! The "NetworkPlan" section contrasts the seed's direct-graph paths
//! (which re-ran shape inference on every call) against the compiled-plan
//! paths that build the analysis once and reuse it — the per-candidate
//! cost the acceptance criteria track.

use perf4sight::campaign::{self, CampaignSpec};
use perf4sight::device::Simulator;
use perf4sight::engine::{CompiledForestPair, PredictionEngine};
use perf4sight::features::{forward_masked, network_features, network_features_from_plan};
use perf4sight::forest::{Forest, TrainMatrix};
use perf4sight::ir::{GraphArena, NetworkPlan, PlanBuffers, PlanView};
use perf4sight::models;
use perf4sight::ofa::{
    capacity_from_convs, evolutionary_search, Constraints, EsConfig, GenerationOracle,
    SubnetConfig, Subset,
};
use perf4sight::profiler::{profile, ProfileJob};
use perf4sight::pruning::{prune, prune_overlay, Strategy};
use perf4sight::runtime::{ForestExecutor, Runtime};
use perf4sight::serve::{PredictionService, ServeConfig, Tenant};
use perf4sight::util::bench_harness::{bench, section, HOTPATH_SCHEMA, HOTPATH_SECTIONS};
use perf4sight::util::json::Json;
use perf4sight::util::rng::Pcg64;

fn main() {
    let sim = Simulator::tx2();
    let g50 = models::resnet50(1000);
    let gmb = models::mobilenet_v2(1000);

    section("hot paths — per-candidate costs of the OFA search loop");

    bench("subnet config -> IR graph build", 300, || {
        let mut rng = Pcg64::new(1);
        let c = SubnetConfig::sample(&mut rng);
        std::hint::black_box(c.build());
    });

    bench("shape inference (resnet50)", 300, || {
        std::hint::black_box(g50.infer_shapes().unwrap());
    });

    bench("feature extraction 57-col (resnet50)", 300, || {
        std::hint::black_box(network_features(&g50, 32).unwrap());
    });

    bench("feature extraction 57-col (mobilenetv2)", 300, || {
        std::hint::black_box(network_features(&gmb, 32).unwrap());
    });

    bench("simulator train_step (resnet50, bs=32)", 300, || {
        std::hint::black_box(sim.train_step(&g50, 32, None).unwrap());
    });

    bench("structured pruning (resnet50 @50%)", 300, || {
        let mut rng = Pcg64::new(2);
        std::hint::black_box(prune(&g50, Strategy::Random, 0.5, &mut rng));
    });

    section("NetworkPlan — compiled analysis layer (build once, reuse)");

    bench("NetworkPlan::build (resnet50)", 300, || {
        std::hint::black_box(NetworkPlan::build(&g50).unwrap());
    });

    let plan50 = NetworkPlan::build(&g50).unwrap();
    bench("train_step via reused plan (resnet50, bs=32)", 300, || {
        std::hint::black_box(sim.train_step_plan(&plan50, 32, None));
    });

    bench("feature extraction via reused plan (resnet50)", 300, || {
        std::hint::black_box(network_features_from_plan(&plan50, 32));
    });

    // The acceptance-criteria pair: one simulated train step plus train
    // (bs=32) and inference (bs=1) feature rows — the per-candidate work of
    // the search — via the seed's direct-graph path vs one compiled plan.
    bench("train_step + 2 feature rows, direct graph (seed path)", 400, || {
        std::hint::black_box((
            sim.train_step(&g50, 32, None).unwrap(),
            network_features(&g50, 32).unwrap(),
            network_features(&g50, 1).unwrap(),
        ));
    });

    bench("train_step + 2 feature rows, one NetworkPlan", 400, || {
        let plan = NetworkPlan::build(&g50).unwrap();
        std::hint::black_box((
            sim.train_step_plan(&plan, 32, None),
            network_features_from_plan(&plan, 32),
            network_features_from_plan(&plan, 1),
        ));
    });

    // Fit a representative forest for prediction benchmarks — and measure
    // model fitting itself at zoo scale: two networks' profiles merged
    // (250 points × 57 features) under the export config (64 trees,
    // depth ≤ 14), the shape `cmd_fit` and the experiments actually run.
    let mut train = profile(&sim, &ProfileJob::new("resnet50", &g50));
    train.extend(profile(&sim, &ProfileJob::new("mobilenet_v2", &gmb)));
    let cfg = perf4sight::runtime::forest_exec::export_forest_config();
    let train_x = train.x();
    let train_y = train.y_gamma();
    let forest = Forest::fit(&train_x, &train_y, &cfg).unwrap();
    let row = network_features(&g50, 32).unwrap();

    section("model fitting — presorted-column fast path vs per-node-sort reference");

    // Bit-identity sanity before timing anything: both fast entry points
    // must equal the seed algorithm (full oracle: tests/fit_equivalence.rs).
    {
        let reference = Forest::fit_reference(&train_x, &train_y, &cfg).unwrap();
        let seq = Forest::fit_sequential(&train_x, &train_y, &cfg).unwrap();
        assert!(
            reference.trees == forest.trees && seq.trees == forest.trees,
            "fast path diverged from the reference — fix before trusting timings"
        );
    }

    let fit_reference = bench("Forest::fit_reference (seed per-node sorts)", 2500, || {
        std::hint::black_box(Forest::fit_reference(&train_x, &train_y, &cfg).unwrap());
    });
    let fit_fast_seq = bench("Forest::fit_sequential (TrainMatrix fast path)", 2500, || {
        std::hint::black_box(Forest::fit_sequential(&train_x, &train_y, &cfg).unwrap());
    });
    let fit_fast_par = bench("Forest::fit (fast path, scoped threads)", 2500, || {
        std::hint::black_box(Forest::fit(&train_x, &train_y, &cfg).unwrap());
    });
    // The presort is paid once per *dataset*, not per fit: refitting a
    // second target from the prebuilt matrix skips it entirely (the Γ+Φ
    // pattern in cmd_fit and the experiments).
    let matrix = TrainMatrix::from_rows(&train_x).unwrap();
    let fit_presort = bench("TrainMatrix::from_rows (presort, once per dataset)", 600, || {
        std::hint::black_box(TrainMatrix::from_rows(&train_x).unwrap());
    });
    let fit_shared = bench("Forest::fit_matrix_sequential (prebuilt matrix)", 2500, || {
        std::hint::black_box(Forest::fit_matrix_sequential(&matrix, &train_y, &cfg).unwrap());
    });
    let fit_seq_speedup = fit_reference.mean_ns / fit_fast_seq.mean_ns;
    let fit_par_speedup = fit_reference.mean_ns / fit_fast_par.mean_ns;
    println!(
        "  -> fit speedup vs reference: sequential {:.2}x, parallel {:.2}x \
         (presort {:.2} ms; shared-matrix refit {:.2} ms)",
        fit_seq_speedup,
        fit_par_speedup,
        fit_presort.mean_ms(),
        fit_shared.mean_ms()
    );

    section("forest prediction");

    bench("forest.predict native (64 trees)", 300, || {
        std::hint::black_box(forest.predict(&row));
    });

    let rows: Vec<Vec<f64>> = (0..256).map(|_| row.clone()).collect();
    bench("forest.predict_batch native (256 rows)", 300, || {
        std::hint::black_box(forest.predict_batch(&rows));
    });

    // The engine's batched slab traversal vs the scalar tree walk above —
    // same 256 rows, bit-identical results (engine_equivalence.rs).
    let compiled = forest.compile();
    bench("CompiledForest::predict_rows (256 rows)", 300, || {
        std::hint::black_box(compiled.predict_rows(&rows));
    });

    // Through the AOT XLA artifact (the Pallas kernel path). Skips when
    // artifacts are absent or the crate was built without the `xla`
    // feature (the stub Runtime reports the latter).
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if Runtime::artifacts_present(&dir) {
        match Runtime::cpu(&dir) {
            Ok(rt) => {
                let exec = ForestExecutor::new(&rt, &forest).unwrap();
                bench("forest predict_one via XLA artifact", 400, || {
                    std::hint::black_box(exec.predict_one(&row).unwrap());
                });
                let s = bench("forest predict_batch(256) via XLA artifact", 600, || {
                    std::hint::black_box(exec.predict_batch(&rows).unwrap());
                });
                println!(
                    "  -> XLA batch throughput: {:.0} candidates/s \
                     (paper budget: 0.1 s per candidate)",
                    256.0 * s.throughput_per_sec()
                );
            }
            Err(e) => println!("  (XLA runtime unavailable: {e}; skipping XLA-path benches)"),
        }
    } else {
        println!("  (artifacts not built; skipping XLA-path benches — run `make artifacts`)");
    }

    section("batched inference — branch-free blocked executor vs PR 2 slab walker");

    // A second forest over the same rows (the Φ latency target) gives the
    // fused-pair executor a real two-model workload; 4096 jittered rows
    // spread the batch across distinct leaves so traversal is not one hot
    // path through identical cursors.
    let train_y_phi = train.y_phi();
    let forest_phi = Forest::fit(&train_x, &train_y_phi, &cfg).unwrap();
    let mut jitter = Pcg64::new(31);
    let inf_flat: Vec<f64> = (0..4096)
        .flat_map(|_| {
            row.iter()
                .map(|&v| v * jitter.uniform(0.25, 1.75))
                .collect::<Vec<f64>>()
        })
        .collect();
    let blocked_g = forest.compile_blocked();
    let blocked_p = forest_phi.compile_blocked();
    let pair = CompiledForestPair::compile(&forest, &forest_phi);
    // Bit-identity sanity before timing anything (full oracle suite:
    // tests/predict_equivalence.rs).
    {
        let nf = row.len();
        let walker = compiled.predict_rows_flat(&inf_flat);
        let blocked = blocked_g.predict_rows_flat(&inf_flat);
        let (pg, pp) = pair.predict_rows_flat(&inf_flat);
        for (i, chunk) in inf_flat.chunks_exact(nf).enumerate() {
            let s = forest.predict(chunk);
            assert_eq!(s.to_bits(), walker[i].to_bits(), "walker diverged from scalar");
            assert_eq!(s.to_bits(), blocked[i].to_bits(), "blocked diverged from scalar");
            assert_eq!(s.to_bits(), pg[i].to_bits(), "fused Γ diverged from scalar");
            let sp = forest_phi.predict(chunk);
            assert_eq!(sp.to_bits(), pp[i].to_bits(), "fused Φ diverged from scalar");
        }
    }
    let inf_walker = bench("CompiledForest::predict_rows_flat (4096 rows)", 1200, || {
        std::hint::black_box(compiled.predict_rows_flat(&inf_flat));
    });
    let inf_blocked = bench("BlockedForest::predict_rows_flat (4096 rows)", 1200, || {
        std::hint::black_box(blocked_g.predict_rows_flat(&inf_flat));
    });
    let inf_two_pass = bench("two blocked walks, Γ then Φ (4096 rows)", 1200, || {
        std::hint::black_box((
            blocked_g.predict_rows_flat(&inf_flat),
            blocked_p.predict_rows_flat(&inf_flat),
        ));
    });
    let inf_fused = bench("CompiledForestPair fused Γ/Φ (4096 rows)", 1200, || {
        std::hint::black_box(pair.predict_rows_flat(&inf_flat));
    });
    let inf_speedup = inf_walker.mean_ns / inf_blocked.mean_ns;
    let fused_speedup = inf_two_pass.mean_ns / inf_fused.mean_ns;
    println!(
        "  -> blocked speedup vs walker: {:.2}x ({:.0} vs {:.0} krows/s); \
         fused pair vs two blocked passes: {:.2}x",
        inf_speedup,
        4.096 * inf_blocked.throughput_per_sec(),
        4.096 * inf_walker.throughput_per_sec(),
        fused_speedup
    );

    section("end-to-end ES candidate evaluation");

    // Full per-candidate evaluation as the ES does it: one plan serves the
    // bs=32 train features and the shared bs=1 inference features.
    bench("ES candidate eval (build+plan+features+3 predictions)", 400, || {
        let mut rng = Pcg64::new(3);
        let c = SubnetConfig::sample(&mut rng);
        let g = c.build();
        let plan = NetworkPlan::build(&g).unwrap();
        let ft = network_features_from_plan(&plan, 32);
        let fi = network_features_from_plan(&plan, 1);
        std::hint::black_box((forest.predict(&ft), forest.predict(&fi)));
    });

    section("PredictionEngine — generation serving + fingerprint cache");

    // One ES generation of 64 candidates, half of them repeats (the shape
    // converged ES populations actually produce). The same fitted forest
    // stands in for all three attribute models — the serving cost is what
    // is measured here, not model quality.
    let mut rng = Pcg64::new(9);
    let distinct: Vec<SubnetConfig> = (0..32).map(|_| SubnetConfig::sample(&mut rng)).collect();
    let mut generation = distinct.clone();
    generation.extend(distinct.iter().copied());

    let mut uncached = PredictionEngine::new(&forest, &forest, &forest).with_cache_capacity(0);
    bench("engine generation, cache off (64 candidates)", 1200, || {
        std::hint::black_box(uncached.evaluate_generation(&generation));
    });

    let mut warm = PredictionEngine::new(&forest, &forest, &forest);
    warm.evaluate_generation(&generation); // fill the memo
    bench("engine generation, warm cache (64 candidates)", 300, || {
        std::hint::black_box(warm.evaluate_generation(&generation));
    });
    let cs = warm.stats();
    println!(
        "  -> cache hit rate {:.1}% ({} hits / {} misses, {} entries)",
        100.0 * cs.hit_rate(),
        cs.hits,
        cs.misses,
        cs.entries
    );

    section("zero-allocation candidate evaluation — overlay fast path vs clone+rebuild");

    // Cold-cache UNIQUE candidates: the common case in early ES
    // generations, where the fingerprint memo cannot help and every
    // candidate pays the full miss path. The clone+rebuild baseline is
    // exactly what the engine's miss path did before the arena layer
    // (graph build + NetworkPlan + fresh rows + capacity), with the same
    // batched predictors, so the delta is pure candidate-prep cost.
    let mut cold_rng = Pcg64::new(21);
    let cold: Vec<SubnetConfig> = (0..256).map(|_| SubnetConfig::sample(&mut cold_rng)).collect();
    let compiled_ref = forest.compile();
    let clone_stats = bench("256 cold candidates, clone+rebuild miss path", 2500, || {
        let mut train_rows = Vec::with_capacity(cold.len());
        let mut infer_rows = Vec::with_capacity(cold.len());
        let mut caps = Vec::with_capacity(cold.len());
        for c in &cold {
            let g = c.build();
            let plan = NetworkPlan::build(&g).unwrap();
            train_rows.push(network_features_from_plan(&plan, 32));
            infer_rows.push(forward_masked(&network_features_from_plan(&plan, 1)));
            caps.push(capacity_from_convs(PlanView::conv_infos(&plan)));
        }
        std::hint::black_box((
            compiled_ref.predict_rows(&train_rows),
            compiled_ref.predict_rows(&infer_rows),
            compiled_ref.predict_rows(&infer_rows),
            caps,
        ));
    });
    let mut cold_engine = PredictionEngine::new(&forest, &forest, &forest).with_cache_capacity(0);
    cold_engine.evaluate_generation(&cold); // warm the per-depth arenas once
    let overlay_stats = bench("256 cold candidates, overlay fast path (engine)", 2500, || {
        std::hint::black_box(cold_engine.evaluate_generation(&cold));
    });
    let clone_cps = 256.0 * clone_stats.throughput_per_sec();
    let overlay_cps = 256.0 * overlay_stats.throughput_per_sec();
    println!(
        "  -> cold-cache unique-candidate throughput: clone+rebuild {:.0}/s, \
         overlay {:.0}/s ({:.2}x)",
        clone_cps,
        overlay_cps,
        overlay_cps / clone_cps
    );

    // Campaign unit prep: what every (network, strategy, level) group of a
    // profiling campaign pays before its first measurement.
    let prep_levels = [0.0, 0.3, 0.5, 0.7, 0.9];
    let prep_legacy = bench("unit prep ×5 levels, prune + NetworkPlan (legacy)", 1200, || {
        for &level in &prep_levels {
            let mut rng = Pcg64::new(4);
            let p = prune(&g50, Strategy::Random, level, &mut rng);
            std::hint::black_box(NetworkPlan::build(&p).unwrap().param_count());
        }
    });
    let arena50 = GraphArena::compile(&g50).unwrap();
    let prep_overlay = bench("unit prep ×5 levels, overlay (incremental)", 1200, || {
        let mut buffers = PlanBuffers::new();
        for &level in &prep_levels {
            let mut rng = Pcg64::new(4);
            let ov = prune_overlay(&arena50, Strategy::Random, level, &mut rng);
            arena50.plan_into(&ov, &mut buffers).unwrap();
            std::hint::black_box(PlanView::param_count(&arena50.view_buffers(&buffers)));
        }
    });
    println!(
        "  -> campaign unit prep speedup: {:.2}x",
        prep_legacy.mean_ns / prep_overlay.mean_ns
    );

    section("profiling campaigns — sharded execution vs monolithic profile()");

    // The same small campaign grid through both producers: the sequential
    // per-(network, strategy) profile() loop vs the sharded work-stealing
    // executor + in-memory merge. Results are bit-identical (the campaign
    // oracle suite asserts it); the delta here is pure scheduling.
    let camp = CampaignSpec {
        networks: vec!["squeezenet".into(), "mnasnet".into()],
        strategies: vec![Strategy::Random],
        regimes: vec![perf4sight::device::TrainRegime::Vanilla],
        levels: vec![0.0, 0.5],
        batch_sizes: vec![4, 16, 32],
        runs: 1,
        seed: 42,
        device: "tx2".into(),
    };
    bench("monolithic campaign (2 nets × 2 levels × 3 bs)", 900, || {
        std::hint::black_box(campaign::profile_campaign(&camp).unwrap());
    });
    bench("sharded campaign (work stealing + merge)", 900, || {
        std::hint::black_box(campaign::collect(&camp).unwrap());
    });

    section("serving throughput — 8-tenant coalescing vs 8 serial searches");

    // Whole-search wall clock, not micro-iterations: N complete
    // evolutionary searches run serially on fresh engines vs concurrently
    // as tenants of one shared service. Disjoint seeds measure the
    // scheduling overhead ceiling (acceptance floor: ≥0.9× serial
    // aggregate throughput); identical seeds measure the cross-tenant
    // cache-sharing win. Both legs also assert the bit-identity
    // guarantee end to end.
    let es_serve = EsConfig {
        population: 24,
        iterations: 6,
        ..Default::default()
    };
    let cons = Constraints::unconstrained();
    let run_serial = |seeds: &[u64]| {
        let started = std::time::Instant::now();
        let bytes: Vec<Vec<u8>> = seeds
            .iter()
            .map(|&seed| {
                let mut engine = PredictionEngine::new(&forest, &forest, &forest);
                let es = EsConfig {
                    seed,
                    ..es_serve.clone()
                };
                evolutionary_search(&cons, &es, Subset::City, &mut engine).deterministic_bytes()
            })
            .collect();
        (started.elapsed(), bytes)
    };
    let run_served = |seeds: &[u64]| {
        let engine = PredictionEngine::new(&forest, &forest, &forest);
        let service = PredictionService::spawn(engine, &ServeConfig::default());
        let tenants: Vec<Tenant> = (0..seeds.len()).map(|_| service.tenant()).collect();
        let started = std::time::Instant::now();
        let bytes: Vec<Vec<u8>> = std::thread::scope(|scope| {
            let handles: Vec<_> = tenants
                .into_iter()
                .zip(seeds)
                .map(|(mut tenant, &seed)| {
                    let es = EsConfig {
                        seed,
                        ..es_serve.clone()
                    };
                    scope.spawn(move || {
                        evolutionary_search(&cons, &es, Subset::City, &mut tenant)
                            .deterministic_bytes()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let wall = started.elapsed();
        service.shutdown();
        (wall, bytes)
    };

    let disjoint: Vec<u64> = (0..8).map(|i| 1000 + i).collect();
    let (disjoint_serial, serial_bytes) = run_serial(&disjoint);
    let (disjoint_served, served_bytes) = run_served(&disjoint);
    assert_eq!(serial_bytes, served_bytes, "disjoint: served results must be bit-identical");
    let disjoint_ratio = disjoint_serial.as_secs_f64() / disjoint_served.as_secs_f64();
    println!(
        "  -> disjoint workloads: serial {:.2?}, served {:.2?} — {:.2}x aggregate throughput",
        disjoint_serial, disjoint_served, disjoint_ratio
    );

    let overlapping = [4242u64; 8];
    let (overlap_serial, serial_bytes) = run_serial(&overlapping);
    let (overlap_served, served_bytes) = run_served(&overlapping);
    assert_eq!(serial_bytes, served_bytes, "overlapping: served results must be bit-identical");
    let overlap_speedup = overlap_serial.as_secs_f64() / overlap_served.as_secs_f64();
    println!(
        "  -> overlapping workloads: serial {:.2?}, served {:.2?} — {:.2}x (shared cache)",
        overlap_serial, overlap_served, overlap_speedup
    );

    // Machine-readable perf-trajectory summary. Written to target/ so
    // local runs never dirty the working tree; CI parses it, enforces the
    // regression gate and uploads it as the BENCH_hotpath artifact. To
    // refresh the checked-in repo-root seed, copy it over deliberately.
    let summary = Json::obj(vec![
        ("schema", Json::Str(HOTPATH_SCHEMA.into())),
        (
            "model_fitting",
            Json::obj(vec![
                ("points", Json::Num(train_x.len() as f64)),
                ("features", Json::Num(train_x[0].len() as f64)),
                ("trees", Json::Num(cfg.n_trees as f64)),
                ("reference_ms", Json::Num(fit_reference.mean_ms())),
                ("fast_sequential_ms", Json::Num(fit_fast_seq.mean_ms())),
                ("fast_parallel_ms", Json::Num(fit_fast_par.mean_ms())),
                ("presort_ms", Json::Num(fit_presort.mean_ms())),
                ("shared_matrix_refit_ms", Json::Num(fit_shared.mean_ms())),
                ("sequential_speedup", Json::Num(fit_seq_speedup)),
                ("parallel_speedup", Json::Num(fit_par_speedup)),
            ]),
        ),
        (
            "cold_cache_unique_candidates",
            Json::obj(vec![
                ("batch", Json::Num(256.0)),
                ("clone_rebuild_cands_per_sec", Json::Num(clone_cps)),
                ("overlay_cands_per_sec", Json::Num(overlay_cps)),
                ("speedup", Json::Num(overlay_cps / clone_cps)),
            ]),
        ),
        (
            "campaign_unit_prep_5_levels",
            Json::obj(vec![
                ("legacy_ms", Json::Num(prep_legacy.mean_ms())),
                ("overlay_ms", Json::Num(prep_overlay.mean_ms())),
                ("speedup", Json::Num(prep_legacy.mean_ns / prep_overlay.mean_ns)),
            ]),
        ),
        (
            "serving_throughput",
            Json::obj(vec![
                ("tenants", Json::Num(8.0)),
                ("population", Json::Num(es_serve.population as f64)),
                ("iterations", Json::Num(es_serve.iterations as f64)),
                ("disjoint_serial_s", Json::Num(disjoint_serial.as_secs_f64())),
                ("disjoint_served_s", Json::Num(disjoint_served.as_secs_f64())),
                ("disjoint_throughput_ratio", Json::Num(disjoint_ratio)),
                ("overlapping_serial_s", Json::Num(overlap_serial.as_secs_f64())),
                ("overlapping_served_s", Json::Num(overlap_served.as_secs_f64())),
                ("overlapping_speedup", Json::Num(overlap_speedup)),
            ]),
        ),
        (
            "inference",
            Json::obj(vec![
                ("batch", Json::Num(4096.0)),
                ("trees", Json::Num(cfg.n_trees as f64)),
                ("walker_ms", Json::Num(inf_walker.mean_ms())),
                ("blocked_ms", Json::Num(inf_blocked.mean_ms())),
                ("blocked_speedup", Json::Num(inf_speedup)),
                ("two_pass_ms", Json::Num(inf_two_pass.mean_ms())),
                ("fused_ms", Json::Num(inf_fused.mean_ms())),
                ("fused_speedup", Json::Num(fused_speedup)),
            ]),
        ),
    ]);
    // The summary must carry exactly the sections the schema constant
    // declares — the same invariant tests/bench_schema.rs pins on the
    // checked-in placeholder.
    for key in HOTPATH_SECTIONS {
        assert!(summary.get(key).is_some(), "bench summary missing declared section {key:?}");
    }
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/target/BENCH_hotpath.json");
    let mut body = summary.to_string();
    body.push('\n');
    std::fs::write(path, body).expect("writing BENCH_hotpath.json");
    println!("wrote {path}");
}
