//! Hot-path micro-benchmarks (the §Perf instrumentation): feature
//! extraction, forest prediction (native and through the XLA artifact),
//! simulator evaluation, pruning, and a full ES iteration. These are the
//! operations the OFA search executes ≥50,000 times.

use perf4sight::device::Simulator;
use perf4sight::features::network_features;
use perf4sight::forest::Forest;
use perf4sight::models;
use perf4sight::ofa::SubnetConfig;
use perf4sight::profiler::{profile, ProfileJob};
use perf4sight::pruning::{prune, Strategy};
use perf4sight::runtime::{ForestExecutor, Runtime};
use perf4sight::util::bench_harness::{bench, section};
use perf4sight::util::rng::Pcg64;

fn main() {
    let sim = Simulator::tx2();
    let g50 = models::resnet50(1000);
    let gmb = models::mobilenet_v2(1000);

    section("hot paths — per-candidate costs of the OFA search loop");

    bench("subnet config -> IR graph build", 300, || {
        let mut rng = Pcg64::new(1);
        let c = SubnetConfig::sample(&mut rng);
        std::hint::black_box(c.build());
    });

    bench("shape inference (resnet50)", 300, || {
        std::hint::black_box(g50.infer_shapes().unwrap());
    });

    bench("feature extraction 57-col (resnet50)", 300, || {
        std::hint::black_box(network_features(&g50, 32).unwrap());
    });

    bench("feature extraction 57-col (mobilenetv2)", 300, || {
        std::hint::black_box(network_features(&gmb, 32).unwrap());
    });

    bench("simulator train_step (resnet50, bs=32)", 300, || {
        std::hint::black_box(sim.train_step(&g50, 32, None).unwrap());
    });

    bench("structured pruning (resnet50 @50%)", 300, || {
        let mut rng = Pcg64::new(2);
        std::hint::black_box(prune(&g50, Strategy::Random, 0.5, &mut rng));
    });

    // Fit a representative forest for prediction benchmarks.
    let train = profile(&sim, &ProfileJob::new("resnet50", &g50));
    let cfg = perf4sight::runtime::forest_exec::export_forest_config();
    let forest = Forest::fit(&train.x(), &train.y_gamma(), &cfg);
    let row = network_features(&g50, 32).unwrap();

    bench("forest.predict native (64 trees)", 300, || {
        std::hint::black_box(forest.predict(&row));
    });

    let rows: Vec<Vec<f64>> = (0..256).map(|_| row.clone()).collect();
    bench("forest.predict_batch native (256 rows)", 300, || {
        std::hint::black_box(forest.predict_batch(&rows));
    });

    // Through the AOT XLA artifact (the Pallas kernel path).
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if Runtime::artifacts_present(&dir) {
        let rt = Runtime::cpu(&dir).unwrap();
        let exec = ForestExecutor::new(&rt, &forest).unwrap();
        bench("forest predict_one via XLA artifact", 400, || {
            std::hint::black_box(exec.predict_one(&row).unwrap());
        });
        let s = bench("forest predict_batch(256) via XLA artifact", 600, || {
            std::hint::black_box(exec.predict_batch(&rows).unwrap());
        });
        println!(
            "  -> XLA batch throughput: {:.0} candidates/s (paper budget: 0.1 s per candidate)",
            256.0 * s.throughput_per_sec()
        );
    } else {
        println!("  (artifacts not built; skipping XLA-path benches — run `make artifacts`)");
    }

    // Full per-candidate evaluation as the ES does it.
    bench("ES candidate evaluation (build+features+3 predictions)", 400, || {
        let mut rng = Pcg64::new(3);
        let c = SubnetConfig::sample(&mut rng);
        let g = c.build();
        let convs = g.conv_infos().unwrap();
        let ft = perf4sight::features::network_features_from_convs(&convs, 32);
        let fi = perf4sight::features::network_features_from_convs(&convs, 1);
        std::hint::black_box((forest.predict(&ft), forest.predict(&fi)));
    });
}
