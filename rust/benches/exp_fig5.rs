//! Regenerates Fig. 5 / App. B (E6): Γ and Φ vs batch size per pruning
//! level, with linearity statistics.

use perf4sight::device::Simulator;
use perf4sight::experiments::fig5;

fn main() {
    let sim = Simulator::tx2();
    let report = fig5::run(&sim, 0x716_5);
    fig5::print(&report);
}
