//! Regenerates Fig. 3 (E1): same-network train/test attribute errors for
//! ResNet18, MobileNetV2, SqueezeNet and MnasNet under random and L1-norm
//! test pruning. Run: `cargo bench --bench exp_fig3`.

use perf4sight::device::Simulator;
use perf4sight::experiments::fig3;
use perf4sight::util::bench_harness::bench;

fn main() {
    let sim = Simulator::tx2();
    let report = fig3::run(&sim, 0x716_3);
    fig3::print(&report);
    // Hot-path timing: one full same-network pipeline (profile+fit+eval).
    bench("fig3 pipeline (squeezenet, full grid)", 400, || {
        let g = perf4sight::models::squeezenet(1000);
        let (train, test) = perf4sight::profiler::train_test_split(
            &sim,
            "squeezenet",
            &g,
            perf4sight::pruning::Strategy::Random,
            1,
        );
        let (fg, _) = perf4sight::experiments::fit_gamma_phi(&train);
        std::hint::black_box(fg.mape(&test.x(), &test.y_gamma()));
    });
}
