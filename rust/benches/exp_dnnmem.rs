//! Regenerates the Sec. 6.2.1 (E4) DNNMem comparison on the simulated
//! RTX 2080Ti, plus the Augur-style and linear-regression baselines.

use perf4sight::experiments::dnnmem_cmp;

fn main() {
    let report = dnnmem_cmp::run(0x6_21);
    dnnmem_cmp::print(&report);
}
