//! Regenerates the training-regime generalisation sweep: one Γ/Φ forest
//! pair fitted across vanilla / checkpointed / frozen training on the
//! widened campaign grid, scored per (network, regime) on held-out levels.

use perf4sight::device::Simulator;
use perf4sight::experiments::regimes;

fn main() {
    let sim = Simulator::tx2();
    let report = regimes::run(&sim, 0x6_2);
    regimes::print(&report);
}
