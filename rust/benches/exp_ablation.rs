//! E9: feature-family knockout ablation on ResNet18 and MobileNetV2.

use perf4sight::device::Simulator;
use perf4sight::experiments::ablation;

fn main() {
    let sim = Simulator::tx2();
    for network in ["resnet18", "mobilenetv2"] {
        let report = ablation::run(&sim, network, 0xab1a);
        ablation::print(&report);
    }
    // Extension: device specificity of the models (see EXPERIMENTS.md).
    let cross = perf4sight::experiments::cross_device::run("resnet18", 0xab1b);
    perf4sight::experiments::cross_device::print(&cross);
    let _ = sim;
}
