//! Regenerates the Sec. 6.1 (E2) AlexNet training-set-size sweep.

use perf4sight::device::Simulator;
use perf4sight::experiments::trainset;

fn main() {
    let sim = Simulator::tx2();
    let report = trainset::run(&sim, 0x6_1);
    trainset::print(&report);
}
