//! Regenerates the Sec. 6.4 (E8) OFA attribute-model accuracy numbers:
//! γ/φ inference models (25/75 split) and Γ generalisation.

use perf4sight::device::Simulator;
use perf4sight::experiments::ofa_models;

fn main() {
    let sim = Simulator::tx2();
    let models = ofa_models::run(&sim, 100, 0x0fa);
    ofa_models::print(&models.report);
}
