//! Analytical feature model — the paper's Sec. 5.2.1 / Appendix B.
//!
//! For every convolution layer `l` (with `n_l` filters of `m_l/g_l × k_l ×
//! k_l`, stride `s_l`, padding `p_l`, input spatial `ip_l`, output spatial
//! `op_l`) and a training batch size `bs`, we compute the expected memory
//! allocations and operation counts of all three cuDNN convolution
//! algorithms (matrix-multiplication, FFT, Winograd) for each of the three
//! training convolutions: Eq.1 (forward), Eq.2 (∂L/∂x) and Eq.3 (∂L/∂w).
//!
//! Features are computed per layer and *summed across layers* (Sec. 5.3) to
//! give a network-level vector. The Winograd block is instantiated for the
//! two tile configurations cuDNN uses most, (q,r) = (4,3) and (3,2)
//! (App. B.2.4), so the nominal 42-feature list expands to 56 columns; the
//! batch size itself is prepended as column 0 for a total of 57.

use crate::device::TrainRegime;
use crate::ir::{ConvInfo, Graph, GraphError, PlanView};

/// Feature families — used by the ablation experiment (E9) to knock out
/// whole algorithm groups.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Batch size column.
    Meta,
    /// Op-independent tensor allocations (App. B.2.1).
    Tensor,
    /// Matrix-multiplication algorithm (App. B.2.2).
    MatMul,
    /// FFT algorithm (App. B.2.3).
    Fft,
    /// Winograd algorithm (App. B.2.4).
    Winograd,
}

/// Number of per-layer feature columns (bs column included).
pub const NUM_FEATURES: usize = 1 + 5 + 10 + 13 + 2 * 14;

/// Winograd tile configurations (q, r) modelled, per App. B.2.4.
pub const WINOGRAD_TILES: [(usize, usize); 2] = [(4, 3), (3, 2)];

/// Stable column names (for dataset dumps and model inspection).
pub fn feature_names() -> Vec<String> {
    let mut names = vec!["bs".to_string()];
    for f in [
        "mem_w",
        "mem_w_grad",
        "mem_ifm_grad",
        "mem_ofm_grad",
        "mem_tensors_sum",
    ] {
        names.push(f.into());
    }
    for f in [
        "mm_i2c_fwd_total",
        "mm_i2c_bwdw_total",
        "mm_i2c_fwd_index",
        "mm_i2c_bwdx_total",
        "mm_i2c_bwdx_index",
        "mm_mem_total_sum",
        "mm_mem_index_sum",
        "mm_ops_fwd",
        "mm_ops_bwdx",
        "mm_ops_sum",
    ] {
        names.push(f.into());
    }
    for f in [
        "fft_mem_w_fwd",
        "fft_mem_ifm_fwd",
        "fft_mem_ofm_bwdw",
        "fft_mem_w_bwdx",
        "fft_mem_ofm_bwdx",
        "fft_mem_fwd_sum",
        "fft_mem_ofm_sum",
        "fft_mem_bwdw_sum",
        "fft_mem_total_sum",
        "fft_ops_fwd",
        "fft_ops_bwdx",
        "fft_ops_bwdw",
        "fft_ops_sum",
    ] {
        names.push(f.into());
    }
    for (q, r) in WINOGRAD_TILES {
        for f in [
            "wino_mem_fwd",
            "wino_mem_bwdx",
            "wino_mem_bwdw",
            "wino_mem_fwd_bwdx",
            "wino_mem_fwd_bwdw",
            "wino_mem_bwdw_bwdx",
            "wino_mem_total_sum",
            "wino_ops_fwd",
            "wino_ops_bwdx",
            "wino_ops_bwdw",
            "wino_ops_fwd_bwdx",
            "wino_ops_fwd_bwdw",
            "wino_ops_bwdx_bwdw",
            "wino_ops_total_sum",
        ] {
            names.push(format!("{f}_q{q}r{r}"));
        }
    }
    debug_assert_eq!(names.len(), NUM_FEATURES);
    names
}

/// Family of each feature column (parallel to [`feature_names`]).
pub fn feature_families() -> Vec<Family> {
    let mut fams = vec![Family::Meta];
    fams.extend(std::iter::repeat(Family::Tensor).take(5));
    fams.extend(std::iter::repeat(Family::MatMul).take(10));
    fams.extend(std::iter::repeat(Family::Fft).take(13));
    fams.extend(std::iter::repeat(Family::Winograd).take(28));
    debug_assert_eq!(fams.len(), NUM_FEATURES);
    fams
}

#[inline]
fn ceil_div(a: usize, b: usize) -> f64 {
    ((a + b - 1) / b) as f64
}

/// Per-layer feature vector for one convolution at batch size `bs`.
///
/// All formulas are verbatim from App. B.2.1–B.2.4 (see the numbered list
/// in the paper); `log` is the natural logarithm.
pub fn layer_features(c: &ConvInfo, bs: usize) -> Vec<f64> {
    layer_features_arr(c, bs).to_vec()
}

/// Allocation-free accumulation variant used by [`network_features`] —
/// the OFA search calls this for every conv of every candidate (§Perf:
/// the per-layer values live in a stack array, no heap traffic).
pub fn accumulate_layer_features(c: &ConvInfo, bs: usize, acc: &mut [f64]) {
    let lf = layer_features_arr(c, bs);
    for (a, v) in acc.iter_mut().zip(lf) {
        *a += v;
    }
}

fn layer_features_arr(c: &ConvInfo, bs: usize) -> [f64; NUM_FEATURES] {
    let bs = bs as f64;
    let n = c.n as f64;
    let m = c.m as f64;
    let k = c.k as f64;
    let g = c.g as f64;
    let ip = c.ip as f64;
    let op = c.op as f64;
    let mg = m / g;

    // Stack-allocated writer (no heap traffic on the search hot path).
    struct W {
        buf: [f64; NUM_FEATURES],
        i: usize,
    }
    impl W {
        #[inline]
        fn push(&mut self, v: f64) {
            self.buf[self.i] = v;
            self.i += 1;
        }
    }
    let mut f = W {
        buf: [0.0; NUM_FEATURES],
        i: 0,
    };
    // Column 0: batch size (meta).
    f.push(bs);

    // ---- B.2.1 tensor allocations (features 1-5) ----
    let mem_w = n * mg * k * k;
    let mem_w_grad = bs * n * mg * k * k;
    let mem_ifm_grad = bs * m * ip * ip;
    let mem_ofm_grad = bs * n * op * op;
    f.push(mem_w);
    f.push(mem_w_grad);
    f.push(mem_ifm_grad);
    f.push(mem_ofm_grad);
    f.push(mem_w + mem_w_grad + mem_ifm_grad + mem_ofm_grad);

    // ---- B.2.2 matrix multiplication (features 6-15) ----
    let i2c_fwd_total = bs * op * op * k * k * m;
    let i2c_bwdw_total = bs * op * op * k * k * mg;
    let i2c_fwd_index = bs * op * op;
    let i2c_bwdx_total = bs * ip * ip * k * k * m;
    let i2c_bwdx_index = bs * ip * ip;
    let ops_fwd_mm = bs * n * op * op * k * k * mg;
    let ops_bwdx_mm = bs * m * ip * ip * k * k * n;
    f.push(i2c_fwd_total);
    f.push(i2c_bwdw_total);
    f.push(i2c_fwd_index);
    f.push(i2c_bwdx_total);
    f.push(i2c_bwdx_index);
    f.push(i2c_fwd_total + i2c_bwdw_total + i2c_bwdx_total);
    f.push(2.0 * i2c_fwd_index + i2c_bwdx_index);
    f.push(ops_fwd_mm);
    f.push(ops_bwdx_mm);
    f.push(2.0 * ops_fwd_mm + ops_bwdx_mm);

    // ---- B.2.3 FFT (features 16-28) ----
    let fft_w_fwd = n * mg * ip * (1.0 + ip);
    let fft_ifm_fwd = bs * m * ip * (1.0 + ip);
    let fft_ofm_bwdw = bs * n * ip * (1.0 + ip);
    let fft_w_bwdx = n * mg * op * (1.0 + op);
    let fft_ofm_bwdx = bs * n * op * (1.0 + op);
    let s21 = fft_w_fwd + fft_ifm_fwd;
    let s22 = fft_ofm_bwdx + fft_ofm_bwdw;
    let s23 = fft_ofm_bwdw + fft_ifm_fwd;
    let common = bs * (m + n) + n * mg;
    let fft_ops_fwd = ip * ip * ip.max(1.0).ln() * common + bs * n * m * ip * ip;
    let fft_ops_bwdx = op * op * op.max(1.0).ln() * common + bs * n * m * op * op;
    let fft_ops_bwdw = ip * (ip * ip).max(1.0).ln() * common + bs * n * m * ip * ip;
    f.push(fft_w_fwd);
    f.push(fft_ifm_fwd);
    f.push(fft_ofm_bwdw);
    f.push(fft_w_bwdx);
    f.push(fft_ofm_bwdx);
    f.push(s21);
    f.push(s22);
    f.push(s23);
    f.push(s21 + s22 + s23);
    f.push(fft_ops_fwd);
    f.push(fft_ops_bwdx);
    f.push(fft_ops_bwdw);
    f.push(fft_ops_fwd + fft_ops_bwdx + fft_ops_bwdw);

    // ---- B.2.4 Winograd, for (q,r) in {(4,3), (3,2)} (features 29-42 ×2) ----
    for (q, r) in WINOGRAD_TILES {
        let qf = q as f64;
        let rf = r as f64;
        let tile = (qf + rf - 1.0) * (qf + rf - 1.0);
        let tiles_ip = ceil_div(c.ip, q) * ceil_div(c.ip, q);
        let tiles_op = ceil_div(c.op, q) * ceil_div(c.op, q);
        let tiles_k = ceil_div(c.k, r) * ceil_div(c.k, r);
        let tiles_op_r = ceil_div(c.op, r) * ceil_div(c.op, r);

        let mem_fwd = bs * n * tiles_ip * 3.0 * tile;
        let mem_bwdx = bs * m * tiles_op * 3.0 * tile;
        let mem_bwdw = bs * n * mg * tiles_ip * 3.0 * tile;
        let ops_fwd = bs * n * mg * tiles_ip * tiles_k * tile;
        let ops_bwdx = bs * m * n * tiles_op * tiles_k * tile;
        let ops_bwdw = bs * n * mg * mg * tiles_ip * tiles_op_r * tile;

        let m32 = mem_fwd + mem_bwdx;
        let m33 = mem_fwd + mem_bwdw;
        let m34 = mem_bwdw + mem_bwdx;
        let o39 = ops_fwd + ops_bwdx;
        let o40 = ops_fwd + ops_bwdw;
        let o41 = ops_bwdx + ops_bwdw;
        f.push(mem_fwd);
        f.push(mem_bwdx);
        f.push(mem_bwdw);
        f.push(m32);
        f.push(m33);
        f.push(m34);
        f.push(m32 + m33 + m34);
        f.push(ops_fwd);
        f.push(ops_bwdx);
        f.push(ops_bwdw);
        f.push(o39);
        f.push(o40);
        f.push(o41);
        f.push(o39 + o40 + o41);
    }

    debug_assert_eq!(f.i, NUM_FEATURES);
    f.buf
}

/// Network-level feature vector: per-layer features summed across all conv
/// layers (Sec. 5.3); the bs column is not summed.
pub fn network_features(graph: &Graph, bs: usize) -> Result<Vec<f64>, GraphError> {
    Ok(network_features_from_convs(&graph.conv_infos()?, bs))
}

/// As [`network_features`] but over any compiled analysis view
/// ([`NetworkPlan`](crate::ir::NetworkPlan) or
/// [`OverlayPlan`](crate::ir::OverlayPlan)) — the entry point for callers
/// that already hold a plan (profiler, OFA search, coordinator), so
/// feature extraction at any batch size is pure arithmetic with no
/// shape-inference pass.
pub fn network_features_from_plan<P: PlanView>(plan: &P, bs: usize) -> Vec<f64> {
    network_features_from_convs(plan.conv_infos(), bs)
}

/// As [`network_features`] but from pre-extracted conv summaries — lets
/// callers that need features at several batch sizes (the OFA search needs
/// bs=32 for Γ and bs=1 for γ/φ) run shape inference once (§Perf).
pub fn network_features_from_convs(convs: &[ConvInfo], bs: usize) -> Vec<f64> {
    let mut total = vec![0.0f64; NUM_FEATURES];
    network_features_into_slice(convs, bs, &mut total);
    total
}

/// Allocation-free variant of [`network_features_from_convs`]: writes the
/// row into a caller-owned scratch `Vec` (cleared and resized in place) —
/// the engine's zero-allocation miss path computes every candidate row
/// this way. Accumulation order is identical to the allocating variant,
/// so results are bit-identical.
pub fn network_features_into(convs: &[ConvInfo], bs: usize, out: &mut Vec<f64>) {
    out.clear();
    out.resize(NUM_FEATURES, 0.0);
    network_features_into_slice(convs, bs, out);
}

fn network_features_into_slice(convs: &[ConvInfo], bs: usize, out: &mut [f64]) {
    debug_assert_eq!(out.len(), NUM_FEATURES);
    for c in convs {
        accumulate_layer_features(c, bs, out);
    }
    out[0] = bs as f64; // bs is a scalar input, not a sum
}

// Tensor-block column indices (columns 1–5), used by the regime transforms
// below; pinned to [`feature_names`] by `regime_feature_indices_match_names`.
const IDX_MEM_W: usize = 1;
const IDX_MEM_W_GRAD: usize = 2;
const IDX_MEM_IFM_GRAD: usize = 3;
const IDX_MEM_OFM_GRAD: usize = 4;
const IDX_MEM_TENSORS_SUM: usize = 5;

/// As [`network_features_from_plan`] under a [`TrainRegime`] — the regime
/// modulates how each convolution's analytical terms accumulate instead of
/// adding columns, so the forest artifact shape ([`NUM_FEATURES`]) is
/// unchanged. `Vanilla` runs the exact unmodified accumulation and is
/// bit-identical to [`network_features_from_plan`].
pub fn network_features_from_plan_regime<P: PlanView>(
    plan: &P,
    bs: usize,
    regime: TrainRegime,
) -> Vec<f64> {
    network_features_from_convs_regime(plan.conv_infos(), bs, regime)
}

/// As [`network_features_from_convs`] under a [`TrainRegime`].
///
/// - `Checkpointed { segments }`: per layer, the stored activation-gradient
///   blocks (`mem_ifm_grad`, `mem_ofm_grad`) shrink by the segment count
///   (only one segment's worth is live at a time) and every forward-pass
///   column doubles (checkpoint re-materialisation re-runs forward);
///   `mem_tensors_sum` is recomputed from the transformed components.
/// - `Frozen { trainable_suffix }`: frozen convolutions contribute only
///   their forward-pass columns (weights stay resident, nothing backward
///   exists); trainable ones accumulate unchanged. A suffix covering every
///   convolution is bit-identical to vanilla.
pub fn network_features_from_convs_regime(
    convs: &[ConvInfo],
    bs: usize,
    regime: TrainRegime,
) -> Vec<f64> {
    match regime {
        TrainRegime::Vanilla => network_features_from_convs(convs, bs),
        TrainRegime::Checkpointed { segments } => {
            let s = segments.max(1) as f64;
            let mask = forward_mask_cached();
            let mut total = vec![0.0f64; NUM_FEATURES];
            for c in convs {
                let mut lf = layer_features_arr(c, bs);
                lf[IDX_MEM_IFM_GRAD] /= s;
                lf[IDX_MEM_OFM_GRAD] /= s;
                for (v, &keep) in lf.iter_mut().zip(mask) {
                    if keep {
                        *v *= 2.0;
                    }
                }
                lf[IDX_MEM_TENSORS_SUM] = lf[IDX_MEM_W]
                    + lf[IDX_MEM_W_GRAD]
                    + lf[IDX_MEM_IFM_GRAD]
                    + lf[IDX_MEM_OFM_GRAD];
                for (a, v) in total.iter_mut().zip(lf) {
                    *a += v;
                }
            }
            total[0] = bs as f64;
            total
        }
        TrainRegime::Frozen { trainable_suffix } => {
            let first_trainable = convs.len().saturating_sub(trainable_suffix);
            let mask = forward_mask_cached();
            let mut total = vec![0.0f64; NUM_FEATURES];
            for (i, c) in convs.iter().enumerate() {
                if i >= first_trainable {
                    accumulate_layer_features(c, bs, &mut total);
                } else {
                    let lf = layer_features_arr(c, bs);
                    for ((a, v), &keep) in total.iter_mut().zip(lf).zip(mask) {
                        if keep {
                            *a += v;
                        }
                    }
                }
            }
            total[0] = bs as f64;
            total
        }
    }
}

/// Inference-stage features: forward-pass terms only (Sec. 6.4 trains the
/// γ/φ models "using only the features corresponding to the forward pass").
/// Returns (names, values) restricted to fwd columns.
pub fn forward_only_mask() -> Vec<bool> {
    feature_names()
        .iter()
        .map(|n| {
            n == "bs"
                || n == "mem_w"
                || n.contains("fwd") && !n.contains("bwd")
                || n == "mm_ops_fwd"
        })
        .collect()
}

/// Zero all backward-pass feature columns, keeping the full
/// [`NUM_FEATURES`]-wide artifact shape (trees never split on
/// constant-zero columns). The γ/φ inference models consume these rows —
/// Sec. 6.4 trains them "using only the features corresponding to the
/// forward pass".
pub fn forward_masked(features: &[f64]) -> Vec<f64> {
    let mut out = features.to_vec();
    forward_mask_in_place(&mut out);
    out
}

/// In-place variant of [`forward_masked`] for rows living in reusable
/// scratch buffers (the engine's zero-allocation miss path).
pub fn forward_mask_in_place(features: &mut [f64]) {
    for (f, &keep) in features.iter_mut().zip(forward_mask_cached()) {
        if !keep {
            *f = 0.0;
        }
    }
}

fn forward_mask_cached() -> &'static [bool] {
    use std::sync::OnceLock;
    static CELL: OnceLock<Vec<bool>> = OnceLock::new();
    CELL.get_or_init(forward_only_mask)
}

/// Apply a column mask to a feature vector.
pub fn mask_features(features: &[f64], mask: &[bool]) -> Vec<f64> {
    features
        .iter()
        .zip(mask)
        .filter_map(|(&f, &keep)| if keep { Some(f) } else { None })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ConvInfo;

    fn sample_conv() -> ConvInfo {
        ConvInfo {
            node: 0,
            n: 8,
            m: 4,
            k: 3,
            s: 1,
            p: 1,
            g: 1,
            ip: 16,
            op: 16,
        }
    }

    #[test]
    fn names_and_families_align() {
        assert_eq!(feature_names().len(), NUM_FEATURES);
        assert_eq!(feature_families().len(), NUM_FEATURES);
        assert_eq!(NUM_FEATURES, 57);
    }

    #[test]
    fn tensor_features_hand_computed() {
        let c = sample_conv();
        let f = layer_features(&c, 2);
        let names = feature_names();
        let get = |name: &str| f[names.iter().position(|n| n == name).unwrap()];
        assert_eq!(get("bs"), 2.0);
        assert_eq!(get("mem_w"), 8.0 * 4.0 * 9.0);
        assert_eq!(get("mem_w_grad"), 2.0 * 8.0 * 4.0 * 9.0);
        assert_eq!(get("mem_ifm_grad"), 2.0 * 4.0 * 256.0);
        assert_eq!(get("mem_ofm_grad"), 2.0 * 8.0 * 256.0);
        assert_eq!(
            get("mem_tensors_sum"),
            get("mem_w") + get("mem_w_grad") + get("mem_ifm_grad") + get("mem_ofm_grad")
        );
    }

    #[test]
    fn mm_features_hand_computed() {
        let c = sample_conv();
        let f = layer_features(&c, 2);
        let names = feature_names();
        let get = |name: &str| f[names.iter().position(|n| n == name).unwrap()];
        // bs*op^2*k^2*m = 2*256*9*4
        assert_eq!(get("mm_i2c_fwd_total"), 2.0 * 256.0 * 9.0 * 4.0);
        assert_eq!(get("mm_i2c_fwd_index"), 2.0 * 256.0);
        // ops_fwd = bs*n*op^2*k^2*(m/g) = 2*8*256*9*4
        assert_eq!(get("mm_ops_fwd"), 2.0 * 8.0 * 256.0 * 9.0 * 4.0);
        assert_eq!(
            get("mm_ops_sum"),
            2.0 * get("mm_ops_fwd") + get("mm_ops_bwdx")
        );
    }

    #[test]
    fn winograd_tile_counts() {
        let c = sample_conv();
        let f = layer_features(&c, 1);
        let names = feature_names();
        let get = |name: &str| f[names.iter().position(|n| n == name).unwrap()];
        // q=4,r=3: ceil(16/4)^2 = 16 tiles, (q+r-1)^2 = 36
        // mem_fwd = bs*n*16*3*36 = 1*8*16*108
        assert_eq!(get("wino_mem_fwd_q4r3"), 8.0 * 16.0 * 3.0 * 36.0);
        // q=3,r=2: ceil(16/3)^2 = 36 tiles, tile = 16
        assert_eq!(get("wino_mem_fwd_q3r2"), 8.0 * 36.0 * 3.0 * 16.0);
    }

    #[test]
    fn bs_linearity_of_bs_dependent_features() {
        let c = sample_conv();
        let f1 = layer_features(&c, 1);
        let f4 = layer_features(&c, 4);
        let names = feature_names();
        for (i, name) in names.iter().enumerate() {
            // weight memories and FFT weight terms are bs-independent
            if name == "mem_w" || name.starts_with("fft_mem_w") {
                assert_eq!(f1[i], f4[i], "{name} should not scale with bs");
            }
        }
        // strictly bs-linear examples
        let get = |f: &[f64], name: &str| f[names.iter().position(|n| n == name).unwrap()];
        assert_eq!(get(&f4, "mem_ifm_grad"), 4.0 * get(&f1, "mem_ifm_grad"));
        assert_eq!(get(&f4, "mm_ops_fwd"), 4.0 * get(&f1, "mm_ops_fwd"));
        assert_eq!(
            get(&f4, "wino_ops_fwd_q4r3"),
            4.0 * get(&f1, "wino_ops_fwd_q4r3")
        );
    }

    #[test]
    fn plan_features_match_graph_features() {
        let g = crate::models::resnet18(1000);
        let plan = g.plan().unwrap();
        for bs in [1usize, 8, 32] {
            assert_eq!(
                network_features(&g, bs).unwrap(),
                network_features_from_plan(&plan, bs)
            );
        }
    }

    #[test]
    fn network_features_are_layer_sums() {
        let g = crate::models::resnet18(1000);
        let nf = network_features(&g, 8).unwrap();
        let convs = g.conv_infos().unwrap();
        let manual: f64 = convs.iter().map(|c| layer_features(c, 8)[1]).sum();
        assert_eq!(nf[1], manual);
        assert_eq!(nf[0], 8.0);
        assert!(nf.iter().all(|x| x.is_finite() && *x >= 0.0));
    }

    #[test]
    fn pruning_reduces_feature_magnitudes() {
        use crate::pruning::{prune, Strategy};
        use crate::util::rng::Pcg64;
        let g = crate::models::mobilenet_v2(1000);
        let mut rng = Pcg64::new(3);
        let p = prune(&g, Strategy::Random, 0.5, &mut rng);
        let f0 = network_features(&g, 32).unwrap();
        let f1 = network_features(&p, 32).unwrap();
        // ops features must strictly shrink
        let names = feature_names();
        let idx = names.iter().position(|n| n == "mm_ops_sum").unwrap();
        assert!(f1[idx] < f0[idx]);
    }

    #[test]
    fn forward_mask_selects_fwd_columns() {
        let mask = forward_only_mask();
        let names = feature_names();
        assert!(mask[0]); // bs
        for (name, &keep) in names.iter().zip(&mask) {
            if name.contains("bwd") {
                assert!(!keep, "{name} wrongly kept");
            }
        }
        let kept = mask.iter().filter(|&&b| b).count();
        assert!(kept >= 8, "too few forward features: {kept}");
        let f = vec![1.0; NUM_FEATURES];
        assert_eq!(mask_features(&f, &mask).len(), kept);
    }

    #[test]
    fn regime_feature_indices_match_names() {
        let names = feature_names();
        assert_eq!(names[IDX_MEM_W], "mem_w");
        assert_eq!(names[IDX_MEM_W_GRAD], "mem_w_grad");
        assert_eq!(names[IDX_MEM_IFM_GRAD], "mem_ifm_grad");
        assert_eq!(names[IDX_MEM_OFM_GRAD], "mem_ofm_grad");
        assert_eq!(names[IDX_MEM_TENSORS_SUM], "mem_tensors_sum");
    }

    #[test]
    fn vanilla_regime_features_bit_identical() {
        use crate::device::TrainRegime;
        let g = crate::models::resnet18(1000);
        let plan = g.plan().unwrap();
        for bs in [1usize, 32] {
            let base = network_features_from_plan(&plan, bs);
            let via = network_features_from_plan_regime(&plan, bs, TrainRegime::Vanilla);
            assert_eq!(base, via);
        }
    }

    #[test]
    fn full_trainable_suffix_features_match_vanilla() {
        use crate::device::TrainRegime;
        let g = crate::models::squeezenet(1000);
        let plan = g.plan().unwrap();
        let n = plan.conv_infos().len();
        assert_eq!(
            network_features_from_plan(&plan, 16),
            network_features_from_plan_regime(
                &plan,
                16,
                TrainRegime::Frozen { trainable_suffix: n }
            )
        );
    }

    #[test]
    fn checkpoint_features_scale_grad_columns_and_double_fwd() {
        use crate::device::TrainRegime;
        let c = sample_conv();
        let v = network_features_from_convs_regime(&[c], 2, TrainRegime::Vanilla);
        let ck = network_features_from_convs_regime(
            &[c],
            2,
            TrainRegime::Checkpointed { segments: 4 },
        );
        let names = feature_names();
        let at = |f: &[f64], name: &str| f[names.iter().position(|n| n == name).unwrap()];
        assert_eq!(at(&ck, "mem_ifm_grad"), at(&v, "mem_ifm_grad") / 4.0);
        assert_eq!(at(&ck, "mem_ofm_grad"), at(&v, "mem_ofm_grad") / 4.0);
        // mem_w is a forward column → doubled
        assert_eq!(at(&ck, "mem_w"), 2.0 * at(&v, "mem_w"));
        // backward op counts untouched
        assert_eq!(at(&ck, "mm_ops_bwdx"), at(&v, "mm_ops_bwdx"));
        // the tensor sum tracks the transformed components
        assert_eq!(
            at(&ck, "mem_tensors_sum"),
            at(&ck, "mem_w") + at(&ck, "mem_w_grad") + at(&ck, "mem_ifm_grad")
                + at(&ck, "mem_ofm_grad")
        );
        assert_eq!(ck[0], 2.0, "bs column stays the scalar batch size");
    }

    #[test]
    fn frozen_features_drop_backward_columns_of_frozen_convs() {
        use crate::device::TrainRegime;
        let g = crate::models::resnet18(1000);
        let plan = g.plan().unwrap();
        let v = network_features_from_plan(&plan, 8);
        let f = network_features_from_plan_regime(
            &plan,
            8,
            TrainRegime::Frozen { trainable_suffix: 2 },
        );
        let names = feature_names();
        let at = |row: &[f64], name: &str| row[names.iter().position(|n| n == name).unwrap()];
        // backward magnitudes shrink strictly, forward sums are unchanged
        assert!(at(&f, "mm_ops_bwdx") < at(&v, "mm_ops_bwdx"));
        assert!(at(&f, "mem_w_grad") < at(&v, "mem_w_grad"));
        assert_eq!(at(&f, "mem_w"), at(&v, "mem_w"));
        assert_eq!(at(&f, "mm_ops_fwd"), at(&v, "mm_ops_fwd"));
    }

    #[test]
    fn depthwise_group_division() {
        let c = ConvInfo {
            node: 0,
            n: 32,
            m: 32,
            k: 3,
            s: 1,
            p: 1,
            g: 32,
            ip: 14,
            op: 14,
        };
        let f = layer_features(&c, 1);
        let names = feature_names();
        let get = |name: &str| f[names.iter().position(|n| n == name).unwrap()];
        // m/g = 1
        assert_eq!(get("mem_w"), 32.0 * 1.0 * 9.0);
        assert_eq!(get("mm_ops_fwd"), 32.0 * 196.0 * 9.0);
    }
}
