//! Bounded-retry policy with exponential backoff and deterministic
//! jitter, shared by the local campaign driver and the distributed
//! dispatch layer.
//!
//! Jitter is derived from a salt rather than an ambient RNG: every
//! process that reasons about the same (campaign, shard, attempt) —
//! the worker deciding whether a failed shard's backoff has elapsed,
//! the test asserting on timing — computes the *same* delay, while
//! different shards still de-synchronize so a burst of failures does
//! not retry in lockstep.

use std::time::Duration;

use crate::util::rng::{hash_seed, Pcg64};

/// Retry budget + backoff shape for one shard attempt sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Re-attempts after the first failure; 0 = fail fast.
    pub retries: usize,
    /// First backoff delay; doubles per failure. 0 disables backoff
    /// (retry immediately — tests, or callers with their own pacing).
    pub base_ms: u64,
    /// Ceiling for the exponential growth.
    pub cap_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            retries: 1,
            base_ms: 250,
            cap_ms: 10_000,
        }
    }
}

impl RetryPolicy {
    /// Total executions allowed per shard (first try + retries).
    pub fn max_attempts(&self) -> usize {
        self.retries.saturating_add(1)
    }

    /// Backoff to wait after `failures` failed attempts (≥ 1) before the
    /// next one: `base · 2^(failures-1)`, capped, scaled by a
    /// deterministic jitter in [0.5, 1.0) derived from `salt`.
    pub fn delay(&self, failures: usize, salt: u64) -> Duration {
        if self.base_ms == 0 || failures == 0 {
            return Duration::ZERO;
        }
        let shift = (failures - 1).min(16) as u32;
        let raw = self.base_ms.saturating_mul(1u64 << shift);
        let capped = raw.min(self.cap_ms.max(self.base_ms));
        let mut rng = Pcg64::with_stream(salt, 0x6261_636b_6f66_6621 ^ failures as u64);
        let jitter = 0.5 + 0.5 * rng.next_f64();
        Duration::from_millis((capped as f64 * jitter).round() as u64)
    }
}

/// Canonical jitter salt for a shard's attempt sequence: every process
/// watching the same (campaign fingerprint, shard, failure count) agrees
/// on the delay without sharing any state.
pub fn shard_salt(fingerprint: u64, shard: usize, failures: usize) -> u64 {
    hash_seed(&format!("{fingerprint:016x}/shard-{shard}/failures-{failures}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delay_is_deterministic_and_bounded() {
        let p = RetryPolicy {
            retries: 5,
            base_ms: 100,
            cap_ms: 1_000,
        };
        for failures in 1..=8 {
            let salt = shard_salt(0xfeed, 3, failures);
            let a = p.delay(failures, salt);
            let b = p.delay(failures, salt);
            assert_eq!(a, b);
            let ceiling = (100u64 << (failures - 1).min(16)).min(1_000);
            assert!(a.as_millis() as u64 <= ceiling, "failures={failures}: {a:?}");
            assert!(a.as_millis() as u64 >= ceiling / 2, "failures={failures}: {a:?}");
        }
    }

    #[test]
    fn zero_base_or_zero_failures_is_no_wait() {
        let p = RetryPolicy {
            retries: 3,
            base_ms: 0,
            cap_ms: 100,
        };
        assert_eq!(p.delay(2, 1), Duration::ZERO);
        let p = RetryPolicy::default();
        assert_eq!(p.delay(0, 1), Duration::ZERO);
    }

    #[test]
    fn large_failure_counts_do_not_overflow() {
        let p = RetryPolicy {
            retries: 100,
            base_ms: u64::MAX / 2,
            cap_ms: u64::MAX,
        };
        // Saturates instead of shifting past 64 bits.
        let d = p.delay(90, 7);
        assert!(d >= Duration::ZERO);
    }

    #[test]
    fn max_attempts_counts_the_first_try() {
        assert_eq!(RetryPolicy { retries: 0, base_ms: 0, cap_ms: 0 }.max_attempts(), 1);
        assert_eq!(RetryPolicy::default().max_attempts(), 2);
    }

    #[test]
    fn different_shards_jitter_differently() {
        let p = RetryPolicy {
            retries: 3,
            base_ms: 10_000,
            cap_ms: 60_000,
        };
        // Not a hard guarantee per pair, but across a few shards at least
        // one delay must differ — lockstep retries are the failure mode.
        let delays: Vec<_> = (0..4).map(|s| p.delay(1, shard_salt(1, s, 1))).collect();
        assert!(delays.iter().any(|d| *d != delays[0]), "{delays:?}");
    }
}
