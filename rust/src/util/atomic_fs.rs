//! Crash-atomic file primitives for checkpoint artifacts and the
//! shared-directory dispatch mailbox.
//!
//! Two write disciplines cover every artifact in the toolflow:
//!
//! * [`write_atomic`] — sibling temp file + `rename`. Readers observe the
//!   old contents or the new contents, never a torn file. Used for every
//!   overwrite-style artifact (datasets, manifests, heartbeat refreshes).
//! * [`publish_new`] — temp file + `hard_link`, which fails if the target
//!   already exists. This is the *claim* primitive: exactly one of N
//!   concurrent publishers wins, and the winner's file is fully written
//!   before it becomes visible (a bare `create_new` + write would expose
//!   a partially-written claim; `rename` silently overwrites on Unix and
//!   cannot arbitrate at all).
//!
//! Temp names are salted with (pid, per-process counter, wall-clock
//! nanos) — bare `process::id()` is not unique across machines sharing a
//! directory, and pid reuse after a crash is routine. Leftover `.tmp-*`
//! files from killed processes are harmless (every reader matches exact
//! names or suffixes) and are swept by [`remove_stale_tmp`] when a driver
//! takes exclusive ownership of a directory.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Milliseconds since the Unix epoch — the heartbeat clock. Wall-clock on
/// purpose: lease timestamps are compared *across machines*, where no
/// monotonic clock is shared. (Clock skew between writer and reader eats
/// into the lease timeout; the dispatch docs tell operators to budget for
/// it.)
pub fn now_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

static SALT_COUNTER: AtomicU64 = AtomicU64::new(0);

/// A salt unique across processes and machines for temp-file names and
/// worker ids: pid × per-process counter × sub-second nanos.
pub fn unique_salt() -> String {
    let nanos = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.subsec_nanos())
        .unwrap_or(0);
    format!(
        "{:x}-{:x}-{:x}",
        std::process::id(),
        SALT_COUNTER.fetch_add(1, Ordering::Relaxed),
        nanos
    )
}

/// Sibling temp path for `path`: same directory (so `rename`/`hard_link`
/// never crosses a filesystem), name suffixed `.tmp-<salt>`.
fn tmp_sibling(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("artifact");
    path.with_file_name(format!("{name}.tmp-{}", unique_salt()))
}

fn ensure_parent(path: &Path) -> io::Result<()> {
    if let Some(dir) = path.parent() {
        // `parent()` of a bare filename is `Some("")` — nothing to create.
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    Ok(())
}

/// Write `contents` to `path` crash-atomically: temp sibling + `rename`.
/// Missing parent directories are created. Concurrent readers see the old
/// file or the new file, never a torn one; a crash leaves at worst a
/// stray `.tmp-*` sibling that every reader ignores.
pub fn write_atomic(path: &Path, contents: &str) -> io::Result<()> {
    ensure_parent(path)?;
    let tmp = tmp_sibling(path);
    std::fs::write(&tmp, contents)?;
    match std::fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            std::fs::remove_file(&tmp).ok();
            Err(e)
        }
    }
}

/// Atomically publish `contents` at `path` *only if nothing is there yet*
/// (temp file + `hard_link`, the shared-directory claim primitive).
/// Returns `Ok(true)` if this call created the file, `Ok(false)` if it
/// already existed — the loser of a claim race. Either way the file a
/// reader observes is fully written.
pub fn publish_new(path: &Path, contents: &str) -> io::Result<bool> {
    ensure_parent(path)?;
    let tmp = tmp_sibling(path);
    std::fs::write(&tmp, contents)?;
    let linked = std::fs::hard_link(&tmp, path);
    std::fs::remove_file(&tmp).ok();
    match linked {
        Ok(()) => Ok(true),
        Err(e) if e.kind() == io::ErrorKind::AlreadyExists => Ok(false),
        Err(e) => Err(e),
    }
}

/// Sweep leftover `*.tmp-*` files (from crashed or killed writers) out of
/// `dir`, non-recursively. Returns how many were removed. Only call this
/// from a context that owns the directory exclusively — the local
/// campaign driver on resume; dispatch-mode processes must *not* sweep
/// (a peer may be mid-rename) and instead rely on every reader ignoring
/// temp names.
pub fn remove_stale_tmp(dir: &Path) -> io::Result<usize> {
    let mut removed = 0;
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let is_tmp = entry
            .file_name()
            .to_str()
            .is_some_and(|n| n.contains(".tmp-"));
        if is_tmp && entry.file_type()?.is_file() && std::fs::remove_file(entry.path()).is_ok() {
            removed += 1;
        }
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "perf4sight-atomicfs-{name}-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn write_atomic_replaces_and_leaves_no_tmp() {
        let dir = tmpdir("write");
        let path = dir.join("nested").join("a.json");
        write_atomic(&path, "one").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "one");
        write_atomic(&path, "two").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "two");
        let leftovers: Vec<_> = std::fs::read_dir(dir.join("nested"))
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_str().is_some_and(|n| n.contains(".tmp-")))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn publish_new_claims_exactly_once() {
        let dir = tmpdir("claim");
        let path = dir.join("claim.json");
        assert!(publish_new(&path, "winner").unwrap());
        assert!(!publish_new(&path, "loser").unwrap());
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "winner");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_tmp_files_are_swept_but_real_files_kept() {
        let dir = tmpdir("sweep");
        std::fs::write(dir.join("keep.json"), "x").unwrap();
        std::fs::write(dir.join("keep.json.tmp-dead-1-2"), "y").unwrap();
        std::fs::write(dir.join("other.tmp-dead-3-4"), "z").unwrap();
        assert_eq!(remove_stale_tmp(&dir).unwrap(), 2);
        assert!(dir.join("keep.json").exists());
        assert_eq!(remove_stale_tmp(&dir).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn salts_are_unique_within_a_process() {
        let a = unique_salt();
        let b = unique_salt();
        assert_ne!(a, b);
    }
}
