//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so the toolflow ships its own
//! PCG-64 (XSL-RR) generator. Every stochastic component of the system
//! (pruning strategies, profiling noise, forest bootstrap sampling,
//! evolutionary search) takes an explicit [`Pcg64`] so runs are exactly
//! reproducible from a seed.

/// PCG-XSL-RR 128/64 generator (Melissa O'Neill's PCG family).
///
/// 128-bit LCG state, 64-bit output via xorshift-low + random rotation.
/// Passes practical statistical tests and is more than adequate for
/// bootstrap sampling / mutation draws.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream constant fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream; distinct streams are
    /// statistically independent for the same seed.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Derive an independent child generator (for per-task seeding).
    pub fn fork(&mut self) -> Self {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Self::with_stream(seed, stream)
    }

    /// Advance the stream as if [`Pcg64::next_u64`] had been called `n`
    /// times (O(n); used to resume a shared stream at a known offset, e.g.
    /// the profiler skipping earlier work units' noise draws).
    pub fn advance(&mut self, n: u64) {
        for _ in 0..n {
            self.next_u64();
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be positive");
        let bound = bound as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (one value per call; cheap enough).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal multiplicative noise centred at 1.0 with small sigma —
    /// used to model measurement jitter in the device simulator.
    pub fn jitter(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher-Yates: first k entries are the sample
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len())]
    }
}

/// Stable 64-bit FNV-1a hash of a string — used to derive per-name seeds so
/// e.g. each (network, pruning-level) pair gets a reproducible stream.
pub fn hash_seed(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_unbiased_enough() {
        let mut rng = Pcg64::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.gen_range(5)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(9);
        for _ in 0..100 {
            let s = rng.sample_indices(20, 7);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 7);
            assert!(t.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn advance_matches_sequential_draws() {
        let mut a = Pcg64::new(17);
        let mut b = Pcg64::new(17);
        a.advance(137);
        for _ in 0..137 {
            b.next_u64();
        }
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Pcg64::new(21);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn hash_seed_stable() {
        assert_eq!(hash_seed("resnet18"), hash_seed("resnet18"));
        assert_ne!(hash_seed("resnet18"), hash_seed("resnet50"));
    }
}
