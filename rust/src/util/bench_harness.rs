//! Tiny criterion-style benchmark harness (criterion itself is unavailable
//! in the offline build). Used by the `[[bench]]` targets with
//! `harness = false`: each bench is a `main()` that both *regenerates a
//! paper table/figure* and reports wall-clock statistics for the hot paths
//! it exercises.

use std::time::Instant;

/// Schema tag of the machine-readable hot-path summary the `hotpath`
/// bench writes (`rust/target/BENCH_hotpath.json`; seed copy at the repo
/// root). Bump it whenever sections are added or removed, and keep
/// [`HOTPATH_SECTIONS`] in step — `rust/tests/bench_schema.rs` pins the
/// checked-in placeholder to both constants so the two cannot drift.
pub const HOTPATH_SCHEMA: &str = "perf4sight/hotpath-bench/v4";

/// The top-level sections of the hotpath summary (v4: the PR 9
/// `inference` section joined the v3 set).
pub const HOTPATH_SECTIONS: [&str; 5] = [
    "model_fitting",
    "cold_cache_unique_candidates",
    "campaign_unit_prep_5_levels",
    "serving_throughput",
    "inference",
];

/// Result of timing a closure repeatedly.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns <= 0.0 {
            0.0
        } else {
            1e9 / self.mean_ns
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` with warmup and adaptive iteration count targeting
/// ~`budget_ms` of measurement, then print a one-line summary.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchStats {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let first = t0.elapsed().as_nanos().max(1) as f64;
    let target_ns = (budget_ms as f64) * 1e6;
    let iters = ((target_ns / first).ceil() as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        median_ns: samples[samples.len() / 2],
        p95_ns: samples[(samples.len() as f64 * 0.95) as usize % samples.len()],
        min_ns: samples[0],
    };
    println!(
        "  [bench] {:<42} mean {:>12}  median {:>12}  p95 {:>12}  ({} iters)",
        stats.name,
        fmt_ns(stats.mean_ns),
        fmt_ns(stats.median_ns),
        fmt_ns(stats.p95_ns),
        stats.iters
    );
    stats
}

/// Print a section header for a regenerated table/figure.
pub fn section(title: &str) {
    println!();
    println!("{}", "=".repeat(78));
    println!("{title}");
    println!("{}", "=".repeat(78));
}

/// Print an aligned table: `header` then rows of equal arity.
pub fn table(header: &[&str], rows: &[Vec<String>]) {
    let cols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), cols, "row arity mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: Vec<String>| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(header.iter().map(|s| s.to_string()).collect())
    );
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
    for row in rows {
        println!("{}", fmt_row(row.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench("noop-ish", 5, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.iters >= 3);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.median_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_checks_arity() {
        table(&["a", "b"], &[vec!["x".into()]]);
    }
}
