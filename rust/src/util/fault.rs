//! Deterministic fault injection for the dispatch recovery paths.
//!
//! The `PERF4SIGHT_FAULT` env var plants faults at named execution points
//! so tests and CI exercise recovery with *real* killed/hung processes
//! instead of trusting the lease protocol by inspection. Grammar (comma
//! list of plans, parsed strictly — a malformed value panics loudly, it
//! never silently disables the fault a test depends on):
//!
//! ```text
//! PERF4SIGHT_FAULT = plan[,plan…]
//! plan             = <point>:<action>[:once][:shard=<i>]
//! point            = shard-start | mid-shard | pre-manifest
//!                  | heartbeat | unit-start
//! action           = exit | error | hang | stall=<ms> | mute
//! ```
//!
//! * `exit` terminates the process (exit code [`FAULT_EXIT_CODE`]),
//!   `error` returns an injected `Err` through the normal failure path,
//!   `hang` freezes execution forever (heartbeating stops too — the
//!   frozen-process model), `stall=<ms>` sleeps then continues (a slow
//!   worker that outlives its lease), and `mute` stops heartbeat
//!   refreshes while execution continues (the network-partitioned model).
//! * `mute` only applies to the `heartbeat` point; `unit-start` sits in
//!   infallible profiler code, so it accepts only the abortive actions
//!   (`exit`, `hang`, `stall`).
//! * `:once` arms the plan across *every process sharing the campaign
//!   dir*: the first process to reach the point claims a marker file
//!   (atomic create) under `<dir>/faults/` and fires; all later arrivals
//!   — including the retry of the shard the fault killed — pass through.
//! * `:shard=<i>` restricts the plan to one shard.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// Env var holding the fault plans.
pub const FAULT_ENV: &str = "PERF4SIGHT_FAULT";

/// Exit code used by the `exit` action — distinct from panic (101) and
/// CLI errors (1), so tests can tell an injected death from a real bug.
pub const FAULT_EXIT_CODE: i32 = 86;

/// Named execution points where a fault can fire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// Entry of a shard's execution, before any unit runs.
    ShardStart,
    /// Halfway through a shard's unit list (units already computed are
    /// lost with the process — the recovery path must recompute them).
    MidShard,
    /// After the shard dataset is written, before its manifest — the
    /// window where a crash leaves data without a completeness marker.
    PreManifest,
    /// Observed by the lease heartbeat thread on every refresh tick.
    Heartbeat,
    /// Entry of one profiling unit (infallible profiler code).
    UnitStart,
}

impl FaultPoint {
    fn name(self) -> &'static str {
        match self {
            FaultPoint::ShardStart => "shard-start",
            FaultPoint::MidShard => "mid-shard",
            FaultPoint::PreManifest => "pre-manifest",
            FaultPoint::Heartbeat => "heartbeat",
            FaultPoint::UnitStart => "unit-start",
        }
    }

    fn from_name(name: &str) -> Option<FaultPoint> {
        match name {
            "shard-start" => Some(FaultPoint::ShardStart),
            "mid-shard" => Some(FaultPoint::MidShard),
            "pre-manifest" => Some(FaultPoint::PreManifest),
            "heartbeat" => Some(FaultPoint::Heartbeat),
            "unit-start" => Some(FaultPoint::UnitStart),
            _ => None,
        }
    }
}

/// What happens when a plan fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    Exit,
    Error,
    Hang,
    Stall { ms: u64 },
    Mute,
}

/// One parsed fault plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    pub point: FaultPoint,
    pub action: FaultAction,
    pub once: bool,
    pub shard: Option<usize>,
}

/// Parse a `PERF4SIGHT_FAULT` value. Strict: anything unrecognized is a
/// named error, never a silently-ignored plan.
pub fn parse_plans(raw: &str) -> Result<Vec<FaultPlan>, String> {
    let mut plans = Vec::new();
    for part in raw.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        plans.push(parse_plan(part).map_err(|e| format!("{FAULT_ENV}: bad plan {part:?}: {e}"))?);
    }
    Ok(plans)
}

fn parse_plan(text: &str) -> Result<FaultPlan, String> {
    let mut fields = text.split(':');
    let point = fields.next().unwrap_or("");
    let point = FaultPoint::from_name(point).ok_or_else(|| {
        format!(
            "unknown point {point:?} (shard-start, mid-shard, pre-manifest, heartbeat, unit-start)"
        )
    })?;
    let action = fields.next().ok_or("missing action")?;
    let action = match action.split_once('=') {
        Some(("stall", ms)) => FaultAction::Stall {
            ms: ms
                .parse()
                .map_err(|_| format!("stall wants integer millis, got {ms:?}"))?,
        },
        None if action == "exit" => FaultAction::Exit,
        None if action == "error" => FaultAction::Error,
        None if action == "hang" => FaultAction::Hang,
        None if action == "mute" => FaultAction::Mute,
        _ => {
            return Err(format!(
                "unknown action {action:?} (exit, error, hang, stall=<ms>, mute)"
            ))
        }
    };
    let mut once = false;
    let mut shard = None;
    for modifier in fields {
        match modifier.split_once('=') {
            None if modifier == "once" => once = true,
            Some(("shard", i)) => {
                shard = Some(
                    i.parse()
                        .map_err(|_| format!("shard wants an index, got {i:?}"))?,
                )
            }
            _ => return Err(format!("unknown modifier {modifier:?} (once, shard=<i>)")),
        }
    }
    if (action == FaultAction::Mute) != (point == FaultPoint::Heartbeat) {
        return Err("mute and the heartbeat point only combine with each other".into());
    }
    if once && action == FaultAction::Mute {
        return Err("mute is a continuous condition; :once does not apply".into());
    }
    if point == FaultPoint::UnitStart && matches!(action, FaultAction::Error) {
        return Err("unit-start sits in infallible code; use exit, hang or stall".into());
    }
    Ok(FaultPlan {
        point,
        action,
        once,
        shard,
    })
}

static PLANS: OnceLock<Vec<FaultPlan>> = OnceLock::new();

/// The process's armed plans (parsed once from the env). Panics on a
/// malformed value: a fault harness that quietly does nothing would let
/// every recovery test pass vacuously.
fn plans() -> &'static [FaultPlan] {
    PLANS.get_or_init(|| match std::env::var(FAULT_ENV) {
        Err(_) => Vec::new(),
        Ok(raw) => match parse_plans(&raw) {
            Ok(p) => p,
            Err(e) => panic!("{e}"),
        },
    })
}

static CONTEXT_DIR: OnceLock<PathBuf> = OnceLock::new();

/// Set the campaign directory used for cross-process `:once` markers.
/// First caller wins (one campaign per process); entry points that own a
/// campaign dir (driver, dispatch worker/coordinator, the hidden
/// profile-worker mode) call this before any fault point is reached.
pub fn set_context_dir(dir: &Path) {
    let _ = CONTEXT_DIR.set(dir.to_path_buf());
}

fn marker_name(plan: &FaultPlan) -> String {
    match plan.shard {
        Some(s) => format!("{}-shard-{s}.fired", plan.point.name()),
        None => format!("{}.fired", plan.point.name()),
    }
}

/// Claim the right to fire a `:once` plan. Cross-process when a context
/// dir is set (atomic marker-file create under `<dir>/faults/`);
/// process-local otherwise.
fn claim_once(plan: &FaultPlan) -> bool {
    let name = marker_name(plan);
    match CONTEXT_DIR.get() {
        Some(dir) => crate::util::atomic_fs::publish_new(
            &dir.join("faults").join(&name),
            &format!("pid {}\n", std::process::id()),
        )
        .unwrap_or(false),
        None => {
            static FIRED: Mutex<Vec<String>> = Mutex::new(Vec::new());
            let mut fired = FIRED.lock().expect("fault marker lock");
            if fired.iter().any(|f| *f == name) {
                false
            } else {
                fired.push(name);
                true
            }
        }
    }
}

static HANG_ENGAGED: AtomicBool = AtomicBool::new(false);

/// Has a `hang` fault frozen this process? The heartbeat thread polls
/// this so a hung worker also stops beating — the frozen-process model,
/// not a zombie that hangs while looking alive.
pub fn hang_engaged() -> bool {
    HANG_ENGAGED.load(Ordering::Relaxed)
}

/// Should the heartbeat for `shard` stop refreshing? True under an armed
/// `heartbeat:mute` plan matching the shard, or once a hang engaged.
pub fn heartbeat_muted(shard: usize) -> bool {
    hang_engaged()
        || plans().iter().any(|p| {
            p.point == FaultPoint::Heartbeat
                && p.action == FaultAction::Mute
                && p.shard.is_none_or(|s| s == shard)
        })
}

/// Fire any armed plan matching (`point`, `shard`). `Err` carries the
/// injected failure for the `error` action; `exit` and `hang` never
/// return.
pub fn check(point: FaultPoint, shard: Option<usize>) -> Result<(), String> {
    for plan in plans() {
        if plan.point != point || (plan.shard.is_some() && plan.shard != shard) {
            continue;
        }
        if plan.once && !claim_once(plan) {
            continue;
        }
        let at = point.name();
        let shard_tag = shard.map(|s| format!(" shard {s}")).unwrap_or_default();
        match plan.action {
            FaultAction::Exit => {
                eprintln!("injected fault: exiting at {at}{shard_tag}");
                std::process::exit(FAULT_EXIT_CODE);
            }
            FaultAction::Hang => {
                eprintln!("injected fault: hanging at {at}{shard_tag}");
                HANG_ENGAGED.store(true, Ordering::Relaxed);
                loop {
                    std::thread::sleep(Duration::from_secs(3600));
                }
            }
            FaultAction::Stall { ms } => {
                eprintln!("injected fault: stalling {ms}ms at {at}{shard_tag}");
                std::thread::sleep(Duration::from_millis(ms));
            }
            FaultAction::Error => {
                return Err(format!("injected fault: error at {at}{shard_tag}"));
            }
            // Continuous condition, observed via `heartbeat_muted`.
            FaultAction::Mute => {}
        }
    }
    Ok(())
}

/// [`check`] for infallible call sites (the profiler's unit entry): only
/// abortive actions can be planted there, so the `Err` arm is
/// unreachable by construction (the parser rejects `unit-start:error`).
pub fn check_infallible(point: FaultPoint, shard: Option<usize>) {
    let _ignored_by_grammar = check(point, shard);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_round_trips() {
        let plans = parse_plans(
            "mid-shard:exit:once:shard=0, heartbeat:mute:shard=1 ,pre-manifest:error,\
             shard-start:stall=250,unit-start:hang",
        )
        .unwrap();
        assert_eq!(plans.len(), 5);
        assert_eq!(
            plans[0],
            FaultPlan {
                point: FaultPoint::MidShard,
                action: FaultAction::Exit,
                once: true,
                shard: Some(0),
            }
        );
        assert_eq!(plans[1].action, FaultAction::Mute);
        assert_eq!(plans[3].action, FaultAction::Stall { ms: 250 });
        assert_eq!(parse_plans("").unwrap(), Vec::new());
    }

    #[test]
    fn malformed_plans_are_named_errors() {
        for bad in [
            "mid-shard",                 // missing action
            "nowhere:exit",              // unknown point
            "mid-shard:explode",         // unknown action
            "mid-shard:stall=soon",      // non-integer stall
            "mid-shard:exit:often",      // unknown modifier
            "mid-shard:exit:shard=x",    // non-integer shard
            "mid-shard:mute",            // mute off the heartbeat point
            "heartbeat:exit",            // heartbeat only mutes
            "heartbeat:mute:once",       // once does not apply to mute
            "unit-start:error",          // no Err channel at unit entry
        ] {
            let err = parse_plans(bad).unwrap_err();
            assert!(err.contains(FAULT_ENV), "{bad}: {err}");
        }
    }

    #[test]
    fn stall_and_error_fire_through_check() {
        // Exercise the firing machinery without env vars (racy across the
        // parallel test runner): drive `check`-equivalent logic via a
        // local plan list is impossible through the static, so only the
        // env-free default is asserted here — no plans, no effect. The
        // full exit/hang/mute paths run as real killed processes in
        // tests/dispatch_recovery.rs.
        assert_eq!(check(FaultPoint::MidShard, Some(0)), Ok(()));
        check_infallible(FaultPoint::UnitStart, None);
        assert!(!heartbeat_muted(0));
        assert!(!hang_engaged());
    }
}
