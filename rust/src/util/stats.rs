//! Small statistics helpers shared by the profiler, forest metrics and the
//! experiment harnesses.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // total_cmp: identical order to partial_cmp on finite inputs, and a
    // NaN in a noisy measurement series degrades the estimate instead of
    // panicking mid-experiment.
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// p-th percentile (0..=100), linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Mean absolute percentage error (the paper's headline error metric).
/// `truth` entries with |t| < eps are skipped to avoid division blow-ups.
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mut total = 0.0;
    let mut n = 0usize;
    for (p, t) in pred.iter().zip(truth) {
        if t.abs() > 1e-9 {
            total += ((p - t) / t).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        100.0 * total / n as f64
    }
}

/// Root mean squared error.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let se: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum();
    (se / pred.len() as f64).sqrt()
}

/// Coefficient of determination R^2 of predictions vs truth.
pub fn r_squared(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let m = mean(truth);
    let ss_tot: f64 = truth.iter().map(|t| (t - m) * (t - m)).sum();
    let ss_res: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (t - p) * (t - p))
        .sum();
    if ss_tot <= 0.0 {
        return if ss_res <= 1e-12 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Ordinary least squares fit y = a*x + b; returns (a, b, r2).
/// Used by the Fig.5 linearity analysis (attribute vs batch size).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    let a = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let b = my - a * mx;
    let pred: Vec<f64> = xs.iter().map(|x| a * x + b).collect();
    let r2 = r_squared(&pred, ys);
    let _ = n;
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_median() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.118033988749895).abs() < 1e-9);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn mape_basic() {
        let truth = [100.0, 200.0];
        let pred = [110.0, 180.0];
        assert!((mape(&pred, &truth) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn mape_skips_zero_truth() {
        assert_eq!(mape(&[5.0], &[0.0]), 0.0);
    }

    #[test]
    fn perfect_linear_fit() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-9);
        assert!((b - 1.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn r2_of_mean_prediction_is_zero() {
        let truth = [1.0, 2.0, 3.0];
        let pred = [2.0, 2.0, 2.0];
        assert!(r_squared(&pred, &truth).abs() < 1e-9);
    }

    #[test]
    fn rmse_zero_for_exact() {
        let t = [1.0, 2.0];
        assert_eq!(rmse(&t, &t), 0.0);
    }

    #[test]
    fn median_and_percentile_survive_nan_and_pin_finite_order() {
        // NaN inputs must not panic (the pre-total_cmp sort did).
        let with_nan = [3.0, f64::NAN, 1.0];
        let _ = median(&with_nan);
        let _ = percentile(&with_nan, 50.0);
        // On finite inputs — ties, negative zero included — the order
        // total_cmp produces matches the reference partial_cmp sort
        // bit-for-bit, so every downstream statistic is unchanged.
        let xs = [2.0, -0.0, 2.0, 0.0, -1.5, 3.25, 0.0];
        let mut reference = xs.to_vec();
        reference.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut total = xs.to_vec();
        total.sort_by(f64::total_cmp);
        for (r, t) in reference.iter().zip(&total) {
            assert_eq!(r.to_bits(), t.to_bits());
        }
        assert_eq!(median(&xs).to_bits(), 0.0f64.to_bits());
    }
}
