//! The one work-stealing drain loop behind every flat schedule in the
//! toolflow: profiling units ([`crate::profiler::profile`]), in-process
//! campaign shards, and campaign worker processes all pull indices from a
//! shared cursor so a slow item never blocks the remaining lanes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `work(0..n)` across `workers` scoped threads, each pulling the
/// next index from a shared cursor (work stealing). Returns `(index,
/// output)` pairs in completion order — sort by index to restore the
/// canonical order.
pub(crate) fn drain_indexed<T, F>(n: usize, workers: usize, work: F) -> Vec<(usize, T)>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let cursor = AtomicUsize::new(0);
    let results = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        let cursor = &cursor;
        let results = &results;
        let work = &work;
        for _ in 0..workers.clamp(1, n.max(1)) {
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = work(i);
                results.lock().unwrap().push((i, out));
            });
        }
    });
    results.into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drains_every_index_exactly_once() {
        for workers in [1, 3, 16] {
            let mut got = drain_indexed(10, workers, |i| i * 2);
            got.sort_by_key(|&(i, _)| i);
            let expect: Vec<(usize, usize)> = (0..10).map(|i| (i, i * 2)).collect();
            assert_eq!(got, expect, "workers={workers}");
        }
    }

    #[test]
    fn empty_input_is_fine() {
        assert!(drain_indexed(0, 4, |i| i).is_empty());
    }
}
