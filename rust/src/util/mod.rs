//! Dependency-free utilities: deterministic RNG, statistics, JSON,
//! property-testing and a micro benchmark harness. These replace the
//! crates (`rand`, `serde`, `proptest`, `criterion`) that are unavailable
//! in the offline build environment — see DESIGN.md §1.

pub mod atomic_fs;
pub mod backoff;
pub mod bench_harness;
pub mod fault;
pub mod fingerprint;
pub mod json;
pub mod pool;
pub mod prop;
pub mod queue;
pub mod rng;
pub mod stats;
