//! Minimal JSON reader/writer.
//!
//! The offline environment has no `serde` facade crate, so datasets, fitted
//! forests and experiment reports are (de)serialised with this small,
//! dependency-free JSON module. It supports the full JSON value model with
//! f64 numbers, which is all the toolflow needs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x.round() as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Convenience: expect an array of f64.
    pub fn f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64()).collect())
    }

    /// Serialise to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "3.5", "-2", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, {"b": "x\ny"}], "c": null, "d": true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("quote\" slash\\ nl\n".into());
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(parsed, v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse("\"\\u0041\"").unwrap();
        assert_eq!(v.as_str(), Some("A"));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn integer_format_compact() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn f64_vec_helper() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.f64_vec().unwrap(), vec![1.0, 2.0, 3.0]);
    }
}
