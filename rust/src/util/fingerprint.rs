//! FNV-1a fingerprint primitives — the single hashing substrate behind
//! every 64-bit topology fingerprint in the toolflow
//! ([`crate::engine::cache::config_fingerprint`],
//! [`crate::engine::cache::graph_fingerprint`] and the arena overlay
//! fingerprint `GraphArena::fingerprint`). Keeping the primitives in one
//! place is what lets the overlay path hash *exactly* the byte stream the
//! materialized-graph path hashes, so the two fingerprints are equal by
//! construction (asserted across zoo × strategies × levels by
//! `rust/tests/overlay_equivalence.rs`).

pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
pub const FNV_PRIME: u64 = 0x100_0000_01b3;

/// Fold `bytes` into the running hash `h`.
#[inline]
pub fn fnv_bytes(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Fold a `u64` (little-endian bytes) into the running hash.
#[inline]
pub fn fnv_u64(h: u64, v: u64) -> u64 {
    fnv_bytes(h, &v.to_le_bytes())
}

/// Fold the decimal ASCII rendering of `v` into the running hash —
/// byte-identical to hashing `v.to_string()` without the allocation (the
/// overlay fingerprint substitutes conv widths into a precompiled byte
/// program this way).
#[inline]
pub fn fnv_decimal(h: u64, mut v: usize) -> u64 {
    let mut buf = [0u8; 20];
    let mut i = buf.len();
    loop {
        i -= 1;
        buf[i] = b'0' + (v % 10) as u8;
        v /= 10;
        if v == 0 {
            break;
        }
    }
    fnv_bytes(h, &buf[i..])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decimal_matches_to_string() {
        for v in [0usize, 1, 9, 10, 64, 999, 1000, 123_456_789, usize::MAX] {
            assert_eq!(
                fnv_decimal(FNV_OFFSET, v),
                fnv_bytes(FNV_OFFSET, v.to_string().as_bytes()),
                "decimal hash mismatch at {v}"
            );
        }
    }

    #[test]
    fn bytes_and_u64_compose() {
        let a = fnv_u64(fnv_bytes(FNV_OFFSET, b"x/"), 7);
        let b = fnv_u64(fnv_bytes(FNV_OFFSET, b"x/"), 7);
        assert_eq!(a, b);
        assert_ne!(a, fnv_u64(fnv_bytes(FNV_OFFSET, b"y/"), 7));
    }
}
