//! In-repo property-based testing helper.
//!
//! `proptest` is not available in the offline build, so this module gives a
//! small deterministic harness in its spirit: run a property over many
//! random cases drawn from a seeded [`Pcg64`], and on failure re-run a
//! simple shrinking loop (halving numeric case parameters) to report a
//! minimal-ish failing case.

use super::rng::Pcg64;

/// Run `prop` over `cases` random inputs produced by `gen`.
///
/// On failure, tries the shrink candidates produced by `shrink` (smallest
/// first is not required; the loop keeps iterating while any candidate still
/// fails) and panics with the final minimal failing case.
pub fn check<T, G, S, P>(seed: u64, cases: usize, mut gen: G, shrink: S, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Pcg64) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Pcg64::new(seed);
    for case_idx in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Shrink: repeatedly move to any failing shrink candidate.
            let mut current = input.clone();
            let mut current_msg = msg;
            let mut budget = 200usize;
            'outer: while budget > 0 {
                for cand in shrink(&current) {
                    budget = budget.saturating_sub(1);
                    if let Err(m) = prop(&cand) {
                        current = cand;
                        current_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={seed}, case {case_idx}/{cases}):\n  input: {current:?}\n  error: {current_msg}"
            );
        }
    }
}

/// Shorthand for properties without shrinking.
pub fn check_no_shrink<T, G, P>(seed: u64, cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Pcg64) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    check(seed, cases, gen, |_| Vec::new(), prop);
}

/// Helper: assert-like conversion for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_no_shrink(
            1,
            50,
            |rng| rng.gen_range(100),
            |&x| {
                let _ = x;
                Ok(())
            },
        );
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check_no_shrink(
            2,
            50,
            |rng| rng.gen_range(100),
            |&x| ensure(x < 90, format!("x={x} too big")),
        );
    }

    #[test]
    fn shrinking_reduces_case() {
        let result = std::panic::catch_unwind(|| {
            check(
                3,
                100,
                |rng| 50 + rng.gen_range(1000),
                |&x| if x > 10 { vec![x / 2, x - 1] } else { vec![] },
                |&x| ensure(x < 40, format!("x={x}")),
            );
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        // Shrinker should get close to the boundary (40), far below the
        // initial >=50 values.
        let shown: usize = msg
            .split("input: ")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(shown >= 40 && shown <= 79, "shrunk to {shown}");
    }
}
