//! Bounded blocking submission queue — the channel plumbing of the
//! serving layer ([`crate::serve`]).
//!
//! `std::sync::mpsc` cannot express the scheduler's two needs in one
//! primitive: a *blocking* bounded push (admission control — a producer
//! that outruns the consumer waits instead of growing the queue without
//! bound) and an atomic *drain* of the whole backlog (the serving loop
//! coalesces every queued request into one batched evaluation). This is
//! a dependency-free Mutex+Condvar implementation of exactly those two
//! operations, multi-producer / single-consumer by convention.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded MPSC queue with blocking `push` (backpressure) and batch
/// `drain` (coalescing). See module docs.
pub struct BoundedQueue<T> {
    capacity: usize,
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

impl<T> BoundedQueue<T> {
    /// A queue admitting at most `capacity` items (clamped to ≥ 1).
    pub fn new(capacity: usize) -> BoundedQueue<T> {
        BoundedQueue {
            capacity: capacity.max(1),
            state: Mutex::new(State {
                items: VecDeque::new(),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Queued items right now (racy by nature; diagnostics only).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue `item`, blocking while the queue is full — the
    /// backpressure that keeps producers from outrunning the consumer.
    /// Returns the item back if the queue was closed (then or while
    /// waiting), so the caller can report the rejection.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if s.closed {
                return Err(item);
            }
            if s.items.len() < self.capacity {
                s.items.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            s = self.not_full.wait(s).unwrap();
        }
    }

    /// Dequeue up to `max` items, blocking until at least one is
    /// available. Returns an empty vec only when the queue is closed
    /// *and* fully drained — the consumer's termination signal. Items
    /// come out in push order.
    pub fn drain(&self, max: usize) -> Vec<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if !s.items.is_empty() {
                let take = max.clamp(1, s.items.len());
                let out: Vec<T> = s.items.drain(..take).collect();
                self.not_full.notify_all();
                return out;
            }
            if s.closed {
                return Vec::new();
            }
            s = self.not_empty.wait(s).unwrap();
        }
    }

    /// Close the queue: pending `push` calls fail, already-queued items
    /// remain drainable, and `drain` returns empty once the backlog is
    /// gone.
    pub fn close(&self) {
        let mut s = self.state.lock().unwrap();
        s.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_then_drain_preserves_order() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 5);
        assert_eq!(q.drain(3), vec![0, 1, 2]);
        assert_eq!(q.drain(usize::MAX), vec![3, 4]);
        assert!(q.is_empty());
    }

    #[test]
    fn full_queue_blocks_push_until_drained() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0usize).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || q2.push(1).is_ok());
        // The pusher must wait on the full queue until we make room.
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.drain(1), vec![0]);
        assert!(pusher.join().unwrap());
        assert_eq!(q.drain(1), vec![1]);
    }

    #[test]
    fn close_rejects_pushes_but_drains_backlog() {
        let q = BoundedQueue::new(4);
        q.push(7).unwrap();
        q.close();
        assert_eq!(q.push(8), Err(8));
        assert_eq!(q.drain(4), vec![7]);
        assert!(q.drain(4).is_empty(), "closed and empty terminates drain");
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q = Arc::new(BoundedQueue::<usize>::new(4));
        let q2 = Arc::clone(&q);
        let consumer = std::thread::spawn(move || q2.drain(4));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(consumer.join().unwrap().is_empty());
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let q = BoundedQueue::new(0);
        assert_eq!(q.capacity(), 1);
        q.push(1).unwrap();
        assert_eq!(q.drain(1), vec![1]);
    }
}
