//! The Once-For-All case study substrate (Sec. 6.4): an elastic
//! OFA-ResNet50 architecture space, a documented synthetic accuracy proxy,
//! and the constrained evolutionary search whose per-candidate attribute
//! estimation is the hot path the paper's models accelerate ~200×.

pub mod accuracy;
pub mod evolution;
pub mod supernet;

pub use accuracy::{
    capacity, capacity_from_convs, initial_accuracy, initial_accuracy_from_capacity,
    initial_accuracy_plan, retrained_accuracy, retrained_accuracy_plan, Subset, ALL_SUBSETS,
};
pub use evolution::{
    evolutionary_search, Attributes, CandidateEval, Constraints, EsConfig, EsResult,
    GenerationOracle, PlanOracle,
};
pub use supernet::{SubnetConfig, BASE_DEPTHS, EXPAND_CHOICES, WIDTH_CHOICES};
