//! Synthetic accuracy proxy for the OFA case study (documented
//! substitution, DESIGN.md §1: no ILSVRC'12 here).
//!
//! Table 2's qualitative structure is: (1) initial accuracy increases
//! monotonically with sub-network capacity with diminishing returns;
//! (2) retraining on a subset adds a subset-dependent boost that is larger
//! for narrow-domain subsets (off-road +4.2pp at A) and larger for smaller
//! networks; (3) searched networks (A, B) retrained can beat the
//! un-retrained MAX. The proxy encodes exactly that, with constants set
//! from Table 2's MAX/MIN rows and seeded noise for realism.

use crate::ir::{ConvInfo, Graph, NetworkPlan};
use crate::util::rng::{hash_seed, Pcg64};

use super::supernet::SubnetConfig;

/// The four autonomous-driving ILSVRC'12 subsets (App. D).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Subset {
    City,
    OffRoad,
    Motorway,
    CountrySide,
}

pub const ALL_SUBSETS: [Subset; 4] = [
    Subset::City,
    Subset::OffRoad,
    Subset::Motorway,
    Subset::CountrySide,
];

impl Subset {
    pub fn name(&self) -> &'static str {
        match self {
            Subset::City => "city",
            Subset::OffRoad => "off-road",
            Subset::Motorway => "motorway",
            Subset::CountrySide => "country-side",
        }
    }

    /// (initial accuracy at MIN capacity, at MAX capacity, retraining boost
    /// scale) — from Table 2's MIN/MAX rows.
    fn constants(&self) -> (f64, f64, f64) {
        match self {
            Subset::City => (76.4, 82.0, 2.6),
            Subset::OffRoad => (79.6, 86.2, 8.4),
            Subset::Motorway => (70.8, 78.3, 6.4),
            Subset::CountrySide => (77.0, 82.4, 2.5),
        }
    }
}

/// Normalised capacity in [0,1]: log-FLOPs position between the MIN and
/// MAX sub-networks.
pub fn capacity(graph: &Graph) -> f64 {
    capacity_from_convs(&graph.conv_infos().expect("valid graph"))
}

/// As [`capacity`] from pre-extracted conv summaries (the search hot path
/// reads them off the candidate's compiled [`NetworkPlan`]).
pub fn capacity_from_convs(convs: &[ConvInfo]) -> f64 {
    let flops: f64 = convs.iter().map(|c| c.fwd_macs()).sum();
    let min_flops = min_max_flops().0;
    let max_flops = min_max_flops().1;
    ((flops.ln() - min_flops.ln()) / (max_flops.ln() - min_flops.ln())).clamp(0.0, 1.0)
}

fn min_max_flops() -> (f64, f64) {
    // Computed once per process.
    use std::sync::OnceLock;
    static CELL: OnceLock<(f64, f64)> = OnceLock::new();
    *CELL.get_or_init(|| {
        let f = |c: SubnetConfig| -> f64 {
            c.build()
                .conv_infos()
                .unwrap()
                .iter()
                .map(|ci| ci.fwd_macs())
                .sum()
        };
        (f(SubnetConfig::min()), f(SubnetConfig::max()))
    })
}

/// Top-1 accuracy (%) of the *deployed* (not retrained) sub-network on a
/// subset. Deterministic per (config, subset).
pub fn initial_accuracy(config: &SubnetConfig, graph: &Graph, subset: Subset) -> f64 {
    initial_accuracy_from_capacity(config, capacity(graph), subset)
}

/// As [`initial_accuracy`] over the candidate's compiled plan.
pub fn initial_accuracy_plan(config: &SubnetConfig, plan: &NetworkPlan<'_>, subset: Subset) -> f64 {
    initial_accuracy_from_capacity(config, capacity_from_convs(plan.conv_infos()), subset)
}

/// As [`initial_accuracy`] from a precomputed capacity scalar — the entry
/// point for engine-cached candidates, whose capacity is memoised
/// alongside the predicted attributes so a cache hit skips the graph
/// build entirely.
pub fn initial_accuracy_from_capacity(config: &SubnetConfig, c: f64, subset: Subset) -> f64 {
    let (lo, hi, _) = subset.constants();
    // Diminishing returns in capacity.
    let acc = lo + (hi - lo) * c.powf(0.65);
    let mut rng = Pcg64::new(hash_seed(&format!("acc/{config:?}/{}", subset.name())));
    (acc + rng.normal() * 0.25).clamp(0.0, 99.0)
}

/// Top-1 accuracy after retraining for 1 epoch on the subset (the DaPR
/// step): smaller networks specialise more; narrow subsets gain more.
pub fn retrained_accuracy(config: &SubnetConfig, graph: &Graph, subset: Subset) -> f64 {
    retrained_accuracy_from_capacity(config, capacity(graph), subset)
}

/// As [`retrained_accuracy`] over the candidate's compiled plan.
pub fn retrained_accuracy_plan(
    config: &SubnetConfig,
    plan: &NetworkPlan<'_>,
    subset: Subset,
) -> f64 {
    retrained_accuracy_from_capacity(config, capacity_from_convs(plan.conv_infos()), subset)
}

fn retrained_accuracy_from_capacity(config: &SubnetConfig, c: f64, subset: Subset) -> f64 {
    let (_, _, boost) = subset.constants();
    let initial = initial_accuracy_from_capacity(config, c, subset);
    let gain = boost * (1.0 - 0.45 * c);
    let mut rng = Pcg64::new(hash_seed(&format!("ret/{config:?}/{}", subset.name())));
    (initial + gain + rng.normal() * 0.2).clamp(0.0, 99.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_bounds() {
        assert!(capacity(&SubnetConfig::min().build()) < 0.05);
        assert!(capacity(&SubnetConfig::max().build()) > 0.95);
    }

    #[test]
    fn accuracy_monotone_in_capacity() {
        let min = SubnetConfig::min();
        let max = SubnetConfig::max();
        let gmin = min.build();
        let gmax = max.build();
        for s in ALL_SUBSETS {
            let a_min = initial_accuracy(&min, &gmin, s);
            let a_max = initial_accuracy(&max, &gmax, s);
            assert!(a_max > a_min + 3.0, "{}: {a_min} !<< {a_max}", s.name());
        }
    }

    #[test]
    fn table2_max_row_reproduced() {
        // MAX initial accuracies: 82.0 / 86.2 / 78.3 / 82.4 (±1pp noise).
        let max = SubnetConfig::max();
        let g = max.build();
        for (s, want) in ALL_SUBSETS.iter().zip([82.0, 86.2, 78.3, 82.4]) {
            let got = initial_accuracy(&max, &g, *s);
            assert!((got - want).abs() < 1.0, "{}: {got} vs {want}", s.name());
        }
    }

    #[test]
    fn retraining_gains_larger_for_small_nets_and_offroad() {
        let min = SubnetConfig::min();
        let max = SubnetConfig::max();
        let gmin = min.build();
        let gmax = max.build();
        let gain = |c: &SubnetConfig, g: &Graph, s: Subset| {
            retrained_accuracy(c, g, s) - initial_accuracy(c, g, s)
        };
        // smaller net gains more on the same subset
        assert!(gain(&min, &gmin, Subset::OffRoad) > gain(&max, &gmax, Subset::OffRoad));
        // off-road gains more than city (narrow domain)
        assert!(gain(&min, &gmin, Subset::OffRoad) > gain(&min, &gmin, Subset::City) + 2.0);
        // Table 2 MIN off-road: 79.6 → 88.1 (+8.5)
        let ret = retrained_accuracy(&min, &gmin, Subset::OffRoad);
        assert!((ret - 88.1).abs() < 1.5, "MIN off-road retrained {ret}");
    }

    #[test]
    fn deterministic() {
        let c = SubnetConfig::max();
        let g = c.build();
        assert_eq!(
            initial_accuracy(&c, &g, Subset::City),
            initial_accuracy(&c, &g, Subset::City)
        );
    }
}
