//! Elastic OFA-ResNet50 architecture space (Cai et al., ICLR 2020 — the
//! paper's [3]). "The OFA network used is OFAResNet50 ... has the same
//! building blocks as ResNet50, but a slightly different connectivity"
//! (Sec. 6.4). We reproduce the *architecture space* — elastic depth,
//! expand ratio and width multiplier per stage — which is what the search
//! and performance-prediction experiments need (weights are not required;
//! accuracy comes from the documented proxy in `accuracy.rs`).

use crate::ir::{Act, Graph, GraphBuilder, NodeId};
use crate::models::make_divisible;
use crate::util::rng::Pcg64;

/// Width-multiplier choices.
pub const WIDTH_CHOICES: [f64; 3] = [0.65, 0.8, 1.0];
/// Bottleneck expand-ratio choices (mid channels = width × expand).
pub const EXPAND_CHOICES: [f64; 3] = [0.20, 0.25, 0.35];
/// Base (maximum) blocks per stage.
pub const BASE_DEPTHS: [usize; 4] = [3, 4, 6, 3];
/// Minimum blocks per stage.
pub const MIN_DEPTH: usize = 2;
/// Base stage output widths.
const STAGE_WIDTHS: [usize; 4] = [256, 512, 1024, 2048];

/// One sub-network configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SubnetConfig {
    /// Blocks per stage, `MIN_DEPTH ..= BASE_DEPTHS[i]`.
    pub depth: [usize; 4],
    /// Expand-ratio index per stage (into EXPAND_CHOICES).
    pub expand: [usize; 4],
    /// Global width-multiplier index (into WIDTH_CHOICES).
    pub width: usize,
}

impl SubnetConfig {
    /// The largest extractable sub-network (Table 2 "MAX").
    pub fn max() -> SubnetConfig {
        SubnetConfig {
            depth: BASE_DEPTHS,
            expand: [2; 4],
            width: 2,
        }
    }

    /// The smallest extractable sub-network (Table 2 "MIN").
    pub fn min() -> SubnetConfig {
        SubnetConfig {
            depth: [MIN_DEPTH; 4],
            expand: [0; 4],
            width: 0,
        }
    }

    /// Uniform random configuration.
    pub fn sample(rng: &mut Pcg64) -> SubnetConfig {
        let mut depth = [0usize; 4];
        let mut expand = [0usize; 4];
        for i in 0..4 {
            depth[i] = MIN_DEPTH + rng.gen_range(BASE_DEPTHS[i] - MIN_DEPTH + 1);
            expand[i] = rng.gen_range(EXPAND_CHOICES.len());
        }
        SubnetConfig {
            depth,
            expand,
            width: rng.gen_range(WIDTH_CHOICES.len()),
        }
    }

    /// Mutate each gene independently with probability `p`.
    pub fn mutate(&self, rng: &mut Pcg64, p: f64) -> SubnetConfig {
        let mut out = *self;
        for i in 0..4 {
            if rng.chance(p) {
                out.depth[i] = MIN_DEPTH + rng.gen_range(BASE_DEPTHS[i] - MIN_DEPTH + 1);
            }
            if rng.chance(p) {
                out.expand[i] = rng.gen_range(EXPAND_CHOICES.len());
            }
        }
        if rng.chance(p) {
            out.width = rng.gen_range(WIDTH_CHOICES.len());
        }
        out
    }

    /// Uniform crossover.
    pub fn crossover(&self, other: &SubnetConfig, rng: &mut Pcg64) -> SubnetConfig {
        let mut out = *self;
        for i in 0..4 {
            if rng.chance(0.5) {
                out.depth[i] = other.depth[i];
            }
            if rng.chance(0.5) {
                out.expand[i] = other.expand[i];
            }
        }
        if rng.chance(0.5) {
            out.width = other.width;
        }
        out
    }

    /// Stem conv width for this configuration's width multiplier.
    fn stem_width(&self) -> usize {
        make_divisible(64.0 * WIDTH_CHOICES[self.width], 8)
    }

    /// `(out_c, mid_c)` of stage `si` under this configuration's width
    /// multiplier and expand ratio — the single width formula shared by
    /// [`SubnetConfig::build`] and [`SubnetConfig::fill_conv_widths`], so
    /// the graph builder and the overlay fast path cannot drift.
    fn stage_dims(&self, si: usize) -> (usize, usize) {
        let w_mult = WIDTH_CHOICES[self.width];
        let out_c = make_divisible(STAGE_WIDTHS[si] as f64 * w_mult, 8);
        let mid_c = make_divisible(out_c as f64 * EXPAND_CHOICES[self.expand[si]], 8);
        (out_c, mid_c)
    }

    /// This configuration's conv `out_c` sequence, in the exact
    /// topological order [`SubnetConfig::build`] adds convolutions
    /// (stem.0, stem.1, then per block conv1/conv2/conv3 and, for the
    /// first block of a stage, the projection). Writing these widths into
    /// a [`PruneOverlay`](crate::ir::PruneOverlay) over the depth-key
    /// arena reproduces the built graph's analysis without building it —
    /// the engine's zero-allocation miss path.
    pub fn fill_conv_widths(&self, out: &mut Vec<usize>) {
        out.clear();
        let stem_w = self.stem_width();
        out.push(stem_w);
        out.push(stem_w);
        for (si, &base_blocks) in BASE_DEPTHS.iter().enumerate() {
            let blocks = self.depth[si].min(base_blocks);
            let (out_c, mid_c) = self.stage_dims(si);
            for bi in 0..blocks {
                out.push(mid_c); // conv1
                out.push(mid_c); // conv2
                out.push(out_c); // conv3
                if bi == 0 {
                    out.push(out_c); // projection shortcut
                }
            }
        }
    }

    /// The arena cache key: only the depth genes change the graph's
    /// *structure* (node count / wiring); expand and width only move conv
    /// widths, which overlays express.
    pub fn depth_key(&self) -> [usize; 4] {
        self.depth
    }

    /// A canonical configuration with the given depths — the base network
    /// an arena is compiled from. Which expand/width genes it carries is
    /// irrelevant: candidates overwrite every conv width via the overlay.
    pub fn depth_representative(depth: [usize; 4]) -> SubnetConfig {
        SubnetConfig {
            depth,
            expand: [0; 4],
            width: 0,
        }
    }

    /// Build the sub-network IR graph (ImageNet geometry, 1000 classes).
    pub fn build(&self) -> Graph {
        let mut g = Graph::new(format!("ofa-resnet50-{self:?}"));
        let x = g.input(3, 224, 224);
        // OFA-ResNet50 stem: two 3x3 convs instead of one 7x7 ("slightly
        // different connectivity" vs plain ResNet50).
        let stem_w = self.stem_width();
        let s1 = g.conv_bn_act("stem.0", x, stem_w, 3, 2, 1, Act::Relu);
        let s2 = g.conv_bn_act("stem.1", s1, stem_w, 3, 1, 1, Act::Relu);
        let mut cur = g.maxpool("stem.pool", s2, 3, 2, 1);
        for (si, &base_blocks) in BASE_DEPTHS.iter().enumerate() {
            let blocks = self.depth[si].min(base_blocks);
            let (out_c, mid_c) = self.stage_dims(si);
            for bi in 0..blocks {
                let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                let name = format!("stage{si}.block{bi}");
                cur = bottleneck(&mut g, &name, cur, mid_c, out_c, stride, bi == 0);
            }
        }
        g.classifier(cur, 1000);
        g
    }
}

fn bottleneck(
    g: &mut Graph,
    name: &str,
    input: NodeId,
    mid: usize,
    out: usize,
    stride: usize,
    project: bool,
) -> NodeId {
    let c1 = g.conv_bn_act(&format!("{name}.conv1"), input, mid, 1, 1, 0, Act::Relu);
    let c2 = g.conv_bn_act(&format!("{name}.conv2"), c1, mid, 3, stride, 1, Act::Relu);
    let c3 = g.conv_bn(&format!("{name}.conv3"), c2, out, 1, 1, 0);
    let identity = if project {
        g.conv_bn(&format!("{name}.proj"), input, out, 1, stride, 0)
    } else {
        input
    };
    let j = g.add_join(&format!("{name}.add"), &[c3, identity]);
    g.relu(&format!("{name}.relu"), j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_much_larger_than_min() {
        let max = SubnetConfig::max().build();
        let min = SubnetConfig::min().build();
        let pmax = max.model_size_mb().unwrap();
        let pmin = min.model_size_mb().unwrap();
        // Table 2: 192 MB vs 26 MB (7.4x). Our space should give >= 3x.
        assert!(pmax / pmin > 3.0, "MAX {pmax:.0}MB MIN {pmin:.0}MB");
        assert!(pmax > 50.0 && pmax < 300.0, "MAX size {pmax:.0}MB");
    }

    #[test]
    fn random_samples_are_valid_and_diverse() {
        let mut rng = Pcg64::new(1);
        let mut sizes = Vec::new();
        for _ in 0..30 {
            let c = SubnetConfig::sample(&mut rng);
            let g = c.build();
            g.infer_shapes().unwrap();
            sizes.push(g.param_count().unwrap());
        }
        sizes.sort_unstable();
        assert!(sizes[29] as f64 / sizes[0] as f64 > 1.5, "no diversity");
    }

    #[test]
    fn mutation_stays_in_bounds() {
        let mut rng = Pcg64::new(2);
        let mut c = SubnetConfig::max();
        for _ in 0..200 {
            c = c.mutate(&mut rng, 0.3);
            for i in 0..4 {
                assert!(c.depth[i] >= MIN_DEPTH && c.depth[i] <= BASE_DEPTHS[i]);
                assert!(c.expand[i] < EXPAND_CHOICES.len());
            }
            assert!(c.width < WIDTH_CHOICES.len());
        }
    }

    #[test]
    fn crossover_mixes_genes() {
        let mut rng = Pcg64::new(3);
        let a = SubnetConfig::max();
        let b = SubnetConfig::min();
        let mut saw_a = false;
        let mut saw_b = false;
        for _ in 0..50 {
            let c = a.crossover(&b, &mut rng);
            if c.depth[0] == a.depth[0] {
                saw_a = true;
            }
            if c.depth[0] == b.depth[0] {
                saw_b = true;
            }
        }
        assert!(saw_a && saw_b);
    }

    #[test]
    fn conv_widths_match_built_graph() {
        // The overlay width sequence must reproduce the built graph's conv
        // out_c values in topological order — for the extremes and a wide
        // random sample of the space.
        let mut rng = Pcg64::new(0x0fa);
        let mut configs = vec![SubnetConfig::min(), SubnetConfig::max()];
        configs.extend((0..100).map(|_| SubnetConfig::sample(&mut rng)));
        let mut widths = Vec::new();
        for c in configs {
            c.fill_conv_widths(&mut widths);
            let g = c.build();
            let built: Vec<usize> = g
                .nodes
                .iter()
                .filter_map(|n| match &n.op {
                    crate::ir::Op::Conv2d { out_c, .. } => Some(*out_c),
                    _ => None,
                })
                .collect();
            assert_eq!(widths, built, "width sequence drifted for {c:?}");
            // Same depths ⇒ same structure as the arena representative.
            let rep = SubnetConfig::depth_representative(c.depth_key()).build();
            assert_eq!(rep.nodes.len(), g.nodes.len());
            for (a, b) in rep.nodes.iter().zip(&g.nodes) {
                assert_eq!(a.op.kind(), b.op.kind());
                assert_eq!(a.inputs, b.inputs);
            }
        }
    }

    #[test]
    fn same_building_blocks_as_resnet50() {
        // The subnet uses 1x1/3x3/1x1 bottlenecks like ResNet50.
        let g = SubnetConfig::max().build();
        let infos = g.conv_infos().unwrap();
        assert!(infos.iter().any(|c| c.k == 3));
        assert!(infos.iter().any(|c| c.k == 1));
        assert!(infos.iter().all(|c| c.g == 1));
    }
}
