//! Evolutionary sub-network search under hard resource constraints —
//! Sec. 6.4: "The ES algorithm starts with a population of 100
//! sub-networks and runs 500 iterations ... at least 50,000 sub-networks
//! sampled", every candidate requiring estimates of Γ (training memory),
//! γ (inference memory) and φ (inference latency).
//!
//! The predictor is pluggable so the experiment can compare: (a) the naive
//! approach — on-device profiling at 20 s/sample — and (b) the paper's
//! approach — random-forest inference (natively or through the XLA
//! artifact). Each candidate's graph is compiled once into a
//! [`NetworkPlan`] which serves the predictor (features / simulator at
//! every batch size) and the accuracy proxy, so a candidate costs exactly
//! one shape-inference pass.

use std::time::{Duration, Instant};

use crate::ir::NetworkPlan;
use crate::util::rng::Pcg64;

use super::accuracy::{initial_accuracy_plan, Subset};
use super::supernet::SubnetConfig;

/// Hard constraints on the three attributes (MB, MB, ms).
#[derive(Clone, Copy, Debug)]
pub struct Constraints {
    /// Training memory Γ at the retraining batch size.
    pub gamma_train_mb: f64,
    /// Inference memory γ at batch 1.
    pub gamma_infer_mb: f64,
    /// Inference latency φ at batch 1.
    pub phi_infer_ms: f64,
}

impl Constraints {
    pub fn unconstrained() -> Constraints {
        Constraints {
            gamma_train_mb: f64::INFINITY,
            gamma_infer_mb: f64::INFINITY,
            phi_infer_ms: f64::INFINITY,
        }
    }
}

/// Attribute estimates for one candidate.
#[derive(Clone, Copy, Debug)]
pub struct Attributes {
    pub gamma_train_mb: f64,
    pub gamma_infer_mb: f64,
    pub phi_infer_ms: f64,
}

impl Attributes {
    pub fn satisfies(&self, c: &Constraints) -> bool {
        self.gamma_train_mb <= c.gamma_train_mb
            && self.gamma_infer_mb <= c.gamma_infer_mb
            && self.phi_infer_ms <= c.phi_infer_ms
    }
}

/// ES hyperparameters (paper defaults).
#[derive(Clone, Debug)]
pub struct EsConfig {
    pub population: usize,
    pub iterations: usize,
    pub parent_fraction: f64,
    pub mutation_prob: f64,
    pub seed: u64,
}

impl Default for EsConfig {
    fn default() -> Self {
        EsConfig {
            population: 100,
            iterations: 500,
            parent_fraction: 0.25,
            mutation_prob: 0.25,
            seed: 0x0fa,
        }
    }
}

/// Search outcome.
#[derive(Clone, Debug)]
pub struct EsResult {
    pub best: SubnetConfig,
    pub best_fitness: f64,
    pub best_attrs: Attributes,
    /// Total candidates whose attributes were estimated (includes
    /// constraint-rejected ones — each costs one prediction).
    pub samples: usize,
    pub elapsed: Duration,
}

/// Run the evolutionary search.
///
/// * `predict` estimates (Γ, γ, φ) for a candidate from its compiled
///   [`NetworkPlan`] — the cost centre the paper's models accelerate 200×.
///   The same plan then feeds the accuracy proxy, so each candidate is
///   analysed exactly once.
/// * `subset` selects the accuracy-proxy fitness target.
pub fn evolutionary_search(
    constraints: &Constraints,
    cfg: &EsConfig,
    subset: Subset,
    mut predict: impl FnMut(&SubnetConfig, &NetworkPlan) -> Attributes,
) -> EsResult {
    let started = Instant::now();
    let mut rng = Pcg64::new(cfg.seed);
    let mut samples = 0usize;

    let evaluate = |c: &SubnetConfig,
                        samples: &mut usize,
                        predict: &mut dyn FnMut(&SubnetConfig, &NetworkPlan) -> Attributes|
     -> Option<(f64, Attributes)> {
        let g = c.build();
        let plan = NetworkPlan::build(&g).expect("OFA sub-networks are always valid");
        *samples += 1;
        let attrs = predict(c, &plan);
        if !attrs.satisfies(constraints) {
            return None;
        }
        Some((initial_accuracy_plan(c, &plan, subset), attrs))
    };

    // Seed population: rejection-sample valid candidates (bounded tries).
    let mut population: Vec<(SubnetConfig, f64, Attributes)> = Vec::new();
    let mut tries = 0usize;
    while population.len() < cfg.population && tries < cfg.population * 60 {
        tries += 1;
        let c = SubnetConfig::sample(&mut rng);
        if let Some((fit, attrs)) = evaluate(&c, &mut samples, &mut predict) {
            population.push((c, fit, attrs));
        }
    }
    assert!(
        !population.is_empty(),
        "constraints admit no sub-network (tried {tries} samples)"
    );

    let n_parents = ((cfg.population as f64 * cfg.parent_fraction) as usize).max(2);
    for _iter in 0..cfg.iterations {
        // Keep the fittest parents.
        population.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        population.truncate(n_parents.min(population.len()));
        // Refill with mutations + crossovers of parents.
        while population.len() < cfg.population {
            let a = rng.gen_range(n_parents.min(population.len()));
            let child = if rng.chance(0.5) {
                population[a].0.mutate(&mut rng, cfg.mutation_prob)
            } else {
                let b = rng.gen_range(n_parents.min(population.len()));
                let crossed = population[a].0.crossover(&population[b].0, &mut rng);
                crossed.mutate(&mut rng, cfg.mutation_prob * 0.5)
            };
            if let Some((fit, attrs)) = evaluate(&child, &mut samples, &mut predict) {
                population.push((child, fit, attrs));
            }
            // Rejection may loop; bail out of pathological constraint sets.
            if samples > cfg.population * (cfg.iterations + 2) * 4 {
                break;
            }
        }
    }

    population.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let (best, best_fitness, best_attrs) = population[0].clone();
    EsResult {
        best,
        best_fitness,
        best_attrs,
        samples,
        elapsed: started.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Simulator;

    fn sim_predict(
        sim: &Simulator,
    ) -> impl FnMut(&SubnetConfig, &NetworkPlan) -> Attributes + '_ {
        move |_c: &SubnetConfig, plan: &NetworkPlan| {
            let t = sim.train_step_plan(plan, 32, None);
            let i = sim.inference_plan(plan, 1, None);
            Attributes {
                gamma_train_mb: t.gamma_mb,
                gamma_infer_mb: i.gamma_mb,
                phi_infer_ms: i.phi_ms,
            }
        }
    }

    fn small_cfg(seed: u64) -> EsConfig {
        EsConfig {
            population: 12,
            iterations: 6,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn unconstrained_search_prefers_capacity() {
        let sim = Simulator::tx2();
        let r = evolutionary_search(
            &Constraints::unconstrained(),
            &small_cfg(1),
            Subset::City,
            sim_predict(&sim),
        );
        // Best fitness should approach the MAX ceiling (82.0).
        assert!(r.best_fitness > 80.0, "fitness {}", r.best_fitness);
        // samples = initial population + iterations × (pop − parents)
        assert!(r.samples >= 12 + 6 * (12 - 3), "samples = {}", r.samples);
    }

    #[test]
    fn constraints_are_respected() {
        let sim = Simulator::tx2();
        let cons = Constraints {
            gamma_train_mb: 4200.0,
            gamma_infer_mb: 1900.0,
            phi_infer_ms: 60.0,
        };
        let r = evolutionary_search(&cons, &small_cfg(2), Subset::OffRoad, sim_predict(&sim));
        assert!(r.best_attrs.satisfies(&cons), "{:?}", r.best_attrs);
        // Tighter constraints → smaller best than unconstrained MAX.
        let unc = evolutionary_search(
            &Constraints::unconstrained(),
            &small_cfg(2),
            Subset::OffRoad,
            sim_predict(&sim),
        );
        assert!(r.best_attrs.gamma_train_mb <= unc.best_attrs.gamma_train_mb + 1e-9);
    }

    #[test]
    #[should_panic(expected = "constraints admit no sub-network")]
    fn impossible_constraints_panic() {
        let sim = Simulator::tx2();
        let cons = Constraints {
            gamma_train_mb: 1.0,
            gamma_infer_mb: 1.0,
            phi_infer_ms: 0.001,
        };
        evolutionary_search(&cons, &small_cfg(3), Subset::City, sim_predict(&sim));
    }

    #[test]
    fn search_is_deterministic_given_seed() {
        let sim = Simulator::tx2();
        let a = evolutionary_search(
            &Constraints::unconstrained(),
            &small_cfg(5),
            Subset::Motorway,
            sim_predict(&sim),
        );
        let b = evolutionary_search(
            &Constraints::unconstrained(),
            &small_cfg(5),
            Subset::Motorway,
            sim_predict(&sim),
        );
        assert_eq!(a.best, b.best);
        assert_eq!(a.samples, b.samples);
    }
}
