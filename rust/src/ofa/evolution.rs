//! Evolutionary sub-network search under hard resource constraints —
//! Sec. 6.4: "The ES algorithm starts with a population of 100
//! sub-networks and runs 500 iterations ... at least 50,000 sub-networks
//! sampled", every candidate requiring estimates of Γ (training memory),
//! γ (inference memory) and φ (inference latency).
//!
//! The predictor is pluggable through [`GenerationOracle`], which answers
//! a whole generation of candidates in one call. The production
//! implementation is [`PredictionEngine`](crate::engine::PredictionEngine)
//! — batched `CompiledForest` traversals plus a fingerprint memo cache —
//! while [`PlanOracle`] adapts any per-candidate closure (simulator ground
//! truth, naive profiling) to the same interface. Candidates are generated
//! in chunks sized to exactly the population shortfall, so the candidate
//! stream is a pure function of the seed: results are identical whichever
//! oracle answers, and a cached run is bit-identical to an uncached one
//! (asserted by `rust/tests/engine_equivalence.rs`).

use std::time::{Duration, Instant};

use crate::engine::CacheStats;
use crate::ir::NetworkPlan;
use crate::util::rng::Pcg64;

use super::accuracy::{capacity_from_convs, initial_accuracy_from_capacity, Subset};
use super::supernet::SubnetConfig;

/// Hard constraints on the three attributes (MB, MB, ms).
#[derive(Clone, Copy, Debug)]
pub struct Constraints {
    /// Training memory Γ at the retraining batch size.
    pub gamma_train_mb: f64,
    /// Inference memory γ at batch 1.
    pub gamma_infer_mb: f64,
    /// Inference latency φ at batch 1.
    pub phi_infer_ms: f64,
}

impl Constraints {
    pub fn unconstrained() -> Constraints {
        Constraints {
            gamma_train_mb: f64::INFINITY,
            gamma_infer_mb: f64::INFINITY,
            phi_infer_ms: f64::INFINITY,
        }
    }
}

/// Attribute estimates for one candidate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Attributes {
    pub gamma_train_mb: f64,
    pub gamma_infer_mb: f64,
    pub phi_infer_ms: f64,
}

impl Attributes {
    pub fn satisfies(&self, c: &Constraints) -> bool {
        self.gamma_train_mb <= c.gamma_train_mb
            && self.gamma_infer_mb <= c.gamma_infer_mb
            && self.phi_infer_ms <= c.phi_infer_ms
    }
}

/// One candidate's oracle answer: the attribute estimates plus the
/// capacity scalar that feeds the accuracy proxy (memoised alongside the
/// attributes by the engine cache, so a repeated candidate skips its graph
/// build entirely).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CandidateEval {
    pub attrs: Attributes,
    /// Normalised log-FLOPs capacity in [0, 1] (see [`super::accuracy`]).
    pub capacity: f64,
}

/// A service answering (Γ, γ, φ) + capacity for a whole generation of
/// candidates in one call — the seam the search hot path hangs on.
pub trait GenerationOracle {
    /// Evaluate every candidate of one generation. Must return one eval
    /// per candidate, in order.
    fn evaluate_generation(&mut self, candidates: &[SubnetConfig]) -> Vec<CandidateEval>;

    /// Cache counters, if this oracle memoises (the engine does; plain
    /// per-candidate oracles return `None`).
    fn cache_stats(&self) -> Option<CacheStats> {
        None
    }
}

/// Adapts a per-candidate closure to [`GenerationOracle`]: builds each
/// candidate's graph and compiled [`NetworkPlan`] and hands both to the
/// closure. This is the uncached **clone+rebuild reference path**, kept
/// deliberately naive: the engine's zero-allocation arena/overlay miss
/// path must stay bit-identical to it (asserted by
/// `rust/tests/engine_equivalence.rs` and `overlay_equivalence.rs`), and
/// it is how tests plug the simulator in as ground truth.
pub struct PlanOracle<F> {
    predict: F,
}

impl<F: FnMut(&SubnetConfig, &NetworkPlan) -> Attributes> PlanOracle<F> {
    pub fn new(predict: F) -> PlanOracle<F> {
        PlanOracle { predict }
    }
}

impl<F: FnMut(&SubnetConfig, &NetworkPlan) -> Attributes> GenerationOracle for PlanOracle<F> {
    fn evaluate_generation(&mut self, candidates: &[SubnetConfig]) -> Vec<CandidateEval> {
        candidates
            .iter()
            .map(|c| {
                let g = c.build();
                let plan = NetworkPlan::build(&g).expect("OFA sub-networks are always valid");
                CandidateEval {
                    attrs: (self.predict)(c, &plan),
                    capacity: capacity_from_convs(plan.conv_infos()),
                }
            })
            .collect()
    }
}

/// ES hyperparameters (paper defaults).
#[derive(Clone, Debug)]
pub struct EsConfig {
    pub population: usize,
    pub iterations: usize,
    pub parent_fraction: f64,
    pub mutation_prob: f64,
    pub seed: u64,
}

impl Default for EsConfig {
    fn default() -> Self {
        EsConfig {
            population: 100,
            iterations: 500,
            parent_fraction: 0.25,
            mutation_prob: 0.25,
            seed: 0x0fa,
        }
    }
}

/// Search outcome.
#[derive(Clone, Debug)]
pub struct EsResult {
    pub best: SubnetConfig,
    pub best_fitness: f64,
    pub best_attrs: Attributes,
    /// Attribute estimates *requested* (includes constraint-rejected
    /// candidates and cache hits) — the paper's "sub-networks sampled"
    /// count, kept so the ≥50,000 comparison stays honest under caching.
    pub samples: usize,
    /// Estimates that actually ran the predictors (cache misses). Equals
    /// `samples` for uncached oracles.
    pub unique_evaluations: usize,
    /// Cache counter deltas for this search (`None` for uncached oracles).
    pub cache: Option<CacheStats>,
    pub elapsed: Duration,
}

impl EsResult {
    /// Canonical byte encoding of everything the search *decided*: the
    /// winning genes, its fitness and attribute estimates (exact f64
    /// bits), and the sample count. Two runs that made identical
    /// decisions encode identically.
    ///
    /// Deliberately excludes `elapsed`, `cache` and `unique_evaluations`:
    /// those describe how the oracle *served* the run (wall clock, shared
    /// cache traffic), which legitimately differs between a serial engine
    /// and a multi-tenant service. This is the equality the serving
    /// layer's bit-identity guarantee is stated in — see
    /// [`crate::serve`] and `rust/tests/serve_identity.rs`.
    pub fn deterministic_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(14 * 8);
        for d in self.best.depth {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for e in self.best.expand {
            out.extend_from_slice(&(e as u64).to_le_bytes());
        }
        out.extend_from_slice(&(self.best.width as u64).to_le_bytes());
        out.extend_from_slice(&self.best_fitness.to_bits().to_le_bytes());
        out.extend_from_slice(&self.best_attrs.gamma_train_mb.to_bits().to_le_bytes());
        out.extend_from_slice(&self.best_attrs.gamma_infer_mb.to_bits().to_le_bytes());
        out.extend_from_slice(&self.best_attrs.phi_infer_ms.to_bits().to_le_bytes());
        out.extend_from_slice(&(self.samples as u64).to_le_bytes());
        out
    }
}

/// Run the evolutionary search.
///
/// Each generation's candidates are evaluated in bulk through `oracle`
/// ([`GenerationOracle::evaluate_generation`]); chunks are sized to the
/// exact population shortfall, so the candidate stream — and therefore the
/// result — is independent of how the oracle answers (cache on/off,
/// batched or scalar).
pub fn evolutionary_search(
    constraints: &Constraints,
    cfg: &EsConfig,
    subset: Subset,
    oracle: &mut dyn GenerationOracle,
) -> EsResult {
    let started = Instant::now();
    let mut rng = Pcg64::new(cfg.seed);
    let mut samples = 0usize;
    let stats_before = oracle.cache_stats();

    // Evaluate one chunk of candidates: constraint screen + fitness.
    let evaluate_chunk = |chunk: &[SubnetConfig],
                          samples: &mut usize,
                          oracle: &mut dyn GenerationOracle|
     -> Vec<Option<(f64, Attributes)>> {
        *samples += chunk.len();
        oracle
            .evaluate_generation(chunk)
            .into_iter()
            .zip(chunk)
            .map(|(eval, c)| {
                if eval.attrs.satisfies(constraints) {
                    Some((
                        initial_accuracy_from_capacity(c, eval.capacity, subset),
                        eval.attrs,
                    ))
                } else {
                    None
                }
            })
            .collect()
    };

    // Seed population: rejection-sample valid candidates (bounded tries),
    // evaluated a shortfall-sized chunk at a time.
    let mut population: Vec<(SubnetConfig, f64, Attributes)> = Vec::new();
    let mut tries = 0usize;
    let try_cap = cfg.population * 60;
    while population.len() < cfg.population && tries < try_cap {
        let need = (cfg.population - population.len()).min(try_cap - tries);
        let chunk: Vec<SubnetConfig> = (0..need).map(|_| SubnetConfig::sample(&mut rng)).collect();
        tries += need;
        for (c, r) in chunk.iter().zip(evaluate_chunk(&chunk, &mut samples, &mut *oracle)) {
            if let Some((fit, attrs)) = r {
                population.push((*c, fit, attrs));
            }
        }
    }
    assert!(
        !population.is_empty(),
        "constraints admit no sub-network (tried {tries} samples)"
    );

    let n_parents = ((cfg.population as f64 * cfg.parent_fraction) as usize).max(2);
    // Rejection may loop; bound total estimates for pathological
    // constraint sets.
    let sample_cap = cfg.population * (cfg.iterations + 2) * 4;
    'iterations: for _iter in 0..cfg.iterations {
        // Keep the fittest parents. total_cmp: descending, same order as
        // partial_cmp on the finite fitness values the oracle produces,
        // and a NaN estimate gets a deterministic rank instead of
        // panicking mid-search.
        population.sort_by(|a, b| b.1.total_cmp(&a.1));
        population.truncate(n_parents.min(population.len()));
        // Refill with mutations + crossovers of parents, one generation
        // chunk at a time.
        while population.len() < cfg.population {
            let parent_n = n_parents.min(population.len());
            let budget = sample_cap.saturating_sub(samples);
            if budget == 0 {
                break 'iterations;
            }
            let need = (cfg.population - population.len()).min(budget);
            let chunk: Vec<SubnetConfig> = (0..need)
                .map(|_| {
                    let a = rng.gen_range(parent_n);
                    if rng.chance(0.5) {
                        population[a].0.mutate(&mut rng, cfg.mutation_prob)
                    } else {
                        let b = rng.gen_range(parent_n);
                        population[a]
                            .0
                            .crossover(&population[b].0, &mut rng)
                            .mutate(&mut rng, cfg.mutation_prob * 0.5)
                    }
                })
                .collect();
            for (c, r) in chunk.iter().zip(evaluate_chunk(&chunk, &mut samples, &mut *oracle)) {
                if let Some((fit, attrs)) = r {
                    population.push((*c, fit, attrs));
                }
            }
        }
    }

    population.sort_by(|a, b| b.1.total_cmp(&a.1));
    // All three fields are `Copy` — no need to clone the winner's tuple.
    let (best, best_fitness, best_attrs) = population[0];
    let cache = match (stats_before, oracle.cache_stats()) {
        (Some(before), Some(after)) => Some(after.since(&before)),
        _ => None,
    };
    let unique_evaluations = cache.map_or(samples, |c| c.misses as usize);
    EsResult {
        best,
        best_fitness,
        best_attrs,
        samples,
        unique_evaluations,
        cache,
        elapsed: started.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Simulator;

    #[test]
    fn descending_fitness_sort_is_nan_safe_and_order_preserving() {
        // The selection sort must (a) not panic on NaN and (b) keep the
        // exact descending order partial_cmp produced on finite values.
        let finite = [93.5, 91.25, 93.5, 88.0, 95.125];
        let mut reference = finite.to_vec();
        reference.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mut total = finite.to_vec();
        total.sort_by(|a, b| b.total_cmp(a));
        for (r, t) in reference.iter().zip(&total) {
            assert_eq!(r.to_bits(), t.to_bits());
        }
        let mut with_nan = vec![93.5, f64::NAN, 88.0];
        with_nan.sort_by(|a, b| b.total_cmp(a));
        assert_eq!(with_nan.iter().filter(|x| x.is_nan()).count(), 1);
    }

    fn sim_predict(
        sim: &Simulator,
    ) -> impl FnMut(&SubnetConfig, &NetworkPlan) -> Attributes + '_ {
        move |_c: &SubnetConfig, plan: &NetworkPlan| {
            let t = sim.train_step_plan(plan, 32, None);
            let i = sim.inference_plan(plan, 1, None);
            Attributes {
                gamma_train_mb: t.gamma_mb,
                gamma_infer_mb: i.gamma_mb,
                phi_infer_ms: i.phi_ms,
            }
        }
    }

    fn small_cfg(seed: u64) -> EsConfig {
        EsConfig {
            population: 12,
            iterations: 6,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn unconstrained_search_prefers_capacity() {
        let sim = Simulator::tx2();
        let r = evolutionary_search(
            &Constraints::unconstrained(),
            &small_cfg(1),
            Subset::City,
            &mut PlanOracle::new(sim_predict(&sim)),
        );
        // Best fitness should approach the MAX ceiling (82.0).
        assert!(r.best_fitness > 80.0, "fitness {}", r.best_fitness);
        // samples = initial population + iterations × (pop − parents)
        assert!(r.samples >= 12 + 6 * (12 - 3), "samples = {}", r.samples);
        // An uncached oracle evaluates every sample and reports no cache.
        assert_eq!(r.unique_evaluations, r.samples);
        assert!(r.cache.is_none());
    }

    #[test]
    fn constraints_are_respected() {
        let sim = Simulator::tx2();
        let cons = Constraints {
            gamma_train_mb: 4200.0,
            gamma_infer_mb: 1900.0,
            phi_infer_ms: 60.0,
        };
        let r = evolutionary_search(
            &cons,
            &small_cfg(2),
            Subset::OffRoad,
            &mut PlanOracle::new(sim_predict(&sim)),
        );
        assert!(r.best_attrs.satisfies(&cons), "{:?}", r.best_attrs);
        // Tighter constraints → smaller best than unconstrained MAX.
        let unc = evolutionary_search(
            &Constraints::unconstrained(),
            &small_cfg(2),
            Subset::OffRoad,
            &mut PlanOracle::new(sim_predict(&sim)),
        );
        assert!(r.best_attrs.gamma_train_mb <= unc.best_attrs.gamma_train_mb + 1e-9);
    }

    #[test]
    #[should_panic(expected = "constraints admit no sub-network")]
    fn impossible_constraints_panic() {
        let sim = Simulator::tx2();
        let cons = Constraints {
            gamma_train_mb: 1.0,
            gamma_infer_mb: 1.0,
            phi_infer_ms: 0.001,
        };
        evolutionary_search(
            &cons,
            &small_cfg(3),
            Subset::City,
            &mut PlanOracle::new(sim_predict(&sim)),
        );
    }

    #[test]
    fn search_is_deterministic_given_seed() {
        let sim = Simulator::tx2();
        let a = evolutionary_search(
            &Constraints::unconstrained(),
            &small_cfg(5),
            Subset::Motorway,
            &mut PlanOracle::new(sim_predict(&sim)),
        );
        let b = evolutionary_search(
            &Constraints::unconstrained(),
            &small_cfg(5),
            Subset::Motorway,
            &mut PlanOracle::new(sim_predict(&sim)),
        );
        assert_eq!(a.best, b.best);
        assert_eq!(a.samples, b.samples);
        assert_eq!(a.deterministic_bytes(), b.deterministic_bytes());
    }

    #[test]
    fn deterministic_bytes_ignore_serving_metadata() {
        let sim = Simulator::tx2();
        let r = evolutionary_search(
            &Constraints::unconstrained(),
            &small_cfg(6),
            Subset::City,
            &mut PlanOracle::new(sim_predict(&sim)),
        );
        // Serving metadata (elapsed, cache traffic, unique evaluations)
        // must not affect the encoding…
        let mut served = r.clone();
        served.elapsed = Duration::from_secs(1234);
        served.unique_evaluations = 0;
        served.cache = Some(CacheStats::default());
        assert_eq!(r.deterministic_bytes(), served.deterministic_bytes());
        // …but any decision field must.
        let mut other = r.clone();
        other.best_fitness += 1.0;
        assert_ne!(r.deterministic_bytes(), other.deterministic_bytes());
    }
}
