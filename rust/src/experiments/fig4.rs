//! E5 / Fig. 4 — training on a *basis* of networks.
//!
//! Γ/Φ forests trained on combined data from {ResNet18, MobileNetV2,
//! SqueezeNet}; tested on those three plus {ResNet50, MnasNet, GoogLeNet}
//! for both random and L1-norm pruning at all 19 levels. Paper findings:
//! modest degradation for basis networks (−1, +4.6, +2.7 pp) and
//! non-basis MnasNet (+2.55 pp) / ResNet50 (+5.6 pp); GoogLeNet degrades
//! most (+16 pp) because no basis network shares its Inception block.

use crate::device::Simulator;
use crate::profiler::{all_levels, profile, Dataset, ProfileJob};
use crate::pruning::Strategy;
use crate::util::bench_harness::{section, table};

use super::{fit_gamma_phi, ErrorRow};

pub const BASIS: [&str; 3] = ["resnet18", "mobilenetv2", "squeezenet"];
pub const NON_BASIS: [&str; 3] = ["resnet50", "mnasnet", "googlenet"];

#[derive(Clone, Debug)]
pub struct Fig4Report {
    pub rows: Vec<ErrorRow>,
}

pub fn run(sim: &Simulator, seed: u64) -> Fig4Report {
    // Combined basis training set (uniform random pruning, the 5 train
    // levels × 25 batch sizes per network).
    let mut train = Dataset::default();
    for network in BASIS {
        let graph = crate::models::by_name(network).unwrap();
        train.extend(profile(
            sim,
            &ProfileJob {
                seed,
                ..ProfileJob::new(network, &graph)
            },
        ));
    }
    let (fg, fp) = fit_gamma_phi(&train);

    // Test on all six networks, all 19 levels, both strategies.
    let levels = all_levels();
    let mut rows = Vec::new();
    for network in BASIS.iter().chain(NON_BASIS.iter()) {
        let graph = crate::models::by_name(network).unwrap();
        for strategy in [Strategy::Random, Strategy::L1Norm] {
            let test = profile(
                sim,
                &ProfileJob {
                    strategy,
                    levels: &levels,
                    seed: seed ^ 0x5eed,
                    ..ProfileJob::new(network, &graph)
                },
            );
            rows.push(ErrorRow {
                network: network.to_string(),
                strategy: if strategy == Strategy::Random {
                    "Rand".into()
                } else {
                    "L1".into()
                },
                gamma_err_pct: fg.mape(&test.x(), &test.y_gamma()),
                phi_err_pct: fp.mape(&test.x(), &test.y_phi()),
            });
        }
    }
    Fig4Report { rows }
}

pub fn print(report: &Fig4Report) {
    section("Fig. 4 — basis-of-networks: train on {ResNet18, MobileNetV2, SqueezeNet}");
    table(
        &["network", "test strategy", "Γ err %", "Φ err %"],
        &report.rows.iter().map(|r| r.cells()).collect::<Vec<_>>(),
    );
    let avg = |nets: &[&str]| {
        let sel: Vec<&ErrorRow> = report
            .rows
            .iter()
            .filter(|r| nets.contains(&r.network.as_str()))
            .collect();
        let n = sel.len().max(1) as f64;
        (
            sel.iter().map(|r| r.gamma_err_pct).sum::<f64>() / n,
            sel.iter().map(|r| r.phi_err_pct).sum::<f64>() / n,
        )
    };
    let (bg, bp) = avg(&BASIS);
    let (ng, np) = avg(&NON_BASIS);
    let (gg, gp) = avg(&["googlenet"]);
    println!("\nbasis networks mean:     Γ {bg:.2}%  Φ {bp:.2}%");
    println!("non-basis networks mean: Γ {ng:.2}%  Φ {np:.2}%");
    println!("googlenet (worst case):  Γ {gg:.2}%  Φ {gp:.2}%");
    println!("paper: non-basis degrades, GoogLeNet most (+16pp) — no Inception block in the basis");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::train_test_split;

    #[test]
    fn googlenet_degrades_most_among_non_basis() {
        // Reduced variant: basis data from 2 networks, test on MnasNet vs
        // GoogLeNet (random strategy only) — the ordering is the claim.
        let sim = Simulator::tx2();
        let mut train = Dataset::default();
        for network in ["resnet18", "squeezenet"] {
            let graph = crate::models::by_name(network).unwrap();
            train.extend(profile(&sim, &ProfileJob::new(network, &graph)));
        }
        let (fg, _) = fit_gamma_phi(&train);
        let mut errs = std::collections::BTreeMap::new();
        for network in ["mnasnet", "googlenet"] {
            let graph = crate::models::by_name(network).unwrap();
            let (_, test) = train_test_split(&sim, network, &graph, Strategy::Random, 2);
            errs.insert(network, fg.mape(&test.x(), &test.y_gamma()));
        }
        // Both should be worse than typical same-network errors (~2%)…
        assert!(errs["googlenet"] > 2.0, "googlenet err {:?}", errs);
        // …and GoogLeNet at least as bad as MnasNet (its block is unseen).
        assert!(
            errs["googlenet"] > 0.8 * errs["mnasnet"],
            "ordering violated: {errs:?}"
        );
    }
}
