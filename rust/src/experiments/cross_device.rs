//! Extension experiment — device specificity of the models.
//!
//! The paper's premise is that the models are tied to a *device and
//! framework combination* ("construct models ... given a target device and
//! framework"; contribution 2 is a methodology to re-profile per device).
//! This experiment quantifies that: Γ/Φ forests trained on the TX2 are
//! applied to the Xavier and the RTX 2080Ti without re-profiling (large
//! errors expected), then re-fitted per device with the same methodology
//! (single-digit errors expected) — demonstrating that the *toolflow*
//! generalises even though the *models* do not.

use crate::device::{DeviceSpec, Simulator};
use crate::profiler::train_test_split;
use crate::pruning::Strategy;
use crate::util::bench_harness::{section, table};

use super::fit_gamma_phi;

#[derive(Clone, Debug)]
pub struct CrossDeviceRow {
    pub target: String,
    /// Errors of the TX2-trained model applied directly.
    pub transferred_gamma_err: f64,
    pub transferred_phi_err: f64,
    /// Errors after re-profiling + re-fitting on the target device.
    pub refit_gamma_err: f64,
    pub refit_phi_err: f64,
}

#[derive(Clone, Debug)]
pub struct CrossDeviceReport {
    pub network: String,
    pub rows: Vec<CrossDeviceRow>,
}

pub fn run(network: &str, seed: u64) -> CrossDeviceReport {
    let graph = crate::models::by_name(network).expect("zoo network");
    // Source models: trained on the TX2.
    let tx2 = Simulator::tx2();
    let (train_tx2, _) = train_test_split(&tx2, network, &graph, Strategy::Random, seed);
    let (fg_tx2, fp_tx2) = fit_gamma_phi(&train_tx2);

    let mut rows = Vec::new();
    for spec in [DeviceSpec::xavier(), DeviceSpec::rtx2080ti()] {
        let sim = Simulator::new(spec);
        let (train_tgt, test_tgt) =
            train_test_split(&sim, network, &graph, Strategy::Random, seed ^ 0xdef1);
        // (a) transfer the TX2 model as-is.
        let transferred_gamma_err = fg_tx2.mape(&test_tgt.x(), &test_tgt.y_gamma());
        let transferred_phi_err = fp_tx2.mape(&test_tgt.x(), &test_tgt.y_phi());
        // (b) re-run the methodology on the target device.
        let (fg, fp) = fit_gamma_phi(&train_tgt);
        rows.push(CrossDeviceRow {
            target: sim.spec.name.to_string(),
            transferred_gamma_err,
            transferred_phi_err,
            refit_gamma_err: fg.mape(&test_tgt.x(), &test_tgt.y_gamma()),
            refit_phi_err: fp.mape(&test_tgt.x(), &test_tgt.y_phi()),
        });
    }
    CrossDeviceReport {
        network: network.to_string(),
        rows,
    }
}

pub fn print(r: &CrossDeviceReport) {
    section(&format!(
        "Cross-device extension — TX2-trained models vs per-device refit ({})",
        r.network
    ));
    table(
        &[
            "target device",
            "transferred Γ err %",
            "transferred Φ err %",
            "refit Γ err %",
            "refit Φ err %",
        ],
        &r.rows
            .iter()
            .map(|row| {
                vec![
                    row.target.clone(),
                    format!("{:.1}", row.transferred_gamma_err),
                    format!("{:.1}", row.transferred_phi_err),
                    format!("{:.2}", row.refit_gamma_err),
                    format!("{:.2}", row.refit_phi_err),
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!("\nmodels are device-specific; the profiling methodology transfers (paper Sec. 1, contribution 2)");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_fails_refit_works() {
        let r = run("squeezenet", 31);
        for row in &r.rows {
            assert!(
                row.transferred_phi_err > 4.0 * row.refit_phi_err,
                "{}: transferred Φ err {:.1}% should dwarf refit {:.2}%",
                row.target,
                row.transferred_phi_err,
                row.refit_phi_err
            );
            assert!(
                row.refit_gamma_err < 5.0,
                "{}: refit Γ err {:.2}%",
                row.target,
                row.refit_gamma_err
            );
        }
        // The 2080Ti (wildly different device class) transfers worse than
        // the Xavier (sibling embedded GPU).
        assert!(
            r.rows[1].transferred_gamma_err > r.rows[0].transferred_gamma_err,
            "{:?}",
            r.rows
        );
    }
}
