//! E6 / Fig. 5 (App. B) — profiled Γ and Φ vs batch size for ResNet18,
//! MobileNetV2, SqueezeNet and MnasNet at pruning levels {0,30,50,70,90}%.
//! The paper's observation: "they display linearity with batch size, but
//! varying linear fit dependent on the network architecture (pruning
//! level)". We regenerate the series and quantify both claims (R² of the
//! per-level linear fit; spread of slopes across levels).

use crate::device::Simulator;
use crate::profiler::{profile, ProfileJob, TRAIN_LEVELS};
use crate::util::bench_harness::section;
use crate::util::stats::linear_fit;

#[derive(Clone, Debug)]
pub struct Series {
    pub network: String,
    pub level: f64,
    pub bs: Vec<usize>,
    pub gamma: Vec<f64>,
    pub phi: Vec<f64>,
    pub gamma_r2: f64,
    pub phi_r2: f64,
    pub gamma_slope: f64,
    pub phi_slope: f64,
}

#[derive(Clone, Debug)]
pub struct Fig5Report {
    pub series: Vec<Series>,
}

pub fn run(sim: &Simulator, seed: u64) -> Fig5Report {
    let mut series = Vec::new();
    for network in ["resnet18", "mobilenetv2", "squeezenet", "mnasnet"] {
        let graph = crate::models::by_name(network).unwrap();
        let ds = profile(
            sim,
            &ProfileJob {
                levels: &TRAIN_LEVELS,
                seed,
                ..ProfileJob::new(network, &graph)
            },
        );
        for &level in TRAIN_LEVELS.iter() {
            let pts: Vec<_> = ds
                .points
                .iter()
                .filter(|p| (p.level - level).abs() < 1e-9)
                .collect();
            let bs: Vec<usize> = pts.iter().map(|p| p.bs).collect();
            let xs: Vec<f64> = bs.iter().map(|&b| b as f64).collect();
            let gamma: Vec<f64> = pts.iter().map(|p| p.gamma_mb).collect();
            let phi: Vec<f64> = pts.iter().map(|p| p.phi_ms).collect();
            let (gs, _, gr2) = linear_fit(&xs, &gamma);
            let (ps, _, pr2) = linear_fit(&xs, &phi);
            series.push(Series {
                network: network.to_string(),
                level,
                bs,
                gamma,
                phi,
                gamma_r2: gr2,
                phi_r2: pr2,
                gamma_slope: gs,
                phi_slope: ps,
            });
        }
    }
    Fig5Report { series }
}

pub fn print(report: &Fig5Report) {
    section("Fig. 5 (App. B) — Γ and Φ vs batch size per pruning level");
    println!("network       level   Γ slope MB/img  Γ R²     Φ slope ms/img  Φ R²");
    println!("{}", "-".repeat(72));
    for s in &report.series {
        println!(
            "{:<13} {:>4.0}%   {:>12.2}  {:.4}   {:>12.2}  {:.4}",
            s.network,
            s.level * 100.0,
            s.gamma_slope,
            s.gamma_r2,
            s.phi_slope,
            s.phi_r2
        );
    }
    // CSV for plotting.
    println!("\nCSV (network,level,bs,gamma_mb,phi_ms):");
    for s in &report.series {
        for ((b, g), p) in s.bs.iter().zip(&s.gamma).zip(&s.phi) {
            println!("{},{},{},{:.1},{:.1}", s.network, s.level, b, g, p);
        }
    }
    println!("\npaper claim: linear in bs (R² ≈ 1), slope varies with pruning level");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linearity_and_slope_variation() {
        let sim = Simulator::tx2();
        let graph = crate::models::squeezenet(1000);
        let ds = profile(
            &sim,
            &ProfileJob {
                levels: &[0.0, 0.9],
                batch_sizes: &[8, 32, 64, 128, 192, 256],
                ..ProfileJob::new("squeezenet", &graph)
            },
        );
        let fit_level = |lvl: f64| {
            let pts: Vec<_> = ds
                .points
                .iter()
                .filter(|p| (p.level - lvl).abs() < 1e-9)
                .collect();
            let xs: Vec<f64> = pts.iter().map(|p| p.bs as f64).collect();
            let ys: Vec<f64> = pts.iter().map(|p| p.gamma_mb).collect();
            linear_fit(&xs, &ys)
        };
        let (s0, _, r0) = fit_level(0.0);
        let (s9, _, r9) = fit_level(0.9);
        assert!(r0 > 0.99 && r9 > 0.99, "not linear: {r0} {r9}");
        assert!(s9 < s0 * 0.8, "slope must shrink with pruning: {s0} vs {s9}");
    }
}
