//! E7 / Table 2 — the on-device OFA case study.
//!
//! Rows MAX and MIN are the extreme sub-networks; A and B come from
//! evolutionary search under progressively stricter (Γ, γ, φ) constraints,
//! with per-candidate attributes predicted by the random-forest models.
//! Search time compares the naive approach (on-device profiling at the
//! paper's measured 20 s/datapoint) against model inference (measured wall
//! clock here) — the paper's ~200× headline.


use crate::device::{Simulator, PROFILE_COST_S};
use crate::engine::{CacheStats, PredictionEngine};
use crate::ir::NetworkPlan;
use crate::ofa::{
    evolutionary_search, initial_accuracy_plan, retrained_accuracy_plan, Constraints, EsConfig,
    GenerationOracle, SubnetConfig, ALL_SUBSETS,
};
use crate::util::bench_harness::{section, table};

use super::ofa_models::OfaModels;

#[derive(Clone, Debug)]
pub struct Table2Row {
    pub name: String,
    /// (naive hours, model hours); None for MAX/MIN (no search needed).
    pub search_time_h: Option<(f64, f64)>,
    pub size_mb: f64,
    pub gamma_mb: f64,
    pub gamma_infer_mb: f64,
    pub phi_ms: f64,
    /// Per subset: (initial, retrained) top-1 %.
    pub accuracy: Vec<(f64, f64)>,
}

#[derive(Clone, Debug)]
pub struct Table2Report {
    pub rows: Vec<Table2Row>,
    pub search_speedup: f64,
    /// Engine cache counters across both searches (they share one memo,
    /// so search B reuses candidates search A already evaluated).
    pub cache: CacheStats,
}

/// Ground-truth attributes of a sub-network (what the paper profiles for
/// the final table).
fn true_attrs(sim: &Simulator, plan: &NetworkPlan<'_>) -> (f64, f64, f64) {
    let t = sim.train_step_plan(plan, 32, None);
    let i = sim.inference_plan(plan, 1, None);
    (t.gamma_mb, i.gamma_mb, i.phi_ms)
}

fn row_for(
    sim: &Simulator,
    name: &str,
    config: &SubnetConfig,
    search_time_h: Option<(f64, f64)>,
) -> Table2Row {
    let g = config.build();
    let plan = NetworkPlan::build(&g).expect("valid sub-network");
    let (gamma, gamma_i, phi) = true_attrs(sim, &plan);
    Table2Row {
        name: name.to_string(),
        search_time_h,
        size_mb: plan.model_size_mb(),
        gamma_mb: gamma,
        gamma_infer_mb: gamma_i,
        phi_ms: phi,
        accuracy: ALL_SUBSETS
            .iter()
            .map(|&s| {
                (
                    initial_accuracy_plan(config, &plan, s),
                    retrained_accuracy_plan(config, &plan, s),
                )
            })
            .collect(),
    }
}

pub fn run(sim: &Simulator, models: &OfaModels, es_cfg: &EsConfig) -> Table2Report {
    // Model-based attribute prediction — the fast path the paper proposes —
    // served by the batched, cache-backed engine. One engine answers the
    // anchor points and both searches, so candidates revisited across
    // searches cost a hash lookup.
    let mut engine = models.engine();

    // Constraint sets placed between the MIN and MAX attribute extremes —
    // "progressively stricter constraints on Γ, γ and φ" (Sec. 6.4). The
    // search sees only *predicted* attributes (that is the whole point of
    // the models), so the constraints are anchored in predicted space too —
    // exactly what an operator calibrating budgets with these models would
    // do. Fractions are chosen so the achieved improvement ratios land near
    // the paper's (A: 1.6×/1.05×/1.8×, B: 1.9×/1.1×/2.8× vs MAX).
    let anchors = engine.evaluate_generation(&[SubnetConfig::max(), SubnetConfig::min()]);
    let pa_max = anchors[0].attrs;
    let pa_min = anchors[1].attrs;
    let between = |lo: f64, hi: f64, frac: f64| lo + frac * (hi - lo);
    let cons_a = Constraints {
        gamma_train_mb: between(pa_min.gamma_train_mb, pa_max.gamma_train_mb, 0.45),
        gamma_infer_mb: between(pa_min.gamma_infer_mb, pa_max.gamma_infer_mb, 0.80),
        phi_infer_ms: between(pa_min.phi_infer_ms, pa_max.phi_infer_ms, 0.45),
    };
    let cons_b = Constraints {
        gamma_train_mb: between(pa_min.gamma_train_mb, pa_max.gamma_train_mb, 0.28),
        gamma_infer_mb: between(pa_min.gamma_infer_mb, pa_max.gamma_infer_mb, 0.55),
        phi_infer_ms: between(pa_min.phi_infer_ms, pa_max.phi_infer_ms, 0.22),
    };

    let search = |engine: &mut PredictionEngine, cons: &Constraints, seed: u64, subset| {
        let cfg = EsConfig {
            seed,
            ..es_cfg.clone()
        };
        let result = evolutionary_search(cons, &cfg, subset, engine);
        let naive_h = result.samples as f64 * PROFILE_COST_S / 3600.0;
        let model_h = result.elapsed.as_secs_f64() / 3600.0;
        (result, naive_h, model_h)
    };

    let (res_a, naive_a, model_a) =
        search(&mut engine, &cons_a, es_cfg.seed, crate::ofa::Subset::City);
    let (res_b, naive_b, model_b) =
        search(&mut engine, &cons_b, es_cfg.seed ^ 1, crate::ofa::Subset::City);

    let rows = vec![
        row_for(sim, "MAX", &SubnetConfig::max(), None),
        row_for(sim, "A", &res_a.best, Some((naive_a, model_a))),
        row_for(sim, "B", &res_b.best, Some((naive_b, model_b))),
        row_for(sim, "MIN", &SubnetConfig::min(), None),
    ];
    let speedup = (naive_a + naive_b) / (model_a + model_b).max(1e-12);
    Table2Report {
        rows,
        search_speedup: speedup,
        cache: engine.stats(),
    }
}

pub fn print(report: &Table2Report) {
    section("Table 2 — on-device OFA model selection and retraining");
    let max = &report.rows[0];
    let ratio = |v: f64, m: f64| format!("{:.2}x", m / v);
    let mut body = Vec::new();
    for r in &report.rows {
        let mut cells = vec![
            r.name.clone(),
            r.search_time_h
                .map(|(n, m)| format!("{:.0}h / {:.2}h", n, m.max(0.01)))
                .unwrap_or_else(|| "-".into()),
            format!("{:.0} ({})", r.size_mb, ratio(r.size_mb, max.size_mb)),
            format!("{:.0} ({})", r.gamma_mb, ratio(r.gamma_mb, max.gamma_mb)),
            format!(
                "{:.0} ({})",
                r.gamma_infer_mb,
                ratio(r.gamma_infer_mb, max.gamma_infer_mb)
            ),
            format!("{:.1} ({})", r.phi_ms, ratio(r.phi_ms, max.phi_ms)),
        ];
        for (init, ret) in &r.accuracy {
            cells.push(format!("{init:.1} → {ret:.1}"));
        }
        body.push(cells);
    }
    table(
        &[
            "subnet",
            "search (naive/model)",
            "size MB",
            "Γ MB (bs32)",
            "γ MB (bs1)",
            "φ ms (bs1)",
            "city",
            "off-road",
            "motorway",
            "country",
        ],
        &body,
    );
    println!(
        "\nsearch speed-up model vs naive profiling: {:.0}x  (paper: ~200x; 11 days → 1.4 h)",
        report.search_speedup
    );
    println!(
        "engine cache over both searches: {} hits / {} misses ({:.1}% hit rate, {} evictions)",
        report.cache.hits,
        report.cache.misses,
        100.0 * report.cache.hit_rate(),
        report.cache.evictions
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ofa_models;

    #[test]
    fn table2_orderings_hold() {
        let sim = Simulator::tx2();
        let models = ofa_models::run(&sim, 24, 9);
        let cfg = EsConfig {
            population: 16,
            iterations: 8,
            ..Default::default()
        };
        let r = run(&sim, &models, &cfg);
        let by = |n: &str| r.rows.iter().find(|x| x.name == n).unwrap();
        let (max, a, b, min) = (by("MAX"), by("A"), by("B"), by("MIN"));
        // Size/attribute ordering MAX ≥ A ≥ B ≥ MIN (allowing small slack
        // from search stochasticity on attributes).
        assert!(max.size_mb > min.size_mb * 3.0);
        assert!(a.gamma_mb <= max.gamma_mb);
        assert!(b.phi_ms <= a.phi_ms * 1.15);
        assert!(min.gamma_mb <= b.gamma_mb * 1.05);
        // Initial accuracy: MAX beats MIN on every subset.
        for (i, _) in ALL_SUBSETS.iter().enumerate() {
            assert!(max.accuracy[i].0 > min.accuracy[i].0);
            // retraining never hurts much and often helps
            assert!(min.accuracy[i].1 > min.accuracy[i].0);
        }
        // Retrained A beats un-retrained MAX in most subsets (paper's
        // central claim).
        let wins = (0..4).filter(|&i| a.accuracy[i].1 > max.accuracy[i].0).count();
        assert!(wins >= 3, "A retrained beats MAX initial in only {wins}/4");
        // Search with models is dramatically faster than naive profiling.
        assert!(r.search_speedup > 50.0, "speedup {:.0}x", r.search_speedup);
        // Both searches went through the engine (anchor points included).
        assert!(r.cache.requests() > 2, "engine unused: {:?}", r.cache);
    }
}
