//! E2 / Sec. 6.1 — tuning the training-set size on AlexNet.
//!
//! Train-set pruning-level sets of size 1..8; test on all remaining
//! levels. Paper: error starts at 33–74% for T={0} and plateaus at 3–6%
//! from T={0,30,50,70,90} onward.

use crate::campaign::{self, CampaignSpec};
use crate::device::Simulator;
use crate::profiler::{all_levels, PAPER_BATCH_SIZES};
use crate::pruning::Strategy;
use crate::util::bench_harness::{section, table};

use super::fit_gamma_phi;

/// The nested training-set sequence (paper's T grows to
/// {0,10,20,30,50,60,70,90}; the 5-level point is the paper's chosen set).
pub fn train_set_sequence() -> Vec<Vec<f64>> {
    vec![
        vec![0.0],
        vec![0.0, 0.90],
        vec![0.0, 0.50, 0.90],
        vec![0.0, 0.30, 0.50, 0.90],
        vec![0.0, 0.30, 0.50, 0.70, 0.90],
        vec![0.0, 0.10, 0.30, 0.50, 0.70, 0.90],
        vec![0.0, 0.10, 0.20, 0.30, 0.50, 0.70, 0.90],
        vec![0.0, 0.10, 0.20, 0.30, 0.50, 0.60, 0.70, 0.90],
    ]
}

#[derive(Clone, Debug)]
pub struct TrainsetReport {
    /// (|T|, Γ err %, Φ err %) per sequence step.
    pub points: Vec<(usize, f64, f64)>,
}

pub fn run(sim: &Simulator, seed: u64) -> TrainsetReport {
    // Two merged campaigns (one per seed stream) over *all* levels replace
    // the former 16 ad-hoc per-step profile() calls: per-level RNG streams
    // are independent, so filtering the merged dataset to a level subset
    // is bit-identical to profiling exactly that subset.
    let spec = |s: u64| CampaignSpec {
        networks: vec!["alexnet".into()],
        strategies: vec![Strategy::Random],
        regimes: vec![crate::device::TrainRegime::Vanilla],
        levels: all_levels(),
        batch_sizes: PAPER_BATCH_SIZES.to_vec(),
        runs: 3,
        seed: s,
        device: sim.spec.name.into(),
    };
    let train_all = campaign::collect(&spec(seed)).expect("alexnet training campaign");
    let test_all = campaign::collect(&spec(seed ^ 0xabcd)).expect("alexnet test campaign");
    let mut points = Vec::new();
    for t_levels in train_set_sequence() {
        let in_t = |level: f64| t_levels.iter().any(|t| (t - level).abs() < 1e-9);
        let train = train_all.filter(|p| in_t(p.level));
        let test = test_all.filter(|p| !in_t(p.level));
        let (fg, fp) = fit_gamma_phi(&train);
        points.push((
            t_levels.len(),
            fg.mape(&test.x(), &test.y_gamma()),
            fp.mape(&test.x(), &test.y_phi()),
        ));
    }
    TrainsetReport { points }
}

pub fn print(report: &TrainsetReport) {
    section("Sec. 6.1 — AlexNet training-set size sweep");
    table(
        &["|T|", "Γ err %", "Φ err %"],
        &report
            .points
            .iter()
            .map(|(n, g, p)| vec![n.to_string(), format!("{g:.2}"), format!("{p:.2}")])
            .collect::<Vec<_>>(),
    );
    println!("\npaper: errors shrink with |T| and plateau at |T|=5 = {{0,30,50,70,90}}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::{profile, ProfileJob};

    #[test]
    fn error_shrinks_then_plateaus() {
        let sim = Simulator::tx2();
        // Check endpoints only (full sweep runs in the bench).
        let graph = crate::models::alexnet(1000);
        let seq = train_set_sequence();
        let mut errs = Vec::new();
        for t_levels in [&seq[0], &seq[4]] {
            let train = profile(
                &sim,
                &ProfileJob {
                    levels: t_levels,
                    seed: 5,
                    ..ProfileJob::new("alexnet", &graph)
                },
            );
            let test = profile(
                &sim,
                &ProfileJob {
                    levels: &[0.25, 0.45, 0.65],
                    seed: 6,
                    ..ProfileJob::new("alexnet", &graph)
                },
            );
            let (fg, _) = fit_gamma_phi(&train);
            errs.push(fg.mape(&test.x(), &test.y_gamma()));
        }
        assert!(
            errs[0] > 1.4 * errs[1],
            "no improvement from |T|=1 ({:.2}%) to |T|=5 ({:.2}%)",
            errs[0],
            errs[1]
        );
    }

    #[test]
    fn filtered_campaign_matches_direct_profiling_bitwise() {
        // The refactor's core assumption: per-level RNG streams are
        // independent, so a level-subset filter of the merged all-levels
        // campaign equals profiling exactly that subset.
        let sim = Simulator::tx2();
        let graph = crate::models::squeezenet(1000);
        let spec = CampaignSpec {
            networks: vec!["squeezenet".into()],
            strategies: vec![Strategy::Random],
            regimes: vec![crate::device::TrainRegime::Vanilla],
            levels: vec![0.0, 0.3, 0.6],
            batch_sizes: vec![4, 16],
            runs: 1,
            seed: 21,
            device: "tx2".into(),
        };
        let merged = campaign::collect(&spec).unwrap();
        let direct = profile(
            &sim,
            &ProfileJob {
                levels: &[0.3],
                batch_sizes: &[4, 16],
                runs: 1,
                seed: 21,
                ..ProfileJob::new("squeezenet", &graph)
            },
        );
        let filtered = merged.filter(|p| (p.level - 0.3).abs() < 1e-9);
        assert_eq!(
            filtered.to_json().to_string(),
            direct.to_json().to_string()
        );
    }
}
