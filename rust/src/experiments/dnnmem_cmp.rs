//! E4 / Sec. 6.2.1 — state-of-the-art comparison on a server GPU.
//!
//! ResNet50 on the (simulated) RTX 2080Ti: perf4sight's learned Γ model vs
//! the DNNMem-style analytical baseline, plus the Augur-style layer-wise
//! and plain-linear-regression baselines. Paper numbers: perf4sight 2.45%
//! vs DNNMem 17.4%; inference-era layer-wise methods 12–30%.

use crate::baselines::{estimate_training_memory_mb, DnnMemConfig, LayerwiseModel, LinearModel};
use crate::device::{DeviceSpec, Simulator};
use crate::profiler::train_test_split;
use crate::pruning::Strategy;
use crate::util::bench_harness::{section, table};
use crate::util::stats;

use super::fit_gamma_phi;

#[derive(Clone, Debug)]
pub struct DnnmemReport {
    pub perf4sight_err: f64,
    pub dnnmem_err: f64,
    pub linreg_err: f64,
    pub layerwise_gamma_err: f64,
    pub layerwise_phi_err: f64,
    pub perf4sight_phi_err: f64,
}

pub fn run(seed: u64) -> DnnmemReport {
    let sim = Simulator::new(DeviceSpec::rtx2080ti());
    let graph = crate::models::resnet50(1000);
    let (train, test) = train_test_split(&sim, "resnet50", &graph, Strategy::Random, seed);

    // perf4sight forests.
    let (fg, fp) = fit_gamma_phi(&train);
    let perf4sight_err = fg.mape(&test.x(), &test.y_gamma());
    let perf4sight_phi_err = fp.mape(&test.x(), &test.y_phi());

    // DNNMem analytical baseline: needs the *graph* per test point.
    let cfg = DnnMemConfig::default();
    let mut dnn_pred = Vec::new();
    let mut truth = Vec::new();
    for p in &test.points {
        // Rebuild the pruned graph deterministically the same way the
        // profiler did.
        let mut rng = crate::util::rng::Pcg64::with_stream(
            seed ^ 0xdead_beef,
            crate::util::rng::hash_seed(&format!("resnet50/random/{:.3}", p.level)),
        );
        let pruned = crate::pruning::prune(&graph, Strategy::Random, p.level, &mut rng);
        dnn_pred.push(estimate_training_memory_mb(&pruned, p.bs, &cfg).unwrap());
        truth.push(p.gamma_mb);
    }
    let dnnmem_err = stats::mape(&dnn_pred, &truth);

    // Linear regression on the analytical features (paper's discarded
    // alternative).
    let lin = LinearModel::fit(&train.x(), &train.y_gamma(), 1e-3);
    let linreg_err = stats::mape(&lin.predict_batch(&test.x()), &test.y_gamma());

    // Augur-style layer-wise model.
    let lw = LayerwiseModel::calibrate(&sim, 150, seed ^ 0xa06);
    let mut lw_gamma = Vec::new();
    let mut lw_phi = Vec::new();
    let mut phi_truth = Vec::new();
    for p in &test.points {
        let mut rng = crate::util::rng::Pcg64::with_stream(
            seed ^ 0xdead_beef,
            crate::util::rng::hash_seed(&format!("resnet50/random/{:.3}", p.level)),
        );
        let pruned = crate::pruning::prune(&graph, Strategy::Random, p.level, &mut rng);
        let (g, ph) = lw.predict(&pruned, p.bs).unwrap();
        lw_gamma.push(g);
        lw_phi.push(ph);
        phi_truth.push(p.phi_ms);
    }

    DnnmemReport {
        perf4sight_err,
        dnnmem_err,
        linreg_err,
        layerwise_gamma_err: stats::mape(&lw_gamma, &truth),
        layerwise_phi_err: stats::mape(&lw_phi, &phi_truth),
        perf4sight_phi_err,
    }
}

pub fn print(r: &DnnmemReport) {
    section("Sec. 6.2.1 — ResNet50 on RTX 2080Ti: Γ prediction error vs baselines");
    table(
        &["method", "Γ err %", "Φ err %", "paper reference"],
        &[
            vec![
                "perf4sight (forest)".into(),
                format!("{:.2}", r.perf4sight_err),
                format!("{:.2}", r.perf4sight_phi_err),
                "2.45% (Γ)".into(),
            ],
            vec![
                "DNNMem [5] (analytical)".into(),
                format!("{:.2}", r.dnnmem_err),
                "-".into(),
                "17.4%".into(),
            ],
            vec![
                "linear regression".into(),
                format!("{:.2}", r.linreg_err),
                "-".into(),
                "discarded (fn.4)".into(),
            ],
            vec![
                "layer-wise matmul [14]".into(),
                format!("{:.2}", r.layerwise_gamma_err),
                format!("{:.2}", r.layerwise_phi_err),
                "12-30% (inference)".into(),
            ],
        ],
    );
    println!(
        "\nshape check: perf4sight beats DNNMem by {:.1}x (paper: 7.1x)",
        r.dnnmem_err / r.perf4sight_err.max(1e-9)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perf4sight_beats_all_baselines() {
        let r = run(21);
        assert!(
            r.perf4sight_err < r.dnnmem_err,
            "forest {:.2}% !< dnnmem {:.2}%",
            r.perf4sight_err,
            r.dnnmem_err
        );
        assert!(r.perf4sight_err < 5.0, "forest err {:.2}%", r.perf4sight_err);
        assert!(r.dnnmem_err > 5.0, "dnnmem err {:.2}%", r.dnnmem_err);
        assert!(
            r.perf4sight_err < r.layerwise_gamma_err,
            "forest !< layerwise"
        );
    }
}
