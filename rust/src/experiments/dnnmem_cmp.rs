//! E4 / Sec. 6.2.1 — state-of-the-art comparison on a server GPU.
//!
//! ResNet50 on the (simulated) RTX 2080Ti: perf4sight's learned Γ model vs
//! the DNNMem-style analytical baseline, plus the Augur-style layer-wise
//! and plain-linear-regression baselines. Paper numbers: perf4sight 2.45%
//! vs DNNMem 17.4%; inference-era layer-wise methods 12–30%.

use crate::baselines::{
    estimate_training_memory_mb_plan, DnnMemConfig, LayerwiseModel, LinearModel,
};
use crate::device::{DeviceSpec, Simulator};
use crate::ir::NetworkPlan;
use crate::profiler::train_test_split;
use crate::pruning::Strategy;
use crate::util::bench_harness::{section, table};
use crate::util::rng::{hash_seed, Pcg64};
use crate::util::stats;

use super::fit_gamma_phi;

#[derive(Clone, Debug)]
pub struct DnnmemReport {
    pub perf4sight_err: f64,
    pub dnnmem_err: f64,
    pub linreg_err: f64,
    pub layerwise_gamma_err: f64,
    pub layerwise_phi_err: f64,
    pub perf4sight_phi_err: f64,
}

pub fn run(seed: u64) -> DnnmemReport {
    let sim = Simulator::new(DeviceSpec::rtx2080ti());
    let graph = crate::models::resnet50(1000);
    let (train, test) = train_test_split(&sim, "resnet50", &graph, Strategy::Random, seed);

    // perf4sight forests.
    let (fg, fp) = fit_gamma_phi(&train);
    let perf4sight_err = fg.mape(&test.x(), &test.y_gamma());
    let perf4sight_phi_err = fp.mape(&test.x(), &test.y_phi());

    // Both graph-level baselines need the pruned topology per test point.
    // Rebuild each level's pruned graph once — deterministically, on the
    // same per-level stream the profiler used — and compile one
    // NetworkPlan per level, shared by DNNMem and the layer-wise model
    // across all 25 batch sizes.
    let mut pruned: Vec<(f64, crate::ir::Graph)> = Vec::new();
    for p in &test.points {
        if !pruned.iter().any(|(l, _)| (l - p.level).abs() < 1e-12) {
            let mut rng = Pcg64::with_stream(
                seed ^ 0xdead_beef,
                hash_seed(&format!("resnet50/random/{:.3}", p.level)),
            );
            pruned.push((
                p.level,
                crate::pruning::prune(&graph, Strategy::Random, p.level, &mut rng),
            ));
        }
    }
    let plans: Vec<(f64, NetworkPlan)> = pruned
        .iter()
        .map(|(l, g)| (*l, NetworkPlan::build(g).expect("valid pruned graph")))
        .collect();
    let plan_for = |level: f64| {
        &plans
            .iter()
            .find(|(l, _)| (l - level).abs() < 1e-12)
            .expect("level was pruned above")
            .1
    };

    let cfg = DnnMemConfig::default();
    let lw = LayerwiseModel::calibrate(&sim, 150, seed ^ 0xa06);
    let mut dnn_pred = Vec::new();
    let mut truth = Vec::new();
    let mut lw_gamma = Vec::new();
    let mut lw_phi = Vec::new();
    let mut phi_truth = Vec::new();
    for p in &test.points {
        let plan = plan_for(p.level);
        dnn_pred.push(estimate_training_memory_mb_plan(plan, p.bs, &cfg));
        truth.push(p.gamma_mb);
        let (g, ph) = lw.predict_plan(plan, p.bs);
        lw_gamma.push(g);
        lw_phi.push(ph);
        phi_truth.push(p.phi_ms);
    }
    let dnnmem_err = stats::mape(&dnn_pred, &truth);

    // Linear regression on the analytical features (paper's discarded
    // alternative).
    let lin = LinearModel::fit(&train.x(), &train.y_gamma(), 1e-3);
    let linreg_err = stats::mape(&lin.predict_batch(&test.x()), &test.y_gamma());

    DnnmemReport {
        perf4sight_err,
        dnnmem_err,
        linreg_err,
        layerwise_gamma_err: stats::mape(&lw_gamma, &truth),
        layerwise_phi_err: stats::mape(&lw_phi, &phi_truth),
        perf4sight_phi_err,
    }
}

pub fn print(r: &DnnmemReport) {
    section("Sec. 6.2.1 — ResNet50 on RTX 2080Ti: Γ prediction error vs baselines");
    table(
        &["method", "Γ err %", "Φ err %", "paper reference"],
        &[
            vec![
                "perf4sight (forest)".into(),
                format!("{:.2}", r.perf4sight_err),
                format!("{:.2}", r.perf4sight_phi_err),
                "2.45% (Γ)".into(),
            ],
            vec![
                "DNNMem [5] (analytical)".into(),
                format!("{:.2}", r.dnnmem_err),
                "-".into(),
                "17.4%".into(),
            ],
            vec![
                "linear regression".into(),
                format!("{:.2}", r.linreg_err),
                "-".into(),
                "discarded (fn.4)".into(),
            ],
            vec![
                "layer-wise matmul [14]".into(),
                format!("{:.2}", r.layerwise_gamma_err),
                format!("{:.2}", r.layerwise_phi_err),
                "12-30% (inference)".into(),
            ],
        ],
    );
    println!(
        "\nshape check: perf4sight beats DNNMem by {:.1}x (paper: 7.1x)",
        r.dnnmem_err / r.perf4sight_err.max(1e-9)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perf4sight_beats_all_baselines() {
        let r = run(21);
        assert!(
            r.perf4sight_err < r.dnnmem_err,
            "forest {:.2}% !< dnnmem {:.2}%",
            r.perf4sight_err,
            r.dnnmem_err
        );
        assert!(r.perf4sight_err < 5.0, "forest err {:.2}%", r.perf4sight_err);
        assert!(r.dnnmem_err > 5.0, "dnnmem err {:.2}%", r.dnnmem_err);
        assert!(
            r.perf4sight_err < r.layerwise_gamma_err,
            "forest !< layerwise"
        );
    }
}
