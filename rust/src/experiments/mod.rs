//! Experiment harnesses — one per table/figure in the paper's evaluation
//! (DESIGN.md §5). Each module exposes a `run(...)` returning a report
//! struct and printing the regenerated rows; the `[[bench]]` targets and
//! the CLI `experiment` subcommand are thin wrappers over these.

pub mod ablation;
pub mod cross_device;
pub mod dnnmem_cmp;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod ofa_models;
pub mod regimes;
pub mod table2;
pub mod topology;
pub mod trainset;

use crate::forest::{Forest, ForestConfig};
use crate::profiler::Dataset;

/// Forest hyperparameters used across experiments: export-compatible
/// (64 trees, depth ≤ 14) so any fitted model can also run through the
/// XLA artifact.
pub fn experiment_forest_config() -> ForestConfig {
    crate::runtime::forest_exec::export_forest_config()
}

/// Fit the paper's two models (Γ and Φ) on a profiled dataset. The
/// presorted [`TrainMatrix`](crate::forest::TrainMatrix) is built once and
/// shared by both fits.
pub fn fit_gamma_phi(train: &Dataset) -> (Forest, Forest) {
    let cfg = experiment_forest_config();
    let m = train.train_matrix().expect("profiled features must be finite");
    let fg = Forest::fit_matrix(&m, &train.y_gamma(), &cfg).expect("Γ fit");
    let fp = Forest::fit_matrix(&m, &train.y_phi(), &cfg).expect("Φ fit");
    (fg, fp)
}

/// Per-network attribute errors (mean absolute percentage error).
#[derive(Clone, Debug)]
pub struct ErrorRow {
    pub network: String,
    pub strategy: String,
    pub gamma_err_pct: f64,
    pub phi_err_pct: f64,
}

impl ErrorRow {
    pub fn cells(&self) -> Vec<String> {
        vec![
            self.network.clone(),
            self.strategy.clone(),
            format!("{:.2}", self.gamma_err_pct),
            format!("{:.2}", self.phi_err_pct),
        ]
    }
}

/// Aggregate means across rows.
pub fn mean_errors(rows: &[ErrorRow]) -> (f64, f64) {
    let n = rows.len().max(1) as f64;
    (
        rows.iter().map(|r| r.gamma_err_pct).sum::<f64>() / n,
        rows.iter().map(|r| r.phi_err_pct).sum::<f64>() / n,
    )
}
