//! E8 / Sec. 6.4 — inference-attribute models for the OFA space.
//!
//! The paper trains γ (inference memory) and φ (inference latency) forests
//! on profiled data from 25 of 100 sampled OFA sub-networks at batch sizes
//! {1,2,4,8,16,32}, using *only the forward-pass features*, and reports
//! 1.8% / 4.4% test error on the remaining 75. It also validates the Γ
//! model trained on ResNet50 data against the 100 sub-networks (4.28%).

use crate::campaign::{self, CampaignSpec};
use crate::device::Simulator;
use crate::engine::{CompiledForestPair, PredictionEngine};
use crate::features::{network_features_from_plan, NUM_FEATURES};
use crate::forest::{Forest, TrainMatrix};
use crate::ir::NetworkPlan;
use crate::ofa::SubnetConfig;
use crate::profiler::{PAPER_BATCH_SIZES, TRAIN_LEVELS};
use crate::pruning::Strategy;
use crate::util::bench_harness::section;
use crate::util::rng::Pcg64;
use crate::util::stats;

use super::{experiment_forest_config, fit_gamma_phi};

// The canonical implementation moved to `features` so the engine can use
// it without depending on the experiment harnesses; re-exported here for
// the established call sites.
pub use crate::features::forward_masked;

/// Inference-profiling batch sizes (Sec. 6.4: "batch sizes 1,2,4,8,16,32").
pub const INFER_BATCH_SIZES: [usize; 6] = [1, 2, 4, 8, 16, 32];

#[derive(Clone, Debug)]
pub struct OfaModelsReport {
    pub gamma_infer_err: f64,
    pub phi_infer_err: f64,
    pub gamma_train_generalisation_err: f64,
    pub subnets: usize,
    /// Mean ± std of Γ over the sampled sub-networks at bs∈{32,64,128}
    /// (paper: 4318 ± 1129 MB).
    pub gamma_mean: f64,
    pub gamma_std: f64,
}

/// Fitted models + report (models reused by the Table 2 experiment).
pub struct OfaModels {
    pub gamma_train: Forest,
    pub gamma_infer: Forest,
    pub phi_infer: Forest,
    pub report: OfaModelsReport,
}

impl OfaModels {
    /// Compile the three fitted forests into a batched, cache-backed
    /// [`PredictionEngine`] — the serving path of the search experiments.
    pub fn engine(&self) -> PredictionEngine {
        PredictionEngine::new(&self.gamma_train, &self.gamma_infer, &self.phi_infer)
    }
}

pub fn run(sim: &Simulator, subnets: usize, seed: u64) -> OfaModels {
    let mut rng = Pcg64::new(seed);
    let configs: Vec<SubnetConfig> = (0..subnets).map(|_| SubnetConfig::sample(&mut rng)).collect();
    let graphs: Vec<_> = configs.iter().map(|c| c.build()).collect();
    // One compiled plan per sampled sub-network, reused across every batch
    // size for both feature extraction and simulation.
    let plans: Vec<NetworkPlan> = graphs
        .iter()
        .map(|g| NetworkPlan::build(g).expect("valid OFA sub-network"))
        .collect();

    // ---- γ/φ inference models: train on the first quarter of subnets ----
    let n_train = (subnets / 4).max(2);
    let mut xg = Vec::new();
    let mut yg = Vec::new();
    let mut yp = Vec::new();
    for plan in plans.iter().take(n_train) {
        for &bs in &INFER_BATCH_SIZES {
            let f = forward_masked(&network_features_from_plan(plan, bs));
            let m = sim.inference_plan(plan, bs, Some(&mut rng));
            xg.push(f);
            yg.push(m.gamma_mb);
            yp.push(m.phi_ms);
        }
    }
    let cfg = experiment_forest_config();
    // Both attribute models share one presorted matrix over the same rows.
    let m = TrainMatrix::from_rows(&xg).expect("finite OFA features");
    let gamma_infer = Forest::fit_matrix(&m, &yg, &cfg).expect("γ fit");
    let phi_infer = Forest::fit_matrix(&m, &yp, &cfg).expect("φ fit");

    // Test on the remaining subnets: collect every row, then answer BOTH
    // models from one fused blocked walk over the shared test rows (bit-
    // identical to per-row `Forest::predict`).
    let mut test_rows = Vec::new();
    let mut gtruth = Vec::new();
    let mut ptruth = Vec::new();
    for plan in plans.iter().skip(n_train) {
        for &bs in &INFER_BATCH_SIZES {
            test_rows.push(forward_masked(&network_features_from_plan(plan, bs)));
            let m = sim.inference_plan(plan, bs, Some(&mut rng));
            gtruth.push(m.gamma_mb);
            ptruth.push(m.phi_ms);
        }
    }
    let (gpred, ppred) = CompiledForestPair::compile(&gamma_infer, &phi_infer)
        .predict_rows(&test_rows);

    // ---- Γ generalisation: model trained on plain ResNet50 TX2 data ----
    // The training data comes from a merged profiling campaign — the one
    // canonical dataset producer — bit-identical to the former ad-hoc
    // per-network profile() call (and no longer paying for the unused
    // held-out half of the old train/test split).
    let train = campaign::collect(&CampaignSpec {
        networks: vec!["resnet50".into()],
        strategies: vec![Strategy::Random],
        regimes: vec![crate::device::TrainRegime::Vanilla],
        levels: TRAIN_LEVELS.to_vec(),
        batch_sizes: PAPER_BATCH_SIZES.to_vec(),
        runs: 3,
        seed,
        device: sim.spec.name.into(),
    })
    .expect("resnet50 training campaign");
    let (gamma_train, _) = fit_gamma_phi(&train);
    let mut tg_rows = Vec::new();
    let mut tg_truth = Vec::new();
    let mut gamma_samples = Vec::new();
    for plan in &plans {
        for &bs in &[32usize, 64, 128] {
            tg_rows.push(network_features_from_plan(plan, bs));
            let m = sim.train_step_plan(plan, bs, Some(&mut rng));
            tg_truth.push(m.gamma_mb);
            if bs <= 128 {
                gamma_samples.push(m.gamma_mb);
            }
        }
    }
    let tg_pred = gamma_train.compile_blocked().predict_rows(&tg_rows);

    let report = OfaModelsReport {
        gamma_infer_err: stats::mape(&gpred, &gtruth),
        phi_infer_err: stats::mape(&ppred, &ptruth),
        gamma_train_generalisation_err: stats::mape(&tg_pred, &tg_truth),
        subnets,
        gamma_mean: stats::mean(&gamma_samples),
        gamma_std: stats::std_dev(&gamma_samples),
    };
    OfaModels {
        gamma_train,
        gamma_infer,
        phi_infer,
        report,
    }
}

pub fn print(r: &OfaModelsReport) {
    section("Sec. 6.4 — OFA sub-network attribute models");
    println!(
        "Γ across sampled subnets (bs 32/64/128): {:.0} ± {:.0} MB  (paper: 4318 ± 1129)",
        r.gamma_mean, r.gamma_std
    );
    println!(
        "γ inference-memory model error:  {:.2}%   (paper: 1.8%)",
        r.gamma_infer_err
    );
    println!(
        "φ inference-latency model error: {:.2}%   (paper: 4.4%)",
        r.phi_infer_err
    );
    println!(
        "Γ model (ResNet50-trained) on OFA subnets: {:.2}%  (paper: 4.28%)",
        r.gamma_train_generalisation_err
    );
    let _ = NUM_FEATURES;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inference_models_single_digit_error() {
        let sim = Simulator::tx2();
        let m = run(&sim, 16, 3);
        assert!(m.report.gamma_infer_err < 6.0, "γ err {:.2}", m.report.gamma_infer_err);
        assert!(m.report.phi_infer_err < 12.0, "φ err {:.2}", m.report.phi_infer_err);
    }

    #[test]
    fn gamma_model_generalises_to_ofa() {
        let sim = Simulator::tx2();
        let m = run(&sim, 10, 4);
        // Paper: 4.28% — allow headroom but demand usable accuracy.
        assert!(
            m.report.gamma_train_generalisation_err < 12.0,
            "Γ generalisation {:.2}%",
            m.report.gamma_train_generalisation_err
        );
    }

    #[test]
    fn forward_mask_zeroes_bwd_columns() {
        let f = vec![1.0; NUM_FEATURES];
        let masked = forward_masked(&f);
        assert_eq!(masked.len(), NUM_FEATURES);
        let zeros = masked.iter().filter(|&&x| x == 0.0).count();
        assert!(zeros > 20, "only {zeros} masked");
        assert_eq!(masked[0], 1.0); // bs survives
    }
}
