//! Training-regime generalisation — the paper's Γ/Φ modelling recipe on
//! the grid widened by the training-regime axis (network × strategy ×
//! level × batch size × regime).
//!
//! One pair of forests is fitted on the *mixed* dataset covering vanilla
//! training, gradient checkpointing and frozen-backbone fine-tuning; the
//! report shows the per-(network, regime) held-out-level errors. The claim
//! under test: regime-aware features keep the models inside the paper's
//! accuracy bands (Γ ≲ 9%, Φ ≲ 15%) without per-regime specialisation.

use crate::campaign::{self, CampaignSpec};
use crate::device::{Simulator, TrainRegime};
use crate::profiler::{test_levels, PAPER_BATCH_SIZES, TRAIN_LEVELS};
use crate::pruning::Strategy;
use crate::util::bench_harness::{section, table};

use super::fit_gamma_phi;

/// The regime sweep the experiment profiles: plain training, 4-segment
/// gradient checkpointing, 3-layer frozen-backbone fine-tuning.
pub fn experiment_regimes() -> Vec<TrainRegime> {
    vec![
        TrainRegime::Vanilla,
        TrainRegime::Checkpointed { segments: 4 },
        TrainRegime::Frozen { trainable_suffix: 3 },
    ]
}

/// Held-out-level errors for one (network, regime) cell.
#[derive(Clone, Debug)]
pub struct RegimeRow {
    pub network: String,
    pub regime: String,
    pub gamma_err_pct: f64,
    pub phi_err_pct: f64,
}

#[derive(Clone, Debug)]
pub struct RegimesReport {
    pub rows: Vec<RegimeRow>,
    pub mean_gamma_err_pct: f64,
    pub mean_phi_err_pct: f64,
}

fn spec(networks: &[&str], levels: Vec<f64>, seed: u64, device: &str) -> CampaignSpec {
    CampaignSpec {
        networks: networks.iter().map(|s| s.to_string()).collect(),
        strategies: vec![Strategy::Random],
        regimes: experiment_regimes(),
        levels,
        batch_sizes: PAPER_BATCH_SIZES.to_vec(),
        runs: 3,
        seed,
        device: device.into(),
    }
}

/// Profile the widened grid, fit one Γ and one Φ forest on the mixed
/// training levels, and score each (network, regime) cell on held-out
/// pruning levels from an independent seed stream.
pub fn run(sim: &Simulator, seed: u64) -> RegimesReport {
    let networks = ["resnet18", "mobilenetv2"];
    let device = sim.spec.name;
    let train = campaign::collect(&spec(&networks, TRAIN_LEVELS.to_vec(), seed, device))
        .expect("regime training campaign");
    let test = campaign::collect(&spec(&networks, test_levels(), seed ^ 0x7e57, device))
        .expect("regime test campaign");
    let (fg, fp) = fit_gamma_phi(&train);
    let mut rows = Vec::new();
    for network in networks {
        for regime in experiment_regimes() {
            let cell = test.filter(|p| p.network == network && p.regime == regime.name());
            rows.push(RegimeRow {
                network: network.to_string(),
                regime: regime.name(),
                gamma_err_pct: fg.mape(&cell.x(), &cell.y_gamma()),
                phi_err_pct: fp.mape(&cell.x(), &cell.y_phi()),
            });
        }
    }
    let n = rows.len().max(1) as f64;
    RegimesReport {
        mean_gamma_err_pct: rows.iter().map(|r| r.gamma_err_pct).sum::<f64>() / n,
        mean_phi_err_pct: rows.iter().map(|r| r.phi_err_pct).sum::<f64>() / n,
        rows,
    }
}

pub fn print(report: &RegimesReport) {
    section("training-regime generalisation — one model over vanilla/ckpt/frozen");
    table(
        &["network", "regime", "Γ err %", "Φ err %"],
        &report
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.network.clone(),
                    r.regime.clone(),
                    format!("{:.2}", r.gamma_err_pct),
                    format!("{:.2}", r.phi_err_pct),
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!(
        "\nmean: Γ {:.2}%  Φ {:.2}%  (paper bands: Γ ≲ 9%, Φ ≲ 15%)",
        report.mean_gamma_err_pct, report.mean_phi_err_pct
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_regime_forest_stays_in_paper_accuracy_bands() {
        // Single-network version of the experiment (squeezenet keeps the
        // runtime test-sized): fit on the full regime × level × bs training
        // grid, score on held-out levels. Thresholds match the zoo-wide
        // bounds pinned in tests/toolflow.rs.
        let device = "tx2";
        let train =
            campaign::collect(&spec(&["squeezenet"], TRAIN_LEVELS.to_vec(), 77, device)).unwrap();
        let test =
            campaign::collect(&spec(&["squeezenet"], test_levels(), 77 ^ 0x7e57, device)).unwrap();
        assert_eq!(
            train.len(),
            TRAIN_LEVELS.len() * PAPER_BATCH_SIZES.len() * experiment_regimes().len()
        );
        let (fg, fp) = fit_gamma_phi(&train);
        for regime in experiment_regimes() {
            let cell = test.filter(|p| p.regime == regime.name());
            assert!(!cell.is_empty(), "{}", regime.name());
            let g = fg.mape(&cell.x(), &cell.y_gamma());
            let p = fp.mape(&cell.x(), &cell.y_phi());
            assert!(g < 9.15, "Γ error {g:.2}% out of band for {}", regime.name());
            assert!(p < 14.7, "Φ error {p:.2}% out of band for {}", regime.name());
        }
    }
}
