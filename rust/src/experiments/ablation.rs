//! E9 — feature-family ablation (design-choice validation).
//!
//! The paper motivates generating features for *all three* convolution
//! algorithms because cuDNN's per-layer choice is unobservable before
//! deployment (Sec. 5.2.1). This ablation knocks out each feature family
//! (tensor allocations, MatMul, FFT, Winograd) and refits the Γ/Φ models —
//! quantifying how much each family contributes.

use crate::device::Simulator;
use crate::engine::CompiledForestPair;
use crate::features::{feature_families, Family, NUM_FEATURES};
use crate::forest::{Forest, TrainMatrix};
use crate::profiler::train_test_split;
use crate::pruning::Strategy;
use crate::util::bench_harness::{section, table};
use crate::util::stats;

use super::experiment_forest_config;

#[derive(Clone, Debug)]
pub struct AblationRow {
    pub knocked_out: String,
    pub gamma_err_pct: f64,
    pub phi_err_pct: f64,
}

#[derive(Clone, Debug)]
pub struct AblationReport {
    pub network: String,
    pub rows: Vec<AblationRow>,
}

fn knockout(x: &[Vec<f64>], family: Option<Family>) -> Vec<Vec<f64>> {
    let fams = feature_families();
    x.iter()
        .map(|row| {
            row.iter()
                .enumerate()
                .map(|(i, &v)| match family {
                    Some(f) if fams[i] == f => 0.0,
                    _ => v,
                })
                .collect()
        })
        .collect()
}

pub fn run(sim: &Simulator, network: &str, seed: u64) -> AblationReport {
    let graph = crate::models::by_name(network).expect("zoo network");
    let (train, test) = train_test_split(sim, network, &graph, Strategy::Random, seed);
    let cfg = experiment_forest_config();

    let cases: Vec<(String, Option<Family>)> = vec![
        ("none (full 57 features)".into(), None),
        ("tensor allocations".into(), Some(Family::Tensor)),
        ("matmul features".into(), Some(Family::MatMul)),
        ("fft features".into(), Some(Family::Fft)),
        ("winograd features".into(), Some(Family::Winograd)),
    ];
    let mut rows = Vec::new();
    for (name, family) in cases {
        let xtr = knockout(&train.x(), family);
        let xte = knockout(&test.x(), family);
        // One presorted matrix per knockout serves both target fits.
        let m = TrainMatrix::from_rows(&xtr).expect("finite knockout features");
        let fg = Forest::fit_matrix(&m, &train.y_gamma(), &cfg).expect("Γ fit");
        let fp = Forest::fit_matrix(&m, &train.y_phi(), &cfg).expect("Φ fit");
        // Held-out predictions: one fused Γ/Φ blocked walk over the
        // shared test rows (bit-identical to the scalar `Forest::mape`
        // path).
        let (gp, pp) = CompiledForestPair::compile(&fg, &fp).predict_rows(&xte);
        rows.push(AblationRow {
            knocked_out: name,
            gamma_err_pct: stats::mape(&gp, &test.y_gamma()),
            phi_err_pct: stats::mape(&pp, &test.y_phi()),
        });
    }
    AblationReport {
        network: network.to_string(),
        rows,
    }
}

pub fn print(r: &AblationReport) {
    section(&format!(
        "Ablation — feature-family knockouts ({})",
        r.network
    ));
    table(
        &["knocked-out family", "Γ err %", "Φ err %"],
        &r.rows
            .iter()
            .map(|row| {
                vec![
                    row.knocked_out.clone(),
                    format!("{:.2}", row.gamma_err_pct),
                    format!("{:.2}", row.phi_err_pct),
                ]
            })
            .collect::<Vec<_>>(),
    );
    println!("\n(the full feature set should be at least as good as any knockout)");
    let _ = NUM_FEATURES;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_feature_set_is_not_dominated() {
        let sim = Simulator::tx2();
        let r = run(&sim, "squeezenet", 17);
        let full = &r.rows[0];
        // Knockouts shouldn't massively beat the full set on both targets
        // simultaneously (forests tolerate redundant features).
        for row in &r.rows[1..] {
            assert!(
                full.gamma_err_pct < row.gamma_err_pct + 2.0,
                "knockout {} strictly dominates: {row:?} vs {full:?}",
                row.knocked_out
            );
        }
    }
}
