//! E3 / Sec. 6.2 (second half) — topology-variation study.
//!
//! MobileNetV2 pruned to 50% under 100 different random pruning strategies
//! (uniform plus early/middle/late-heavy per-layer distributions), batch
//! size 80. Paper: Γ = 4423±1597 MB and Φ = 1741±871 ms across topologies;
//! models trained on *uniform* random pruning only predict them with mean
//! errors 1.32% (Γ) and 9.90% (Φ).

use crate::device::Simulator;
use crate::features::network_features;
use crate::profiler::{profile, ProfileJob};
use crate::pruning::{prune, Profile, Strategy, ALL_PROFILES};
use crate::util::bench_harness::section;
use crate::util::rng::Pcg64;
use crate::util::stats;

use super::fit_gamma_phi;

#[derive(Clone, Debug)]
pub struct TopologyReport {
    pub gamma_mean: f64,
    pub gamma_std: f64,
    pub phi_mean: f64,
    pub phi_std: f64,
    pub gamma_err_pct: f64,
    pub phi_err_pct: f64,
    pub strategies: usize,
}

pub fn run(sim: &Simulator, strategies: usize, seed: u64) -> TopologyReport {
    let graph = crate::models::mobilenet_v2(1000);
    // Models trained on the standard uniform-random profiling data.
    let train = profile(sim, &ProfileJob::new("mobilenetv2", &graph));
    let (fg, fp) = fit_gamma_phi(&train);

    // 100 random strategies at level 0.5, bs = 80.
    let mut rng = Pcg64::new(seed);
    let bs = 80usize;
    let mut gammas = Vec::new();
    let mut phis = Vec::new();
    let mut gpreds = Vec::new();
    let mut ppreds = Vec::new();
    for i in 0..strategies {
        // Mix the named profiles with fully random weightings.
        let profile_kind = if i < ALL_PROFILES.len() {
            ALL_PROFILES[i]
        } else {
            Profile::Random
        };
        let mut prune_rng = rng.fork();
        let pruned = prune(
            &graph,
            Strategy::Weighted(profile_kind),
            0.5,
            &mut prune_rng,
        );
        let mut meas_rng = rng.fork();
        let m = sim.train_step(&pruned, bs, Some(&mut meas_rng)).unwrap();
        gammas.push(m.gamma_mb);
        phis.push(m.phi_ms);
        let f = network_features(&pruned, bs).unwrap();
        gpreds.push(fg.predict(&f));
        ppreds.push(fp.predict(&f));
    }

    TopologyReport {
        gamma_mean: stats::mean(&gammas),
        gamma_std: stats::std_dev(&gammas),
        phi_mean: stats::mean(&phis),
        phi_std: stats::std_dev(&phis),
        gamma_err_pct: stats::mape(&gpreds, &gammas),
        phi_err_pct: stats::mape(&ppreds, &phis),
        strategies,
    }
}

pub fn print(r: &TopologyReport) {
    section("Sec. 6.2 — MobileNetV2 @50%, 100 pruning strategies, bs=80");
    println!(
        "measured Γ = {:.0} ± {:.0} MB   (paper: 4423 ± 1597 MB)",
        r.gamma_mean, r.gamma_std
    );
    println!(
        "measured Φ = {:.0} ± {:.0} ms   (paper: 1741 ± 871 ms)",
        r.phi_mean, r.phi_std
    );
    println!(
        "prediction error: Γ {:.2}%  Φ {:.2}%   (paper: 1.32% / 9.90%)",
        r.gamma_err_pct, r.phi_err_pct
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_variation_is_predictable() {
        let sim = Simulator::tx2();
        let r = run(&sim, 12, 7);
        // Strategies must create real spread…
        assert!(r.gamma_std > 0.02 * r.gamma_mean, "no topology spread");
        // …and the uniform-trained model must still predict well
        // (paper: 1.32% / 9.90%).
        assert!(r.gamma_err_pct < 8.0, "Γ err {:.2}%", r.gamma_err_pct);
        assert!(r.phi_err_pct < 15.0, "Φ err {:.2}%", r.phi_err_pct);
    }
}
