//! E1 / Fig. 3 — same base network in training and test sets.
//!
//! For each of {ResNet18, MobileNetV2, SqueezeNet, MnasNet}: train the Γ/Φ
//! forests on T = {0,30,50,70,90}% random-pruned topologies × 25 batch
//! sizes, test on the 14 held-out levels under (a) random and (b) L1-norm
//! pruning. Paper headline: mean Γ error ≤ 9.15%, Φ ≤ 14.7%; overall means
//! 5.53% / 9.37% (fn. 6, with Fig. 4 included).

use crate::device::Simulator;
use crate::profiler::train_test_split;
use crate::pruning::Strategy;
use crate::util::bench_harness::{section, table};

use super::{fit_gamma_phi, mean_errors, ErrorRow};

pub const NETWORKS: [&str; 4] = ["resnet18", "mobilenetv2", "squeezenet", "mnasnet"];

#[derive(Clone, Debug)]
pub struct Fig3Report {
    pub rows: Vec<ErrorRow>,
    pub mean_gamma_err: f64,
    pub mean_phi_err: f64,
}

pub fn run(sim: &Simulator, seed: u64) -> Fig3Report {
    let mut rows = Vec::new();
    for network in NETWORKS {
        let graph = crate::models::by_name(network).expect("zoo network");
        let (train, test_rand) =
            train_test_split(sim, network, &graph, Strategy::Random, seed);
        let (_, test_l1) = train_test_split(sim, network, &graph, Strategy::L1Norm, seed);
        let (fg, fp) = fit_gamma_phi(&train);
        for (label, test) in [("Rand", &test_rand), ("L1", &test_l1)] {
            rows.push(ErrorRow {
                network: network.to_string(),
                strategy: label.to_string(),
                gamma_err_pct: fg.mape(&test.x(), &test.y_gamma()),
                phi_err_pct: fp.mape(&test.x(), &test.y_phi()),
            });
        }
    }
    let (mg, mp) = mean_errors(&rows);
    Fig3Report {
        rows,
        mean_gamma_err: mg,
        mean_phi_err: mp,
    }
}

pub fn print(report: &Fig3Report) {
    section("Fig. 3 — same-network train/test: mean attribute prediction error (%)");
    table(
        &["network", "test strategy", "Γ err %", "Φ err %"],
        &report.rows.iter().map(|r| r.cells()).collect::<Vec<_>>(),
    );
    println!(
        "\nmeans: Γ {:.2}%  Φ {:.2}%   (paper: ≤9.15% / ≤14.7% worst-case; 5.53% / 9.37% overall means)",
        report.mean_gamma_err, report.mean_phi_err
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shape_holds_on_two_networks() {
        // Subset (2 networks) for test speed; the bench runs all 4.
        let sim = Simulator::tx2();
        let mut rows = Vec::new();
        for network in ["squeezenet", "mnasnet"] {
            let graph = crate::models::by_name(network).unwrap();
            let (train, test) =
                train_test_split(&sim, network, &graph, Strategy::Random, 3);
            let (fg, fp) = fit_gamma_phi(&train);
            rows.push(ErrorRow {
                network: network.into(),
                strategy: "Rand".into(),
                gamma_err_pct: fg.mape(&test.x(), &test.y_gamma()),
                phi_err_pct: fp.mape(&test.x(), &test.y_phi()),
            });
        }
        for r in &rows {
            assert!(r.gamma_err_pct < 9.15, "{r:?}");
            assert!(r.phi_err_pct < 14.7, "{r:?}");
        }
    }
}
