//! Network intermediate representation.
//!
//! CNN architectures are expressed as DAGs of typed operators with
//! per-sample shape inference (`C × H × W`; the batch dimension is symbolic,
//! exactly as in the paper's analytical model where every feature is linear
//! in `bs`). The IR is the common substrate for the network zoo, structured
//! pruning, analytical feature extraction and the device simulator.
//!
//! Derived analyses (shapes, conv summaries, parameter counts) are compiled
//! once into a [`NetworkPlan`] and shared by every consumer; see
//! [`plan`] for the invalidation rule (prune ⇒ rebuild plan).

pub mod builder;
pub mod graph;
pub mod op;
pub mod plan;
pub mod shapes;

pub use builder::GraphBuilder;
pub use graph::{ConvInfo, Graph, GraphError, Node, NodeId};
pub use op::{Act, Groups, Op};
pub use plan::NetworkPlan;
pub use shapes::{conv_out_spatial, pool_out_spatial_ceil, Shape};
