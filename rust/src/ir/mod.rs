//! Network intermediate representation.
//!
//! CNN architectures are expressed as DAGs of typed operators with
//! per-sample shape inference (`C × H × W`; the batch dimension is symbolic,
//! exactly as in the paper's analytical model where every feature is linear
//! in `bs`). The IR is the common substrate for the network zoo, structured
//! pruning, analytical feature extraction and the device simulator.
//!
//! Derived analyses (shapes, conv summaries, parameter counts) are compiled
//! once into a [`NetworkPlan`] and shared by every consumer; see
//! [`plan`] for the invalidation rule (prune ⇒ rebuild plan).
//!
//! Hot paths that evaluate many *pruned variants* of one base network go
//! one level further: [`arena`] compiles the base graph once into a
//! [`GraphArena`], expresses each candidate as a [`PruneOverlay`] and
//! rebuilds analyses incrementally into reusable [`PlanBuffers`] — no
//! graph clone, no full re-inference, no per-candidate allocation. Both
//! analysis forms are consumed through the [`PlanView`] trait.

pub mod arena;
pub mod builder;
pub mod graph;
pub mod op;
pub mod plan;
pub mod shapes;

pub use arena::{GraphArena, OverlayPlan, PlanBuffers, PlanSnapshot, PruneOverlay};
pub use builder::GraphBuilder;
pub use graph::{ConvInfo, Graph, GraphError, Node, NodeId};
pub use op::{Act, Groups, Op};
pub use plan::{NetworkPlan, PlanView};
pub use shapes::{conv_out_spatial, pool_out_spatial_ceil, Shape};
