//! Network intermediate representation.
//!
//! CNN architectures are expressed as DAGs of typed operators with
//! per-sample shape inference (`C × H × W`; the batch dimension is symbolic,
//! exactly as in the paper's analytical model where every feature is linear
//! in `bs`). The IR is the common substrate for the network zoo, structured
//! pruning, analytical feature extraction and the device simulator.

pub mod builder;
pub mod graph;
pub mod op;
pub mod shapes;

pub use builder::GraphBuilder;
pub use graph::{ConvInfo, Graph, GraphError, Node, NodeId};
pub use op::{Act, Groups, Op};
pub use shapes::{conv_out_spatial, pool_out_spatial_ceil, Shape};
