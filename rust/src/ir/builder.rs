//! Convenience builders for common layer patterns (conv-bn-relu, residual
//! blocks, inverted residuals, Fire and Inception modules). The network zoo
//! in `models/` is written entirely in terms of these helpers.

use super::graph::{Graph, NodeId};
use super::op::{Act, Groups, Op};

/// Fluent extension methods over [`Graph`] for building networks.
pub trait GraphBuilder {
    fn input(&mut self, c: usize, h: usize, w: usize) -> NodeId;
    fn conv(
        &mut self,
        name: &str,
        input: NodeId,
        out_c: usize,
        k: usize,
        s: usize,
        p: usize,
    ) -> NodeId;
    fn conv_g(
        &mut self,
        name: &str,
        input: NodeId,
        out_c: usize,
        k: usize,
        s: usize,
        p: usize,
        groups: Groups,
        bias: bool,
    ) -> NodeId;
    /// conv → batch-norm → activation.
    fn conv_bn_act(
        &mut self,
        name: &str,
        input: NodeId,
        out_c: usize,
        k: usize,
        s: usize,
        p: usize,
        act: Act,
    ) -> NodeId;
    /// conv → batch-norm (no activation; e.g. residual branch tails).
    fn conv_bn(
        &mut self,
        name: &str,
        input: NodeId,
        out_c: usize,
        k: usize,
        s: usize,
        p: usize,
    ) -> NodeId;
    /// depthwise conv → bn → activation.
    fn dwconv_bn_act(&mut self, name: &str, input: NodeId, k: usize, s: usize, act: Act)
        -> NodeId;
    fn relu(&mut self, name: &str, input: NodeId) -> NodeId;
    fn maxpool(&mut self, name: &str, input: NodeId, k: usize, s: usize, p: usize) -> NodeId;
    fn maxpool_ceil(&mut self, name: &str, input: NodeId, k: usize, s: usize, p: usize)
        -> NodeId;
    fn gap(&mut self, name: &str, input: NodeId) -> NodeId;
    /// global-avg-pool → flatten → linear classifier head.
    fn classifier(&mut self, input: NodeId, classes: usize) -> NodeId;
    fn add_join(&mut self, name: &str, inputs: &[NodeId]) -> NodeId;
    fn concat(&mut self, name: &str, inputs: &[NodeId]) -> NodeId;
}

impl GraphBuilder for Graph {
    fn input(&mut self, c: usize, h: usize, w: usize) -> NodeId {
        self.add("input", Op::Input { c, h, w }, &[])
    }

    fn conv(
        &mut self,
        name: &str,
        input: NodeId,
        out_c: usize,
        k: usize,
        s: usize,
        p: usize,
    ) -> NodeId {
        self.conv_g(name, input, out_c, k, s, p, Groups::Fixed(1), false)
    }

    fn conv_g(
        &mut self,
        name: &str,
        input: NodeId,
        out_c: usize,
        k: usize,
        s: usize,
        p: usize,
        groups: Groups,
        bias: bool,
    ) -> NodeId {
        self.add(
            name,
            Op::Conv2d {
                out_c,
                k,
                s,
                p,
                groups,
                bias,
            },
            &[input],
        )
    }

    fn conv_bn_act(
        &mut self,
        name: &str,
        input: NodeId,
        out_c: usize,
        k: usize,
        s: usize,
        p: usize,
        act: Act,
    ) -> NodeId {
        let c = self.conv(name, input, out_c, k, s, p);
        let b = self.add(format!("{name}.bn"), Op::BatchNorm, &[c]);
        self.add(format!("{name}.act"), Op::Activation(act), &[b])
    }

    fn conv_bn(
        &mut self,
        name: &str,
        input: NodeId,
        out_c: usize,
        k: usize,
        s: usize,
        p: usize,
    ) -> NodeId {
        let c = self.conv(name, input, out_c, k, s, p);
        self.add(format!("{name}.bn"), Op::BatchNorm, &[c])
    }

    fn dwconv_bn_act(
        &mut self,
        name: &str,
        input: NodeId,
        k: usize,
        s: usize,
        act: Act,
    ) -> NodeId {
        let c = self.conv_g(name, input, 0, k, s, k / 2, Groups::Depthwise, false);
        let b = self.add(format!("{name}.bn"), Op::BatchNorm, &[c]);
        self.add(format!("{name}.act"), Op::Activation(act), &[b])
    }

    fn relu(&mut self, name: &str, input: NodeId) -> NodeId {
        self.add(name, Op::Activation(Act::Relu), &[input])
    }

    fn maxpool(&mut self, name: &str, input: NodeId, k: usize, s: usize, p: usize) -> NodeId {
        self.add(
            name,
            Op::MaxPool {
                k,
                s,
                p,
                ceil: false,
            },
            &[input],
        )
    }

    fn maxpool_ceil(
        &mut self,
        name: &str,
        input: NodeId,
        k: usize,
        s: usize,
        p: usize,
    ) -> NodeId {
        self.add(
            name,
            Op::MaxPool {
                k,
                s,
                p,
                ceil: true,
            },
            &[input],
        )
    }

    fn gap(&mut self, name: &str, input: NodeId) -> NodeId {
        self.add(name, Op::GlobalAvgPool, &[input])
    }

    fn classifier(&mut self, input: NodeId, classes: usize) -> NodeId {
        let g = self.gap("head.gap", input);
        let f = self.add("head.flatten", Op::Flatten, &[g]);
        self.add(
            "head.fc",
            Op::Linear {
                out: classes,
                bias: true,
            },
            &[f],
        )
    }

    fn add_join(&mut self, name: &str, inputs: &[NodeId]) -> NodeId {
        self.add(name, Op::Add, inputs)
    }

    fn concat(&mut self, name: &str, inputs: &[NodeId]) -> NodeId {
        self.add(name, Op::Concat, inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chain_produces_valid_graph() {
        let mut g = Graph::new("b");
        let x = g.input(3, 224, 224);
        let c = g.conv_bn_act("stem", x, 32, 3, 2, 1, Act::Relu);
        let d = g.dwconv_bn_act("dw", c, 3, 1, Act::Relu6);
        let head = g.classifier(d, 1000);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[d].channels(), 32);
        assert_eq!(shapes[head].numel(), 1000);
    }

    #[test]
    fn residual_join_builder() {
        let mut g = Graph::new("res");
        let x = g.input(3, 32, 32);
        let a = g.conv_bn_act("c1", x, 8, 3, 1, 1, Act::Relu);
        let b = g.conv_bn("c2", a, 8, 3, 1, 1);
        let sc = g.conv_bn("sc", x, 8, 1, 1, 0);
        let j = g.add_join("join", &[b, sc]);
        let r = g.relu("out", j);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[r].channels(), 8);
    }
}
