//! Operator vocabulary of the network IR.
//!
//! The set covers everything needed to express the paper's network zoo
//! (AlexNet, VGG, ResNet-18/50, MobileNetV2, SqueezeNet, MnasNet, GoogLeNet,
//! NiN and the elastic OFA-ResNet50 space): convolutions with groups,
//! batch-norm, activations, pooling, linear heads, and the residual / concat
//! connectivity that drives pruning dependency analysis.

/// Grouping mode of a convolution.
///
/// Depthwise convolutions are represented symbolically rather than with a
/// literal group count so that structured pruning keeps them valid: after
/// the input channel count changes, `Depthwise` still means `groups == m_l`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Groups {
    /// Standard convolution (`g = 1`) or explicit grouped conv (`g = n`).
    Fixed(usize),
    /// Depthwise: `g = in_channels`, out channels tied to in channels.
    Depthwise,
}

impl Groups {
    /// Resolve to a concrete group count for a given input channel count.
    pub fn resolve(&self, in_c: usize) -> usize {
        match *self {
            Groups::Fixed(g) => g,
            Groups::Depthwise => in_c,
        }
    }
}

/// Activation functions (all shape-preserving; they matter for the device
/// simulator's pointwise cost and memory accounting, not for the features).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    Relu,
    Relu6,
    HSwish,
    Sigmoid,
}

/// IR operators.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// Network input: `C × H × W` per sample.
    Input { c: usize, h: usize, w: usize },
    /// 2-D convolution with `out_c` filters (the paper's `n_l`).
    Conv2d {
        out_c: usize,
        k: usize,
        s: usize,
        p: usize,
        groups: Groups,
        bias: bool,
    },
    /// Batch normalisation over channels.
    BatchNorm,
    /// Pointwise activation.
    Activation(Act),
    /// Max pooling; `ceil` selects ceil-mode output rounding.
    MaxPool { k: usize, s: usize, p: usize, ceil: bool },
    /// Average pooling.
    AvgPool { k: usize, s: usize, p: usize, ceil: bool },
    /// Global average pool to `C × 1 × 1`.
    GlobalAvgPool,
    /// Fully connected layer with `out` features.
    Linear { out: usize, bias: bool },
    /// Elementwise addition of all inputs (residual join).
    Add,
    /// Channel-dimension concatenation of all inputs (Fire / Inception).
    Concat,
    /// Flatten `C × H × W` → vector.
    Flatten,
    /// Dropout (memory-relevant only: PyTorch stores the mask).
    Dropout(f64),
}

impl Op {
    /// Does this op preserve the channel count of its (single) input?
    /// Used by pruning dependency analysis to walk back to the node that
    /// *defines* a channel dimension.
    pub fn preserves_channels(&self) -> bool {
        matches!(
            self,
            Op::BatchNorm
                | Op::Activation(_)
                | Op::MaxPool { .. }
                | Op::AvgPool { .. }
                | Op::GlobalAvgPool
                | Op::Dropout(_)
        )
    }

    /// Short mnemonic for debugging / dumps.
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Input { .. } => "input",
            Op::Conv2d { .. } => "conv",
            Op::BatchNorm => "bn",
            Op::Activation(_) => "act",
            Op::MaxPool { .. } => "maxpool",
            Op::AvgPool { .. } => "avgpool",
            Op::GlobalAvgPool => "gap",
            Op::Linear { .. } => "linear",
            Op::Add => "add",
            Op::Concat => "concat",
            Op::Flatten => "flatten",
            Op::Dropout(_) => "dropout",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_resolution() {
        assert_eq!(Groups::Fixed(1).resolve(64), 1);
        assert_eq!(Groups::Fixed(4).resolve(64), 4);
        assert_eq!(Groups::Depthwise.resolve(32), 32);
        assert_eq!(Groups::Depthwise.resolve(17), 17);
    }

    #[test]
    fn channel_preservation_classification() {
        assert!(Op::BatchNorm.preserves_channels());
        assert!(Op::Activation(Act::Relu).preserves_channels());
        assert!(Op::GlobalAvgPool.preserves_channels());
        assert!(!Op::Concat.preserves_channels());
        assert!(!Op::Add.preserves_channels());
        assert!(!Op::Conv2d {
            out_c: 8,
            k: 3,
            s: 1,
            p: 1,
            groups: Groups::Fixed(1),
            bias: false
        }
        .preserves_channels());
    }
}
