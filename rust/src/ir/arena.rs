//! The zero-allocation candidate-evaluation fast path: [`GraphArena`],
//! [`PruneOverlay`] and incremental overlay plans.
//!
//! The OFA search and the profiling campaigns evaluate tens of thousands
//! of *unique* topologies. Before this layer, every unique candidate paid
//! a full `Graph::clone` (per-node `String` names + `Vec` inputs), a
//! from-scratch `prune`, a complete `NetworkPlan::build` shape-inference
//! pass and fresh feature-row allocations. The arena compiles a base
//! network **once** into immutable, cache-friendly tables:
//!
//! - node names interned into a single `String` (span table),
//! - input adjacency in CSR form (one flat `Vec<NodeId>` + offsets),
//! - an op table plus a conv table (node id ↔ conv slot, base widths),
//! - a precompiled fingerprint byte program (see [`GraphArena::fingerprint`]),
//! - the pruning dependency analysis (`protected_convs` + `prune_groups`),
//!   computed once per base network instead of on every `prune` call.
//!
//! A pruned candidate is then just a [`PruneOverlay`] — per-conv output
//! widths over the arena — and its analysis is rebuilt **incrementally**
//! into caller-owned [`PlanBuffers`]: only nodes downstream of a changed
//! conv recompute their shape, and parameter totals update by delta. The
//! resulting [`OverlayPlan`] view implements
//! [`PlanView`](super::plan::PlanView), so the simulator and feature
//! extractor run the exact same code as over a [`NetworkPlan`].
//!
//! # Invalidation rule
//!
//! The arena is immutable per base network. Pruning never mutates it:
//! prune ⇒ new overlay ⇒ new fingerprint (and the overlay's widths are
//! the *only* candidate state). This extends PR 1's "prune ⇒ rebuild
//! plan" and PR 2's "prune ⇒ new fingerprint ⇒ cache miss" rules without
//! ever cloning or mutating a graph.
//!
//! # Bit-identity
//!
//! Every derived quantity goes through the same per-node kernels the
//! legacy path uses (`node_output_shape`, `conv_info_from_shapes`,
//! `node_param_count`), shape/param arithmetic is exact (`usize`), and
//! the fingerprint hashes the identical byte stream — so overlay results
//! are bit-identical to clone+rebuild, asserted across the zoo by
//! `rust/tests/overlay_equivalence.rs`.

use std::sync::atomic::{AtomicU64, Ordering};

use super::graph::{
    conv_info_from_shapes, node_output_shape, node_param_count, ConvInfo, Graph, GraphError, Node,
    NodeId,
};
use super::op::Op;
use super::plan::PlanView;
use super::shapes::Shape;
use crate::pruning::{protected_convs, prune_groups_from_shapes, PruneGroup};
use crate::util::fingerprint::{fnv_bytes, fnv_decimal, fnv_u64, FNV_OFFSET};

/// Process-unique arena ids: overlays and buffers carry the id of the
/// arena they were built for, so cross-arena mixups fail loudly instead
/// of producing silently wrong analyses.
static NEXT_ARENA_ID: AtomicU64 = AtomicU64::new(1);

/// Fingerprint byte program per node: non-conv ops hash a fixed
/// precomputed span; convs hash prefix + overlay width (decimal) + suffix,
/// reproducing `format!("{:?}", op)` of the materialized op exactly.
#[derive(Clone, Debug)]
enum FpNode {
    Fixed { start: u32, end: u32 },
    Conv { pre: (u32, u32), suf: (u32, u32), slot: u32 },
}

/// Debug prefix of `Op::Conv2d` up to the `out_c` digits — `out_c` is the
/// first field, so everything before the digits is this constant.
const CONV_DBG_PREFIX: &str = "Conv2d { out_c: ";

/// An immutable, compiled base network (see module docs). Built once per
/// base graph; all candidate state lives in [`PruneOverlay`]s.
#[derive(Clone, Debug)]
pub struct GraphArena {
    id: u64,
    name: String,
    output: NodeId,
    /// Op per node. Conv `out_c` values here are the *base* widths;
    /// overlays supply effective widths without touching this table.
    ops: Vec<Op>,
    /// CSR input adjacency: node `i`'s inputs are
    /// `inputs[input_offsets[i]..input_offsets[i+1]]`.
    input_offsets: Vec<u32>,
    inputs: Vec<NodeId>,
    /// Interned node names (one allocation for the whole graph).
    names: String,
    name_spans: Vec<(u32, u32)>,
    /// Conv node ids in topological order (conv slot ↔ position here).
    convs: Vec<NodeId>,
    /// Node id → conv slot, `u32::MAX` for non-conv nodes.
    conv_slot: Vec<u32>,
    /// Base (unpruned) `out_c` per conv slot.
    base_widths: Vec<usize>,
    /// Analysis of the base (identity-overlay) network.
    base: PlanSnapshot,
    /// Fingerprint byte program (see [`FpNode`]).
    fp_bytes: Vec<u8>,
    fp_nodes: Vec<FpNode>,
    /// Pruning dependency analysis, computed once per base network
    /// (`protected_convs` + `prune_groups` used to run on every `prune`).
    protected: Vec<NodeId>,
    groups: Vec<PruneGroup>,
}

impl GraphArena {
    /// Compile `graph` into the arena form. One validating shape-inference
    /// pass (the same one `NetworkPlan::build` runs) plus the pruning
    /// dependency analysis; everything downstream is allocation-free.
    pub fn compile(graph: &Graph) -> Result<GraphArena, GraphError> {
        let shapes = graph.infer_shapes()?;
        let n = graph.nodes.len();
        let mut input_offsets = Vec::with_capacity(n + 1);
        let mut inputs = Vec::new();
        let mut names = String::new();
        let mut name_spans = Vec::with_capacity(n);
        let mut ops = Vec::with_capacity(n);
        let mut convs = Vec::new();
        let mut conv_slot = vec![u32::MAX; n];
        let mut base_widths = Vec::new();
        for node in &graph.nodes {
            input_offsets.push(inputs.len() as u32);
            inputs.extend_from_slice(&node.inputs);
            let start = names.len() as u32;
            names.push_str(&node.name);
            name_spans.push((start, names.len() as u32));
            if let Op::Conv2d { out_c, .. } = &node.op {
                conv_slot[node.id] = convs.len() as u32;
                convs.push(node.id);
                base_widths.push(*out_c);
            }
            ops.push(node.op.clone());
        }
        input_offsets.push(inputs.len() as u32);

        // Fingerprint byte program: replicate engine::cache::graph_fingerprint's
        // per-node `format!("{:?}", op)` bytes, with conv widths left as holes.
        let mut fp_bytes = Vec::new();
        let mut fp_nodes = Vec::with_capacity(n);
        for node in &graph.nodes {
            let dbg = format!("{:?}", node.op);
            if let Op::Conv2d { out_c, .. } = &node.op {
                let digits = out_c.to_string();
                assert!(
                    dbg.starts_with(CONV_DBG_PREFIX)
                        && dbg[CONV_DBG_PREFIX.len()..].starts_with(&digits),
                    "unexpected Conv2d debug layout: {dbg}"
                );
                let pre_start = fp_bytes.len() as u32;
                fp_bytes.extend_from_slice(CONV_DBG_PREFIX.as_bytes());
                let pre_end = fp_bytes.len() as u32;
                fp_bytes.extend_from_slice(dbg[CONV_DBG_PREFIX.len() + digits.len()..].as_bytes());
                let suf_end = fp_bytes.len() as u32;
                fp_nodes.push(FpNode::Conv {
                    pre: (pre_start, pre_end),
                    suf: (pre_end, suf_end),
                    slot: conv_slot[node.id],
                });
            } else {
                let start = fp_bytes.len() as u32;
                fp_bytes.extend_from_slice(dbg.as_bytes());
                fp_nodes.push(FpNode::Fixed {
                    start,
                    end: fp_bytes.len() as u32,
                });
            }
        }

        let protected = protected_convs(graph);
        // Reuse this compile's shape pass — no second inference inside the
        // dependency analysis.
        let groups = prune_groups_from_shapes(graph, &protected, &shapes);

        let id = NEXT_ARENA_ID.fetch_add(1, Ordering::Relaxed);
        let convs_info: Vec<ConvInfo> = graph
            .nodes
            .iter()
            .filter_map(|nd| conv_info_from_shapes(nd.id, &nd.op, &nd.inputs, &shapes))
            .collect();
        let node_params: Vec<usize> = graph
            .nodes
            .iter()
            .map(|nd| node_param_count(nd.id, &nd.op, &nd.inputs, &shapes))
            .collect();
        let param_count = node_params.iter().sum();
        let base = PlanSnapshot {
            arena_id: id,
            shapes,
            convs: convs_info,
            node_params,
            param_count,
        };

        Ok(GraphArena {
            id,
            name: graph.name.clone(),
            output: graph.output,
            ops,
            input_offsets,
            inputs,
            names,
            name_spans,
            convs,
            conv_slot,
            base_widths,
            base,
            fp_bytes,
            fp_nodes,
            protected,
            groups,
        })
    }

    /// Process-unique id of this arena.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Name of the base graph.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Node count.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Number of convolution nodes (the overlay width-vector length).
    pub fn conv_count(&self) -> usize {
        self.convs.len()
    }

    /// Conv node ids in topological order (slot `i` ↔ `conv_ids()[i]`).
    pub fn conv_ids(&self) -> &[NodeId] {
        &self.convs
    }

    /// Conv slot of a node, if it is a convolution.
    pub fn conv_slot_of(&self, id: NodeId) -> Option<usize> {
        let s = self.conv_slot[id];
        (s != u32::MAX).then_some(s as usize)
    }

    /// Base (unpruned) `out_c` per conv slot.
    pub fn base_widths(&self) -> &[usize] {
        &self.base_widths
    }

    /// The cached pruning dependency analysis: conv ids whose filter count
    /// is pinned by the class dimension.
    pub fn protected_convs(&self) -> &[NodeId] {
        &self.protected
    }

    /// The cached channel-dependency groups (see [`crate::pruning::groups`]).
    pub fn prune_groups(&self) -> &[PruneGroup] {
        &self.groups
    }

    fn node_inputs(&self, id: NodeId) -> &[NodeId] {
        &self.inputs[self.input_offsets[id] as usize..self.input_offsets[id + 1] as usize]
    }

    fn node_name(&self, id: NodeId) -> &str {
        let (s, e) = self.name_spans[id];
        &self.names[s as usize..e as usize]
    }

    fn width_override(&self, id: NodeId, overlay: &PruneOverlay) -> Option<usize> {
        let slot = self.conv_slot[id];
        (slot != u32::MAX).then(|| overlay.widths[slot as usize])
    }

    /// The identity overlay: base widths everywhere (an unpruned network).
    pub fn identity_overlay(&self) -> PruneOverlay {
        PruneOverlay {
            arena_id: self.id,
            widths: self.base_widths.clone(),
        }
    }

    /// Analysis view of the unmodified base network (compiled once).
    pub fn base_view(&self) -> OverlayPlan<'_> {
        OverlayPlan {
            arena: self,
            snap: &self.base,
        }
    }

    /// Structural fingerprint of (arena, overlay) — byte-identical to
    /// [`crate::engine::cache::graph_fingerprint`] of the materialized
    /// pruned graph, computed without building one and without allocating.
    pub fn fingerprint(&self, overlay: &PruneOverlay) -> u64 {
        assert_eq!(
            overlay.arena_id, self.id,
            "overlay belongs to a different arena"
        );
        let mut h = fnv_bytes(FNV_OFFSET, b"graph/");
        h = fnv_u64(h, self.ops.len() as u64);
        h = fnv_u64(h, self.output as u64);
        for (id, fp) in self.fp_nodes.iter().enumerate() {
            match fp {
                FpNode::Fixed { start, end } => {
                    h = fnv_bytes(h, &self.fp_bytes[*start as usize..*end as usize]);
                }
                FpNode::Conv { pre, suf, slot } => {
                    h = fnv_bytes(h, &self.fp_bytes[pre.0 as usize..pre.1 as usize]);
                    h = fnv_decimal(h, overlay.widths[*slot as usize]);
                    h = fnv_bytes(h, &self.fp_bytes[suf.0 as usize..suf.1 as usize]);
                }
            }
            let ins = self.node_inputs(id);
            h = fnv_u64(h, ins.len() as u64);
            for &i in ins {
                h = fnv_u64(h, i as u64);
            }
        }
        h
    }

    /// Rebuild the overlay's analysis into `buf`. When `buf` already holds
    /// this arena's analysis for some earlier overlay, only nodes
    /// downstream of a changed conv recompute (incremental shape
    /// inference); otherwise a full single-pass build runs. Either way the
    /// result is bit-identical to `NetworkPlan::build` over the
    /// materialized pruned graph.
    pub fn plan_into(
        &self,
        overlay: &PruneOverlay,
        buf: &mut PlanBuffers,
    ) -> Result<(), GraphError> {
        assert_eq!(
            overlay.arena_id, self.id,
            "overlay belongs to a different arena"
        );
        assert_eq!(
            overlay.widths.len(),
            self.convs.len(),
            "overlay width vector does not match the arena's conv count"
        );
        // Callers may fill widths wholesale via `widths_mut`, bypassing
        // `set_width`'s per-slot assert — re-establish the invariant loudly
        // here rather than let a zero width flow into silently wrong
        // shapes/params on chain topologies.
        assert!(
            overlay.widths.iter().all(|&w| w >= 1),
            "overlay contains a zero conv width"
        );
        let r = if buf.arena_id == Some(self.id) && buf.widths.len() == overlay.widths.len() {
            self.plan_incremental(overlay, buf)
        } else {
            self.plan_full(overlay, buf)
        };
        if r.is_err() {
            // A failed rebuild leaves the buffers partially written —
            // invalidate so the next call starts from scratch.
            buf.arena_id = None;
        }
        r
    }

    fn plan_full(&self, overlay: &PruneOverlay, buf: &mut PlanBuffers) -> Result<(), GraphError> {
        let n = self.ops.len();
        buf.arena_id = Some(self.id);
        buf.widths.clear();
        buf.widths.extend_from_slice(&overlay.widths);
        let snap = &mut buf.snap;
        snap.arena_id = self.id;
        snap.shapes.clear();
        snap.shapes.reserve(n);
        for id in 0..n {
            let shape = node_output_shape(
                id,
                self.node_name(id),
                &self.ops[id],
                self.node_inputs(id),
                &snap.shapes,
                self.width_override(id, overlay),
            )?;
            snap.shapes.push(shape);
        }
        snap.convs.clear();
        for &cid in &self.convs {
            snap.convs.push(
                conv_info_from_shapes(cid, &self.ops[cid], self.node_inputs(cid), &snap.shapes)
                    .expect("conv table only lists conv nodes"),
            );
        }
        snap.node_params.clear();
        let mut total = 0usize;
        for id in 0..n {
            let p = node_param_count(id, &self.ops[id], self.node_inputs(id), &snap.shapes);
            snap.node_params.push(p);
            total += p;
        }
        snap.param_count = total;
        Ok(())
    }

    fn plan_incremental(
        &self,
        overlay: &PruneOverlay,
        buf: &mut PlanBuffers,
    ) -> Result<(), GraphError> {
        let n = self.ops.len();
        buf.shape_changed.clear();
        buf.shape_changed.resize(n, false);
        let snap = &mut buf.snap;
        let mut total = snap.param_count;
        for id in 0..n {
            let slot = self.conv_slot[id];
            let width_changed = slot != u32::MAX
                && overlay.widths[slot as usize] != buf.widths[slot as usize];
            let input_changed = self
                .node_inputs(id)
                .iter()
                .any(|&i| buf.shape_changed[i]);
            if !(width_changed || input_changed) {
                continue;
            }
            // Recompute this node. Its own output may still be unchanged
            // (e.g. a conv whose *input* narrowed: out_c is fixed by the
            // overlay) — then downstream propagation stops, but its
            // ConvInfo / parameter contribution must refresh regardless.
            let new_shape = node_output_shape(
                id,
                self.node_name(id),
                &self.ops[id],
                self.node_inputs(id),
                &snap.shapes,
                self.width_override(id, overlay),
            )?;
            if new_shape != snap.shapes[id] {
                snap.shapes[id] = new_shape;
                buf.shape_changed[id] = true;
            }
            if slot != u32::MAX {
                snap.convs[slot as usize] = conv_info_from_shapes(
                    id,
                    &self.ops[id],
                    self.node_inputs(id),
                    &snap.shapes,
                )
                .expect("conv table only lists conv nodes");
            }
            let p = node_param_count(id, &self.ops[id], self.node_inputs(id), &snap.shapes);
            total = total - snap.node_params[id] + p;
            snap.node_params[id] = p;
        }
        snap.param_count = total;
        buf.widths.copy_from_slice(&overlay.widths);
        Ok(())
    }

    /// View over buffers last filled by [`GraphArena::plan_into`] on this
    /// arena.
    pub fn view_buffers<'a>(&'a self, buf: &'a PlanBuffers) -> OverlayPlan<'a> {
        assert_eq!(
            buf.arena_id,
            Some(self.id),
            "buffers were not compiled for this arena"
        );
        OverlayPlan {
            arena: self,
            snap: &buf.snap,
        }
    }

    /// View over a detached [`PlanSnapshot`] taken from this arena's
    /// buffers (how the profiler shares one plan per level across its
    /// worker pool).
    pub fn view<'a>(&'a self, snap: &'a PlanSnapshot) -> OverlayPlan<'a> {
        assert_eq!(
            snap.arena_id, self.id,
            "snapshot was not compiled for this arena"
        );
        OverlayPlan { arena: self, snap }
    }

    /// Materialize (arena, overlay) back into a plain [`Graph`] — test /
    /// interop escape hatch, **not** on any hot path (the whole point of
    /// the overlay is to never do this per candidate).
    pub fn to_graph(&self, overlay: &PruneOverlay) -> Graph {
        assert_eq!(
            overlay.arena_id, self.id,
            "overlay belongs to a different arena"
        );
        let mut nodes = Vec::with_capacity(self.ops.len());
        for id in 0..self.ops.len() {
            let mut op = self.ops[id].clone();
            if let Op::Conv2d { out_c, .. } = &mut op {
                *out_c = overlay.widths[self.conv_slot[id] as usize];
            }
            nodes.push(Node {
                id,
                name: self.node_name(id).to_string(),
                op,
                inputs: self.node_inputs(id).to_vec(),
            });
        }
        Graph {
            name: self.name.clone(),
            nodes,
            output: self.output,
        }
    }
}

/// Per-conv output widths over a [`GraphArena`] — the entire state of a
/// pruned candidate. Producing one *is* pruning on the fast path (see
/// [`crate::pruning::prune_overlay`]); no graph is cloned or mutated.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PruneOverlay {
    arena_id: u64,
    widths: Vec<usize>,
}

impl PruneOverlay {
    /// Effective `out_c` per conv slot (depthwise slots carry the nominal
    /// base width; their effective channels follow the input, exactly as
    /// in the graph path).
    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    /// Set one conv slot's width.
    pub fn set_width(&mut self, slot: usize, width: usize) {
        assert!(width >= 1, "cannot prune a conv to zero filters");
        self.widths[slot] = width;
    }

    /// Rebind to `arena` leaving the width vector empty for the caller to
    /// fill completely (e.g. `SubnetConfig::fill_conv_widths`) — one
    /// overlay allocation serves candidates across many arenas with no
    /// identity-width copy (deliberately *not* an identity rebind: for a
    /// fresh identity overlay use [`GraphArena::identity_overlay`]).
    pub fn rebind_empty(&mut self, arena: &GraphArena) {
        self.arena_id = arena.id;
        self.widths.clear();
    }

    /// Direct width-vector access for callers that overwrite every slot
    /// (the OFA engine writes a candidate's full width sequence). Length
    /// must end up equal to the arena's conv count — enforced by
    /// [`GraphArena::plan_into`] / [`GraphArena::fingerprint`].
    pub fn widths_mut(&mut self) -> &mut Vec<usize> {
        &mut self.widths
    }

    /// Id of the arena this overlay was built for.
    pub fn arena_id(&self) -> u64 {
        self.arena_id
    }
}

/// A detached analysis snapshot (shapes, conv summaries, per-node and
/// total parameter counts) of one (arena, overlay) pair. Cheap to clone;
/// the profiler takes one per pruning level so its worker pool can read
/// them concurrently while the buffers move on.
#[derive(Clone, Debug, Default)]
pub struct PlanSnapshot {
    arena_id: u64,
    shapes: Vec<Shape>,
    convs: Vec<ConvInfo>,
    node_params: Vec<usize>,
    param_count: usize,
}

/// Caller-owned scratch for overlay plan rebuilds: reused across a whole
/// generation or campaign shard, so steady-state candidate evaluation
/// performs no heap allocation. Holds the last overlay's widths (the
/// incremental diff base) and the current [`PlanSnapshot`].
#[derive(Clone, Debug, Default)]
pub struct PlanBuffers {
    arena_id: Option<u64>,
    widths: Vec<usize>,
    snap: PlanSnapshot,
    shape_changed: Vec<bool>,
}

impl PlanBuffers {
    pub fn new() -> PlanBuffers {
        PlanBuffers::default()
    }

    /// Detach a clone of the current analysis (see [`PlanSnapshot`]).
    pub fn snapshot(&self) -> PlanSnapshot {
        self.snap.clone()
    }

    /// Forget any held analysis (the next `plan_into` runs a full build).
    pub fn invalidate(&mut self) {
        self.arena_id = None;
    }
}

/// Read-only analysis view of one (arena, overlay) pair — the overlay
/// path's counterpart of [`NetworkPlan`](super::plan::NetworkPlan),
/// implementing the same [`PlanView`] trait so the simulator and feature
/// extractor are oblivious to which one they are reading.
#[derive(Clone, Copy, Debug)]
pub struct OverlayPlan<'a> {
    arena: &'a GraphArena,
    snap: &'a PlanSnapshot,
}

impl<'a> OverlayPlan<'a> {
    /// The arena this view reads from.
    pub fn arena(&self) -> &'a GraphArena {
        self.arena
    }

    /// Model size in MB at fp32.
    pub fn model_size_mb(&self) -> f64 {
        self.snap.param_count as f64 * 4.0 / (1024.0 * 1024.0)
    }
}

impl PlanView for OverlayPlan<'_> {
    fn n_nodes(&self) -> usize {
        self.snap.shapes.len()
    }

    fn op(&self, id: NodeId) -> &Op {
        &self.arena.ops[id]
    }

    fn inputs(&self, id: NodeId) -> &[NodeId] {
        self.arena.node_inputs(id)
    }

    fn shapes(&self) -> &[Shape] {
        &self.snap.shapes
    }

    fn conv_infos(&self) -> &[ConvInfo] {
        &self.snap.convs
    }

    fn param_count(&self) -> usize {
        self.snap.param_count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::graph_fingerprint;
    use crate::ir::NetworkPlan;
    use crate::models;
    use crate::pruning::{prune, prune_overlay, Strategy};
    use crate::util::rng::Pcg64;

    #[test]
    fn base_view_matches_network_plan() {
        let g = models::resnet18(1000);
        let arena = GraphArena::compile(&g).unwrap();
        let plan = NetworkPlan::build(&g).unwrap();
        let view = arena.base_view();
        assert_eq!(view.shapes(), PlanView::shapes(&plan));
        assert_eq!(view.conv_infos(), PlanView::conv_infos(&plan));
        assert_eq!(PlanView::param_count(&view), PlanView::param_count(&plan));
        assert_eq!(view.n_nodes(), g.len());
        for id in 0..g.len() {
            assert_eq!(view.op(id), &g.nodes[id].op);
            assert_eq!(view.inputs(id), g.nodes[id].inputs.as_slice());
        }
    }

    #[test]
    fn identity_overlay_fingerprint_matches_graph() {
        let g = models::squeezenet(1000);
        let arena = GraphArena::compile(&g).unwrap();
        let ov = arena.identity_overlay();
        assert_eq!(arena.fingerprint(&ov), graph_fingerprint(&g));
    }

    #[test]
    fn overlay_plan_and_fingerprint_match_pruned_graph() {
        let g = models::mobilenet_v2(1000);
        let arena = GraphArena::compile(&g).unwrap();
        let mut buf = PlanBuffers::new();
        for level in [0.0, 0.3, 0.7] {
            let mut rng_a = Pcg64::new(42);
            let mut rng_b = Pcg64::new(42);
            let pruned = prune(&g, Strategy::L1Norm, level, &mut rng_a);
            let ov = prune_overlay(&arena, Strategy::L1Norm, level, &mut rng_b);
            assert_eq!(
                rng_a.next_u64(),
                rng_b.next_u64(),
                "RNG streams diverged at level {level}"
            );
            arena.plan_into(&ov, &mut buf).unwrap();
            let view = arena.view_buffers(&buf);
            let plan = NetworkPlan::build(&pruned).unwrap();
            assert_eq!(view.shapes(), PlanView::shapes(&plan), "level {level}");
            assert_eq!(view.conv_infos(), PlanView::conv_infos(&plan));
            assert_eq!(PlanView::param_count(&view), PlanView::param_count(&plan));
            assert_eq!(arena.fingerprint(&ov), graph_fingerprint(&pruned));
        }
    }

    #[test]
    fn incremental_equals_full_rebuild() {
        let g = models::resnet50(1000);
        let arena = GraphArena::compile(&g).unwrap();
        let mut incremental = PlanBuffers::new();
        for (seed, level) in [(1u64, 0.2), (2, 0.5), (3, 0.1), (4, 0.8)] {
            let mut rng = Pcg64::new(seed);
            let ov = prune_overlay(&arena, Strategy::Random, level, &mut rng);
            arena.plan_into(&ov, &mut incremental).unwrap();
            let mut fresh = PlanBuffers::new();
            arena.plan_into(&ov, &mut fresh).unwrap();
            let a = arena.view_buffers(&incremental);
            let b = arena.view_buffers(&fresh);
            assert_eq!(a.shapes(), b.shapes());
            assert_eq!(a.conv_infos(), b.conv_infos());
            assert_eq!(PlanView::param_count(&a), PlanView::param_count(&b));
        }
    }

    #[test]
    fn to_graph_round_trips_structure() {
        let g = models::nin(1000);
        let arena = GraphArena::compile(&g).unwrap();
        let mut rng = Pcg64::new(5);
        let ov = prune_overlay(&arena, Strategy::Random, 0.5, &mut rng);
        let mut rng2 = Pcg64::new(5);
        let pruned = prune(&g, Strategy::Random, 0.5, &mut rng2);
        let back = arena.to_graph(&ov);
        assert_eq!(back.nodes.len(), pruned.nodes.len());
        assert_eq!(back.output, pruned.output);
        for (a, b) in back.nodes.iter().zip(&pruned.nodes) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.op, b.op);
            assert_eq!(a.inputs, b.inputs);
        }
    }

    #[test]
    #[should_panic(expected = "different arena")]
    fn cross_arena_overlay_rejected() {
        let a = GraphArena::compile(&models::alexnet(1000)).unwrap();
        let b = GraphArena::compile(&models::alexnet(1000)).unwrap();
        let ov = a.identity_overlay();
        let mut buf = PlanBuffers::new();
        let _ = b.plan_into(&ov, &mut buf);
    }

    #[test]
    fn error_in_rebuild_invalidates_buffers() {
        let g = models::resnet18(1000);
        let arena = GraphArena::compile(&g).unwrap();
        let mut buf = PlanBuffers::new();
        let ov = arena.identity_overlay();
        arena.plan_into(&ov, &mut buf).unwrap();
        // Break one member of a residual group: the Add arm must reject
        // the channel mismatch, and the buffers must invalidate.
        let mut bad = arena.identity_overlay();
        let slot = arena.conv_slot_of(arena.conv_ids()[0]).unwrap();
        bad.set_width(slot, 7);
        assert!(arena.plan_into(&bad, &mut buf).is_err());
        // Next plan (full rebuild) still works.
        arena.plan_into(&ov, &mut buf).unwrap();
        assert_eq!(
            PlanView::param_count(&arena.view_buffers(&buf)),
            g.param_count().unwrap()
        );
    }
}
