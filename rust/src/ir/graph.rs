//! Network graph representation, builder API and shape inference.
//!
//! Networks are DAGs of [`Op`] nodes stored in topological order (the
//! builder only lets a node consume earlier nodes, so the invariant holds by
//! construction). Shape inference propagates per-sample `C × H × W` shapes
//! and is re-run after structured pruning mutates filter counts.
//!
//! The per-call analyses below ([`Graph::infer_shapes`],
//! [`Graph::conv_infos`], [`Graph::param_count`]) are the reference
//! implementations; hot paths compile them once into a
//! [`NetworkPlan`](super::plan::NetworkPlan) via [`Graph::plan`] and reuse
//! the cached results. Pruning mutates the graph, so any plan must be
//! rebuilt afterwards (prune ⇒ rebuild plan — enforced by the borrow).

use super::op::{Groups, Op};
use super::plan::NetworkPlan;
use super::shapes::{conv_out_spatial, pool_out_spatial_ceil, Shape};
use std::fmt;

/// Node id (index into `Graph::nodes`).
pub type NodeId = usize;

/// A single IR node.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: Op,
    pub inputs: Vec<NodeId>,
}

/// Errors raised by graph validation / shape inference.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum GraphError {
    #[error("node {0} ({1}): expected {2} inputs, got {3}")]
    Arity(NodeId, String, &'static str, usize),
    #[error("node {0} ({1}): input {2} is not an earlier node")]
    Order(NodeId, String, NodeId),
    #[error("node {0} ({1}): channel mismatch across inputs: {2:?}")]
    ChannelMismatch(NodeId, String, Vec<usize>),
    #[error("node {0} ({1}): spatial mismatch across inputs: {2:?}")]
    SpatialMismatch(NodeId, String, Vec<usize>),
    #[error("node {0} ({1}): {2}")]
    Invalid(NodeId, String, String),
    #[error("graph has no nodes")]
    Empty,
}

/// Per-convolution layer summary: exactly the paper's per-layer variables
/// (`n_l, m_l, k_l, s_l, p_l, g_l, ip_l, op_l`) used by the analytical
/// feature extractor and the device simulator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvInfo {
    pub node: NodeId,
    /// Number of filters `n_l` (output channels).
    pub n: usize,
    /// Input channels `m_l`.
    pub m: usize,
    /// Square kernel size `k_l`.
    pub k: usize,
    /// Stride `s_l`.
    pub s: usize,
    /// Padding `p_l`.
    pub p: usize,
    /// Groups `g_l` (resolved; depthwise ⇒ `g == m`).
    pub g: usize,
    /// Input spatial size `ip_l`.
    pub ip: usize,
    /// Output spatial size `op_l`.
    pub op: usize,
}

impl ConvInfo {
    /// Weight parameter count `n · m/g · k²`.
    pub fn weight_params(&self) -> usize {
        self.n * (self.m / self.g) * self.k * self.k
    }

    /// Forward MACs `bs=1`: `n · op² · k² · m/g`.
    pub fn fwd_macs(&self) -> f64 {
        self.n as f64 * (self.op * self.op) as f64 * (self.k * self.k) as f64
            * (self.m / self.g) as f64
    }

    /// Is this a depthwise convolution?
    pub fn is_depthwise(&self) -> bool {
        self.g == self.m && self.g > 1
    }
}

/// The network graph.
#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    /// Output node (defaults to the last node added).
    pub output: NodeId,
}

impl Graph {
    pub fn new(name: impl Into<String>) -> Self {
        Graph {
            name: name.into(),
            nodes: Vec::new(),
            output: 0,
        }
    }

    /// Append a node consuming `inputs` (must be earlier ids). Returns its id.
    pub fn add(&mut self, name: impl Into<String>, op: Op, inputs: &[NodeId]) -> NodeId {
        let id = self.nodes.len();
        for &i in inputs {
            assert!(i < id, "node inputs must reference earlier nodes");
        }
        self.nodes.push(Node {
            id,
            name: name.into(),
            op,
            inputs: inputs.to_vec(),
        });
        self.output = id;
        id
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Ids of all convolution nodes, in topological (≈ depth) order.
    pub fn conv_ids(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Conv2d { .. }))
            .map(|n| n.id)
            .collect()
    }

    /// Mutate a convolution's filter count (structured pruning).
    pub fn set_conv_filters(&mut self, id: NodeId, new_out_c: usize) {
        assert!(new_out_c >= 1, "cannot prune a conv to zero filters");
        match &mut self.nodes[id].op {
            Op::Conv2d { out_c, .. } => *out_c = new_out_c,
            other => panic!("node {id} is {}, not conv", other.kind()),
        }
    }

    /// Infer per-node output shapes; validates the graph as it goes.
    ///
    /// Per-node semantics live in [`node_output_shape`] — the single
    /// kernel shared with the overlay fast path
    /// ([`GraphArena`](super::arena::GraphArena)), so the two inference
    /// paths cannot drift. The multi-input (`Add`/`Concat`) arms validate
    /// by direct iteration over the input shapes — no temporary
    /// allocations on the success path (§Perf).
    pub fn infer_shapes(&self) -> Result<Vec<Shape>, GraphError> {
        if self.nodes.is_empty() {
            return Err(GraphError::Empty);
        }
        let mut shapes: Vec<Shape> = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            for &i in &node.inputs {
                if i >= node.id {
                    return Err(GraphError::Order(node.id, node.name.clone(), i));
                }
            }
            let shape =
                node_output_shape(node.id, &node.name, &node.op, &node.inputs, &shapes, None)?;
            shapes.push(shape);
        }
        Ok(shapes)
    }

    /// Extract the paper's per-conv-layer variables (requires a valid graph).
    pub fn conv_infos(&self) -> Result<Vec<ConvInfo>, GraphError> {
        let shapes = self.infer_shapes()?;
        Ok(conv_infos_from_shapes(self, &shapes))
    }

    /// Total parameter count (conv weights+bias, BN affine+running stats,
    /// linear weights+bias) — used for "Model Size (MB)" in Table 2.
    pub fn param_count(&self) -> Result<usize, GraphError> {
        let shapes = self.infer_shapes()?;
        Ok(param_count_from_shapes(self, &shapes))
    }

    /// Model size in MB at fp32.
    pub fn model_size_mb(&self) -> Result<f64, GraphError> {
        Ok(self.param_count()? as f64 * 4.0 / (1024.0 * 1024.0))
    }

    /// Compile this graph's analysis plan: one validating pass caching
    /// shapes, conv summaries and parameter counts for all downstream
    /// consumers. Rebuild after any mutation (e.g. pruning).
    pub fn plan(&self) -> Result<NetworkPlan<'_>, GraphError> {
        NetworkPlan::build(self)
    }
}

/// Output shape of one node from its op, inputs and the already-inferred
/// shapes of earlier nodes — the single per-node inference kernel shared by
/// [`Graph::infer_shapes`] and the overlay fast path
/// (`GraphArena::plan_into`), so the two cannot drift.
///
/// `out_c_override` substitutes the conv's filter count without mutating
/// the op — how a [`PruneOverlay`](super::arena::PruneOverlay) expresses
/// pruned widths. Pass `None` to read the op's own `out_c`.
///
/// The multi-input arms validate by direct iteration (all-equal-to-first
/// is equivalent to pairwise-adjacent equality); the error-payload vectors
/// are only built on the failure path, so the hot path never allocates.
pub(crate) fn node_output_shape(
    id: NodeId,
    name: &str,
    op: &Op,
    inputs: &[NodeId],
    shapes: &[Shape],
    out_c_override: Option<usize>,
) -> Result<Shape, GraphError> {
    let unary = |want: &'static str| -> Result<Shape, GraphError> {
        if inputs.len() != 1 {
            Err(GraphError::Arity(id, name.to_string(), want, inputs.len()))
        } else {
            Ok(shapes[inputs[0]])
        }
    };
    Ok(match op {
        Op::Input { c, h, w } => {
            if !inputs.is_empty() {
                return Err(GraphError::Arity(id, name.to_string(), "0", inputs.len()));
            }
            Shape::chw(*c, *h, *w)
        }
        Op::Conv2d {
            out_c,
            k,
            s,
            p,
            groups,
            ..
        } => {
            let input = unary("1")?;
            let (c, h) = match input {
                Shape::Chw { c, h, w } => {
                    if h != w {
                        return Err(GraphError::Invalid(
                            id,
                            name.to_string(),
                            format!("non-square input {h}x{w}"),
                        ));
                    }
                    (c, h)
                }
                Shape::Flat { .. } => {
                    return Err(GraphError::Invalid(
                        id,
                        name.to_string(),
                        "conv over flat tensor".into(),
                    ))
                }
            };
            let g = groups.resolve(c);
            if g == 0 || c % g != 0 {
                return Err(GraphError::Invalid(
                    id,
                    name.to_string(),
                    format!("channels {c} not divisible by groups {g}"),
                ));
            }
            // Depthwise convs tie out channels to in channels.
            let n = match groups {
                Groups::Depthwise => c,
                Groups::Fixed(_) => out_c_override.unwrap_or(*out_c),
            };
            if n % g != 0 {
                return Err(GraphError::Invalid(
                    id,
                    name.to_string(),
                    format!("filters {n} not divisible by groups {g}"),
                ));
            }
            let oh = conv_out_spatial(h, *k, *s, *p);
            Shape::chw(n, oh, oh)
        }
        Op::MaxPool { k, s, p, ceil } | Op::AvgPool { k, s, p, ceil } => {
            let input = unary("1")?;
            match input {
                Shape::Chw { c, h, .. } => {
                    let oh = if *ceil {
                        pool_out_spatial_ceil(h, *k, *s, *p)
                    } else {
                        conv_out_spatial(h, *k, *s, *p)
                    };
                    Shape::chw(c, oh, oh)
                }
                Shape::Flat { .. } => {
                    return Err(GraphError::Invalid(
                        id,
                        name.to_string(),
                        "pool over flat tensor".into(),
                    ))
                }
            }
        }
        Op::GlobalAvgPool => {
            let input = unary("1")?;
            Shape::chw(input.channels(), 1, 1)
        }
        Op::BatchNorm | Op::Activation(_) | Op::Dropout(_) => unary("1")?,
        Op::Flatten => {
            let input = unary("1")?;
            Shape::Flat { n: input.numel() }
        }
        Op::Linear { out, .. } => {
            let input = unary("1")?;
            match input {
                Shape::Flat { .. } => Shape::Flat { n: *out },
                Shape::Chw { .. } => {
                    return Err(GraphError::Invalid(
                        id,
                        name.to_string(),
                        "linear requires flattened input".into(),
                    ))
                }
            }
        }
        Op::Add => {
            if inputs.len() < 2 {
                return Err(GraphError::Arity(id, name.to_string(), ">=2", inputs.len()));
            }
            let c0 = shapes[inputs[0]].channels();
            if inputs.iter().any(|&i| shapes[i].channels() != c0) {
                return Err(GraphError::ChannelMismatch(
                    id,
                    name.to_string(),
                    inputs.iter().map(|&i| shapes[i].channels()).collect(),
                ));
            }
            let s0 = shapes[inputs[0]].spatial();
            if inputs.iter().any(|&i| shapes[i].spatial() != s0) {
                return Err(GraphError::SpatialMismatch(
                    id,
                    name.to_string(),
                    inputs.iter().map(|&i| shapes[i].spatial()).collect(),
                ));
            }
            shapes[inputs[0]]
        }
        Op::Concat => {
            if inputs.len() < 2 {
                return Err(GraphError::Arity(id, name.to_string(), ">=2", inputs.len()));
            }
            let s0 = shapes[inputs[0]].spatial();
            if inputs.iter().any(|&i| shapes[i].spatial() != s0) {
                return Err(GraphError::SpatialMismatch(
                    id,
                    name.to_string(),
                    inputs.iter().map(|&i| shapes[i].spatial()).collect(),
                ));
            }
            let c: usize = inputs.iter().map(|&i| shapes[i].channels()).sum();
            Shape::chw(c, s0, s0)
        }
    })
}

/// Conv summary of one node from pre-inferred shapes, or `None` for
/// non-conv ops — the per-node implementation behind
/// [`conv_infos_from_shapes`] and the overlay fast path.
pub(crate) fn conv_info_from_shapes(
    id: NodeId,
    op: &Op,
    inputs: &[NodeId],
    shapes: &[Shape],
) -> Option<ConvInfo> {
    if let Op::Conv2d {
        k, s, p, groups, ..
    } = op
    {
        let in_shape = shapes[inputs[0]];
        let out_shape = shapes[id];
        let m = in_shape.channels();
        Some(ConvInfo {
            node: id,
            n: out_shape.channels(),
            m,
            k: *k,
            s: *s,
            p: *p,
            g: groups.resolve(m),
            ip: in_shape.spatial(),
            op: out_shape.spatial(),
        })
    } else {
        None
    }
}

/// Conv summaries from pre-inferred shapes — the single implementation
/// shared by [`Graph::conv_infos`] and `NetworkPlan::build`, so the two
/// paths cannot drift.
pub(crate) fn conv_infos_from_shapes(graph: &Graph, shapes: &[Shape]) -> Vec<ConvInfo> {
    graph
        .nodes
        .iter()
        .filter_map(|node| conv_info_from_shapes(node.id, &node.op, &node.inputs, shapes))
        .collect()
}

/// Parameter contribution of one node from pre-inferred shapes (conv
/// weights+bias, BN affine+running stats, linear weights+bias; zero for
/// everything else) — the per-node implementation behind
/// [`param_count_from_shapes`] and the overlay fast path's incremental
/// parameter updates.
pub(crate) fn node_param_count(id: NodeId, op: &Op, inputs: &[NodeId], shapes: &[Shape]) -> usize {
    match op {
        Op::Conv2d {
            bias, groups, k, ..
        } => {
            let m = shapes[inputs[0]].channels();
            let n = shapes[id].channels();
            let g = groups.resolve(m);
            n * (m / g) * k * k + if *bias { n } else { 0 }
        }
        // weight, bias, running mean, running var
        Op::BatchNorm => 4 * shapes[id].channels(),
        Op::Linear { out, bias } => {
            let inf = shapes[inputs[0]].numel();
            inf * out + if *bias { *out } else { 0 }
        }
        _ => 0,
    }
}

/// Parameter count from pre-inferred shapes — the single implementation
/// shared by [`Graph::param_count`] and `NetworkPlan::build`.
pub(crate) fn param_count_from_shapes(graph: &Graph, shapes: &[Shape]) -> usize {
    graph
        .nodes
        .iter()
        .map(|node| node_param_count(node.id, &node.op, &node.inputs, shapes))
        .sum()
}

impl fmt::Display for Graph {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "graph {} ({} nodes)", self.name, self.nodes.len())?;
        let shapes = self.infer_shapes().ok();
        for node in &self.nodes {
            let shape = shapes
                .as_ref()
                .map(|s| format!("{:?}", s[node.id]))
                .unwrap_or_default();
            writeln!(
                f,
                "  #{:<4} {:<28} {:<8} <- {:?}  {}",
                node.id,
                node.name,
                node.op.kind(),
                node.inputs,
                shape
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::Act;

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny");
        let x = g.add("input", Op::Input { c: 3, h: 32, w: 32 }, &[]);
        let c1 = g.add(
            "conv1",
            Op::Conv2d {
                out_c: 16,
                k: 3,
                s: 1,
                p: 1,
                groups: Groups::Fixed(1),
                bias: false,
            },
            &[x],
        );
        let b1 = g.add("bn1", Op::BatchNorm, &[c1]);
        let r1 = g.add("relu1", Op::Activation(Act::Relu), &[b1]);
        let gp = g.add("gap", Op::GlobalAvgPool, &[r1]);
        let fl = g.add("flatten", Op::Flatten, &[gp]);
        g.add(
            "fc",
            Op::Linear {
                out: 10,
                bias: true,
            },
            &[fl],
        );
        g
    }

    #[test]
    fn shape_inference_tiny() {
        let g = tiny();
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[1], Shape::chw(16, 32, 32));
        assert_eq!(shapes[4], Shape::chw(16, 1, 1));
        assert_eq!(*shapes.last().unwrap(), Shape::Flat { n: 10 });
    }

    #[test]
    fn conv_info_extraction() {
        let g = tiny();
        let infos = g.conv_infos().unwrap();
        assert_eq!(infos.len(), 1);
        let c = infos[0];
        assert_eq!((c.n, c.m, c.k, c.s, c.p, c.g, c.ip, c.op), (16, 3, 3, 1, 1, 1, 32, 32));
        assert_eq!(c.weight_params(), 16 * 3 * 9);
    }

    #[test]
    fn param_count_tiny() {
        let g = tiny();
        // conv 16*3*9 + bn 4*16 + fc 16*10+10
        assert_eq!(g.param_count().unwrap(), 432 + 64 + 170);
    }

    #[test]
    fn pruning_mutation_propagates() {
        let mut g = tiny();
        g.set_conv_filters(1, 8);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[1].channels(), 8);
        // fc input shrinks accordingly
        assert_eq!(g.param_count().unwrap(), 8 * 27 + 32 + 90);
    }

    #[test]
    fn add_channel_mismatch_detected() {
        let mut g = Graph::new("bad");
        let x = g.add("in", Op::Input { c: 3, h: 8, w: 8 }, &[]);
        let a = g.add(
            "a",
            Op::Conv2d {
                out_c: 4,
                k: 1,
                s: 1,
                p: 0,
                groups: Groups::Fixed(1),
                bias: false,
            },
            &[x],
        );
        let b = g.add(
            "b",
            Op::Conv2d {
                out_c: 6,
                k: 1,
                s: 1,
                p: 0,
                groups: Groups::Fixed(1),
                bias: false,
            },
            &[x],
        );
        g.add("add", Op::Add, &[a, b]);
        assert!(matches!(
            g.infer_shapes(),
            Err(GraphError::ChannelMismatch(..))
        ));
    }

    #[test]
    fn concat_sums_channels() {
        let mut g = Graph::new("cat");
        let x = g.add("in", Op::Input { c: 3, h: 8, w: 8 }, &[]);
        let a = g.add(
            "a",
            Op::Conv2d {
                out_c: 4,
                k: 1,
                s: 1,
                p: 0,
                groups: Groups::Fixed(1),
                bias: false,
            },
            &[x],
        );
        let b = g.add(
            "b",
            Op::Conv2d {
                out_c: 6,
                k: 3,
                s: 1,
                p: 1,
                groups: Groups::Fixed(1),
                bias: false,
            },
            &[x],
        );
        let c = g.add("cat", Op::Concat, &[a, b]);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[c].channels(), 10);
    }

    #[test]
    fn depthwise_ties_output_channels() {
        let mut g = Graph::new("dw");
        let x = g.add("in", Op::Input { c: 3, h: 8, w: 8 }, &[]);
        let pw = g.add(
            "pw",
            Op::Conv2d {
                out_c: 12,
                k: 1,
                s: 1,
                p: 0,
                groups: Groups::Fixed(1),
                bias: false,
            },
            &[x],
        );
        let dw = g.add(
            "dw",
            Op::Conv2d {
                out_c: 12, // nominal; tied to input at inference time
                k: 3,
                s: 1,
                p: 1,
                groups: Groups::Depthwise,
                bias: false,
            },
            &[pw],
        );
        // prune the pointwise conv; depthwise must follow
        g.set_conv_filters(pw, 7);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[dw].channels(), 7);
        let infos = g.conv_infos().unwrap();
        assert!(infos[1].is_depthwise());
        assert_eq!(infos[1].g, 7);
    }

    #[test]
    fn display_does_not_panic() {
        let g = tiny();
        let s = format!("{g}");
        assert!(s.contains("conv1"));
    }
}
