//! The compiled analysis layer: [`NetworkPlan`].
//!
//! Every downstream consumer of a [`Graph`] — the device simulator, the
//! analytical feature extractor, the profiler, the baselines, the OFA
//! accuracy proxy — needs the same derived facts: inferred per-node shapes,
//! per-convolution summaries ([`ConvInfo`]), and the parameter count.
//! Before this layer existed each consumer re-ran `Graph::infer_shapes()`
//! on demand, so a single simulated training step paid for shape inference
//! six times and an OFA search candidate paid for it eight-plus times.
//!
//! `NetworkPlan::build` performs **one** validating pass over the graph and
//! caches everything; consumers take `&NetworkPlan` and read the cached
//! results. The cached quantities go through the very same
//! `*_from_shapes` implementations the corresponding `Graph` methods use,
//! so plan-based paths are bit-identical to the direct-graph paths by
//! construction (and asserted end-to-end across the whole model zoo by
//! `rust/tests/plan_equivalence.rs`).
//!
//! # Invalidation rule
//!
//! A plan is a snapshot of one graph topology. Structured pruning mutates
//! filter counts, so: **prune ⇒ rebuild the plan**. The borrow of the
//! underlying graph makes stale plans unrepresentable — a `NetworkPlan`
//! holds `&Graph`, so the graph cannot be mutated while a plan over it is
//! alive.

use super::graph::{
    conv_infos_from_shapes, param_count_from_shapes, ConvInfo, Graph, GraphError, NodeId,
};
use super::op::Op;
use super::shapes::Shape;

/// Read-only access to a compiled network analysis: topology (ops +
/// wiring) plus the derived shapes, conv summaries and parameter count.
///
/// Two implementations exist: [`NetworkPlan`] (a snapshot of a concrete
/// [`Graph`]) and [`OverlayPlan`](super::arena::OverlayPlan) (an arena +
/// pruning-overlay view that never materializes a graph). Consumers — the
/// device simulator, the feature extractor, the profiler — are generic
/// over this trait, so both paths run the very same code and stay
/// bit-identical by construction.
///
/// Note: under an overlay, `op(id)`'s `Conv2d::out_c` is the *base*
/// network's nominal filter count; effective channel counts must be read
/// from `shapes()` / `conv_infos()` (which every consumer already does —
/// `out_c` alone determines nothing once depthwise ties and overlays
/// exist).
pub trait PlanView {
    /// Node count of the underlying topology.
    fn n_nodes(&self) -> usize;
    /// Operator of one node (see the note on `Conv2d::out_c` above).
    fn op(&self, id: NodeId) -> &Op;
    /// Input node ids of one node.
    fn inputs(&self, id: NodeId) -> &[NodeId];
    /// Inferred per-node output shapes (parallel to node ids).
    fn shapes(&self) -> &[Shape];
    /// Per-convolution summaries, in topological order.
    fn conv_infos(&self) -> &[ConvInfo];
    /// Total parameter count.
    fn param_count(&self) -> usize;
}

impl<'g> PlanView for NetworkPlan<'g> {
    fn n_nodes(&self) -> usize {
        self.shapes.len()
    }

    fn op(&self, id: NodeId) -> &Op {
        &self.graph.nodes[id].op
    }

    fn inputs(&self, id: NodeId) -> &[NodeId] {
        &self.graph.nodes[id].inputs
    }

    fn shapes(&self) -> &[Shape] {
        &self.shapes
    }

    fn conv_infos(&self) -> &[ConvInfo] {
        &self.convs
    }

    fn param_count(&self) -> usize {
        self.param_count
    }
}

/// One-pass compiled analysis of a [`Graph`]: shapes, conv summaries and
/// parameter counts, computed together and cached for reuse.
#[derive(Clone, Debug)]
pub struct NetworkPlan<'g> {
    graph: &'g Graph,
    shapes: Vec<Shape>,
    convs: Vec<ConvInfo>,
    param_count: usize,
}

impl<'g> NetworkPlan<'g> {
    /// Compile the plan: a single validating shape-inference pass, with the
    /// conv summaries and parameter count derived from the shared shape
    /// vector through the same `*_from_shapes` implementations
    /// [`Graph::conv_infos`] and [`Graph::param_count`] use, so results
    /// are bit-identical by construction.
    pub fn build(graph: &'g Graph) -> Result<Self, GraphError> {
        let shapes = graph.infer_shapes()?;
        let convs = conv_infos_from_shapes(graph, &shapes);
        let param_count = param_count_from_shapes(graph, &shapes);
        Ok(NetworkPlan {
            graph,
            shapes,
            convs,
            param_count,
        })
    }

    /// The graph this plan was compiled from.
    pub fn graph(&self) -> &'g Graph {
        self.graph
    }

    /// Inferred per-node output shapes (parallel to `graph.nodes`).
    pub fn shapes(&self) -> &[Shape] {
        &self.shapes
    }

    /// Inferred output shape of one node.
    pub fn shape(&self, id: NodeId) -> Shape {
        self.shapes[id]
    }

    /// Per-convolution summaries (the paper's per-layer variables), in
    /// topological order.
    pub fn conv_infos(&self) -> &[ConvInfo] {
        &self.convs
    }

    /// Total parameter count (conv weights+bias, BN affine+running stats,
    /// linear weights+bias).
    pub fn param_count(&self) -> usize {
        self.param_count
    }

    /// Model size in MB at fp32.
    pub fn model_size_mb(&self) -> f64 {
        self.param_count as f64 * 4.0 / (1024.0 * 1024.0)
    }

    /// Total forward MACs at `bs = 1`, summed over conv layers.
    pub fn fwd_macs(&self) -> f64 {
        self.convs.iter().map(|c| c.fwd_macs()).sum()
    }

    /// Node count of the underlying graph.
    pub fn len(&self) -> usize {
        self.shapes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shapes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::Act;
    use crate::ir::{Groups, Op};

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny");
        let x = g.add("input", Op::Input { c: 3, h: 32, w: 32 }, &[]);
        let c1 = g.add(
            "conv1",
            Op::Conv2d {
                out_c: 16,
                k: 3,
                s: 1,
                p: 1,
                groups: Groups::Fixed(1),
                bias: false,
            },
            &[x],
        );
        let b1 = g.add("bn1", Op::BatchNorm, &[c1]);
        let r1 = g.add("relu1", Op::Activation(Act::Relu), &[b1]);
        let gp = g.add("gap", Op::GlobalAvgPool, &[r1]);
        let fl = g.add("flatten", Op::Flatten, &[gp]);
        g.add(
            "fc",
            Op::Linear {
                out: 10,
                bias: true,
            },
            &[fl],
        );
        g
    }

    #[test]
    fn plan_matches_graph_methods() {
        let g = tiny();
        let plan = NetworkPlan::build(&g).unwrap();
        assert_eq!(plan.shapes(), g.infer_shapes().unwrap().as_slice());
        assert_eq!(plan.conv_infos(), g.conv_infos().unwrap().as_slice());
        assert_eq!(plan.param_count(), g.param_count().unwrap());
        assert_eq!(plan.model_size_mb(), g.model_size_mb().unwrap());
        assert_eq!(plan.len(), g.len());
        assert!(!plan.is_empty());
    }

    #[test]
    fn plan_rejects_invalid_graphs() {
        let mut g = Graph::new("bad");
        let x = g.add("in", Op::Input { c: 3, h: 8, w: 8 }, &[]);
        let a = g.add(
            "a",
            Op::Conv2d {
                out_c: 4,
                k: 1,
                s: 1,
                p: 0,
                groups: Groups::Fixed(1),
                bias: false,
            },
            &[x],
        );
        let b = g.add(
            "b",
            Op::Conv2d {
                out_c: 6,
                k: 1,
                s: 1,
                p: 0,
                groups: Groups::Fixed(1),
                bias: false,
            },
            &[x],
        );
        g.add("add", Op::Add, &[a, b]);
        assert!(NetworkPlan::build(&g).is_err());
    }

    #[test]
    fn prune_then_rebuild_tracks_mutation() {
        let mut g = tiny();
        let before = NetworkPlan::build(&g).unwrap().param_count();
        g.set_conv_filters(1, 8);
        // The invalidation rule: the old plan cannot outlive the mutation
        // (borrowck), so a fresh build is the only way to read the graph —
        // and it must see the new filter count.
        let after = NetworkPlan::build(&g).unwrap();
        assert!(after.param_count() < before);
        assert_eq!(after.conv_infos()[0].n, 8);
    }
}
