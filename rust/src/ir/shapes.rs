//! Tensor shapes flowing through the network IR.
//!
//! Shapes are per-sample (`C × H × W`); the batch dimension is implicit and
//! carried separately by the feature extractor / device simulator, matching
//! the paper's formulation where every term is linear in `bs`.

/// Per-sample activation shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shape {
    /// Feature map `C × H × W` (NCHW minus the batch dim).
    Chw { c: usize, h: usize, w: usize },
    /// Flattened feature vector of length `n`.
    Flat { n: usize },
}

impl Shape {
    pub fn chw(c: usize, h: usize, w: usize) -> Self {
        Shape::Chw { c, h, w }
    }

    /// Number of elements per sample.
    pub fn numel(&self) -> usize {
        match *self {
            Shape::Chw { c, h, w } => c * h * w,
            Shape::Flat { n } => n,
        }
    }

    /// Channel count (Flat tensors report their length as channels).
    pub fn channels(&self) -> usize {
        match *self {
            Shape::Chw { c, .. } => c,
            Shape::Flat { n } => n,
        }
    }

    /// Spatial size, assuming square maps (the paper's setting).
    pub fn spatial(&self) -> usize {
        match *self {
            Shape::Chw { h, .. } => h,
            Shape::Flat { .. } => 1,
        }
    }
}

/// Output spatial size of a conv/pool:
/// `op = 1 + floor((ip + 2p - k) / s)` (paper Sec.5.2.1).
pub fn conv_out_spatial(ip: usize, k: usize, s: usize, p: usize) -> usize {
    let padded = ip + 2 * p;
    assert!(
        padded >= k,
        "kernel {k} larger than padded input {padded} (ip={ip}, p={p})"
    );
    1 + (padded - k) / s
}

/// Output spatial size with ceil rounding (PyTorch `ceil_mode=True` pooling,
/// used by GoogLeNet's grid-reduction pools).
pub fn pool_out_spatial_ceil(ip: usize, k: usize, s: usize, p: usize) -> usize {
    let padded = ip + 2 * p;
    assert!(padded >= k);
    1 + (padded - k + s - 1) / s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_formula_matches_paper() {
        // 224x224, k=7, s=2, p=3 -> 112 (ResNet stem)
        assert_eq!(conv_out_spatial(224, 7, 2, 3), 112);
        // 3x3 s=1 p=1 preserves spatial size
        assert_eq!(conv_out_spatial(56, 3, 1, 1), 56);
        // 1x1 s=1 p=0 preserves
        assert_eq!(conv_out_spatial(14, 1, 1, 0), 14);
        // pool k=3 s=2 p=1: 112 -> 56
        assert_eq!(conv_out_spatial(112, 3, 2, 1), 56);
    }

    #[test]
    fn ceil_mode_rounds_up() {
        // 56 -> k=3 s=2 p=0: floor gives 27, ceil gives 28
        assert_eq!(conv_out_spatial(56, 3, 2, 0), 27);
        assert_eq!(pool_out_spatial_ceil(56, 3, 2, 0), 28);
        // exact division: both modes agree
        assert_eq!(pool_out_spatial_ceil(55, 3, 2, 0), 27);
    }

    #[test]
    fn numel_and_channels() {
        let s = Shape::chw(64, 56, 56);
        assert_eq!(s.numel(), 64 * 56 * 56);
        assert_eq!(s.channels(), 64);
        assert_eq!(s.spatial(), 56);
        let f = Shape::Flat { n: 1000 };
        assert_eq!(f.numel(), 1000);
        assert_eq!(f.channels(), 1000);
    }

    #[test]
    #[should_panic]
    fn conv_kernel_too_large_panics() {
        conv_out_spatial(2, 7, 1, 0);
    }
}
