//! Simulated cuDNN: per-layer, per-operation convolution algorithm
//! selection. This is the black-box the paper's decision trees must learn —
//! "cuDNN uses proprietary heuristics on a per layer basis to select
//! between the Matrix Multiplication, FFT, and Winograd convolution
//! algorithms" (Sec. 5.2.1).
//!
//! The simulated heuristic mirrors `cudnnGetConvolution*Algorithm`: among
//! the algorithms *eligible* for the layer geometry, pick the one with the
//! lowest estimated execution time whose workspace fits under the cap.
//! Eligibility and the cost model follow the published behaviour of the
//! algorithms (Jorda et al. 2019 [8]; Lavin & Gray 2016 [11]; Mathieu et
//! al. 2014 [16]).

use crate::ir::ConvInfo;

use super::spec::DeviceSpec;

/// The three training convolutions (paper Eqs. 1–3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvOp {
    /// Eq.1: `y = x * w`.
    Fwd,
    /// Eq.2: `∂L/∂x = ∂L/∂y * rot180(w)`.
    BwdData,
    /// Eq.3: `∂L/∂w = x * ∂L/∂y`.
    BwdFilter,
}

pub const ALL_OPS: [ConvOp; 3] = [ConvOp::Fwd, ConvOp::BwdData, ConvOp::BwdFilter];

/// Convolution algorithms the simulated cuDNN chooses between.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Explicit im2col + GEMM (stores the full im2col matrix).
    Gemm,
    /// Implicit GEMM (stores only window indices).
    ImplicitGemm,
    /// FFT-domain convolution.
    Fft,
    /// Winograd minimal filtering, (q,r) = (4,3).
    Winograd,
}

pub const ALL_ALGOS: [Algo; 4] = [Algo::Gemm, Algo::ImplicitGemm, Algo::Fft, Algo::Winograd];

/// Outcome of algorithm selection for one (layer, op).
#[derive(Clone, Copy, Debug)]
pub struct Choice {
    pub algo: Algo,
    /// Workspace bytes allocated for the op.
    pub workspace_bytes: f64,
    /// Estimated execution time, milliseconds.
    pub time_ms: f64,
}

const BYTES: f64 = 4.0; // fp32

/// Workspace bytes required by `algo` for `(layer, op)` at batch `bs`.
/// Formulas are the paper's App. B memory features (in elements) × 4 bytes.
pub fn workspace_bytes(c: &ConvInfo, op: ConvOp, algo: Algo, bs: usize) -> f64 {
    let bs = bs as f64;
    let n = c.n as f64;
    let m = c.m as f64;
    let k = c.k as f64;
    let mg = (c.m / c.g) as f64;
    let ip = c.ip as f64;
    let opd = c.op as f64;
    match algo {
        Algo::Gemm => {
            let elems = match op {
                ConvOp::Fwd => bs * opd * opd * k * k * m,
                ConvOp::BwdData => bs * ip * ip * k * k * m,
                ConvOp::BwdFilter => bs * opd * opd * k * k * mg,
            };
            elems * BYTES
        }
        Algo::ImplicitGemm => {
            let elems = match op {
                ConvOp::Fwd | ConvOp::BwdFilter => bs * opd * opd,
                ConvOp::BwdData => bs * ip * ip,
            };
            elems * BYTES
        }
        Algo::Fft => {
            // Complex-valued transforms of both operands (×2 for re/im).
            let elems = match op {
                ConvOp::Fwd => n * mg * ip * (1.0 + ip) + bs * m * ip * (1.0 + ip),
                ConvOp::BwdData => {
                    n * mg * opd * (1.0 + opd) + bs * n * opd * (1.0 + opd)
                }
                ConvOp::BwdFilter => {
                    bs * n * ip * (1.0 + ip) + bs * m * ip * (1.0 + ip)
                }
            };
            elems * 2.0 * BYTES
        }
        Algo::Winograd => {
            let (q, r) = (4.0f64, 3.0f64);
            let tile = (q + r - 1.0) * (q + r - 1.0);
            let tiles_ip = (ip / q).ceil() * (ip / q).ceil();
            let tiles_op = (opd / q).ceil() * (opd / q).ceil();
            let elems = match op {
                ConvOp::Fwd => bs * n * tiles_ip * 3.0 * tile,
                ConvOp::BwdData => bs * m * tiles_op * 3.0 * tile,
                ConvOp::BwdFilter => bs * n * mg * tiles_ip * 3.0 * tile,
            };
            elems * BYTES
        }
    }
}

/// Multiply–accumulate count of `algo` for `(layer, op)` at batch `bs`
/// (the paper's `ops` features), as *effective* MACs including algorithmic
/// savings.
pub fn op_macs(c: &ConvInfo, op: ConvOp, algo: Algo, bs: usize) -> f64 {
    let bs = bs as f64;
    let n = c.n as f64;
    let m = c.m as f64;
    let k = c.k as f64;
    let mg = (c.m / c.g) as f64;
    let ip = c.ip as f64;
    let opd = c.op as f64;
    match algo {
        Algo::Gemm | Algo::ImplicitGemm => match op {
            ConvOp::Fwd | ConvOp::BwdFilter => bs * n * opd * opd * k * k * mg,
            ConvOp::BwdData => bs * m * ip * ip * k * k * n / c.g as f64,
        },
        Algo::Fft => {
            let common = bs * (m + n) + n * mg;
            match op {
                ConvOp::Fwd => ip * ip * ip.max(1.0).ln() * common + bs * n * m * ip * ip / c.g as f64,
                ConvOp::BwdData => {
                    opd * opd * opd.max(1.0).ln() * common + bs * n * m * opd * opd / c.g as f64
                }
                ConvOp::BwdFilter => {
                    ip * (ip * ip).max(1.0).ln() * common + bs * n * m * ip * ip / c.g as f64
                }
            }
        }
        Algo::Winograd => {
            let (q, r) = (4.0f64, 3.0f64);
            let tile = (q + r - 1.0) * (q + r - 1.0);
            let tiles_ip = (ip / q).ceil() * (ip / q).ceil();
            let tiles_op = (opd / q).ceil() * (opd / q).ceil();
            let tiles_k = (k / r).ceil() * (k / r).ceil();
            match op {
                ConvOp::Fwd => bs * n * mg * tiles_ip * tiles_k * tile,
                ConvOp::BwdData => bs * m * n * tiles_op * tiles_k * tile / c.g as f64,
                ConvOp::BwdFilter => {
                    let tiles_op_r = (opd / r).ceil() * (opd / r).ceil();
                    bs * n * mg * mg * tiles_ip * tiles_op_r.min(tiles_ip) * tile
                }
            }
        }
    }
}

/// Arithmetic efficiency of each algorithm relative to device peak —
/// Winograd pays transform overhead; implicit GEMM recomputes addressing;
/// FFT is bandwidth-heavy.
fn algo_efficiency(algo: Algo) -> f64 {
    match algo {
        Algo::Gemm => 0.52,
        Algo::ImplicitGemm => 0.44,
        Algo::Fft => 0.38,
        Algo::Winograd => 0.40,
    }
}

/// Is `algo` applicable to this layer geometry (cuDNN support matrix)?
pub fn eligible(c: &ConvInfo, algo: Algo) -> bool {
    match algo {
        Algo::Gemm | Algo::ImplicitGemm => true,
        // cuDNN winograd: 3x3, stride 1, ungrouped.
        Algo::Winograd => c.k == 3 && c.s == 1 && c.g == 1 && c.ip >= 4,
        // FFT: stride 1, ungrouped, kernel >= 5 (smaller kernels never win),
        // moderate spatial size (transform memory explodes beyond).
        Algo::Fft => c.k >= 5 && c.s == 1 && c.g == 1 && c.ip <= 64,
    }
}

/// Estimated execution time (ms) of `(layer, op, algo)` on `spec` — the
/// roofline of compute vs memory traffic, with an occupancy penalty for
/// small launches.
pub fn estimate_time_ms(
    spec: &DeviceSpec,
    c: &ConvInfo,
    op: ConvOp,
    algo: Algo,
    bs: usize,
) -> f64 {
    let macs = op_macs(c, op, algo, bs);
    let flops = 2.0 * macs;
    // Occupancy: how well the launch fills the device. Work items are
    // output tiles; small late layers or tiny batches underutilise.
    let work = (bs * c.n * c.op * c.op) as f64;
    let occupancy = (work / (spec.cores as f64 * 64.0)).min(1.0).max(0.02);
    let eff = algo_efficiency(algo) * occupancy;
    let t_compute_ms = flops / (spec.peak_gflops() * 1e9 * eff) * 1e3;

    // Memory traffic: read inputs + weights, write outputs, touch workspace.
    let bsf = bs as f64;
    let io_bytes = (bsf * (c.m * c.ip * c.ip) as f64
        + bsf * (c.n * c.op * c.op) as f64
        + (c.n * (c.m / c.g) * c.k * c.k) as f64)
        * BYTES
        + workspace_bytes(c, op, algo, bs);
    let t_mem_ms = io_bytes / (spec.mem_bw_gbps * 1e9 * spec.bw_efficiency) * 1e3;

    t_compute_ms.max(t_mem_ms) + spec.launch_overhead_us / 1e3
}

/// cuDNN-style selection: cheapest eligible algorithm whose workspace fits.
pub fn choose(spec: &DeviceSpec, c: &ConvInfo, op: ConvOp, bs: usize) -> Choice {
    let cap_bytes = spec.workspace_cap_mb * 1024.0 * 1024.0;
    let mut best: Option<Choice> = None;
    for algo in ALL_ALGOS {
        if !eligible(c, algo) {
            continue;
        }
        let ws = workspace_bytes(c, op, algo, bs);
        if ws > cap_bytes && algo != Algo::ImplicitGemm {
            continue; // ImplicitGemm is the fallback that always fits
        }
        let t = estimate_time_ms(spec, c, op, algo, bs);
        if best.map_or(true, |b| t < b.time_ms) {
            best = Some(Choice {
                algo,
                workspace_bytes: ws,
                time_ms: t,
            });
        }
    }
    best.expect("ImplicitGemm is always eligible")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(n: usize, m: usize, k: usize, s: usize, g: usize, ip: usize) -> ConvInfo {
        let p = k / 2;
        let op = crate::ir::conv_out_spatial(ip, k, s, p);
        ConvInfo {
            node: 0,
            n,
            m,
            k,
            s,
            p,
            g,
            ip,
            op,
        }
    }

    #[test]
    fn winograd_wins_on_3x3_stride1() {
        let spec = DeviceSpec::tx2();
        let c = conv(256, 256, 3, 1, 1, 28);
        let choice = choose(&spec, &c, ConvOp::Fwd, 32);
        assert_eq!(choice.algo, Algo::Winograd);
    }

    #[test]
    fn winograd_ineligible_for_stride2() {
        let c = conv(64, 64, 3, 2, 1, 56);
        assert!(!eligible(&c, Algo::Winograd));
        assert!(eligible(&c, Algo::Gemm));
    }

    #[test]
    fn fft_eligible_only_for_large_kernels() {
        assert!(eligible(&conv(64, 64, 5, 1, 1, 28), Algo::Fft));
        assert!(!eligible(&conv(64, 64, 3, 1, 1, 28), Algo::Fft));
        assert!(!eligible(&conv(64, 64, 5, 2, 1, 28), Algo::Fft));
        // too large spatially
        assert!(!eligible(&conv(64, 3, 7, 1, 1, 224), Algo::Fft));
    }

    #[test]
    fn depthwise_uses_implicit_gemm() {
        let spec = DeviceSpec::tx2();
        let c = conv(128, 128, 3, 1, 128, 28);
        assert!(!eligible(&c, Algo::Winograd));
        let choice = choose(&spec, &c, ConvOp::Fwd, 32);
        assert!(matches!(choice.algo, Algo::ImplicitGemm | Algo::Gemm));
    }

    #[test]
    fn workspace_cap_forces_fallback() {
        let spec = DeviceSpec::tx2(); // 512MB cap
        // Huge early layer: explicit im2col would need bs*op^2*k^2*m*4B
        let c = conv(64, 64, 3, 1, 1, 224);
        let ws_gemm = workspace_bytes(&c, ConvOp::Fwd, Algo::Gemm, 256);
        assert!(ws_gemm > 512.0 * 1024.0 * 1024.0);
        let choice = choose(&spec, &c, ConvOp::Fwd, 256);
        assert_ne!(choice.algo, Algo::Gemm);
    }

    #[test]
    fn time_scales_with_batch() {
        let spec = DeviceSpec::tx2();
        let c = conv(128, 128, 3, 1, 1, 28);
        let t8 = choose(&spec, &c, ConvOp::Fwd, 8).time_ms;
        let t64 = choose(&spec, &c, ConvOp::Fwd, 64).time_ms;
        assert!(t64 > 4.0 * t8, "t8={t8} t64={t64}");
    }

    #[test]
    fn server_gpu_faster() {
        let tx2 = DeviceSpec::tx2();
        let ti = DeviceSpec::rtx2080ti();
        let c = conv(256, 256, 3, 1, 1, 14);
        let t_tx2 = choose(&tx2, &c, ConvOp::Fwd, 32).time_ms;
        let t_ti = choose(&ti, &c, ConvOp::Fwd, 32).time_ms;
        assert!(t_ti < t_tx2 / 5.0, "tx2={t_tx2} ti={t_ti}");
    }

    #[test]
    fn all_ops_choosable_across_geometries() {
        let spec = DeviceSpec::tx2();
        for (n, m, k, s, g, ip) in [
            (64, 3, 7, 2, 1, 224),
            (64, 64, 1, 1, 1, 56),
            (128, 128, 3, 2, 1, 56),
            (32, 32, 3, 1, 32, 112),
            (96, 16, 5, 1, 1, 27),
        ] {
            let c = conv(n, m, k, s, g, ip);
            for op in ALL_OPS {
                let ch = choose(&spec, &c, op, 16);
                assert!(ch.time_ms > 0.0 && ch.time_ms.is_finite());
                assert!(ch.workspace_bytes >= 0.0);
            }
        }
    }

    #[test]
    fn winograd_reduces_macs_vs_gemm() {
        let c = conv(256, 256, 3, 1, 1, 28);
        let g = op_macs(&c, ConvOp::Fwd, Algo::Gemm, 1);
        let w = op_macs(&c, ConvOp::Fwd, Algo::Winograd, 1);
        // classic ~4x reduction for 4x4 output tiles with 3x3 kernels
        let ratio = g / w;
        assert!((3.0..5.0).contains(&ratio), "ratio={ratio}");
    }
}
