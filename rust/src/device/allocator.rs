//! PyTorch-style caching-allocator model.
//!
//! The real allocator rounds small blocks to 512-byte multiples and carves
//! large blocks out of 2 MiB (and bigger) segments, then *caches* freed
//! blocks instead of returning them to the driver — so observed process
//! memory is the rounded high-water mark, not the live-byte sum. The
//! rounding staircase is one of the framework-specific nonlinearities the
//! random forest absorbs (it is invisible to the analytical features).

/// Small-block quantum (bytes).
pub const SMALL_QUANTUM: f64 = 512.0;
/// Large-block segment quantum (bytes): 2 MiB.
pub const LARGE_QUANTUM: f64 = 2.0 * 1024.0 * 1024.0;
/// Threshold between the small and large pools: 1 MiB.
pub const LARGE_THRESHOLD: f64 = 1024.0 * 1024.0;
/// Fragmentation overhead of the large pool (segments split imperfectly).
pub const FRAG_OVERHEAD: f64 = 0.035;

/// Bytes actually reserved for a single allocation request.
pub fn round_block(bytes: f64) -> f64 {
    if bytes <= 0.0 {
        return 0.0;
    }
    if bytes < LARGE_THRESHOLD {
        (bytes / SMALL_QUANTUM).ceil() * SMALL_QUANTUM
    } else {
        (bytes / LARGE_QUANTUM).ceil() * LARGE_QUANTUM
    }
}

/// Reserved total for a set of simultaneously-live allocations, including
/// large-pool fragmentation.
pub fn pool_reserved(blocks: impl IntoIterator<Item = f64>) -> f64 {
    let mut small = 0.0;
    let mut large = 0.0;
    for b in blocks {
        let r = round_block(b);
        if b < LARGE_THRESHOLD {
            small += r;
        } else {
            large += r;
        }
    }
    small + large * (1.0 + FRAG_OVERHEAD)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_blocks_round_to_512() {
        assert_eq!(round_block(1.0), 512.0);
        assert_eq!(round_block(512.0), 512.0);
        assert_eq!(round_block(513.0), 1024.0);
    }

    #[test]
    fn large_blocks_round_to_2mb() {
        let two_mb = 2.0 * 1024.0 * 1024.0;
        assert_eq!(round_block(1.5 * 1024.0 * 1024.0), two_mb);
        assert_eq!(round_block(two_mb + 1.0), 2.0 * two_mb);
    }

    #[test]
    fn zero_is_zero() {
        assert_eq!(round_block(0.0), 0.0);
        assert_eq!(round_block(-5.0), 0.0);
    }

    #[test]
    fn pool_includes_fragmentation_only_for_large() {
        let small_only = pool_reserved([1000.0, 2000.0]);
        assert_eq!(small_only, 1024.0 + 2048.0);
        let large_only = pool_reserved([3.0 * 1024.0 * 1024.0]);
        assert!(large_only > 4.0 * 1024.0 * 1024.0); // rounded + frag
    }

    #[test]
    fn rounding_is_monotone() {
        let mut prev = 0.0;
        for i in 1..2000 {
            let r = round_block(i as f64 * 700.0);
            assert!(r >= prev);
            assert!(r >= i as f64 * 700.0);
            prev = r;
        }
    }
}
