//! Training-regime knobs: the memory-reduced training configurations that
//! make edge retraining viable (NeuroFlux-style gradient checkpointing and
//! frozen-backbone / partial-backprop fine-tuning). A [`TrainRegime`] is a
//! campaign axis exactly like a pruning [`Strategy`](crate::pruning::Strategy):
//! it has a stable string name (`vanilla`, `ckpt:N`, `frozen:N`) used in CLI
//! flags, dataset rows and campaign specs, and [`TrainRegime::Vanilla`] is
//! guaranteed to reproduce the pre-regime simulator numbers bit-identically.

use std::fmt;

/// How the simulated training step is executed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum TrainRegime {
    /// Plain fp32 training: every activation retained for backward, every
    /// layer trainable. This is the regime the paper profiles.
    #[default]
    Vanilla,
    /// Gradient checkpointing over `segments` contiguous graph segments:
    /// only segment-boundary activations stay resident between forward and
    /// backward; each segment's interior is re-materialised by re-running
    /// its forward during the backward pass. Memory drops (Γ), latency
    /// rises by one extra forward sweep (Φ).
    Checkpointed {
        /// Number of contiguous checkpoint segments (≥ 1). `1` checkpoints
        /// the whole network behind a single boundary.
        segments: usize,
    },
    /// Frozen-backbone fine-tuning: only the last `trainable_suffix`
    /// convolutions (and everything downstream of the first of them) train.
    /// Frozen layers run forward only — no weight/data gradients, no
    /// optimizer state, no saved activations. Both Γ and Φ drop.
    Frozen {
        /// Number of trailing trainable convolutions (≥ 1). A suffix that
        /// covers every convolution degenerates to [`TrainRegime::Vanilla`].
        trainable_suffix: usize,
    },
}

impl TrainRegime {
    /// Stable identifier used in CLI flags, dataset rows, campaign specs
    /// and fingerprints.
    pub fn name(&self) -> String {
        match self {
            TrainRegime::Vanilla => "vanilla".to_string(),
            TrainRegime::Checkpointed { segments } => format!("ckpt:{segments}"),
            TrainRegime::Frozen { trainable_suffix } => format!("frozen:{trainable_suffix}"),
        }
    }

    /// Inverse of [`TrainRegime::name`]. Returns `None` for unknown names
    /// or out-of-range parameters (`ckpt:0`, `frozen:0`).
    pub fn from_name(name: &str) -> Option<TrainRegime> {
        if name == "vanilla" {
            return Some(TrainRegime::Vanilla);
        }
        if let Some(n) = name.strip_prefix("ckpt:") {
            return n
                .parse::<usize>()
                .ok()
                .filter(|&s| s >= 1)
                .map(|segments| TrainRegime::Checkpointed { segments });
        }
        if let Some(n) = name.strip_prefix("frozen:") {
            return n
                .parse::<usize>()
                .ok()
                .filter(|&s| s >= 1)
                .map(|trainable_suffix| TrainRegime::Frozen { trainable_suffix });
        }
        None
    }

    /// Parse a comma-separated regime list (CLI `--regimes`, `[campaign]`
    /// config). Whitespace around entries is ignored.
    pub fn parse_list(list: &str) -> Result<Vec<TrainRegime>, String> {
        list.split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| {
                TrainRegime::from_name(s).ok_or_else(|| {
                    format!("unknown training regime {s:?} (expected vanilla, ckpt:N or frozen:N)")
                })
            })
            .collect()
    }

    pub fn is_vanilla(&self) -> bool {
        matches!(self, TrainRegime::Vanilla)
    }

    /// Reject degenerate parameters (zero segments / zero trainable layers).
    pub fn validate(&self) -> Result<(), String> {
        match self {
            TrainRegime::Vanilla => Ok(()),
            TrainRegime::Checkpointed { segments } if *segments == 0 => {
                Err("ckpt regime needs at least 1 segment".to_string())
            }
            TrainRegime::Frozen { trainable_suffix } if *trainable_suffix == 0 => {
                Err("frozen regime needs at least 1 trainable convolution".to_string())
            }
            _ => Ok(()),
        }
    }
}

impl fmt::Display for TrainRegime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for r in [
            TrainRegime::Vanilla,
            TrainRegime::Checkpointed { segments: 1 },
            TrainRegime::Checkpointed { segments: 4 },
            TrainRegime::Frozen { trainable_suffix: 2 },
            TrainRegime::Frozen { trainable_suffix: 17 },
        ] {
            assert_eq!(TrainRegime::from_name(&r.name()), Some(r));
            assert!(r.validate().is_ok());
        }
    }

    #[test]
    fn bad_names_rejected() {
        for bad in [
            "", "Vanilla", "ckpt", "ckpt:", "ckpt:0", "ckpt:-1", "ckpt:x", "frozen", "frozen:0",
            "frozen:1.5", "fp16",
        ] {
            assert_eq!(TrainRegime::from_name(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn list_parsing() {
        let rs = TrainRegime::parse_list("vanilla, ckpt:4 ,frozen:2").unwrap();
        assert_eq!(
            rs,
            vec![
                TrainRegime::Vanilla,
                TrainRegime::Checkpointed { segments: 4 },
                TrainRegime::Frozen { trainable_suffix: 2 },
            ]
        );
        assert!(TrainRegime::parse_list("vanilla,nope").is_err());
    }

    #[test]
    fn zero_parameters_fail_validation() {
        assert!(TrainRegime::Checkpointed { segments: 0 }.validate().is_err());
        assert!(TrainRegime::Frozen { trainable_suffix: 0 }.validate().is_err());
    }
}
