//! The edge-GPU training simulator: the substrate that stands in for the
//! physical Jetson TX2 / RTX 2080Ti testbeds (see DESIGN.md §1).
//!
//! Given a network graph, a batch size and a [`DeviceSpec`], it produces
//! the paper's two training attributes — total memory footprint Γ and
//! mini-batch latency Φ — plus the inference attributes γ and φ used by the
//! OFA case study. The model combines:
//!
//! - per-(layer, op) cuDNN algorithm selection ([`super::cudnn`]),
//! - a PyTorch-style caching allocator ([`super::allocator`]),
//! - autograd bookkeeping (which tensors are retained for backward),
//! - roofline latency with occupancy and launch overheads,
//! - framework/OS constants and (on unified devices) CPU-side dataloader
//!   memory, and
//! - multiplicative measurement noise when an RNG is supplied.
//!
//! Everything here is *hidden* from the analytical features — the random
//! forest's job, exactly as on real hardware, is to learn it.
//!
//! All analysis paths consume a compiled analysis view (`*_plan` methods,
//! generic over [`PlanView`] — a [`NetworkPlan`] or the overlay fast
//! path's [`OverlayPlan`](crate::ir::OverlayPlan)); the `&Graph` entry
//! points are thin wrappers that build a plan once and delegate. Callers
//! that evaluate a topology more than once — the profiler across 25 batch
//! sizes, the OFA search across features and three attributes — should
//! build the plan themselves and reuse it. Because both view types feed
//! the identical code below, overlay-based measurements are bit-identical
//! to graph-based ones (`rust/tests/overlay_equivalence.rs`).

use crate::ir::{Graph, GraphError, NetworkPlan, Op, PlanView};
use crate::util::rng::Pcg64;

use super::allocator::{pool_reserved, round_block};
use super::cudnn::{choose, ConvOp};
use super::regime::TrainRegime;
use super::spec::DeviceSpec;

const BYTES: f64 = 4.0;
const MB: f64 = 1024.0 * 1024.0;

/// Wall-clock cost of profiling one datapoint on the real device — the
/// paper measures "on average 20s per data point" on the TX2 (Sec. 6.4).
/// Used to account naive-search time honestly.
pub const PROFILE_COST_S: f64 = 20.0;

/// One simulated training-step measurement.
#[derive(Clone, Copy, Debug)]
pub struct TrainMeasurement {
    /// Total training memory footprint, MB (the paper's Γ).
    pub gamma_mb: f64,
    /// Mini-batch training latency, ms (the paper's Φ).
    pub phi_ms: f64,
}

/// One simulated inference measurement.
#[derive(Clone, Copy, Debug)]
pub struct InferMeasurement {
    /// Inference memory footprint, MB (the paper's γ).
    pub gamma_mb: f64,
    /// Batch inference latency, ms (the paper's φ).
    pub phi_ms: f64,
}

/// Detailed memory breakdown (diagnostics / DESIGN.md tables).
#[derive(Clone, Debug, Default)]
pub struct MemoryBreakdown {
    pub framework_mb: f64,
    pub params_mb: f64,
    pub optimizer_mb: f64,
    pub activations_mb: f64,
    pub workspace_mb: f64,
    pub transient_mb: f64,
    pub io_mb: f64,
}

impl MemoryBreakdown {
    pub fn total_mb(&self) -> f64 {
        self.framework_mb
            + self.params_mb
            + self.optimizer_mb
            + self.activations_mb
            + self.workspace_mb
            + self.transient_mb
            + self.io_mb
    }
}

/// The simulator for one device.
#[derive(Clone, Debug)]
pub struct Simulator {
    pub spec: DeviceSpec,
}

impl Simulator {
    pub fn new(spec: DeviceSpec) -> Self {
        Simulator { spec }
    }

    pub fn tx2() -> Self {
        Self::new(DeviceSpec::tx2())
    }

    /// Simulate a full training step (fwd + bwd + SGD update). When `rng`
    /// is provided the result carries measurement noise; pass `None` for
    /// the noise-free expectation.
    pub fn train_step(
        &self,
        graph: &Graph,
        bs: usize,
        rng: Option<&mut Pcg64>,
    ) -> Result<TrainMeasurement, GraphError> {
        Ok(self.train_step_plan(&NetworkPlan::build(graph)?, bs, rng))
    }

    /// As [`Simulator::train_step`] over a pre-compiled analysis view
    /// (infallible: the view proves the topology valid).
    pub fn train_step_plan<P: PlanView>(
        &self,
        plan: &P,
        bs: usize,
        mut rng: Option<&mut Pcg64>,
    ) -> TrainMeasurement {
        let mem = self.train_memory_breakdown_plan(plan, bs);
        let phi = self.train_latency_ms_plan(plan, bs);
        let (g_noise, p_noise) = match rng.as_deref_mut() {
            Some(r) => (r.jitter(0.008), r.jitter(0.015)),
            None => (1.0, 1.0),
        };
        TrainMeasurement {
            gamma_mb: mem.total_mb() * g_noise,
            phi_ms: phi * p_noise,
        }
    }

    /// Simulate inference (forward only, no autograd retention).
    pub fn inference(
        &self,
        graph: &Graph,
        bs: usize,
        rng: Option<&mut Pcg64>,
    ) -> Result<InferMeasurement, GraphError> {
        Ok(self.inference_plan(&NetworkPlan::build(graph)?, bs, rng))
    }

    /// As [`Simulator::inference`] over a pre-compiled analysis view.
    pub fn inference_plan<P: PlanView>(
        &self,
        plan: &P,
        bs: usize,
        mut rng: Option<&mut Pcg64>,
    ) -> InferMeasurement {
        let gamma = self.infer_memory_mb_plan(plan, bs);
        let phi = self.infer_latency_ms_plan(plan, bs);
        let (g_noise, p_noise) = match rng.as_deref_mut() {
            Some(r) => (r.jitter(0.006), r.jitter(0.012)),
            None => (1.0, 1.0),
        };
        InferMeasurement {
            gamma_mb: gamma * g_noise,
            phi_ms: phi * p_noise,
        }
    }

    /// Γ components (noise-free).
    pub fn train_memory_breakdown(
        &self,
        graph: &Graph,
        bs: usize,
    ) -> Result<MemoryBreakdown, GraphError> {
        Ok(self.train_memory_breakdown_plan(&NetworkPlan::build(graph)?, bs))
    }

    /// Γ components (noise-free) from a pre-compiled analysis view.
    pub fn train_memory_breakdown_plan<P: PlanView>(&self, plan: &P, bs: usize) -> MemoryBreakdown {
        let n_nodes = plan.n_nodes();
        let shapes = plan.shapes();
        let convs = plan.conv_infos();
        let bsf = bs as f64;

        // --- parameters, gradients, momentum ---
        let params = plan.param_count() as f64;
        let params_mb = pool_reserved([params * BYTES]) / MB;
        // grad + SGD momentum buffer (PyTorch momentum SGD).
        let optimizer_mb = 2.0 * params_mb;

        // --- activations retained for backward ---
        // `retained[i]` marks node i's output tensor as alive until its
        // consumer's backward; a tensor saved by several consumers counts
        // once (PyTorch keeps references, not copies).
        let mut retained = vec![false; n_nodes];
        let mut extra_blocks: Vec<f64> = Vec::new(); // masks, indices, stats
        for id in 0..n_nodes {
            match plan.op(id) {
                Op::Conv2d { .. } | Op::Linear { .. } => {
                    retained[plan.inputs(id)[0]] = true;
                }
                Op::BatchNorm => {
                    retained[plan.inputs(id)[0]] = true;
                    // saved mean + invstd
                    let c = shapes[id].channels() as f64;
                    extra_blocks.push(2.0 * c * BYTES);
                }
                Op::Activation(_) => {
                    // in-place ReLU keeps its output (the next consumer's
                    // input) — mark own output.
                    retained[id] = true;
                }
                Op::MaxPool { .. } => {
                    // backward needs int64 argmax indices
                    let elems = bsf * shapes[id].numel() as f64;
                    extra_blocks.push(elems * 8.0);
                }
                Op::Dropout(_) => {
                    // bool mask
                    let elems = bsf * shapes[id].numel() as f64;
                    extra_blocks.push(elems);
                }
                Op::Add | Op::Concat | Op::AvgPool { .. } | Op::GlobalAvgPool
                | Op::Flatten | Op::Input { .. } => {}
            }
        }
        let act_blocks = (0..n_nodes)
            .filter(|&i| retained[i])
            .map(|i| bsf * shapes[i].numel() as f64 * BYTES)
            .chain(extra_blocks.iter().copied());
        let activations_mb = pool_reserved(act_blocks) / MB;

        // --- cuDNN workspace high-water mark (allocator caches the max) ---
        let mut ws_peak = 0.0f64;
        for (i, c) in convs.iter().enumerate() {
            for op in [ConvOp::Fwd, ConvOp::BwdFilter, ConvOp::BwdData] {
                if op == ConvOp::BwdData && i == 0 {
                    continue; // no grad w.r.t. the data input
                }
                let ch = choose(&self.spec, c, op, bs);
                ws_peak = ws_peak.max(ch.workspace_bytes);
            }
        }
        let workspace_mb = round_block(ws_peak) / MB;

        // --- transient backward peak: largest simultaneous (grad_out +
        //     grad_in) pair ---
        let mut transient = 0.0f64;
        for id in 0..n_nodes {
            let out = bsf * shapes[id].numel() as f64;
            let inp: f64 = plan
                .inputs(id)
                .iter()
                .map(|&i| bsf * shapes[i].numel() as f64)
                .sum();
            transient = transient.max((out + inp) * BYTES);
        }
        let transient_mb = round_block(transient) / MB;

        // --- input pipeline ---
        let in_numel = shapes[0].numel() as f64;
        let io_mb = if self.spec.unified {
            // staging + device copy + dataloader worker RSS (unified memory
            // counts CPU-side allocations too)
            (2.0 * bsf * in_numel * BYTES) / MB + 260.0
        } else {
            (bsf * in_numel * BYTES) / MB
        };

        MemoryBreakdown {
            framework_mb: self.spec.framework_base_train_mb,
            params_mb,
            optimizer_mb,
            activations_mb,
            workspace_mb,
            transient_mb,
            io_mb,
        }
    }

    /// Φ (noise-free): conv ops via cuDNN choices + pointwise/BN/pool/linear
    /// traffic + optimizer + per-launch and per-step overheads.
    pub fn train_latency_ms(&self, graph: &Graph, bs: usize) -> Result<f64, GraphError> {
        Ok(self.train_latency_ms_plan(&NetworkPlan::build(graph)?, bs))
    }

    /// Φ (noise-free) from a pre-compiled analysis view.
    pub fn train_latency_ms_plan<P: PlanView>(&self, plan: &P, bs: usize) -> f64 {
        let n_nodes = plan.n_nodes();
        let shapes = plan.shapes();
        let convs = plan.conv_infos();
        let bsf = bs as f64;
        let bw = self.spec.mem_bw_gbps * 1e9 * self.spec.bw_efficiency;
        let launch_ms = self.spec.launch_overhead_us / 1e3;
        let mut t = self.spec.step_overhead_ms;

        // Convolutions: fwd + bwd_filter (+ bwd_data except the first conv).
        for (i, c) in convs.iter().enumerate() {
            t += choose(&self.spec, c, ConvOp::Fwd, bs).time_ms;
            t += choose(&self.spec, c, ConvOp::BwdFilter, bs).time_ms;
            if i != 0 {
                t += choose(&self.spec, c, ConvOp::BwdData, bs).time_ms;
            }
        }

        // Pointwise / normalisation / pooling / joins: bandwidth-bound.
        let traffic = |factor: f64, elems: f64, launches: f64| {
            factor * elems * BYTES / bw * 1e3 + launches * launch_ms
        };
        for id in 0..n_nodes {
            let elems = bsf * shapes[id].numel() as f64;
            t += match plan.op(id) {
                Op::BatchNorm => traffic(3.0 + 5.0, elems, 2.0),
                Op::Activation(_) => traffic(2.0 + 3.0, elems, 2.0),
                Op::MaxPool { .. } | Op::AvgPool { .. } => {
                    let in_elems = bsf * shapes[plan.inputs(id)[0]].numel() as f64;
                    traffic(2.0, in_elems + elems, 2.0)
                }
                Op::GlobalAvgPool => {
                    let in_elems = bsf * shapes[plan.inputs(id)[0]].numel() as f64;
                    traffic(1.0, in_elems, 2.0)
                }
                Op::Add => traffic(3.0, elems, 1.0),
                Op::Concat => traffic(2.0 + 2.0, elems, 2.0),
                Op::Dropout(_) => traffic(2.0 + 2.0, elems, 2.0),
                Op::Linear { out, .. } => {
                    let inf = shapes[plan.inputs(id)[0]].numel() as f64;
                    let macs = bsf * inf * *out as f64;
                    // fwd + bwd_x + bwd_w, modest efficiency for skinny GEMMs
                    let flops = 3.0 * 2.0 * macs;
                    let t_c = flops / (self.spec.peak_gflops() * 1e9 * 0.35) * 1e3;
                    let weight_bytes = inf * *out as f64 * BYTES;
                    let t_m = 3.0 * weight_bytes / bw * 1e3;
                    t_c.max(t_m) + 3.0 * launch_ms
                }
                Op::Input { .. } | Op::Flatten | Op::Conv2d { .. } => 0.0,
            };
        }

        // SGD momentum update: read w/g/m, write w/m.
        let params = plan.param_count() as f64;
        t += 5.0 * params * BYTES / bw * 1e3 + launch_ms * 3.0;
        t
    }

    // ---- training-regime-aware entry points -----------------------------
    //
    // `TrainRegime::Vanilla` delegates to the unmodified methods above, so
    // vanilla measurements are bit-identical to the pre-regime simulator
    // (pinned by rust/tests/regime_equivalence.rs). The other regimes reuse
    // the same retention / kernel-choice / roofline machinery with the
    // regime's schedule applied.

    /// As [`Simulator::train_step`] under a [`TrainRegime`].
    pub fn train_step_regime(
        &self,
        graph: &Graph,
        bs: usize,
        regime: TrainRegime,
        rng: Option<&mut Pcg64>,
    ) -> Result<TrainMeasurement, GraphError> {
        Ok(self.train_step_plan_regime(&NetworkPlan::build(graph)?, bs, regime, rng))
    }

    /// As [`Simulator::train_step_plan`] under a [`TrainRegime`]. Noise
    /// draws happen in the same order as the vanilla entry point, so an RNG
    /// stream advances identically whichever regime it measures.
    pub fn train_step_plan_regime<P: PlanView>(
        &self,
        plan: &P,
        bs: usize,
        regime: TrainRegime,
        mut rng: Option<&mut Pcg64>,
    ) -> TrainMeasurement {
        let mem = self.train_memory_breakdown_plan_regime(plan, bs, regime);
        let phi = self.train_latency_ms_plan_regime(plan, bs, regime);
        let (g_noise, p_noise) = match rng.as_deref_mut() {
            Some(r) => (r.jitter(0.008), r.jitter(0.015)),
            None => (1.0, 1.0),
        };
        TrainMeasurement {
            gamma_mb: mem.total_mb() * g_noise,
            phi_ms: phi * p_noise,
        }
    }

    /// Γ components (noise-free) under a [`TrainRegime`].
    pub fn train_memory_breakdown_plan_regime<P: PlanView>(
        &self,
        plan: &P,
        bs: usize,
        regime: TrainRegime,
    ) -> MemoryBreakdown {
        match regime {
            TrainRegime::Vanilla => self.train_memory_breakdown_plan(plan, bs),
            TrainRegime::Checkpointed { segments } => {
                self.train_memory_breakdown_ckpt(plan, bs, segments)
            }
            TrainRegime::Frozen { trainable_suffix } => {
                self.train_memory_breakdown_frozen(plan, bs, trainable_suffix)
            }
        }
    }

    /// Φ (noise-free) under a [`TrainRegime`].
    pub fn train_latency_ms_plan_regime<P: PlanView>(
        &self,
        plan: &P,
        bs: usize,
        regime: TrainRegime,
    ) -> f64 {
        match regime {
            TrainRegime::Vanilla => self.train_latency_ms_plan(plan, bs),
            // Checkpointing keeps the backward schedule intact and adds one
            // full re-materialising forward sweep: each segment's interior
            // is re-run exactly once during backward, so the extra work is
            // one forward pass regardless of the segment count.
            TrainRegime::Checkpointed { .. } => {
                self.train_latency_ms_plan(plan, bs) + self.forward_sweep_ms(plan, bs)
            }
            TrainRegime::Frozen { trainable_suffix } => {
                self.train_latency_ms_frozen(plan, bs, trainable_suffix)
            }
        }
    }

    /// Γ components for frozen-backbone fine-tuning: only the trailing
    /// `trainable_suffix` convolutions (and everything downstream of the
    /// first of them) keep autograd retention, optimizer state and backward
    /// workspaces. A suffix covering every convolution degenerates to the
    /// vanilla computation (and is arithmetically identical to it).
    fn train_memory_breakdown_frozen<P: PlanView>(
        &self,
        plan: &P,
        bs: usize,
        trainable_suffix: usize,
    ) -> MemoryBreakdown {
        let n_nodes = plan.n_nodes();
        let shapes = plan.shapes();
        let convs = plan.conv_infos();
        let bsf = bs as f64;
        let (first_trainable, cutoff) = frozen_boundary(plan, trainable_suffix);

        // Weights all stay resident (frozen layers still run forward), but
        // gradient + momentum buffers exist only for trainable parameters.
        let params = plan.param_count() as f64;
        let params_mb = pool_reserved([params * BYTES]) / MB;
        let optimizer_mb = if cutoff == 0 {
            2.0 * params_mb
        } else {
            let trainable = trainable_param_count(plan, cutoff) as f64;
            2.0 * pool_reserved([trainable * BYTES]) / MB
        };

        // Autograd retention starts at the trainable cutoff: frozen layers
        // save nothing for backward. A trainable consumer may still retain
        // the frozen region's last output (its own input).
        let mut retained = vec![false; n_nodes];
        let mut extra_blocks: Vec<f64> = Vec::new();
        for id in cutoff..n_nodes {
            match plan.op(id) {
                Op::Conv2d { .. } | Op::Linear { .. } => {
                    retained[plan.inputs(id)[0]] = true;
                }
                Op::BatchNorm => {
                    retained[plan.inputs(id)[0]] = true;
                    let c = shapes[id].channels() as f64;
                    extra_blocks.push(2.0 * c * BYTES);
                }
                Op::Activation(_) => {
                    retained[id] = true;
                }
                Op::MaxPool { .. } => {
                    let elems = bsf * shapes[id].numel() as f64;
                    extra_blocks.push(elems * 8.0);
                }
                Op::Dropout(_) => {
                    let elems = bsf * shapes[id].numel() as f64;
                    extra_blocks.push(elems);
                }
                Op::Add | Op::Concat | Op::AvgPool { .. } | Op::GlobalAvgPool
                | Op::Flatten | Op::Input { .. } => {}
            }
        }
        let act_blocks = (0..n_nodes)
            .filter(|&i| retained[i])
            .map(|i| bsf * shapes[i].numel() as f64 * BYTES)
            .chain(extra_blocks.iter().copied());
        let activations_mb = pool_reserved(act_blocks) / MB;

        // Workspace: frozen convs run forward only, and the first trainable
        // conv needs no bwd_data (nothing upstream receives gradients —
        // with nothing frozen this reduces to the vanilla i == 0 skip).
        let mut ws_peak = 0.0f64;
        for (i, c) in convs.iter().enumerate() {
            for op in [ConvOp::Fwd, ConvOp::BwdFilter, ConvOp::BwdData] {
                if op == ConvOp::BwdFilter && i < first_trainable {
                    continue;
                }
                if op == ConvOp::BwdData && i <= first_trainable {
                    continue;
                }
                let ch = choose(&self.spec, c, op, bs);
                ws_peak = ws_peak.max(ch.workspace_bytes);
            }
        }
        let workspace_mb = round_block(ws_peak) / MB;

        // Transient (grad_out + grad_in) pairs exist only where backward
        // actually runs.
        let mut transient = 0.0f64;
        for id in cutoff..n_nodes {
            let out = bsf * shapes[id].numel() as f64;
            let inp: f64 = plan
                .inputs(id)
                .iter()
                .map(|&i| bsf * shapes[i].numel() as f64)
                .sum();
            transient = transient.max((out + inp) * BYTES);
        }
        let transient_mb = round_block(transient) / MB;

        let in_numel = shapes[0].numel() as f64;
        let io_mb = if self.spec.unified {
            (2.0 * bsf * in_numel * BYTES) / MB + 260.0
        } else {
            (bsf * in_numel * BYTES) / MB
        };

        MemoryBreakdown {
            framework_mb: self.spec.framework_base_train_mb,
            params_mb,
            optimizer_mb,
            activations_mb,
            workspace_mb,
            transient_mb,
            io_mb,
        }
    }

    /// Γ components under gradient checkpointing: between forward and
    /// backward only the segment-boundary outputs (the checkpoints) stay
    /// resident; one segment's interior retention is re-materialised at a
    /// time during backward, so the live peak is boundaries + the heaviest
    /// single segment. Weights, optimizer, workspace, transient and io are
    /// unchanged — the same kernels run, just more than once.
    fn train_memory_breakdown_ckpt<P: PlanView>(
        &self,
        plan: &P,
        bs: usize,
        segments: usize,
    ) -> MemoryBreakdown {
        let n_nodes = plan.n_nodes();
        let shapes = plan.shapes();
        let convs = plan.conv_infos();
        let bsf = bs as f64;

        let params = plan.param_count() as f64;
        let params_mb = pool_reserved([params * BYTES]) / MB;
        let optimizer_mb = 2.0 * params_mb;

        // Vanilla retention bookkeeping, with every auxiliary block tagged
        // by the node that produced it so it can be assigned to a segment.
        let mut retained = vec![false; n_nodes];
        let mut extra_blocks: Vec<(usize, f64)> = Vec::new();
        for id in 0..n_nodes {
            match plan.op(id) {
                Op::Conv2d { .. } | Op::Linear { .. } => {
                    retained[plan.inputs(id)[0]] = true;
                }
                Op::BatchNorm => {
                    retained[plan.inputs(id)[0]] = true;
                    let c = shapes[id].channels() as f64;
                    extra_blocks.push((id, 2.0 * c * BYTES));
                }
                Op::Activation(_) => {
                    retained[id] = true;
                }
                Op::MaxPool { .. } => {
                    let elems = bsf * shapes[id].numel() as f64;
                    extra_blocks.push((id, elems * 8.0));
                }
                Op::Dropout(_) => {
                    let elems = bsf * shapes[id].numel() as f64;
                    extra_blocks.push((id, elems));
                }
                Op::Add | Op::Concat | Op::AvgPool { .. } | Op::GlobalAvgPool
                | Op::Flatten | Op::Input { .. } => {}
            }
        }

        // Balanced contiguous segmentation by node id: node `id` belongs to
        // segment `id·S/n`. Note S = 1 stores a boundary and still
        // re-materialises everything at once — real memory savings start at
        // S ≥ 2, exactly as with torch.utils.checkpoint.
        let s = segments.clamp(1, n_nodes);
        let seg_of = |id: usize| id * s / n_nodes;
        let block = |id: usize| bsf * shapes[id].numel() as f64 * BYTES;
        let mut seg_raw = vec![0.0f64; s];
        for (id, &r) in retained.iter().enumerate() {
            if r {
                seg_raw[seg_of(id)] += block(id);
            }
        }
        for &(id, b) in &extra_blocks {
            seg_raw[seg_of(id)] += b;
        }
        let mut peak_seg = 0usize;
        for (k, &raw) in seg_raw.iter().enumerate() {
            if raw > seg_raw[peak_seg] {
                peak_seg = k;
            }
        }
        // A checkpoint that is also retained inside the peak segment counts
        // twice — once stored, once re-materialised — which is the
        // conservative (allocator's-eye) view.
        let boundaries =
            (0..n_nodes).filter(|&id| id + 1 == n_nodes || seg_of(id + 1) != seg_of(id));
        let act_blocks = boundaries
            .map(block)
            .chain(
                (0..n_nodes)
                    .filter(|&id| retained[id] && seg_of(id) == peak_seg)
                    .map(block),
            )
            .chain(
                extra_blocks
                    .iter()
                    .filter(|&&(id, _)| seg_of(id) == peak_seg)
                    .map(|&(_, b)| b),
            );
        let activations_mb = pool_reserved(act_blocks) / MB;

        let mut ws_peak = 0.0f64;
        for (i, c) in convs.iter().enumerate() {
            for op in [ConvOp::Fwd, ConvOp::BwdFilter, ConvOp::BwdData] {
                if op == ConvOp::BwdData && i == 0 {
                    continue;
                }
                let ch = choose(&self.spec, c, op, bs);
                ws_peak = ws_peak.max(ch.workspace_bytes);
            }
        }
        let workspace_mb = round_block(ws_peak) / MB;

        let mut transient = 0.0f64;
        for id in 0..n_nodes {
            let out = bsf * shapes[id].numel() as f64;
            let inp: f64 = plan
                .inputs(id)
                .iter()
                .map(|&i| bsf * shapes[i].numel() as f64)
                .sum();
            transient = transient.max((out + inp) * BYTES);
        }
        let transient_mb = round_block(transient) / MB;

        let in_numel = shapes[0].numel() as f64;
        let io_mb = if self.spec.unified {
            (2.0 * bsf * in_numel * BYTES) / MB + 260.0
        } else {
            (bsf * in_numel * BYTES) / MB
        };

        MemoryBreakdown {
            framework_mb: self.spec.framework_base_train_mb,
            params_mb,
            optimizer_mb,
            activations_mb,
            workspace_mb,
            transient_mb,
            io_mb,
        }
    }

    /// Φ for frozen-backbone fine-tuning: frozen convs skip bwd_filter and
    /// bwd_data kernels, frozen pointwise nodes pay only their forward
    /// traffic share, and the optimizer touches trainable parameters only.
    fn train_latency_ms_frozen<P: PlanView>(
        &self,
        plan: &P,
        bs: usize,
        trainable_suffix: usize,
    ) -> f64 {
        let n_nodes = plan.n_nodes();
        let shapes = plan.shapes();
        let convs = plan.conv_infos();
        let bsf = bs as f64;
        let bw = self.spec.mem_bw_gbps * 1e9 * self.spec.bw_efficiency;
        let launch_ms = self.spec.launch_overhead_us / 1e3;
        let (first_trainable, cutoff) = frozen_boundary(plan, trainable_suffix);
        let mut t = self.spec.step_overhead_ms;

        // The first trainable conv needs no bwd_data: nothing upstream
        // receives gradients (reduces to the vanilla i == 0 skip when
        // nothing is frozen).
        for (i, c) in convs.iter().enumerate() {
            t += choose(&self.spec, c, ConvOp::Fwd, bs).time_ms;
            if i >= first_trainable {
                t += choose(&self.spec, c, ConvOp::BwdFilter, bs).time_ms;
                if i != 0 && i != first_trainable {
                    t += choose(&self.spec, c, ConvOp::BwdData, bs).time_ms;
                }
            }
        }

        let traffic = |factor: f64, elems: f64, launches: f64| {
            factor * elems * BYTES / bw * 1e3 + launches * launch_ms
        };
        for id in 0..n_nodes {
            let elems = bsf * shapes[id].numel() as f64;
            t += if id < cutoff {
                self.fwd_node_ms(plan, id, bsf, bw, launch_ms)
            } else {
                match plan.op(id) {
                    Op::BatchNorm => traffic(3.0 + 5.0, elems, 2.0),
                    Op::Activation(_) => traffic(2.0 + 3.0, elems, 2.0),
                    Op::MaxPool { .. } | Op::AvgPool { .. } => {
                        let in_elems = bsf * shapes[plan.inputs(id)[0]].numel() as f64;
                        traffic(2.0, in_elems + elems, 2.0)
                    }
                    Op::GlobalAvgPool => {
                        let in_elems = bsf * shapes[plan.inputs(id)[0]].numel() as f64;
                        traffic(1.0, in_elems, 2.0)
                    }
                    Op::Add => traffic(3.0, elems, 1.0),
                    Op::Concat => traffic(2.0 + 2.0, elems, 2.0),
                    Op::Dropout(_) => traffic(2.0 + 2.0, elems, 2.0),
                    Op::Linear { out, .. } => {
                        let inf = shapes[plan.inputs(id)[0]].numel() as f64;
                        let macs = bsf * inf * *out as f64;
                        let flops = 3.0 * 2.0 * macs;
                        let t_c = flops / (self.spec.peak_gflops() * 1e9 * 0.35) * 1e3;
                        let weight_bytes = inf * *out as f64 * BYTES;
                        let t_m = 3.0 * weight_bytes / bw * 1e3;
                        t_c.max(t_m) + 3.0 * launch_ms
                    }
                    Op::Input { .. } | Op::Flatten | Op::Conv2d { .. } => 0.0,
                }
            };
        }

        let params = if cutoff == 0 {
            plan.param_count()
        } else {
            trainable_param_count(plan, cutoff)
        } as f64;
        t += 5.0 * params * BYTES / bw * 1e3 + launch_ms * 3.0;
        t
    }

    /// One full forward pass (conv kernels + every other node's forward
    /// traffic share), without dispatch or step overheads — the extra work
    /// a checkpointed backward performs to re-materialise activations.
    fn forward_sweep_ms<P: PlanView>(&self, plan: &P, bs: usize) -> f64 {
        let n_nodes = plan.n_nodes();
        let convs = plan.conv_infos();
        let bsf = bs as f64;
        let bw = self.spec.mem_bw_gbps * 1e9 * self.spec.bw_efficiency;
        let launch_ms = self.spec.launch_overhead_us / 1e3;
        let mut t = 0.0;
        for c in convs {
            t += choose(&self.spec, c, ConvOp::Fwd, bs).time_ms;
        }
        for id in 0..n_nodes {
            t += self.fwd_node_ms(plan, id, bsf, bw, launch_ms);
        }
        t
    }

    /// Forward-pass share of one non-conv node's bandwidth-bound cost —
    /// used for frozen (forward-only) regions and checkpoint re-forwards.
    /// Each arm is the forward slice of the corresponding arm in
    /// [`Simulator::train_latency_ms_plan`], so it never exceeds it.
    fn fwd_node_ms<P: PlanView>(
        &self,
        plan: &P,
        id: usize,
        bsf: f64,
        bw: f64,
        launch_ms: f64,
    ) -> f64 {
        let shapes = plan.shapes();
        let elems = bsf * shapes[id].numel() as f64;
        let traffic = |factor: f64, elems: f64, launches: f64| {
            factor * elems * BYTES / bw * 1e3 + launches * launch_ms
        };
        match plan.op(id) {
            Op::BatchNorm => traffic(3.0, elems, 1.0),
            Op::Activation(_) => traffic(2.0, elems, 1.0),
            Op::MaxPool { .. } | Op::AvgPool { .. } => {
                let in_elems = bsf * shapes[plan.inputs(id)[0]].numel() as f64;
                traffic(1.0, in_elems + elems, 1.0)
            }
            Op::GlobalAvgPool => {
                let in_elems = bsf * shapes[plan.inputs(id)[0]].numel() as f64;
                traffic(0.5, in_elems, 1.0)
            }
            Op::Add => traffic(3.0, elems, 1.0),
            Op::Concat => traffic(2.0, elems, 1.0),
            Op::Dropout(_) => traffic(2.0, elems, 1.0),
            Op::Linear { out, .. } => {
                let inf = shapes[plan.inputs(id)[0]].numel() as f64;
                let macs = bsf * inf * *out as f64;
                let t_c = 2.0 * macs / (self.spec.peak_gflops() * 1e9 * 0.35) * 1e3;
                let weight_bytes = inf * *out as f64 * BYTES;
                let t_m = weight_bytes / bw * 1e3;
                t_c.max(t_m) + launch_ms
            }
            Op::Input { .. } | Op::Flatten | Op::Conv2d { .. } => 0.0,
        }
    }

    /// Inference memory γ (noise-free).
    pub fn infer_memory_mb(&self, graph: &Graph, bs: usize) -> Result<f64, GraphError> {
        Ok(self.infer_memory_mb_plan(&NetworkPlan::build(graph)?, bs))
    }

    /// Inference memory γ (noise-free) from a pre-compiled analysis view.
    pub fn infer_memory_mb_plan<P: PlanView>(&self, plan: &P, bs: usize) -> f64 {
        let shapes = plan.shapes();
        let convs = plan.conv_infos();
        let bsf = bs as f64;
        let params = plan.param_count() as f64;
        let params_mb = pool_reserved([params * BYTES]) / MB;
        // Ping-pong activation buffers: the two largest simultaneous
        // tensors bound the live set without autograd.
        let mut sizes: Vec<f64> = shapes
            .iter()
            .map(|s| bsf * s.numel() as f64 * BYTES)
            .collect();
        // total_cmp: NaN-safe, and identical to the previous partial_cmp
        // order on the finite non-negative sizes produced here.
        sizes.sort_by(|a, b| b.total_cmp(a));
        let act_mb = pool_reserved(sizes.into_iter().take(2)) / MB;
        let mut ws_peak = 0.0f64;
        for c in convs {
            ws_peak = ws_peak.max(choose(&self.spec, c, ConvOp::Fwd, bs).workspace_bytes);
        }
        let io_mb = if self.spec.unified {
            (2.0 * bsf * shapes[0].numel() as f64 * BYTES) / MB + 120.0
        } else {
            (bsf * shapes[0].numel() as f64 * BYTES) / MB
        };
        self.spec.framework_base_infer_mb
            + params_mb
            + act_mb
            + round_block(ws_peak) / MB
            + io_mb
    }

    /// Inference latency φ (noise-free).
    pub fn infer_latency_ms(&self, graph: &Graph, bs: usize) -> Result<f64, GraphError> {
        Ok(self.infer_latency_ms_plan(&NetworkPlan::build(graph)?, bs))
    }

    /// Inference latency φ (noise-free) from a pre-compiled analysis view.
    pub fn infer_latency_ms_plan<P: PlanView>(&self, plan: &P, bs: usize) -> f64 {
        let n_nodes = plan.n_nodes();
        let shapes = plan.shapes();
        let convs = plan.conv_infos();
        let bsf = bs as f64;
        let bw = self.spec.mem_bw_gbps * 1e9 * self.spec.bw_efficiency;
        let launch_ms = self.spec.launch_overhead_us / 1e3;
        let mut t = 1.2; // dispatch overhead
        for c in convs {
            t += choose(&self.spec, c, ConvOp::Fwd, bs).time_ms;
        }
        for id in 0..n_nodes {
            let elems = bsf * shapes[id].numel() as f64;
            t += match plan.op(id) {
                Op::BatchNorm => 3.0 * elems * BYTES / bw * 1e3 + launch_ms,
                Op::Activation(_) | Op::Dropout(_) => {
                    2.0 * elems * BYTES / bw * 1e3 + launch_ms
                }
                Op::MaxPool { .. } | Op::AvgPool { .. } | Op::GlobalAvgPool => {
                    let in_elems = bsf * shapes[plan.inputs(id)[0]].numel() as f64;
                    2.0 * in_elems * BYTES / bw * 1e3 + launch_ms
                }
                Op::Add | Op::Concat => 3.0 * elems * BYTES / bw * 1e3 + launch_ms,
                Op::Linear { out, .. } => {
                    let inf = shapes[plan.inputs(id)[0]].numel() as f64;
                    let macs = bsf * inf * *out as f64;
                    let t_c = 2.0 * macs / (self.spec.peak_gflops() * 1e9 * 0.35) * 1e3;
                    let t_m = inf * *out as f64 * BYTES / bw * 1e3;
                    t_c.max(t_m) + launch_ms
                }
                _ => 0.0,
            };
        }
        t
    }
}

/// First trainable conv index and the node-id cutoff of the trainable
/// region under a frozen regime. A suffix covering every convolution (or a
/// conv-free graph) yields cutoff 0 — the whole graph trains, i.e. vanilla.
fn frozen_boundary<P: PlanView>(plan: &P, trainable_suffix: usize) -> (usize, usize) {
    let convs = plan.conv_infos();
    let first_trainable = convs.len().saturating_sub(trainable_suffix);
    let cutoff = if first_trainable == 0 {
        0
    } else {
        convs[first_trainable].node
    };
    (first_trainable, cutoff)
}

/// Parameters owned by nodes at or after `cutoff` (the trainable region).
fn trainable_param_count<P: PlanView>(plan: &P, cutoff: usize) -> usize {
    let shapes = plan.shapes();
    (cutoff..plan.n_nodes())
        .map(|id| crate::ir::graph::node_param_count(id, plan.op(id), plan.inputs(id), shapes))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn mobilenetv2_bs80_magnitudes_match_paper_ballpark() {
        // Paper Sec. 6.2: MobileNetV2 @50% pruning, bs=80 on TX2 measured
        // Γ = 4423±1597 MB, Φ = 1741±871 ms across topologies. The unpruned
        // net at bs=80 should land in the same order of magnitude.
        let sim = Simulator::tx2();
        let g = models::mobilenet_v2(1000);
        let m = sim.train_step(&g, 80, None).unwrap();
        assert!(
            (2500.0..12000.0).contains(&m.gamma_mb),
            "Γ = {} MB",
            m.gamma_mb
        );
        assert!((600.0..6000.0).contains(&m.phi_ms), "Φ = {} ms", m.phi_ms);
    }

    #[test]
    fn gamma_linear_in_batch_size() {
        // Paper App. B: "they display linearity with batch size".
        let sim = Simulator::tx2();
        let g = models::resnet18(1000);
        let bss: Vec<usize> = vec![8, 16, 32, 64, 128];
        let xs: Vec<f64> = bss.iter().map(|&b| b as f64).collect();
        let gammas: Vec<f64> = bss
            .iter()
            .map(|&b| sim.train_step(&g, b, None).unwrap().gamma_mb)
            .collect();
        let phis: Vec<f64> = bss
            .iter()
            .map(|&b| sim.train_step(&g, b, None).unwrap().phi_ms)
            .collect();
        let (_, _, r2g) = crate::util::stats::linear_fit(&xs, &gammas);
        let (_, _, r2p) = crate::util::stats::linear_fit(&xs, &phis);
        assert!(r2g > 0.995, "Γ–bs linearity r2={r2g}");
        assert!(r2p > 0.98, "Φ–bs linearity r2={r2p}");
    }

    #[test]
    fn pruning_reduces_both_attributes() {
        use crate::pruning::{prune, Strategy};
        let sim = Simulator::tx2();
        let g = models::resnet18(1000);
        let mut rng = Pcg64::new(5);
        let p = prune(&g, Strategy::Random, 0.7, &mut rng);
        let full = sim.train_step(&g, 64, None).unwrap();
        let pruned = sim.train_step(&p, 64, None).unwrap();
        assert!(pruned.gamma_mb < full.gamma_mb);
        assert!(pruned.phi_ms < full.phi_ms);
    }

    #[test]
    fn noise_is_small_and_seeded() {
        let sim = Simulator::tx2();
        let g = models::squeezenet(1000);
        let base = sim.train_step(&g, 32, None).unwrap();
        let mut r1 = Pcg64::new(9);
        let mut r2 = Pcg64::new(9);
        let n1 = sim.train_step(&g, 32, Some(&mut r1)).unwrap();
        let n2 = sim.train_step(&g, 32, Some(&mut r2)).unwrap();
        assert_eq!(n1.gamma_mb, n2.gamma_mb);
        assert!((n1.gamma_mb / base.gamma_mb - 1.0).abs() < 0.05);
        assert!((n1.phi_ms / base.phi_ms - 1.0).abs() < 0.08);
    }

    #[test]
    fn inference_cheaper_than_training() {
        let sim = Simulator::tx2();
        let g = models::resnet50(1000);
        let t = sim.train_step(&g, 32, None).unwrap();
        let i = sim.inference(&g, 32, None).unwrap();
        assert!(i.gamma_mb < t.gamma_mb);
        assert!(i.phi_ms < t.phi_ms / 2.0);
    }

    #[test]
    fn table2_magnitudes_resnet50_on_tx2() {
        // Table 2 MAX (ResNet50-like, 192MB params): Γ(bs32)=5838 MB,
        // γ(bs1)=1958 MB, φ(bs1)=69.6 ms. Our unpruned ResNet50 (97MB) at
        // bs=32 should land within ~2x of the Γ scale and γ should be
        // base + O(100MB).
        let sim = Simulator::tx2();
        let g = models::resnet50(1000);
        let t = sim.train_step(&g, 32, None).unwrap();
        assert!((2500.0..9000.0).contains(&t.gamma_mb), "Γ = {}", t.gamma_mb);
        let i = sim.inference(&g, 1, None).unwrap();
        assert!((1500.0..2600.0).contains(&i.gamma_mb), "γ = {}", i.gamma_mb);
        assert!((15.0..350.0).contains(&i.phi_ms), "φ = {}", i.phi_ms);
    }

    #[test]
    fn server_gpu_trains_faster_with_less_gamma_offset() {
        let tx2 = Simulator::tx2();
        let ti = Simulator::new(DeviceSpec::rtx2080ti());
        let g = models::resnet18(1000);
        let m_tx2 = tx2.train_step(&g, 32, None).unwrap();
        let m_ti = ti.train_step(&g, 32, None).unwrap();
        assert!(m_ti.phi_ms < m_tx2.phi_ms / 4.0);
        assert!(m_ti.gamma_mb < m_tx2.gamma_mb);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let sim = Simulator::tx2();
        let g = models::mnasnet(1000);
        let b = sim.train_memory_breakdown(&g, 16).unwrap();
        let m = sim.train_step(&g, 16, None).unwrap();
        assert!((b.total_mb() - m.gamma_mb).abs() < 1e-6);
        assert!(b.activations_mb > 0.0 && b.workspace_mb >= 0.0);
    }

    #[test]
    fn vanilla_regime_is_bit_identical() {
        let sim = Simulator::tx2();
        let g = models::resnet18(1000);
        let plan = g.plan().unwrap();
        for bs in [4usize, 32] {
            let base = sim.train_step_plan(&plan, bs, None);
            let via = sim.train_step_plan_regime(&plan, bs, TrainRegime::Vanilla, None);
            assert_eq!(base.gamma_mb.to_bits(), via.gamma_mb.to_bits());
            assert_eq!(base.phi_ms.to_bits(), via.phi_ms.to_bits());
        }
    }

    #[test]
    fn checkpointing_trades_memory_for_latency() {
        let sim = Simulator::tx2();
        for g in [models::resnet18(1000), models::mobilenet_v2(1000)] {
            let plan = g.plan().unwrap();
            let v = sim.train_step_plan(&plan, 32, None);
            for segments in [2usize, 4] {
                let c = sim.train_step_plan_regime(
                    &plan,
                    32,
                    TrainRegime::Checkpointed { segments },
                    None,
                );
                assert!(c.gamma_mb < v.gamma_mb, "{}: Γ {} !< {}", g.name, c.gamma_mb, v.gamma_mb);
                assert!(c.phi_ms > v.phi_ms, "{}: Φ {} !> {}", g.name, c.phi_ms, v.phi_ms);
            }
        }
    }

    #[test]
    fn freezing_lowers_memory_and_latency() {
        let sim = Simulator::tx2();
        for g in [models::resnet18(1000), models::mobilenet_v2(1000)] {
            let plan = g.plan().unwrap();
            let v = sim.train_step_plan(&plan, 32, None);
            let f = sim.train_step_plan_regime(
                &plan,
                32,
                TrainRegime::Frozen { trainable_suffix: 3 },
                None,
            );
            assert!(f.gamma_mb < v.gamma_mb, "{}: Γ {} !< {}", g.name, f.gamma_mb, v.gamma_mb);
            assert!(f.phi_ms < v.phi_ms, "{}: Φ {} !< {}", g.name, f.phi_ms, v.phi_ms);
        }
    }

    #[test]
    fn full_trainable_suffix_degenerates_to_vanilla() {
        let sim = Simulator::tx2();
        let g = models::squeezenet(1000);
        let plan = g.plan().unwrap();
        let n_convs = plan.conv_infos().len();
        let v = sim.train_step_plan(&plan, 16, None);
        let f = sim.train_step_plan_regime(
            &plan,
            16,
            TrainRegime::Frozen {
                trainable_suffix: n_convs,
            },
            None,
        );
        assert_eq!(v.gamma_mb.to_bits(), f.gamma_mb.to_bits());
        assert_eq!(v.phi_ms.to_bits(), f.phi_ms.to_bits());
    }

    #[test]
    fn regime_noise_draws_match_vanilla_stream() {
        // Whatever the regime, a measurement consumes the same RNG draws —
        // the profiler's resumable unit streams rely on this.
        let sim = Simulator::tx2();
        let g = models::squeezenet(1000);
        let plan = g.plan().unwrap();
        let mut r1 = Pcg64::new(21);
        let mut r2 = Pcg64::new(21);
        sim.train_step_plan(&plan, 8, Some(&mut r1));
        sim.train_step_plan_regime(
            &plan,
            8,
            TrainRegime::Checkpointed { segments: 4 },
            Some(&mut r2),
        );
        assert_eq!(r1.next_u64(), r2.next_u64());
    }
}
