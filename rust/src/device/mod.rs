//! The simulated edge-GPU substrate (DESIGN.md §1, S5): device specs,
//! cuDNN-style convolution algorithm selection, a PyTorch-style caching
//! allocator, and the training/inference performance simulator that
//! produces the paper's Γ/Φ/γ/φ attributes.

pub mod allocator;
pub mod cudnn;
pub mod regime;
pub mod simulator;
pub mod spec;

pub use cudnn::{Algo, Choice, ConvOp};
pub use regime::TrainRegime;
pub use simulator::{InferMeasurement, MemoryBreakdown, Simulator, TrainMeasurement, PROFILE_COST_S};
pub use spec::DeviceSpec;
