//! Device specifications for the simulated GPUs.
//!
//! The paper's testbeds are the NVIDIA Jetson TX2 (embedded, unified
//! memory) and an RTX 2080Ti (server, discrete memory, Sec. 6.2.1); we add
//! the Xavier mentioned in the introduction. Numbers are the public
//! datasheet values; framework constants approximate PyTorch 1.6 + CUDA
//! 10.2 + cuDNN 8.0 process footprints on those systems.

/// Static description of a target device + framework combination.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// CUDA core count.
    pub cores: usize,
    /// Streaming multiprocessors.
    pub sms: usize,
    /// Core clock, GHz.
    pub clock_ghz: f64,
    /// DRAM bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Total DRAM, MB.
    pub dram_mb: f64,
    /// Unified CPU+GPU memory (Jetson) vs discrete (server).
    pub unified: bool,
    /// Memory reported as used by an idle training process: CUDA context,
    /// framework, cuDNN handles; on unified devices also the OS/desktop
    /// share observed through /proc/meminfo.
    pub framework_base_train_mb: f64,
    /// Same, for an inference-only process.
    pub framework_base_infer_mb: f64,
    /// Kernel launch + driver overhead per launched op, microseconds.
    pub launch_overhead_us: f64,
    /// Fixed per-iteration framework overhead (python dispatch, optimizer
    /// bookkeeping), milliseconds.
    pub step_overhead_ms: f64,
    /// cuDNN workspace cap, MB (PyTorch leaves this to cuDNN defaults).
    pub workspace_cap_mb: f64,
    /// Fraction of peak DRAM bandwidth sustained by well-formed kernels.
    pub bw_efficiency: f64,
}

impl DeviceSpec {
    /// Peak fp32 throughput in GFLOP/s (2 flops per FMA per core per clock).
    pub fn peak_gflops(&self) -> f64 {
        self.cores as f64 * self.clock_ghz * 2.0
    }

    /// NVIDIA Jetson TX2: 256 Pascal cores, 8 GB unified LPDDR4.
    pub fn tx2() -> Self {
        DeviceSpec {
            name: "jetson-tx2",
            cores: 256,
            sms: 2,
            clock_ghz: 1.3,
            mem_bw_gbps: 59.7,
            dram_mb: 8192.0,
            unified: true,
            framework_base_train_mb: 1850.0,
            framework_base_infer_mb: 1500.0,
            launch_overhead_us: 45.0,
            step_overhead_ms: 6.0,
            workspace_cap_mb: 512.0,
            bw_efficiency: 0.68,
        }
    }

    /// NVIDIA Jetson Xavier AGX: 512 Volta cores, 16 GB unified.
    pub fn xavier() -> Self {
        DeviceSpec {
            name: "jetson-xavier",
            cores: 512,
            sms: 8,
            clock_ghz: 1.377,
            mem_bw_gbps: 137.0,
            dram_mb: 16384.0,
            unified: true,
            framework_base_train_mb: 2050.0,
            framework_base_infer_mb: 1650.0,
            launch_overhead_us: 25.0,
            step_overhead_ms: 4.0,
            workspace_cap_mb: 1024.0,
            bw_efficiency: 0.72,
        }
    }

    /// NVIDIA RTX 2080Ti: 4352 Turing cores, 11 GB GDDR6 (discrete).
    /// Used for the DNNMem comparison (Sec. 6.2.1): Γ here counts only GPU
    /// memory (pynvml), so no CPU-side terms.
    pub fn rtx2080ti() -> Self {
        DeviceSpec {
            name: "rtx-2080ti",
            cores: 4352,
            sms: 68,
            clock_ghz: 1.545,
            mem_bw_gbps: 616.0,
            dram_mb: 11264.0,
            unified: false,
            framework_base_train_mb: 980.0,
            framework_base_infer_mb: 780.0,
            launch_overhead_us: 6.0,
            step_overhead_ms: 1.5,
            workspace_cap_mb: 2048.0,
            bw_efficiency: 0.78,
        }
    }

    /// Look up a preset by name.
    pub fn by_name(name: &str) -> Option<Self> {
        Some(match name {
            "tx2" | "jetson-tx2" => Self::tx2(),
            "xavier" | "jetson-xavier" => Self::xavier(),
            "2080ti" | "rtx-2080ti" => Self::rtx2080ti(),
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tx2_peak_flops() {
        // 256 * 1.3 * 2 = 665.6 GFLOP/s
        assert!((DeviceSpec::tx2().peak_gflops() - 665.6).abs() < 0.1);
    }

    #[test]
    fn server_gpu_much_faster_than_edge() {
        let tx2 = DeviceSpec::tx2();
        let ti = DeviceSpec::rtx2080ti();
        assert!(ti.peak_gflops() > 15.0 * tx2.peak_gflops());
        assert!(ti.mem_bw_gbps > 8.0 * tx2.mem_bw_gbps);
        assert!(!ti.unified && tx2.unified);
    }

    #[test]
    fn presets_by_name() {
        assert!(DeviceSpec::by_name("tx2").is_some());
        assert!(DeviceSpec::by_name("2080ti").is_some());
        assert!(DeviceSpec::by_name("xavier").is_some());
        assert!(DeviceSpec::by_name("a100").is_none());
    }
}
