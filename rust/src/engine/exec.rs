//! Branch-free blocked forest inference — [`BlockedForest`] and the fused
//! Γ/Φ [`CompiledForestPair`].
//!
//! The PR 2 slab walker ([`CompiledForest`](crate::engine::CompiledForest))
//! already batches rows through cache-resident trees, but every node visit
//! still takes a data-dependent branch (`if row[f] <= t { left } else
//! { right }`) and chases two independent child pointers. Split decisions
//! in a fitted forest are close to coin flips, so on deep trees the walker
//! spends most of its time in branch-miss stalls. This module rebuilds
//! batched inference around three ideas:
//!
//! 1. **Depth-interleaved tree blocks.** Trees are grouped into blocks of
//!    [`TREE_BLOCK`] lanes; within a block, nodes are laid out level by
//!    level (all lanes' roots, then all lanes' depth-1 nodes, …) and the
//!    two children of every internal node occupy *adjacent* slab slots.
//!    Each node therefore stores a single `first_child` index; a whole
//!    block level is one contiguous, prefetchable run.
//! 2. **Arithmetic child select.** The traversal step is
//!    `idx = first_child[idx] + !(row[f] <= threshold[idx]) as u32` — a
//!    compare + setcc + add, no conditional control flow. Leaves carry
//!    `threshold = +∞` and `first_child = self`, so a cursor that reaches
//!    a leaf early self-loops for the tree's remaining levels; every lane
//!    runs a *fixed* per-tree step count (its depth), which is what makes
//!    the select branch-free in the first place.
//! 3. **(row tile × tree block) tiling.** Evaluation walks [`ROW_TILE`]
//!    rows at a time against each block: the tile's features
//!    (32 × 57 × 8 B ≈ 14 KB) and the block's current level stay
//!    L1-resident across the whole pass. Tiles fan out over scoped
//!    threads; per-thread cursor scratch ([`ExecScratch`]) is reused, so
//!    the steady state allocates nothing (matching the PR 5/7 discipline).
//!
//! [`CompiledForestPair`] fuses the engine's two inference models: Γ and Φ
//! are always predicted over the *same* feature rows, so the pair
//! evaluates both forests tile by tile — one memory walk over the features
//! serves two models.
//!
//! **Determinism contract.** Per row, leaf values accumulate in tree order
//! (block by block, lane by lane) followed by one divide — exactly the
//! scalar `Forest::predict` sequence — so every path here is
//! **bit-identical** to the scalar reference. Rows containing NaN features
//! (which a fixed step count cannot traverse meaningfully) are detected up
//! front and answered by a reference-semantics walk over the same blocked
//! layout, preserving bit-identity for them too. The oracle suite is
//! `rust/tests/predict_equivalence.rs`.

use crate::forest::{Forest, Tree, TreeNode};

/// Trees per block — the lane dimension of the depth-interleaved slabs.
pub const TREE_BLOCK: usize = 8;

/// Rows per tile: 32 rows of 57 features ≈ 14 KB of f64s, comfortably
/// L1-resident alongside one block level.
pub const ROW_TILE: usize = 32;

/// Below this many tiles per worker, thread spawn overhead beats the win.
const MIN_TILES_PER_WORKER: usize = 4;

/// Per-block metadata: where its depth-interleaved nodes start and how
/// many fixed traversal steps each lane (tree) runs.
#[derive(Clone, Debug)]
struct BlockMeta {
    /// Slab index of the block's level-0 region; lane `l`'s root sits at
    /// `node_start + l`.
    node_start: u32,
    /// Trees in this block (≤ [`TREE_BLOCK`]).
    lanes: u32,
    /// Fixed step count per lane — the tree's edge depth. Cursors of
    /// shallower lanes self-loop at their leaves.
    steps: [u32; TREE_BLOCK],
    /// `max(steps)` — the block's level count.
    max_steps: u32,
}

/// A fitted forest compiled to the branch-free blocked layout (see module
/// docs). Produced by [`BlockedForest::compile`] or
/// [`Forest::compile_blocked`].
#[derive(Clone, Debug)]
pub struct BlockedForest {
    n_features: usize,
    n_trees: usize,
    blocks: Vec<BlockMeta>,
    /// Split feature per node (0 at leaves — never decides anything there
    /// because the leaf threshold is +∞).
    feature: Vec<u32>,
    /// Split threshold; `+∞` at leaves keeps the arithmetic select on the
    /// self-loop.
    threshold: Vec<f64>,
    /// Slab index of the left child; the right child is `first_child + 1`.
    /// Self-referential at leaves.
    first_child: Vec<u32>,
    /// Leaf value (also stored for internal nodes, never read there).
    value: Vec<f64>,
}

/// Reusable cursor scratch for the tiled traversal: one `u32` cursor per
/// (lane, tile row). Hand one to [`BlockedForest::predict_into`] /
/// [`CompiledForestPair::predict_into`] and the steady state allocates
/// nothing.
#[derive(Debug)]
pub struct ExecScratch {
    cur: Vec<u32>,
}

impl ExecScratch {
    pub fn new() -> ExecScratch {
        ExecScratch {
            cur: vec![0; TREE_BLOCK * ROW_TILE],
        }
    }
}

impl Default for ExecScratch {
    fn default() -> Self {
        ExecScratch::new()
    }
}

impl BlockedForest {
    /// Compile a fitted forest into the depth-interleaved blocked layout.
    pub fn compile(forest: &Forest) -> BlockedForest {
        let total: usize = forest.trees.iter().map(|t| t.nodes.len()).sum();
        let mut bf = BlockedForest {
            n_features: forest.n_features,
            n_trees: forest.trees.len(),
            blocks: Vec::with_capacity(forest.trees.len().div_ceil(TREE_BLOCK)),
            feature: Vec::with_capacity(total),
            threshold: Vec::with_capacity(total),
            first_child: Vec::with_capacity(total),
            value: Vec::with_capacity(total),
        };
        for chunk in forest.trees.chunks(TREE_BLOCK) {
            bf.build_block(chunk);
        }
        bf
    }

    pub fn n_trees(&self) -> usize {
        self.n_trees
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Total nodes across all blocks (equals the source forest's total).
    pub fn n_nodes(&self) -> usize {
        self.value.len()
    }

    /// Append one node to the slabs; internal nodes get their
    /// `first_child` patched when their children are emitted one level
    /// down.
    fn push_node(&mut self, n: &TreeNode) -> u32 {
        let slab = self.feature.len() as u32;
        if n.is_leaf() {
            self.feature.push(0);
            self.threshold.push(f64::INFINITY);
        } else {
            self.feature.push(n.feature);
            self.threshold.push(n.threshold);
        }
        self.first_child.push(slab);
        self.value.push(n.value);
        slab
    }

    /// Emit one block: a breadth-first sweep over up to [`TREE_BLOCK`]
    /// trees at once, appending each level's nodes contiguously (lanes in
    /// tree order within the level) and keeping every child pair adjacent.
    fn build_block(&mut self, trees: &[Tree]) {
        let node_start = self.feature.len() as u32;
        // Nodes of the level being expanded: (lane, tree node, slab slot).
        let mut level: Vec<(usize, u32, u32)> = Vec::new();
        for (l, t) in trees.iter().enumerate() {
            let slab = self.push_node(&t.nodes[0]);
            level.push((l, 0, slab));
        }
        let mut steps = [0u32; TREE_BLOCK];
        let mut depth = 0u32;
        let mut next: Vec<(usize, u32, u32)> = Vec::new();
        while !level.is_empty() {
            next.clear();
            depth += 1;
            for &(l, ni, slab) in &level {
                let node = trees[l].nodes[ni as usize];
                if node.is_leaf() {
                    continue;
                }
                let first = self.push_node(&trees[l].nodes[node.left as usize]);
                self.push_node(&trees[l].nodes[node.right as usize]);
                self.first_child[slab as usize] = first;
                steps[l] = depth;
                next.push((l, node.left, first));
                next.push((l, node.right, first + 1));
            }
            std::mem::swap(&mut level, &mut next);
        }
        let max_steps = steps[..trees.len()].iter().copied().max().unwrap_or(0);
        self.blocks.push(BlockMeta {
            node_start,
            lanes: trees.len() as u32,
            steps,
            max_steps,
        });
    }

    fn check_batch(&self, flat: &[f64]) -> usize {
        assert_eq!(
            flat.len() % self.n_features,
            0,
            "flat row buffer length must be a multiple of n_features"
        );
        flat.len() / self.n_features
    }

    /// Predict many rows (row-major nested form) — bit-identical to
    /// per-row `Forest::predict`. Thin flattening adapter over
    /// [`BlockedForest::predict_rows_flat`].
    pub fn predict_rows(&self, rows: &[Vec<f64>]) -> Vec<f64> {
        let mut flat = Vec::with_capacity(rows.len() * self.n_features);
        for row in rows {
            debug_assert_eq!(row.len(), self.n_features);
            flat.extend_from_slice(row);
        }
        self.predict_rows_flat(&flat)
    }

    /// Predict a flat row-major buffer (`n_features` columns per row).
    pub fn predict_rows_flat(&self, flat: &[f64]) -> Vec<f64> {
        let n = self.check_batch(flat);
        let mut out = vec![0.0f64; n];
        self.predict_into(flat, &mut ExecScratch::new(), &mut out);
        out
    }

    /// Predict into a caller-owned output slice with caller-owned scratch:
    /// the zero-steady-state-allocation entry the engine drives. Batches
    /// large enough to amortize thread spawns fan tiles out over scoped
    /// threads (each worker brings its own scratch); smaller batches run
    /// serially on `scratch`.
    pub fn predict_into(&self, flat: &[f64], scratch: &mut ExecScratch, out: &mut [f64]) {
        let n = self.check_batch(flat);
        assert_eq!(out.len(), n, "output length must match the row count");
        if n == 0 {
            return;
        }
        if flat.iter().any(|v| v.is_nan()) {
            // A fixed step count cannot traverse NaN comparisons; fall
            // back to the reference-semantics walk (still bit-identical
            // to scalar `Forest::predict`, where NaN always goes right).
            self.predict_ref_into(flat, out);
            return;
        }
        let tiles = n.div_ceil(ROW_TILE);
        let workers = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(tiles / MIN_TILES_PER_WORKER)
            .max(1);
        if workers == 1 {
            self.eval_tiles(flat, scratch, out);
            return;
        }
        let chunk_rows = tiles.div_ceil(workers) * ROW_TILE;
        std::thread::scope(|scope| {
            for (rows, outs) in flat
                .chunks(chunk_rows * self.n_features)
                .zip(out.chunks_mut(chunk_rows))
            {
                scope.spawn(move || self.eval_tiles(rows, &mut ExecScratch::new(), outs));
            }
        });
    }

    /// Serial tile loop over one contiguous row range.
    fn eval_tiles(&self, flat: &[f64], scratch: &mut ExecScratch, out: &mut [f64]) {
        scratch.cur.resize(TREE_BLOCK * ROW_TILE, 0);
        for (tile, tile_out) in flat
            .chunks(ROW_TILE * self.n_features)
            .zip(out.chunks_mut(ROW_TILE))
        {
            self.eval_tile(tile, scratch, tile_out);
        }
    }

    /// One (row tile × every tree block) pass. The only data-dependent
    /// state is the cursor value itself: each level advances every
    /// (lane, row) cursor with the arithmetic child select, and finished
    /// lanes self-loop at their leaves. Accumulation is per row in tree
    /// order, then one divide — the scalar reference's exact sequence.
    fn eval_tile(&self, tile: &[f64], scratch: &mut ExecScratch, out: &mut [f64]) {
        let nf = self.n_features;
        let tn = out.len();
        debug_assert_eq!(tile.len(), tn * nf);
        debug_assert!(scratch.cur.len() >= TREE_BLOCK * ROW_TILE);
        out.fill(0.0);
        for block in &self.blocks {
            let lanes = block.lanes as usize;
            for l in 0..lanes {
                let root = block.node_start + l as u32;
                scratch.cur[l * ROW_TILE..l * ROW_TILE + tn].fill(root);
            }
            for step in 0..block.max_steps {
                for l in 0..lanes {
                    if block.steps[l] <= step {
                        continue;
                    }
                    let cur = &mut scratch.cur[l * ROW_TILE..l * ROW_TILE + tn];
                    for (r, c) in cur.iter_mut().enumerate() {
                        let idx = *c as usize;
                        let f = self.feature[idx] as usize;
                        let go_right = !(tile[r * nf + f] <= self.threshold[idx]) as u32;
                        *c = self.first_child[idx] + go_right;
                    }
                }
            }
            for (r, acc) in out.iter_mut().enumerate() {
                for l in 0..lanes {
                    *acc += self.value[scratch.cur[l * ROW_TILE + r] as usize];
                }
            }
        }
        let nt = self.n_trees as f64;
        for acc in out.iter_mut() {
            *acc /= nt;
        }
    }

    /// Reference-semantics traversal over the blocked layout (explicit
    /// leaf test, no fixed step count) for batches containing NaN
    /// features. NaN comparisons are false, so NaN rows fall to the right
    /// child at every split — exactly `Forest::predict`.
    fn predict_ref_into(&self, flat: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        for block in &self.blocks {
            for l in 0..block.lanes as usize {
                let root = (block.node_start + l as u32) as usize;
                for (row, acc) in flat.chunks_exact(self.n_features).zip(out.iter_mut()) {
                    let mut idx = root;
                    loop {
                        let first = self.first_child[idx] as usize;
                        if first == idx {
                            break;
                        }
                        let f = self.feature[idx] as usize;
                        idx = first + !(row[f] <= self.threshold[idx]) as usize;
                    }
                    *acc += self.value[idx];
                }
            }
        }
        let nt = self.n_trees as f64;
        for acc in out.iter_mut() {
            *acc /= nt;
        }
    }
}

/// Two forests over the same feature rows, evaluated in one fused tiled
/// pass: the engine's (γ, φ) inference models always see identical rows,
/// so fusing them halves the feature-memory traffic (see module docs).
#[derive(Clone, Debug)]
pub struct CompiledForestPair {
    gamma: BlockedForest,
    phi: BlockedForest,
}

impl CompiledForestPair {
    /// Compile both forests into blocked form. They must consume the same
    /// feature layout.
    pub fn compile(gamma: &Forest, phi: &Forest) -> CompiledForestPair {
        assert_eq!(
            gamma.n_features, phi.n_features,
            "paired forests must consume the same feature rows"
        );
        CompiledForestPair {
            gamma: BlockedForest::compile(gamma),
            phi: BlockedForest::compile(phi),
        }
    }

    pub fn gamma(&self) -> &BlockedForest {
        &self.gamma
    }

    pub fn phi(&self) -> &BlockedForest {
        &self.phi
    }

    pub fn n_features(&self) -> usize {
        self.gamma.n_features
    }

    /// Fused prediction of both targets over nested rows — returns
    /// `(gamma, phi)`, each bit-identical to its forest's scalar path.
    pub fn predict_rows(&self, rows: &[Vec<f64>]) -> (Vec<f64>, Vec<f64>) {
        let mut flat = Vec::with_capacity(rows.len() * self.gamma.n_features);
        for row in rows {
            debug_assert_eq!(row.len(), self.gamma.n_features);
            flat.extend_from_slice(row);
        }
        self.predict_rows_flat(&flat)
    }

    /// Fused prediction over a flat row-major buffer.
    pub fn predict_rows_flat(&self, flat: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let n = self.gamma.check_batch(flat);
        let mut out_gamma = vec![0.0f64; n];
        let mut out_phi = vec![0.0f64; n];
        self.predict_into(flat, &mut ExecScratch::new(), &mut out_gamma, &mut out_phi);
        (out_gamma, out_phi)
    }

    /// Fused prediction into caller-owned outputs with caller-owned
    /// scratch — both forests walk each row tile while it is hot, one
    /// memory pass over the features instead of two.
    pub fn predict_into(
        &self,
        flat: &[f64],
        scratch: &mut ExecScratch,
        out_gamma: &mut [f64],
        out_phi: &mut [f64],
    ) {
        let n = self.gamma.check_batch(flat);
        assert_eq!(out_gamma.len(), n, "gamma output length must match the row count");
        assert_eq!(out_phi.len(), n, "phi output length must match the row count");
        if n == 0 {
            return;
        }
        if flat.iter().any(|v| v.is_nan()) {
            self.gamma.predict_ref_into(flat, out_gamma);
            self.phi.predict_ref_into(flat, out_phi);
            return;
        }
        let tiles = n.div_ceil(ROW_TILE);
        let workers = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(tiles / MIN_TILES_PER_WORKER)
            .max(1);
        if workers == 1 {
            self.eval_tiles_pair(flat, scratch, out_gamma, out_phi);
            return;
        }
        let chunk_rows = tiles.div_ceil(workers) * ROW_TILE;
        std::thread::scope(|scope| {
            for ((rows, g), p) in flat
                .chunks(chunk_rows * self.gamma.n_features)
                .zip(out_gamma.chunks_mut(chunk_rows))
                .zip(out_phi.chunks_mut(chunk_rows))
            {
                scope.spawn(move || self.eval_tiles_pair(rows, &mut ExecScratch::new(), g, p));
            }
        });
    }

    fn eval_tiles_pair(
        &self,
        flat: &[f64],
        scratch: &mut ExecScratch,
        out_gamma: &mut [f64],
        out_phi: &mut [f64],
    ) {
        scratch.cur.resize(TREE_BLOCK * ROW_TILE, 0);
        let nf = self.gamma.n_features;
        for ((tile, g), p) in flat
            .chunks(ROW_TILE * nf)
            .zip(out_gamma.chunks_mut(ROW_TILE))
            .zip(out_phi.chunks_mut(ROW_TILE))
        {
            self.gamma.eval_tile(tile, scratch, g);
            self.phi.eval_tile(tile, scratch, p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CompiledForest;
    use crate::forest::ForestConfig;
    use crate::util::rng::Pcg64;

    fn synth(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.uniform(0.0, 10.0);
            let b = rng.next_f64();
            let c = rng.uniform(0.0, 2.0);
            x.push(vec![a, b, c]);
            y.push(2.0 * a + if b > 0.5 { 10.0 } else { 0.0 } + c * a);
        }
        (x, y)
    }

    fn assert_bits(a: &[f64], b: &[f64], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: row {i} diverges ({x} vs {y})");
        }
    }

    #[test]
    fn layout_invariants_hold() {
        let (x, y) = synth(250, 41);
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig {
                n_trees: 13, // a ragged final block of 5 lanes
                ..Default::default()
            },
        )
        .unwrap();
        let b = BlockedForest::compile(&f);
        assert_eq!(b.n_trees(), 13);
        assert_eq!(b.n_nodes(), f.trees.iter().map(|t| t.nodes.len()).sum::<usize>());
        assert_eq!(b.blocks.len(), 2);
        assert_eq!(b.blocks[0].lanes, 8);
        assert_eq!(b.blocks[1].lanes, 5);
        for idx in 0..b.n_nodes() {
            let fc = b.first_child[idx] as usize;
            if fc == idx {
                // Leaf: self-loop with an always-left threshold.
                assert_eq!(b.threshold[idx], f64::INFINITY);
                assert_eq!(b.feature[idx], 0);
            } else {
                // Internal: contiguous child pair strictly below it.
                assert!(fc > idx, "child pair must be emitted after the parent");
                assert!(fc + 1 < b.n_nodes(), "child pair must fit in the slab");
            }
        }
    }

    #[test]
    fn blocked_bit_identical_to_scalar_and_walker() {
        let (x, y) = synth(300, 42);
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig {
                n_trees: 24,
                ..Default::default()
            },
        )
        .unwrap();
        let blocked = BlockedForest::compile(&f);
        let walker = CompiledForest::compile(&f);
        // Enough rows to force the multi-worker tiled path.
        let rows: Vec<Vec<f64>> = (0..700).map(|i| x[i % x.len()].clone()).collect();
        let scalar: Vec<f64> = rows.iter().map(|r| f.predict(r)).collect();
        assert_bits(&blocked.predict_rows(&rows), &scalar, "blocked vs scalar");
        assert_bits(&walker.predict_rows(&rows), &scalar, "walker vs scalar");
        // Degenerate tiles: single row, and a partial final tile.
        assert_bits(&blocked.predict_rows(&rows[..1]), &scalar[..1], "single row");
        assert_bits(&blocked.predict_rows(&rows[..33]), &scalar[..33], "partial tile");
    }

    #[test]
    fn fused_pair_matches_two_separate_walks() {
        let (x, y) = synth(220, 43);
        let y2: Vec<f64> = y.iter().map(|v| v * 3.0 + 1.0).collect();
        let cfg = ForestConfig {
            n_trees: 10,
            ..Default::default()
        };
        let fg = Forest::fit(&x, &y, &cfg).unwrap();
        let fp = Forest::fit(&x, &y2, &cfg).unwrap();
        let pair = CompiledForestPair::compile(&fg, &fp);
        let (g, p) = pair.predict_rows(&x);
        assert_bits(&g, &BlockedForest::compile(&fg).predict_rows(&x), "fused gamma");
        assert_bits(&p, &BlockedForest::compile(&fp).predict_rows(&x), "fused phi");
    }

    #[test]
    fn nan_rows_fall_back_to_reference_semantics() {
        let (x, y) = synth(120, 44);
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig {
                n_trees: 6,
                ..Default::default()
            },
        )
        .unwrap();
        let blocked = BlockedForest::compile(&f);
        let mut rows = x.clone();
        rows[3][1] = f64::NAN;
        rows[7] = vec![f64::NAN; 3];
        let scalar: Vec<f64> = rows.iter().map(|r| f.predict(r)).collect();
        assert_bits(&blocked.predict_rows(&rows), &scalar, "NaN fallback");
    }

    #[test]
    fn single_leaf_trees_take_zero_steps() {
        let (x, y) = synth(60, 45);
        // max_depth 0 makes every tree a single root leaf (steps == 0).
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig {
                n_trees: 3,
                max_depth: 0,
                ..Default::default()
            },
        )
        .unwrap();
        let blocked = BlockedForest::compile(&f);
        assert_eq!(blocked.blocks[0].max_steps, 0);
        let scalar: Vec<f64> = x.iter().map(|r| f.predict(r)).collect();
        assert_bits(&blocked.predict_rows(&x), &scalar, "leaf-only forest");
    }

    #[test]
    fn empty_batch_is_fine() {
        let (x, y) = synth(50, 46);
        let f = Forest::fit(&x, &y, &ForestConfig::default()).unwrap();
        let blocked = BlockedForest::compile(&f);
        assert!(blocked.predict_rows(&[]).is_empty());
        let pair = CompiledForestPair::compile(&f, &f);
        let (g, p) = pair.predict_rows_flat(&[]);
        assert!(g.is_empty() && p.is_empty());
    }
}
