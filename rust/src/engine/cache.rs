//! Topology-fingerprint memo cache for the [`PredictionEngine`]
//! (`crate::engine`): repeated ES candidates cost one hash lookup instead
//! of a graph build + plan compile + feature extraction + three forest
//! traversals.
//!
//! Keys are 64-bit FNV-1a fingerprints of the candidate's topology —
//! [`config_fingerprint`] for OFA [`SubnetConfig`]s, [`graph_fingerprint`]
//! for arbitrary (e.g. pruned) graphs. The invalidation rule is the same
//! as PR 1's plan rule, one level up: **prune ⇒ new topology ⇒ new
//! fingerprint ⇒ cache miss** — a mutated graph can never alias a cached
//! entry. Entries additionally store the full `SubnetConfig` and compare
//! it on lookup, so a (vanishingly unlikely) 64-bit collision degrades to
//! a miss, never to a wrong answer.

use std::collections::HashMap;

use crate::ir::Graph;
use crate::ofa::{CandidateEval, SubnetConfig};
use crate::util::fingerprint::{fnv_bytes, fnv_u64, FNV_OFFSET};

/// Fingerprint of an OFA sub-network configuration (its nine genes fully
/// determine the built graph's topology).
pub fn config_fingerprint(c: &SubnetConfig) -> u64 {
    let mut h = fnv_bytes(FNV_OFFSET, b"subnet/");
    for i in 0..4 {
        h = fnv_u64(h, c.depth[i] as u64);
        h = fnv_u64(h, c.expand[i] as u64);
    }
    fnv_u64(h, c.width as u64)
}

/// Structural fingerprint of an arbitrary IR graph: every node's operator
/// (with all its parameters) and wiring, independent of node names.
/// Structured pruning rewrites conv filter counts, so a pruned graph never
/// shares a fingerprint with its parent.
///
/// `GraphArena::fingerprint` (the overlay fast path) computes this very
/// hash from (arena, overlay) without materializing the pruned graph —
/// any change here must be mirrored there (and is guarded by
/// `rust/tests/overlay_equivalence.rs`).
pub fn graph_fingerprint(g: &Graph) -> u64 {
    let mut h = fnv_bytes(FNV_OFFSET, b"graph/");
    h = fnv_u64(h, g.nodes.len() as u64);
    h = fnv_u64(h, g.output as u64);
    for n in &g.nodes {
        h = fnv_bytes(h, format!("{:?}", n.op).as_bytes());
        h = fnv_u64(h, n.inputs.len() as u64);
        for &i in &n.inputs {
            h = fnv_u64(h, i as u64);
        }
    }
    h
}

/// Cache counters. `hits + misses` equals the total attribute estimates
/// requested; `misses` counts the estimates that actually ran the batched
/// predictors (a batch-local duplicate of an in-flight miss is served from
/// the generation's own results and counted as a hit).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Entries discarded to stay within capacity (LRU order).
    pub evictions: u64,
    /// Live entries at sampling time.
    pub entries: u64,
}

impl CacheStats {
    pub fn requests(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of requests served without evaluation, in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        if self.requests() == 0 {
            0.0
        } else {
            self.hits as f64 / self.requests() as f64
        }
    }

    /// Counter deltas accumulated since `earlier` (a snapshot of the same
    /// cache); `entries` is reported as-is.
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            evictions: self.evictions - earlier.evictions,
            entries: self.entries,
        }
    }
}

struct Entry {
    /// Collision guard: compared on every lookup.
    config: SubnetConfig,
    eval: CandidateEval,
    /// The compiled plan's bs=32 training-feature row.
    f_train: Vec<f64>,
    /// The forward-masked bs=1 inference-feature row (shared by γ and φ).
    f_infer: Vec<f64>,
    last_used: u64,
}

/// Bounded LRU memo keyed by topology fingerprint.
pub struct FingerprintCache {
    capacity: usize,
    map: HashMap<u64, Entry>,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl FingerprintCache {
    /// `capacity == 0` disables the cache (every lookup misses, nothing is
    /// stored) — the reference configuration of the equivalence suite.
    pub fn new(capacity: usize) -> FingerprintCache {
        FingerprintCache {
            capacity,
            map: HashMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Look up a candidate; a hit refreshes its LRU stamp and bumps the hit
    /// counter. A miss counts nothing — the caller decides whether the
    /// candidate becomes an evaluation ([`FingerprintCache::note_misses`])
    /// or is served from the in-flight batch
    /// ([`FingerprintCache::note_batch_hits`]).
    pub fn get(&mut self, fp: u64, config: &SubnetConfig) -> Option<CandidateEval> {
        self.tick += 1;
        let tick = self.tick;
        match self.map.get_mut(&fp) {
            Some(e) if e.config == *config => {
                e.last_used = tick;
                self.hits += 1;
                Some(e.eval)
            }
            _ => None,
        }
    }

    /// Cached feature rows `(f_train, f_infer)` for a candidate, if present.
    pub fn rows(&self, fp: u64, config: &SubnetConfig) -> Option<(&[f64], &[f64])> {
        self.map
            .get(&fp)
            .filter(|e| e.config == *config)
            .map(|e| (e.f_train.as_slice(), e.f_infer.as_slice()))
    }

    /// Record `n` requests answered from the current generation's freshly
    /// computed results (batch-local duplicates).
    pub fn note_batch_hits(&mut self, n: u64) {
        self.hits += n;
    }

    /// Record `n` requests that ran the batched predictors.
    pub fn note_misses(&mut self, n: u64) {
        self.misses += n;
    }

    /// Insert (or refresh) an entry, evicting the least-recently-used one
    /// when at capacity. No-op when the cache is disabled.
    pub fn insert(
        &mut self,
        fp: u64,
        config: &SubnetConfig,
        eval: CandidateEval,
        f_train: Vec<f64>,
        f_infer: Vec<f64>,
    ) {
        if self.capacity == 0 {
            return;
        }
        self.tick += 1;
        if !self.map.contains_key(&fp) && self.map.len() >= self.capacity {
            // O(len) scan; `last_used` stamps are unique so the victim is
            // deterministic regardless of HashMap iteration order.
            if let Some(lru) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&k, _)| k)
            {
                self.map.remove(&lru);
                self.evictions += 1;
            }
        }
        self.map.insert(
            fp,
            Entry {
                config: *config,
                eval,
                f_train,
                f_infer,
                last_used: self.tick,
            },
        );
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            entries: self.map.len() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ofa::Attributes;
    use crate::util::rng::Pcg64;

    fn eval(v: f64) -> CandidateEval {
        CandidateEval {
            attrs: Attributes {
                gamma_train_mb: v,
                gamma_infer_mb: v,
                phi_infer_ms: v,
            },
            capacity: 0.5,
        }
    }

    #[test]
    fn hit_returns_inserted_eval() {
        let mut cache = FingerprintCache::new(4);
        let c = SubnetConfig::max();
        let fp = config_fingerprint(&c);
        assert!(cache.get(fp, &c).is_none());
        cache.insert(fp, &c, eval(7.0), vec![1.0], vec![2.0]);
        let got = cache.get(fp, &c).expect("hit");
        assert_eq!(got.attrs.gamma_train_mb, 7.0);
        assert_eq!(cache.rows(fp, &c).unwrap(), (&[1.0][..], &[2.0][..]));
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn lru_eviction_discards_oldest() {
        let mut cache = FingerprintCache::new(2);
        let (a, b, c) = (
            SubnetConfig::min(),
            SubnetConfig::max(),
            SubnetConfig {
                width: 1,
                ..SubnetConfig::min()
            },
        );
        cache.insert(config_fingerprint(&a), &a, eval(1.0), vec![], vec![]);
        cache.insert(config_fingerprint(&b), &b, eval(2.0), vec![], vec![]);
        // Touch `a` so `b` becomes the LRU victim.
        assert!(cache.get(config_fingerprint(&a), &a).is_some());
        cache.insert(config_fingerprint(&c), &c, eval(3.0), vec![], vec![]);
        assert_eq!(cache.stats().evictions, 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(config_fingerprint(&a), &a).is_some());
        assert!(cache.get(config_fingerprint(&b), &b).is_none());
        assert!(cache.get(config_fingerprint(&c), &c).is_some());
    }

    #[test]
    fn zero_capacity_disables_storage() {
        let mut cache = FingerprintCache::new(0);
        let c = SubnetConfig::max();
        let fp = config_fingerprint(&c);
        cache.insert(fp, &c, eval(1.0), vec![], vec![]);
        assert!(cache.get(fp, &c).is_none());
        assert!(cache.is_empty());
    }

    #[test]
    fn config_fingerprints_distinct_over_entire_space() {
        // Enumerate every legal SubnetConfig (60 × 81 × 3 = 14,580) and
        // assert zero fingerprint collisions.
        use crate::ofa::{BASE_DEPTHS, EXPAND_CHOICES, WIDTH_CHOICES};
        let mut seen = std::collections::HashSet::new();
        let mut count = 0usize;
        let depth_choices: Vec<Vec<usize>> = BASE_DEPTHS
            .iter()
            .map(|&max| (crate::ofa::supernet::MIN_DEPTH..=max).collect())
            .collect();
        for &d0 in &depth_choices[0] {
            for &d1 in &depth_choices[1] {
                for &d2 in &depth_choices[2] {
                    for &d3 in &depth_choices[3] {
                        for e0 in 0..EXPAND_CHOICES.len() {
                            for e1 in 0..EXPAND_CHOICES.len() {
                                for e2 in 0..EXPAND_CHOICES.len() {
                                    for e3 in 0..EXPAND_CHOICES.len() {
                                        for w in 0..WIDTH_CHOICES.len() {
                                            let c = SubnetConfig {
                                                depth: [d0, d1, d2, d3],
                                                expand: [e0, e1, e2, e3],
                                                width: w,
                                            };
                                            assert!(
                                                seen.insert(config_fingerprint(&c)),
                                                "collision at {c:?}"
                                            );
                                            count += 1;
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        assert_eq!(count, 14_580);
    }

    #[test]
    fn graph_fingerprint_changes_on_prune() {
        let g = crate::models::resnet18(1000);
        let fp = graph_fingerprint(&g);
        assert_eq!(fp, graph_fingerprint(&g), "fingerprint must be stable");
        let mut rng = Pcg64::new(9);
        let pruned = crate::pruning::prune(&g, crate::pruning::Strategy::L1Norm, 0.5, &mut rng);
        assert_ne!(fp, graph_fingerprint(&pruned), "prune must change the fingerprint");
    }
}
