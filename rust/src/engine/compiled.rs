//! [`CompiledForest`] — a fitted [`Forest`] flattened into contiguous
//! structure-of-arrays node slabs for batched inference.
//!
//! `Forest::predict` walks `Vec<Tree>` → `Vec<TreeNode>` pointer chains:
//! every node visit loads a 40-byte struct to read at most three fields,
//! and every row re-walks every tree from a cold cache. The compiled form
//! stores one slab per field (feature / threshold / left / right / value)
//! with absolute child indices, so a traversal touches only the bytes it
//! compares, and [`CompiledForest::predict_rows`] drives *many rows through
//! each tree in turn* — the tree's nodes stay cache-resident across the
//! whole row batch, and row chunks fan out over scoped threads.
//!
//! Accumulation order is the scalar reference's exactly (per row: tree 0,
//! tree 1, … then one divide), so batched results are **bit-identical** to
//! `Forest::predict` — asserted across zoo-trained models by
//! `rust/tests/engine_equivalence.rs` and `rust/tests/predict_equivalence.rs`.
//!
//! Since PR 9 the *hot* batched path is the branch-free blocked executor
//! ([`crate::engine::exec`]); this walker is retained as the branchy
//! mid-level reference (every node visit still takes a data-dependent
//! branch) and as the one producer of the padded [`ForestTensors`] layout.
//! Every entry point funnels into a single serial kernel
//! (`predict_into_flat`), so the reference cannot drift from itself.

use crate::forest::{Forest, ForestTensors};

/// A forest compiled to flat SoA slabs (see module docs).
#[derive(Clone, Debug)]
pub struct CompiledForest {
    n_features: usize,
    n_trees: usize,
    /// Maximum tree depth (fixed-shape traversal bound for the tensor export).
    depth: usize,
    /// Slab offset of each tree's root; `offsets[n_trees]` is the slab length.
    offsets: Vec<u32>,
    /// Split feature per node; `u32::MAX` marks a leaf.
    feature: Vec<u32>,
    threshold: Vec<f64>,
    /// Absolute child indices into the slab (self-referential at leaves).
    left: Vec<u32>,
    right: Vec<u32>,
    value: Vec<f64>,
}

/// Below this many rows per worker, thread spawn overhead beats the win.
const MIN_ROWS_PER_WORKER: usize = 16;

impl CompiledForest {
    /// Flatten a fitted forest into the slab layout.
    pub fn compile(forest: &Forest) -> CompiledForest {
        let total: usize = forest.trees.iter().map(|t| t.nodes.len()).sum();
        let mut offsets = Vec::with_capacity(forest.trees.len() + 1);
        let mut feature = Vec::with_capacity(total);
        let mut threshold = Vec::with_capacity(total);
        let mut left = Vec::with_capacity(total);
        let mut right = Vec::with_capacity(total);
        let mut value = Vec::with_capacity(total);
        let mut base = 0u32;
        for t in &forest.trees {
            offsets.push(base);
            for n in &t.nodes {
                feature.push(n.feature);
                threshold.push(n.threshold);
                left.push(base + n.left);
                right.push(base + n.right);
                value.push(n.value);
            }
            base += t.nodes.len() as u32;
        }
        offsets.push(base);
        CompiledForest {
            n_features: forest.n_features,
            n_trees: forest.trees.len(),
            depth: forest.trees.iter().map(|t| t.depth()).max().unwrap_or(1),
            offsets,
            feature,
            threshold,
            left,
            right,
            value,
        }
    }

    pub fn n_trees(&self) -> usize {
        self.n_trees
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Maximum tree depth.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Total nodes across all trees.
    pub fn n_nodes(&self) -> usize {
        self.value.len()
    }

    /// Node count of the largest tree (the padded tensor export's node
    /// dimension).
    pub fn max_tree_nodes(&self) -> usize {
        (0..self.n_trees)
            .map(|t| (self.offsets[t + 1] - self.offsets[t]) as usize)
            .max()
            .unwrap_or(1)
    }

    /// Predict one row — bit-identical to [`Forest::predict`]. A 1-row
    /// batch through the single serial kernel (`predict_into_flat`), so
    /// the scalar entry point shares the batched path's traversal exactly.
    pub fn predict_row(&self, row: &[f64]) -> f64 {
        debug_assert_eq!(row.len(), self.n_features);
        let mut out = [0.0f64];
        self.predict_into_flat(row, &mut out);
        out[0]
    }

    /// Predict many rows, traversing each tree once per row *batch* (the
    /// tree's slab stays hot across rows) and splitting the batch over
    /// scoped threads. Bit-identical to per-row [`Forest::predict`].
    ///
    /// Thin adapter over [`CompiledForest::predict_rows_flat`] — one copy
    /// into a flat row-major buffer, then the single shared dispatch, so
    /// the two entry points cannot drift.
    pub fn predict_rows(&self, rows: &[Vec<f64>]) -> Vec<f64> {
        let mut flat = Vec::with_capacity(rows.len() * self.n_features);
        for row in rows {
            debug_assert_eq!(row.len(), self.n_features);
            flat.extend_from_slice(row);
        }
        self.predict_rows_flat(&flat)
    }

    /// As [`CompiledForest::predict_rows`] over one flat row-major buffer
    /// (`n_features` columns per row) — the engine's zero-allocation miss
    /// path accumulates candidate rows into one reusable `Vec<f64>` and
    /// predicts them all here without materializing per-row `Vec`s. This
    /// is the one batched dispatch (worker split + serial kernel);
    /// accumulation order matches the scalar walk, so results are
    /// bit-identical to per-row [`Forest::predict`].
    pub fn predict_rows_flat(&self, flat: &[f64]) -> Vec<f64> {
        assert_eq!(
            flat.len() % self.n_features,
            0,
            "flat row buffer length must be a multiple of n_features"
        );
        let n = flat.len() / self.n_features;
        if n == 0 {
            return Vec::new();
        }
        let mut out = vec![0.0f64; n];
        let workers = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(n / MIN_ROWS_PER_WORKER)
            .max(1);
        if workers == 1 {
            self.predict_into_flat(flat, &mut out);
            return out;
        }
        let chunk = (n + workers - 1) / workers;
        std::thread::scope(|scope| {
            for (row_chunk, out_chunk) in flat
                .chunks(chunk * self.n_features)
                .zip(out.chunks_mut(chunk))
            {
                scope.spawn(move || self.predict_into_flat(row_chunk, out_chunk));
            }
        });
        out
    }

    /// Serial batched kernel over a flat row-major buffer: trees outer,
    /// rows inner (see module docs).
    fn predict_into_flat(&self, flat: &[f64], out: &mut [f64]) {
        debug_assert_eq!(flat.len(), out.len() * self.n_features);
        for t in 0..self.n_trees {
            let root = self.offsets[t] as usize;
            for (row, acc) in flat.chunks_exact(self.n_features).zip(out.iter_mut()) {
                *acc += self.traverse(root, row);
            }
        }
        let nt = self.n_trees as f64;
        for acc in out.iter_mut() {
            *acc /= nt;
        }
    }

    #[inline]
    fn traverse(&self, root: usize, row: &[f64]) -> f64 {
        debug_assert_eq!(row.len(), self.n_features);
        let mut idx = root;
        loop {
            let f = self.feature[idx];
            if f == u32::MAX {
                return self.value[idx];
            }
            idx = if row[f as usize] <= self.threshold[idx] {
                self.left[idx] as usize
            } else {
                self.right[idx] as usize
            };
        }
    }

    /// Export to the fixed-shape padded tensors the L1 Pallas / XLA kernel
    /// consumes — same slabs, node dimension padded to the largest tree
    /// with self-looping leaves. This is the one producer of
    /// [`ForestTensors`]; `Forest::to_tensors` delegates here, so the
    /// native batched path and the artifact path share one layout.
    pub fn to_tensors(&self) -> ForestTensors {
        let nt = self.n_trees;
        let tree_nodes = |t: usize| (self.offsets[t + 1] - self.offsets[t]) as usize;
        let max_nodes = self.max_tree_nodes();
        let mut feature = vec![0i32; nt * max_nodes];
        let mut threshold = vec![f32::INFINITY; nt * max_nodes];
        let mut left = vec![0i32; nt * max_nodes];
        let mut right = vec![0i32; nt * max_nodes];
        let mut value = vec![0f32; nt * max_nodes];
        for t in 0..nt {
            let base = self.offsets[t] as usize;
            for ni in 0..tree_nodes(t) {
                let i = t * max_nodes + ni;
                let s = base + ni;
                if self.feature[s] == u32::MAX {
                    // Leaf: self-loop so extra fixed-depth iterations are no-ops.
                    left[i] = ni as i32;
                    right[i] = ni as i32;
                } else {
                    feature[i] = self.feature[s] as i32;
                    threshold[i] = self.threshold[s] as f32;
                    left[i] = (self.left[s] as usize - base) as i32;
                    right[i] = (self.right[s] as usize - base) as i32;
                }
                value[i] = self.value[s] as f32;
            }
            // Padding nodes: self-looping zero-value leaves (never reached).
            for ni in tree_nodes(t)..max_nodes {
                let i = t * max_nodes + ni;
                left[i] = ni as i32;
                right[i] = ni as i32;
            }
        }
        ForestTensors {
            n_trees: nt,
            n_nodes: max_nodes,
            depth: self.depth,
            feature,
            threshold,
            left,
            right,
            value,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forest::ForestConfig;
    use crate::util::rng::Pcg64;

    fn synth(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.uniform(0.0, 10.0);
            let b = rng.next_f64();
            let c = rng.uniform(0.0, 2.0);
            x.push(vec![a, b, c]);
            y.push(2.0 * a + if b > 0.5 { 10.0 } else { 0.0 } + c * a);
        }
        (x, y)
    }

    #[test]
    fn batched_rows_bit_identical_to_scalar() {
        let (x, y) = synth(300, 11);
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig {
                n_trees: 24,
                ..Default::default()
            },
        )
        .unwrap();
        let c = CompiledForest::compile(&f);
        let batched = c.predict_rows(&x);
        assert_eq!(batched.len(), x.len());
        for (row, &b) in x.iter().zip(&batched) {
            let scalar = f.predict(row);
            assert_eq!(scalar.to_bits(), b.to_bits(), "row diverges");
            assert_eq!(c.predict_row(row).to_bits(), scalar.to_bits());
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let (x, y) = synth(50, 12);
        let c = CompiledForest::compile(&Forest::fit(&x, &y, &ForestConfig::default()).unwrap());
        assert!(c.predict_rows(&[]).is_empty());
        assert!(c.predict_rows_flat(&[]).is_empty());
    }

    #[test]
    fn flat_rows_bit_identical_to_nested() {
        let (x, y) = synth(300, 15);
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig {
                n_trees: 16,
                ..Default::default()
            },
        )
        .unwrap();
        let c = CompiledForest::compile(&f);
        // Enough rows to force the multi-worker path in both variants.
        let rows: Vec<Vec<f64>> = (0..600).map(|i| x[i % x.len()].clone()).collect();
        let flat: Vec<f64> = rows.iter().flatten().copied().collect();
        let a = c.predict_rows(&rows);
        let b = c.predict_rows_flat(&flat);
        assert_eq!(a.len(), b.len());
        for (&ai, &bi) in a.iter().zip(&b) {
            assert_eq!(ai.to_bits(), bi.to_bits());
        }
    }

    #[test]
    fn large_batch_spans_threads() {
        let (x, y) = synth(200, 13);
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig {
                n_trees: 10,
                ..Default::default()
            },
        )
        .unwrap();
        let c = CompiledForest::compile(&f);
        // 1000 rows forces the multi-worker path on any multicore box.
        let rows: Vec<Vec<f64>> = (0..1000).map(|i| x[i % x.len()].clone()).collect();
        let batched = c.predict_rows(&rows);
        for (row, &b) in rows.iter().zip(&batched) {
            assert_eq!(f.predict(row).to_bits(), b.to_bits());
        }
    }

    #[test]
    fn tensor_export_round_trips_through_compiled_layout() {
        let (x, y) = synth(150, 14);
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig {
                n_trees: 8,
                max_depth: 9,
                ..Default::default()
            },
        )
        .unwrap();
        let t = CompiledForest::compile(&f).to_tensors();
        for row in x.iter().take(25) {
            let a = f.predict(row);
            let b = t.predict(row, t.depth);
            assert!((a - b).abs() / a.abs().max(1.0) < 1e-5, "{a} vs {b}");
        }
    }
}
