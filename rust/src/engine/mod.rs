//! The PredictionEngine — batched, cache-aware serving of the paper's
//! three attribute models (Γ training memory, γ inference memory, φ
//! inference latency).
//!
//! The ES of Sec. 6.4 needs ≥50,000 (Γ, γ, φ) estimates; the paper's whole
//! point is that forest inference makes each estimate cheap enough to
//! replace 20 s/sample on-device profiling. This subsystem turns the
//! remaining per-candidate cost into a query service with three pillars:
//!
//! 1. [`BlockedForest`] — trees compiled into the branch-free blocked
//!    executor ([`exec`]): depth-interleaved node slabs per tree block, an
//!    arithmetic child select instead of a per-node branch, and
//!    (row tile × tree block) evaluation passes. The engine's two
//!    inference models are fused into one [`CompiledForestPair`] so Γ and
//!    Φ share a single memory walk over each feature tile. Every path is
//!    bit-identical to the scalar `Forest::predict` reference
//!    (`rust/tests/predict_equivalence.rs`); the PR 2 slab walker
//!    ([`CompiledForest`]) is retained as the branchy reference and the
//!    [`ForestTensors`](crate::forest::ForestTensors) producer.
//! 2. [`FingerprintCache`] — a memo keyed by topology fingerprint: a
//!    repeated ES candidate costs one hash lookup instead of graph build +
//!    plan compile + feature extraction + three forest traversals.
//!    Invalidation follows PR 1's plan rule: prune ⇒ new fingerprint ⇒
//!    miss.
//! 3. Generation-batched evaluation — [`ofa::evolution`](crate::ofa) hands
//!    the engine a whole generation of candidates at once; the uncached
//!    ones are answered in exactly **two** blocked passes (Γ-train plus
//!    the fused γ/φ walk).
//!
//! Since PR 5 the *miss path* is zero-allocation too: candidates are
//! evaluated through per-depth-key [`GraphArena`]s + `PruneOverlay`s with
//! incremental plan rebuilds and flat feature-row scratch (see
//! [`crate::ir::arena`]) — a unique candidate never builds a `Graph`,
//! never runs full shape inference from scratch, and never allocates a
//! feature row. Invalidation is unchanged: prune ⇒ new overlay ⇒ new
//! fingerprint ⇒ miss.
//!
//! Since PR 6 the engine is **shareable**: an engine value is a handle
//! onto an `Arc`-shared core (the three compiled forests plus an
//! interior-mutable fingerprint cache behind a `Mutex`), with only the
//! evaluation scratch private to the handle. [`PredictionEngine::fork`]
//! yields further handles onto the same cache — the substrate of the
//! multi-tenant serving layer in [`crate::serve`], which coalesces queries
//! from many concurrent clients into the same generation-batched calls.
//! One `evaluate_generation` is a single cache transaction (the lock is
//! held across lookup, evaluation and insert), so counters stay exact
//! under concurrency: `hits + misses` always equals the queries submitted.

pub mod cache;
pub mod compiled;
pub mod exec;

pub use cache::{config_fingerprint, graph_fingerprint, CacheStats, FingerprintCache};
pub use compiled::CompiledForest;
pub use exec::{BlockedForest, CompiledForestPair, ExecScratch};

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::features::{forward_mask_in_place, network_features_into, NUM_FEATURES};
use crate::forest::Forest;
use crate::ir::{GraphArena, PlanBuffers, PlanView, PruneOverlay};
use crate::ofa::{capacity_from_convs, Attributes, CandidateEval, GenerationOracle, SubnetConfig};

/// Γ is estimated at the paper's retraining batch size (Sec. 6.4).
pub const TRAIN_BS: usize = 32;

/// Default memo capacity — comfortably above the 14,580 distinct
/// `SubnetConfig`s, so paper-scale searches never evict.
pub const DEFAULT_CACHE_CAPACITY: usize = 32_768;

/// How one query of a generation was answered — the provenance the
/// serving layer needs to attribute hits and misses to individual
/// tenants (the cache's own counters aggregate over every handle).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueryOutcome {
    /// Answered from the shared fingerprint memo.
    CacheHit,
    /// Duplicate of an in-flight miss in the same coalesced generation
    /// (possibly submitted by a *different* tenant) — served from the
    /// fresh results without a second evaluation.
    BatchHit,
    /// Ran the batched predictors (a cache miss).
    Evaluated,
}

/// Reusable per-handle evaluation state for the zero-allocation miss
/// path: one compiled [`GraphArena`] per OFA depth key (only the four
/// depth genes change the graph *structure*; expand/width genes are pure
/// conv-width overlays), a rebindable [`PruneOverlay`], incremental
/// [`PlanBuffers`], and flat feature-row scratch. After the arenas for
/// the depths in play exist (at most 60), evaluating a unique candidate
/// performs no graph build, no full shape inference and no per-row heap
/// allocation.
#[derive(Default)]
struct EvalScratch {
    arenas: HashMap<[usize; 4], GraphArena>,
    overlay: Option<PruneOverlay>,
    buffers: PlanBuffers,
    /// One-row scratch (bs=32 then masked bs=1 rows are staged here).
    row: Vec<f64>,
    /// Flat row-major batches handed to the blocked executors.
    train_flat: Vec<f64>,
    infer_flat: Vec<f64>,
    /// Cursor scratch for the branch-free tiled traversal.
    exec: ExecScratch,
    /// Prediction outputs (resized per generation, reused across calls).
    out_gamma_t: Vec<f64>,
    out_gamma_i: Vec<f64>,
    out_phi_i: Vec<f64>,
}

/// The `Send + Sync` core every engine handle shares: the three compiled
/// attribute models (immutable after construction) and the fingerprint
/// memo behind its lock. Γ-train is its own blocked executor; the γ/φ
/// inference models — always predicted over the same masked rows — are
/// fused into one [`CompiledForestPair`].
struct EngineShared {
    gamma_train: BlockedForest,
    infer_pair: CompiledForestPair,
    cache: Mutex<FingerprintCache>,
}

/// Batched, cache-aware server for (Γ, γ, φ) queries (see module docs).
///
/// An engine value is a *handle*: [`PredictionEngine::fork`] produces
/// further handles onto the same compiled forests and shared cache, each
/// with private evaluation scratch, so handles can serve from different
/// threads (they are `Send`) while pooling memo entries and counters.
pub struct PredictionEngine {
    shared: Arc<EngineShared>,
    scratch: EvalScratch,
}

impl PredictionEngine {
    /// Compile the three fitted forests into the batched slab layout. The
    /// Γ model consumes full bs=32 feature rows; the γ/φ models consume
    /// forward-masked bs=1 rows (the same convention the experiments fit
    /// them with).
    pub fn new(gamma_train: &Forest, gamma_infer: &Forest, phi_infer: &Forest) -> PredictionEngine {
        for f in [gamma_train, gamma_infer, phi_infer] {
            assert_eq!(
                f.n_features, NUM_FEATURES,
                "engine forests must consume the {NUM_FEATURES}-column feature rows"
            );
        }
        PredictionEngine {
            shared: Arc::new(EngineShared {
                gamma_train: BlockedForest::compile(gamma_train),
                infer_pair: CompiledForestPair::compile(gamma_infer, phi_infer),
                cache: Mutex::new(FingerprintCache::new(DEFAULT_CACHE_CAPACITY)),
            }),
            scratch: EvalScratch::default(),
        }
    }

    /// Replace the memo with one of the given capacity. `0` disables
    /// caching entirely — the reference configuration the equivalence
    /// suite compares against. Meant for construction time: forked
    /// handles share the cache, so a replacement resets their memo (and
    /// its counters) too.
    pub fn with_cache_capacity(self, capacity: usize) -> PredictionEngine {
        *self.lock_cache() = FingerprintCache::new(capacity);
        self
    }

    /// A second handle onto the same compiled forests and shared
    /// fingerprint cache, with fresh private scratch. Forked handles can
    /// evaluate from other threads; each `evaluate_generation` is one
    /// atomic cache transaction, so the shared counters stay exact.
    pub fn fork(&self) -> PredictionEngine {
        PredictionEngine {
            shared: Arc::clone(&self.shared),
            scratch: EvalScratch::default(),
        }
    }

    fn lock_cache(&self) -> std::sync::MutexGuard<'_, FingerprintCache> {
        self.shared.cache.lock().expect("engine cache poisoned")
    }

    /// Current cache counters (shared across every forked handle).
    pub fn stats(&self) -> CacheStats {
        self.lock_cache().stats()
    }

    /// The memoised feature rows `(f_train, f_infer)` of a previously
    /// evaluated candidate, if still cached. Returns owned copies — the
    /// rows live behind the shared cache lock.
    pub fn cached_feature_rows(&self, config: &SubnetConfig) -> Option<(Vec<f64>, Vec<f64>)> {
        self.lock_cache()
            .rows(config_fingerprint(config), config)
            .map(|(t, i)| (t.to_vec(), i.to_vec()))
    }

    /// Answer Γ/γ/φ for `candidates` in two blocked branch-free passes
    /// (Γ-train, then the fused γ/φ pair) via the
    /// zero-allocation overlay fast path: per candidate, fetch (or compile
    /// once) the depth-key arena, write the candidate's conv widths into
    /// the reusable overlay, rebuild the analysis incrementally into the
    /// engine's plan buffers, and accumulate the feature rows into flat
    /// scratch. No graph is ever built on this path; results are
    /// bit-identical to the clone+rebuild reference
    /// (`rust/tests/engine_equivalence.rs`, `overlay_equivalence.rs`).
    ///
    /// The (train, infer) rows stay in `self.scratch.{train,infer}_flat`
    /// (row `i` at `i*NUM_FEATURES..`) for the caller to memoise.
    fn compute_batch(&mut self, candidates: &[SubnetConfig]) -> Vec<CandidateEval> {
        let scratch = &mut self.scratch;
        scratch.train_flat.clear();
        scratch.infer_flat.clear();
        let mut capacities = Vec::with_capacity(candidates.len());
        for c in candidates {
            let arena = scratch.arenas.entry(c.depth_key()).or_insert_with(|| {
                let rep = SubnetConfig::depth_representative(c.depth_key()).build();
                GraphArena::compile(&rep).expect("OFA sub-networks are always valid")
            });
            let overlay = scratch
                .overlay
                .get_or_insert_with(|| arena.identity_overlay());
            overlay.rebind_empty(arena);
            c.fill_conv_widths(overlay.widths_mut());
            arena
                .plan_into(overlay, &mut scratch.buffers)
                .expect("OFA sub-networks are always valid");
            let view = arena.view_buffers(&scratch.buffers);
            network_features_into(view.conv_infos(), TRAIN_BS, &mut scratch.row);
            scratch.train_flat.extend_from_slice(&scratch.row);
            network_features_into(view.conv_infos(), 1, &mut scratch.row);
            forward_mask_in_place(&mut scratch.row);
            scratch.infer_flat.extend_from_slice(&scratch.row);
            capacities.push(capacity_from_convs(view.conv_infos()));
        }
        // Two blocked passes answer all three models: Γ over the train
        // rows, then the fused γ/φ pair sharing one walk over the infer
        // rows. Outputs and cursor scratch are engine-owned, so the steady
        // state allocates nothing here.
        let n = candidates.len();
        scratch.out_gamma_t.resize(n, 0.0);
        scratch.out_gamma_i.resize(n, 0.0);
        scratch.out_phi_i.resize(n, 0.0);
        self.shared.gamma_train.predict_into(
            &scratch.train_flat,
            &mut scratch.exec,
            &mut scratch.out_gamma_t,
        );
        self.shared.infer_pair.predict_into(
            &scratch.infer_flat,
            &mut scratch.exec,
            &mut scratch.out_gamma_i,
            &mut scratch.out_phi_i,
        );
        capacities
            .iter()
            .enumerate()
            .map(|(i, &capacity)| CandidateEval {
                attrs: Attributes {
                    gamma_train_mb: scratch.out_gamma_t[i],
                    gamma_infer_mb: scratch.out_gamma_i[i],
                    phi_infer_ms: scratch.out_phi_i[i],
                },
                capacity,
            })
            .collect()
    }

    /// [`GenerationOracle::evaluate_generation`] plus per-query
    /// provenance: how each candidate was answered (shared-memo hit,
    /// in-flight duplicate, or a real evaluation). The serving layer uses
    /// the outcomes to keep per-tenant hit/miss counters; plain callers
    /// use the untraced trait method.
    ///
    /// The shared cache is locked for the whole call — one generation is
    /// one atomic cache transaction, so concurrent forked handles cannot
    /// interleave lookups and inserts mid-generation and the counters
    /// keep their single-caller meaning.
    pub fn evaluate_generation_traced(
        &mut self,
        candidates: &[SubnetConfig],
    ) -> (Vec<CandidateEval>, Vec<QueryOutcome>) {
        if candidates.is_empty() {
            return (Vec::new(), Vec::new());
        }
        // The guard borrows a local clone of the Arc, leaving `self` free
        // for `compute_batch` (which never touches the cache).
        let shared = Arc::clone(&self.shared);
        let mut cache = shared.cache.lock().expect("engine cache poisoned");
        if cache.capacity() == 0 {
            // Cache disabled: every request is an evaluation.
            let evals = self.compute_batch(candidates);
            cache.note_misses(candidates.len() as u64);
            return (evals, vec![QueryOutcome::Evaluated; candidates.len()]);
        }
        let fps: Vec<u64> = candidates.iter().map(config_fingerprint).collect();
        let mut out: Vec<Option<CandidateEval>> = vec![None; candidates.len()];
        let mut outcomes = vec![QueryOutcome::Evaluated; candidates.len()];
        // Unique misses, in first-appearance order. Dedup compares the full
        // config, not just the fingerprint, mirroring the cache's collision
        // guard: a 64-bit collision costs a second evaluation, never a
        // wrong answer.
        let mut miss_slots: HashMap<u64, Vec<usize>> = HashMap::new();
        let mut miss_idx: Vec<usize> = Vec::new();
        for (i, (&fp, c)) in fps.iter().zip(candidates).enumerate() {
            if let Some(eval) = cache.get(fp, c) {
                out[i] = Some(eval);
                outcomes[i] = QueryOutcome::CacheHit;
            } else {
                let slots = miss_slots.entry(fp).or_default();
                if !slots.iter().any(|&s| candidates[miss_idx[s]] == *c) {
                    slots.push(miss_idx.len());
                    miss_idx.push(i);
                }
            }
        }
        let missing: Vec<SubnetConfig> = miss_idx.iter().map(|&i| candidates[i]).collect();
        let evals = self.compute_batch(&missing);
        cache.note_misses(missing.len() as u64);
        // Memoise each fresh evaluation; its rows sit in the flat scratch
        // at `slot * NUM_FEATURES` (the only per-candidate allocations
        // left are the cache's own copies).
        for (slot, (&i, eval)) in miss_idx.iter().zip(evals.iter().copied()).enumerate() {
            let f_train = self.scratch.train_flat[slot * NUM_FEATURES..(slot + 1) * NUM_FEATURES]
                .to_vec();
            let f_infer = self.scratch.infer_flat[slot * NUM_FEATURES..(slot + 1) * NUM_FEATURES]
                .to_vec();
            cache.insert(fps[i], &candidates[i], eval, f_train, f_infer);
        }
        // Fill batch-local duplicates from the freshly computed slots.
        let mut batch_hits = 0u64;
        for (i, &fp) in fps.iter().enumerate() {
            if out[i].is_none() {
                let slot = *miss_slots[&fp]
                    .iter()
                    .find(|&&s| candidates[miss_idx[s]] == candidates[i])
                    .expect("every missing candidate was evaluated");
                out[i] = Some(evals[slot]);
                if miss_idx[slot] != i {
                    batch_hits += 1;
                    outcomes[i] = QueryOutcome::BatchHit;
                }
            }
        }
        cache.note_batch_hits(batch_hits);
        let resolved = out
            .into_iter()
            .map(|e| e.expect("every candidate resolved"))
            .collect();
        (resolved, outcomes)
    }
}

impl GenerationOracle for PredictionEngine {
    /// Serve one generation: cache hits are answered by lookup, the unique
    /// misses are evaluated together (two blocked passes — Γ, then the
    /// fused γ/φ pair), and batch-local duplicates are filled from the
    /// fresh results.
    fn evaluate_generation(&mut self, candidates: &[SubnetConfig]) -> Vec<CandidateEval> {
        self.evaluate_generation_traced(candidates).0
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        Some(self.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// A small engine whose three roles are served by one synthetic forest
    /// fitted on feature-row geometry (enough for serving-layer tests; the
    /// model-quality tests live in `experiments::ofa_models`).
    fn tiny_engine(cache_capacity: usize) -> PredictionEngine {
        let mut rng = Pcg64::new(0xe27);
        let x: Vec<Vec<f64>> = (0..40)
            .map(|_| (0..NUM_FEATURES).map(|_| rng.uniform(0.0, 1e6)).collect())
            .collect();
        let y: Vec<f64> = x.iter().map(|r| r[1] / 1e3 + r[3] / 1e4 + 100.0).collect();
        let f = Forest::fit(
            &x,
            &y,
            &crate::forest::ForestConfig {
                n_trees: 8,
                max_depth: 6,
                ..Default::default()
            },
        )
        .unwrap();
        PredictionEngine::new(&f, &f, &f).with_cache_capacity(cache_capacity)
    }

    #[test]
    fn repeat_candidate_is_a_hit_and_bit_identical() {
        let mut eng = tiny_engine(64);
        let c = SubnetConfig::min();
        let first = eng.evaluate_generation(&[c])[0];
        let second = eng.evaluate_generation(&[c])[0];
        assert_eq!(first, second);
        let s = eng.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!(eng.cached_feature_rows(&c).is_some());
    }

    #[test]
    fn batch_local_duplicates_evaluate_once() {
        let mut eng = tiny_engine(64);
        let c = SubnetConfig::max();
        let evals = eng.evaluate_generation(&[c, c, c]);
        assert_eq!(evals[0], evals[1]);
        assert_eq!(evals[1], evals[2]);
        let s = eng.stats();
        assert_eq!((s.hits, s.misses), (2, 1), "one evaluation, two memo answers");
    }

    #[test]
    fn disabled_cache_counts_every_request_as_miss() {
        let mut eng = tiny_engine(0);
        let c = SubnetConfig::min();
        let a = eng.evaluate_generation(&[c])[0];
        let b = eng.evaluate_generation(&[c])[0];
        assert_eq!(a, b, "determinism does not depend on the cache");
        let s = eng.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 2, 0));
        assert!(eng.cached_feature_rows(&c).is_none());
    }

    #[test]
    fn eviction_counter_moves_at_tiny_capacity() {
        let mut eng = tiny_engine(2);
        let mid = SubnetConfig {
            width: 1,
            ..SubnetConfig::min()
        };
        let gen3 = [SubnetConfig::min(), SubnetConfig::max(), mid];
        eng.evaluate_generation(&gen3);
        let s = eng.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.evictions, 1);
    }

    #[test]
    fn engine_handles_are_send() {
        fn assert_send<T: Send>() {}
        assert_send::<PredictionEngine>();
    }

    #[test]
    fn forked_handles_share_cache_and_counters() {
        let mut a = tiny_engine(64);
        let c = SubnetConfig::min();
        let first = a.evaluate_generation(&[c])[0];
        // A fork sees the memo entry the original handle produced…
        let mut b = a.fork();
        let second = b.evaluate_generation(&[c])[0];
        assert_eq!(first, second);
        let s = a.stats();
        assert_eq!((s.hits, s.misses), (1, 1), "fork answered from the shared memo");
        // …and both handles read the same counters.
        assert_eq!(a.stats(), b.stats());
        assert!(b.cached_feature_rows(&c).is_some());
    }

    #[test]
    fn traced_outcomes_match_counter_semantics() {
        let mut eng = tiny_engine(64);
        let (a, b) = (SubnetConfig::min(), SubnetConfig::max());
        let (_, outcomes) = eng.evaluate_generation_traced(&[a, a, b]);
        assert_eq!(
            outcomes,
            vec![QueryOutcome::Evaluated, QueryOutcome::BatchHit, QueryOutcome::Evaluated]
        );
        let (_, outcomes) = eng.evaluate_generation_traced(&[b, a]);
        assert_eq!(outcomes, vec![QueryOutcome::CacheHit, QueryOutcome::CacheHit]);
        let s = eng.stats();
        assert_eq!((s.hits, s.misses), (3, 2));
    }
}
