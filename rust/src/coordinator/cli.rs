//! Minimal argument parser (clap is unavailable offline): positional
//! subcommand + `--key value` / `--flag` options.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse an iterator of raw args (not including argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare `--` not supported".into());
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.options.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    /// Parse an optional usize option (`Ok(None)` when absent).
    pub fn usize_opt(&self, key: &str) -> Result<Option<usize>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|e| format!("--{key}: {e}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    /// Parse a comma-separated f64 list option.
    pub fn f64_list(&self, key: &str) -> Result<Option<Vec<f64>>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse::<f64>().map_err(|e| format!("--{key}: {e}")))
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }

    /// Parse a comma-separated usize list option.
    pub fn usize_list(&self, key: &str) -> Result<Option<Vec<usize>>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse::<usize>().map_err(|e| format!("--{key}: {e}")))
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("profile --network resnet18 --bs 32 --verbose");
        assert_eq!(a.positional, vec!["profile"]);
        assert_eq!(a.get("network"), Some("resnet18"));
        assert_eq!(a.usize_or("bs", 1).unwrap(), 32);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("fit --target=gamma --lambda=0.5");
        assert_eq!(a.get("target"), Some("gamma"));
        assert_eq!(a.f64_or("lambda", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn lists() {
        let a = parse("profile --levels 0,0.3,0.5 --batch-sizes 2,4,8");
        assert_eq!(a.f64_list("levels").unwrap().unwrap(), vec![0.0, 0.3, 0.5]);
        assert_eq!(
            a.usize_list("batch-sizes").unwrap().unwrap(),
            vec![2, 4, 8]
        );
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("x --bs abc");
        assert!(a.usize_or("bs", 1).is_err());
    }

    #[test]
    fn optional_usize() {
        let a = parse("campaign --shards 4");
        assert_eq!(a.usize_opt("shards").unwrap(), Some(4));
        assert_eq!(a.usize_opt("workers").unwrap(), None);
        assert!(parse("campaign --shards x").usize_opt("shards").is_err());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse("x --offset -3.5");
        assert_eq!(a.f64_or("offset", 0.0).unwrap(), -3.5);
    }
}
