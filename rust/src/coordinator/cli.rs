//! Minimal argument parser (clap is unavailable offline): positional
//! subcommand + `--key value` / `--flag` options.
//!
//! Every malformed invocation surfaces as a named [`CliError`] — never a
//! panic. The historical hazard: a value-taking flag as the *final* token
//! (`predict --bs`) used to route through an `iter.next().unwrap()`; it now
//! records the flag sentinel and the typed getters report
//! [`CliError::MissingValue`] when they reach it.

use std::collections::BTreeMap;
use std::fmt;

/// Structured CLI-parsing failure. Converts into the coordinator's
/// `Result<_, String>` error channel via `From`, so `?` works unchanged at
/// every call site while tests can match on the variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CliError {
    /// A bare `--` token with no option name.
    BareDoubleDash,
    /// A value-taking option reached without a value (e.g. `predict --bs`
    /// as the final token, or `--bs --verbose`).
    MissingValue { flag: String },
    /// An option value that failed to parse as the expected type.
    Invalid { flag: String, message: String },
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CliError::BareDoubleDash => f.write_str("bare `--` not supported"),
            CliError::MissingValue { flag } => write!(f, "--{flag} expects a value"),
            CliError::Invalid { flag, message } => write!(f, "--{flag}: {message}"),
        }
    }
}

impl std::error::Error for CliError {}

impl From<CliError> for String {
    fn from(e: CliError) -> String {
        e.to_string()
    }
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse an iterator of raw args (not including argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if key.is_empty() {
                    return Err(CliError::BareDoubleDash);
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    // peek() said a value follows, but never unwrap the
                    // draw: report the flag by name if the iterator lies.
                    let v = iter.next().ok_or_else(|| CliError::MissingValue {
                        flag: key.to_string(),
                    })?;
                    out.options.insert(key.to_string(), v);
                } else {
                    out.options.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Parse one option value, mapping a parse failure on the bare-flag
    /// sentinel (`"true"`, recorded when no value followed the flag) to
    /// [`CliError::MissingValue`] — `predict --bs` means the value is
    /// missing, not that "true" is a malformed number.
    fn typed<T: std::str::FromStr>(&self, key: &str, v: &str) -> Result<T, CliError>
    where
        T::Err: fmt::Display,
    {
        v.parse().map_err(|e: T::Err| {
            if v == "true" && self.get(key) == Some("true") {
                CliError::MissingValue {
                    flag: key.to_string(),
                }
            } else {
                CliError::Invalid {
                    flag: key.to_string(),
                    message: e.to_string(),
                }
            }
        })
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => self.typed(key, v),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => self.typed(key, v),
        }
    }

    /// Parse an optional usize option (`Ok(None)` when absent).
    pub fn usize_opt(&self, key: &str) -> Result<Option<usize>, CliError> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => self.typed(key, v).map(Some),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => self.typed(key, v),
        }
    }

    /// Parse a comma-separated f64 list option.
    pub fn f64_list(&self, key: &str) -> Result<Option<Vec<f64>>, CliError> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|s| self.typed(key, s.trim()))
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }

    /// Parse a comma-separated usize list option.
    pub fn usize_list(&self, key: &str) -> Result<Option<Vec<usize>>, CliError> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|s| self.typed(key, s.trim()))
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("profile --network resnet18 --bs 32 --verbose");
        assert_eq!(a.positional, vec!["profile"]);
        assert_eq!(a.get("network"), Some("resnet18"));
        assert_eq!(a.usize_or("bs", 1).unwrap(), 32);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("fit --target=gamma --lambda=0.5");
        assert_eq!(a.get("target"), Some("gamma"));
        assert_eq!(a.f64_or("lambda", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn lists() {
        let a = parse("profile --levels 0,0.3,0.5 --batch-sizes 2,4,8");
        assert_eq!(a.f64_list("levels").unwrap().unwrap(), vec![0.0, 0.3, 0.5]);
        assert_eq!(
            a.usize_list("batch-sizes").unwrap().unwrap(),
            vec![2, 4, 8]
        );
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("x --bs abc");
        let err = a.usize_or("bs", 1).unwrap_err();
        assert!(matches!(&err, CliError::Invalid { flag, .. } if flag == "bs"), "{err}");
        assert!(err.to_string().starts_with("--bs: "), "{err}");
    }

    #[test]
    fn value_flag_as_final_token_is_missing_value_not_a_panic() {
        // The historical `iter.next().unwrap()` hazard: a value-taking
        // flag with nothing after it. Parsing must succeed (the flag
        // records the sentinel) and the typed getters must report a
        // named MissingValue, not a confusing number-parse error.
        for cmdline in ["predict --bs", "profile --runs", "x --bs --verbose"] {
            let a = Args::parse(cmdline.split_whitespace().map(String::from)).unwrap();
            let err = a.usize_or(cmdline.split("--").nth(1).unwrap().trim(), 1).unwrap_err();
            assert!(
                matches!(&err, CliError::MissingValue { .. }),
                "{cmdline:?}: {err}"
            );
        }
        let a = parse("predict --bs");
        assert_eq!(a.usize_or("bs", 1), Err(CliError::MissingValue { flag: "bs".into() }));
        assert_eq!(a.usize_list("bs").unwrap_err().to_string(), "--bs expects a value");
        // An explicit `--flag true` for a *numeric* option is still the
        // missing-value case (the sentinel is indistinguishable), but
        // boolean flags keep working.
        assert!(parse("x --verbose").flag("verbose"));
    }

    #[test]
    fn bare_double_dash_is_a_named_error() {
        let err = Args::parse(["--".to_string()]).unwrap_err();
        assert_eq!(err, CliError::BareDoubleDash);
        assert_eq!(err.to_string(), "bare `--` not supported");
        // Still converts into the coordinator's String error channel.
        let s: String = err.into();
        assert_eq!(s, "bare `--` not supported");
    }

    #[test]
    fn optional_usize() {
        let a = parse("campaign --shards 4");
        assert_eq!(a.usize_opt("shards").unwrap(), Some(4));
        assert_eq!(a.usize_opt("workers").unwrap(), None);
        assert!(parse("campaign --shards x").usize_opt("shards").is_err());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse("x --offset -3.5");
        assert_eq!(a.f64_or("offset", 0.0).unwrap(), -3.5);
    }
}
