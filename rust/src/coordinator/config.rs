//! Toolflow configuration: defaults + a minimal TOML-subset file format
//! (sections, `key = value` with strings / numbers / booleans / inline
//! arrays of numbers). Used by the CLI `--config` flag so runs are
//! declarative and reproducible.

use std::collections::BTreeMap;
use std::path::Path;

use crate::forest::ForestConfig;

/// Parsed config values, addressable as `section.key`.
#[derive(Clone, Debug, Default)]
pub struct RawConfig {
    values: BTreeMap<String, String>,
}

impl RawConfig {
    pub fn parse(text: &str) -> Result<RawConfig, String> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw_line) in text.lines().enumerate() {
            let line = raw_line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(sec) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = sec.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(format!("line {}: expected `key = value`", lineno + 1));
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim().to_string();
            if val.starts_with('"') && val.ends_with('"') && val.len() >= 2 {
                val = val[1..val.len() - 1].to_string();
            }
            values.insert(key, val);
        }
        Ok(RawConfig { values })
    }

    pub fn load(path: &Path) -> Result<RawConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn string(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

/// Resolved toolflow configuration.
#[derive(Clone, Debug)]
pub struct ToolflowConfig {
    pub device: String,
    pub seed: u64,
    pub runs: usize,
    pub forest: ForestConfig,
    pub artifacts_dir: String,
    pub data_dir: String,
    /// Campaign driver worker-pool width (`[campaign] workers`); 0 = auto
    /// (the `PERF4SIGHT_WORKERS` env override, else available
    /// parallelism).
    pub campaign_workers: usize,
    /// Campaign shard count (`[campaign] shards`); 0 = auto (one shard
    /// per worker).
    pub campaign_shards: usize,
    /// Default training-regime sweep (`[campaign] regimes`): a comma list
    /// of regime names (`vanilla`, `ckpt:N`, `frozen:N`). Overridden by
    /// the CLI `--regimes`; parsed and validated at campaign start.
    pub campaign_regimes: String,
    /// Per-shard retry budget of the local campaign driver
    /// (`[campaign] retries`): a failed shard is re-executed up to this
    /// many extra times (with backoff) before the run errors. 0 = fail
    /// fast.
    pub campaign_retries: usize,
    /// Wall-clock budget per spawned campaign worker process in
    /// milliseconds (`[campaign] worker_timeout_ms`); a worker exceeding
    /// it is killed and charged a failed attempt. 0 = no timeout.
    pub campaign_worker_timeout_ms: u64,
    /// Dispatch-mode lease timeout (`[dispatch] lease_timeout_ms`): a
    /// worker whose heartbeat is older than this is presumed dead and its
    /// shard reclaimed.
    pub dispatch_lease_timeout_ms: u64,
    /// Dispatch-mode worker heartbeat cadence
    /// (`[dispatch] heartbeat_ms`) — keep well under the lease timeout.
    pub dispatch_heartbeat_ms: u64,
    /// Dispatch-mode mailbox poll interval (`[dispatch] poll_ms`) for
    /// both coordinator and workers.
    pub dispatch_poll_ms: u64,
    /// Dispatch-mode per-shard retry budget (`[dispatch] retries`):
    /// failures + lease reclaims tolerated per shard before the
    /// coordinator aborts the campaign.
    pub dispatch_retries: usize,
    /// Dispatch-mode backoff base in milliseconds
    /// (`[dispatch] backoff_base_ms`); doubles per failure, jittered.
    pub dispatch_backoff_base_ms: u64,
    /// Dispatch-mode backoff cap in milliseconds
    /// (`[dispatch] backoff_cap_ms`).
    pub dispatch_backoff_cap_ms: u64,
    /// Dispatch-mode idle timeout in milliseconds
    /// (`[dispatch] idle_timeout_ms`): coordinator/worker gives up after
    /// this long with no fleet progress. 0 = wait forever.
    pub dispatch_idle_timeout_ms: u64,
    /// Serving-queue admission bound (`[serve] queue_capacity`):
    /// generations that may wait before tenant submits block.
    pub serve_queue_capacity: usize,
    /// Most requests coalesced into one engine generation per serving
    /// drain (`[serve] max_coalesce`).
    pub serve_max_coalesce: usize,
}

impl Default for ToolflowConfig {
    fn default() -> Self {
        ToolflowConfig {
            device: "tx2".into(),
            seed: 0x9e1f,
            runs: 3,
            forest: crate::runtime::forest_exec::export_forest_config(),
            artifacts_dir: "artifacts".into(),
            data_dir: "data".into(),
            campaign_workers: 0,
            campaign_shards: 0,
            campaign_regimes: "vanilla".into(),
            campaign_retries: 1,
            campaign_worker_timeout_ms: 0,
            dispatch_lease_timeout_ms: 10_000,
            dispatch_heartbeat_ms: 2_000,
            dispatch_poll_ms: 500,
            dispatch_retries: 3,
            dispatch_backoff_base_ms: 500,
            dispatch_backoff_cap_ms: 10_000,
            dispatch_idle_timeout_ms: 0,
            serve_queue_capacity: 64,
            serve_max_coalesce: 16,
        }
    }
}

impl ToolflowConfig {
    pub fn from_raw(raw: &RawConfig) -> ToolflowConfig {
        let d = ToolflowConfig::default();
        ToolflowConfig {
            device: raw.string("device", &d.device),
            seed: raw.u64("seed", d.seed),
            runs: raw.usize("profiling.runs", d.runs),
            forest: ForestConfig {
                n_trees: raw.usize("forest.n_trees", d.forest.n_trees),
                max_depth: raw.usize("forest.max_depth", d.forest.max_depth),
                min_samples_leaf: raw.usize("forest.min_samples_leaf", d.forest.min_samples_leaf),
                min_samples_split: raw
                    .usize("forest.min_samples_split", d.forest.min_samples_split),
                feature_fraction: raw.f64("forest.feature_fraction", d.forest.feature_fraction),
                bootstrap: raw.string("forest.bootstrap", "true") != "false",
                seed: raw.u64("forest.seed", d.forest.seed),
            },
            artifacts_dir: raw.string("paths.artifacts", &d.artifacts_dir),
            data_dir: raw.string("paths.data", &d.data_dir),
            campaign_workers: raw.usize("campaign.workers", d.campaign_workers),
            campaign_shards: raw.usize("campaign.shards", d.campaign_shards),
            campaign_regimes: raw.string("campaign.regimes", &d.campaign_regimes),
            campaign_retries: raw.usize("campaign.retries", d.campaign_retries),
            campaign_worker_timeout_ms: raw
                .u64("campaign.worker_timeout_ms", d.campaign_worker_timeout_ms),
            dispatch_lease_timeout_ms: raw
                .u64("dispatch.lease_timeout_ms", d.dispatch_lease_timeout_ms),
            dispatch_heartbeat_ms: raw.u64("dispatch.heartbeat_ms", d.dispatch_heartbeat_ms),
            dispatch_poll_ms: raw.u64("dispatch.poll_ms", d.dispatch_poll_ms),
            dispatch_retries: raw.usize("dispatch.retries", d.dispatch_retries),
            dispatch_backoff_base_ms: raw
                .u64("dispatch.backoff_base_ms", d.dispatch_backoff_base_ms),
            dispatch_backoff_cap_ms: raw.u64("dispatch.backoff_cap_ms", d.dispatch_backoff_cap_ms),
            dispatch_idle_timeout_ms: raw
                .u64("dispatch.idle_timeout_ms", d.dispatch_idle_timeout_ms),
            serve_queue_capacity: raw.usize("serve.queue_capacity", d.serve_queue_capacity),
            serve_max_coalesce: raw.usize("serve.max_coalesce", d.serve_max_coalesce),
        }
    }

    pub fn load(path: &Path) -> Result<ToolflowConfig, String> {
        Ok(Self::from_raw(&RawConfig::load(path)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# perf4sight config
device = "xavier"
seed = 42

[forest]
n_trees = 64
max_depth = 10
feature_fraction = 0.5

[profiling]
runs = 5

[campaign]
workers = 3
shards = 6
regimes = "vanilla,ckpt:4"
retries = 2
worker_timeout_ms = 60000

[dispatch]
lease_timeout_ms = 5000
heartbeat_ms = 1000
retries = 4
idle_timeout_ms = 120000

[serve]
queue_capacity = 32
max_coalesce = 8

[paths]
artifacts = "build/artifacts"
"#;

    #[test]
    fn parse_sections_and_types() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        assert_eq!(raw.get("device"), Some("xavier"));
        assert_eq!(raw.usize("forest.n_trees", 0), 64);
        assert_eq!(raw.f64("forest.feature_fraction", 0.0), 0.5);
        assert_eq!(raw.get("missing"), None);
    }

    #[test]
    fn resolved_config() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        let cfg = ToolflowConfig::from_raw(&raw);
        assert_eq!(cfg.device, "xavier");
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.runs, 5);
        assert_eq!(cfg.forest.n_trees, 64);
        assert_eq!(cfg.forest.max_depth, 10);
        assert_eq!(cfg.artifacts_dir, "build/artifacts");
        assert_eq!(cfg.campaign_workers, 3);
        assert_eq!(cfg.campaign_shards, 6);
        assert_eq!(cfg.campaign_regimes, "vanilla,ckpt:4");
        assert_eq!(cfg.campaign_retries, 2);
        assert_eq!(cfg.campaign_worker_timeout_ms, 60_000);
        assert_eq!(cfg.dispatch_lease_timeout_ms, 5_000);
        assert_eq!(cfg.dispatch_heartbeat_ms, 1_000);
        assert_eq!(cfg.dispatch_retries, 4);
        assert_eq!(cfg.dispatch_idle_timeout_ms, 120_000);
        assert_eq!(cfg.serve_queue_capacity, 32);
        assert_eq!(cfg.serve_max_coalesce, 8);
        // untouched keys keep defaults
        assert_eq!(cfg.data_dir, "data");
        let d = ToolflowConfig::default();
        assert_eq!(d.serve_queue_capacity, 64);
        assert_eq!(d.serve_max_coalesce, 16);
        assert_eq!(d.campaign_regimes, "vanilla");
        assert_eq!(d.campaign_retries, 1);
        assert_eq!(d.campaign_worker_timeout_ms, 0);
        assert_eq!(d.dispatch_retries, 3);
        assert_eq!(d.dispatch_poll_ms, 500);
        assert_eq!(d.dispatch_backoff_base_ms, 500);
        assert_eq!(d.dispatch_backoff_cap_ms, 10_000);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let raw = RawConfig::parse("# all comments\n\n  \n").unwrap();
        assert_eq!(raw.get("device"), None);
    }

    #[test]
    fn malformed_line_is_error() {
        assert!(RawConfig::parse("not a kv line").is_err());
    }
}
