//! The L3 coordinator: CLI subcommand dispatch for the whole toolflow
//! (Fig. 2 of the paper — pruning, profiling, feature generation, forest
//! training, prediction, OFA search — plus the experiment harnesses and
//! the AOT training demo).

pub mod cli;
pub mod config;

pub use cli::{Args, CliError};
pub use config::{RawConfig, ToolflowConfig};

use std::path::{Path, PathBuf};

use crate::campaign::{self, CampaignSpec, DriverConfig, ExecMode};
use crate::device::{DeviceSpec, Simulator, TrainRegime};
use crate::engine::CompiledForestPair;
use crate::experiments;
use crate::features::network_features_from_plan_regime;
use crate::forest::Forest;
use crate::ofa::{Constraints, EsConfig, Subset};
use crate::profiler::{profile, Dataset, ProfileJob, PAPER_BATCH_SIZES, TRAIN_LEVELS};
use crate::pruning::Strategy;
use crate::serve::{PredictionService, ServeConfig, TenantStats};
use crate::util::json::Json;

const USAGE: &str = "\
perf4sight — CNN training performance models for edge GPUs (paper reproduction)

USAGE: perf4sight <command> [--options]

COMMANDS:
  zoo                               list the network zoo
  profile    --network N [--device tx2] [--strategy random|l1norm]
             [--regime vanilla|ckpt:N|frozen:N] [--levels 0,0.3,..]
             [--batch-sizes 2,4,..] [--runs 3] [--seed S] --out FILE.json
             (or: --shards K --shard-index I --out-dir DIR to run one
              campaign shard and write shard-I.json + its manifest)
  campaign   --networks N1,N2[,..] --out-dir DIR [--strategies random,l1norm]
             [--regimes vanilla,ckpt:4,frozen:2] [--levels 0,0.3,..]
             [--batch-sizes 2,4,..] [--runs 3] [--seed S]
             [--device tx2] [--shards K] [--workers W] [--in-process]
             [--merge-only] [--format json|csv] [--out FILE]
             [--retries R] [--worker-timeout-ms MS]
             (spawns W worker processes that drain K shards work-stealing
              style, checkpointing shard-*.json + manifests under DIR, then
              merges them — bit-identical to single-process profiling.
              Re-running resumes: complete shards are skipped. Failed
              shards are retried R times with backoff; a worker past its
              timeout is killed and charged a failed attempt.)
             --dispatch coordinator|worker: fault-tolerant distributed
              dispatch over a shared directory (NFS etc). The coordinator
              announces the campaign under DIR, reclaims dead workers'
              leases and merges; workers (same flags minus the grid, plus
              [--worker-id ID]) claim shards via lease files + heartbeats.
              Knobs: [--lease-timeout-ms MS] [--heartbeat-ms MS]
              [--poll-ms MS] [--retries R] [--backoff-base-ms MS]
              [--backoff-cap-ms MS] [--idle-timeout-ms MS]
              [--local-workers N] (coordinator also spawns N local worker
              processes — single-machine fault-tolerant mode).
  fit        --data FILE.json[,FILE2..] --target gamma|phi --out MODEL.json
  predict    --model MODEL.json [--phi-model MODEL2.json] --network N
             [--level 0.3,0.5,..] [--bs 2,4,..]
             [--strategy random] [--regime vanilla|ckpt:N|frozen:N]
             [--device tx2] [--seed S]
             (comma lists sweep level × bs in one blocked branch-free pass;
              --phi-model answers both targets from one fused Γ/Φ walk)
  search     [--device tx2] [--subset city|off-road|motorway|country-side]
             [--gamma-max MB] [--gamma-infer-max MB] [--phi-max MS]
             [--population 100] [--iterations 500] [--subnets 100] [--seed S]
             [--tenants N [--verify-serial] [--queue-capacity 64] [--coalesce 16]]
             (--tenants N runs N concurrent searches, seeds S..S+N, as
              tenants of one shared prediction service — cross-tenant
              batch coalescing over one engine cache. --verify-serial
              re-runs each serially and fails unless results are
              byte-identical.)
  train-demo [--steps 100] [--lr 0.1] [--artifacts DIR] [--seed S]
  experiment fig3|fig4|fig5|table2|trainset|regimes|topology|dnnmem|ofa-models|ablation|cross-device|all
             [--seed S] [--quick]
  help

Options may also come from --config FILE (TOML subset; see rust/src/coordinator/config.rs).
The PERF4SIGHT_WORKERS env var pins worker-pool width (profiling + campaigns).
";

/// Entry point used by `main.rs`.
pub fn run(raw_args: Vec<String>) -> Result<(), String> {
    let args = Args::parse(raw_args)?;
    let cfg = match args.get("config") {
        Some(path) => ToolflowConfig::load(Path::new(path))?,
        None => ToolflowConfig::default(),
    };
    match args.positional.first().map(|s| s.as_str()) {
        Some("zoo") => cmd_zoo(),
        Some("profile") => cmd_profile(&args, &cfg),
        Some("campaign") => cmd_campaign(&args, &cfg),
        // Hidden: the campaign driver self-execs this mode to run one
        // shard in a worker process.
        Some("profile-worker") => cmd_profile_worker(&args),
        Some("fit") => cmd_fit(&args, &cfg),
        Some("predict") => cmd_predict(&args, &cfg),
        Some("search") => cmd_search(&args, &cfg),
        Some("train-demo") => cmd_train_demo(&args, &cfg),
        Some("experiment") => cmd_experiment(&args, &cfg),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(format!("unknown command {other:?}\n{USAGE}")),
    }
}

fn simulator(args: &Args, cfg: &ToolflowConfig) -> Result<Simulator, String> {
    let name = args.get_or("device", &cfg.device);
    DeviceSpec::by_name(&name)
        .map(Simulator::new)
        .ok_or_else(|| format!("unknown device {name:?} (tx2, xavier, 2080ti)"))
}

/// `--regime NAME` (profile / predict): a single training regime,
/// defaulting to vanilla.
fn regime_arg(args: &Args) -> Result<TrainRegime, String> {
    match args.get("regime") {
        None => Ok(TrainRegime::Vanilla),
        Some(name) => TrainRegime::from_name(name).ok_or_else(|| {
            format!("unknown training regime {name:?} (expected vanilla, ckpt:N or frozen:N)")
        }),
    }
}

fn strategy_of(name: &str) -> Result<Strategy, String> {
    Strategy::from_name(name).ok_or_else(|| format!("unknown strategy {name:?}"))
}

fn cmd_zoo() -> Result<(), String> {
    println!("{:<14} {:>10} {:>10} {:>7}", "network", "params(M)", "size(MB)", "convs");
    for name in crate::models::ZOO {
        let g = crate::models::by_name(name).unwrap();
        let plan = g.plan().map_err(|e| e.to_string())?;
        println!(
            "{:<14} {:>10.2} {:>10.1} {:>7}",
            name,
            plan.param_count() as f64 / 1e6,
            plan.model_size_mb(),
            plan.conv_infos().len()
        );
    }
    Ok(())
}

fn cmd_profile(args: &Args, cfg: &ToolflowConfig) -> Result<(), String> {
    let network = args.get("network").ok_or("--network required")?;
    let graph = crate::models::by_name(network).ok_or_else(|| format!("unknown network {network}"))?;
    let strategy = strategy_of(&args.get_or("strategy", "random"))?;
    let levels = args.f64_list("levels")?.unwrap_or_else(|| TRAIN_LEVELS.to_vec());
    let batch_sizes = args
        .usize_list("batch-sizes")?
        .unwrap_or_else(|| PAPER_BATCH_SIZES.to_vec());
    let runs = args.usize_or("runs", cfg.runs)?;
    let seed = args.u64_or("seed", cfg.seed)?;
    let regime = regime_arg(args)?;

    // Shard mode: run one shard of the single-network campaign grid and
    // checkpoint it (shard-I.json + manifest) for a later `campaign
    // --merge-only`.
    if let Some(shards) = args.usize_opt("shards")? {
        let shard_index = args
            .usize_opt("shard-index")?
            .ok_or("--shard-index required with --shards")?;
        let dir = PathBuf::from(
            args.get("out-dir")
                .ok_or("--out-dir required with --shards (shard + manifest files land there)")?,
        );
        let spec = CampaignSpec {
            networks: vec![network.to_string()],
            strategies: vec![strategy],
            regimes: vec![regime],
            levels,
            batch_sizes,
            runs,
            seed,
            device: args.get_or("device", &cfg.device),
        };
        spec.validate()?;
        let plans = spec.shard_plans(shards);
        let plan = plans.get(shard_index).ok_or_else(|| {
            format!("--shard-index {shard_index} out of range ({} shards)", plans.len())
        })?;
        campaign::ensure_spec_file(&spec, &dir)?;
        campaign::write_shard(&spec, &dir, plan)?;
        println!(
            "shard {}/{}: {} of {} units → {}",
            shard_index,
            plans.len(),
            plan.units.len(),
            spec.total_units(),
            dir.display()
        );
        return Ok(());
    }

    let sim = simulator(args, cfg)?;
    let job = ProfileJob {
        network,
        graph: &graph,
        strategy,
        regime,
        levels: &levels,
        batch_sizes: &batch_sizes,
        runs,
        seed,
    };
    let started = std::time::Instant::now();
    let ds = profile(&sim, &job);
    let out = args.get("out").ok_or("--out required")?;
    ds.save(Path::new(out)).map_err(|e| e.to_string())?;
    println!(
        "profiled {} points ({} levels × {} batch sizes) on {} in {:.2?} → {}",
        ds.len(),
        levels.len(),
        batch_sizes.len(),
        sim.spec.name,
        started.elapsed(),
        out
    );
    Ok(())
}

/// Build a [`CampaignSpec`] from the `campaign` subcommand's grid flags
/// (shared by the local driver and the dispatch coordinator).
fn campaign_spec_from_args(args: &Args, cfg: &ToolflowConfig) -> Result<CampaignSpec, String> {
    let networks: Vec<String> = args
        .get("networks")
        .ok_or("--networks required (comma list; see `zoo`)")?
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let strategies = match args.get("strategies") {
        None => vec![Strategy::Random],
        Some(list) => list
            .split(',')
            .map(|s| strategy_of(s.trim()))
            .collect::<Result<Vec<_>, _>>()?,
    };
    let regimes = TrainRegime::parse_list(&args.get_or("regimes", &cfg.campaign_regimes))?;
    let spec = CampaignSpec {
        networks,
        strategies,
        regimes,
        levels: args.f64_list("levels")?.unwrap_or_else(|| TRAIN_LEVELS.to_vec()),
        batch_sizes: args
            .usize_list("batch-sizes")?
            .unwrap_or_else(|| PAPER_BATCH_SIZES.to_vec()),
        runs: args.usize_or("runs", cfg.runs)?,
        seed: args.u64_or("seed", cfg.seed)?,
        device: args.get_or("device", &cfg.device),
    };
    spec.validate()?;
    Ok(spec)
}

/// Resolve the campaign shard count: CLI flag, then config, then the
/// partition already checkpointed under `dir` (resume must survive a
/// changed worker width), then one shard per worker.
fn campaign_shard_count(
    args: &Args,
    cfg: &ToolflowConfig,
    dir: &Path,
    workers: usize,
) -> Result<usize, String> {
    Ok(match args.usize_opt("shards")? {
        Some(n) => n,
        None if cfg.campaign_shards > 0 => cfg.campaign_shards,
        None => campaign::existing_shard_count(dir).unwrap_or(workers),
    })
}

/// `0` means "disabled" for every millisecond knob with an optional
/// timeout semantic.
fn ms_opt(ms: u64) -> Option<std::time::Duration> {
    (ms > 0).then(|| std::time::Duration::from_millis(ms))
}

/// Dispatch-side retry policy from flags + `[dispatch]` config. Both the
/// coordinator and every worker must resolve the same values, or they
/// disagree on when a shard is exhausted.
fn dispatch_retry(args: &Args, cfg: &ToolflowConfig) -> Result<campaign::RetryPolicy, String> {
    Ok(campaign::RetryPolicy {
        retries: args.usize_or("retries", cfg.dispatch_retries)?,
        base_ms: args.u64_or("backoff-base-ms", cfg.dispatch_backoff_base_ms)?,
        cap_ms: args.u64_or("backoff-cap-ms", cfg.dispatch_backoff_cap_ms)?,
    })
}

/// Merge the checkpointed shards under `dir` and save the dataset in the
/// requested format — the shared tail of every campaign entry point.
fn merge_and_save(
    args: &Args,
    spec: &CampaignSpec,
    dir: &Path,
    format: &str,
    started: std::time::Instant,
) -> Result<(), String> {
    let ds = campaign::merge(spec, dir)?;
    let out = args.get("out").map(PathBuf::from).unwrap_or_else(|| {
        dir.join(if format == "csv" { "dataset.csv" } else { "dataset.json" })
    });
    if format == "csv" {
        ds.save_csv(&out).map_err(|e| e.to_string())?;
    } else {
        ds.save(&out).map_err(|e| e.to_string())?;
    }
    println!(
        "merged {} manifest-checked points in {:.2?} → {}",
        ds.len(),
        started.elapsed(),
        out.display()
    );
    Ok(())
}

fn cmd_campaign(args: &Args, cfg: &ToolflowConfig) -> Result<(), String> {
    match args.get("dispatch") {
        None => {}
        Some("worker") => return cmd_dispatch_worker(args, cfg),
        Some("coordinator") => return cmd_dispatch_coordinator(args, cfg),
        Some(other) => {
            return Err(format!("--dispatch must be coordinator|worker, got {other}"));
        }
    }
    let dir = PathBuf::from(args.get("out-dir").ok_or("--out-dir required")?);
    // Validate the output format up front: a typo must fail instantly,
    // not after a multi-hour profiling run.
    let format = args.get_or("format", "json");
    if format != "json" && format != "csv" {
        return Err(format!("--format must be json|csv, got {format}"));
    }
    let started = std::time::Instant::now();
    let spec = if args.flag("merge-only") {
        CampaignSpec::load(&dir.join(campaign::SPEC_FILE))?
    } else {
        let spec = campaign_spec_from_args(args, cfg)?;
        let total = spec.total_units();
        let workers =
            campaign::resolve_workers(args.usize_opt("workers")?, cfg.campaign_workers, total);
        let shards = campaign_shard_count(args, cfg, &dir, workers)?;
        let retry_default = campaign::RetryPolicy::default();
        let driver_cfg = DriverConfig {
            shards,
            workers,
            mode: if args.flag("in-process") {
                ExecMode::InProcess
            } else {
                ExecMode::Spawn
            },
            exe: None,
            worker_timeout: ms_opt(
                args.u64_or("worker-timeout-ms", cfg.campaign_worker_timeout_ms)?,
            ),
            retry: campaign::RetryPolicy {
                retries: args.usize_or("retries", cfg.campaign_retries)?,
                base_ms: args.u64_or("backoff-base-ms", retry_default.base_ms)?,
                cap_ms: args.u64_or("backoff-cap-ms", retry_default.cap_ms)?,
            },
        };
        let run = campaign::run_campaign(&spec, &dir, &driver_cfg)?;
        let retried = run.attempts.iter().filter(|&&(_, tries)| tries > 1).count();
        println!(
            "campaign: {} units across {} shard(s) — {} executed ({} retried), {} resumed \
             complete — on {} {}",
            total,
            run.shards,
            run.executed.len(),
            retried,
            run.skipped.len(),
            workers,
            match driver_cfg.mode {
                ExecMode::Spawn => "worker process(es)",
                ExecMode::InProcess => "in-process worker(s)",
            }
        );
        spec
    };
    merge_and_save(args, &spec, &dir, &format, started)
}

/// `campaign --dispatch coordinator`: announce the campaign into the
/// shared mailbox under `--out-dir`, supervise the worker fleet (lease
/// reclaim, retry budget, abort), then merge — bit-identical to the
/// single-process path. `--local-workers N` additionally spawns N worker
/// processes on this machine (fault-tolerant single-machine mode and the
/// CI smoke topology).
fn cmd_dispatch_coordinator(args: &Args, cfg: &ToolflowConfig) -> Result<(), String> {
    let dir = PathBuf::from(args.get("out-dir").ok_or("--out-dir required")?);
    let format = args.get_or("format", "json");
    if format != "json" && format != "csv" {
        return Err(format!("--format must be json|csv, got {format}"));
    }
    let started = std::time::Instant::now();
    let spec = campaign_spec_from_args(args, cfg)?;
    let total = spec.total_units();
    let workers =
        campaign::resolve_workers(args.usize_opt("workers")?, cfg.campaign_workers, total);
    let shards = campaign_shard_count(args, cfg, &dir, workers)?;
    let coord_cfg = campaign::CoordinatorConfig {
        shards,
        lease_timeout: std::time::Duration::from_millis(
            args.u64_or("lease-timeout-ms", cfg.dispatch_lease_timeout_ms)?.max(1),
        ),
        poll: std::time::Duration::from_millis(
            args.u64_or("poll-ms", cfg.dispatch_poll_ms)?.max(1),
        ),
        retry: dispatch_retry(args, cfg)?,
        idle_timeout: ms_opt(args.u64_or("idle-timeout-ms", cfg.dispatch_idle_timeout_ms)?),
    };
    let local = args.usize_opt("local-workers")?.unwrap_or(0);
    let mut children = Vec::with_capacity(local);
    if local > 0 {
        let exe = std::env::current_exe()
            .map_err(|e| format!("resolving current executable for --local-workers: {e}"))?;
        for i in 0..local {
            let child = std::process::Command::new(&exe)
                .arg("campaign")
                .arg("--dispatch")
                .arg("worker")
                .arg("--out-dir")
                .arg(&dir)
                .arg("--worker-id")
                .arg(format!("local-{i}-{}", std::process::id()))
                .arg("--heartbeat-ms")
                .arg(args.u64_or("heartbeat-ms", cfg.dispatch_heartbeat_ms)?.to_string())
                .arg("--poll-ms")
                .arg(coord_cfg.poll.as_millis().to_string())
                .arg("--retries")
                .arg(coord_cfg.retry.retries.to_string())
                .arg("--backoff-base-ms")
                .arg(coord_cfg.retry.base_ms.to_string())
                .arg("--backoff-cap-ms")
                .arg(coord_cfg.retry.cap_ms.to_string())
                .stdin(std::process::Stdio::null())
                .stdout(std::process::Stdio::null())
                .spawn()
                .map_err(|e| format!("spawning local dispatch worker {i}: {e}"))?;
            children.push(child);
        }
    }
    let result = campaign::run_coordinator(&spec, &dir, &coord_cfg);
    // Local workers exit on their own (campaign drained, or the abort
    // marker the failing coordinator posted); kill covers early errors
    // that never reached the mailbox.
    for mut child in children {
        if result.is_err() {
            child.kill().ok();
        }
        child.wait().ok();
    }
    let report = result?;
    println!(
        "dispatch: {} units across {} shard(s) — {} resumed complete, {} lease(s) reclaimed, \
         {} attempt record(s)",
        total,
        report.shards,
        report.resumed.len(),
        report.reclaimed.len(),
        report.attempts.iter().sum::<usize>()
    );
    merge_and_save(args, &spec, &dir, &format, started)
}

/// `campaign --dispatch worker`: park on the mailbox under `--out-dir`,
/// claim and execute shards until the campaign drains or aborts. Run any
/// number of these, on any machines sharing the directory.
fn cmd_dispatch_worker(args: &Args, cfg: &ToolflowConfig) -> Result<(), String> {
    let dir = PathBuf::from(args.get("out-dir").ok_or("--out-dir required")?);
    let mut worker_cfg = campaign::WorkerConfig {
        heartbeat: std::time::Duration::from_millis(
            args.u64_or("heartbeat-ms", cfg.dispatch_heartbeat_ms)?.max(1),
        ),
        poll: std::time::Duration::from_millis(
            args.u64_or("poll-ms", cfg.dispatch_poll_ms)?.max(1),
        ),
        retry: dispatch_retry(args, cfg)?,
        idle_timeout: ms_opt(args.u64_or("idle-timeout-ms", cfg.dispatch_idle_timeout_ms)?),
        ..Default::default()
    };
    if let Some(id) = args.get("worker-id") {
        worker_cfg.worker_id = id.to_string();
    }
    let report = campaign::run_worker(&dir, &worker_cfg)?;
    println!(
        "worker {}: executed {} shard(s) {:?}, {} failed attempt(s)",
        report.worker_id,
        report.executed.len(),
        report.executed,
        report.failed.len()
    );
    Ok(())
}

/// Hidden worker mode: execute one shard of a campaign spec file. Spawned
/// by the campaign driver (self-exec); not part of the documented CLI.
fn cmd_profile_worker(args: &Args) -> Result<(), String> {
    let spec = CampaignSpec::load(Path::new(args.get("spec").ok_or("--spec required")?))?;
    let shards = args.usize_opt("shards")?.ok_or("--shards required")?;
    let shard_index = args
        .usize_opt("shard-index")?
        .ok_or("--shard-index required")?;
    let dir = PathBuf::from(args.get("out-dir").ok_or("--out-dir required")?);
    // Anchor :once fault markers in the campaign dir so injected faults
    // fire exactly once across worker re-spawns (retry tests and drills).
    crate::util::fault::set_context_dir(&dir);
    let plans = spec.shard_plans(shards);
    let plan = plans
        .get(shard_index)
        .ok_or_else(|| format!("shard index {shard_index} out of range ({} shards)", plans.len()))?;
    campaign::write_shard(&spec, &dir, plan)
}

fn cmd_fit(args: &Args, cfg: &ToolflowConfig) -> Result<(), String> {
    let data = args.get("data").ok_or("--data required")?;
    let mut ds = Dataset::default();
    for path in data.split(',') {
        ds.extend(Dataset::load(Path::new(path.trim()))?);
    }
    if ds.is_empty() {
        return Err("empty dataset".into());
    }
    let target = args.get_or("target", "gamma");
    let y = match target.as_str() {
        "gamma" => ds.y_gamma(),
        "phi" => ds.y_phi(),
        other => return Err(format!("--target must be gamma|phi, got {other}")),
    };
    // Presort once (column-major + per-feature order), fit from the
    // borrowed view — no row-major copies of the merged dataset.
    let m = ds.train_matrix().map_err(|e| e.to_string())?;
    let forest = Forest::fit_matrix(&m, &y, &cfg.forest).map_err(|e| e.to_string())?;
    let train_err = forest.mape(&ds.x(), &y);
    let out = args.get("out").ok_or("--out required")?;
    if let Some(dir) = Path::new(out).parent() {
        // `parent()` of a bare filename is `Some("")` — nothing to create.
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| format!("creating output directory {}: {e}", dir.display()))?;
        }
    }
    std::fs::write(out, forest.to_json().to_string()).map_err(|e| e.to_string())?;
    println!(
        "fitted {} forest on {} points (train MAPE {:.2}%) → {}",
        target,
        ds.len(),
        train_err,
        out
    );
    Ok(())
}

fn cmd_predict(args: &Args, cfg: &ToolflowConfig) -> Result<(), String> {
    let model_path = args.get("model").ok_or("--model required")?;
    let text = std::fs::read_to_string(model_path).map_err(|e| e.to_string())?;
    let forest = Forest::from_json(&Json::parse(&text)?)?;
    // A second model over the same feature rows (typically the Φ latency
    // forest next to a Γ memory one): both targets are answered from one
    // fused blocked walk over the sweep's rows.
    let phi_forest = match args.get("phi-model") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
            let phi = Forest::from_json(&Json::parse(&text)?)?;
            if phi.n_features != forest.n_features {
                return Err(format!(
                    "--phi-model consumes {} features but --model consumes {}",
                    phi.n_features, forest.n_features
                ));
            }
            Some(phi)
        }
        None => None,
    };
    let network = args.get("network").ok_or("--network required")?;
    let graph = crate::models::by_name(network).ok_or_else(|| format!("unknown network {network}"))?;
    // `--level 0.3` and `--bs 32` accept comma lists (`--levels` is an
    // alias matching the profile subcommand); the full (level × bs) sweep
    // is answered by ONE pass through the blocked branch-free executor
    // (fused over both models when --phi-model is given).
    let levels = match args.f64_list("level")? {
        Some(v) => v,
        None => args.f64_list("levels")?.unwrap_or_else(|| vec![0.0]),
    };
    let batch_sizes = args.usize_list("bs")?.unwrap_or_else(|| vec![32]);
    if levels.is_empty() || batch_sizes.is_empty() {
        return Err("--level and --bs need at least one value".into());
    }
    let strategy = strategy_of(&args.get_or("strategy", "random"))?;
    let regime = regime_arg(args)?;
    let seed = args.u64_or("seed", cfg.seed)?;
    // One pruned topology + compiled plan per level (prune ⇒ rebuild plan;
    // each level prunes the original graph from a fresh seeded RNG, so a
    // single-point invocation reproduces the pre-sweep behaviour exactly).
    let pruned: Vec<_> = levels
        .iter()
        .map(|&level| {
            let mut rng = crate::util::rng::Pcg64::new(seed);
            crate::pruning::prune(&graph, strategy, level, &mut rng)
        })
        .collect();
    let mut plans = Vec::with_capacity(pruned.len());
    for g in &pruned {
        plans.push(g.plan().map_err(|e| e.to_string())?);
    }
    let mut rows = Vec::with_capacity(levels.len() * batch_sizes.len());
    for plan in &plans {
        for &bs in &batch_sizes {
            rows.push(network_features_from_plan_regime(plan, bs, regime));
        }
    }
    let (preds, phi_preds) = match &phi_forest {
        Some(phi) => {
            let (g, p) = CompiledForestPair::compile(&forest, phi).predict_rows(&rows);
            (g, Some(p))
        }
        None => (forest.compile_blocked().predict_rows(&rows), None),
    };
    // Optional ground-truth comparison on the simulated device.
    let truth_sim = if args.get("device").is_some() || args.flag("truth") {
        Some(simulator(args, cfg)?)
    } else {
        None
    };
    let mut header = vec!["level", "bs"];
    if phi_preds.is_some() {
        header.push("predicted Γ");
        header.push("predicted Φ");
    } else {
        header.push("predicted");
    }
    if truth_sim.is_some() {
        header.push("sim Γ MB");
        header.push("sim Φ ms");
    }
    let mut body = Vec::new();
    for (li, (level, plan)) in levels.iter().zip(&plans).enumerate() {
        for (bi, &bs) in batch_sizes.iter().enumerate() {
            let i = li * batch_sizes.len() + bi;
            let mut cells = vec![
                format!("{:.0}%", level * 100.0),
                format!("{bs}"),
                format!("{:.1}", preds[i]),
            ];
            if let Some(p) = &phi_preds {
                cells.push(format!("{:.1}", p[i]));
            }
            if let Some(sim) = &truth_sim {
                let m = sim.train_step_plan_regime(plan, bs, regime, None);
                cells.push(format!("{:.1}", m.gamma_mb));
                cells.push(format!("{:.1}", m.phi_ms));
            }
            body.push(cells);
        }
    }
    println!(
        "{network} ({} levels × {} batch sizes, one {} pass{}):",
        levels.len(),
        batch_sizes.len(),
        if phi_preds.is_some() {
            "fused Γ/Φ blocked"
        } else {
            "blocked branch-free"
        },
        truth_sim
            .as_ref()
            .map(|s| format!("; truth on {}", s.spec.name))
            .unwrap_or_default()
    );
    crate::util::bench_harness::table(&header, &body);
    Ok(())
}

fn cmd_search(args: &Args, cfg: &ToolflowConfig) -> Result<(), String> {
    let sim = simulator(args, cfg)?;
    let subset = match args.get_or("subset", "city").as_str() {
        "city" => Subset::City,
        "off-road" | "offroad" => Subset::OffRoad,
        "motorway" => Subset::Motorway,
        "country-side" | "countryside" => Subset::CountrySide,
        other => return Err(format!("unknown subset {other}")),
    };
    let subnets = args.usize_or("subnets", 40)?;
    let seed = args.u64_or("seed", cfg.seed)?;
    // Validate up front — a bad tenant count must not cost a model fit.
    let tenants = args.usize_opt("tenants")?;
    if tenants == Some(0) {
        return Err("--tenants must be ≥ 1".into());
    }
    println!("fitting OFA attribute models ({subnets} sampled sub-networks)…");
    let models = experiments::ofa_models::run(&sim, subnets, seed);
    experiments::ofa_models::print(&models.report);

    // The batched, cache-backed engine serves every (Γ, γ, φ) estimate:
    // each generation is answered in two blocked branch-free passes (Γ,
    // then the fused γ/φ pair), repeated candidates by a fingerprint
    // lookup.
    let mut engine = models.engine();
    let cons = Constraints {
        gamma_train_mb: args.f64_or("gamma-max", f64::INFINITY)?,
        gamma_infer_mb: args.f64_or("gamma-infer-max", f64::INFINITY)?,
        phi_infer_ms: args.f64_or("phi-max", f64::INFINITY)?,
    };
    let es_cfg = EsConfig {
        population: args.usize_or("population", 100)?,
        iterations: args.usize_or("iterations", 500)?,
        seed,
        ..Default::default()
    };
    if let Some(n) = tenants {
        return cmd_search_served(args, cfg, &models, &cons, &es_cfg, subset, n);
    }
    println!("running evolutionary search ({} × {})…", es_cfg.population, es_cfg.iterations);
    let result = crate::ofa::evolutionary_search(&cons, &es_cfg, subset, &mut engine);
    let naive_h = result.samples as f64 * crate::device::PROFILE_COST_S / 3600.0;
    println!("\nbest sub-network: {:?}", result.best);
    println!("predicted accuracy ({}): {:.1}%", subset.name(), result.best_fitness);
    println!("predicted attributes: {:?}", result.best_attrs);
    // `samples` counts attribute estimates *requested* (the paper's
    // "sub-networks sampled" figure — what naive profiling would have had
    // to measure); `unique evaluations` counts the cache misses that
    // actually ran the predictors.
    println!(
        "{} sub-networks sampled ({} unique evaluations, {} answered by the engine cache) in {:.2?}",
        result.samples,
        result.unique_evaluations,
        result.samples - result.unique_evaluations,
        result.elapsed
    );
    if let Some(cs) = result.cache {
        println!(
            "engine cache: {} hits / {} misses / {} evictions ({:.1}% hit rate, {} entries live)",
            cs.hits,
            cs.misses,
            cs.evictions,
            100.0 * cs.hit_rate(),
            cs.entries
        );
    }
    println!(
        "naive on-device profiling of all {} samples would take {:.1} h — {:.0}x slower",
        result.samples,
        naive_h,
        naive_h * 3600.0 / result.elapsed.as_secs_f64().max(1e-9)
    );
    Ok(())
}

/// `search --tenants N`: run N concurrent evolutionary searches (seeds
/// `seed..seed+N`) as tenants of one [`PredictionService`] sharing a
/// single engine — cross-tenant batch coalescing, one fingerprint cache.
/// `--verify-serial` re-runs every search serially on a fresh engine and
/// fails loudly unless the served results are byte-identical.
fn cmd_search_served(
    args: &Args,
    cfg: &ToolflowConfig,
    models: &experiments::ofa_models::OfaModels,
    cons: &Constraints,
    es_cfg: &EsConfig,
    subset: Subset,
    n_tenants: usize,
) -> Result<(), String> {
    let serve_cfg = ServeConfig {
        queue_capacity: args.usize_or("queue-capacity", cfg.serve_queue_capacity)?,
        max_coalesce: args.usize_or("coalesce", cfg.serve_max_coalesce)?,
    };
    println!(
        "serving {} concurrent searches ({} × {}) through one shared engine (queue {}, coalesce {})…",
        n_tenants,
        es_cfg.population,
        es_cfg.iterations,
        serve_cfg.queue_capacity,
        serve_cfg.max_coalesce
    );
    let service = PredictionService::spawn(models.engine(), &serve_cfg);
    // Mint every tenant here, in order: ids (and the stats table) stay
    // deterministic whatever the search threads do.
    let tenants: Vec<crate::serve::Tenant> = (0..n_tenants).map(|_| service.tenant()).collect();
    let started = std::time::Instant::now();
    let results: Vec<crate::ofa::EsResult> = std::thread::scope(|scope| {
        let handles: Vec<_> = tenants
            .into_iter()
            .enumerate()
            .map(|(i, mut tenant)| {
                let es_i = EsConfig {
                    seed: es_cfg.seed + i as u64,
                    ..es_cfg.clone()
                };
                scope.spawn(move || {
                    crate::ofa::evolutionary_search(cons, &es_i, subset, &mut tenant)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("search thread panicked"))
            .collect()
    });
    let wall = started.elapsed();
    let cache = service.cache_stats();
    let stats = service.shutdown();

    let header = ["tenant", "seed", "best acc %", "samples", "hit %", "mean wait µs", "max wait µs"];
    let body: Vec<Vec<String>> = results
        .iter()
        .zip(&stats)
        .enumerate()
        .map(|(i, (r, s))| {
            vec![
                format!("{i}"),
                format!("{}", es_cfg.seed + i as u64),
                format!("{:.1}", r.best_fitness),
                format!("{}", r.samples),
                format!("{:.1}", 100.0 * s.hit_rate()),
                format!("{:.1}", s.mean_wait_ns() / 1e3),
                format!("{:.1}", s.max_wait_ns as f64 / 1e3),
            ]
        })
        .collect();
    crate::util::bench_harness::table(&header, &body);

    let agg = TenantStats::aggregate(&stats);
    let total_samples: usize = results.iter().map(|r| r.samples).sum();
    println!(
        "aggregate: {} samples across {} tenants in {:.2?} — {:.0} estimates/s",
        total_samples,
        n_tenants,
        wall,
        total_samples as f64 / wall.as_secs_f64().max(1e-9)
    );
    println!(
        "shared cache: {} hits / {} misses ({:.1}% hit rate, {} entries); provenance: {} memo hits, {} in-flight duplicates, {} evaluated",
        cache.hits,
        cache.misses,
        100.0 * cache.hit_rate(),
        cache.entries,
        agg.cache_hits,
        agg.batch_hits,
        agg.evaluated
    );
    let best = results
        .iter()
        // total_cmp: same order as partial_cmp on the finite fitness
        // values produced here, and no panic if one ever goes NaN.
        .max_by(|a, b| a.best_fitness.total_cmp(&b.best_fitness))
        .expect("at least one tenant");
    println!("best sub-network across tenants: {:?}", best.best);
    println!("predicted accuracy ({}): {:.1}%", subset.name(), best.best_fitness);
    println!("predicted attributes: {:?}", best.best_attrs);

    if args.flag("verify-serial") {
        println!("verifying against {n_tenants} serial single-caller runs…");
        for (i, served) in results.iter().enumerate() {
            let mut engine = models.engine();
            let es_i = EsConfig {
                seed: es_cfg.seed + i as u64,
                ..es_cfg.clone()
            };
            let serial = crate::ofa::evolutionary_search(cons, &es_i, subset, &mut engine);
            if serial.deterministic_bytes() != served.deterministic_bytes() {
                return Err(format!(
                    "tenant {i} (seed {}) diverged from its serial run: served best {:?}, serial best {:?}",
                    es_i.seed, served.best, serial.best
                ));
            }
        }
        println!(
            "bit-identity verified: {n_tenants} served results match their serial runs byte for byte"
        );
    }
    Ok(())
}

fn cmd_train_demo(args: &Args, cfg: &ToolflowConfig) -> Result<(), String> {
    use crate::runtime::{Runtime, TrainState, TrainStepExecutor};
    let dir = args.get_or("artifacts", &cfg.artifacts_dir);
    let dir = Path::new(&dir);
    if !Runtime::artifacts_present(dir) {
        return Err(format!(
            "artifacts missing in {} — run `make artifacts` first",
            dir.display()
        ));
    }
    let rt = Runtime::cpu(dir).map_err(|e| e.to_string())?;
    let exec = TrainStepExecutor::new(&rt).map_err(|e| e.to_string())?;
    let steps = args.usize_or("steps", 100)?;
    let lr = args.f64_or("lr", 0.1)? as f32;
    let mut state = TrainState::init(args.u64_or("seed", cfg.seed)?);
    let mut rng = crate::util::rng::Pcg64::new(args.u64_or("seed", cfg.seed)? ^ 0xbeef);
    println!("training the L2 CNN (pallas conv kernels) through the AOT artifact…");
    for step in 0..steps {
        let (x, y) = crate::runtime::trainstep_exec::synthetic_batch(&mut rng);
        let loss = exec.step(&mut state, &x, &y, lr).map_err(|e| e.to_string())?;
        if step % 10 == 0 || step == steps - 1 {
            println!("step {step:>4}  loss {loss:.4}");
        }
    }
    Ok(())
}

fn cmd_experiment(args: &Args, cfg: &ToolflowConfig) -> Result<(), String> {
    let which = args
        .positional
        .get(1)
        .ok_or("experiment name required (fig3|fig4|fig5|table2|trainset|regimes|topology|dnnmem|ofa-models|ablation|cross-device|all)")?
        .as_str();
    let sim = simulator(args, cfg)?;
    let seed = args.u64_or("seed", cfg.seed)?;
    let quick = args.flag("quick");
    let run_one = |name: &str| -> Result<(), String> {
        match name {
            "fig3" => experiments::fig3::print(&experiments::fig3::run(&sim, seed)),
            "trainset" => experiments::trainset::print(&experiments::trainset::run(&sim, seed)),
            "topology" => experiments::topology::print(&experiments::topology::run(
                &sim,
                if quick { 20 } else { 100 },
                seed,
            )),
            "dnnmem" => experiments::dnnmem_cmp::print(&experiments::dnnmem_cmp::run(seed)),
            "regimes" => experiments::regimes::print(&experiments::regimes::run(&sim, seed)),
            "fig4" => experiments::fig4::print(&experiments::fig4::run(&sim, seed)),
            "fig5" => experiments::fig5::print(&experiments::fig5::run(&sim, seed)),
            "ofa-models" => {
                let m = experiments::ofa_models::run(&sim, if quick { 24 } else { 100 }, seed);
                experiments::ofa_models::print(&m.report);
            }
            "table2" => {
                let m = experiments::ofa_models::run(&sim, if quick { 24 } else { 100 }, seed);
                let es = if quick {
                    EsConfig {
                        population: 20,
                        iterations: 20,
                        ..Default::default()
                    }
                } else {
                    EsConfig::default()
                };
                experiments::table2::print(&experiments::table2::run(&sim, &m, &es));
            }
            "cross-device" => experiments::cross_device::print(&experiments::cross_device::run(
                &args.get_or("network", "resnet18"),
                seed,
            )),
            "ablation" => experiments::ablation::print(&experiments::ablation::run(
                &sim,
                &args.get_or("network", "resnet18"),
                seed,
            )),
            other => return Err(format!("unknown experiment {other}")),
        }
        Ok(())
    };
    if which == "all" {
        for name in [
            "fig3", "trainset", "regimes", "topology", "dnnmem", "fig4", "fig5", "ofa-models",
            "table2", "ablation", "cross-device",
        ] {
            run_one(name)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}
