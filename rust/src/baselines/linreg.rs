//! Ridge linear regression on the analytical features — the alternative
//! the paper evaluated and discarded ("Linear regression was evaluated as a
//! possibility but discarded due to poor performance", Sec. 5.2 fn. 4).
//! Kept as a baseline so the decision-tree-vs-linear comparison is
//! reproducible.

/// Solve (AᵀA + λI) w = Aᵀy by Gaussian elimination with partial pivoting.
pub fn ridge_fit(x: &[Vec<f64>], y: &[f64], lambda: f64) -> Vec<f64> {
    assert_eq!(x.len(), y.len());
    assert!(!x.is_empty());
    let n = x.len();
    let d = x[0].len() + 1; // + intercept
    // Normal equations.
    let mut a = vec![vec![0.0f64; d + 1]; d]; // augmented [AtA | Aty]
    for i in 0..n {
        let mut row = Vec::with_capacity(d);
        row.push(1.0);
        row.extend_from_slice(&x[i]);
        for r in 0..d {
            for c in 0..d {
                a[r][c] += row[r] * row[c];
            }
            a[r][d] += row[r] * y[i];
        }
    }
    for r in 0..d {
        a[r][r] += lambda;
    }
    // Gaussian elimination.
    for col in 0..d {
        // pivot
        let mut piv = col;
        for r in (col + 1)..d {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        a.swap(col, piv);
        let diag = a[col][col];
        if diag.abs() < 1e-12 {
            continue; // singular direction; ridge term should prevent this
        }
        for r in 0..d {
            if r != col {
                let factor = a[r][col] / diag;
                for c in col..=d {
                    a[r][c] -= factor * a[col][c];
                }
            }
        }
    }
    (0..d)
        .map(|r| {
            if a[r][r].abs() < 1e-12 {
                0.0
            } else {
                a[r][d] / a[r][r]
            }
        })
        .collect()
}

/// Predict with fitted weights (`w[0]` is the intercept).
pub fn ridge_predict(w: &[f64], row: &[f64]) -> f64 {
    w[0] + row.iter().zip(&w[1..]).map(|(x, c)| x * c).sum::<f64>()
}

/// Fitted linear model with feature standardisation (numerically necessary:
/// the analytical features span ~12 orders of magnitude).
#[derive(Clone, Debug)]
pub struct LinearModel {
    pub weights: Vec<f64>,
    pub mean: Vec<f64>,
    pub scale: Vec<f64>,
}

impl LinearModel {
    pub fn fit(x: &[Vec<f64>], y: &[f64], lambda: f64) -> LinearModel {
        let d = x[0].len();
        let n = x.len() as f64;
        let mut mean = vec![0.0; d];
        for row in x {
            for (m, v) in mean.iter_mut().zip(row) {
                *m += v / n;
            }
        }
        let mut scale = vec![0.0; d];
        for row in x {
            for j in 0..d {
                scale[j] += (row[j] - mean[j]) * (row[j] - mean[j]) / n;
            }
        }
        for s in &mut scale {
            *s = s.sqrt().max(1e-12);
        }
        let xs: Vec<Vec<f64>> = x
            .iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .map(|(j, v)| (v - mean[j]) / scale[j])
                    .collect()
            })
            .collect();
        let weights = ridge_fit(&xs, y, lambda);
        LinearModel {
            weights,
            mean,
            scale,
        }
    }

    pub fn predict(&self, row: &[f64]) -> f64 {
        let xs: Vec<f64> = row
            .iter()
            .enumerate()
            .map(|(j, v)| (v - self.mean[j]) / self.scale[j])
            .collect();
        ridge_predict(&self.weights, &xs)
    }

    pub fn predict_batch(&self, rows: &[Vec<f64>]) -> Vec<f64> {
        rows.iter().map(|r| self.predict(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn recovers_exact_linear_function() {
        let mut rng = Pcg64::new(1);
        let x: Vec<Vec<f64>> = (0..100)
            .map(|_| vec![rng.uniform(0.0, 10.0), rng.uniform(-5.0, 5.0)])
            .collect();
        let y: Vec<f64> = x.iter().map(|r| 3.0 + 2.0 * r[0] - 0.5 * r[1]).collect();
        let w = ridge_fit(&x, &y, 1e-9);
        assert!((w[0] - 3.0).abs() < 1e-6, "{w:?}");
        assert!((w[1] - 2.0).abs() < 1e-6);
        assert!((w[2] + 0.5).abs() < 1e-6);
        assert!((ridge_predict(&w, &[1.0, 1.0]) - 4.5).abs() < 1e-6);
    }

    #[test]
    fn standardised_model_handles_huge_scales() {
        let mut rng = Pcg64::new(2);
        let x: Vec<Vec<f64>> = (0..200)
            .map(|_| vec![rng.uniform(0.0, 1e12), rng.uniform(0.0, 1.0)])
            .collect();
        let y: Vec<f64> = x.iter().map(|r| 1e-9 * r[0] + 100.0 * r[1]).collect();
        let m = LinearModel::fit(&x, &y, 1e-6);
        let pred = m.predict(&[5e11, 0.5]);
        let truth = 1e-9 * 5e11 + 50.0;
        assert!((pred - truth).abs() / truth < 0.01, "pred={pred} truth={truth}");
    }

    #[test]
    fn ridge_shrinks_with_lambda() {
        let x = vec![vec![1.0], vec![2.0], vec![3.0]];
        let y = vec![2.0, 4.0, 6.0];
        let w_small = ridge_fit(&x, &y, 1e-9);
        let w_big = ridge_fit(&x, &y, 100.0);
        assert!(w_big[1].abs() < w_small[1].abs());
    }
}
