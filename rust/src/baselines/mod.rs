//! Comparison baselines the paper evaluates against (S8 in DESIGN.md):
//! DNNMem's analytical memory model [5], Augur's layer-wise matmul
//! regression [14], and plain linear regression on the analytical features
//! (the alternative the paper discarded).

pub mod dnnmem;
pub mod layerwise;
pub mod linreg;

pub use dnnmem::{estimate_training_memory_mb, estimate_training_memory_mb_plan, DnnMemConfig};
pub use layerwise::LayerwiseModel;
pub use linreg::LinearModel;
