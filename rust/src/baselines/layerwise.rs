//! Augur-style layer-wise predictor (Lu et al., IEEE TMC 2021 — the
//! paper's [14]). Approximates every convolution as a matrix
//! multiplication, fits per-metric linear coefficients on profiled random
//! matmul sizes, and sums layer estimates — the inference-era methodology
//! the paper argues breaks down for training (Sec. 3.1): it ignores
//! cuDNN's per-layer algorithm choices and the framework's whole-network
//! memory behaviour.

use crate::device::Simulator;
use crate::ir::{ConvInfo, Graph, GraphError, NetworkPlan};
use crate::util::rng::Pcg64;

use super::linreg::LinearModel;

/// Matmul proxy features of a conv layer at batch `bs`:
/// [macs, im2col bytes, weight bytes, output bytes].
fn matmul_features(c: &ConvInfo, bs: usize) -> Vec<f64> {
    let bsf = bs as f64;
    let macs = bsf * c.fwd_macs();
    let i2c = bsf * (c.op * c.op * c.k * c.k * c.m) as f64;
    let w = c.weight_params() as f64;
    let out = bsf * (c.n * c.op * c.op) as f64;
    vec![macs, i2c, w, out]
}

/// The fitted layer-wise model.
#[derive(Clone, Debug)]
pub struct LayerwiseModel {
    latency: LinearModel,
    memory: LinearModel,
}

impl LayerwiseModel {
    /// Calibrate on random single-conv "networks" (the Augur methodology:
    /// profile random matrix-multiplication sizes on the device).
    pub fn calibrate(sim: &Simulator, samples: usize, seed: u64) -> LayerwiseModel {
        use crate::ir::{Graph, GraphBuilder};
        let mut rng = Pcg64::new(seed);
        let mut x_lat = Vec::new();
        let mut y_lat = Vec::new();
        let mut x_mem = Vec::new();
        let mut y_mem = Vec::new();
        for _ in 0..samples {
            let m = 1usize << rng.gen_range(7); // 1..64 in channels
            let n = 8 * (1 + rng.gen_range(48)); // filters
            let k = *rng.choose(&[1usize, 3, 5, 7]);
            let ip = *rng.choose(&[7usize, 14, 28, 56, 112]);
            let bs = *rng.choose(&[2usize, 8, 32, 96, 192]);
            if ip + 2 * (k / 2) < k {
                continue;
            }
            let mut g = Graph::new("probe");
            let x = g.input(m, ip, ip);
            g.conv("conv", x, n, k, 1, k / 2);
            let Ok(info) = g.conv_infos() else { continue };
            let c = info[0];
            let feats = matmul_features(&c, bs);
            // "Profile" the single layer on the device.
            let meas = sim.train_step(&g, bs, None).expect("probe sim");
            x_lat.push(feats.clone());
            y_lat.push(meas.phi_ms);
            x_mem.push(feats);
            y_mem.push(meas.gamma_mb);
        }
        LayerwiseModel {
            latency: LinearModel::fit(&x_lat, &y_lat, 1e-6),
            memory: LinearModel::fit(&x_mem, &y_mem, 1e-6),
        }
    }

    /// Layer-wise prediction: sum per-layer estimates (latency), or sum
    /// per-layer memory minus the duplicated framework base (memory) — the
    /// double-count correction Augur applies.
    pub fn predict(&self, graph: &Graph, bs: usize) -> Result<(f64, f64), GraphError> {
        Ok(self.predict_from_convs(&graph.conv_infos()?, bs))
    }

    /// As [`LayerwiseModel::predict`] over a pre-compiled plan.
    pub fn predict_plan(&self, plan: &NetworkPlan<'_>, bs: usize) -> (f64, f64) {
        self.predict_from_convs(plan.conv_infos(), bs)
    }

    fn predict_from_convs(&self, convs: &[ConvInfo], bs: usize) -> (f64, f64) {
        let mut phi = 0.0;
        let mut gamma = 0.0;
        // Every single-layer probe bakes in the per-step framework
        // overhead (step dispatch / framework base); Augur keeps one copy
        // and sums only the marginal per-layer contributions.
        let base_mem = self.memory.predict(&[0.0, 0.0, 0.0, 0.0]);
        let base_lat = self.latency.predict(&[0.0, 0.0, 0.0, 0.0]);
        for c in convs {
            let f = matmul_features(c, bs);
            phi += (self.latency.predict(&f) - base_lat).max(0.0);
            gamma += (self.memory.predict(&f) - base_mem).max(0.0);
        }
        phi += base_lat.max(0.0);
        gamma += base_mem.max(0.0);
        (gamma, phi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn calibrated_model_is_in_the_right_decade_but_imprecise() {
        let sim = Simulator::tx2();
        let model = LayerwiseModel::calibrate(&sim, 120, 42);
        let g = models::resnet18(1000);
        let truth = sim.train_step(&g, 32, None).unwrap();
        let (gamma, phi) = model.predict(&g, 32).unwrap();
        // Right order of magnitude…
        assert!(gamma > truth.gamma_mb / 8.0 && gamma < truth.gamma_mb * 8.0);
        assert!(phi > truth.phi_ms / 8.0 && phi < truth.phi_ms * 8.0);
        // …but noticeably worse than the paper's single-digit targets
        // (this is the [14] baseline the paper beats: 12–30% error).
        let gerr = ((gamma - truth.gamma_mb) / truth.gamma_mb).abs() * 100.0;
        let perr = ((phi - truth.phi_ms) / truth.phi_ms).abs() * 100.0;
        assert!(
            gerr > 3.0 || perr > 3.0,
            "layer-wise baseline suspiciously exact: {gerr:.1}% / {perr:.1}%"
        );
    }
}
