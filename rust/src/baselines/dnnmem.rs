//! DNNMem-style analytical memory estimator (Gao et al., ESEC/FSE 2020 —
//! the paper's [5]). Reimplemented as the comparison baseline for the
//! Sec. 6.2.1 experiment.
//!
//! DNNMem estimates GPU memory from first principles: weight/gradient/
//! optimizer tensors + live activations from a liveness walk + a CUDA
//! context constant + a cuDNN workspace estimate. Its published error on
//! PyTorch is 0.6–23% (17.4% in the configuration the paper compares
//! against) because the *framework-specific* terms — caching-allocator
//! rounding and fragmentation, per-device context size, dataloader
//! residency, maxpool/dropout bookkeeping tensors, and the actual cuDNN
//! algorithm choices — are handcrafted constants rather than learned.
//! This implementation reproduces exactly that failure mode: it is a
//! correct first-principles model whose framework constants are generic.

use crate::ir::{Graph, GraphError, NetworkPlan, Op};

const BYTES: f64 = 4.0;
const MB: f64 = 1024.0 * 1024.0;

/// Handcrafted framework constants, as published (generic across devices —
/// this genericity is where the error comes from).
#[derive(Clone, Debug)]
pub struct DnnMemConfig {
    /// Assumed CUDA context + framework footprint, MB.
    pub cuda_context_mb: f64,
    /// Assumed cuDNN workspace allowance, MB.
    pub workspace_allowance_mb: f64,
}

impl Default for DnnMemConfig {
    fn default() -> Self {
        DnnMemConfig {
            cuda_context_mb: 750.0,
            workspace_allowance_mb: 64.0,
        }
    }
}

/// Estimate training memory consumption (MB) for `graph` at batch `bs`.
pub fn estimate_training_memory_mb(
    graph: &Graph,
    bs: usize,
    cfg: &DnnMemConfig,
) -> Result<f64, GraphError> {
    Ok(estimate_training_memory_mb_plan(
        &NetworkPlan::build(graph)?,
        bs,
        cfg,
    ))
}

/// As [`estimate_training_memory_mb`] over a pre-compiled plan — the
/// comparison experiment evaluates every pruned topology at 25 batch
/// sizes, so the plan amortises the liveness walk's shape inference.
pub fn estimate_training_memory_mb_plan(
    plan: &NetworkPlan<'_>,
    bs: usize,
    cfg: &DnnMemConfig,
) -> f64 {
    let graph = plan.graph();
    let shapes = plan.shapes();
    let bsf = bs as f64;

    // Weight, gradient and optimizer (momentum) tensors.
    let params = plan.param_count() as f64;
    let weight_mb = 3.0 * params * BYTES / MB;

    // Activation liveness: DNNMem walks the graph and keeps every tensor
    // needed by backward — conv/linear/BN inputs and activation outputs —
    // but models them as exact tensor sizes (no allocator rounding) and
    // misses framework bookkeeping (maxpool indices, dropout masks,
    // dataloader buffers).
    let mut retained = vec![false; graph.len()];
    for node in &graph.nodes {
        match &node.op {
            Op::Conv2d { .. } | Op::Linear { .. } | Op::BatchNorm => {
                retained[node.inputs[0]] = true;
            }
            Op::Activation(_) => {
                retained[node.id] = true;
            }
            _ => {}
        }
    }
    let activations: f64 = graph
        .nodes
        .iter()
        .filter(|n| retained[n.id])
        .map(|n| bsf * shapes[n.id].numel() as f64 * BYTES)
        .sum();
    let act_mb = activations / MB;

    // Input batch.
    let input_mb = bsf * shapes[0].numel() as f64 * BYTES / MB;

    cfg.cuda_context_mb + weight_mb + act_mb + cfg.workspace_allowance_mb + input_mb
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceSpec, Simulator};
    use crate::models;

    #[test]
    fn estimate_is_positive_and_scales_with_bs() {
        let g = models::resnet50(1000);
        let cfg = DnnMemConfig::default();
        let m8 = estimate_training_memory_mb(&g, 8, &cfg).unwrap();
        let m64 = estimate_training_memory_mb(&g, 64, &cfg).unwrap();
        assert!(m8 > 0.0);
        assert!(m64 > 4.0 * m8 - cfg.cuda_context_mb * 4.0);
    }

    #[test]
    fn dnnmem_error_on_server_gpu_is_double_digit() {
        // The Sec. 6.2.1 setting: ResNet50 on the (simulated) RTX 2080Ti.
        // DNNMem's handcrafted constants should miss by >8% on average —
        // the gap perf4sight's learned models close.
        let sim = Simulator::new(DeviceSpec::rtx2080ti());
        let g = models::resnet50(1000);
        let cfg = DnnMemConfig::default();
        let mut errs = Vec::new();
        for bs in [8usize, 16, 32, 64] {
            let truth = sim.train_step(&g, bs, None).unwrap().gamma_mb;
            let est = estimate_training_memory_mb(&g, bs, &cfg).unwrap();
            errs.push(((est - truth) / truth).abs() * 100.0);
        }
        let mean = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mean > 5.0, "DNNMem too accurate?! mean err = {mean}%");
        assert!(mean < 60.0, "DNNMem absurdly wrong: {mean}%");
    }
}
