//! Campaign specification: the full zoo-scale profiling work grid
//! (networks × strategies × levels × batch sizes), its canonical unit
//! order, and the deterministic partition into shards.
//!
//! The canonical order is the concatenation, network-major then
//! strategy-major, of the profiler's level-major / batch-size-minor
//! schedule — i.e. exactly what running [`crate::profiler::profile`] per
//! (network, strategy) pair in spec order would produce. Unit ids index
//! that order, so any partition of the id space can be merged back into
//! the canonical dataset without re-sorting ambiguity.

use std::path::Path;

use crate::device::{DeviceSpec, Simulator, TrainRegime};
use crate::pruning::Strategy;
use crate::util::json::Json;
use crate::util::rng::hash_seed;

/// File name of the serialised spec inside a campaign output directory.
pub const SPEC_FILE: &str = "spec.json";

/// The full profiling campaign: every (network × strategy × regime × level
/// × batch size) point to measure, plus the measurement parameters.
/// Serialisable, fingerprintable, and shardable — the unit of work
/// distribution.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignSpec {
    pub networks: Vec<String>,
    pub strategies: Vec<Strategy>,
    /// Training regimes to sweep. `[Vanilla]` reproduces the historical
    /// grid (and the historical spec JSON / fingerprint bytes).
    pub regimes: Vec<TrainRegime>,
    pub levels: Vec<f64>,
    pub batch_sizes: Vec<usize>,
    /// Noisy measurements averaged per datapoint.
    pub runs: usize,
    /// Base seed; identical to [`crate::profiler::ProfileJob::seed`]
    /// semantics, so campaign output is bit-compatible with `profile()`.
    pub seed: u64,
    /// Simulated device preset name ([`DeviceSpec::by_name`]).
    pub device: String,
}

/// One resolved work unit of a campaign: a single (network, strategy,
/// level, batch size) datapoint plus the indices needed to resume the
/// level's RNG stream at the right offset.
#[derive(Clone, Copy, Debug)]
pub struct CampaignUnit<'a> {
    pub id: usize,
    pub network: &'a str,
    pub strategy: Strategy,
    pub regime: TrainRegime,
    pub level: f64,
    pub bs: usize,
    pub net_index: usize,
    pub strategy_index: usize,
    pub regime_index: usize,
    pub level_index: usize,
    /// Position of `bs` within the spec's batch-size list — the RNG
    /// fast-forward offset within the level's measurement stream.
    pub bs_index: usize,
}

/// A contiguous slice of the canonical unit order, assigned to one worker
/// execution. `count` is the partition width the plan was cut from; a
/// worker re-deriving the partition from (spec, count, index) lands on the
/// same unit list.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardPlan {
    pub index: usize,
    pub count: usize,
    pub units: Vec<usize>,
}

impl CampaignSpec {
    /// Check the spec is executable: known networks and device, non-empty
    /// grid axes, sane levels.
    pub fn validate(&self) -> Result<(), String> {
        if self.networks.is_empty() {
            return Err("campaign spec: no networks".into());
        }
        for n in &self.networks {
            if crate::models::by_name(n).is_none() {
                return Err(format!("campaign spec: unknown network {n:?}"));
            }
        }
        if self.strategies.is_empty() {
            return Err("campaign spec: no strategies".into());
        }
        if self.regimes.is_empty() {
            return Err("campaign spec: no training regimes".into());
        }
        for r in &self.regimes {
            r.validate().map_err(|e| format!("campaign spec: {e}"))?;
        }
        if self.levels.is_empty() {
            return Err("campaign spec: no levels".into());
        }
        for &l in &self.levels {
            if !(0.0..1.0).contains(&l) {
                return Err(format!("campaign spec: level {l} outside [0,1)"));
            }
        }
        if self.batch_sizes.is_empty() {
            return Err("campaign spec: no batch sizes".into());
        }
        if self.batch_sizes.contains(&0) {
            return Err("campaign spec: batch size 0".into());
        }
        if self.runs == 0 {
            return Err("campaign spec: runs must be ≥ 1".into());
        }
        if DeviceSpec::by_name(&self.device).is_none() {
            return Err(format!(
                "campaign spec: unknown device {:?} (tx2, xavier, 2080ti)",
                self.device
            ));
        }
        Ok(())
    }

    /// The simulated device the spec targets.
    pub fn simulator(&self) -> Result<Simulator, String> {
        DeviceSpec::by_name(&self.device)
            .map(Simulator::new)
            .ok_or_else(|| format!("unknown device {:?}", self.device))
    }

    /// Total number of work units in the grid.
    pub fn total_units(&self) -> usize {
        self.networks.len()
            * self.strategies.len()
            * self.regimes.len()
            * self.levels.len()
            * self.batch_sizes.len()
    }

    /// Resolve unit `id` in the canonical order (network-major, then
    /// strategy, then regime, then level, batch size minor).
    pub fn unit(&self, id: usize) -> CampaignUnit<'_> {
        assert!(id < self.total_units(), "unit id {id} out of range");
        let b = self.batch_sizes.len();
        let l = self.levels.len();
        let r = self.regimes.len();
        let s = self.strategies.len();
        let bs_index = id % b;
        let level_index = (id / b) % l;
        let regime_index = (id / (b * l)) % r;
        let strategy_index = (id / (b * l * r)) % s;
        let net_index = id / (b * l * r * s);
        CampaignUnit {
            id,
            network: &self.networks[net_index],
            strategy: self.strategies[strategy_index],
            regime: self.regimes[regime_index],
            level: self.levels[level_index],
            bs: self.batch_sizes[bs_index],
            net_index,
            strategy_index,
            regime_index,
            level_index,
            bs_index,
        }
    }

    /// Deterministically partition the unit space into `count` contiguous,
    /// balanced shards. Boundaries are aligned to whole (network,
    /// strategy, level) groups — one group is one pruned topology × all
    /// batch sizes — so no topology is ever pruned and planned twice
    /// across shards; `count` therefore clamps to the group count.
    pub fn shard_plans(&self, count: usize) -> Vec<ShardPlan> {
        let group = self.batch_sizes.len().max(1);
        let groups = self.total_units() / group;
        let count = count.clamp(1, groups.max(1));
        (0..count)
            .map(|index| ShardPlan {
                index,
                count,
                units: (index * groups / count * group..(index + 1) * groups / count * group)
                    .collect(),
            })
            .collect()
    }

    /// Stable 64-bit fingerprint of the spec — the manifest invalidation
    /// key: any change to the grid or measurement parameters produces a
    /// different fingerprint, so shard files can never be merged across
    /// campaigns.
    pub fn fingerprint(&self) -> u64 {
        hash_seed(&self.to_json().to_string())
    }

    // ---------- persistence ----------

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("networks", Json::arr_str(&self.networks)),
            (
                "strategies",
                Json::arr_str(
                    &self
                        .strategies
                        .iter()
                        .map(|s| s.name())
                        .collect::<Vec<_>>(),
                ),
            ),
        ];
        // A vanilla-only sweep serialises without the key so historical
        // spec files and fingerprints stay byte-identical (resumable dirs).
        if self.regimes != [TrainRegime::Vanilla] {
            fields.push((
                "regimes",
                Json::arr_str(&self.regimes.iter().map(|r| r.name()).collect::<Vec<_>>()),
            ));
        }
        fields.extend([
            ("levels", Json::arr_f64(&self.levels)),
            ("batch_sizes", Json::arr_usize(&self.batch_sizes)),
            ("runs", Json::Num(self.runs as f64)),
            // Hex string: u64 seeds are not exactly representable as f64.
            ("seed", Json::Str(format!("{:016x}", self.seed))),
            ("device", Json::Str(self.device.clone())),
        ]);
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<CampaignSpec, String> {
        let str_list = |key: &str| -> Result<Vec<String>, String> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("campaign spec: missing {key}"))?
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| format!("campaign spec: {key} entries must be strings"))
                })
                .collect()
        };
        let strategies = str_list("strategies")?
            .iter()
            .map(|s| {
                Strategy::from_name(s)
                    .ok_or_else(|| format!("campaign spec: unknown strategy {s:?}"))
            })
            .collect::<Result<Vec<_>, _>>()?;
        // Missing key ⇒ pre-regime spec ⇒ vanilla-only sweep.
        let regimes = if j.get("regimes").is_some() {
            str_list("regimes")?
                .iter()
                .map(|r| {
                    TrainRegime::from_name(r)
                        .ok_or_else(|| format!("campaign spec: unknown training regime {r:?}"))
                })
                .collect::<Result<Vec<_>, _>>()?
        } else {
            vec![TrainRegime::Vanilla]
        };
        let batch_sizes = j
            .get("batch_sizes")
            .and_then(Json::as_arr)
            .ok_or("campaign spec: missing batch_sizes")?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| "campaign spec: batch_sizes must be integers".to_string())
            })
            .collect::<Result<Vec<_>, _>>()?;
        let seed = match j.get("seed") {
            Some(Json::Str(s)) => u64::from_str_radix(s.trim_start_matches("0x"), 16)
                .map_err(|e| format!("campaign spec: bad seed {s:?}: {e}"))?,
            Some(v) => v
                .as_f64()
                .map(|x| x as u64)
                .ok_or("campaign spec: bad seed")?,
            None => return Err("campaign spec: missing seed".into()),
        };
        Ok(CampaignSpec {
            networks: str_list("networks")?,
            strategies,
            regimes,
            levels: j
                .get("levels")
                .and_then(Json::f64_vec)
                .ok_or("campaign spec: missing levels")?,
            batch_sizes,
            runs: j
                .get("runs")
                .and_then(Json::as_usize)
                .ok_or("campaign spec: missing runs")?,
            seed,
            device: j
                .get("device")
                .and_then(Json::as_str)
                .ok_or("campaign spec: missing device")?
                .to_string(),
        })
    }

    pub fn save(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| format!("writing campaign spec {}: {e}", path.display()))
    }

    pub fn load(path: &Path) -> Result<CampaignSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading campaign spec {}: {e}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| format!("corrupt campaign spec {}: {e}", path.display()))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CampaignSpec {
        CampaignSpec {
            networks: vec!["squeezenet".into(), "mnasnet".into()],
            strategies: vec![Strategy::Random, Strategy::L1Norm],
            regimes: vec![TrainRegime::Vanilla],
            levels: vec![0.0, 0.3, 0.5],
            batch_sizes: vec![4, 16],
            runs: 2,
            seed: 0x9e1f,
            device: "tx2".into(),
        }
    }

    #[test]
    fn canonical_order_matches_nested_loops() {
        let mut s = spec();
        s.regimes = vec![
            TrainRegime::Vanilla,
            TrainRegime::Checkpointed { segments: 4 },
        ];
        assert_eq!(s.total_units(), 2 * 2 * 2 * 3 * 2);
        let mut id = 0;
        for (ni, net) in s.networks.iter().enumerate() {
            for (si, &strat) in s.strategies.iter().enumerate() {
                for (ri, &regime) in s.regimes.iter().enumerate() {
                    for (li, &level) in s.levels.iter().enumerate() {
                        for (bi, &bs) in s.batch_sizes.iter().enumerate() {
                            let u = s.unit(id);
                            assert_eq!(u.network, net);
                            assert_eq!(u.strategy, strat);
                            assert_eq!(u.regime, regime);
                            assert_eq!(u.level, level);
                            assert_eq!(u.bs, bs);
                            assert_eq!(
                                (
                                    u.net_index,
                                    u.strategy_index,
                                    u.regime_index,
                                    u.level_index,
                                    u.bs_index
                                ),
                                (ni, si, ri, li, bi)
                            );
                            id += 1;
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn shards_partition_exactly_on_group_boundaries() {
        let s = spec();
        let group = s.batch_sizes.len();
        for count in [1, 2, 3, 5, 7, 24, 100] {
            let plans = s.shard_plans(count);
            assert!(plans.len() * group <= s.total_units());
            let mut seen = Vec::new();
            for p in &plans {
                assert_eq!(p.count, plans.len());
                // Aligned starts: a (network, strategy, level) topology is
                // never split across shards.
                assert_eq!(p.units[0] % group, 0, "count={count}");
                seen.extend(p.units.iter().copied());
            }
            assert_eq!(seen, (0..s.total_units()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn json_roundtrip_preserves_fingerprint() {
        let s = spec();
        let back = CampaignSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.fingerprint(), s.fingerprint());
    }

    #[test]
    fn fingerprint_changes_with_any_field() {
        let base = spec();
        let mut seeded = base.clone();
        seeded.seed ^= 1;
        let mut leveled = base.clone();
        leveled.levels.push(0.7);
        let mut dev = base.clone();
        dev.device = "xavier".into();
        for other in [seeded, leveled, dev] {
            assert_ne!(base.fingerprint(), other.fingerprint());
        }
    }

    #[test]
    fn vanilla_spec_json_and_fingerprint_match_pre_regime_bytes() {
        // The serialised form of a vanilla-only spec must not mention
        // regimes at all — old campaign directories stay resumable.
        let s = spec();
        let j = s.to_json().to_string();
        assert!(!j.contains("regimes"), "{j}");
        // A pre-regime spec file (no "regimes" key) loads as vanilla-only
        // and round-trips to the same fingerprint.
        let back = CampaignSpec::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.regimes, vec![TrainRegime::Vanilla]);
        assert_eq!(back.fingerprint(), s.fingerprint());
    }

    #[test]
    fn regime_axis_round_trips_and_changes_fingerprint() {
        let base = spec();
        let mut swept = base.clone();
        swept.regimes = vec![
            TrainRegime::Vanilla,
            TrainRegime::Checkpointed { segments: 4 },
            TrainRegime::Frozen { trainable_suffix: 2 },
        ];
        assert_ne!(swept.fingerprint(), base.fingerprint());
        let back = CampaignSpec::from_json(&swept.to_json()).unwrap();
        assert_eq!(back, swept);
        assert_eq!(back.fingerprint(), swept.fingerprint());
    }

    #[test]
    fn validate_rejects_bad_specs() {
        let mut s = spec();
        s.networks = vec!["lenet".into()];
        assert!(s.validate().is_err());
        let mut s = spec();
        s.levels = vec![1.5];
        assert!(s.validate().is_err());
        let mut s = spec();
        s.device = "a100".into();
        assert!(s.validate().is_err());
        let mut s = spec();
        s.batch_sizes.clear();
        assert!(s.validate().is_err());
        let mut s = spec();
        s.regimes.clear();
        assert!(s.validate().is_err());
        let mut s = spec();
        s.regimes = vec![TrainRegime::Checkpointed { segments: 0 }];
        assert!(s.validate().is_err());
        assert!(spec().validate().is_ok());
    }
}
