//! Campaign execution: shard-scoped profiling (reusing the profiler's
//! RNG-offset machinery, so any unit computes the same bits anywhere), a
//! resumable work-stealing driver, and the worker-process spawn path.
//!
//! The driver drains pending shards through a fixed number of lanes; each
//! lane pulls the next un-done shard from a shared cursor (work stealing —
//! a slow shard never blocks the others). In [`ExecMode::Spawn`] a lane
//! runs the shard in a spawned worker *process* (the binary re-executed in
//! its hidden `profile-worker` mode); in [`ExecMode::InProcess`] it runs
//! on a thread of the current process.
//!
//! Resume: every manifest in the output dir is first validated against
//! the spec fingerprint and the requested partition (stale or foreign
//! shard files fail loudly); a shard is then complete iff its manifest
//! and dataset files are present. Complete shards are skipped on
//! re-runs; missing shard files are simply re-executed (workers write
//! the dataset before the manifest — the manifest itself atomically —
//! so a crash can never leave a manifest without its full data).

use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

use crate::ir::{GraphArena, PlanBuffers};
use crate::profiler::{level_stream, profile_unit, Dataset, ProfilePoint};
use crate::pruning::prune_overlay;
use crate::util::atomic_fs::{publish_new, remove_stale_tmp};
use crate::util::backoff::{shard_salt, RetryPolicy};
use crate::util::fault::{self, FaultPoint};
use crate::util::pool::drain_indexed;
use crate::util::rng::Pcg64;

use super::manifest::{shard_dataset_name, shard_manifest_path, ShardManifest};
use super::spec::{CampaignSpec, ShardPlan, SPEC_FILE};

/// How the driver executes a shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Spawn worker processes (self-exec via the hidden `profile-worker`
    /// CLI mode).
    Spawn,
    /// Run shards on threads of the current process.
    InProcess,
}

/// Driver configuration.
#[derive(Clone, Debug)]
pub struct DriverConfig {
    /// Number of shards to cut the campaign into (clamped to the unit
    /// count).
    pub shards: usize,
    /// Concurrent lanes draining the shard queue (worker processes in
    /// [`ExecMode::Spawn`], threads in [`ExecMode::InProcess`]).
    pub workers: usize,
    pub mode: ExecMode,
    /// Binary to self-exec in [`ExecMode::Spawn`]; `None` uses
    /// `std::env::current_exe()` (correct when running as the perf4sight
    /// CLI; test harnesses pass their `CARGO_BIN_EXE_perf4sight`).
    pub exe: Option<PathBuf>,
    /// Wall-clock budget per spawned worker process; a worker exceeding
    /// it is killed and its shard charged a failed attempt. `None` waits
    /// forever; ignored in [`ExecMode::InProcess`] (threads cannot be
    /// killed safely).
    pub worker_timeout: Option<Duration>,
    /// Per-shard retry budget + backoff for failed shard executions.
    /// `retries: 0` fails fast on the first error.
    pub retry: RetryPolicy,
}

/// What a driver run did — which shards executed and which were resumed
/// as already complete.
#[derive(Clone, Debug)]
pub struct CampaignRun {
    /// Actual partition width after clamping.
    pub shards: usize,
    pub executed: Vec<usize>,
    pub skipped: Vec<usize>,
    /// `(shard, attempts)` for every shard this run executed — attempts
    /// above 1 mean the retry policy absorbed transient failures.
    pub attempts: Vec<(usize, usize)>,
}

/// Execute one shard's units in canonical order. Consecutive units of the
/// same network share one compiled [`GraphArena`]; each (network,
/// strategy, level) group prunes as an overlay whose analysis rebuilds
/// *incrementally* into shard-local plan buffers (no graph clone, no
/// from-scratch inference — the per-unit prep cost of a campaign). Every
/// unit fast-forwards the level's measurement stream to its sequential
/// offset, so output bits match the single-process
/// [`crate::profiler::profile`] path exactly.
pub fn execute_shard(spec: &CampaignSpec, shard: &ShardPlan) -> Result<Vec<ProfilePoint>, String> {
    fault::check(FaultPoint::ShardStart, Some(shard.index))?;
    spec.validate()?;
    let sim = spec.simulator()?;
    let mut points = Vec::with_capacity(shard.units.len());
    let mut current: Option<(usize, GraphArena)> = None;
    let mut buffers = PlanBuffers::new();
    let mut i = 0;
    while i < shard.units.len() {
        let head = spec.unit(shard.units[i]);
        if current.as_ref().map(|&(ni, _)| ni) != Some(head.net_index) {
            let graph = crate::models::by_name(head.network)
                .ok_or_else(|| format!("unknown network {:?}", head.network))?;
            let arena = GraphArena::compile(&graph)
                .map_err(|e| format!("compiling arena for {}: {e}", head.network))?;
            current = Some((head.net_index, arena));
        }
        let (_, arena) = current.as_ref().expect("arena compiled above");
        // The RNG stream is keyed on (network, strategy, level) only —
        // regimes deliberately share the level's pruning and noise draws,
        // exactly like the sequential profiler, so the group key gains the
        // regime index (regime-specific measurement entry points) while the
        // stream derivation stays unchanged.
        let mut rng = Pcg64::with_stream(
            spec.seed,
            level_stream(head.network, head.strategy, head.level),
        );
        let overlay = prune_overlay(arena, head.strategy, head.level, &mut rng);
        arena
            .plan_into(&overlay, &mut buffers)
            .map_err(|e| format!("planning pruned {}: {e}", head.network))?;
        let plan = arena.view_buffers(&buffers);
        while i < shard.units.len() {
            let u = spec.unit(shard.units[i]);
            if (u.net_index, u.strategy_index, u.regime_index, u.level_index)
                != (
                    head.net_index,
                    head.strategy_index,
                    head.regime_index,
                    head.level_index,
                )
            {
                break;
            }
            if i == shard.units.len() / 2 {
                fault::check(FaultPoint::MidShard, Some(shard.index))?;
            }
            points.push(profile_unit(
                &sim, u.network, u.strategy, u.regime, spec.runs, &plan, u.level, &rng,
                u.bs_index, u.bs,
            ));
            i += 1;
        }
    }
    Ok(points)
}

/// Execute a shard and checkpoint it: dataset file first, manifest last
/// (the manifest's existence is the completeness marker the driver and
/// merge step trust).
pub fn write_shard(spec: &CampaignSpec, dir: &Path, shard: &ShardPlan) -> Result<(), String> {
    let points = execute_shard(spec, shard)?;
    let dataset = shard_dataset_name(shard.index);
    Dataset::new(points)
        .save(&dir.join(&dataset))
        .map_err(|e| e.to_string())?;
    // Crash window under test: dataset on disk, manifest not yet — the
    // shard must count as incomplete and re-execute to identical bytes.
    fault::check(FaultPoint::PreManifest, Some(shard.index))?;
    let manifest = ShardManifest {
        fingerprint: spec.fingerprint(),
        shard_index: shard.index,
        shard_count: shard.count,
        dataset,
        units: shard.units.clone(),
    };
    manifest.save(&shard_manifest_path(dir, shard.index))
}

/// Write `spec.json` into the campaign dir, or verify an existing one
/// matches. Returns the spec path. Publication is crash-atomic and
/// first-writer-wins ([`publish_new`]), then *always* verified by
/// re-loading — concurrent invocations (racing coordinators, a worker
/// beating the coordinator to the dir) converge or fail loudly, and no
/// reader ever observes a torn spec.
pub fn ensure_spec_file(spec: &CampaignSpec, dir: &Path) -> Result<PathBuf, String> {
    std::fs::create_dir_all(dir)
        .map_err(|e| format!("creating campaign dir {}: {e}", dir.display()))?;
    let path = dir.join(SPEC_FILE);
    publish_new(&path, &spec.to_json().to_string())
        .map_err(|e| format!("writing campaign spec {}: {e}", path.display()))?;
    let existing = CampaignSpec::load(&path)?;
    if existing.fingerprint() != spec.fingerprint() {
        return Err(format!(
            "campaign dir {} already holds a different spec (fingerprint {:016x}, \
             expected {:016x}); use a fresh --out-dir or delete its shard files",
            dir.display(),
            existing.fingerprint(),
            spec.fingerprint()
        ));
    }
    Ok(path)
}

/// The partition width recorded by a previous run's manifests under
/// `dir`, if any (first readable manifest in sorted order, so the answer
/// is deterministic). Lets an auto-sharded campaign resume under
/// different parallelism (other machine, changed `PERF4SIGHT_WORKERS`)
/// instead of erroring on a partition mismatch; unreadable manifests are
/// left for [`run_campaign`] to report properly.
pub fn existing_shard_count(dir: &Path) -> Option<usize> {
    super::merge::manifest_paths(dir)
        .ok()?
        .into_iter()
        .find_map(|p| ShardManifest::load(&p).ok().map(|m| m.shard_count))
}

/// Validate every checkpointed manifest under `dir` against this spec
/// and partition. Stale files from a different campaign, or from an
/// older partition (e.g. a crashed run re-invoked with another
/// `--shards`), must fail loudly here — not silently coexist with the
/// new partition's shards and wedge the merge with duplicate-coverage
/// errors later.
pub(crate) fn validate_existing_manifests(
    dir: &Path,
    fingerprint: u64,
    plans: &[ShardPlan],
) -> Result<(), String> {
    for mpath in super::merge::manifest_paths(dir)? {
        let m = ShardManifest::load(&mpath)?;
        if m.fingerprint != fingerprint {
            return Err(format!(
                "shard manifest {} belongs to a different campaign (fingerprint {:016x}, \
                 expected {:016x}); use a fresh --out-dir or delete the stale shard files",
                mpath.display(),
                m.fingerprint,
                fingerprint
            ));
        }
        let aligned = m.shard_count == plans.len()
            && plans
                .get(m.shard_index)
                .is_some_and(|p| p.units == m.units);
        if !aligned {
            return Err(format!(
                "shard manifest {} was written for a different partition ({} shards); \
                 re-run with --shards {} or use a fresh --out-dir",
                mpath.display(),
                m.shard_count,
                m.shard_count
            ));
        }
    }
    Ok(())
}

/// Is this shard already checkpointed? Its manifest was validated against
/// the spec and partition up front, and a manifest is only ever written
/// after its dataset (atomically), so completeness is just "both files
/// present" — no dataset parse; every point is re-verified at merge time
/// anyway.
pub(crate) fn shard_complete(dir: &Path, shard: &ShardPlan) -> bool {
    shard_manifest_path(dir, shard.index).exists()
        && dir.join(shard_dataset_name(shard.index)).exists()
}

/// Run a campaign to completion under `dir`: partition, skip checkpointed
/// shards, and drain the rest work-stealing style through
/// `cfg.workers` lanes. Idempotent — re-running after a crash resumes
/// where the last run stopped.
pub fn run_campaign(
    spec: &CampaignSpec,
    dir: &Path,
    cfg: &DriverConfig,
) -> Result<CampaignRun, String> {
    spec.validate()?;
    if cfg.shards == 0 {
        return Err("campaign driver: shard count must be ≥ 1".into());
    }
    fault::set_context_dir(dir);
    let spec_path = ensure_spec_file(spec, dir)?;
    // Leftover temp files from crashed writers are inert (never matched
    // by manifest/dataset readers) but untidy; sweep them best-effort.
    remove_stale_tmp(dir).ok();
    let fingerprint = spec.fingerprint();
    let plans = spec.shard_plans(cfg.shards);
    validate_existing_manifests(dir, fingerprint, &plans)?;
    let mut pending = Vec::new();
    let mut skipped = Vec::new();
    for plan in &plans {
        if shard_complete(dir, plan) {
            skipped.push(plan.index);
        } else {
            pending.push(plan.clone());
        }
    }
    let executed: Vec<usize> = pending.iter().map(|p| p.index).collect();
    let exe: Option<PathBuf> = match cfg.mode {
        ExecMode::InProcess => None,
        ExecMode::Spawn => Some(match &cfg.exe {
            Some(p) => p.clone(),
            None => std::env::current_exe()
                .map_err(|e| format!("resolving current executable for worker spawn: {e}"))?,
        }),
    };
    let workers = cfg.workers.clamp(1, pending.len().max(1));
    // Every pending shard is attempted even when a sibling fails: whatever
    // completes is checkpointed for the next resume, and all failures are
    // reported together — each with its attempt count, so flaky-but-
    // absorbed shards are distinguishable from first-try successes.
    let outcomes = drain_indexed(pending.len(), workers, |i| {
        let shard = &pending[i];
        let mut failures = 0usize;
        loop {
            let result = match &exe {
                Some(exe) => spawn_worker(exe, &spec_path, dir, shard, cfg.worker_timeout),
                None => write_shard(spec, dir, shard),
            };
            match result {
                Ok(()) => return (failures + 1, Ok(())),
                Err(e) => {
                    failures += 1;
                    if failures >= cfg.retry.max_attempts() {
                        let err = format!(
                            "shard {} failed after {failures} attempt(s): {e}",
                            shard.index
                        );
                        return (failures, Err(err));
                    }
                    let salt = shard_salt(fingerprint, shard.index, failures);
                    std::thread::sleep(cfg.retry.delay(failures, salt));
                }
            }
        }
    });
    let mut attempts = Vec::with_capacity(outcomes.len());
    let mut errors = Vec::new();
    for (i, (tries, result)) in outcomes {
        attempts.push((pending[i].index, tries));
        if let Err(e) = result {
            errors.push(e);
        }
    }
    attempts.sort_unstable();
    if !errors.is_empty() {
        return Err(errors.join("\n"));
    }
    Ok(CampaignRun {
        shards: plans.len(),
        executed,
        skipped,
        attempts,
    })
}

/// Run one shard in a spawned worker process via the hidden
/// `profile-worker` CLI mode. With a `timeout`, a worker that exceeds it
/// (hung GPU driver, deadlocked allocator, injected hang) is killed and
/// reported as a named failure — a hung child must never wedge the whole
/// campaign.
fn spawn_worker(
    exe: &Path,
    spec_path: &Path,
    dir: &Path,
    shard: &ShardPlan,
    timeout: Option<Duration>,
) -> Result<(), String> {
    let mut child = Command::new(exe)
        .arg("profile-worker")
        .arg("--spec")
        .arg(spec_path)
        .arg("--shards")
        .arg(shard.count.to_string())
        .arg("--shard-index")
        .arg(shard.index.to_string())
        .arg("--out-dir")
        .arg(dir)
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::piped())
        .spawn()
        .map_err(|e| format!("spawning worker for shard {}: {e}", shard.index))?;
    // Drain stderr on its own thread: a chatty worker filling the pipe
    // while we only poll `try_wait` would deadlock both processes.
    let mut stderr = child.stderr.take().expect("stderr was piped above");
    let drain = std::thread::spawn(move || {
        use std::io::Read;
        let mut buf = String::new();
        stderr.read_to_string(&mut buf).ok();
        buf
    });
    let started = Instant::now();
    let status = loop {
        match child.try_wait() {
            Ok(Some(status)) => break status,
            Ok(None) => {}
            Err(e) => {
                child.kill().ok();
                child.wait().ok();
                drain.join().ok();
                return Err(format!("waiting on worker for shard {}: {e}", shard.index));
            }
        }
        if let Some(limit) = timeout {
            if started.elapsed() > limit {
                child.kill().ok();
                child.wait().ok();
                drain.join().ok();
                return Err(format!(
                    "worker process for shard {} timed out after {limit:?} and was killed",
                    shard.index
                ));
            }
        }
        std::thread::sleep(Duration::from_millis(15));
    };
    let stderr_text = drain.join().unwrap_or_default();
    if !status.success() {
        return Err(format!(
            "worker process for shard {} failed ({}): {}",
            shard.index,
            status,
            stderr_text.trim()
        ));
    }
    Ok(())
}
