//! Sharded, resumable profiling campaigns — the job system that turns the
//! paper's Sec. 5.1 profiling sweeps from a single-process function call
//! into a crash-tolerant, machine-saturating pipeline.
//!
//! A [`CampaignSpec`] names the full (networks × strategies × levels ×
//! batch sizes) grid and is deterministically partitioned into
//! [`ShardPlan`]s over a canonical unit order. The [`driver`] drains
//! shards work-stealing style, either on in-process threads or across
//! spawned worker processes (the binary self-exec'd in its hidden
//! `profile-worker` CLI mode); each shard checkpoints a dataset file plus
//! a fingerprinted [`ShardManifest`]. The [`merge()`] step validates
//! completeness against the manifests and reassembles the canonical
//! dataset.
//!
//! Invariant: because every profiling unit fast-forwards its level's RNG
//! stream to the exact offset the sequential order would have reached
//! (the profiler's `NOISE_DRAWS_PER_MEASUREMENT` machinery), a merged
//! campaign is **bit-identical** — JSON bytes included — to running
//! [`crate::profiler::profile`] per (network, strategy) in one process,
//! for *any* shard count and *any* worker placement. Invalidation rule:
//! any spec change ⇒ new fingerprint ⇒ stale shard files are rejected
//! instead of merged.

pub mod dispatch;
pub mod driver;
pub mod manifest;
pub mod merge;
pub mod spec;

pub use crate::util::backoff::RetryPolicy;
pub use dispatch::{
    run_coordinator, run_worker, CoordinatorConfig, DispatchReport, WorkerConfig, WorkerReport,
};
pub use driver::{
    ensure_spec_file, execute_shard, existing_shard_count, run_campaign, write_shard,
    CampaignRun, DriverConfig, ExecMode,
};
pub use manifest::ShardManifest;
pub use merge::{merge, merge_dir};
pub use spec::{CampaignSpec, CampaignUnit, ShardPlan, SPEC_FILE};

use crate::profiler::{profile, worker_width, Dataset, ProfileJob};
use crate::util::pool::drain_indexed;

/// The single-process reference path: one [`profile`] call per
/// (network, strategy, regime) triple in spec order. This is the oracle
/// every sharded execution must reproduce bitwise.
pub fn profile_campaign(spec: &CampaignSpec) -> Result<Dataset, String> {
    spec.validate()?;
    let sim = spec.simulator()?;
    let mut out = Dataset::default();
    for network in &spec.networks {
        let graph = crate::models::by_name(network)
            .ok_or_else(|| format!("unknown network {network:?}"))?;
        for &strategy in &spec.strategies {
            for &regime in &spec.regimes {
                let job = ProfileJob {
                    network,
                    graph: &graph,
                    strategy,
                    regime,
                    levels: &spec.levels,
                    batch_sizes: &spec.batch_sizes,
                    runs: spec.runs,
                    seed: spec.seed,
                };
                out.extend(profile(&sim, &job));
            }
        }
    }
    Ok(out)
}

/// Execute a whole campaign in-process — shards drained work-stealing
/// style by a thread pool, merged in memory — and return the canonical
/// dataset. Bit-identical to [`profile_campaign`]; this is the fast path
/// the experiment harnesses fit from.
pub fn collect(spec: &CampaignSpec) -> Result<Dataset, String> {
    spec.validate()?;
    let total = spec.total_units();
    let workers = worker_width(total);
    // A few shards per worker so one slow shard cannot straggle the pool.
    let plans = spec.shard_plans(workers * 4);
    let mut results = drain_indexed(plans.len(), workers, |i| execute_shard(spec, &plans[i]));
    // Contiguous ascending shards: concatenation in shard order *is* the
    // canonical unit order.
    results.sort_by_key(|&(i, _)| i);
    let mut points = Vec::with_capacity(total);
    for (_, r) in results {
        points.extend(r?);
    }
    Ok(Dataset::new(points))
}

/// Resolve the campaign driver's worker count: CLI flag, then the
/// `PERF4SIGHT_WORKERS` env override (pinned, reproducible parallelism
/// for CI and benches), then the config-file knob, then the machine's
/// available parallelism; always clamped to `[1, cap]`.
pub fn resolve_workers(cli: Option<usize>, configured: usize, cap: usize) -> usize {
    cli.filter(|&w| w > 0)
        .or_else(crate::profiler::env_workers)
        .or_else(|| (configured > 0).then_some(configured))
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .clamp(1, cap.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::Strategy;

    fn tiny_spec() -> CampaignSpec {
        CampaignSpec {
            networks: vec!["squeezenet".into()],
            strategies: vec![Strategy::Random],
            regimes: vec![crate::device::TrainRegime::Vanilla],
            levels: vec![0.0, 0.5],
            batch_sizes: vec![4, 16],
            runs: 1,
            seed: 3,
            device: "tx2".into(),
        }
    }

    #[test]
    fn collect_matches_reference_bitwise() {
        let spec = tiny_spec();
        let a = profile_campaign(&spec).unwrap();
        let b = collect(&spec).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn collect_matches_reference_bitwise_with_regime_axis() {
        use crate::device::TrainRegime;
        let mut spec = tiny_spec();
        spec.regimes = vec![
            TrainRegime::Vanilla,
            TrainRegime::Checkpointed { segments: 4 },
            TrainRegime::Frozen { trainable_suffix: 2 },
        ];
        let a = profile_campaign(&spec).unwrap();
        let b = collect(&spec).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        // Every regime actually appears in the output.
        for r in &spec.regimes {
            assert!(a.points.iter().any(|p| p.regime == r.name()), "{}", r.name());
        }
    }

    #[test]
    fn execute_shard_covers_its_units() {
        let spec = tiny_spec();
        let plans = spec.shard_plans(3);
        let n: usize = plans
            .iter()
            .map(|p| execute_shard(&spec, p).unwrap().len())
            .sum();
        assert_eq!(n, spec.total_units());
    }

    #[test]
    fn resolve_workers_precedence_and_clamp() {
        // CLI wins regardless of config; everything clamps to cap.
        assert_eq!(resolve_workers(Some(3), 8, 100), 3);
        assert_eq!(resolve_workers(Some(64), 8, 4), 4);
        if std::env::var("PERF4SIGHT_WORKERS").is_ok() {
            return; // the env override would shadow the fall-through cases
        }
        assert_eq!(resolve_workers(Some(0), 5, 100), 5);
        assert_eq!(resolve_workers(None, 2, 100), 2);
        assert!(resolve_workers(None, 0, 100) >= 1);
        assert_eq!(resolve_workers(None, 0, 1), 1);
    }
}
