//! Shard manifests: the completeness contract between workers and the
//! merge step. A worker writes its shard's dataset file first and the
//! manifest last, so a manifest's existence implies the dataset it names
//! was fully written; the merge step trusts nothing else.
//!
//! Invalidation rule: a manifest binds its shard to one campaign via the
//! spec fingerprint. Any spec change ⇒ new fingerprint ⇒ stale manifests
//! are rejected with a clear error instead of silently merging mixed
//! campaigns. A corrupt manifest is likewise a hard error — delete it and
//! re-run the driver to regenerate the shard.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Per-shard sidecar describing exactly which campaign units the shard's
/// dataset file holds, in dataset row order.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardManifest {
    /// [`super::CampaignSpec::fingerprint`] of the campaign the shard
    /// belongs to.
    pub fingerprint: u64,
    pub shard_index: usize,
    /// Partition width the shard was cut from.
    pub shard_count: usize,
    /// Dataset file name, relative to the manifest's directory.
    pub dataset: String,
    /// Canonical unit ids, in the same order as the dataset's points.
    pub units: Vec<usize>,
}

/// Dataset file name for shard `index`.
pub fn shard_dataset_name(index: usize) -> String {
    format!("shard-{index}.json")
}

/// Manifest path for shard `index` under `dir`.
pub fn shard_manifest_path(dir: &Path, index: usize) -> PathBuf {
    dir.join(format!("shard-{index}.manifest.json"))
}

impl ShardManifest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            // Hex string: u64 fingerprints are not exactly representable
            // as f64.
            ("campaign", Json::Str(format!("{:016x}", self.fingerprint))),
            ("shard_index", Json::Num(self.shard_index as f64)),
            ("shard_count", Json::Num(self.shard_count as f64)),
            ("dataset", Json::Str(self.dataset.clone())),
            ("units", Json::arr_usize(&self.units)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ShardManifest, String> {
        let fp = j
            .get("campaign")
            .and_then(Json::as_str)
            .ok_or("manifest: missing campaign fingerprint")?;
        let fingerprint = u64::from_str_radix(fp.trim_start_matches("0x"), 16)
            .map_err(|e| format!("manifest: bad campaign fingerprint {fp:?}: {e}"))?;
        let units = j
            .get("units")
            .and_then(Json::as_arr)
            .ok_or("manifest: missing units")?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| "manifest: units must be integers".to_string()))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(ShardManifest {
            fingerprint,
            shard_index: j
                .get("shard_index")
                .and_then(Json::as_usize)
                .ok_or("manifest: missing shard_index")?,
            shard_count: j
                .get("shard_count")
                .and_then(Json::as_usize)
                .ok_or("manifest: missing shard_count")?,
            dataset: j
                .get("dataset")
                .and_then(Json::as_str)
                .ok_or("manifest: missing dataset")?
                .to_string(),
            units,
        })
    }

    /// Write the manifest atomically (salted sibling temp file + rename,
    /// [`crate::util::atomic_fs::write_atomic`]): the manifest is the
    /// shard's resume marker, so a crash mid-write must leave either the
    /// old state or the new one, never a torn file that would hard-error
    /// every later resume. The salt covers concurrent same-pid writers
    /// (dispatch lease races re-executing a shard are benign-by-design);
    /// leftover `*.tmp-*` files are ignored by the driver and merge
    /// scans and swept by the next driver run.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        crate::util::atomic_fs::write_atomic(path, &self.to_json().to_string())
            .map_err(|e| format!("writing shard manifest {}: {e}", path.display()))
    }

    pub fn load(path: &Path) -> Result<ShardManifest, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading shard manifest {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| {
            format!(
                "corrupt shard manifest {}: {e} — delete it and re-run the campaign driver \
                 to regenerate the shard",
                path.display()
            )
        })?;
        Self::from_json(&j).map_err(|e| format!("corrupt shard manifest {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let m = ShardManifest {
            fingerprint: 0xdead_beef_0123_4567,
            shard_index: 2,
            shard_count: 5,
            dataset: "shard-2.json".into(),
            units: vec![10, 11, 12, 13],
        };
        let back = ShardManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn file_roundtrip_and_corruption_error() {
        let dir = std::env::temp_dir().join(format!(
            "perf4sight-manifest-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let m = ShardManifest {
            fingerprint: 7,
            shard_index: 0,
            shard_count: 1,
            dataset: shard_dataset_name(0),
            units: vec![0, 1],
        };
        let path = shard_manifest_path(&dir, 0);
        m.save(&path).unwrap();
        assert_eq!(ShardManifest::load(&path).unwrap(), m);
        std::fs::write(&path, "{not json").unwrap();
        let err = ShardManifest::load(&path).unwrap_err();
        assert!(err.contains("corrupt shard manifest"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
