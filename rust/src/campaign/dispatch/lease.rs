//! Shard leases: the mutual-exclusion and liveness primitive of the
//! dispatch mailbox.
//!
//! A worker claims a shard by *atomically creating* its lease file
//! (`leases/shard-<i>.lease.json`, [`publish_new`] — exactly one of N
//! racing claimants wins and the file a reader sees is always whole).
//! While executing, a heartbeat thread refreshes the lease's `beat_ms`
//! on a cadence via temp-file + rename. The coordinator reclaims a lease
//! whose heartbeat has gone stale by removing the file, which re-opens
//! the shard for claiming.
//!
//! Benign race, by design: a worker that was reclaimed but is still
//! running (stalled, then woke up) may finish its shard concurrently
//! with the re-claimant. That is *observationally harmless* — shard
//! bytes are a pure function of (spec, shard) under the RNG-offset
//! contract, and every artifact write is atomic, so both writers produce
//! identical files. The refresh path checks ownership before rewriting
//! so a reclaimed lease is never resurrected by a slow heartbeat.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::atomic_fs::{now_ms, publish_new, write_atomic};
use crate::util::fault;
use crate::util::json::Json;

/// Subdirectory of the campaign dir holding lease files.
pub fn lease_dir(dir: &Path) -> PathBuf {
    dir.join("leases")
}

/// Lease file path for `shard` under campaign dir `dir`.
pub fn lease_path(dir: &Path, shard: usize) -> PathBuf {
    lease_dir(dir).join(format!("shard-{shard}.lease.json"))
}

/// One shard claim: who holds it, for which campaign, and how fresh.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Lease {
    /// Campaign fingerprint the claim belongs to — a lease from another
    /// campaign in the same dir is a hard error, like a stale manifest.
    pub fingerprint: u64,
    pub shard: usize,
    pub worker: String,
    /// Failed attempts already recorded when this claim was taken.
    pub attempt: usize,
    /// Last heartbeat, milliseconds since the Unix epoch.
    pub beat_ms: u64,
}

impl Lease {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            // Hex string: u64 fingerprints are not exactly representable
            // as f64.
            ("campaign", Json::Str(format!("{:016x}", self.fingerprint))),
            ("shard", Json::Num(self.shard as f64)),
            ("worker", Json::Str(self.worker.clone())),
            ("attempt", Json::Num(self.attempt as f64)),
            ("beat_ms", Json::Num(self.beat_ms as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Lease, String> {
        let fp = j
            .get("campaign")
            .and_then(Json::as_str)
            .ok_or("lease: missing campaign fingerprint")?;
        Ok(Lease {
            fingerprint: u64::from_str_radix(fp.trim_start_matches("0x"), 16)
                .map_err(|e| format!("lease: bad campaign fingerprint {fp:?}: {e}"))?,
            shard: j
                .get("shard")
                .and_then(Json::as_usize)
                .ok_or("lease: missing shard")?,
            worker: j
                .get("worker")
                .and_then(Json::as_str)
                .ok_or("lease: missing worker")?
                .to_string(),
            attempt: j
                .get("attempt")
                .and_then(Json::as_usize)
                .ok_or("lease: missing attempt")?,
            beat_ms: j
                .get("beat_ms")
                .and_then(Json::as_f64)
                .ok_or("lease: missing beat_ms")? as u64,
        })
    }

    /// Load the lease at `path`; `Ok(None)` when no lease is present. A
    /// present-but-unreadable lease is a hard error naming the file —
    /// claims are published whole, so corruption is stale foreign state,
    /// not a race.
    pub fn load_if_present(path: &Path) -> Result<Option<Lease>, String> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(format!("reading lease {}: {e}", path.display())),
        };
        let j = Json::parse(&text).map_err(|e| {
            format!(
                "corrupt lease {}: {e} — delete it to re-open the shard",
                path.display()
            )
        })?;
        Self::from_json(&j)
            .map_err(|e| format!("corrupt lease {}: {e}", path.display()))
            .map(Some)
    }

    /// Try to claim `shard`: atomically create its lease file. `None`
    /// when another worker holds the claim.
    pub fn try_claim(
        dir: &Path,
        shard: usize,
        fingerprint: u64,
        worker: &str,
        attempt: usize,
    ) -> Result<Option<Lease>, String> {
        let lease = Lease {
            fingerprint,
            shard,
            worker: worker.to_string(),
            attempt,
            beat_ms: now_ms(),
        };
        let path = lease_path(dir, shard);
        match publish_new(&path, &lease.to_json().to_string()) {
            Ok(true) => Ok(Some(lease)),
            Ok(false) => Ok(None),
            Err(e) => Err(format!("claiming lease {}: {e}", path.display())),
        }
    }

    /// Refresh the heartbeat on disk — only if the lease still exists and
    /// still names this worker. `Ok(false)` means the claim was reclaimed
    /// or released (stop beating); rewriting it would resurrect a lease
    /// the coordinator already handed to someone else.
    pub fn refresh(&mut self, dir: &Path) -> Result<bool, String> {
        let path = lease_path(dir, self.shard);
        match Lease::load_if_present(&path)? {
            Some(current) if current.worker == self.worker => {
                self.beat_ms = now_ms();
                write_atomic(&path, &self.to_json().to_string())
                    .map_err(|e| format!("refreshing lease {}: {e}", path.display()))?;
                Ok(true)
            }
            _ => Ok(false),
        }
    }

    /// Release the claim (best-effort, owner-checked): remove the lease
    /// file iff it still names this worker. A failure here only delays
    /// the shard until the lease times out, so callers may ignore it.
    pub fn release(&self, dir: &Path) -> Result<(), String> {
        let path = lease_path(dir, self.shard);
        if let Some(current) = Lease::load_if_present(&path)? {
            if current.worker == self.worker {
                std::fs::remove_file(&path)
                    .map_err(|e| format!("releasing lease {}: {e}", path.display()))?;
            }
        }
        Ok(())
    }

    /// Has the heartbeat gone stale relative to `now_ms`?
    pub fn expired(&self, timeout: Duration, now_ms: u64) -> bool {
        now_ms.saturating_sub(self.beat_ms) > timeout.as_millis() as u64
    }
}

/// Background heartbeat for one held lease. Dropping it stops the thread
/// and joins it; refreshes stop on their own if the lease disappears or
/// changes hands, or when a fault plan mutes/hangs the worker.
pub struct Heartbeat {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Start refreshing `lease` every `every` until dropped.
pub fn start_heartbeat(dir: &Path, lease: &Lease, every: Duration) -> Heartbeat {
    let stop = Arc::new(AtomicBool::new(false));
    let stop_flag = Arc::clone(&stop);
    let dir = dir.to_path_buf();
    let mut lease = lease.clone();
    let handle = std::thread::spawn(move || {
        // Short ticks between refreshes so drop() never waits a full
        // cadence, and a hang/mute fault is observed promptly.
        let tick = every.min(Duration::from_millis(10)).max(Duration::from_millis(1));
        loop {
            let next = Instant::now() + every;
            while Instant::now() < next {
                if stop_flag.load(Ordering::Relaxed) {
                    return;
                }
                std::thread::sleep(tick);
            }
            if stop_flag.load(Ordering::Relaxed) || fault::heartbeat_muted(lease.shard) {
                return;
            }
            if !matches!(lease.refresh(&dir), Ok(true)) {
                return;
            }
        }
    });
    Heartbeat {
        stop,
        handle: Some(handle),
    }
}

impl Drop for Heartbeat {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.handle.take() {
            handle.join().ok();
        }
    }
}
