//! The dispatch coordinator: announces the campaign into the mailbox,
//! then polls until every shard is checkpointed — reclaiming expired
//! leases, enforcing the per-shard retry budget, and aborting the fleet
//! loudly when a shard is hopeless or the mailbox goes dead.
//!
//! The coordinator never executes shards itself and holds no state that
//! is not in the mailbox: killing and restarting it is always safe (a
//! restart re-validates the checkpoints, grants a fresh retry budget and
//! resumes polling).

use std::path::Path;
use std::time::{Duration, Instant};

use crate::campaign::driver::{ensure_spec_file, shard_complete, validate_existing_manifests};
use crate::campaign::spec::CampaignSpec;
use crate::util::atomic_fs::now_ms;
use crate::util::backoff::RetryPolicy;

use super::lease::{lease_path, Lease};
use super::mailbox::{self, AttemptKind, AttemptRecord, DispatchFile};

/// Coordinator-side dispatch knobs.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Partition width announced to the fleet.
    pub shards: usize,
    /// A lease whose heartbeat is older than this is reclaimed and its
    /// shard re-opened. Budget for worker heartbeat cadence, shared-dir
    /// sync latency *and* cross-machine clock skew.
    pub lease_timeout: Duration,
    /// Mailbox poll interval.
    pub poll: Duration,
    /// Per-shard budget of failures + reclaims; exhausting it aborts the
    /// whole campaign with the shard named.
    pub retry: RetryPolicy,
    /// Abort when nothing progresses for this long (no completions, no
    /// live leases); `None` waits forever.
    pub idle_timeout: Option<Duration>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            shards: 1,
            lease_timeout: Duration::from_millis(10_000),
            poll: Duration::from_millis(500),
            retry: RetryPolicy {
                retries: 3,
                base_ms: 500,
                cap_ms: 10_000,
            },
            idle_timeout: None,
        }
    }
}

/// What a coordinator run observed.
#[derive(Clone, Debug)]
pub struct DispatchReport {
    /// Partition width after clamping.
    pub shards: usize,
    /// Shards already checkpointed when the coordinator started.
    pub resumed: Vec<usize>,
    /// Expired-lease reclaims, in observation order (repeats possible).
    pub reclaimed: Vec<usize>,
    /// Attempt records per shard at completion (failures + reclaims).
    pub attempts: Vec<usize>,
}

/// Drive the campaign at `dir` to completion through the worker fleet.
/// Blocks until every shard is checkpointed (`Ok`) or the run aborts
/// (`Err`, with the abort marker posted so workers stop too).
pub fn run_coordinator(
    spec: &CampaignSpec,
    dir: &Path,
    cfg: &CoordinatorConfig,
) -> Result<DispatchReport, String> {
    spec.validate()?;
    if cfg.shards == 0 {
        return Err("dispatch coordinator: shard count must be ≥ 1".into());
    }
    ensure_spec_file(spec, dir)?;
    let fingerprint = spec.fingerprint();
    let plans = spec.shard_plans(cfg.shards);
    validate_existing_manifests(dir, fingerprint, &plans)?;
    // Each coordinator run grants a fresh retry budget: clear the abort
    // marker and the attempt ledger from any previous (aborted) run.
    mailbox::clear_abort(dir)?;
    mailbox::clear_attempts(dir)?;
    DispatchFile::ensure(dir, fingerprint, plans.len())?;
    let resumed: Vec<usize> = plans
        .iter()
        .filter(|p| shard_complete(dir, p))
        .map(|p| p.index)
        .collect();
    let mut reclaimed = Vec::new();
    let mut last_progress = Instant::now();
    let mut last_complete = resumed.len();
    let poll = cfg.poll.max(Duration::from_millis(1));
    loop {
        let mut complete = 0;
        let mut live = false;
        for plan in &plans {
            let path = lease_path(dir, plan.index);
            if shard_complete(dir, plan) {
                complete += 1;
                // Orphan lease on a finished shard (worker died after the
                // checkpoint, or a benign duplicate completion): drop it
                // without charging an attempt.
                if Lease::load_if_present(&path)?.is_some() {
                    std::fs::remove_file(&path).ok();
                }
                continue;
            }
            if let Some(lease) = Lease::load_if_present(&path)? {
                if lease.fingerprint != fingerprint {
                    return Err(format!(
                        "lease {} belongs to a different campaign (fingerprint {:016x}, \
                         expected {:016x}); stale dispatch dir — use a fresh --out-dir",
                        path.display(),
                        lease.fingerprint,
                        fingerprint
                    ));
                }
                if lease.expired(cfg.lease_timeout, now_ms()) {
                    mailbox::record_attempt(
                        dir,
                        &AttemptRecord {
                            shard: plan.index,
                            worker: lease.worker.clone(),
                            kind: AttemptKind::Reclaimed,
                            error: format!(
                                "lease expired: no heartbeat from {:?} for over {:?}",
                                lease.worker, cfg.lease_timeout
                            ),
                            at_ms: now_ms(),
                        },
                    )?;
                    std::fs::remove_file(&path)
                        .map_err(|e| format!("reclaiming lease {}: {e}", path.display()))?;
                    reclaimed.push(plan.index);
                }
                // Either way someone was (or just stopped being) on it —
                // a reclaim re-opens the shard, which is progress.
                live = true;
            }
            let attempts = mailbox::shard_attempts(dir, plan.index)?;
            if attempts.len() >= cfg.retry.max_attempts() {
                let last_error = attempts.last().map(|a| a.error.clone()).unwrap_or_default();
                let reason = format!(
                    "shard {} exhausted its retry budget ({} attempt(s) recorded, {} \
                     allowed); last: {last_error}",
                    plan.index,
                    attempts.len(),
                    cfg.retry.max_attempts()
                );
                mailbox::write_abort(dir, &reason)?;
                return Err(format!("campaign dispatch aborted: {reason}"));
            }
        }
        if complete == plans.len() {
            break;
        }
        if complete > last_complete || live {
            last_complete = last_complete.max(complete);
            last_progress = Instant::now();
        }
        if let Some(limit) = cfg.idle_timeout {
            if last_progress.elapsed() > limit {
                let reason = format!(
                    "no progress for {limit:?} ({complete}/{} shards complete, no live \
                     leases) — are any workers running?",
                    plans.len()
                );
                mailbox::write_abort(dir, &reason)?;
                return Err(format!("campaign dispatch aborted: {reason}"));
            }
        }
        std::thread::sleep(poll);
    }
    let mut attempts = Vec::with_capacity(plans.len());
    for plan in &plans {
        attempts.push(mailbox::shard_attempts(dir, plan.index)?.len());
    }
    Ok(DispatchReport {
        shards: plans.len(),
        resumed,
        reclaimed,
        attempts,
    })
}
