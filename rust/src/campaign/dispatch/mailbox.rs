//! The shared-directory mailbox: everything coordinator and workers say
//! to each other besides leases and the shard artifacts themselves.
//!
//! Layout under the campaign dir (all writes crash-atomic):
//!
//! ```text
//! spec.json                     campaign spec (fingerprint anchor)
//! dispatch.json                 partition announcement {campaign, shards}
//! dispatch-abort.json           coordinator's stop order (reason inside)
//! leases/shard-<i>.lease.json   live claims (see lease.rs)
//! attempts/shard-<i>-<salt>.json  one failure/reclaim record per event
//! faults/                       :once fault-injection markers
//! shard-<i>.json + .manifest.json the PR 3 checkpoint artifacts
//! ```
//!
//! Attempt records are append-only events, one file each, so workers and
//! coordinator count a shard's failures without any shared counter or
//! file locking; the per-event salt keeps concurrent writers from
//! colliding. The retry *budget* is the count of these records.

use std::path::{Path, PathBuf};

use crate::util::atomic_fs::{unique_salt, write_atomic};
use crate::util::json::Json;

/// Partition announcement file name.
pub const DISPATCH_FILE: &str = "dispatch.json";

/// Abort marker file name.
pub const ABORT_FILE: &str = "dispatch-abort.json";

/// The coordinator's announcement: which campaign this mailbox serves
/// and how many shards it was cut into. Workers wait for it, then derive
/// the identical partition from (spec, shards).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DispatchFile {
    pub fingerprint: u64,
    pub shards: usize,
}

impl DispatchFile {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("campaign", Json::Str(format!("{:016x}", self.fingerprint))),
            ("shards", Json::Num(self.shards as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<DispatchFile, String> {
        let fp = j
            .get("campaign")
            .and_then(Json::as_str)
            .ok_or("dispatch file: missing campaign fingerprint")?;
        Ok(DispatchFile {
            fingerprint: u64::from_str_radix(fp.trim_start_matches("0x"), 16)
                .map_err(|e| format!("dispatch file: bad campaign fingerprint {fp:?}: {e}"))?,
            shards: j
                .get("shards")
                .and_then(Json::as_usize)
                .ok_or("dispatch file: missing shards")?,
        })
    }

    pub fn load(path: &Path) -> Result<DispatchFile, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading dispatch file {}: {e}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| format!("corrupt dispatch file {}: {e}", path.display()))?;
        Self::from_json(&j)
    }

    /// Write the announcement, or verify an existing one matches — a
    /// mailbox already announced for another campaign or another
    /// partition is a hard error, mirroring the spec/manifest checks.
    pub fn ensure(dir: &Path, fingerprint: u64, shards: usize) -> Result<DispatchFile, String> {
        let path = dir.join(DISPATCH_FILE);
        let wanted = DispatchFile { fingerprint, shards };
        if path.exists() {
            let existing = Self::load(&path)?;
            if existing != wanted {
                return Err(format!(
                    "dispatch file {} announces campaign {:016x} in {} shard(s), expected \
                     {:016x} in {} — use a fresh --out-dir or re-run with --shards {}",
                    path.display(),
                    existing.fingerprint,
                    existing.shards,
                    fingerprint,
                    shards,
                    existing.shards
                ));
            }
            return Ok(existing);
        }
        write_atomic(&path, &wanted.to_json().to_string())
            .map_err(|e| format!("writing dispatch file {}: {e}", path.display()))?;
        Ok(wanted)
    }
}

/// Read the abort marker's reason, if the coordinator posted one.
pub fn read_abort(dir: &Path) -> Option<String> {
    let text = std::fs::read_to_string(dir.join(ABORT_FILE)).ok()?;
    let reason = Json::parse(&text)
        .ok()
        .and_then(|j| j.get("reason").and_then(Json::as_str).map(str::to_string));
    Some(reason.unwrap_or_else(|| "unreadable abort marker".to_string()))
}

/// Post the abort marker: every polling worker exits with the reason.
pub fn write_abort(dir: &Path, reason: &str) -> Result<(), String> {
    let path = dir.join(ABORT_FILE);
    let j = Json::obj(vec![("reason", Json::Str(reason.to_string()))]);
    write_atomic(&path, &j.to_string())
        .map_err(|e| format!("writing abort marker {}: {e}", path.display()))
}

/// Clear the abort marker (coordinator startup: each coordinator run
/// grants a fresh retry budget).
pub fn clear_abort(dir: &Path) -> Result<(), String> {
    match std::fs::remove_file(dir.join(ABORT_FILE)) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
        Err(e) => Err(format!("clearing abort marker under {}: {e}", dir.display())),
    }
}

/// Why an attempt ended without the shard completing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttemptKind {
    /// The executing worker reported an error.
    Failed,
    /// The coordinator reclaimed an expired lease (worker presumed dead).
    Reclaimed,
}

impl AttemptKind {
    fn name(self) -> &'static str {
        match self {
            AttemptKind::Failed => "failed",
            AttemptKind::Reclaimed => "reclaimed",
        }
    }

    fn from_name(name: &str) -> Option<AttemptKind> {
        match name {
            "failed" => Some(AttemptKind::Failed),
            "reclaimed" => Some(AttemptKind::Reclaimed),
            _ => None,
        }
    }
}

/// One failure/reclaim event for a shard.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AttemptRecord {
    pub shard: usize,
    /// Worker whose attempt ended (the lease holder, for reclaims).
    pub worker: String,
    pub kind: AttemptKind,
    pub error: String,
    /// Event time, ms since the Unix epoch — the backoff anchor.
    pub at_ms: u64,
}

fn attempts_dir(dir: &Path) -> PathBuf {
    dir.join("attempts")
}

impl AttemptRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard", Json::Num(self.shard as f64)),
            ("worker", Json::Str(self.worker.clone())),
            ("kind", Json::Str(self.kind.name().to_string())),
            ("error", Json::Str(self.error.clone())),
            ("at_ms", Json::Num(self.at_ms as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<AttemptRecord, String> {
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or("attempt record: missing kind")?;
        Ok(AttemptRecord {
            shard: j
                .get("shard")
                .and_then(Json::as_usize)
                .ok_or("attempt record: missing shard")?,
            worker: j
                .get("worker")
                .and_then(Json::as_str)
                .ok_or("attempt record: missing worker")?
                .to_string(),
            kind: AttemptKind::from_name(kind)
                .ok_or_else(|| format!("attempt record: unknown kind {kind:?}"))?,
            error: j
                .get("error")
                .and_then(Json::as_str)
                .ok_or("attempt record: missing error")?
                .to_string(),
            at_ms: j
                .get("at_ms")
                .and_then(Json::as_f64)
                .ok_or("attempt record: missing at_ms")? as u64,
        })
    }
}

/// Append one attempt record for `record.shard` (its own salted file —
/// no lock, no clobbering a concurrent writer).
pub fn record_attempt(dir: &Path, record: &AttemptRecord) -> Result<(), String> {
    let path = attempts_dir(dir).join(format!("shard-{}-{}.json", record.shard, unique_salt()));
    write_atomic(&path, &record.to_json().to_string())
        .map_err(|e| format!("writing attempt record {}: {e}", path.display()))
}

/// All recorded attempts for `shard`, oldest first (ties broken by file
/// name so every process agrees on the order).
pub fn shard_attempts(dir: &Path, shard: usize) -> Result<Vec<AttemptRecord>, String> {
    let adir = attempts_dir(dir);
    let entries = match std::fs::read_dir(&adir) {
        Ok(entries) => entries,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(format!("reading attempts dir {}: {e}", adir.display())),
    };
    // The trailing '-' keeps shard-1 from matching shard-10's records.
    let prefix = format!("shard-{shard}-");
    let mut named: Vec<(String, AttemptRecord)> = Vec::new();
    for entry in entries.filter_map(|e| e.ok()) {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if !name.starts_with(&prefix) || !name.ends_with(".json") || name.contains(".tmp-") {
            continue;
        }
        let path = entry.path();
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("reading attempt record {}: {e}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| format!("corrupt attempt record {}: {e}", path.display()))?;
        let record = AttemptRecord::from_json(&j)
            .map_err(|e| format!("corrupt attempt record {}: {e}", path.display()))?;
        named.push((name.to_string(), record));
    }
    named.sort_by(|a, b| (a.1.at_ms, &a.0).cmp(&(b.1.at_ms, &b.0)));
    Ok(named.into_iter().map(|(_, r)| r).collect())
}

/// Remove every attempt record (coordinator startup: the retry budget is
/// per coordinator run, so a re-run after fixing the cause starts clean).
pub fn clear_attempts(dir: &Path) -> Result<(), String> {
    let adir = attempts_dir(dir);
    let entries = match std::fs::read_dir(&adir) {
        Ok(entries) => entries,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(format!("reading attempts dir {}: {e}", adir.display())),
    };
    for entry in entries.filter_map(|e| e.ok()) {
        std::fs::remove_file(entry.path())
            .map_err(|e| format!("clearing attempt record {}: {e}", entry.path().display()))?;
    }
    Ok(())
}
