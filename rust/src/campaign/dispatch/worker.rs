//! The dispatch worker loop: poll the mailbox, claim an unleased
//! incomplete shard, execute it under a heartbeat, checkpoint, repeat —
//! until every shard is complete or the coordinator aborts.
//!
//! Workers are stateless and interchangeable: everything they need is in
//! the mailbox (spec + partition announcement), and everything they
//! produce is the same checkpoint artifacts the local driver writes.
//! A worker can join late, die, or be duplicated freely — correctness
//! rests on lease mutual exclusion plus the RNG-offset determinism
//! contract (re-executions reproduce identical bytes).

use std::path::Path;
use std::time::{Duration, Instant};

use crate::campaign::driver::{shard_complete, write_shard};
use crate::campaign::spec::{CampaignSpec, SPEC_FILE};
use crate::util::atomic_fs::{now_ms, unique_salt};
use crate::util::backoff::{shard_salt, RetryPolicy};
use crate::util::fault;

use super::lease::{start_heartbeat, Lease};
use super::mailbox::{self, AttemptKind, AttemptRecord, DispatchFile};

/// Worker-side dispatch knobs.
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Identity recorded in leases and attempt records. Must be unique
    /// per process across the fleet; the default salts pid + time.
    pub worker_id: String,
    /// Lease heartbeat cadence. Keep it several times smaller than the
    /// coordinator's lease timeout or healthy workers get reclaimed.
    pub heartbeat: Duration,
    /// Mailbox poll interval while waiting for claimable work.
    pub poll: Duration,
    /// Retry budget + backoff — must match the coordinator's so both
    /// sides agree on when a shard is eligible and when it is exhausted.
    pub retry: RetryPolicy,
    /// Give up when no campaign appears / no progress happens for this
    /// long; `None` waits forever (fleet workers parked on a mailbox).
    pub idle_timeout: Option<Duration>,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            worker_id: format!("worker-{}", unique_salt()),
            heartbeat: Duration::from_millis(2_000),
            poll: Duration::from_millis(500),
            retry: RetryPolicy {
                retries: 3,
                base_ms: 500,
                cap_ms: 10_000,
            },
            idle_timeout: None,
        }
    }
}

/// What one worker run did.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub worker_id: String,
    /// Shards this worker executed to completion, in execution order.
    pub executed: Vec<usize>,
    /// Shards whose attempt by this worker failed (recorded for retry).
    pub failed: Vec<usize>,
}

/// Run the worker loop against the mailbox at `dir` until the campaign
/// completes (`Ok`) or aborts / times out idle (`Err`).
pub fn run_worker(dir: &Path, cfg: &WorkerConfig) -> Result<WorkerReport, String> {
    fault::set_context_dir(dir);
    let poll = cfg.poll.max(Duration::from_millis(1));
    let started = Instant::now();
    // Phase 1: wait for the coordinator's announcement.
    let (spec, dispatch) = loop {
        if let Some(reason) = mailbox::read_abort(dir) {
            return Err(format!("campaign aborted by coordinator: {reason}"));
        }
        let spec_path = dir.join(SPEC_FILE);
        let dispatch_path = dir.join(mailbox::DISPATCH_FILE);
        if spec_path.exists() && dispatch_path.exists() {
            let spec = CampaignSpec::load(&spec_path)?;
            let dispatch = DispatchFile::load(&dispatch_path)?;
            if dispatch.fingerprint != spec.fingerprint() {
                return Err(format!(
                    "dispatch file {} announces campaign {:016x} but {} fingerprints to \
                     {:016x} — torn or stale mailbox",
                    dispatch_path.display(),
                    dispatch.fingerprint,
                    spec_path.display(),
                    spec.fingerprint()
                ));
            }
            break (spec, dispatch);
        }
        if let Some(limit) = cfg.idle_timeout {
            if started.elapsed() > limit {
                return Err(format!(
                    "worker {}: no campaign announced under {} within {limit:?}",
                    cfg.worker_id,
                    dir.display()
                ));
            }
        }
        std::thread::sleep(poll);
    };
    spec.validate()?;
    let fingerprint = spec.fingerprint();
    let plans = spec.shard_plans(dispatch.shards);
    let mut executed = Vec::new();
    let mut failed = Vec::new();
    let mut last_progress = Instant::now();
    let mut last_complete = 0;
    // Phase 2: claim-execute-checkpoint until the campaign drains.
    loop {
        if let Some(reason) = mailbox::read_abort(dir) {
            return Err(format!("campaign aborted by coordinator: {reason}"));
        }
        let mut complete = 0;
        let mut did_work = false;
        for plan in &plans {
            if shard_complete(dir, plan) {
                complete += 1;
                continue;
            }
            let attempts = mailbox::shard_attempts(dir, plan.index)?;
            if attempts.len() >= cfg.retry.max_attempts() {
                // Budget exhausted: leave it for the coordinator to abort.
                continue;
            }
            if let Some(last) = attempts.last() {
                let wait = cfg
                    .retry
                    .delay(attempts.len(), shard_salt(fingerprint, plan.index, attempts.len()));
                if now_ms() < last.at_ms.saturating_add(wait.as_millis() as u64) {
                    continue; // backing off after the last failure
                }
            }
            let claim =
                Lease::try_claim(dir, plan.index, fingerprint, &cfg.worker_id, attempts.len())?;
            let Some(lease) = claim else { continue };
            did_work = true;
            let heartbeat = start_heartbeat(dir, &lease, cfg.heartbeat);
            let result = write_shard(&spec, dir, plan);
            drop(heartbeat);
            match result {
                Ok(()) => {
                    executed.push(plan.index);
                    complete += 1;
                }
                Err(error) => {
                    failed.push(plan.index);
                    mailbox::record_attempt(
                        dir,
                        &AttemptRecord {
                            shard: plan.index,
                            worker: cfg.worker_id.clone(),
                            kind: AttemptKind::Failed,
                            error,
                            at_ms: now_ms(),
                        },
                    )?;
                }
            }
            // Best-effort: an unreleased lease only delays the shard
            // until the coordinator's lease timeout.
            lease.release(dir).ok();
        }
        if complete == plans.len() {
            return Ok(WorkerReport {
                worker_id: cfg.worker_id.clone(),
                executed,
                failed,
            });
        }
        // Peers completing shards counts as progress too — an idle
        // worker must not give up while the fleet is healthy.
        if did_work || complete > last_complete {
            last_complete = last_complete.max(complete);
            last_progress = Instant::now();
        }
        if !did_work {
            if let Some(limit) = cfg.idle_timeout {
                if last_progress.elapsed() > limit {
                    return Err(format!(
                        "worker {}: no claimable work and no fleet progress for {limit:?} \
                         ({}/{} shards complete) — coordinator gone?",
                        cfg.worker_id,
                        complete,
                        plans.len()
                    ));
                }
            }
            std::thread::sleep(poll);
        }
    }
}
