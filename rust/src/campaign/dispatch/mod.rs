//! Fault-tolerant distributed campaign dispatch over a shared-directory
//! mailbox.
//!
//! One **coordinator** announces a campaign (spec + partition) into a
//! directory any number of **workers** can reach — a local path, NFS, a
//! synced folder. Workers claim shards by atomically creating lease
//! files, execute them with the exact same checkpoint writer the local
//! driver uses, and heartbeat while they work. The coordinator polls the
//! mailbox: it reclaims leases whose heartbeat went stale (crashed or
//! hung worker), re-opens those shards for the fleet, enforces a bounded
//! per-shard retry budget with exponential backoff, and aborts the whole
//! campaign loudly when a shard is hopeless.
//!
//! No sockets, no locks, no daemons: every protocol message is a small
//! JSON file written crash-atomically ([`crate::util::atomic_fs`]), so
//! the only infrastructure requirement is a directory with atomic rename
//! and hard links (any POSIX filesystem). Correctness under races and
//! re-execution rests on the RNG-offset determinism contract: a shard's
//! bytes are a pure function of (spec, shard plan), so a duplicated or
//! retried execution writes identical files and the merged dataset stays
//! bit-identical to a single-process [`crate::profiler::profile`] run.
//!
//! Module map:
//! - [`mailbox`] — on-disk protocol files (announcement, abort marker,
//!   attempt ledger) and the mailbox layout.
//! - [`lease`] — shard claims, heartbeats, expiry.
//! - [`worker`] — the claim-execute-checkpoint worker loop.
//! - [`coordinator`] — the poll-reclaim-abort control loop.
//!
//! Fault injection for tests and drills lives in [`crate::util::fault`]:
//! set `PERF4SIGHT_FAULT` to crash, hang, or mute a worker at named
//! points mid-shard.

pub mod coordinator;
pub mod lease;
pub mod mailbox;
pub mod worker;

pub use coordinator::{run_coordinator, CoordinatorConfig, DispatchReport};
pub use lease::{lease_path, Lease};
pub use mailbox::{read_abort, shard_attempts, AttemptKind, AttemptRecord, DispatchFile};
pub use worker::{run_worker, WorkerConfig, WorkerReport};
