//! Manifest-checked dataset merging: validate that the shard files under a
//! campaign directory cover the spec's unit grid exactly once, then
//! reassemble them in canonical level-major order. The merged [`Dataset`]
//! is bit-identical (JSON bytes included) to what the single-process
//! [`crate::profiler::profile`] path produces — guarded by the oracle
//! tests in `rust/tests/campaign_shards.rs`.

use std::path::{Path, PathBuf};

use crate::profiler::{Dataset, ProfilePoint};

use super::manifest::ShardManifest;
use super::spec::{CampaignSpec, SPEC_FILE};

/// Merge a campaign directory using the spec stored inside it.
pub fn merge_dir(dir: &Path) -> Result<(CampaignSpec, Dataset), String> {
    let spec = CampaignSpec::load(&dir.join(SPEC_FILE))?;
    let ds = merge(&spec, dir)?;
    Ok((spec, ds))
}

/// Merge the shard files under `dir` into the canonical dataset for
/// `spec`, validating completeness against the manifests: every unit
/// covered exactly once, every shard bound to this spec's fingerprint,
/// every point matching its unit's provenance. Any violation is a hard
/// error naming the offending file.
pub fn merge(spec: &CampaignSpec, dir: &Path) -> Result<Dataset, String> {
    spec.validate()?;
    let total = spec.total_units();
    let fingerprint = spec.fingerprint();
    let manifest_paths = manifest_paths(dir)?;
    if manifest_paths.is_empty() {
        return Err(format!(
            "no shard manifests under {} — run the campaign driver first",
            dir.display()
        ));
    }
    let mut slots: Vec<Option<ProfilePoint>> = vec![None; total];
    for mpath in &manifest_paths {
        let m = ShardManifest::load(mpath)?;
        if m.fingerprint != fingerprint {
            return Err(format!(
                "shard manifest {} belongs to a different campaign (fingerprint {:016x}, \
                 expected {:016x}); stale shard files? use a fresh --out-dir",
                mpath.display(),
                m.fingerprint,
                fingerprint
            ));
        }
        let dpath = dir.join(&m.dataset);
        let ds = Dataset::load(&dpath).map_err(|e| {
            format!(
                "shard dataset for manifest {}: {e} — delete this shard's files and \
                 re-run the campaign driver to regenerate it",
                mpath.display()
            )
        })?;
        if ds.len() != m.units.len() {
            return Err(format!(
                "{}: dataset {} holds {} points but the manifest lists {} units — \
                 delete this shard's files and re-run the campaign driver",
                mpath.display(),
                dpath.display(),
                ds.len(),
                m.units.len()
            ));
        }
        for (&uid, point) in m.units.iter().zip(ds.points) {
            if uid >= total {
                return Err(format!(
                    "{}: unit id {uid} out of range (grid has {total} units)",
                    mpath.display()
                ));
            }
            let unit = spec.unit(uid);
            if point.network != unit.network
                || point.strategy != unit.strategy.name()
                || point.regime != unit.regime.name()
                || point.level != unit.level
                || point.bs != unit.bs
            {
                return Err(format!(
                    "{}: point for unit {uid} is ({}, {}, {}, level {}, bs {}) but the spec \
                     expects ({}, {}, {}, level {}, bs {})",
                    mpath.display(),
                    point.network,
                    point.strategy,
                    point.regime,
                    point.level,
                    point.bs,
                    unit.network,
                    unit.strategy.name(),
                    unit.regime.name(),
                    unit.level,
                    unit.bs
                ));
            }
            if slots[uid].is_some() {
                return Err(format!(
                    "unit {uid} is covered by more than one shard (second copy in {})",
                    mpath.display()
                ));
            }
            slots[uid] = Some(point);
        }
    }
    let missing: Vec<usize> = slots
        .iter()
        .enumerate()
        .filter(|(_, s)| s.is_none())
        .map(|(i, _)| i)
        .collect();
    if !missing.is_empty() {
        return Err(format!(
            "campaign under {} is incomplete: {}/{} units missing (first ids: {:?}) — \
             re-run the campaign driver to fill the gaps",
            dir.display(),
            missing.len(),
            total,
            &missing[..missing.len().min(8)]
        ));
    }
    Ok(Dataset::new(slots.into_iter().flatten().collect()))
}

/// Shard manifest files under `dir`, sorted so every consumer (merge
/// validation, the driver's up-front partition check, shard-count
/// adoption on resume) sees them in a deterministic order.
pub(crate) fn manifest_paths(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("reading campaign dir {}: {e}", dir.display()))?;
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("shard-") && n.ends_with(".manifest.json"))
                .unwrap_or(false)
        })
        .collect();
    paths.sort();
    Ok(paths)
}
