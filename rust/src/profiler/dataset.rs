//! Profiled datasets: (feature vector, Γ, Φ) rows with provenance, JSON
//! and CSV persistence, and train-matrix extraction.

use crate::features::feature_names;
use crate::forest::{FitError, TrainMatrix};
use crate::util::json::Json;
use std::path::Path;

/// One profiled datapoint — an entire network's training step.
#[derive(Clone, Debug)]
pub struct ProfilePoint {
    pub network: String,
    pub strategy: String,
    /// Training regime name ([`TrainRegime::name`](crate::device::TrainRegime::name)):
    /// `vanilla`, `ckpt:N` or `frozen:N`. Serialized only when non-vanilla,
    /// so vanilla datasets keep their historical (v1) JSON/CSV bytes.
    pub regime: String,
    /// Pruning level in [0,1).
    pub level: f64,
    pub bs: usize,
    /// Analytical features (`crate::features::NUM_FEATURES` columns).
    pub features: Vec<f64>,
    /// Measured training memory, MB.
    pub gamma_mb: f64,
    /// Measured mini-batch latency, ms.
    pub phi_ms: f64,
}

/// A collection of profile points.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub points: Vec<ProfilePoint>,
}

impl Dataset {
    pub fn new(points: Vec<ProfilePoint>) -> Self {
        Dataset { points }
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Merge another dataset into this one.
    pub fn extend(&mut self, other: Dataset) {
        self.points.extend(other.points);
    }

    /// Feature matrix (row-major). Copies every row — prediction-time
    /// callers that only need a fit should use [`Dataset::train_matrix`].
    pub fn x(&self) -> Vec<Vec<f64>> {
        self.points.iter().map(|p| p.features.clone()).collect()
    }

    /// Compile the features for fitting: column-major storage plus one
    /// presorted index array per feature, built straight from the borrowed
    /// point rows (no row-major copy). The matrix is target-agnostic —
    /// build it once and fit both the Γ and Φ forests from it
    /// ([`Forest::fit_matrix`](crate::forest::Forest::fit_matrix)).
    pub fn train_matrix(&self) -> Result<TrainMatrix, FitError> {
        TrainMatrix::from_row_iter(self.points.iter().map(|p| p.features.as_slice()))
    }

    /// Γ targets.
    pub fn y_gamma(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.gamma_mb).collect()
    }

    /// Φ targets.
    pub fn y_phi(&self) -> Vec<f64> {
        self.points.iter().map(|p| p.phi_ms).collect()
    }

    /// Filter by predicate.
    pub fn filter(&self, f: impl Fn(&ProfilePoint) -> bool) -> Dataset {
        Dataset::new(self.points.iter().filter(|p| f(p)).cloned().collect())
    }

    // ---------- persistence ----------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "feature_names",
                Json::arr_str(&feature_names()),
            ),
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|p| {
                            let mut fields = vec![
                                ("network", Json::Str(p.network.clone())),
                                ("strategy", Json::Str(p.strategy.clone())),
                            ];
                            // v1 back-compat: vanilla rows keep their
                            // historical bytes (no regime key).
                            if p.regime != "vanilla" {
                                fields.push(("regime", Json::Str(p.regime.clone())));
                            }
                            fields.extend([
                                ("level", Json::Num(p.level)),
                                ("bs", Json::Num(p.bs as f64)),
                                ("features", Json::arr_f64(&p.features)),
                                ("gamma_mb", Json::Num(p.gamma_mb)),
                                ("phi_ms", Json::Num(p.phi_ms)),
                            ]);
                            Json::obj(fields)
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Dataset, String> {
        let points_j = j.get("points").and_then(Json::as_arr).ok_or("missing points")?;
        let mut points = Vec::with_capacity(points_j.len());
        for pj in points_j {
            points.push(ProfilePoint {
                network: pj
                    .get("network")
                    .and_then(Json::as_str)
                    .ok_or("network")?
                    .to_string(),
                strategy: pj
                    .get("strategy")
                    .and_then(Json::as_str)
                    .ok_or("strategy")?
                    .to_string(),
                regime: pj
                    .get("regime")
                    .and_then(Json::as_str)
                    .unwrap_or("vanilla")
                    .to_string(),
                level: pj.get("level").and_then(Json::as_f64).ok_or("level")?,
                bs: pj.get("bs").and_then(Json::as_usize).ok_or("bs")?,
                features: pj
                    .get("features")
                    .and_then(Json::f64_vec)
                    .ok_or("features")?,
                gamma_mb: pj.get("gamma_mb").and_then(Json::as_f64).ok_or("gamma")?,
                phi_ms: pj.get("phi_ms").and_then(Json::as_f64).ok_or("phi")?,
            });
        }
        Ok(Dataset { points })
    }

    /// Save as JSON, creating missing parent directories (parity with the
    /// `cmd_fit` output-dir handling). Errors name the offending path.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        write_named(path, self.to_json().to_string())
    }

    /// Save as CSV with the same parent-directory handling and
    /// path-named errors as [`Dataset::save`] — the campaign
    /// `--format csv` output path.
    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        write_named(path, self.to_csv())
    }

    pub fn load(path: &Path) -> Result<Dataset, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading dataset {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| format!("corrupt dataset {}: {e}", path.display()))?;
        Self::from_json(&j)
    }

    /// CSV dump (header + rows) for external analysis / plotting.
    ///
    /// All-vanilla datasets emit the historical v1 header (no `regime`
    /// column, bytes identical to pre-regime builds); any non-vanilla row
    /// upgrades the whole dump to the v2 header with `regime` third.
    pub fn to_csv(&self) -> String {
        let with_regime = self.points.iter().any(|p| p.regime != "vanilla");
        let mut out = String::new();
        if with_regime {
            out.push_str("network,strategy,regime,level,bs,gamma_mb,phi_ms");
        } else {
            out.push_str("network,strategy,level,bs,gamma_mb,phi_ms");
        }
        for n in feature_names() {
            out.push(',');
            out.push_str(&n);
        }
        out.push('\n');
        for p in &self.points {
            if with_regime {
                out.push_str(&format!(
                    "{},{},{},{},{},{},{}",
                    p.network, p.strategy, p.regime, p.level, p.bs, p.gamma_mb, p.phi_ms
                ));
            } else {
                out.push_str(&format!(
                    "{},{},{},{},{},{}",
                    p.network, p.strategy, p.level, p.bs, p.gamma_mb, p.phi_ms
                ));
            }
            for f in &p.features {
                out.push_str(&format!(",{f}"));
            }
            out.push('\n');
        }
        out
    }

    /// Inverse of [`Dataset::to_csv`]: floats round-trip bitwise (`{}` on
    /// f64 prints the shortest representation that parses back exactly).
    /// Accepts both the v1 (regime-less) and v2 headers; v1 rows load as
    /// `vanilla`. Used by the campaign `--format csv` output path.
    pub fn from_csv(text: &str) -> Result<Dataset, String> {
        const V1_META: [&str; 6] = ["network", "strategy", "level", "bs", "gamma_mb", "phi_ms"];
        const V2_META: [&str; 7] = [
            "network", "strategy", "regime", "level", "bs", "gamma_mb", "phi_ms",
        ];
        let n_features = feature_names().len();
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty CSV")?;
        let head: Vec<&str> = header.split(',').collect();
        let with_regime = if head.len() == V2_META.len() + n_features && head[..7] == V2_META {
            true
        } else if head.len() == V1_META.len() + n_features && head[..6] == V1_META {
            false
        } else {
            return Err(format!("unexpected CSV header: {header}"));
        };
        let meta = if with_regime { 7 } else { 6 };
        let expected_cols = meta + n_features;
        let mut points = Vec::new();
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split(',').collect();
            if cols.len() != expected_cols {
                return Err(format!(
                    "CSV line {}: {} columns, expected {expected_cols}",
                    i + 2,
                    cols.len()
                ));
            }
            let f64_at = |c: usize| -> Result<f64, String> {
                cols[c]
                    .parse::<f64>()
                    .map_err(|e| format!("CSV line {}: column {}: {e}", i + 2, c + 1))
            };
            let o = meta - 6; // offset of the post-regime columns
            points.push(ProfilePoint {
                network: cols[0].to_string(),
                strategy: cols[1].to_string(),
                regime: if with_regime {
                    cols[2].to_string()
                } else {
                    "vanilla".to_string()
                },
                level: f64_at(2 + o)?,
                bs: cols[3 + o]
                    .parse()
                    .map_err(|e| format!("CSV line {}: bs: {e}", i + 2))?,
                features: (meta..expected_cols)
                    .map(f64_at)
                    .collect::<Result<Vec<_>, _>>()?,
                gamma_mb: f64_at(4 + o)?,
                phi_ms: f64_at(5 + o)?,
            });
        }
        Ok(Dataset::new(points))
    }
}

/// Write a dataset artifact, creating missing parent directories;
/// errors name the offending path.
fn write_named(path: &Path, contents: String) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        // `parent()` of a bare filename is `Some("")` — nothing to create.
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| {
                std::io::Error::new(
                    e.kind(),
                    format!(
                        "creating parent directory {} for dataset {}: {e}",
                        dir.display(),
                        path.display()
                    ),
                )
            })?;
        }
    }
    // Crash-atomic (salted sibling temp file + rename): a dataset is a
    // shard's checkpoint payload, so readers must see old bytes, new
    // bytes, or nothing — never a torn file.
    crate::util::atomic_fs::write_atomic(path, &contents).map_err(|e| {
        std::io::Error::new(e.kind(), format!("saving dataset to {}: {e}", path.display()))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::NUM_FEATURES;

    fn point(net: &str, bs: usize, g: f64) -> ProfilePoint {
        ProfilePoint {
            network: net.into(),
            strategy: "random".into(),
            regime: "vanilla".into(),
            level: 0.3,
            bs,
            features: vec![1.0; NUM_FEATURES],
            gamma_mb: g,
            phi_ms: g / 2.0,
        }
    }

    #[test]
    fn xy_extraction() {
        let ds = Dataset::new(vec![point("a", 2, 100.0), point("b", 4, 200.0)]);
        assert_eq!(ds.x().len(), 2);
        assert_eq!(ds.y_gamma(), vec![100.0, 200.0]);
        assert_eq!(ds.y_phi(), vec![50.0, 100.0]);
    }

    #[test]
    fn train_matrix_mirrors_x() {
        let mut a = point("a", 2, 100.0);
        a.features[3] = 7.5;
        let ds = Dataset::new(vec![a, point("b", 4, 200.0)]);
        let m = ds.train_matrix().unwrap();
        let x = ds.x();
        assert_eq!(m.n_rows(), 2);
        assert_eq!(m.n_features(), NUM_FEATURES);
        for f in 0..NUM_FEATURES {
            assert_eq!(m.col(f), &[x[0][f], x[1][f]]);
        }
    }

    #[test]
    fn filter_by_network() {
        let ds = Dataset::new(vec![point("a", 2, 1.0), point("b", 2, 2.0)]);
        let only_a = ds.filter(|p| p.network == "a");
        assert_eq!(only_a.len(), 1);
        assert_eq!(only_a.points[0].gamma_mb, 1.0);
    }

    #[test]
    fn json_roundtrip() {
        let ds = Dataset::new(vec![point("net", 16, 1234.5)]);
        let j = ds.to_json().to_string();
        let back = Dataset::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.len(), 1);
        let p = &back.points[0];
        assert_eq!(p.network, "net");
        assert_eq!(p.bs, 16);
        assert!((p.gamma_mb - 1234.5).abs() < 1e-9);
        assert_eq!(p.features.len(), NUM_FEATURES);
    }

    #[test]
    fn file_roundtrip() {
        let ds = Dataset::new(vec![point("x", 8, 42.0)]);
        let dir = std::env::temp_dir().join("perf4sight-test-ds");
        let path = dir.join("ds.json");
        ds.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(back.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_creates_nested_parents_and_errors_name_the_path() {
        let ds = Dataset::new(vec![point("x", 8, 42.0)]);
        let dir = std::env::temp_dir().join(format!(
            "perf4sight-test-ds-nested-{}",
            std::process::id()
        ));
        // Two missing directory levels.
        let path = dir.join("a/b/ds.json");
        ds.save(&path).unwrap();
        assert!(path.exists());
        std::fs::remove_dir_all(&dir).ok();
        // Unwritable target: the error message surfaces the path.
        let bad = std::path::Path::new("/proc/perf4sight-definitely-not-writable/ds.json");
        let err = ds.save(bad).unwrap_err().to_string();
        assert!(err.contains("ds.json"), "error should name the path: {err}");
        // Load errors name the path too.
        let missing = std::path::Path::new("/nonexistent/p4s.json");
        let err = Dataset::load(missing).unwrap_err();
        assert!(err.contains("/nonexistent/p4s.json"), "{err}");
    }

    #[test]
    fn csv_has_header_and_rows() {
        let ds = Dataset::new(vec![point("a", 2, 1.0)]);
        let csv = ds.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("network,strategy,level,bs"));
        assert_eq!(lines[0].split(',').count(), 6 + NUM_FEATURES);
    }

    #[test]
    fn csv_roundtrip_bitwise() {
        let mut a = point("resnet18", 32, 1234.567_890_123);
        a.features = (0..NUM_FEATURES).map(|i| (i as f64) * 0.3 + 0.007).collect();
        a.level = 0.30000000000000004; // a level all_levels() actually produces
        let mut b = point("squeezenet", 2, 0.125);
        b.phi_ms = 1.0 / 3.0;
        let ds = Dataset::new(vec![a, b]);
        let back = Dataset::from_csv(&ds.to_csv()).unwrap();
        // Bitwise identity, JSON bytes included.
        assert_eq!(back.to_json().to_string(), ds.to_json().to_string());
    }

    #[test]
    fn vanilla_points_serialize_without_regime_key() {
        // v1 back-compat: an all-vanilla dataset must produce byte-for-byte
        // the same JSON and CSV as pre-regime builds.
        let ds = Dataset::new(vec![point("a", 2, 1.0)]);
        assert!(!ds.to_json().to_string().contains("regime"));
        assert!(ds.to_csv().starts_with("network,strategy,level,bs"));
    }

    #[test]
    fn regime_roundtrips_json_and_csv() {
        let mut a = point("resnet18", 8, 321.5);
        a.regime = "ckpt:4".into();
        let mut b = point("resnet18", 8, 290.25);
        b.regime = "frozen:2".into();
        let ds = Dataset::new(vec![a, b, point("plain", 2, 1.0)]);

        let j = ds.to_json().to_string();
        let back = Dataset::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.points[0].regime, "ckpt:4");
        assert_eq!(back.points[2].regime, "vanilla");
        assert_eq!(back.to_json().to_string(), j);

        let csv = ds.to_csv();
        assert!(csv.starts_with("network,strategy,regime,level,bs"));
        let back = Dataset::from_csv(&csv).unwrap();
        assert_eq!(back.points[1].regime, "frozen:2");
        assert_eq!(back.to_csv(), csv);
    }

    #[test]
    fn v1_csv_still_loads_with_vanilla_regime() {
        // A regime-less dump (all points vanilla) uses the v1 header; loading
        // it defaults every row to vanilla and re-serializes to the same bytes.
        let ds = Dataset::new(vec![point("a", 2, 1.0), point("b", 4, 2.0)]);
        let v1 = ds.to_csv();
        let back = Dataset::from_csv(&v1).unwrap();
        assert!(back.points.iter().all(|p| p.regime == "vanilla"));
        assert_eq!(back.to_csv(), v1);
    }

    #[test]
    fn csv_rejects_malformed_input() {
        assert!(Dataset::from_csv("").is_err());
        assert!(Dataset::from_csv("wrong,header\n").is_err());
        let good = Dataset::new(vec![point("a", 2, 1.0)]).to_csv();
        let truncated: String = good.lines().next().unwrap().to_string() + "\na,b,0.1\n";
        assert!(Dataset::from_csv(&truncated).is_err());
    }
}
