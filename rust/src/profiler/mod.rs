//! Network-wise profiling (the paper's Sec. 5.1): each datapoint is the
//! *entire network's* training step — never an isolated layer — measured on
//! the (simulated) target device across pruning levels, pruning strategies
//! and batch sizes, paired with the analytical feature vector.

pub mod dataset;

pub use dataset::{Dataset, ProfilePoint};

use crate::device::Simulator;
use crate::features::network_features;
use crate::ir::Graph;
use crate::pruning::{prune, Strategy};
use crate::util::rng::{hash_seed, Pcg64};

/// The paper's 25 profiled batch sizes (App. A): powers of two to 64, then
/// every 10 up to 256.
pub const PAPER_BATCH_SIZES: [usize; 25] = [
    2, 4, 8, 16, 32, 64, 70, 80, 90, 100, 110, 120, 128, 140, 150, 160, 170, 180, 190, 200,
    210, 220, 230, 240, 256,
];

/// The paper's training-set pruning levels (Sec. 6.1): {0, 30, 50, 70, 90}%.
pub const TRAIN_LEVELS: [f64; 5] = [0.0, 0.30, 0.50, 0.70, 0.90];

/// All levels {5x | x ∈ [0, 18]}%.
pub fn all_levels() -> Vec<f64> {
    (0..=18).map(|x| x as f64 * 0.05).collect()
}

/// Test levels: all levels not in the training set.
pub fn test_levels() -> Vec<f64> {
    all_levels()
        .into_iter()
        .filter(|l| !TRAIN_LEVELS.iter().any(|t| (t - l).abs() < 1e-9))
        .collect()
}

/// Profiling job description.
#[derive(Clone, Debug)]
pub struct ProfileJob<'a> {
    pub network: &'a str,
    pub graph: &'a Graph,
    pub strategy: Strategy,
    pub levels: &'a [f64],
    pub batch_sizes: &'a [usize],
    /// Noisy measurements averaged per datapoint (the paper averages
    /// multiple runs; we use 3).
    pub runs: usize,
    /// Base seed; per-(level) streams are derived from it and the network
    /// name, so datasets are exactly reproducible.
    pub seed: u64,
}

impl<'a> ProfileJob<'a> {
    pub fn new(network: &'a str, graph: &'a Graph) -> Self {
        ProfileJob {
            network,
            graph,
            strategy: Strategy::Random,
            levels: &TRAIN_LEVELS,
            batch_sizes: &PAPER_BATCH_SIZES,
            runs: 3,
            seed: 0x9e1f,
        }
    }
}

/// Profile a network per the job spec: for every (level, bs), prune,
/// extract features, and average `runs` noisy simulated measurements.
/// Parallelised over pruning levels with scoped threads.
pub fn profile(sim: &Simulator, job: &ProfileJob) -> Dataset {
    let mut points: Vec<ProfilePoint> = Vec::new();
    let results: Vec<Vec<ProfilePoint>> = std::thread::scope(|scope| {
        let handles: Vec<_> = job
            .levels
            .iter()
            .map(|&level| {
                let sim = sim.clone();
                let job = job.clone();
                scope.spawn(move || profile_one_level(&sim, &job, level))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for r in results {
        points.extend(r);
    }
    Dataset::new(points)
}

fn profile_one_level(sim: &Simulator, job: &ProfileJob, level: f64) -> Vec<ProfilePoint> {
    let stream = hash_seed(&format!(
        "{}/{}/{level:.3}",
        job.network,
        job.strategy.name()
    ));
    let mut rng = Pcg64::with_stream(job.seed, stream);
    let pruned = prune(job.graph, job.strategy, level, &mut rng);
    let mut out = Vec::with_capacity(job.batch_sizes.len());
    for &bs in job.batch_sizes {
        let features = network_features(&pruned, bs).expect("valid pruned graph");
        let mut gamma = 0.0;
        let mut phi = 0.0;
        for _ in 0..job.runs.max(1) {
            let m = sim
                .train_step(&pruned, bs, Some(&mut rng))
                .expect("simulation");
            gamma += m.gamma_mb;
            phi += m.phi_ms;
        }
        let runs = job.runs.max(1) as f64;
        out.push(ProfilePoint {
            network: job.network.to_string(),
            strategy: job.strategy.name(),
            level,
            bs,
            features,
            gamma_mb: gamma / runs,
            phi_ms: phi / runs,
        });
    }
    out
}

/// Convenience: profile one network at the paper's train/test split.
/// Returns `(train, test)` datasets using the given strategies.
pub fn train_test_split(
    sim: &Simulator,
    network: &str,
    graph: &Graph,
    test_strategy: Strategy,
    seed: u64,
) -> (Dataset, Dataset) {
    let train_job = ProfileJob {
        strategy: Strategy::Random,
        levels: &TRAIN_LEVELS,
        seed,
        ..ProfileJob::new(network, graph)
    };
    let levels = test_levels();
    let test_job = ProfileJob {
        strategy: test_strategy,
        levels: &levels,
        seed: seed ^ 0xdead_beef,
        ..ProfileJob::new(network, graph)
    };
    (profile(sim, &train_job), profile(sim, &test_job))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn paper_constants() {
        assert_eq!(PAPER_BATCH_SIZES.len(), 25);
        assert_eq!(all_levels().len(), 19);
        assert_eq!(test_levels().len(), 14);
        assert!((all_levels()[18] - 0.90).abs() < 1e-12);
    }

    #[test]
    fn profile_produces_grid() {
        let sim = Simulator::tx2();
        let g = models::squeezenet(1000);
        let job = ProfileJob {
            levels: &[0.0, 0.5],
            batch_sizes: &[4, 32],
            runs: 2,
            ..ProfileJob::new("squeezenet", &g)
        };
        let ds = profile(&sim, &job);
        assert_eq!(ds.points.len(), 4);
        assert!(ds.points.iter().all(|p| p.gamma_mb > 0.0 && p.phi_ms > 0.0));
        // level-0 bs-32 should consume more than level-0.5 bs-32
        let find = |lvl: f64, bs: usize| {
            ds.points
                .iter()
                .find(|p| (p.level - lvl).abs() < 1e-9 && p.bs == bs)
                .unwrap()
        };
        assert!(find(0.0, 32).gamma_mb > find(0.5, 32).gamma_mb);
    }

    #[test]
    fn profiling_is_reproducible() {
        let sim = Simulator::tx2();
        let g = models::squeezenet(1000);
        let job = ProfileJob {
            levels: &[0.3],
            batch_sizes: &[16],
            ..ProfileJob::new("squeezenet", &g)
        };
        let a = profile(&sim, &job);
        let b = profile(&sim, &job);
        assert_eq!(a.points[0].gamma_mb, b.points[0].gamma_mb);
        assert_eq!(a.points[0].phi_ms, b.points[0].phi_ms);
    }

    #[test]
    fn train_test_levels_disjoint() {
        let sim = Simulator::tx2();
        let g = models::squeezenet(1000);
        let (train, test) =
            train_test_split(&sim, "squeezenet", &g, Strategy::Random, 7);
        let train_levels: Vec<f64> = train.points.iter().map(|p| p.level).collect();
        for p in &test.points {
            assert!(!train_levels.iter().any(|l| (l - p.level).abs() < 1e-9));
        }
        assert_eq!(train.points.len(), 5 * 25);
        assert_eq!(test.points.len(), 14 * 25);
    }
}
