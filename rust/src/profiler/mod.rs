//! Network-wise profiling (the paper's Sec. 5.1): each datapoint is the
//! *entire network's* training step — never an isolated layer — measured on
//! the (simulated) target device across pruning levels, pruning strategies
//! and batch sizes, paired with the analytical feature vector.
//!
//! Execution model: the base graph is compiled once into a
//! [`GraphArena`]; pruning runs once per level (sequentially, on the same
//! per-level RNG stream as always, so pruned topologies stay reproducible
//! and reconstructible by consumers such as the DNNMem comparison) as a
//! `PruneOverlay` whose analysis is rebuilt *incrementally* into shared
//! plan buffers — no graph clone, no from-scratch shape inference; and the
//! flat (level × batch-size) work units are drained by a worker pool, so
//! parallelism is bounded by the unit count (e.g. 125) rather than the
//! level count (5). Every work unit resumes its level's measurement
//! stream at the exact offset the sequential order would have reached
//! (each measurement consumes a fixed number of noise draws), so datasets
//! are **bit-identical** to [`profile_sequential`], the original
//! per-level clone+rebuild implementation kept as the determinism oracle.

pub mod dataset;

pub use dataset::{Dataset, ProfilePoint};

use crate::device::{Simulator, TrainRegime};
use crate::features::network_features_from_plan_regime;
use crate::ir::{Graph, GraphArena, PlanBuffers, PlanSnapshot, PlanView};
use crate::pruning::{prune, prune_overlay, Strategy};
use crate::util::rng::{hash_seed, Pcg64};

/// The paper's 25 profiled batch sizes (App. A): powers of two to 64, then
/// every 10 up to 256.
pub const PAPER_BATCH_SIZES: [usize; 25] = [
    2, 4, 8, 16, 32, 64, 70, 80, 90, 100, 110, 120, 128, 140, 150, 160, 170, 180, 190, 200,
    210, 220, 230, 240, 256,
];

/// The paper's training-set pruning levels (Sec. 6.1): {0, 30, 50, 70, 90}%.
pub const TRAIN_LEVELS: [f64; 5] = [0.0, 0.30, 0.50, 0.70, 0.90];

/// All levels {5x | x ∈ [0, 18]}%.
pub fn all_levels() -> Vec<f64> {
    (0..=18).map(|x| x as f64 * 0.05).collect()
}

/// Test levels: all levels not in the training set.
pub fn test_levels() -> Vec<f64> {
    all_levels()
        .into_iter()
        .filter(|l| !TRAIN_LEVELS.iter().any(|t| (t - l).abs() < 1e-9))
        .collect()
}

/// Profiling job description.
#[derive(Clone, Debug)]
pub struct ProfileJob<'a> {
    pub network: &'a str,
    pub graph: &'a Graph,
    pub strategy: Strategy,
    /// Training regime measured (vanilla, checkpointed, frozen). The
    /// regime shares the level's pruning/noise RNG stream — the pruned
    /// topology and draw schedule are regime-independent, so vanilla
    /// datasets stay bit-identical to the pre-regime profiler.
    pub regime: TrainRegime,
    pub levels: &'a [f64],
    pub batch_sizes: &'a [usize],
    /// Noisy measurements averaged per datapoint (the paper averages
    /// multiple runs; we use 3).
    pub runs: usize,
    /// Base seed; per-(level) streams are derived from it and the network
    /// name, so datasets are exactly reproducible.
    pub seed: u64,
}

impl<'a> ProfileJob<'a> {
    pub fn new(network: &'a str, graph: &'a Graph) -> Self {
        ProfileJob {
            network,
            graph,
            strategy: Strategy::Random,
            regime: TrainRegime::Vanilla,
            levels: &TRAIN_LEVELS,
            batch_sizes: &PAPER_BATCH_SIZES,
            runs: 3,
            seed: 0x9e1f,
        }
    }
}

/// Noise draws one `train_step` measurement consumes from the stream: two
/// log-normal jitters (Γ, Φ), each one Box-Muller normal of two `next_u64`
/// draws. Lets a work unit fast-forward past earlier batch sizes' draws;
/// `flat_profile_matches_sequential_reference` guards the count.
const NOISE_DRAWS_PER_MEASUREMENT: u64 = 4;

/// Worker-pool width for flat profiling schedules: the
/// `PERF4SIGHT_WORKERS` env override when set (pinned, reproducible
/// parallelism for CI and benches), otherwise the machine's available
/// parallelism; always clamped to `[1, cap]`. Used by [`profile`] and the
/// campaign subsystem's in-process execution.
pub fn worker_width(cap: usize) -> usize {
    let fallback = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    env_workers().unwrap_or(fallback).clamp(1, cap.max(1))
}

/// The `PERF4SIGHT_WORKERS` override when set to a positive integer —
/// the single parsing point shared by [`worker_width`] and the campaign
/// driver's worker resolution. A malformed value is **not** silently
/// ignored: it falls back to auto width but warns on stderr (once per
/// process), so a typo like `PERF4SIGHT_WORKERS=8x` cannot quietly
/// change which parallelism a "pinned" CI run actually used.
pub(crate) fn env_workers() -> Option<usize> {
    match parse_workers(std::env::var("PERF4SIGHT_WORKERS").ok().as_deref()) {
        Ok(n) => n,
        Err(err) => {
            static WARN_ONCE: std::sync::Once = std::sync::Once::new();
            WARN_ONCE.call_once(|| eprintln!("warning: {err}; using auto worker width"));
            None
        }
    }
}

/// Pure parsing logic behind [`env_workers`], split out for tests
/// (reading the real env var would race across the parallel test runner).
/// `Ok(None)` means unset (auto width); a set-but-malformed value is a
/// named error, never a silent fallback.
fn parse_workers(raw: Option<&str>) -> Result<Option<usize>, String> {
    let Some(raw) = raw else { return Ok(None) };
    match raw.trim().parse::<usize>() {
        Ok(0) => Err("PERF4SIGHT_WORKERS must be a positive integer, got 0".to_string()),
        Ok(n) => Ok(Some(n)),
        Err(_) => Err(format!(
            "PERF4SIGHT_WORKERS must be a positive integer, got {raw:?}"
        )),
    }
}

/// Profile a network per the job spec: for every (level, bs), prune,
/// extract features, and average `runs` noisy simulated measurements.
///
/// The base graph is compiled once into a [`GraphArena`]; each level's
/// pruning is a [`PruneOverlay`](crate::ir::PruneOverlay) on the
/// historical per-level RNG stream, and its analysis is rebuilt
/// *incrementally* into shared [`PlanBuffers`] (level N+1 diffs against
/// level N — no graph clone, no from-scratch inference). The flat
/// (level, bs) work units then run on a scoped worker pool, each unit
/// reading its level's detached [`PlanSnapshot`] and resuming the level's
/// measurement stream at its sequential offset — output is bit-identical
/// to [`profile_sequential`], the clone+rebuild oracle.
pub fn profile(sim: &Simulator, job: &ProfileJob) -> Dataset {
    let arena = GraphArena::compile(job.graph).expect("valid base graph");
    let mut buffers = PlanBuffers::new();
    // One pruning overlay + analysis snapshot per level, on the historical
    // per-level stream (consumers reconstruct these topologies from the
    // same derivation). The post-prune RNG state is kept: it is the start
    // of the level's measurement stream.
    let pruned: Vec<(f64, PlanSnapshot, Pcg64)> = job
        .levels
        .iter()
        .map(|&level| {
            let mut rng = Pcg64::with_stream(
                job.seed,
                level_stream(job.network, job.strategy, level),
            );
            let overlay = prune_overlay(&arena, job.strategy, level, &mut rng);
            arena
                .plan_into(&overlay, &mut buffers)
                .expect("valid pruned overlay");
            (level, buffers.snapshot(), rng)
        })
        .collect();

    // Flat (level, bs) work units drained work-stealing style.
    let units: Vec<(usize, usize)> = (0..pruned.len())
        .flat_map(|li| (0..job.batch_sizes.len()).map(move |bi| (li, bi)))
        .collect();
    let workers = worker_width(units.len());
    let mut results = crate::util::pool::drain_indexed(units.len(), workers, |i| {
        let (li, bi) = units[i];
        let (level, ref snap, ref base_rng) = pruned[li];
        profile_unit(
            sim,
            job.network,
            job.strategy,
            job.regime,
            job.runs,
            &arena.view(snap),
            level,
            base_rng,
            bi,
            job.batch_sizes[bi],
        )
    });
    // Restore the deterministic level-major, batch-size-minor order.
    results.sort_by_key(|&(i, _)| i);
    Dataset::new(results.into_iter().map(|(_, p)| p).collect())
}

/// The original single-thread-per-level implementation, kept as the
/// determinism oracle for [`profile`]: one RNG stream per level drives
/// pruning and then every measurement in batch-size order, with the
/// direct-graph (non-plan) analysis paths. With `TrainRegime::Vanilla`
/// the regime entry points delegate to the unmodified pre-regime code,
/// so this remains the historical reference byte for byte.
pub fn profile_sequential(sim: &Simulator, job: &ProfileJob) -> Dataset {
    let mut points = Vec::new();
    for &level in job.levels {
        let mut rng = Pcg64::with_stream(
            job.seed,
            level_stream(job.network, job.strategy, level),
        );
        let pruned = prune(job.graph, job.strategy, level, &mut rng);
        for &bs in job.batch_sizes {
            let convs = pruned.conv_infos().expect("valid pruned graph");
            let features =
                crate::features::network_features_from_convs_regime(&convs, bs, job.regime);
            let mut gamma = 0.0;
            let mut phi = 0.0;
            for _ in 0..job.runs.max(1) {
                let m = sim
                    .train_step_regime(&pruned, bs, job.regime, Some(&mut rng))
                    .expect("simulation");
                gamma += m.gamma_mb;
                phi += m.phi_ms;
            }
            let runs = job.runs.max(1) as f64;
            points.push(ProfilePoint {
                network: job.network.to_string(),
                strategy: job.strategy.name(),
                regime: job.regime.name(),
                level,
                bs,
                features,
                gamma_mb: gamma / runs,
                phi_ms: phi / runs,
            });
        }
    }
    Dataset::new(points)
}

/// Per-level RNG stream (drives pruning then measurement; the historical
/// derivation — `dnnmem_cmp` reconstructs pruned graphs from it, and the
/// campaign subsystem derives shard-local streams from it).
pub(crate) fn level_stream(network: &str, strategy: Strategy, level: f64) -> u64 {
    hash_seed(&format!("{network}/{}/{level:.3}", strategy.name()))
}

/// One (level, bs) datapoint: plan-based features + averaged noisy runs.
/// `base_rng` is the level stream just after pruning; the unit
/// fast-forwards past the draws earlier batch sizes consume, so any
/// worker — thread or spawned campaign process — can run it anywhere, in
/// any order, and reproduce the sequential values bit for bit. Generic
/// over [`PlanView`], so the campaign driver's overlay plans and any
/// legacy `NetworkPlan` feed the identical code.
#[allow(clippy::too_many_arguments)]
pub(crate) fn profile_unit<P: PlanView>(
    sim: &Simulator,
    network: &str,
    strategy: Strategy,
    regime: TrainRegime,
    runs: usize,
    plan: &P,
    level: f64,
    base_rng: &Pcg64,
    bs_index: usize,
    bs: usize,
) -> ProfilePoint {
    // Finest-grained fault seam: lets tests kill or stall a worker inside
    // a unit, between the shard-level checkpoints. Unit-start faults are
    // infallible by construction (`error` is rejected at parse time) so
    // the measurement path stays non-Result.
    crate::util::fault::check_infallible(crate::util::fault::FaultPoint::UnitStart, None);
    let runs = runs.max(1);
    let mut rng = base_rng.clone();
    rng.advance(bs_index as u64 * runs as u64 * NOISE_DRAWS_PER_MEASUREMENT);
    let features = network_features_from_plan_regime(plan, bs, regime);
    let mut gamma = 0.0;
    let mut phi = 0.0;
    for _ in 0..runs {
        let m = sim.train_step_plan_regime(plan, bs, regime, Some(&mut rng));
        gamma += m.gamma_mb;
        phi += m.phi_ms;
    }
    ProfilePoint {
        network: network.to_string(),
        strategy: strategy.name(),
        regime: regime.name(),
        level,
        bs,
        features,
        gamma_mb: gamma / runs as f64,
        phi_ms: phi / runs as f64,
    }
}

/// Convenience: profile one network at the paper's train/test split.
/// Returns `(train, test)` datasets using the given strategies.
pub fn train_test_split(
    sim: &Simulator,
    network: &str,
    graph: &Graph,
    test_strategy: Strategy,
    seed: u64,
) -> (Dataset, Dataset) {
    let train_job = ProfileJob {
        strategy: Strategy::Random,
        levels: &TRAIN_LEVELS,
        seed,
        ..ProfileJob::new(network, graph)
    };
    let levels = test_levels();
    let test_job = ProfileJob {
        strategy: test_strategy,
        levels: &levels,
        seed: seed ^ 0xdead_beef,
        ..ProfileJob::new(network, graph)
    };
    (profile(sim, &train_job), profile(sim, &test_job))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn worker_env_parsing_and_clamp() {
        // Override applies when parseable and positive; unset means auto.
        assert_eq!(parse_workers(Some("2")), Ok(Some(2)));
        assert_eq!(parse_workers(Some(" 3 ")), Ok(Some(3)));
        assert_eq!(parse_workers(None), Ok(None));
        // Junk and zero are *named* errors, not a silent fallback.
        let junk = parse_workers(Some("zippy")).unwrap_err();
        assert!(junk.contains("PERF4SIGHT_WORKERS"), "{junk}");
        assert!(junk.contains("zippy"), "{junk}");
        let zero = parse_workers(Some("0")).unwrap_err();
        assert!(zero.contains("positive"), "{zero}");
        assert!(parse_workers(Some("-1")).is_err());
        assert!(parse_workers(Some("")).is_err());
        // worker_width clamps to [1, cap] whatever the env says.
        assert!(worker_width(4) <= 4);
        assert_eq!(worker_width(0), 1);
    }

    #[test]
    fn paper_constants() {
        assert_eq!(PAPER_BATCH_SIZES.len(), 25);
        assert_eq!(all_levels().len(), 19);
        assert_eq!(test_levels().len(), 14);
        assert!((all_levels()[18] - 0.90).abs() < 1e-12);
    }

    #[test]
    fn profile_produces_grid() {
        let sim = Simulator::tx2();
        let g = models::squeezenet(1000);
        let job = ProfileJob {
            levels: &[0.0, 0.5],
            batch_sizes: &[4, 32],
            runs: 2,
            ..ProfileJob::new("squeezenet", &g)
        };
        let ds = profile(&sim, &job);
        assert_eq!(ds.points.len(), 4);
        assert!(ds.points.iter().all(|p| p.gamma_mb > 0.0 && p.phi_ms > 0.0));
        // level-0 bs-32 should consume more than level-0.5 bs-32
        let find = |lvl: f64, bs: usize| {
            ds.points
                .iter()
                .find(|p| (p.level - lvl).abs() < 1e-9 && p.bs == bs)
                .unwrap()
        };
        assert!(find(0.0, 32).gamma_mb > find(0.5, 32).gamma_mb);
    }

    #[test]
    fn flat_profile_matches_sequential_reference() {
        // The flat parallel schedule + plan reuse must reproduce the
        // original per-level sequential implementation bit for bit
        // (features, Γ and Φ — including the noise draws).
        let sim = Simulator::tx2();
        let g = models::squeezenet(1000);
        let job = ProfileJob {
            levels: &[0.0, 0.4, 0.7],
            batch_sizes: &[4, 16, 32],
            runs: 2,
            ..ProfileJob::new("squeezenet", &g)
        };
        let flat = profile(&sim, &job);
        let seq = profile_sequential(&sim, &job);
        assert_eq!(flat.len(), seq.len());
        for (a, b) in flat.points.iter().zip(&seq.points) {
            assert_eq!((a.level, a.bs), (b.level, b.bs));
            assert_eq!(a.features, b.features, "level {} bs {}", a.level, a.bs);
            assert_eq!(a.gamma_mb, b.gamma_mb, "level {} bs {}", a.level, a.bs);
            assert_eq!(a.phi_ms, b.phi_ms, "level {} bs {}", a.level, a.bs);
        }
    }

    #[test]
    fn regime_profile_matches_sequential_reference() {
        // The flat schedule must reproduce the sequential reference for
        // non-vanilla regimes too — same pruned topologies, same draws.
        let sim = Simulator::tx2();
        let g = models::squeezenet(1000);
        for regime in [
            TrainRegime::Checkpointed { segments: 4 },
            TrainRegime::Frozen { trainable_suffix: 3 },
        ] {
            let job = ProfileJob {
                regime,
                levels: &[0.0, 0.5],
                batch_sizes: &[4, 16],
                runs: 2,
                ..ProfileJob::new("squeezenet", &g)
            };
            let flat = profile(&sim, &job);
            let seq = profile_sequential(&sim, &job);
            assert_eq!(flat.len(), seq.len());
            for (a, b) in flat.points.iter().zip(&seq.points) {
                assert_eq!(a.regime, regime.name());
                assert_eq!(a.features, b.features);
                assert_eq!(a.gamma_mb, b.gamma_mb);
                assert_eq!(a.phi_ms, b.phi_ms);
            }
        }
    }

    #[test]
    fn profiling_is_reproducible() {
        let sim = Simulator::tx2();
        let g = models::squeezenet(1000);
        let job = ProfileJob {
            levels: &[0.3],
            batch_sizes: &[16],
            ..ProfileJob::new("squeezenet", &g)
        };
        let a = profile(&sim, &job);
        let b = profile(&sim, &job);
        assert_eq!(a.points[0].gamma_mb, b.points[0].gamma_mb);
        assert_eq!(a.points[0].phi_ms, b.points[0].phi_ms);
    }

    #[test]
    fn train_test_levels_disjoint() {
        let sim = Simulator::tx2();
        let g = models::squeezenet(1000);
        let (train, test) =
            train_test_split(&sim, "squeezenet", &g, Strategy::Random, 7);
        let train_levels: Vec<f64> = train.points.iter().map(|p| p.level).collect();
        for p in &test.points {
            assert!(!train_levels.iter().any(|l| (l - p.level).abs() < 1e-9));
        }
        assert_eq!(train.points.len(), 5 * 25);
        assert_eq!(test.points.len(), 14 * 25);
    }
}
