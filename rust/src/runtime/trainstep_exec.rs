//! Training-step executor: drives `trainstep.hlo.txt` — a full
//! fwd+bwd+SGD step of the L2 CNN whose convolutions are the L1 Pallas
//! kernels — from Rust. Used by `examples/train_cnn.rs` to train on a
//! synthetic workload and log the loss curve (the end-to-end validation
//! demanded by DESIGN.md §6).

use anyhow::Result;
#[cfg(feature = "xla")]
use anyhow::Context;

use crate::util::rng::Pcg64;

use super::Runtime;
#[cfg(feature = "xla")]
use super::{literal_f32, literal_scalar_f32};

/// Shapes of the training artifact (mirrors python/compile/model.py).
pub const TRAIN_BATCH: usize = 64;
pub const IMG_C: usize = 3;
pub const IMG_HW: usize = 32;
pub const NUM_CLASSES: usize = 10;
pub const CHANNELS: [usize; 3] = [16, 32, 32];

/// Host-side training state: the 8 parameter tensors.
#[derive(Clone, Debug)]
pub struct TrainState {
    /// (data, dims) per parameter, in artifact order
    /// w1,b1,w2,b2,w3,b3,wf,bf.
    pub params: Vec<(Vec<f32>, Vec<i64>)>,
}

impl TrainState {
    /// He-initialised parameters (matches `model.init_params` in spirit;
    /// exact values differ — initialisation is host-side).
    pub fn init(seed: u64) -> TrainState {
        let mut rng = Pcg64::new(seed);
        let [c1, c2, c3] = CHANNELS;
        let mut params = Vec::new();
        let mut he = |shape: Vec<i64>, fan_in: usize| {
            let n: usize = shape.iter().map(|&d| d as usize).product();
            let std = (2.0 / fan_in as f64).sqrt();
            let data: Vec<f32> = (0..n).map(|_| (rng.normal() * std) as f32).collect();
            (data, shape)
        };
        params.push(he(vec![c1 as i64, IMG_C as i64, 3, 3], IMG_C * 9));
        params.push((vec![0.0; c1], vec![c1 as i64]));
        params.push(he(vec![c2 as i64, c1 as i64, 3, 3], c1 * 9));
        params.push((vec![0.0; c2], vec![c2 as i64]));
        params.push(he(vec![c3 as i64, c2 as i64, 3, 3], c2 * 9));
        params.push((vec![0.0; c3], vec![c3 as i64]));
        params.push(he(vec![c3 as i64, NUM_CLASSES as i64], c3));
        params.push((vec![0.0; NUM_CLASSES], vec![NUM_CLASSES as i64]));
        TrainState { params }
    }
}

/// The executor (stub without the `xla` feature: construction fails).
#[cfg(feature = "xla")]
pub struct TrainStepExecutor {
    exe: xla::PjRtLoadedExecutable,
}

/// Stub executor: keeps callers compiling without the `xla` feature.
#[cfg(not(feature = "xla"))]
pub struct TrainStepExecutor {
    _private: (),
}

#[cfg(not(feature = "xla"))]
impl TrainStepExecutor {
    pub fn new(_rt: &Runtime) -> Result<TrainStepExecutor> {
        anyhow::bail!("TrainStepExecutor requires the `xla` feature")
    }

    pub fn step(&self, _state: &mut TrainState, _x: &[f32], _y: &[i32], _lr: f32) -> Result<f64> {
        anyhow::bail!("TrainStepExecutor requires the `xla` feature")
    }
}

#[cfg(feature = "xla")]
impl TrainStepExecutor {
    pub fn new(rt: &Runtime) -> Result<TrainStepExecutor> {
        Ok(TrainStepExecutor {
            exe: rt.load("trainstep.hlo.txt")?,
        })
    }

    /// Execute one SGD step; updates `state` in place, returns the loss.
    /// `x`: (TRAIN_BATCH·3·32·32) f32, `y`: TRAIN_BATCH labels.
    pub fn step(&self, state: &mut TrainState, x: &[f32], y: &[i32], lr: f32) -> Result<f64> {
        assert_eq!(x.len(), TRAIN_BATCH * IMG_C * IMG_HW * IMG_HW);
        assert_eq!(y.len(), TRAIN_BATCH);
        let mut args: Vec<xla::Literal> = Vec::with_capacity(11);
        for (data, dims) in &state.params {
            args.push(literal_f32(data, dims)?);
        }
        args.push(literal_f32(
            x,
            &[TRAIN_BATCH as i64, IMG_C as i64, IMG_HW as i64, IMG_HW as i64],
        )?);
        args.push(
            xla::Literal::vec1(y)
                .reshape(&[TRAIN_BATCH as i64])
                .map_err(|e| anyhow::anyhow!("labels: {e:?}"))?,
        );
        args.push(literal_scalar_f32(lr));

        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("trainstep execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch: {e:?}"))?;
        let mut outs = result
            .clone()
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        anyhow::ensure!(outs.len() == 9, "expected 9 outputs, got {}", outs.len());
        let loss_lit = outs.pop().context("loss output")?;
        for (slot, lit) in state.params.iter_mut().zip(outs) {
            slot.0 = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("param out: {e:?}"))?;
        }
        let loss: f32 = loss_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("loss: {e:?}"))?[0];
        Ok(loss as f64)
    }
}

/// Synthetic classification batch matching python/tests/test_model.py:
/// class k shifts channel (k mod 3) in a class-dependent half of the image.
pub fn synthetic_batch(rng: &mut Pcg64) -> (Vec<f32>, Vec<i32>) {
    let mut x = vec![0f32; TRAIN_BATCH * IMG_C * IMG_HW * IMG_HW];
    let mut y = vec![0i32; TRAIN_BATCH];
    for b in 0..TRAIN_BATCH {
        let label = rng.gen_range(NUM_CLASSES);
        y[b] = label as i32;
        let c = label % IMG_C;
        let q = label / IMG_C;
        for ch in 0..IMG_C {
            for i in 0..IMG_HW {
                for j in 0..IMG_HW {
                    let mut v = (rng.normal() * 0.5) as f32;
                    if ch == c && (i / 16) == (q % 2) {
                        v += 1.5;
                    }
                    x[((b * IMG_C + ch) * IMG_HW + i) * IMG_HW + j] = v;
                }
            }
        }
    }
    (x, y)
}
