//! Forest-inference executor: runs the `forest_b{1,256}.hlo.txt` artifacts
//! (L2 graph wrapping the L1 Pallas traversal kernel) against forests
//! fitted in Rust, padded to the artifact's fixed shapes.
//!
//! The padded tensors come from the same `CompiledForest` slab layout the
//! native `PredictionEngine` serves (`Forest::to_tensors` delegates to
//! `CompiledForest::to_tensors`), so the artifact path and the batched
//! host path traverse one forest representation; `ForestTensors::
//! predict_rows` is the host-side reference for the kernel's
//! rows-per-tree batching schedule.
//!
//! The executor itself needs the `xla` feature; the artifact-shape
//! constants and the export-compatible forest config below are pure Rust
//! and always available.

use anyhow::Result;

use crate::forest::{Forest, ForestTensors};

use super::Runtime;

/// Fixed artifact shapes (mirrors `python/compile/model.py` and
/// `artifacts/manifest.json`).
#[derive(Clone, Copy, Debug)]
pub struct ForestArtifactShape {
    pub trees: usize,
    pub nodes: usize,
    pub depth: usize,
    pub features: usize,
}

impl Default for ForestArtifactShape {
    fn default() -> Self {
        ForestArtifactShape {
            trees: 64,
            nodes: 2048,
            depth: 16,
            features: crate::features::NUM_FEATURES,
        }
    }
}

/// An executor bound to one fitted forest.
///
/// §Perf note: the five tree tensors (~2.6 MB total at the 64×2048
/// artifact shape) are uploaded ONCE as device-resident [`xla::PjRtBuffer`]s
/// at construction and reused by every call via `execute_b`; only the
/// feature rows are transferred per prediction. The original
/// literal-per-call implementation deep-copied all five arrays on every
/// prediction and was ~39× slower on the single-row path (see
/// EXPERIMENTS.md §Perf).
#[cfg(feature = "xla")]
pub struct ForestExecutor {
    client: xla::PjRtClient,
    exe_b1: xla::PjRtLoadedExecutable,
    exe_b256: xla::PjRtLoadedExecutable,
    shape: ForestArtifactShape,
    // Device-resident tree tensors, uploaded once.
    feature: xla::PjRtBuffer,
    threshold: xla::PjRtBuffer,
    left: xla::PjRtBuffer,
    right: xla::PjRtBuffer,
    value: xla::PjRtBuffer,
}

#[cfg(feature = "xla")]
impl ForestExecutor {
    /// Load the artifacts and bind `forest` (must fit the artifact shape:
    /// exactly `trees` trees — padding trees would change the mean — and at
    /// most `nodes` nodes and `depth` levels).
    pub fn new(rt: &Runtime, forest: &Forest) -> Result<ForestExecutor> {
        use anyhow::bail;
        let shape = ForestArtifactShape::default();
        let mut t = forest.to_tensors();
        if t.n_trees != shape.trees {
            bail!(
                "forest has {} trees; the artifact expects exactly {} \
                 (fit with ForestConfig::for_export())",
                t.n_trees,
                shape.trees
            );
        }
        if t.n_nodes > shape.nodes {
            bail!(
                "forest trees too large: {} nodes > artifact cap {} \
                 (reduce max_depth or raise min_samples_leaf)",
                t.n_nodes,
                shape.nodes
            );
        }
        if t.depth > shape.depth {
            bail!("tree depth {} exceeds artifact traversal depth {}", t.depth, shape.depth);
        }
        if forest.n_features != shape.features {
            bail!(
                "forest has {} features, artifact expects {}",
                forest.n_features,
                shape.features
            );
        }
        t.pad_nodes_to(shape.nodes);
        let dims = [shape.trees, shape.nodes];
        let upload_i32 = |data: &[i32]| {
            rt.client
                .buffer_from_host_buffer(data, &dims, None)
                .map_err(|e| anyhow::anyhow!("tree tensor upload: {e:?}"))
        };
        let upload_f32 = |data: &[f32]| {
            rt.client
                .buffer_from_host_buffer(data, &dims, None)
                .map_err(|e| anyhow::anyhow!("tree tensor upload: {e:?}"))
        };
        Ok(ForestExecutor {
            client: rt.client.clone(),
            exe_b1: rt.load("forest_b1.hlo.txt")?,
            exe_b256: rt.load("forest_b256.hlo.txt")?,
            shape,
            feature: upload_i32(&t.feature)?,
            threshold: upload_f32(&t.threshold)?,
            left: upload_i32(&t.left)?,
            right: upload_i32(&t.right)?,
            value: upload_f32(&t.value)?,
        })
    }

    /// Tensor form of the bound forest (for cross-checks).
    pub fn shape(&self) -> ForestArtifactShape {
        self.shape
    }

    fn run(&self, exe: &xla::PjRtLoadedExecutable, xs: &[f32], batch: usize, n: usize) -> Result<Vec<f64>> {
        // Only the feature rows move host→device; tree tensors are resident.
        let x = self
            .client
            .buffer_from_host_buffer(xs, &[batch, self.shape.features], None)
            .map_err(|e| anyhow::anyhow!("x upload: {e:?}"))?;
        let args = [
            &x,
            &self.feature,
            &self.threshold,
            &self.left,
            &self.right,
            &self.value,
        ];
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow::anyhow!("forest execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        let v: Vec<f32> = out
            .to_vec()
            .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        Ok(v.into_iter().take(n).map(|x| x as f64).collect())
    }

    /// Predict a single feature row through the XLA artifact.
    pub fn predict_one(&self, row: &[f64]) -> Result<f64> {
        let xs: Vec<f32> = row.iter().map(|&v| v as f32).collect();
        Ok(self.run(&self.exe_b1, &xs, 1, 1)?[0])
    }

    /// Predict many rows (chunks of 256; the final chunk is zero-padded).
    pub fn predict_batch(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        let f = self.shape.features;
        let mut out = Vec::with_capacity(rows.len());
        let mut xs = vec![0f32; 256 * f];
        for chunk in rows.chunks(256) {
            xs.iter_mut().for_each(|v| *v = 0.0);
            for (i, row) in chunk.iter().enumerate() {
                for (j, &v) in row.iter().enumerate() {
                    xs[i * f + j] = v as f32;
                }
            }
            out.extend(self.run(&self.exe_b256, &xs, 256, chunk.len())?);
        }
        Ok(out)
    }
}

/// Stub executor: keeps callers compiling without the `xla` feature; every
/// operation reports that the PJRT path is unavailable. Unconstructible in
/// practice because [`Runtime::cpu`] already fails in stub builds.
#[cfg(not(feature = "xla"))]
pub struct ForestExecutor {
    _private: (),
}

#[cfg(not(feature = "xla"))]
impl ForestExecutor {
    pub fn new(_rt: &Runtime, _forest: &Forest) -> Result<ForestExecutor> {
        anyhow::bail!("ForestExecutor requires the `xla` feature")
    }

    pub fn shape(&self) -> ForestArtifactShape {
        ForestArtifactShape::default()
    }

    pub fn predict_one(&self, _row: &[f64]) -> Result<f64> {
        anyhow::bail!("ForestExecutor requires the `xla` feature")
    }

    pub fn predict_batch(&self, _rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        anyhow::bail!("ForestExecutor requires the `xla` feature")
    }
}

/// Forest config whose shape always fits the artifact: exactly 64 trees,
/// depth ≤ 15.
pub fn export_forest_config() -> crate::forest::ForestConfig {
    crate::forest::ForestConfig {
        n_trees: 64,
        max_depth: 14,
        ..Default::default()
    }
}

/// Validate a fitted forest against the artifact shape without a runtime.
pub fn fits_artifact(t: &ForestTensors) -> bool {
    let s = ForestArtifactShape::default();
    t.n_trees == s.trees && t.n_nodes <= s.nodes && t.depth <= s.depth
}

/// As [`fits_artifact`], straight off the engine's compiled slab layout
/// (no padded export needed — the two representations share tree shape).
pub fn compiled_fits_artifact(c: &crate::engine::CompiledForest) -> bool {
    let s = ForestArtifactShape::default();
    c.n_trees() == s.trees && c.max_tree_nodes() <= s.nodes && c.depth() <= s.depth
}
