//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path. Python
//! is never involved at runtime — the pattern from
//! /opt/xla-example/load_hlo/ (HLO *text* interchange; see aot.py for why
//! text, not serialised protos).
//!
//! The PJRT-backed execution paths require the `xla` cargo feature (which
//! in turn needs the xla-rs bindings and libpjrt from the lab toolchain
//! image). Without the feature this module compiles as a stub: artifact
//! presence checks, manifest parsing and the pure-Rust pieces
//! ([`TrainState`], [`forest_exec::export_forest_config`], …) all work,
//! while [`Runtime::cpu`] and the executors return a clear error.

pub mod forest_exec;
pub mod trainstep_exec;

pub use forest_exec::ForestExecutor;
pub use trainstep_exec::{TrainState, TrainStepExecutor};

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// A loaded PJRT CPU runtime (stub without the `xla` feature:
/// construction fails with a clear error).
pub struct Runtime {
    #[cfg(feature = "xla")]
    pub client: xla::PjRtClient,
    pub artifacts: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    #[cfg(feature = "xla")]
    pub fn cpu(artifacts: impl Into<PathBuf>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifacts: artifacts.into(),
        })
    }

    /// Stub: the crate was built without the `xla` feature, so no PJRT
    /// client can be created.
    #[cfg(not(feature = "xla"))]
    pub fn cpu(artifacts: impl Into<PathBuf>) -> Result<Runtime> {
        let artifacts: PathBuf = artifacts.into();
        anyhow::bail!(
            "PJRT runtime unavailable: perf4sight was built without the `xla` feature \
             (artifacts dir: {}). Rebuild with `--features xla` on a machine with the \
             xla-rs toolchain.",
            artifacts.display()
        )
    }

    /// Load + compile an HLO-text artifact by file name.
    #[cfg(feature = "xla")]
    pub fn load(&self, name: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.artifacts.join(name);
        self.load_path(&path)
    }

    /// Stub: loading executables needs the `xla` feature.
    #[cfg(not(feature = "xla"))]
    pub fn load(&self, name: &str) -> Result<()> {
        anyhow::bail!(
            "cannot load {name}: perf4sight was built without the `xla` feature"
        )
    }

    /// Load + compile an HLO-text file.
    #[cfg(feature = "xla")]
    pub fn load_path(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))
    }

    /// Parse `manifest.json` from the artifacts directory.
    pub fn manifest(&self) -> Result<Json> {
        let path = self.artifacts.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))
    }

    /// True if the artifacts directory holds all expected files.
    pub fn artifacts_present(dir: &Path) -> bool {
        [
            "trainstep.hlo.txt",
            "forest_b1.hlo.txt",
            "forest_b256.hlo.txt",
            "manifest.json",
        ]
        .iter()
        .all(|f| dir.join(f).exists())
    }
}

/// Build an f32 literal with the given dims.
#[cfg(feature = "xla")]
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    lit.reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape f32 literal: {e:?}"))
}

/// Build an i32 literal with the given dims.
#[cfg(feature = "xla")]
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    lit.reshape(dims)
        .map_err(|e| anyhow::anyhow!("reshape i32 literal: {e:?}"))
}

/// Build an f32 scalar literal.
#[cfg(feature = "xla")]
pub fn literal_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "xla")]
    #[test]
    fn literal_construction_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        let v: Vec<f32> = l.to_vec().unwrap();
        assert_eq!(v, vec![1.0, 2.0, 3.0, 4.0]);
        let i = literal_i32(&[5, 6], &[2]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![5, 6]);
    }

    #[test]
    fn artifacts_presence_check() {
        assert!(!Runtime::artifacts_present(Path::new("/nonexistent")));
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_errors_cleanly() {
        let err = Runtime::cpu("/tmp/nowhere").err().expect("stub must error");
        assert!(err.to_string().contains("xla"));
    }
}
