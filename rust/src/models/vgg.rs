//! VGG-16 and NiN — networks used by the related work the paper compares
//! against ([14] Augur profiles NIN/VGG; [5] DNNMem profiles VGG16), kept
//! in the zoo for the baseline experiments and extra coverage.

use crate::ir::{Act, Graph, GraphBuilder, NodeId, Op};

/// VGG-16 (configuration D) with batch-norm.
pub fn vgg16(classes: usize) -> Graph {
    let mut g = Graph::new("vgg16");
    let x = g.input(3, 224, 224);
    let cfg: [&[usize]; 5] = [
        &[64, 64],
        &[128, 128],
        &[256, 256, 256],
        &[512, 512, 512],
        &[512, 512, 512],
    ];
    let mut cur = x;
    for (bi, block) in cfg.iter().enumerate() {
        for (ci, &c) in block.iter().enumerate() {
            cur = g.conv_bn_act(&format!("conv{}_{}", bi + 1, ci + 1), cur, c, 3, 1, 1, Act::Relu);
        }
        cur = g.maxpool(&format!("pool{}", bi + 1), cur, 2, 2, 0);
    }
    let f = g.add("flatten", Op::Flatten, &[cur]);
    let l1 = g.add("fc1", Op::Linear { out: 4096, bias: true }, &[f]);
    let r1 = g.add("fc1.relu", Op::Activation(Act::Relu), &[l1]);
    let d1 = g.add("fc1.drop", Op::Dropout(0.5), &[r1]);
    let l2 = g.add("fc2", Op::Linear { out: 4096, bias: true }, &[d1]);
    let r2 = g.add("fc2.relu", Op::Activation(Act::Relu), &[l2]);
    let d2 = g.add("fc2.drop", Op::Dropout(0.5), &[r2]);
    g.add("fc3", Op::Linear { out: classes, bias: true }, &[d2]);
    g
}

/// Network-in-Network (Lin et al., 2014), ImageNet variant.
pub fn nin(classes: usize) -> Graph {
    let mut g = Graph::new("nin");
    let x = g.input(3, 224, 224);
    let block = |g: &mut Graph, name: &str, input: NodeId, c: usize, k: usize, s: usize, p: usize| {
        let c1 = g.conv(&format!("{name}.conv"), input, c, k, s, p);
        let r1 = g.relu(&format!("{name}.relu"), c1);
        let m1 = g.conv(&format!("{name}.cccp1"), r1, c, 1, 1, 0);
        let mr1 = g.relu(&format!("{name}.cccp1.relu"), m1);
        let m2 = g.conv(&format!("{name}.cccp2"), mr1, c, 1, 1, 0);
        g.relu(&format!("{name}.cccp2.relu"), m2)
    };
    let b1 = block(&mut g, "block1", x, 96, 11, 4, 0);
    let p1 = g.maxpool_ceil("pool1", b1, 3, 2, 0);
    let b2 = block(&mut g, "block2", p1, 256, 5, 1, 2);
    let p2 = g.maxpool_ceil("pool2", b2, 3, 2, 0);
    let b3 = block(&mut g, "block3", p2, 384, 3, 1, 1);
    let p3 = g.maxpool_ceil("pool3", b3, 3, 2, 0);
    let d = g.add("dropout", Op::Dropout(0.5), &[p3]);
    // Final block maps straight to class scores, then GAP.
    let c4 = g.conv("block4.conv", d, 1024, 3, 1, 1);
    let r4 = g.relu("block4.relu", c4);
    let m4 = g.conv("block4.cccp1", r4, 1024, 1, 1, 0);
    let mr4 = g.relu("block4.cccp1.relu", m4);
    let cls = g.conv("block4.cccp2", mr4, classes, 1, 1, 0);
    let rc = g.relu("block4.cccp2.relu", cls);
    let gp = g.gap("gap", rc);
    g.add("flatten", Op::Flatten, &[gp]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_params_match_torchvision() {
        let g = vgg16(1000);
        // torchvision vgg16_bn: 138.37M
        let p = g.param_count().unwrap() as f64 / 1e6;
        assert!((137.0..140.0).contains(&p), "params = {p}M");
        assert_eq!(g.conv_infos().unwrap().len(), 13);
    }

    #[test]
    fn vgg16_flatten_is_25088() {
        let g = vgg16(1000);
        let shapes = g.infer_shapes().unwrap();
        let f = g.nodes.iter().find(|n| n.name == "flatten").unwrap().id;
        assert_eq!(shapes[f].numel(), 512 * 7 * 7);
    }

    #[test]
    fn nin_output_classes() {
        let g = nin(1000);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.output].numel(), 1000);
        // 3 blocks * 3 convs + final block (conv + 2 cccp)
        assert_eq!(g.conv_infos().unwrap().len(), 12);
    }
}
