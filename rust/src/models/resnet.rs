//! ResNet-18 (basic blocks) and ResNet-50 (bottleneck blocks), He et al.
//! 2016, torchvision layout at 3×224×224. ResNet18 is in the paper's
//! profiling basis; ResNet50 tests basis generalisation (Fig. 4) and the
//! DNNMem comparison (Sec. 6.2.1).

use crate::ir::{Act, Graph, GraphBuilder, NodeId};

/// One basic residual block (two 3×3 convs) with optional downsample.
fn basic_block(
    g: &mut Graph,
    name: &str,
    input: NodeId,
    out_c: usize,
    stride: usize,
    downsample: bool,
) -> NodeId {
    let c1 = g.conv_bn_act(&format!("{name}.conv1"), input, out_c, 3, stride, 1, Act::Relu);
    let c2 = g.conv_bn(&format!("{name}.conv2"), c1, out_c, 3, 1, 1);
    let identity = if downsample {
        g.conv_bn(&format!("{name}.downsample"), input, out_c, 1, stride, 0)
    } else {
        input
    };
    let j = g.add_join(&format!("{name}.add"), &[c2, identity]);
    g.relu(&format!("{name}.relu"), j)
}

/// One bottleneck block (1×1 reduce, 3×3, 1×1 expand ×4).
fn bottleneck_block(
    g: &mut Graph,
    name: &str,
    input: NodeId,
    mid_c: usize,
    out_c: usize,
    stride: usize,
    downsample: bool,
) -> NodeId {
    let c1 = g.conv_bn_act(&format!("{name}.conv1"), input, mid_c, 1, 1, 0, Act::Relu);
    let c2 = g.conv_bn_act(&format!("{name}.conv2"), c1, mid_c, 3, stride, 1, Act::Relu);
    let c3 = g.conv_bn(&format!("{name}.conv3"), c2, out_c, 1, 1, 0);
    let identity = if downsample {
        g.conv_bn(&format!("{name}.downsample"), input, out_c, 1, stride, 0)
    } else {
        input
    };
    let j = g.add_join(&format!("{name}.add"), &[c3, identity]);
    g.relu(&format!("{name}.relu"), j)
}

fn stem(g: &mut Graph) -> NodeId {
    let x = g.input(3, 224, 224);
    let c = g.conv_bn_act("conv1", x, 64, 7, 2, 3, Act::Relu);
    g.maxpool("maxpool", c, 3, 2, 1)
}

/// ResNet-18: stages [2,2,2,2] of basic blocks, widths [64,128,256,512].
pub fn resnet18(classes: usize) -> Graph {
    let mut g = Graph::new("resnet18");
    let mut cur = stem(&mut g);
    let widths = [64usize, 128, 256, 512];
    for (si, &w) in widths.iter().enumerate() {
        for bi in 0..2 {
            let name = format!("layer{}.{}", si + 1, bi);
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            let downsample = bi == 0 && si > 0;
            cur = basic_block(&mut g, &name, cur, w, stride, downsample);
        }
    }
    g.classifier(cur, classes);
    g
}

/// ResNet-50: stages [3,4,6,3] of bottlenecks, output widths
/// [256,512,1024,2048] with mid widths [64,128,256,512].
pub fn resnet50(classes: usize) -> Graph {
    let mut g = Graph::new("resnet50");
    let mut cur = stem(&mut g);
    let stages: [(usize, usize, usize); 4] = [
        (3, 64, 256),
        (4, 128, 512),
        (6, 256, 1024),
        (3, 512, 2048),
    ];
    for (si, &(blocks, mid, out)) in stages.iter().enumerate() {
        for bi in 0..blocks {
            let name = format!("layer{}.{}", si + 1, bi);
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            // First block of every stage changes the channel count, so it
            // always needs a projection shortcut (including layer1.0).
            let downsample = bi == 0;
            cur = bottleneck_block(&mut g, &name, cur, mid, out, stride, downsample);
        }
    }
    g.classifier(cur, classes);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_params_match_torchvision() {
        let g = resnet18(1000);
        // torchvision: 11.69M (trainable); our count adds BN running stats.
        let p = g.param_count().unwrap() as f64 / 1e6;
        assert!((11.5..12.0).contains(&p), "params = {p}M");
        // 20 convs: 1 stem + 8 blocks * 2 + 3 downsamples
        assert_eq!(g.conv_infos().unwrap().len(), 20);
    }

    #[test]
    fn resnet50_params_match_torchvision() {
        let g = resnet50(1000);
        // torchvision: 25.56M
        let p = g.param_count().unwrap() as f64 / 1e6;
        assert!((25.2..26.2).contains(&p), "params = {p}M");
        // 53 convs: 1 stem + 16 blocks * 3 + 4 downsamples
        assert_eq!(g.conv_infos().unwrap().len(), 53);
    }

    #[test]
    fn resnet18_stage_spatial_sizes() {
        let g = resnet18(1000);
        let shapes = g.infer_shapes().unwrap();
        let by_name = |n: &str| {
            shapes[g.nodes.iter().find(|x| x.name == n).unwrap().id]
        };
        assert_eq!(by_name("maxpool").spatial(), 56);
        assert_eq!(by_name("layer1.1.relu").spatial(), 56);
        assert_eq!(by_name("layer2.1.relu").spatial(), 28);
        assert_eq!(by_name("layer3.1.relu").spatial(), 14);
        assert_eq!(by_name("layer4.1.relu").spatial(), 7);
        assert_eq!(by_name("layer4.1.relu").channels(), 512);
    }

    #[test]
    fn resnet50_final_channels() {
        let g = resnet50(1000);
        let shapes = g.infer_shapes().unwrap();
        let last_relu = g
            .nodes
            .iter()
            .filter(|n| n.name.ends_with(".relu"))
            .last()
            .unwrap()
            .id;
        assert_eq!(shapes[last_relu].channels(), 2048);
        assert_eq!(shapes[last_relu].spatial(), 7);
    }
}
