//! SqueezeNet 1.0 (Iandola et al., 2016), torchvision layout at 3×224×224.
//! Part of the paper's profiling basis; its Fire module shares the
//! branch-and-concatenate structure with GoogLeNet's Inception (App. C).

use crate::ir::{Graph, GraphBuilder, NodeId, Op};

/// Fire module: squeeze 1×1 → relu → {expand 1×1, expand 3×3} → concat.
fn fire(
    g: &mut Graph,
    name: &str,
    input: NodeId,
    squeeze: usize,
    expand1: usize,
    expand3: usize,
) -> NodeId {
    let s = g.conv(&format!("{name}.squeeze"), input, squeeze, 1, 1, 0);
    let sr = g.relu(&format!("{name}.squeeze.relu"), s);
    let e1 = g.conv(&format!("{name}.expand1x1"), sr, expand1, 1, 1, 0);
    let e1r = g.relu(&format!("{name}.expand1x1.relu"), e1);
    let e3 = g.conv(&format!("{name}.expand3x3"), sr, expand3, 3, 1, 1);
    let e3r = g.relu(&format!("{name}.expand3x3.relu"), e3);
    g.concat(&format!("{name}.concat"), &[e1r, e3r])
}

/// SqueezeNet v1.0.
pub fn squeezenet(classes: usize) -> Graph {
    let mut g = Graph::new("squeezenet");
    let x = g.input(3, 224, 224);
    let c1 = g.conv("features.0", x, 96, 7, 2, 0);
    let r1 = g.relu("features.1", c1);
    let p1 = g.maxpool_ceil("features.2", r1, 3, 2, 0);
    let f2 = fire(&mut g, "fire2", p1, 16, 64, 64);
    let f3 = fire(&mut g, "fire3", f2, 16, 64, 64);
    let f4 = fire(&mut g, "fire4", f3, 32, 128, 128);
    let p2 = g.maxpool_ceil("features.7", f4, 3, 2, 0);
    let f5 = fire(&mut g, "fire5", p2, 32, 128, 128);
    let f6 = fire(&mut g, "fire6", f5, 48, 192, 192);
    let f7 = fire(&mut g, "fire7", f6, 48, 192, 192);
    let f8 = fire(&mut g, "fire8", f7, 64, 256, 256);
    let p3 = g.maxpool_ceil("features.12", f8, 3, 2, 0);
    let f9 = fire(&mut g, "fire9", p3, 64, 256, 256);
    // Classifier: dropout → final 1×1 conv to `classes` → relu → GAP.
    let d = g.add("classifier.0", Op::Dropout(0.5), &[f9]);
    let cf = g.conv("classifier.1", d, classes, 1, 1, 0);
    let cr = g.relu("classifier.2", cf);
    let gp = g.gap("classifier.3", cr);
    g.add("classifier.flatten", Op::Flatten, &[gp]);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squeezenet_params_match_torchvision() {
        let g = squeezenet(1000);
        // torchvision squeezenet1_0: 1.248M
        let p = g.param_count().unwrap() as f64 / 1e6;
        assert!((1.15..1.35).contains(&p), "params = {p}M");
        // 26 convs: stem + 8 fires * 3 + classifier
        assert_eq!(g.conv_infos().unwrap().len(), 26);
    }

    #[test]
    fn fire_concat_channels() {
        let g = squeezenet(1000);
        let shapes = g.infer_shapes().unwrap();
        let f2 = g.nodes.iter().find(|n| n.name == "fire2.concat").unwrap().id;
        assert_eq!(shapes[f2].channels(), 128);
        let f9 = g.nodes.iter().find(|n| n.name == "fire9.concat").unwrap().id;
        assert_eq!(shapes[f9].channels(), 512);
    }

    #[test]
    fn output_is_class_vector() {
        let g = squeezenet(1000);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.output].numel(), 1000);
    }

    #[test]
    fn ceil_mode_pool_sizes() {
        // 224 -> conv k7 s2 -> 109 -> pool ceil k3 s2 -> 54
        let g = squeezenet(1000);
        let shapes = g.infer_shapes().unwrap();
        let p1 = g.nodes.iter().find(|n| n.name == "features.2").unwrap().id;
        assert_eq!(shapes[p1].spatial(), 54);
    }
}
