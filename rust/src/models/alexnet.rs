//! AlexNet (Krizhevsky et al., 2012), torchvision layout, 3×224×224.
//! Used by the paper only to tune the training-set-size hyperparameter
//! (Sec. 6.1) and then excluded from the evaluation.

use crate::ir::{Act, Graph, GraphBuilder, Op};

/// Build AlexNet with `classes` output classes.
pub fn alexnet(classes: usize) -> Graph {
    let mut g = Graph::new("alexnet");
    let x = g.input(3, 224, 224);
    let c1 = g.conv("features.0", x, 64, 11, 4, 2);
    let r1 = g.relu("features.1", c1);
    let p1 = g.maxpool("features.2", r1, 3, 2, 0);
    let c2 = g.conv("features.3", p1, 192, 5, 1, 2);
    let r2 = g.relu("features.4", c2);
    let p2 = g.maxpool("features.5", r2, 3, 2, 0);
    let c3 = g.conv("features.6", p2, 384, 3, 1, 1);
    let r3 = g.relu("features.7", c3);
    let c4 = g.conv("features.8", r3, 256, 3, 1, 1);
    let r4 = g.relu("features.9", c4);
    let c5 = g.conv("features.10", r4, 256, 3, 1, 1);
    let r5 = g.relu("features.11", c5);
    let p3 = g.maxpool("features.12", r5, 3, 2, 0);
    // At 224 input the feature map is already 6x6 here (adaptive pool is a
    // no-op); flatten straight into the classifier.
    let d1 = g.add("classifier.0", Op::Dropout(0.5), &[p3]);
    let f = g.add("classifier.flatten", Op::Flatten, &[d1]);
    let l1 = g.add(
        "classifier.1",
        Op::Linear {
            out: 4096,
            bias: true,
        },
        &[f],
    );
    let a1 = g.add("classifier.2", Op::Activation(Act::Relu), &[l1]);
    let d2 = g.add("classifier.3", Op::Dropout(0.5), &[a1]);
    let l2 = g.add(
        "classifier.4",
        Op::Linear {
            out: 4096,
            bias: true,
        },
        &[d2],
    );
    let a2 = g.add("classifier.5", Op::Activation(Act::Relu), &[l2]);
    g.add(
        "classifier.6",
        Op::Linear {
            out: classes,
            bias: true,
        },
        &[a2],
    );
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_shapes_and_params() {
        let g = alexnet(1000);
        let shapes = g.infer_shapes().unwrap();
        assert_eq!(shapes[g.output].numel(), 1000);
        // torchvision AlexNet has 61.1M parameters.
        let p = g.param_count().unwrap() as f64 / 1e6;
        assert!((60.0..62.5).contains(&p), "params = {p}M");
        assert_eq!(g.conv_infos().unwrap().len(), 5);
    }

    #[test]
    fn alexnet_feature_map_is_6x6_before_flatten() {
        let g = alexnet(1000);
        let shapes = g.infer_shapes().unwrap();
        // node for maxpool features.12
        let pool = g
            .nodes
            .iter()
            .find(|n| n.name == "features.12")
            .unwrap()
            .id;
        assert_eq!(shapes[pool].spatial(), 6);
        assert_eq!(shapes[pool].channels(), 256);
    }
}
