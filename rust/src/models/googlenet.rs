//! GoogLeNet (Szegedy et al., 2015) with batch-norm, torchvision layout at
//! 3×224×224. The paper's hardest basis-generalisation target (Fig. 4):
//! its 4-branch Inception module (with a 5×5 branch) appears in no basis
//! network.

use crate::ir::{Act, Graph, GraphBuilder, NodeId, Op};

/// Inception module: 1×1 / 1×1→3×3 / 1×1→5×5 (torchvision uses 3×3 here but
/// the original paper and App. C describe 5×5 — we keep 5×5, which also
/// exercises the FFT-eligible path of the feature model) / pool→1×1.
#[allow(clippy::too_many_arguments)]
fn inception(
    g: &mut Graph,
    name: &str,
    input: NodeId,
    c1: usize,
    c2r: usize,
    c2: usize,
    c3r: usize,
    c3: usize,
    c4: usize,
) -> NodeId {
    let b1 = g.conv_bn_act(&format!("{name}.b1"), input, c1, 1, 1, 0, Act::Relu);
    let b2a = g.conv_bn_act(&format!("{name}.b2.reduce"), input, c2r, 1, 1, 0, Act::Relu);
    let b2 = g.conv_bn_act(&format!("{name}.b2.conv"), b2a, c2, 3, 1, 1, Act::Relu);
    let b3a = g.conv_bn_act(&format!("{name}.b3.reduce"), input, c3r, 1, 1, 0, Act::Relu);
    let b3 = g.conv_bn_act(&format!("{name}.b3.conv"), b3a, c3, 5, 1, 2, Act::Relu);
    let pool = g.add(
        format!("{name}.b4.pool"),
        Op::MaxPool {
            k: 3,
            s: 1,
            p: 1,
            ceil: true,
        },
        &[input],
    );
    let b4 = g.conv_bn_act(&format!("{name}.b4.conv"), pool, c4, 1, 1, 0, Act::Relu);
    g.concat(&format!("{name}.concat"), &[b1, b2, b3, b4])
}

/// GoogLeNet (a.k.a. Inception v1) without auxiliary heads.
pub fn googlenet(classes: usize) -> Graph {
    let mut g = Graph::new("googlenet");
    let x = g.input(3, 224, 224);
    let c1 = g.conv_bn_act("conv1", x, 64, 7, 2, 3, Act::Relu);
    let p1 = g.maxpool_ceil("maxpool1", c1, 3, 2, 0);
    let c2 = g.conv_bn_act("conv2", p1, 64, 1, 1, 0, Act::Relu);
    let c3 = g.conv_bn_act("conv3", c2, 192, 3, 1, 1, Act::Relu);
    let p2 = g.maxpool_ceil("maxpool2", c3, 3, 2, 0);

    let i3a = inception(&mut g, "inception3a", p2, 64, 96, 128, 16, 32, 32);
    let i3b = inception(&mut g, "inception3b", i3a, 128, 128, 192, 32, 96, 64);
    let p3 = g.maxpool_ceil("maxpool3", i3b, 3, 2, 0);

    let i4a = inception(&mut g, "inception4a", p3, 192, 96, 208, 16, 48, 64);
    let i4b = inception(&mut g, "inception4b", i4a, 160, 112, 224, 24, 64, 64);
    let i4c = inception(&mut g, "inception4c", i4b, 128, 128, 256, 24, 64, 64);
    let i4d = inception(&mut g, "inception4d", i4c, 112, 144, 288, 32, 64, 64);
    let i4e = inception(&mut g, "inception4e", i4d, 256, 160, 320, 32, 128, 128);
    let p4 = g.maxpool_ceil("maxpool4", i4e, 2, 2, 0);

    let i5a = inception(&mut g, "inception5a", p4, 256, 160, 320, 32, 128, 128);
    let i5b = inception(&mut g, "inception5b", i5a, 384, 192, 384, 48, 128, 128);

    let gp = g.gap("head.gap", i5b);
    let d = g.add("head.dropout", Op::Dropout(0.2), &[gp]);
    let f = g.add("head.flatten", Op::Flatten, &[d]);
    g.add(
        "head.fc",
        Op::Linear {
            out: classes,
            bias: true,
        },
        &[f],
    );
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn googlenet_params_in_expected_range() {
        let g = googlenet(1000);
        // torchvision googlenet (bn, no aux): 6.62M; ours uses 5×5 in branch
        // 3 (original paper) so slightly more.
        let p = g.param_count().unwrap() as f64 / 1e6;
        assert!((6.3..8.5).contains(&p), "params = {p}M");
        // 2 + 5x5 branch per module: 57 convs total
        assert_eq!(g.conv_infos().unwrap().len(), 3 + 9 * 6);
    }

    #[test]
    fn inception_concat_channels() {
        let g = googlenet(1000);
        let shapes = g.infer_shapes().unwrap();
        let i3a = g
            .nodes
            .iter()
            .find(|n| n.name == "inception3a.concat")
            .unwrap()
            .id;
        assert_eq!(shapes[i3a].channels(), 64 + 128 + 32 + 32);
        let i5b = g
            .nodes
            .iter()
            .find(|n| n.name == "inception5b.concat")
            .unwrap()
            .id;
        assert_eq!(shapes[i5b].channels(), 384 + 384 + 128 + 128);
        assert_eq!(shapes[i5b].spatial(), 7);
    }

    #[test]
    fn has_5x5_convs() {
        let g = googlenet(1000);
        let k5 = g
            .conv_infos()
            .unwrap()
            .iter()
            .filter(|c| c.k == 5)
            .count();
        assert_eq!(k5, 9);
    }
}
