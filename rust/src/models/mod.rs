//! The network zoo: every architecture the paper profiles, evaluates on, or
//! compares against, built as IR graphs at 3×224×224 (ILSVRC'12 geometry).
//!
//! | network | role in the paper |
//! |---|---|
//! | AlexNet | Sec. 6.1 training-set-size tuning only |
//! | ResNet18, MobileNetV2, SqueezeNet | profiling basis (Figs. 3, 4) |
//! | MnasNet | same-network eval (Fig. 3) + non-basis target (Fig. 4) |
//! | ResNet50 | non-basis target (Fig. 4) + DNNMem comparison (Sec. 6.2.1) |
//! | GoogLeNet | hardest non-basis target (Fig. 4) |
//! | VGG16, NiN | related-work baselines ([5], [14]) |
//!
//! The elastic OFA-ResNet50 space lives in `crate::ofa`.

mod alexnet;
mod googlenet;
mod mnasnet;
mod mobilenet;
mod resnet;
mod squeezenet;
mod vgg;

pub use alexnet::alexnet;
pub use googlenet::googlenet;
pub use mnasnet::mnasnet;
pub use mobilenet::{make_divisible, mobilenet_v2, mobilenet_v2_width};
pub use resnet::{resnet18, resnet50};
pub use squeezenet::squeezenet;
pub use vgg::{nin, vgg16};

use crate::ir::Graph;

/// Names of all zoo networks, in a stable order.
pub const ZOO: &[&str] = &[
    "alexnet",
    "resnet18",
    "resnet50",
    "mobilenetv2",
    "squeezenet",
    "mnasnet",
    "googlenet",
    "vgg16",
    "nin",
];

/// Build a zoo network by name (1000 classes).
pub fn by_name(name: &str) -> Option<Graph> {
    Some(match name {
        "alexnet" => alexnet(1000),
        "resnet18" => resnet18(1000),
        "resnet50" => resnet50(1000),
        "mobilenetv2" => mobilenet_v2(1000),
        "squeezenet" => squeezenet(1000),
        "mnasnet" => mnasnet(1000),
        "googlenet" => googlenet(1000),
        "vgg16" => vgg16(1000),
        "nin" => nin(1000),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whole_zoo_builds_and_infers() {
        for name in ZOO {
            let g = by_name(name).unwrap();
            let shapes = g.infer_shapes().unwrap_or_else(|e| {
                panic!("{name} failed shape inference: {e}");
            });
            assert!(!shapes.is_empty());
            assert!(!g.conv_infos().unwrap().is_empty(), "{name} has convs");
            assert!(g.param_count().unwrap() > 100_000, "{name} param count");
        }
    }

    #[test]
    fn by_name_unknown_is_none() {
        assert!(by_name("lenet").is_none());
    }

    #[test]
    fn zoo_param_ordering_sane() {
        // VGG16 > AlexNet > ResNet50 > ResNet18 > GoogLeNet > MnasNet >
        // MobileNetV2 > SqueezeNet
        let p = |n: &str| by_name(n).unwrap().param_count().unwrap();
        assert!(p("vgg16") > p("alexnet"));
        assert!(p("alexnet") > p("resnet50"));
        assert!(p("resnet50") > p("resnet18"));
        assert!(p("resnet18") > p("googlenet"));
        assert!(p("googlenet") > p("mnasnet"));
        assert!(p("mnasnet") > p("mobilenetv2"));
        assert!(p("mobilenetv2") > p("squeezenet"));
    }
}
