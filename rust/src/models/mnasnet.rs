//! MnasNet 1.0 (Tan et al., 2019), torchvision layout at 3×224×224.
//! The paper's NAS-generated basis member; shares the depthwise-separable
//! inverted-residual block with MobileNetV2 (App. C).

use crate::ir::{Act, Graph, GraphBuilder, NodeId};

/// MBConv block with configurable kernel size (3 or 5).
#[allow(clippy::too_many_arguments)]
fn mbconv(
    g: &mut Graph,
    name: &str,
    input: NodeId,
    in_c: usize,
    out_c: usize,
    k: usize,
    stride: usize,
    expand: usize,
) -> NodeId {
    let hidden = in_c * expand;
    let mut cur = input;
    if expand != 1 {
        cur = g.conv_bn_act(&format!("{name}.expand"), cur, hidden, 1, 1, 0, Act::Relu);
    }
    cur = g.dwconv_bn_act(&format!("{name}.dw"), cur, k, stride, Act::Relu);
    cur = g.conv_bn(&format!("{name}.project"), cur, out_c, 1, 1, 0);
    if stride == 1 && in_c == out_c {
        g.add_join(&format!("{name}.add"), &[cur, input])
    } else {
        cur
    }
}

/// MnasNet-B1 at depth multiplier 1.0 (torchvision `mnasnet1_0`).
pub fn mnasnet(classes: usize) -> Graph {
    let mut g = Graph::new("mnasnet");
    let x = g.input(3, 224, 224);
    // Stem: conv 32 s2 → depthwise separable to 16.
    let stem = g.conv_bn_act("stem.conv", x, 32, 3, 2, 1, Act::Relu);
    let dw = g.dwconv_bn_act("stem.dw", stem, 3, 1, Act::Relu);
    let mut cur = g.conv_bn("stem.project", dw, 16, 1, 1, 0);
    let mut in_c = 16usize;
    // (expand t, channels c, repeats n, stride s, kernel k)
    let settings: [(usize, usize, usize, usize, usize); 6] = [
        (3, 24, 3, 2, 3),
        (3, 40, 3, 2, 5),
        (6, 80, 3, 2, 5),
        (6, 96, 2, 1, 3),
        (6, 192, 4, 2, 5),
        (6, 320, 1, 1, 3),
    ];
    let mut idx = 0usize;
    for &(t, c, n, s, k) in &settings {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            cur = mbconv(&mut g, &format!("block{idx}"), cur, in_c, c, k, stride, t);
            in_c = c;
            idx += 1;
        }
    }
    let head = g.conv_bn_act("head.conv", cur, 1280, 1, 1, 0, Act::Relu);
    g.classifier(head, classes);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnasnet_params_match_torchvision() {
        let g = mnasnet(1000);
        // torchvision mnasnet1_0: 4.38M
        let p = g.param_count().unwrap() as f64 / 1e6;
        assert!((4.2..4.6).contains(&p), "params = {p}M");
    }

    #[test]
    fn mixed_kernel_sizes_present() {
        let g = mnasnet(1000);
        let infos = g.conv_infos().unwrap();
        let k5 = infos.iter().filter(|c| c.k == 5 && c.is_depthwise()).count();
        let k3 = infos.iter().filter(|c| c.k == 3 && c.is_depthwise()).count();
        assert_eq!(k5, 10); // stages with k=5: 3 + 3 + 4
        assert_eq!(k3, 7); // stem dw + stages with k=3: 3 + 2 + 1
    }

    #[test]
    fn final_spatial_is_7() {
        let g = mnasnet(1000);
        let shapes = g.infer_shapes().unwrap();
        let head = g.nodes.iter().find(|n| n.name == "head.conv.act").unwrap().id;
        assert_eq!(shapes[head].spatial(), 7);
    }
}
