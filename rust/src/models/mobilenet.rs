//! MobileNetV2 (Sandler et al., 2018), torchvision layout at 3×224×224.
//! Part of the paper's profiling basis; also the subject of the Sec. 6.2
//! 100-strategy topology experiment.

use crate::ir::{Act, Graph, GraphBuilder, NodeId};

/// Round channel counts to multiples of 8 as in the reference
/// implementation (`_make_divisible`).
pub fn make_divisible(v: f64, divisor: usize) -> usize {
    let d = divisor as f64;
    let new_v = ((v + d / 2.0) / d).floor() as usize * divisor;
    let new_v = new_v.max(divisor);
    if (new_v as f64) < 0.9 * v {
        new_v + divisor
    } else {
        new_v
    }
}

/// Inverted residual block: 1×1 expand → 3×3 depthwise (stride s) →
/// 1×1 project (linear). Residual join when stride 1 and shapes match.
fn inverted_residual(
    g: &mut Graph,
    name: &str,
    input: NodeId,
    in_c: usize,
    out_c: usize,
    stride: usize,
    expand: usize,
) -> NodeId {
    let hidden = in_c * expand;
    let mut cur = input;
    if expand != 1 {
        cur = g.conv_bn_act(&format!("{name}.expand"), cur, hidden, 1, 1, 0, Act::Relu6);
    }
    cur = g.dwconv_bn_act(&format!("{name}.dw"), cur, 3, stride, Act::Relu6);
    cur = g.conv_bn(&format!("{name}.project"), cur, out_c, 1, 1, 0);
    if stride == 1 && in_c == out_c {
        g.add_join(&format!("{name}.add"), &[cur, input])
    } else {
        cur
    }
}

/// MobileNetV2 with width multiplier 1.0.
pub fn mobilenet_v2(classes: usize) -> Graph {
    mobilenet_v2_width(classes, 1.0)
}

/// MobileNetV2 with an arbitrary width multiplier (used by ablations).
pub fn mobilenet_v2_width(classes: usize, width: f64) -> Graph {
    let mut g = Graph::new("mobilenetv2");
    let x = g.input(3, 224, 224);
    // (expand t, channels c, repeats n, stride s)
    let settings: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut in_c = make_divisible(32.0 * width, 8);
    let mut cur = g.conv_bn_act("stem", x, in_c, 3, 2, 1, Act::Relu6);
    let mut idx = 0usize;
    for &(t, c, n, s) in &settings {
        let out_c = make_divisible(c as f64 * width, 8);
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            cur = inverted_residual(
                &mut g,
                &format!("block{idx}"),
                cur,
                in_c,
                out_c,
                stride,
                t,
            );
            in_c = out_c;
            idx += 1;
        }
    }
    let last = make_divisible((1280.0 * width).max(1280.0), 8);
    let head = g.conv_bn_act("head.conv", cur, last, 1, 1, 0, Act::Relu6);
    g.classifier(head, classes);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn make_divisible_matches_reference() {
        assert_eq!(make_divisible(32.0, 8), 32);
        assert_eq!(make_divisible(16.0, 8), 16);
        // 18 rounds down to 16, but 16 < 0.9*18 so bumps to 24 (reference
        // implementation behaviour).
        assert_eq!(make_divisible(24.0 * 0.75, 8), 24);
        assert_eq!(make_divisible(20.0, 8), 24);
        assert_eq!(make_divisible(12.0, 8), 16); // rounds up, >= divisor
    }

    #[test]
    fn mobilenetv2_params_match_torchvision() {
        let g = mobilenet_v2(1000);
        // torchvision: 3.50M
        let p = g.param_count().unwrap() as f64 / 1e6;
        assert!((3.3..3.7).contains(&p), "params = {p}M");
        // 52 convs: stem + 17 blocks (16 with expand = 3 convs, first = 2) + head
        assert_eq!(g.conv_infos().unwrap().len(), 52);
    }

    #[test]
    fn depthwise_blocks_present() {
        let g = mobilenet_v2(1000);
        let infos = g.conv_infos().unwrap();
        let dw = infos.iter().filter(|c| c.is_depthwise()).count();
        assert_eq!(dw, 17);
    }

    #[test]
    fn output_spatial_is_7() {
        let g = mobilenet_v2(1000);
        let shapes = g.infer_shapes().unwrap();
        let head = g.nodes.iter().find(|n| n.name == "head.conv.act").unwrap().id;
        assert_eq!(shapes[head].spatial(), 7);
        assert_eq!(shapes[head].channels(), 1280);
    }

    #[test]
    fn width_multiplier_scales_params() {
        let p1 = mobilenet_v2_width(1000, 1.0).param_count().unwrap();
        let p075 = mobilenet_v2_width(1000, 0.75).param_count().unwrap();
        assert!(p075 < p1);
    }
}
