//! Structured filter pruning (the paper's topology-variation mechanism,
//! standing in for the ADaPT tool). A [`Strategy`] distributes a global
//! pruning level over dependency-consistent groups of convolutions; the
//! result is a *new* graph with reduced filter counts and re-inferred
//! shapes.

pub mod groups;
pub mod strategy;

pub use groups::{groups_consistent, prune_groups, PruneGroup};
pub use strategy::{Profile, Strategy, ALL_PROFILES};

use crate::ir::{Graph, NodeId, Op};
use crate::util::rng::Pcg64;

/// Conv node ids that must keep their filter count: final classifier convs
/// whose out-channels are the class count (SqueezeNet, NiN).
pub fn protected_convs(graph: &Graph) -> Vec<NodeId> {
    // Heuristic: a conv whose output (after channel-preserving ops) reaches
    // the graph output without passing through another conv or linear layer
    // defines the class dimension.
    let mut protected = Vec::new();
    // Walk back from the output through channel-preserving / flatten ops.
    let mut cur = graph.output;
    loop {
        let node = graph.node(cur);
        match &node.op {
            Op::Conv2d { .. } => {
                protected.push(cur);
                break;
            }
            Op::Linear { .. } | Op::Input { .. } | Op::Add | Op::Concat => break,
            _ => {
                if let Some(&prev) = node.inputs.first() {
                    cur = prev;
                } else {
                    break;
                }
            }
        }
    }
    protected
}

/// Apply structured pruning: returns a pruned clone of `graph`.
///
/// `level` is the fraction of filters removed globally (the paper's
/// "pruning level", e.g. 0.5 for 50%); `strategy` shapes the per-layer
/// distribution; `rng` provides the randomness (seeded ⇒ reproducible).
pub fn prune(graph: &Graph, strategy: Strategy, level: f64, rng: &mut Pcg64) -> Graph {
    let mut out = graph.clone();
    if level <= 0.0 {
        return out;
    }
    let protected = protected_convs(graph);
    let groups = prune_groups(graph, &protected);
    for group in &groups {
        if !group.prunable {
            continue;
        }
        let removed = strategy.removed_filters(group.filters, group.depth, level, rng);
        if removed == 0 {
            continue;
        }
        let kept = (group.filters - removed).max(1);
        for &conv in &group.convs {
            out.set_conv_filters(conv, kept);
        }
    }
    out.name = format!(
        "{}-{}-{:.0}pct",
        graph.name,
        strategy.name(),
        level * 100.0
    );
    debug_assert!(out.infer_shapes().is_ok());
    out
}

/// Fraction of conv weight parameters actually removed (diagnostic).
pub fn achieved_level(original: &Graph, pruned: &Graph) -> f64 {
    let w = |g: &Graph| -> f64 {
        g.conv_infos()
            .unwrap()
            .iter()
            .map(|c| c.weight_params() as f64)
            .sum()
    };
    1.0 - w(pruned) / w(original)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn pruned_graphs_stay_valid_across_zoo() {
        for name in models::ZOO {
            let g = models::by_name(name).unwrap();
            for (si, strategy) in [Strategy::Random, Strategy::L1Norm].iter().enumerate() {
                let mut rng = Pcg64::new(100 + si as u64);
                for level in [0.3, 0.5, 0.7, 0.9] {
                    let p = prune(&g, *strategy, level, &mut rng);
                    p.infer_shapes().unwrap_or_else(|e| {
                        panic!("{name} {strategy:?} @{level}: {e}")
                    });
                    assert!(
                        p.param_count().unwrap() < g.param_count().unwrap(),
                        "{name} @{level} did not shrink"
                    );
                }
            }
        }
    }

    #[test]
    fn pruning_reduces_filters_proportionally() {
        let g = models::vgg16(1000);
        let mut rng = Pcg64::new(7);
        let p = prune(&g, Strategy::Random, 0.5, &mut rng);
        let lvl = achieved_level(&g, &p);
        // Random binomial pruning at 50% should remove ~75% of conv weights
        // (both input and output channels shrink ~50%) — check it's large
        // and seed-stable.
        assert!(lvl > 0.5, "achieved {lvl}");
        let mut rng2 = Pcg64::new(7);
        let p2 = prune(&g, Strategy::Random, 0.5, &mut rng2);
        assert_eq!(p.param_count().unwrap(), p2.param_count().unwrap());
    }

    #[test]
    fn level_zero_is_identity() {
        let g = models::resnet18(1000);
        let mut rng = Pcg64::new(8);
        let p = prune(&g, Strategy::Random, 0.0, &mut rng);
        assert_eq!(p.param_count().unwrap(), g.param_count().unwrap());
    }

    #[test]
    fn classifier_conv_protected_in_squeezenet() {
        let g = models::squeezenet(1000);
        let mut rng = Pcg64::new(9);
        let p = prune(&g, Strategy::Random, 0.9, &mut rng);
        let shapes = p.infer_shapes().unwrap();
        assert_eq!(shapes[p.output].numel(), 1000, "class dim was pruned!");
    }

    #[test]
    fn nin_classifier_protected() {
        let g = models::nin(1000);
        let mut rng = Pcg64::new(10);
        let p = prune(&g, Strategy::L1Norm, 0.7, &mut rng);
        let shapes = p.infer_shapes().unwrap();
        assert_eq!(shapes[p.output].numel(), 1000);
    }

    #[test]
    fn higher_levels_remove_more() {
        let g = models::resnet50(1000);
        let mut prev = g.param_count().unwrap();
        for level in [0.3, 0.5, 0.7, 0.9] {
            let mut rng = Pcg64::new(11);
            let p = prune(&g, Strategy::L1Norm, level, &mut rng);
            let count = p.param_count().unwrap();
            assert!(count < prev, "level {level}: {count} !< {prev}");
            prev = count;
        }
    }

    #[test]
    fn different_seeds_give_different_topologies() {
        let g = models::mobilenet_v2(1000);
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let pa = prune(&g, Strategy::Random, 0.5, &mut a);
        let pb = prune(&g, Strategy::Random, 0.5, &mut b);
        assert_ne!(pa.param_count().unwrap(), pb.param_count().unwrap());
    }
}
