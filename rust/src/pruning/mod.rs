//! Structured filter pruning (the paper's topology-variation mechanism,
//! standing in for the ADaPT tool). A [`Strategy`] distributes a global
//! pruning level over dependency-consistent groups of convolutions.
//!
//! Two equivalent producers exist: [`prune`] returns a *new* graph with
//! reduced filter counts (the clone+rebuild reference path), while
//! [`prune_overlay`] writes the same widths into a
//! [`PruneOverlay`](crate::ir::PruneOverlay) over a compiled
//! [`GraphArena`](crate::ir::GraphArena) — no clone, no mutation, and the
//! dependency analysis (`protected_convs` + `prune_groups`) is read from
//! the arena's once-per-base-network cache instead of being recomputed on
//! every call. Both consume the RNG stream identically, so overlay-pruned
//! analyses are bit-identical to graph-pruned ones
//! (`rust/tests/overlay_equivalence.rs`).

pub mod groups;
pub mod strategy;

pub use groups::{groups_consistent, prune_groups, PruneGroup};
pub(crate) use groups::prune_groups_from_shapes;
pub use strategy::{Profile, Strategy, ALL_PROFILES};

use crate::ir::{Graph, GraphArena, NodeId, Op, PruneOverlay};
use crate::util::rng::Pcg64;

/// Conv node ids that must keep their filter count: final classifier convs
/// whose out-channels are the class count (SqueezeNet, NiN).
pub fn protected_convs(graph: &Graph) -> Vec<NodeId> {
    // Heuristic: a conv whose output (after channel-preserving ops) reaches
    // the graph output without passing through another conv or linear layer
    // defines the class dimension.
    let mut protected = Vec::new();
    // Walk back from the output through channel-preserving / flatten ops.
    let mut cur = graph.output;
    loop {
        let node = graph.node(cur);
        match &node.op {
            Op::Conv2d { .. } => {
                protected.push(cur);
                break;
            }
            Op::Linear { .. } | Op::Input { .. } | Op::Add | Op::Concat => break,
            _ => {
                if let Some(&prev) = node.inputs.first() {
                    cur = prev;
                } else {
                    break;
                }
            }
        }
    }
    protected
}

/// Apply structured pruning: returns a pruned clone of `graph`.
///
/// `level` is the fraction of filters removed globally (the paper's
/// "pruning level", e.g. 0.5 for 50%); `strategy` shapes the per-layer
/// distribution; `rng` provides the randomness (seeded ⇒ reproducible).
pub fn prune(graph: &Graph, strategy: Strategy, level: f64, rng: &mut Pcg64) -> Graph {
    let mut out = graph.clone();
    if level <= 0.0 {
        return out;
    }
    let protected = protected_convs(graph);
    let groups = prune_groups(graph, &protected);
    for group in &groups {
        if !group.prunable {
            continue;
        }
        let removed = strategy.removed_filters(group.filters, group.depth, level, rng);
        if removed == 0 {
            continue;
        }
        let kept = (group.filters - removed).max(1);
        for &conv in &group.convs {
            out.set_conv_filters(conv, kept);
        }
    }
    out.name = format!(
        "{}-{}-{:.0}pct",
        graph.name,
        strategy.name(),
        level * 100.0
    );
    debug_assert!(out.infer_shapes().is_ok());
    out
}

/// Structured pruning on the overlay fast path: the same per-group width
/// decisions as [`prune`] — the identical RNG draws, in the identical
/// group order — written into a [`PruneOverlay`] instead of a cloned and
/// mutated graph. The dependency analysis comes from the arena's
/// compile-time cache, so nothing here walks the graph.
pub fn prune_overlay(
    arena: &GraphArena,
    strategy: Strategy,
    level: f64,
    rng: &mut Pcg64,
) -> PruneOverlay {
    let mut overlay = arena.identity_overlay();
    if level <= 0.0 {
        return overlay;
    }
    for group in arena.prune_groups() {
        if !group.prunable {
            continue;
        }
        let removed = strategy.removed_filters(group.filters, group.depth, level, rng);
        if removed == 0 {
            continue;
        }
        let kept = (group.filters - removed).max(1);
        for &conv in &group.convs {
            let slot = arena
                .conv_slot_of(conv)
                .expect("prune groups only list conv nodes");
            overlay.set_width(slot, kept);
        }
    }
    overlay
}

/// Fraction of conv weight parameters actually removed (diagnostic).
pub fn achieved_level(original: &Graph, pruned: &Graph) -> f64 {
    let w = |g: &Graph| -> f64 {
        g.conv_infos()
            .unwrap()
            .iter()
            .map(|c| c.weight_params() as f64)
            .sum()
    };
    1.0 - w(pruned) / w(original)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn pruned_graphs_stay_valid_across_zoo() {
        for name in models::ZOO {
            let g = models::by_name(name).unwrap();
            for (si, strategy) in [Strategy::Random, Strategy::L1Norm].iter().enumerate() {
                let mut rng = Pcg64::new(100 + si as u64);
                for level in [0.3, 0.5, 0.7, 0.9] {
                    let p = prune(&g, *strategy, level, &mut rng);
                    p.infer_shapes().unwrap_or_else(|e| {
                        panic!("{name} {strategy:?} @{level}: {e}")
                    });
                    assert!(
                        p.param_count().unwrap() < g.param_count().unwrap(),
                        "{name} @{level} did not shrink"
                    );
                }
            }
        }
    }

    #[test]
    fn pruning_reduces_filters_proportionally() {
        let g = models::vgg16(1000);
        let mut rng = Pcg64::new(7);
        let p = prune(&g, Strategy::Random, 0.5, &mut rng);
        let lvl = achieved_level(&g, &p);
        // Random binomial pruning at 50% should remove ~75% of conv weights
        // (both input and output channels shrink ~50%) — check it's large
        // and seed-stable.
        assert!(lvl > 0.5, "achieved {lvl}");
        let mut rng2 = Pcg64::new(7);
        let p2 = prune(&g, Strategy::Random, 0.5, &mut rng2);
        assert_eq!(p.param_count().unwrap(), p2.param_count().unwrap());
    }

    #[test]
    fn level_zero_is_identity() {
        let g = models::resnet18(1000);
        let mut rng = Pcg64::new(8);
        let p = prune(&g, Strategy::Random, 0.0, &mut rng);
        assert_eq!(p.param_count().unwrap(), g.param_count().unwrap());
    }

    #[test]
    fn classifier_conv_protected_in_squeezenet() {
        let g = models::squeezenet(1000);
        let mut rng = Pcg64::new(9);
        let p = prune(&g, Strategy::Random, 0.9, &mut rng);
        let shapes = p.infer_shapes().unwrap();
        assert_eq!(shapes[p.output].numel(), 1000, "class dim was pruned!");
    }

    #[test]
    fn nin_classifier_protected() {
        let g = models::nin(1000);
        let mut rng = Pcg64::new(10);
        let p = prune(&g, Strategy::L1Norm, 0.7, &mut rng);
        let shapes = p.infer_shapes().unwrap();
        assert_eq!(shapes[p.output].numel(), 1000);
    }

    #[test]
    fn higher_levels_remove_more() {
        let g = models::resnet50(1000);
        let mut prev = g.param_count().unwrap();
        for level in [0.3, 0.5, 0.7, 0.9] {
            let mut rng = Pcg64::new(11);
            let p = prune(&g, Strategy::L1Norm, level, &mut rng);
            let count = p.param_count().unwrap();
            assert!(count < prev, "level {level}: {count} !< {prev}");
            prev = count;
        }
    }

    #[test]
    fn overlay_widths_match_graph_pruning() {
        use crate::ir::{GraphArena, Op};
        for name in ["squeezenet", "resnet18", "mobilenetv2"] {
            let g = models::by_name(name).unwrap();
            let arena = GraphArena::compile(&g).unwrap();
            for (si, strategy) in [Strategy::Random, Strategy::L1Norm].iter().enumerate() {
                for level in [0.0, 0.5, 0.9] {
                    let mut ra = Pcg64::new(50 + si as u64);
                    let mut rb = ra.clone();
                    let p = prune(&g, *strategy, level, &mut ra);
                    let ov = prune_overlay(&arena, *strategy, level, &mut rb);
                    for (slot, &cid) in arena.conv_ids().iter().enumerate() {
                        if let Op::Conv2d { out_c, .. } = &p.nodes[cid].op {
                            assert_eq!(
                                ov.widths()[slot],
                                *out_c,
                                "{name} {strategy:?} @{level} conv {cid}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn different_seeds_give_different_topologies() {
        let g = models::mobilenet_v2(1000);
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let pa = prune(&g, Strategy::Random, 0.5, &mut a);
        let pb = prune(&g, Strategy::Random, 0.5, &mut b);
        assert_ne!(pa.param_count().unwrap(), pb.param_count().unwrap());
    }
}
