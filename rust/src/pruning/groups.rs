//! Channel-dependency analysis for structured pruning.
//!
//! Removing filters from a conv changes its output channel count. Residual
//! `Add` joins require equal channels on every input, so all convs whose
//! outputs meet at an `Add` (walking through channel-preserving ops) must be
//! pruned *together*. Depthwise convs inherit their input's channel count
//! and are never pruned directly. Concat outputs that flow into an `Add`
//! pin the channel count of every contributing conv, making them
//! unprunable (conservative, and sufficient for the zoo).

use crate::ir::{Graph, Groups, NodeId, Op, Shape};
use std::collections::BTreeMap;

/// Union-find over channel groups.
struct Uf {
    parent: Vec<usize>,
}

impl Uf {
    fn new(n: usize) -> Self {
        Uf {
            parent: (0..n).collect(),
        }
    }
    fn find(&mut self, x: usize) -> usize {
        if self.parent[x] != x {
            let root = self.find(self.parent[x]);
            self.parent[x] = root;
        }
        self.parent[x]
    }
    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent[ra.max(rb)] = ra.min(rb);
        }
    }
}

/// A set of convolutions that must keep identical filter counts.
#[derive(Clone, Debug)]
pub struct PruneGroup {
    /// Conv node ids whose `out_c` is set jointly (depthwise excluded).
    pub convs: Vec<NodeId>,
    /// Original (unpruned) filter count shared by all members.
    pub filters: usize,
    /// False when the group's channel count is pinned (network input,
    /// classifier conv, or concat feeding a residual join).
    pub prunable: bool,
    /// Normalised depth in [0,1] of the group's first conv (for
    /// depth-weighted strategies like L1-norm).
    pub depth: f64,
}

/// Compute prune groups for a graph.
///
/// `protected` lists conv node ids that must never be pruned (e.g. a final
/// 1×1 classifier conv whose out-channels are the class count — SqueezeNet
/// and NiN).
pub fn prune_groups(graph: &Graph, protected: &[NodeId]) -> Vec<PruneGroup> {
    let shapes = graph
        .infer_shapes()
        .expect("prune_groups requires a valid graph");
    prune_groups_from_shapes(graph, protected, &shapes)
}

/// As [`prune_groups`] from pre-inferred shapes — lets callers that
/// already ran shape inference (`GraphArena::compile`) skip the second
/// pass.
pub(crate) fn prune_groups_from_shapes(
    graph: &Graph,
    protected: &[NodeId],
    shapes: &[Shape],
) -> Vec<PruneGroup> {
    let n = graph.len();
    let mut uf = Uf::new(n);
    // Group representative per node: the node that *defines* the channel
    // dimension observed at this node's output.
    let mut rep: Vec<usize> = vec![0; n];
    // Concat outputs remember which upstream groups contribute channels.
    let mut concat_contrib: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    // Groups that must not be pruned.
    let mut pinned: Vec<bool> = vec![false; n];

    for node in &graph.nodes {
        match &node.op {
            Op::Input { .. } => {
                rep[node.id] = node.id;
                pinned[node.id] = true;
            }
            Op::Conv2d { groups, .. } => {
                if matches!(groups, Groups::Depthwise) {
                    // Channels tied to the input's defining group.
                    rep[node.id] = rep[node.inputs[0]];
                } else {
                    rep[node.id] = node.id;
                }
            }
            Op::Add => {
                // All inputs' defining groups merge.
                let first = rep[node.inputs[0]];
                for &i in &node.inputs[1..] {
                    uf.union(first, rep[i]);
                }
                rep[node.id] = first;
                // If any merged group is a concat, pin its contributors.
                for &i in &node.inputs {
                    let r = rep[i];
                    if let Some(contrib) = concat_contrib.get(&r) {
                        for &c in contrib {
                            pinned[c] = true;
                        }
                        pinned[r] = true;
                    }
                }
            }
            Op::Concat => {
                rep[node.id] = node.id;
                // Concat defines a fresh, not-directly-prunable channel dim;
                // its *inputs* stay independently prunable unless pinned
                // later by an Add.
                pinned[node.id] = true;
                let contribs: Vec<usize> =
                    node.inputs.iter().map(|&i| rep[i]).collect();
                concat_contrib.insert(node.id, contribs);
            }
            // Channel-preserving unary ops and the flat tail of the net.
            _ => {
                if let Some(&first) = node.inputs.first() {
                    rep[node.id] = rep[first];
                } else {
                    rep[node.id] = node.id;
                }
            }
        }
    }

    // Collapse union-find and bucket convs by root.
    let conv_ids = graph.conv_ids();
    let n_convs = conv_ids.len().max(1);
    let conv_order: BTreeMap<NodeId, usize> = conv_ids
        .iter()
        .enumerate()
        .map(|(i, &id)| (id, i))
        .collect();

    let mut buckets: BTreeMap<usize, PruneGroup> = BTreeMap::new();
    for &cid in &conv_ids {
        let node = graph.node(cid);
        let depthwise = matches!(
            node.op,
            Op::Conv2d {
                groups: Groups::Depthwise,
                ..
            }
        );
        if depthwise {
            continue; // follows its input automatically
        }
        let root = uf.find(rep[cid]);
        let entry = buckets.entry(root).or_insert_with(|| PruneGroup {
            convs: Vec::new(),
            filters: shapes[cid].channels(),
            prunable: true,
            depth: conv_order[&cid] as f64 / n_convs as f64,
        });
        entry.convs.push(cid);
        if protected.contains(&cid) {
            entry.prunable = false;
        }
    }
    // Apply pins: a group rooted at a pinned node (input/concat) is
    // unprunable, as is any group unioned with one.
    let mut groups: Vec<PruneGroup> = Vec::new();
    for (root, mut g) in buckets {
        let mut any_pinned = pinned[root];
        // Also check whether any pinned node shares this root.
        for (i, &p) in pinned.iter().enumerate() {
            if p && uf.find(rep[i]) == root {
                any_pinned = true;
                break;
            }
        }
        if any_pinned {
            g.prunable = false;
        }
        groups.push(g);
    }
    groups
}

/// Validate that all members of every group still have equal filter counts
/// (test/debug helper; cheap invariant check).
pub fn groups_consistent(graph: &Graph, groups: &[PruneGroup]) -> bool {
    let shapes = match graph.infer_shapes() {
        Ok(s) => s,
        Err(_) => return false,
    };
    groups.iter().all(|g| {
        let counts: Vec<usize> = g.convs.iter().map(|&c| shapes[c].channels()).collect();
        counts.windows(2).all(|w| w[0] == w[1])
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Act, GraphBuilder};
    use crate::models;

    #[test]
    fn plain_chain_gives_singleton_groups() {
        let g = models::alexnet(1000);
        let groups = prune_groups(&g, &[]);
        // 5 convs, no residuals → 5 singleton groups, all prunable.
        assert_eq!(groups.len(), 5);
        assert!(groups.iter().all(|gr| gr.convs.len() == 1 && gr.prunable));
    }

    #[test]
    fn resnet18_residual_groups_merge() {
        let g = models::resnet18(1000);
        let groups = prune_groups(&g, &[]);
        // Stage channel groups: stem conv + layer1 outputs share 56x56x64
        // channels through the identity path.
        let big: Vec<_> = groups.iter().filter(|gr| gr.convs.len() > 1).collect();
        assert!(!big.is_empty());
        // stem group: conv1 + layer1.0.conv2 + layer1.1.conv2 (identity
        // residuals) = 3 members.
        let stem_group = groups
            .iter()
            .find(|gr| gr.convs.contains(&g.nodes.iter().find(|n| n.name == "conv1").unwrap().id))
            .unwrap();
        assert_eq!(stem_group.convs.len(), 3);
        assert!(groups_consistent(&g, &groups));
    }

    #[test]
    fn depthwise_not_a_member() {
        let g = models::mobilenet_v2(1000);
        let groups = prune_groups(&g, &[]);
        let dw_ids: Vec<NodeId> = g
            .conv_infos()
            .unwrap()
            .iter()
            .filter(|c| c.is_depthwise())
            .map(|c| c.node)
            .collect();
        for gr in &groups {
            for c in &gr.convs {
                assert!(!dw_ids.contains(c), "depthwise conv in a prune group");
            }
        }
    }

    #[test]
    fn concat_into_add_pins_contributors() {
        // fire-like concat feeding a residual join must pin the expand convs
        let mut g = Graph::new("cat-add");
        let x = g.input(3, 8, 8);
        let pre = g.conv_bn_act("pre", x, 8, 1, 1, 0, Act::Relu);
        let a = g.conv("a", pre, 4, 1, 1, 0);
        let b = g.conv("b", pre, 4, 3, 1, 1);
        let cat = g.concat("cat", &[a, b]);
        let j = g.add_join("join", &[cat, pre]);
        let _out = g.relu("out", j);
        let groups = prune_groups(&g, &[]);
        let by_conv = |name: &str| {
            let id = g.nodes.iter().find(|n| n.name == name).unwrap().id;
            groups.iter().find(|gr| gr.convs.contains(&id)).unwrap()
        };
        assert!(!by_conv("a").prunable);
        assert!(!by_conv("b").prunable);
        // `pre` is unioned with the concat output via the Add → also pinned.
        assert!(!by_conv("pre").prunable);
    }

    #[test]
    fn protected_convs_unprunable() {
        let g = models::squeezenet(1000);
        let classifier = g
            .nodes
            .iter()
            .find(|n| n.name == "classifier.1")
            .unwrap()
            .id;
        let groups = prune_groups(&g, &[classifier]);
        let gr = groups
            .iter()
            .find(|gr| gr.convs.contains(&classifier))
            .unwrap();
        assert!(!gr.prunable);
    }

    #[test]
    fn depths_are_monotone_in_topo_order() {
        let g = models::vgg16(1000);
        let groups = prune_groups(&g, &[]);
        let mut depths: Vec<f64> = groups.iter().map(|gr| gr.depth).collect();
        let sorted = {
            let mut d = depths.clone();
            d.sort_by(|a, b| a.partial_cmp(b).unwrap());
            d
        };
        depths.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(depths, sorted);
        assert!(depths.iter().all(|&d| (0.0..1.0).contains(&d)));
    }
}
