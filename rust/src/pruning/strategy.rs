//! Pruning strategies: how a global pruning level is distributed over
//! layers. Mirrors the paper's setups:
//!
//! - `Random` — "randomly pruning filters with equal probability across all
//!   layers": every filter enters a global pool, so each layer's removed
//!   count is Binomial(n_l, level) (seed-dependent jitter across layers).
//! - `L1Norm` — emulates magnitude pruning which "results in more filters
//!   pruned from deeper layers": removal weight grows exponentially with
//!   normalised depth.
//! - `Weighted` — the Sec. 6.2 topology study: uniform / early-heavy /
//!   middle-heavy / late-heavy / random per-layer weightings at a fixed
//!   global level.

use crate::util::rng::Pcg64;

/// Per-layer weighting profiles for [`Strategy::Weighted`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    Uniform,
    EarlyHeavy,
    MiddleHeavy,
    LateHeavy,
    Random,
}

pub const ALL_PROFILES: [Profile; 5] = [
    Profile::Uniform,
    Profile::EarlyHeavy,
    Profile::MiddleHeavy,
    Profile::LateHeavy,
    Profile::Random,
];

/// A pruning strategy `S` in the paper's notation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Strategy {
    Random,
    L1Norm,
    Weighted(Profile),
}

impl Strategy {
    pub fn name(&self) -> String {
        match self {
            Strategy::Random => "random".into(),
            Strategy::L1Norm => "l1norm".into(),
            Strategy::Weighted(p) => format!("weighted-{p:?}").to_lowercase(),
        }
    }

    /// Inverse of [`Strategy::name`] (plus the `l1` shorthand) — used by
    /// the CLI and the campaign spec (de)serialisation.
    pub fn from_name(name: &str) -> Option<Strategy> {
        Some(match name {
            "random" => Strategy::Random,
            "l1norm" | "l1" => Strategy::L1Norm,
            other => {
                let profile = other.strip_prefix("weighted-")?;
                Strategy::Weighted(match profile {
                    "uniform" => Profile::Uniform,
                    "earlyheavy" => Profile::EarlyHeavy,
                    "middleheavy" => Profile::MiddleHeavy,
                    "lateheavy" => Profile::LateHeavy,
                    "random" => Profile::Random,
                    _ => return None,
                })
            }
        })
    }

    /// Number of filters to REMOVE from a group of `filters` filters at
    /// normalised depth `depth`, targeting global `level` ∈ [0,1).
    ///
    /// Always leaves at least one filter. The per-strategy depth weight is
    /// normalised so the *expected* global removal fraction equals `level`
    /// (exact for Uniform, asymptotically for the others).
    pub fn removed_filters(
        &self,
        filters: usize,
        depth: f64,
        level: f64,
        rng: &mut Pcg64,
    ) -> usize {
        assert!((0.0..1.0).contains(&level), "level must be in [0,1)");
        if level == 0.0 || filters <= 1 {
            return 0;
        }
        let frac = match self {
            Strategy::Random => {
                // Binomial(n, level) via normal approximation for large n,
                // exact sampling for small n.
                return sample_binomial(filters, level, rng).min(filters - 1);
            }
            Strategy::L1Norm => {
                // weight grows with depth; mean of w over depth∈[0,1] is 1
                // for alpha=1.2: w(d) = alpha*exp(beta*d)/ (exp(beta)-1) * beta
                let beta = 1.5f64;
                let w = beta * (beta * depth).exp() / ((beta).exp() - 1.0);
                (level * w).min(0.95)
            }
            Strategy::Weighted(profile) => {
                let w = match profile {
                    Profile::Uniform => 1.0,
                    Profile::EarlyHeavy => 2.0 * (1.0 - depth).powi(2) * 1.5,
                    Profile::MiddleHeavy => {
                        1.8 * (-8.0 * (depth - 0.5) * (depth - 0.5)).exp() * 1.6
                    }
                    Profile::LateHeavy => 2.0 * depth * depth * 1.5,
                    Profile::Random => 2.0 * rng.next_f64(),
                };
                (level * w).min(0.95)
            }
        };
        (((filters as f64) * frac).round() as usize).min(filters - 1)
    }
}

/// Sample Binomial(n, p). Exact inversion for small n; normal
/// approximation with continuity correction for large n.
fn sample_binomial(n: usize, p: f64, rng: &mut Pcg64) -> usize {
    if n <= 64 {
        (0..n).filter(|_| rng.chance(p)).count()
    } else {
        let mean = n as f64 * p;
        let std = (n as f64 * p * (1.0 - p)).sqrt();
        let x = rng.normal_ms(mean, std).round();
        x.clamp(0.0, n as f64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_level_removes_nothing() {
        let mut rng = Pcg64::new(1);
        for s in [Strategy::Random, Strategy::L1Norm] {
            assert_eq!(s.removed_filters(64, 0.5, 0.0, &mut rng), 0);
        }
    }

    #[test]
    fn always_leaves_one_filter() {
        let mut rng = Pcg64::new(2);
        for s in [
            Strategy::Random,
            Strategy::L1Norm,
            Strategy::Weighted(Profile::LateHeavy),
        ] {
            for _ in 0..200 {
                let removed = s.removed_filters(4, 0.99, 0.9, &mut rng);
                assert!(removed < 4);
            }
        }
    }

    #[test]
    fn random_strategy_mean_matches_level() {
        let mut rng = Pcg64::new(3);
        let n = 256;
        let trials = 500;
        let total: usize = (0..trials)
            .map(|_| Strategy::Random.removed_filters(n, 0.3, 0.5, &mut rng))
            .sum();
        let mean_frac = total as f64 / (trials * n) as f64;
        assert!((mean_frac - 0.5).abs() < 0.02, "mean frac = {mean_frac}");
    }

    #[test]
    fn l1norm_prunes_deeper_layers_more() {
        let mut rng = Pcg64::new(4);
        let shallow = Strategy::L1Norm.removed_filters(512, 0.05, 0.5, &mut rng);
        let deep = Strategy::L1Norm.removed_filters(512, 0.95, 0.5, &mut rng);
        assert!(deep > shallow, "deep={deep} shallow={shallow}");
    }

    #[test]
    fn early_heavy_profile_prunes_early_layers_more() {
        let mut rng = Pcg64::new(5);
        let s = Strategy::Weighted(Profile::EarlyHeavy);
        let early = s.removed_filters(512, 0.05, 0.5, &mut rng);
        let late = s.removed_filters(512, 0.95, 0.5, &mut rng);
        assert!(early > late, "early={early} late={late}");
    }

    #[test]
    fn binomial_sampler_moments() {
        let mut rng = Pcg64::new(6);
        // Large-n path.
        let xs: Vec<f64> = (0..2000)
            .map(|_| sample_binomial(1000, 0.3, &mut rng) as f64)
            .collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 300.0).abs() < 5.0, "mean={mean}");
    }

    #[test]
    fn strategy_names_stable() {
        assert_eq!(Strategy::Random.name(), "random");
        assert_eq!(Strategy::L1Norm.name(), "l1norm");
        assert_eq!(
            Strategy::Weighted(Profile::MiddleHeavy).name(),
            "weighted-middleheavy"
        );
    }

    #[test]
    fn from_name_round_trips_every_strategy() {
        let mut all = vec![Strategy::Random, Strategy::L1Norm];
        all.extend(ALL_PROFILES.iter().map(|&p| Strategy::Weighted(p)));
        for s in all {
            assert_eq!(Strategy::from_name(&s.name()), Some(s));
        }
        assert_eq!(Strategy::from_name("l1"), Some(Strategy::L1Norm));
        assert_eq!(Strategy::from_name("magnitude"), None);
        assert_eq!(Strategy::from_name("weighted-steep"), None);
    }
}
