//! Async multi-tenant prediction service — cross-client batch coalescing
//! over one shared [`PredictionEngine`].
//!
//! perf4sight's value is rapid identification of trainable
//! configurations, which in practice means many concurrent consumers —
//! evolutionary searches, CLI sweeps, campaign fits — hammering the same
//! Γ/γ/φ predictors. The engine (PR 2/5) is batched and cached but
//! strictly single-caller; this module is the serving seam that lets N
//! clients share it without forfeiting batching or cache reuse:
//!
//! ```text
//!  Tenant 0 ─┐  submit(generation)            ┌──────────────────────┐
//!  Tenant 1 ─┼─▶ BoundedQueue ─▶ serving loop │ coalesce requests    │
//!    …       │   (admission     (one thread)  │ dedup in-flight fps  │
//!  Tenant N ─┘    control)                    │ 2 blocked passes     │
//!      ▲                                      │ shared memo cache    │
//!      └────────── per-request reply ◀────────┴──────────────────────┘
//! ```
//!
//! Each client holds a [`Tenant`] handle and submits whole generations of
//! [`SubnetConfig`] queries; [`Tenant`] implements
//! [`GenerationOracle`], so [`evolutionary_search`](crate::ofa) runs
//! **unmodified** on top of the service. The serving loop drains the
//! bounded queue (a full queue blocks `submit` — backpressure), coalesces
//! everything queued into one engine generation, and the engine's
//! batch-local dedup then collapses identical in-flight candidates
//! *across tenants* into a single evaluation before the shortfall-sized
//! blocked branch-free passes run — one
//! [`BlockedForest`](crate::engine::BlockedForest) walk for Γ plus one
//! fused [`CompiledForestPair`](crate::engine::CompiledForestPair) γ/φ
//! walk (see [`crate::engine::exec`]). Results fan back out per request,
//! and per-tenant
//! hit/miss/latency counters ([`TenantStats`]) are kept from the engine's
//! traced outcomes.
//!
//! **Bit-identity guarantee.** Every query is answered by the same pure
//! per-candidate computation whatever batch it lands in, so N concurrent
//! searches through one service return results byte-identical to N serial
//! single-caller runs ([`EsResult::deterministic_bytes`](crate::ofa::EsResult::deterministic_bytes);
//! asserted for N ∈ {1, 4, 8} by `rust/tests/serve_identity.rs` and by
//! CI's serve-smoke job). To keep that guarantee, [`Tenant::cache_stats`]
//! deliberately reports `None`: the shared cache's counters depend on
//! co-tenant traffic, and must not leak into a tenant's `EsResult`.

pub mod stats;

pub use stats::TenantStats;

use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::engine::{CacheStats, PredictionEngine, QueryOutcome};
use crate::ofa::{CandidateEval, GenerationOracle, SubnetConfig};
use crate::util::queue::BoundedQueue;

/// Serving-loop knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Generations that may wait in the queue before `submit` blocks
    /// (admission control). Tenants block on their reply between
    /// submissions, so the backlog is also bounded by the tenant count.
    pub queue_capacity: usize,
    /// Most requests coalesced into one engine generation per drain.
    pub max_coalesce: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_capacity: 64,
            max_coalesce: 16,
        }
    }
}

/// One queued generation: who asked, what, when, and where the answer
/// goes.
struct Request {
    tenant: usize,
    candidates: Vec<SubnetConfig>,
    enqueued: Instant,
    reply: mpsc::Sender<Vec<CandidateEval>>,
}

/// State shared between the service handle, its tenants and the serving
/// loop.
struct ServiceShared {
    queue: BoundedQueue<Request>,
    stats: Mutex<Vec<TenantStats>>,
}

/// Handle to a running prediction service: spawns the serving loop,
/// mints [`Tenant`]s, reports stats, and joins the loop on
/// shutdown/drop. See module docs.
pub struct PredictionService {
    shared: Arc<ServiceShared>,
    /// Stats-only fork of the served engine (same shared cache).
    probe: PredictionEngine,
    worker: Option<JoinHandle<()>>,
}

impl PredictionService {
    /// Move `engine` into a freshly spawned serving loop. The engine's
    /// cache (including anything already memoised) becomes the service's
    /// shared cache.
    pub fn spawn(engine: PredictionEngine, cfg: &ServeConfig) -> PredictionService {
        let shared = Arc::new(ServiceShared {
            queue: BoundedQueue::new(cfg.queue_capacity),
            stats: Mutex::new(Vec::new()),
        });
        let probe = engine.fork();
        let loop_shared = Arc::clone(&shared);
        let max_coalesce = cfg.max_coalesce.max(1);
        let worker = std::thread::Builder::new()
            .name("p4s-serve".into())
            .spawn(move || serve_loop(engine, loop_shared, max_coalesce))
            .expect("spawning the serving loop");
        PredictionService {
            shared,
            probe,
            worker: Some(worker),
        }
    }

    /// Mint a tenant handle. Tenants are cheap; mint one per concurrent
    /// client (ids are dense and stable, in mint order).
    pub fn tenant(&self) -> Tenant {
        let mut stats = self.shared.stats.lock().unwrap();
        stats.push(TenantStats::default());
        Tenant {
            shared: Arc::clone(&self.shared),
            id: stats.len() - 1,
        }
    }

    /// Snapshot of every tenant's counters, indexed by tenant id.
    pub fn tenant_stats(&self) -> Vec<TenantStats> {
        self.shared.stats.lock().unwrap().clone()
    }

    /// Counters of the shared engine cache (aggregate over all tenants).
    pub fn cache_stats(&self) -> CacheStats {
        self.probe.stats()
    }

    /// Generations currently waiting in the queue (diagnostics).
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.len()
    }

    /// Stop admitting work, serve the backlog, join the loop, and return
    /// the final per-tenant counters. Call after every client finished —
    /// a tenant submitting afterwards panics (its service is gone).
    pub fn shutdown(mut self) -> Vec<TenantStats> {
        self.close_and_join();
        self.tenant_stats()
    }

    fn close_and_join(&mut self) {
        self.shared.queue.close();
        if let Some(handle) = self.worker.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for PredictionService {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// A client handle onto a [`PredictionService`]. Implements
/// [`GenerationOracle`], so an `evolutionary_search` takes a `&mut
/// Tenant` exactly where it would take a `&mut PredictionEngine`.
pub struct Tenant {
    shared: Arc<ServiceShared>,
    id: usize,
}

impl Tenant {
    pub fn id(&self) -> usize {
        self.id
    }

    /// Submit one generation and block until the serving loop answers.
    /// Blocks earlier on a full queue (admission control). Panics if the
    /// service was shut down while this tenant is still active — that is
    /// a lifecycle bug, not a recoverable condition.
    pub fn submit(&self, candidates: &[SubnetConfig]) -> Vec<CandidateEval> {
        if candidates.is_empty() {
            return Vec::new();
        }
        let (reply, answer) = mpsc::channel();
        let request = Request {
            tenant: self.id,
            candidates: candidates.to_vec(),
            enqueued: Instant::now(),
            reply,
        };
        if self.shared.queue.push(request).is_err() {
            panic!("prediction service shut down with tenant {} still active", self.id);
        }
        answer.recv().expect("serving loop dropped a reply channel")
    }

    /// This tenant's counters so far.
    pub fn stats(&self) -> TenantStats {
        self.shared.stats.lock().unwrap()[self.id]
    }
}

impl GenerationOracle for Tenant {
    fn evaluate_generation(&mut self, candidates: &[SubnetConfig]) -> Vec<CandidateEval> {
        self.submit(candidates)
    }

    /// Deliberately `None`: the shared cache's counters depend on
    /// co-tenant traffic, and reporting them here would make a tenant's
    /// `EsResult` (its `cache`/`unique_evaluations` fields) depend on
    /// scheduling — breaking the serial-vs-concurrent bit-identity
    /// guarantee. Per-tenant serving counters live in
    /// [`Tenant::stats`]; the aggregate cache view in
    /// [`PredictionService::cache_stats`].
    fn cache_stats(&self) -> Option<CacheStats> {
        None
    }
}

/// The scheduler: drain everything queued (blocking for the first
/// request), coalesce into one engine generation, fan results back out,
/// attribute outcomes to tenants. Exits when the queue is closed and
/// empty.
fn serve_loop(mut engine: PredictionEngine, shared: Arc<ServiceShared>, max_coalesce: usize) {
    loop {
        let requests = shared.queue.drain(max_coalesce);
        if requests.is_empty() {
            break;
        }
        let total: usize = requests.iter().map(|r| r.candidates.len()).sum();
        let mut coalesced = Vec::with_capacity(total);
        for r in &requests {
            coalesced.extend_from_slice(&r.candidates);
        }
        // One shared-cache transaction for the whole cross-tenant batch:
        // in-flight duplicates collapse to one evaluation, misses run in
        // three shortfall-sized batched traversals.
        let (evals, outcomes) = engine.evaluate_generation_traced(&coalesced);
        let served = Instant::now();
        let mut stats = shared.stats.lock().unwrap();
        let mut start = 0usize;
        for r in requests {
            let end = start + r.candidates.len();
            let t = &mut stats[r.tenant];
            t.generations += 1;
            t.queries += r.candidates.len() as u64;
            for outcome in &outcomes[start..end] {
                match outcome {
                    QueryOutcome::CacheHit => t.cache_hits += 1,
                    QueryOutcome::BatchHit => t.batch_hits += 1,
                    QueryOutcome::Evaluated => t.evaluated += 1,
                }
            }
            let wait_ns = served.duration_since(r.enqueued).as_nanos() as u64;
            t.wait_ns += wait_ns;
            t.max_wait_ns = t.max_wait_ns.max(wait_ns);
            // A tenant that vanished mid-request must not stop the loop;
            // the send result is deliberately ignored.
            let _ = r.reply.send(evals[start..end].to_vec());
            start = end;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::NUM_FEATURES;
    use crate::forest::{Forest, ForestConfig};
    use crate::util::rng::Pcg64;

    /// Engine over one synthetic forest (serving-layer behaviour only;
    /// model quality is tested in `experiments::ofa_models`).
    fn tiny_engine() -> PredictionEngine {
        let mut rng = Pcg64::new(0x5e17e);
        let x: Vec<Vec<f64>> = (0..40)
            .map(|_| (0..NUM_FEATURES).map(|_| rng.uniform(0.0, 1e6)).collect())
            .collect();
        let y: Vec<f64> = x.iter().map(|r| r[1] / 1e3 + r[3] / 1e4 + 100.0).collect();
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig {
                n_trees: 8,
                max_depth: 6,
                ..Default::default()
            },
        )
        .unwrap();
        PredictionEngine::new(&f, &f, &f)
    }

    fn sample_generation(seed: u64, n: usize) -> Vec<SubnetConfig> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| SubnetConfig::sample(&mut rng)).collect()
    }

    #[test]
    fn service_answers_match_direct_engine_bitwise() {
        let engine = tiny_engine();
        // Independent cache-less engine — not a fork, which would share
        // (and here disable) the service's cache.
        let mut reference = tiny_engine().with_cache_capacity(0);
        let generation = sample_generation(1, 24);
        let expected = reference.evaluate_generation(&generation);
        let service = PredictionService::spawn(engine, &ServeConfig::default());
        let mut tenant = service.tenant();
        let got = tenant.evaluate_generation(&generation);
        assert_eq!(expected, got, "served answers must be bit-identical");
        assert!(tenant.cache_stats().is_none(), "tenants must not leak cache stats");
        service.shutdown();
    }

    #[test]
    fn per_tenant_stats_account_every_query() {
        let service = PredictionService::spawn(tiny_engine(), &ServeConfig::default());
        let a = service.tenant();
        let b = service.tenant();
        let generation = sample_generation(2, 16);
        a.submit(&generation);
        // Same workload again from the other tenant: answered entirely
        // without evaluation (cross-tenant cache sharing).
        b.submit(&generation);
        let stats = service.shutdown();
        assert_eq!(stats.len(), 2);
        let (sa, sb) = (stats[0], stats[1]);
        assert_eq!(sa.queries, 16);
        assert_eq!(sa.hits() + sa.evaluated, sa.queries);
        assert_eq!(sb.queries, 16);
        assert_eq!(sb.evaluated, 0, "tenant b rides tenant a's cache");
        assert_eq!(sb.hits(), 16);
        assert!(sa.generations == 1 && sb.generations == 1);
        assert!(sa.max_wait_ns > 0 && sb.max_wait_ns > 0);
    }

    #[test]
    fn duplicates_within_one_submission_are_batch_hits() {
        let service = PredictionService::spawn(tiny_engine(), &ServeConfig::default());
        let tenant = service.tenant();
        let mut generation = sample_generation(3, 8);
        let dup = generation[0];
        generation.push(dup);
        let evals = tenant.submit(&generation);
        assert_eq!(evals[0], evals[8], "duplicate answered from the in-flight batch");
        let s = tenant.stats();
        assert_eq!(s.queries, 9);
        assert_eq!(s.batch_hits, 1);
        assert_eq!(s.evaluated, 8);
        service.shutdown();
    }

    #[test]
    fn empty_submission_is_fine() {
        let service = PredictionService::spawn(tiny_engine(), &ServeConfig::default());
        let tenant = service.tenant();
        assert!(tenant.submit(&[]).is_empty());
        let stats = service.shutdown();
        assert_eq!(stats[0], TenantStats::default());
    }

    #[test]
    fn aggregate_cache_stats_visible_through_service() {
        let service = PredictionService::spawn(tiny_engine(), &ServeConfig::default());
        let tenant = service.tenant();
        let generation = sample_generation(4, 12);
        tenant.submit(&generation);
        tenant.submit(&generation);
        let cs = service.cache_stats();
        assert_eq!(cs.requests(), 24);
        assert_eq!(cs.misses, 12);
        assert_eq!(cs.hits, 12);
        service.shutdown();
    }
}
