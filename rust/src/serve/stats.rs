//! Per-tenant serving statistics, kept by the serving loop from the
//! engine's traced query outcomes (see
//! [`PredictionEngine::evaluate_generation_traced`](crate::engine::PredictionEngine::evaluate_generation_traced)).
//!
//! The shared cache's own [`CacheStats`](crate::engine::CacheStats)
//! aggregate over *every* client; these counters attribute each query to
//! the tenant that submitted it, which is what capacity planning for a
//! multi-tenant deployment needs — who is hot, who rides whose cache, and
//! how long requests sit in the queue.

/// Counters for one [`Tenant`](crate::serve::Tenant) handle.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantStats {
    /// Generations (submit calls) served.
    pub generations: u64,
    /// Candidate queries submitted across all generations.
    pub queries: u64,
    /// Queries answered from the shared fingerprint memo.
    pub cache_hits: u64,
    /// Queries answered from an in-flight duplicate in the same coalesced
    /// batch — possibly one submitted by a *different* tenant.
    pub batch_hits: u64,
    /// Queries that ran the batched predictors (cache misses).
    pub evaluated: u64,
    /// Total submit→served latency across generations, nanoseconds.
    pub wait_ns: u64,
    /// Worst single-generation submit→served latency, nanoseconds.
    pub max_wait_ns: u64,
}

impl TenantStats {
    /// Queries answered without running the predictors.
    pub fn hits(&self) -> u64 {
        self.cache_hits + self.batch_hits
    }

    /// Fraction of queries answered without evaluation, in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        if self.queries == 0 {
            0.0
        } else {
            self.hits() as f64 / self.queries as f64
        }
    }

    /// Mean submit→served latency per generation, nanoseconds.
    pub fn mean_wait_ns(&self) -> f64 {
        if self.generations == 0 {
            0.0
        } else {
            self.wait_ns as f64 / self.generations as f64
        }
    }

    /// Fleet totals across tenants (`max_wait_ns` is the overall worst).
    pub fn aggregate(all: &[TenantStats]) -> TenantStats {
        let mut sum = TenantStats::default();
        for t in all {
            sum.generations += t.generations;
            sum.queries += t.queries;
            sum.cache_hits += t.cache_hits;
            sum.batch_hits += t.batch_hits;
            sum.evaluated += t.evaluated;
            sum.wait_ns += t.wait_ns;
            sum.max_wait_ns = sum.max_wait_ns.max(t.max_wait_ns);
        }
        sum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_and_aggregation() {
        let a = TenantStats {
            generations: 2,
            queries: 10,
            cache_hits: 4,
            batch_hits: 2,
            evaluated: 4,
            wait_ns: 2_000,
            max_wait_ns: 1_500,
        };
        let b = TenantStats {
            generations: 1,
            queries: 5,
            cache_hits: 0,
            batch_hits: 0,
            evaluated: 5,
            wait_ns: 700,
            max_wait_ns: 700,
        };
        assert_eq!(a.hits(), 6);
        assert!((a.hit_rate() - 0.6).abs() < 1e-12);
        assert!((a.mean_wait_ns() - 1_000.0).abs() < 1e-12);
        let sum = TenantStats::aggregate(&[a, b]);
        assert_eq!(sum.generations, 3);
        assert_eq!(sum.queries, 15);
        assert_eq!(sum.evaluated, 9);
        assert_eq!(sum.max_wait_ns, 1_500);
        let zero = TenantStats::default();
        assert_eq!(zero.hit_rate(), 0.0);
        assert_eq!(zero.mean_wait_ns(), 0.0);
    }
}
