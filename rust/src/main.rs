//! perf4sight CLI entrypoint — see `perf4sight help`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = perf4sight::coordinator::run(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
