//! # perf4sight
//!
//! Reproduction of *"perf4sight: A toolflow to model CNN training
//! performance on Edge GPUs"* (Rajagopal & Bouganis, 2021) as a three-layer
//! Rust + JAX + Pallas system. See `DESIGN.md` for the architecture and
//! `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! Layer map:
//! - **L3 (this crate)** — the toolflow: network IR + zoo, structured
//!   pruning, analytical features, edge-GPU simulator, network-wise
//!   profiler, random-forest models, OFA evolutionary search, experiment
//!   harnesses, PJRT runtime.
//! - **L2/L1 (`python/compile/`)** — build-time JAX graphs + Pallas kernels
//!   AOT-lowered to HLO text in `artifacts/`, executed from `runtime/`.

pub mod baselines;
pub mod campaign;
pub mod coordinator;
pub mod device;
pub mod engine;
pub mod experiments;
pub mod features;
pub mod forest;
pub mod ir;
pub mod models;
pub mod ofa;
pub mod profiler;
pub mod pruning;
pub mod runtime;
pub mod serve;
pub mod util;
