//! CART regression trees (Quinlan/Breiman-style), trained by recursive
//! variance-minimising binary splits — the building block of the paper's
//! random-forest models (Sec. 5.2: "A decision tree selects terms that best
//! partition the space into regions of low entropy. Regression predictions
//! are made by classifying new data points into these regions and
//! predicting the mean value of that region").

use crate::util::rng::Pcg64;

/// A node in the flattened tree. Leaves have `feature == u32::MAX` and
/// self-referential children (which makes fixed-depth tensor traversal in
/// the Pallas kernel a no-op once a leaf is reached).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TreeNode {
    pub feature: u32,
    pub threshold: f64,
    pub left: u32,
    pub right: u32,
    /// Mean target of the training samples in this region.
    pub value: f64,
}

impl TreeNode {
    pub fn is_leaf(&self) -> bool {
        self.feature == u32::MAX
    }
}

/// Hyperparameters for one tree / the whole forest.
#[derive(Clone, Debug)]
pub struct TreeConfig {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    pub min_samples_split: usize,
    /// Number of candidate features per split (`None` ⇒ all; the forest
    /// default is n/3, the classic regression-forest setting).
    pub max_features: Option<usize>,
}

impl Default for TreeConfig {
    fn default() -> Self {
        TreeConfig {
            max_depth: 12,
            min_samples_leaf: 1,
            min_samples_split: 2,
            max_features: None,
        }
    }
}

/// A fitted regression tree.
#[derive(Clone, Debug, PartialEq)]
pub struct Tree {
    pub nodes: Vec<TreeNode>,
}

impl Tree {
    /// Fit a tree on `x[indices]` (row-major `n × d`) against `y[indices]`.
    ///
    /// This is the per-node-sort *reference* builder. `indices` must be in
    /// canonical order — ascending row id, bootstrap duplicates adjacent —
    /// which is the sample enumeration order the presorted-column fast
    /// path ([`FitScratch::fit_tree`](crate::forest::FitScratch)) shares;
    /// `Forest::fit_reference` sorts its bootstrap draw before calling in.
    pub fn fit(
        x: &[Vec<f64>],
        y: &[f64],
        indices: &[usize],
        cfg: &TreeConfig,
        rng: &mut Pcg64,
    ) -> Tree {
        assert!(!indices.is_empty(), "cannot fit a tree on zero samples");
        let d = x[0].len();
        let mut nodes = Vec::new();
        let mut idx = indices.to_vec();
        build(x, y, &mut idx, 0, cfg, d, rng, &mut nodes, 0);
        Tree { nodes }
    }

    /// Predict a single row.
    pub fn predict(&self, row: &[f64]) -> f64 {
        let mut node = &self.nodes[0];
        loop {
            if node.is_leaf() {
                return node.value;
            }
            node = if row[node.feature as usize] <= node.threshold {
                &self.nodes[node.left as usize]
            } else {
                &self.nodes[node.right as usize]
            };
        }
    }

    pub fn depth(&self) -> usize {
        fn d(nodes: &[TreeNode], i: usize) -> usize {
            let n = &nodes[i];
            if n.is_leaf() {
                1
            } else {
                1 + d(nodes, n.left as usize).max(d(nodes, n.right as usize))
            }
        }
        d(&self.nodes, 0)
    }

    pub fn leaf_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }
}

/// Recursively build nodes; returns the index of the created node.
#[allow(clippy::too_many_arguments)]
fn build(
    x: &[Vec<f64>],
    y: &[f64],
    indices: &mut [usize],
    depth: usize,
    cfg: &TreeConfig,
    d: usize,
    rng: &mut Pcg64,
    nodes: &mut Vec<TreeNode>,
    _parent: usize,
) -> u32 {
    let mean = indices.iter().map(|&i| y[i]).sum::<f64>() / indices.len() as f64;
    let make_leaf = |nodes: &mut Vec<TreeNode>| -> u32 {
        let id = nodes.len() as u32;
        nodes.push(TreeNode {
            feature: u32::MAX,
            threshold: f64::INFINITY,
            left: id,
            right: id,
            value: mean,
        });
        id
    };

    if depth >= cfg.max_depth
        || indices.len() < cfg.min_samples_split
        || indices.len() < 2 * cfg.min_samples_leaf
    {
        return make_leaf(nodes);
    }

    // Candidate feature subset.
    let n_candidates = cfg.max_features.unwrap_or(d).clamp(1, d);
    let candidates: Vec<usize> = if n_candidates == d {
        (0..d).collect()
    } else {
        rng.sample_indices(d, n_candidates)
    };

    // Find the variance-minimising split across candidates.
    let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, score)
    let mut sorted = indices.to_vec();
    for &f in &candidates {
        // Fresh stable sort per candidate from the node's canonical order
        // (ascending row id, bootstrap duplicates adjacent) ⇒ scan order
        // is exactly (feature value, row id). The presorted-column fast
        // path (`forest::train`) reproduces this order by filtering its
        // global presort, which is what makes the two paths bit-identical.
        // `total_cmp` keeps the comparator total; non-finite values are
        // rejected before fitting starts (`FitError::NonFiniteFeature`).
        sorted.copy_from_slice(indices);
        sorted.sort_by(|&a, &b| x[a][f].total_cmp(&x[b][f]));
        let total_sum: f64 = sorted.iter().map(|&i| y[i]).sum();
        let total_sq: f64 = sorted.iter().map(|&i| y[i] * y[i]).sum();
        let n = sorted.len() as f64;
        let mut left_sum = 0.0;
        let mut left_sq = 0.0;
        for (pos, &i) in sorted.iter().enumerate() {
            left_sum += y[i];
            left_sq += y[i] * y[i];
            let nl = (pos + 1) as f64;
            let nr = n - nl;
            if (pos + 1) < cfg.min_samples_leaf || (sorted.len() - pos - 1) < cfg.min_samples_leaf
            {
                continue;
            }
            if nr == 0.0 {
                break;
            }
            // Can't split between equal feature values.
            let xv = x[i][f];
            let xn = x[sorted[pos + 1]][f];
            if xv == xn {
                continue;
            }
            // Weighted SSE of the two children.
            let right_sum = total_sum - left_sum;
            let right_sq = total_sq - left_sq;
            let sse = (left_sq - left_sum * left_sum / nl)
                + (right_sq - right_sum * right_sum / nr);
            if best.map_or(true, |(_, _, s)| sse < s) {
                best = Some((f, 0.5 * (xv + xn), sse));
            }
        }
    }

    let Some((feature, threshold, _)) = best else {
        return make_leaf(nodes);
    };

    // Partition indices in place.
    let mut left_idx: Vec<usize> = Vec::new();
    let mut right_idx: Vec<usize> = Vec::new();
    for &i in indices.iter() {
        if x[i][feature] <= threshold {
            left_idx.push(i);
        } else {
            right_idx.push(i);
        }
    }
    if left_idx.is_empty() || right_idx.is_empty() {
        return make_leaf(nodes);
    }

    let id = nodes.len() as u32;
    nodes.push(TreeNode {
        feature: feature as u32,
        threshold,
        left: 0,
        right: 0,
        value: mean,
    });
    let l = build(x, y, &mut left_idx, depth + 1, cfg, d, rng, nodes, id as usize);
    let r = build(x, y, &mut right_idx, depth + 1, cfg, d, rng, nodes, id as usize);
    nodes[id as usize].left = l;
    nodes[id as usize].right = r;
    id
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_data() -> (Vec<Vec<f64>>, Vec<f64>) {
        // y = 3*x0 + step(x1 > 5)
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..20 {
            for j in 0..10 {
                x.push(vec![i as f64, j as f64]);
                y.push(3.0 * i as f64 + if j > 5 { 10.0 } else { 0.0 });
            }
        }
        (x, y)
    }

    #[test]
    fn fits_piecewise_function_exactly() {
        let (x, y) = grid_data();
        let idx: Vec<usize> = (0..x.len()).collect();
        let cfg = TreeConfig {
            max_depth: 16,
            ..Default::default()
        };
        let mut rng = Pcg64::new(1);
        let t = Tree::fit(&x, &y, &idx, &cfg, &mut rng);
        for (xi, yi) in x.iter().zip(&y) {
            assert!((t.predict(xi) - yi).abs() < 1e-9);
        }
    }

    #[test]
    fn depth_limit_respected() {
        let (x, y) = grid_data();
        let idx: Vec<usize> = (0..x.len()).collect();
        let cfg = TreeConfig {
            max_depth: 3,
            ..Default::default()
        };
        let mut rng = Pcg64::new(2);
        let t = Tree::fit(&x, &y, &idx, &cfg, &mut rng);
        assert!(t.depth() <= 4); // root at depth 0 → ≤ 4 levels of nodes
    }

    #[test]
    fn constant_target_gives_single_leaf() {
        let x = vec![vec![1.0], vec![2.0], vec![3.0]];
        let y = vec![7.0, 7.0, 7.0];
        let idx = vec![0, 1, 2];
        let mut rng = Pcg64::new(3);
        let t = Tree::fit(&x, &y, &idx, &TreeConfig::default(), &mut rng);
        // A constant target has zero variance everywhere; any structure
        // still predicts 7 exactly.
        assert_eq!(t.predict(&[1.5]), 7.0);
        assert_eq!(t.predict(&[99.0]), 7.0);
    }

    #[test]
    fn min_samples_leaf_respected() {
        let (x, y) = grid_data();
        let idx: Vec<usize> = (0..x.len()).collect();
        let cfg = TreeConfig {
            min_samples_leaf: 25,
            max_depth: 20,
            ..Default::default()
        };
        let mut rng = Pcg64::new(4);
        let t = Tree::fit(&x, &y, &idx, &cfg, &mut rng);
        // 200 samples / >=25 per leaf → at most 8 leaves
        assert!(t.leaf_count() <= 8);
    }

    #[test]
    fn extrapolation_clamps_to_leaf_means() {
        let (x, y) = grid_data();
        let idx: Vec<usize> = (0..x.len()).collect();
        let mut rng = Pcg64::new(5);
        let t = Tree::fit(&x, &y, &idx, &TreeConfig::default(), &mut rng);
        let pred = t.predict(&[1e9, 1e9]);
        let max_y = y.iter().cloned().fold(f64::MIN, f64::max);
        assert!(pred <= max_y + 1e-9);
    }

    #[test]
    fn leaves_self_loop() {
        let x = vec![vec![0.0], vec![1.0]];
        let y = vec![0.0, 1.0];
        let mut rng = Pcg64::new(6);
        let t = Tree::fit(&x, &y, &[0, 1], &TreeConfig::default(), &mut rng);
        for (i, n) in t.nodes.iter().enumerate() {
            if n.is_leaf() {
                assert_eq!(n.left as usize, i);
                assert_eq!(n.right as usize, i);
            }
        }
    }
}
