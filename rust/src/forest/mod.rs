//! Random-forest regression (Breiman 2001), from scratch — the paper's
//! model class for both Γ and Φ ("random forests are employed to model both
//! the memory and latency of training", Sec. 5.2). Includes bootstrap
//! bagging, per-split feature subsampling, JSON persistence, and export to
//! the padded tensor layout consumed by the L1 Pallas inference kernel.

pub mod train;
pub mod tree;

pub use train::{FitError, FitScratch, TrainMatrix};
pub use tree::{Tree, TreeConfig, TreeNode};

use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats;

/// Forest hyperparameters. Defaults follow the classic regression-forest
/// recipe (100 trees, n/3 features per split, bootstrap on).
#[derive(Clone, Debug)]
pub struct ForestConfig {
    pub n_trees: usize,
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    pub min_samples_split: usize,
    /// Fraction of features considered per split (1.0 ⇒ all).
    pub feature_fraction: f64,
    pub bootstrap: bool,
    pub seed: u64,
}

impl Default for ForestConfig {
    fn default() -> Self {
        ForestConfig {
            n_trees: 100,
            max_depth: 12,
            min_samples_leaf: 1,
            min_samples_split: 2,
            feature_fraction: 1.0 / 3.0,
            bootstrap: true,
            seed: 0xf0e57,
        }
    }
}

impl ForestConfig {
    /// Reject configs that would previously have clamped silently or
    /// panicked deep inside fitting. Run automatically by every fit entry
    /// point.
    pub fn validate(&self) -> Result<(), FitError> {
        if self.n_trees == 0 {
            return Err(FitError::InvalidConfig(
                "n_trees must be at least 1".into(),
            ));
        }
        // Negated comparison so NaN fails too.
        if !(self.feature_fraction > 0.0 && self.feature_fraction <= 1.0) {
            return Err(FitError::InvalidConfig(format!(
                "feature_fraction must be in (0, 1], got {}",
                self.feature_fraction
            )));
        }
        if self.min_samples_leaf == 0 {
            return Err(FitError::InvalidConfig(
                "min_samples_leaf must be at least 1".into(),
            ));
        }
        Ok(())
    }
}

/// A fitted random forest.
#[derive(Clone, Debug)]
pub struct Forest {
    pub trees: Vec<Tree>,
    pub n_features: usize,
    pub config: ForestConfig,
}

impl Forest {
    /// Fit on row-major `x` (n × d) against `y` (n): compile the training
    /// set into a [`TrainMatrix`] (one presort per feature) and train
    /// trees in parallel on scoped threads over the presorted-column fast
    /// path. Rejects malformed inputs (shape, non-finite values, bad
    /// config) with a named [`FitError`] before any work starts.
    ///
    /// Every per-tree RNG is forked from the seed generator up front, in
    /// the same sequential order [`Forest::fit_sequential`] uses, so each
    /// tree's randomness is independent of scheduling. Both run the fast
    /// path and both are node-for-node bit-identical to the retained
    /// per-node-sort algorithm, [`Forest::fit_reference`] (asserted by
    /// `rust/tests/fit_equivalence.rs` and `rust/tests/plan_equivalence.rs`).
    pub fn fit(x: &[Vec<f64>], y: &[f64], config: &ForestConfig) -> Result<Forest, FitError> {
        let m = TrainMatrix::from_rows(x)?;
        Self::fit_matrix(&m, y, config)
    }

    /// Single-threaded [`Forest::fit`] (same fast path, no thread pool).
    /// Kept as the scheduling-determinism oracle for the parallel path and
    /// for profiling comparisons.
    pub fn fit_sequential(
        x: &[Vec<f64>],
        y: &[f64],
        config: &ForestConfig,
    ) -> Result<Forest, FitError> {
        let m = TrainMatrix::from_rows(x)?;
        Self::fit_matrix_sequential(&m, y, config)
    }

    /// Fit from an already-compiled [`TrainMatrix`] (parallel). The matrix
    /// is target-agnostic, so callers fitting several targets on one
    /// dataset — Γ and Φ in `cmd_fit` and the experiments — presort once
    /// and fit many times.
    pub fn fit_matrix(
        m: &TrainMatrix,
        y: &[f64],
        config: &ForestConfig,
    ) -> Result<Forest, FitError> {
        let (tree_cfg, rngs) = Self::prepare(m, y, config)?;
        let workers = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(rngs.len())
            .max(1);
        // Round-robin distribution keeps per-worker load even.
        let mut chunks: Vec<Vec<(usize, Pcg64)>> = (0..workers).map(|_| Vec::new()).collect();
        for (i, r) in rngs.into_iter().enumerate() {
            chunks[i % workers].push((i, r));
        }
        let tree_cfg = &tree_cfg;
        let bootstrap = config.bootstrap;
        let mut fitted: Vec<(usize, Tree)> = std::thread::scope(|scope| {
            let handles: Vec<_> = chunks
                .into_iter()
                .map(|chunk| {
                    scope.spawn(move || {
                        // One scratch per worker: after the first tree
                        // sizes it, node expansion allocates nothing.
                        let mut scratch = FitScratch::new();
                        chunk
                            .into_iter()
                            .map(|(i, mut rng)| {
                                (i, scratch.fit_tree(m, y, bootstrap, tree_cfg, &mut rng))
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().unwrap())
                .collect()
        });
        fitted.sort_by_key(|&(i, _)| i);
        Ok(Forest {
            trees: fitted.into_iter().map(|(_, t)| t).collect(),
            n_features: m.n_features(),
            config: config.clone(),
        })
    }

    /// Single-threaded [`Forest::fit_matrix`].
    pub fn fit_matrix_sequential(
        m: &TrainMatrix,
        y: &[f64],
        config: &ForestConfig,
    ) -> Result<Forest, FitError> {
        let (tree_cfg, rngs) = Self::prepare(m, y, config)?;
        let mut scratch = FitScratch::new();
        let trees: Vec<Tree> = rngs
            .into_iter()
            .map(|mut rng| scratch.fit_tree(m, y, config.bootstrap, &tree_cfg, &mut rng))
            .collect();
        Ok(Forest {
            trees,
            n_features: m.n_features(),
            config: config.clone(),
        })
    }

    /// The seed per-node-sort algorithm, retained as the bit-identity
    /// oracle for the presorted-column fast path: same RNG fork order,
    /// same bootstrap draws (sorted into the shared canonical order), one
    /// stable `total_cmp` sort per candidate feature per node.
    pub fn fit_reference(
        x: &[Vec<f64>],
        y: &[f64],
        config: &ForestConfig,
    ) -> Result<Forest, FitError> {
        let (n, d) = train::validate_rows(x)?;
        config.validate()?;
        train::validate_targets(n, y)?;
        let tree_cfg = Self::tree_config(d, config);
        let mut rng = Pcg64::new(config.seed);
        let trees: Vec<Tree> = (0..config.n_trees)
            .map(|_| {
                let mut rng = rng.fork();
                Self::fit_one_tree_reference(x, y, n, config.bootstrap, &tree_cfg, &mut rng)
            })
            .collect();
        Ok(Forest {
            trees,
            n_features: d,
            config: config.clone(),
        })
    }

    /// Shared fit setup: validate config and targets, derive the tree
    /// config, and fork one RNG per tree from the seed generator
    /// (sequential order — identical across all fit entry points).
    fn prepare(
        m: &TrainMatrix,
        y: &[f64],
        config: &ForestConfig,
    ) -> Result<(TreeConfig, Vec<Pcg64>), FitError> {
        config.validate()?;
        m.validate_targets(y)?;
        let tree_cfg = Self::tree_config(m.n_features(), config);
        let mut rng = Pcg64::new(config.seed);
        let rngs: Vec<Pcg64> = (0..config.n_trees).map(|_| rng.fork()).collect();
        Ok((tree_cfg, rngs))
    }

    fn tree_config(d: usize, config: &ForestConfig) -> TreeConfig {
        let max_features = ((d as f64 * config.feature_fraction).ceil() as usize).clamp(1, d);
        TreeConfig {
            max_depth: config.max_depth,
            min_samples_leaf: config.min_samples_leaf,
            min_samples_split: config.min_samples_split,
            max_features: Some(max_features),
        }
    }

    /// Fit one reference tree from its private RNG (bootstrap draw + split
    /// sampling). The bootstrap draw is sorted ascending — the canonical
    /// sample order shared with the fast path's multiplicity counts; the
    /// draw itself consumes the RNG in the original order, so both paths
    /// see identical generator states.
    fn fit_one_tree_reference(
        x: &[Vec<f64>],
        y: &[f64],
        n: usize,
        bootstrap: bool,
        tree_cfg: &TreeConfig,
        rng: &mut Pcg64,
    ) -> Tree {
        let mut indices: Vec<usize> = if bootstrap {
            (0..n).map(|_| rng.gen_range(n)).collect()
        } else {
            (0..n).collect()
        };
        indices.sort_unstable();
        Tree::fit(x, y, &indices, tree_cfg, rng)
    }

    /// Predict one row (mean over trees). This is the scalar *reference*
    /// path; hot loops compile the forest once
    /// ([`Forest::compile`]) and answer whole row batches through
    /// [`CompiledForest::predict_rows`](crate::engine::CompiledForest),
    /// which is bit-identical by construction.
    pub fn predict(&self, row: &[f64]) -> f64 {
        debug_assert_eq!(row.len(), self.n_features);
        let sum: f64 = self.trees.iter().map(|t| t.predict(row)).sum();
        sum / self.trees.len() as f64
    }

    /// Predict many rows (scalar reference; see [`Forest::predict`]).
    pub fn predict_batch(&self, rows: &[Vec<f64>]) -> Vec<f64> {
        rows.iter().map(|r| self.predict(r)).collect()
    }

    /// Flatten into the contiguous SoA layout served by the
    /// `PredictionEngine` (batched traversal, parallel row chunks). This
    /// is the retained branchy reference walker; batch-heavy callers use
    /// [`Forest::compile_blocked`].
    pub fn compile(&self) -> crate::engine::CompiledForest {
        crate::engine::CompiledForest::compile(self)
    }

    /// Compile into the branch-free blocked executor
    /// ([`BlockedForest`](crate::engine::BlockedForest)) — the batched
    /// inference fast path behind the engine, `cmd_predict` sweeps and
    /// the experiment oracles. Bit-identical to [`Forest::predict`]
    /// (`rust/tests/predict_equivalence.rs`).
    pub fn compile_blocked(&self) -> crate::engine::BlockedForest {
        crate::engine::BlockedForest::compile(self)
    }

    /// Mean absolute percentage error on a labelled set (the paper's
    /// error metric).
    pub fn mape(&self, x: &[Vec<f64>], y: &[f64]) -> f64 {
        stats::mape(&self.predict_batch(x), y)
    }

    /// Split-frequency feature importance (how often each feature is used
    /// as a split, weighted by node sample share ≈ 1/2^depth proxy: we use
    /// plain counts which is sufficient for reporting).
    pub fn feature_importance(&self) -> Vec<f64> {
        let mut counts = vec![0.0f64; self.n_features];
        for t in &self.trees {
            for n in &t.nodes {
                if !n.is_leaf() {
                    counts[n.feature as usize] += 1.0;
                }
            }
        }
        let total: f64 = counts.iter().sum::<f64>().max(1.0);
        counts.iter_mut().for_each(|c| *c /= total);
        counts
    }

    // ---------- persistence ----------

    pub fn to_json(&self) -> Json {
        let trees: Vec<Json> = self
            .trees
            .iter()
            .map(|t| {
                Json::obj(vec![
                    (
                        "feature",
                        Json::arr_usize(
                            &t.nodes
                                .iter()
                                .map(|n| {
                                    if n.is_leaf() {
                                        usize::MAX >> 1 // sentinel that survives f64
                                    } else {
                                        n.feature as usize
                                    }
                                })
                                .collect::<Vec<_>>(),
                        ),
                    ),
                    (
                        "threshold",
                        Json::arr_f64(
                            &t.nodes
                                .iter()
                                .map(|n| if n.is_leaf() { 1e300 } else { n.threshold })
                                .collect::<Vec<_>>(),
                        ),
                    ),
                    (
                        "left",
                        Json::arr_usize(
                            &t.nodes.iter().map(|n| n.left as usize).collect::<Vec<_>>(),
                        ),
                    ),
                    (
                        "right",
                        Json::arr_usize(
                            &t.nodes.iter().map(|n| n.right as usize).collect::<Vec<_>>(),
                        ),
                    ),
                    (
                        "value",
                        Json::arr_f64(
                            &t.nodes.iter().map(|n| n.value).collect::<Vec<_>>(),
                        ),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("n_features", Json::Num(self.n_features as f64)),
            ("n_trees", Json::Num(self.trees.len() as f64)),
            ("trees", Json::Arr(trees)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Forest, String> {
        let n_features = j
            .get("n_features")
            .and_then(Json::as_usize)
            .ok_or("missing n_features")?;
        let trees_j = j.get("trees").and_then(Json::as_arr).ok_or("missing trees")?;
        let leaf_sentinel = (usize::MAX >> 1) as f64;
        let mut trees = Vec::new();
        for tj in trees_j {
            let feats = tj.get("feature").and_then(Json::f64_vec).ok_or("feature")?;
            let thr = tj.get("threshold").and_then(Json::f64_vec).ok_or("threshold")?;
            let left = tj.get("left").and_then(Json::f64_vec).ok_or("left")?;
            let right = tj.get("right").and_then(Json::f64_vec).ok_or("right")?;
            let value = tj.get("value").and_then(Json::f64_vec).ok_or("value")?;
            let n = feats.len();
            if [thr.len(), left.len(), right.len(), value.len()] != [n, n, n, n] {
                return Err("ragged tree arrays".into());
            }
            let nodes: Vec<TreeNode> = (0..n)
                .map(|i| {
                    let is_leaf = feats[i] >= leaf_sentinel;
                    TreeNode {
                        feature: if is_leaf { u32::MAX } else { feats[i] as u32 },
                        threshold: if is_leaf { f64::INFINITY } else { thr[i] },
                        left: left[i] as u32,
                        right: right[i] as u32,
                        value: value[i],
                    }
                })
                .collect();
            trees.push(Tree { nodes });
        }
        Ok(Forest {
            trees,
            n_features,
            config: ForestConfig::default(),
        })
    }

    // ---------- tensor export for the Pallas / XLA inference kernel ----------

    /// Export as fixed-shape arrays: every tree padded to the same node
    /// count, leaves self-looping, thresholds +inf at leaves so iterative
    /// `idx = x[feat] <= thr ? left : right` traversal is stable at any
    /// fixed depth ≥ max tree depth. Layout matches
    /// `python/compile/kernels/forest.py`.
    ///
    /// Derived from the same compiled slab layout the native batched path
    /// uses (`CompiledForest::to_tensors`), so the XLA artifact and the
    /// `PredictionEngine` serve one forest representation. Note the
    /// [`ForestTensors`] quantization contract: thresholds/values downcast
    /// to `f32`, so the artifact is *not* bit-identical to the native f64
    /// executors.
    pub fn to_tensors(&self) -> ForestTensors {
        self.compile().to_tensors()
    }
}

/// Fixed-shape forest arrays for XLA execution (row-major `[tree, node]`).
///
/// **Quantization contract.** Thresholds and leaf values are stored as
/// `f32` (the Pallas kernel's element type — see
/// `python/compile/kernels/forest.py`), and traversal compares
/// `row[f] as f32 <= threshold`. The `f64 → f32` cast is **lossy by
/// design**: rows within one f32 ulp of a split threshold may take the
/// other branch than the native `f64` paths, and leaf values round to the
/// nearest f32. Consumers needing bit-identity to [`Forest::predict`] must
/// use the native executors
/// ([`CompiledForest`](crate::engine::CompiledForest),
/// [`BlockedForest`](crate::engine::BlockedForest)) — the tensor artifact
/// trades that for a fixed-shape fp32 kernel layout. The exact rounding
/// behaviour is pinned by the
/// `tensor_quantization_contract_pins_lossy_f32_cast` test below.
#[derive(Clone, Debug)]
pub struct ForestTensors {
    pub n_trees: usize,
    pub n_nodes: usize,
    /// Maximum tree depth (number of traversal iterations needed).
    pub depth: usize,
    pub feature: Vec<i32>,
    pub threshold: Vec<f32>,
    pub left: Vec<i32>,
    pub right: Vec<i32>,
    pub value: Vec<f32>,
}

impl ForestTensors {
    /// Reference traversal over the padded arrays (must match both the
    /// Rust `Forest::predict` and the Pallas kernel numerics).
    pub fn predict(&self, row: &[f64], iterations: usize) -> f64 {
        let mut acc = 0.0f64;
        for t in 0..self.n_trees {
            let base = t * self.n_nodes;
            let mut idx = 0usize;
            for _ in 0..iterations {
                let f = self.feature[base + idx] as usize;
                let go_left = (row[f] as f32) <= self.threshold[base + idx];
                idx = if go_left {
                    self.left[base + idx] as usize
                } else {
                    self.right[base + idx] as usize
                };
            }
            acc += self.value[base + idx] as f64;
        }
        acc / self.n_trees as f64
    }

    /// Batched reference traversal: many rows through each padded tree in
    /// turn (the tree's arrays stay cache-resident across the row batch —
    /// the same schedule `CompiledForest::predict_rows` and the Pallas
    /// kernel's grid use). Accumulation order matches
    /// [`ForestTensors::predict`], so results are bit-identical to the
    /// per-row path.
    pub fn predict_rows(&self, rows: &[Vec<f64>], iterations: usize) -> Vec<f64> {
        let mut out = vec![0.0f64; rows.len()];
        for t in 0..self.n_trees {
            let base = t * self.n_nodes;
            for (row, acc) in rows.iter().zip(out.iter_mut()) {
                let mut idx = 0usize;
                for _ in 0..iterations {
                    let f = self.feature[base + idx] as usize;
                    let go_left = (row[f] as f32) <= self.threshold[base + idx];
                    idx = if go_left {
                        self.left[base + idx] as usize
                    } else {
                        self.right[base + idx] as usize
                    };
                }
                *acc += self.value[base + idx] as f64;
            }
        }
        let nt = self.n_trees as f64;
        out.iter_mut().for_each(|v| *v /= nt);
        out
    }

    /// Pad the node dimension up to `nodes` (for fixed-shape artifacts).
    pub fn pad_nodes_to(&mut self, nodes: usize) {
        assert!(nodes >= self.n_nodes);
        if nodes == self.n_nodes {
            return;
        }
        let nt = self.n_trees;
        let old = self.n_nodes;
        let mut feature = vec![0i32; nt * nodes];
        let mut threshold = vec![f32::INFINITY; nt * nodes];
        let mut left = vec![0i32; nt * nodes];
        let mut right = vec![0i32; nt * nodes];
        let mut value = vec![0f32; nt * nodes];
        for t in 0..nt {
            for n in 0..old {
                feature[t * nodes + n] = self.feature[t * old + n];
                threshold[t * nodes + n] = self.threshold[t * old + n];
                left[t * nodes + n] = self.left[t * old + n];
                right[t * nodes + n] = self.right[t * old + n];
                value[t * nodes + n] = self.value[t * old + n];
            }
            for n in old..nodes {
                left[t * nodes + n] = n as i32;
                right[t * nodes + n] = n as i32;
            }
        }
        self.feature = feature;
        self.threshold = threshold;
        self.left = left;
        self.right = right;
        self.value = value;
        self.n_nodes = nodes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        // y = 2*x0 + 10*step(x1>0.5) + x2*x0 + noise
        let mut rng = Pcg64::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.uniform(0.0, 10.0);
            let b = rng.next_f64();
            let c = rng.uniform(0.0, 2.0);
            x.push(vec![a, b, c]);
            y.push(2.0 * a + if b > 0.5 { 10.0 } else { 0.0 } + c * a + rng.normal() * 0.1);
        }
        (x, y)
    }

    #[test]
    fn forest_beats_mean_predictor() {
        let (x, y) = synth(400, 1);
        let (xt, yt) = synth(100, 2);
        let cfg = ForestConfig {
            n_trees: 30,
            ..Default::default()
        };
        let f = Forest::fit(&x, &y, &cfg).unwrap();
        let pred = f.predict_batch(&xt);
        let r2 = stats::r_squared(&pred, &yt);
        assert!(r2 > 0.95, "r2 = {r2}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = synth(100, 3);
        let cfg = ForestConfig {
            n_trees: 5,
            seed: 42,
            ..Default::default()
        };
        let f1 = Forest::fit(&x, &y, &cfg).unwrap();
        let f2 = Forest::fit(&x, &y, &cfg).unwrap();
        assert_eq!(f1.predict(&x[0]), f2.predict(&x[0]));
    }

    #[test]
    fn fit_rejects_invalid_configs_and_inputs() {
        let (x, y) = synth(30, 20);
        let bad_trees = ForestConfig {
            n_trees: 0,
            ..Default::default()
        };
        assert!(matches!(
            Forest::fit(&x, &y, &bad_trees),
            Err(FitError::InvalidConfig(_))
        ));
        for ff in [0.0, -0.5, 1.5, f64::NAN] {
            let bad_ff = ForestConfig {
                feature_fraction: ff,
                ..Default::default()
            };
            assert!(matches!(
                Forest::fit(&x, &y, &bad_ff),
                Err(FitError::InvalidConfig(_))
            ));
        }
        let bad_leaf = ForestConfig {
            min_samples_leaf: 0,
            ..Default::default()
        };
        assert!(matches!(
            Forest::fit(&x, &y, &bad_leaf),
            Err(FitError::InvalidConfig(_))
        ));
        // The reference path applies the same validation.
        assert!(matches!(
            Forest::fit_reference(&x, &y, &bad_leaf),
            Err(FitError::InvalidConfig(_))
        ));

        let cfg = ForestConfig::default();
        assert_eq!(
            Forest::fit(&[], &[], &cfg).unwrap_err(),
            FitError::EmptyTrainingSet
        );
        let mut x_nan = x.clone();
        x_nan[3][1] = f64::NAN;
        assert!(matches!(
            Forest::fit(&x_nan, &y, &cfg),
            Err(FitError::NonFiniteFeature { row: 3, feature: 1, .. })
        ));
        let mut y_inf = y.clone();
        y_inf[5] = f64::NEG_INFINITY;
        assert!(matches!(
            Forest::fit(&x, &y_inf, &cfg),
            Err(FitError::NonFiniteTarget { row: 5, .. })
        ));
        assert!(matches!(
            Forest::fit(&x, &y[..y.len() - 1], &cfg),
            Err(FitError::TargetLength { .. })
        ));
    }

    #[test]
    fn predictions_bounded_by_target_range() {
        let (x, y) = synth(200, 4);
        let f = Forest::fit(&x, &y, &ForestConfig::default()).unwrap();
        let lo = y.iter().cloned().fold(f64::MAX, f64::min);
        let hi = y.iter().cloned().fold(f64::MIN, f64::max);
        for row in &x {
            let p = f.predict(row);
            assert!(p >= lo - 1e-9 && p <= hi + 1e-9);
        }
    }

    #[test]
    fn json_roundtrip_preserves_predictions() {
        let (x, y) = synth(150, 5);
        let cfg = ForestConfig {
            n_trees: 10,
            ..Default::default()
        };
        let f = Forest::fit(&x, &y, &cfg).unwrap();
        let j = f.to_json().to_string();
        let f2 = Forest::from_json(&Json::parse(&j).unwrap()).unwrap();
        for row in x.iter().take(20) {
            assert!((f.predict(row) - f2.predict(row)).abs() < 1e-9);
        }
    }

    #[test]
    fn tensor_export_matches_forest() {
        let (x, y) = synth(150, 6);
        let cfg = ForestConfig {
            n_trees: 8,
            max_depth: 9,
            ..Default::default()
        };
        let f = Forest::fit(&x, &y, &cfg).unwrap();
        let t = f.to_tensors();
        assert!(t.depth <= 10);
        for row in x.iter().take(30) {
            let a = f.predict(row);
            let b = t.predict(row, t.depth);
            assert!(
                (a - b).abs() / a.abs().max(1.0) < 1e-5,
                "forest {a} vs tensors {b}"
            );
        }
    }

    #[test]
    fn tensor_padding_preserves_predictions() {
        let (x, y) = synth(120, 7);
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig {
                n_trees: 6,
                ..Default::default()
            },
        )
        .unwrap();
        let mut t = f.to_tensors();
        let before: Vec<f64> = x.iter().take(10).map(|r| t.predict(r, t.depth)).collect();
        t.pad_nodes_to(t.n_nodes + 37);
        let after: Vec<f64> = x.iter().take(10).map(|r| t.predict(r, t.depth)).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn tensor_quantization_contract_pins_lossy_f32_cast() {
        // A split threshold that is not representable in f32: a row one
        // f64 ulp above it still quantizes onto the threshold's f32, so
        // the tensor path takes the other branch than the f64 forest.
        // This is the documented ForestTensors contract — the native
        // executors (CompiledForest, BlockedForest) are exempt.
        let split = TreeNode {
            feature: 0,
            threshold: 0.3,
            left: 1,
            right: 2,
            value: 0.0,
        };
        let lo = TreeNode {
            feature: u32::MAX,
            threshold: f64::INFINITY,
            left: 1,
            right: 1,
            value: 1.0 / 3.0,
        };
        let hi = TreeNode {
            feature: u32::MAX,
            threshold: f64::INFINITY,
            left: 2,
            right: 2,
            value: 2.0 / 3.0,
        };
        let f = Forest {
            trees: vec![Tree {
                nodes: vec![split, lo, hi],
            }],
            n_features: 1,
            config: ForestConfig::default(),
        };
        let t = f.to_tensors();
        // The cast itself is pinned: nearest-f32 rounding, lossy for
        // values with no exact f32 representation.
        assert_eq!(t.threshold[0], 0.3f64 as f32);
        assert_eq!(t.value[1], (1.0f64 / 3.0) as f32);
        assert_ne!(f64::from(t.value[1]), 1.0 / 3.0);
        // One f64 ulp above the threshold: the f64 forest goes right…
        let row = [0.300_000_000_000_000_04_f64];
        assert!(row[0] > 0.3);
        assert_eq!(f.predict(&row).to_bits(), (2.0f64 / 3.0).to_bits());
        // …but row and threshold collapse onto the same f32, so the
        // quantized comparison `row <= threshold` sends the tensors left.
        let quantized = t.predict(&row, t.depth);
        assert_eq!(quantized.to_bits(), f64::from((1.0f64 / 3.0) as f32).to_bits());
        // The native blocked path stays bit-identical to the f64 forest.
        let blocked = f.compile_blocked().predict_rows(&[row.to_vec()]);
        assert_eq!(blocked[0].to_bits(), f.predict(&row).to_bits());
    }

    #[test]
    fn extra_iterations_are_stable_at_leaves() {
        let (x, y) = synth(100, 8);
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig {
                n_trees: 4,
                ..Default::default()
            },
        )
        .unwrap();
        let t = f.to_tensors();
        for row in x.iter().take(10) {
            assert_eq!(t.predict(row, t.depth), t.predict(row, t.depth + 5));
        }
    }

    #[test]
    fn feature_importance_finds_relevant_features() {
        let (x, y) = synth(300, 9);
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig {
                n_trees: 20,
                ..Default::default()
            },
        )
        .unwrap();
        let imp = f.feature_importance();
        assert_eq!(imp.len(), 3);
        // x0 drives most of the variance.
        assert!(imp[0] > imp[2], "importances: {imp:?}");
        assert!((imp.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mape_on_train_small() {
        // Offset targets away from zero — MAPE is undefined near 0 (the
        // paper's Γ/Φ are always strictly positive and large).
        let (x, mut y) = synth(300, 10);
        for v in &mut y {
            *v += 100.0;
        }
        let f = Forest::fit(&x, &y, &ForestConfig::default()).unwrap();
        let err = f.mape(&x, &y);
        assert!(err < 3.0, "train MAPE = {err}");
    }
}
