//! Presorted-column training fast path.
//!
//! The seed tree builder re-sorted a node's index list for **every
//! candidate feature at every node** (O(nodes × features × n log n)
//! comparison sorts through row-major `Vec<Vec<f64>>` indirection) and
//! allocated fresh `sorted`/`left`/`right` vectors per node. This module
//! applies the arena/overlay playbook to model fitting: compile the
//! training set once into a [`TrainMatrix`] — column-major feature storage
//! plus one presorted index array per feature — share it read-only across
//! all trees and threads, and expand nodes with nothing but linear scans
//! over reusable per-thread [`FitScratch`] buffers.
//!
//! # Determinism contract
//!
//! `Forest::fit` on this path is **node-for-node bit-identical** to the
//! retained per-node-sort reference (`Forest::fit_reference`), asserted by
//! `rust/tests/fit_equivalence.rs`. Floating-point accumulation order is
//! part of that contract, so both paths scan a node's samples in one
//! canonical order:
//!
//! - a node's sample multiset is enumerated in **ascending row id** order,
//!   bootstrap duplicates adjacent (the reference sorts its bootstrap draw;
//!   this path keeps per-row multiplicity counts);
//! - a candidate feature's samples are scanned in **(feature value, row
//!   id)** order — `f64::total_cmp`, ties broken by row id (the reference
//!   stable-sorts the ascending list afresh per candidate; this path
//!   filters the globally presorted column by node membership);
//! - score ties keep the first candidate in sampled order and the earliest
//!   scan position (strict `<` on the SSE), exactly as the reference loop.
//!
//! Partitioning a node's per-feature index segments stably by split side
//! preserves both orders for the children, so no re-sorting ever happens
//! after the single presort in [`TrainMatrix`] construction.

use crate::forest::tree::{Tree, TreeConfig, TreeNode};
use crate::util::rng::Pcg64;

/// Why a forest could not be fitted. Raised up front — fitting never
/// panics mid-sort on malformed inputs or silently clamps a bad config.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum FitError {
    #[error("empty training set")]
    EmptyTrainingSet,
    #[error("training rows have zero features")]
    NoFeatures,
    #[error("training row {row} has {len} features, expected {expected}")]
    RaggedRow {
        row: usize,
        len: usize,
        expected: usize,
    },
    #[error("feature matrix has {rows} rows but target vector has {targets}")]
    TargetLength { rows: usize, targets: usize },
    #[error("non-finite feature value {value} at row {row}, feature {feature}")]
    NonFiniteFeature {
        row: usize,
        feature: usize,
        value: f64,
    },
    #[error("non-finite target value {value} at row {row}")]
    NonFiniteTarget { row: usize, value: f64 },
    #[error("invalid forest config: {0}")]
    InvalidConfig(String),
}

/// A training set compiled for fast tree construction: column-major
/// feature storage plus one stable presorted row-index array per feature
/// (`f64::total_cmp`, ties by row id). Built once per fit — or once per
/// *dataset*: the matrix is target-agnostic, so one matrix serves the Γ
/// fit, the Φ fit and any future attribute forest
/// ([`Forest::fit_matrix`](crate::forest::Forest::fit_matrix)).
///
/// Shared read-only across all trees and worker threads.
#[derive(Clone, Debug)]
pub struct TrainMatrix {
    n: usize,
    d: usize,
    /// Column-major values: `cols[f * n + i]` = feature `f` of row `i`.
    cols: Vec<f64>,
    /// Presorted row ids: `order[f * n ..][..n]` lists rows in
    /// (value, row id) order for feature `f`.
    order: Vec<u32>,
}

impl TrainMatrix {
    /// Compile a row-major feature matrix. Validates shape and rejects
    /// non-finite values with a named error.
    pub fn from_rows(x: &[Vec<f64>]) -> Result<TrainMatrix, FitError> {
        Self::from_row_iter(x.iter().map(|r| r.as_slice()))
    }

    /// Compile from borrowed feature rows without materialising a
    /// row-major copy (the `Dataset::x()` clone the seed fit paid twice
    /// per experiment).
    pub fn from_row_iter<'a, I>(rows: I) -> Result<TrainMatrix, FitError>
    where
        I: ExactSizeIterator<Item = &'a [f64]>,
    {
        let n = rows.len();
        if n == 0 {
            return Err(FitError::EmptyTrainingSet);
        }
        assert!(n <= u32::MAX as usize, "training set exceeds u32 row ids");
        let mut d = 0usize;
        let mut cols: Vec<f64> = Vec::new();
        for (i, row) in rows.enumerate() {
            if i == 0 {
                d = row.len();
                if d == 0 {
                    return Err(FitError::NoFeatures);
                }
                cols = vec![0.0; d * n];
            } else if row.len() != d {
                return Err(FitError::RaggedRow {
                    row: i,
                    len: row.len(),
                    expected: d,
                });
            }
            for (f, &v) in row.iter().enumerate() {
                if !v.is_finite() {
                    return Err(FitError::NonFiniteFeature {
                        row: i,
                        feature: f,
                        value: v,
                    });
                }
                cols[f * n + i] = v;
            }
        }
        let mut order = vec![0u32; d * n];
        for f in 0..d {
            let col = &cols[f * n..(f + 1) * n];
            let seg = &mut order[f * n..(f + 1) * n];
            for (k, slot) in seg.iter_mut().enumerate() {
                *slot = k as u32;
            }
            // Stable sort over ascending row ids ⇒ (value, row id) order.
            seg.sort_by(|&a, &b| col[a as usize].total_cmp(&col[b as usize]));
        }
        Ok(TrainMatrix { n, d, cols, order })
    }

    pub fn n_rows(&self) -> usize {
        self.n
    }

    pub fn n_features(&self) -> usize {
        self.d
    }

    /// Feature column `f` as a contiguous slice (indexed by row id).
    pub fn col(&self, f: usize) -> &[f64] {
        &self.cols[f * self.n..(f + 1) * self.n]
    }

    /// Row ids in (value, row id) order for feature `f`.
    pub fn order(&self, f: usize) -> &[u32] {
        &self.order[f * self.n..(f + 1) * self.n]
    }

    /// Check a target vector against this matrix (length + finiteness).
    pub fn validate_targets(&self, y: &[f64]) -> Result<(), FitError> {
        validate_targets(self.n, y)
    }
}

/// Validate a row-major feature matrix without compiling it (the reference
/// path's entry check — same errors as [`TrainMatrix::from_rows`]).
pub(crate) fn validate_rows(x: &[Vec<f64>]) -> Result<(usize, usize), FitError> {
    if x.is_empty() {
        return Err(FitError::EmptyTrainingSet);
    }
    let d = x[0].len();
    if d == 0 {
        return Err(FitError::NoFeatures);
    }
    for (i, row) in x.iter().enumerate() {
        if row.len() != d {
            return Err(FitError::RaggedRow {
                row: i,
                len: row.len(),
                expected: d,
            });
        }
        for (f, &v) in row.iter().enumerate() {
            if !v.is_finite() {
                return Err(FitError::NonFiniteFeature {
                    row: i,
                    feature: f,
                    value: v,
                });
            }
        }
    }
    Ok((x.len(), d))
}

pub(crate) fn validate_targets(n: usize, y: &[f64]) -> Result<(), FitError> {
    if y.len() != n {
        return Err(FitError::TargetLength {
            rows: n,
            targets: y.len(),
        });
    }
    for (i, &v) in y.iter().enumerate() {
        if !v.is_finite() {
            return Err(FitError::NonFiniteTarget { row: i, value: v });
        }
    }
    Ok(())
}

/// Reusable per-thread buffers for tree construction. After the first tree
/// sizes them, steady-state node expansion allocates nothing: membership
/// marks, partition buffers and the candidate-feature shuffle all live
/// here, and bootstrap duplicate rows are per-row multiplicity counts
/// rather than duplicated indices.
#[derive(Default)]
pub struct FitScratch {
    /// Bootstrap multiplicity per row (0 ⇒ not a member of this tree).
    counts: Vec<u32>,
    /// `(d + 1)` row-id arrays of stride `n`: slot `f` holds the tree's
    /// member rows in feature-`f` presorted order, slot `d` ("identity")
    /// holds them in ascending row-id order. A node is a `[lo, hi)`
    /// segment of every slot; splits stable-partition the segments in
    /// place so children need no sorting.
    arrays: Vec<u32>,
    /// Stable-partition spill buffer (right-side rows of one segment).
    tmp: Vec<u32>,
    /// Split side per row for the node currently being partitioned.
    goes_left: Vec<bool>,
    /// Candidate-feature shuffle buffer (replays `Pcg64::sample_indices`
    /// draw-for-draw without its per-node allocation).
    feats: Vec<usize>,
}

impl FitScratch {
    pub fn new() -> FitScratch {
        FitScratch::default()
    }

    fn ensure(&mut self, n: usize, d: usize) {
        self.counts.resize(n, 0);
        self.arrays.resize((d + 1) * n, 0);
        self.tmp.resize(n, 0);
        self.goes_left.resize(n, false);
        self.feats.resize(d, 0);
    }

    /// Fit one tree on the fast path. Consumes the RNG draw-for-draw like
    /// the reference (`n` bootstrap draws, then `sample_indices`-shaped
    /// candidate draws per node) and produces bit-identical nodes.
    pub fn fit_tree(
        &mut self,
        m: &TrainMatrix,
        y: &[f64],
        bootstrap: bool,
        cfg: &TreeConfig,
        rng: &mut Pcg64,
    ) -> Tree {
        let n = m.n_rows();
        let d = m.n_features();
        self.ensure(n, d);

        // Per-row multiplicities: the bootstrap draw order is irrelevant
        // once counted — the canonical enumeration (ascending row id,
        // duplicates adjacent) matches the reference's sorted draw.
        let u = if bootstrap {
            self.counts.iter_mut().for_each(|c| *c = 0);
            for _ in 0..n {
                self.counts[rng.gen_range(n)] += 1;
            }
            // Seed the root segments: each presorted column filtered by
            // the membership mask, plus the ascending identity slot.
            let mut u = 0usize;
            for f in 0..d {
                let mut k = f * n;
                for &r in m.order(f) {
                    if self.counts[r as usize] > 0 {
                        self.arrays[k] = r;
                        k += 1;
                    }
                }
                u = k - f * n;
            }
            let mut k = d * n;
            for r in 0..n as u32 {
                if self.counts[r as usize] > 0 {
                    self.arrays[k] = r;
                    k += 1;
                }
            }
            debug_assert_eq!(k - d * n, u);
            u
        } else {
            self.counts.iter_mut().for_each(|c| *c = 1);
            for f in 0..d {
                self.arrays[f * n..(f + 1) * n].copy_from_slice(m.order(f));
            }
            for (k, slot) in self.arrays[d * n..(d + 1) * n].iter_mut().enumerate() {
                *slot = k as u32;
            }
            n
        };

        let mut nodes = Vec::new();
        let mut ctx = TreeCtx {
            m,
            y,
            cfg,
            counts: &self.counts,
            arrays: &mut self.arrays,
            tmp: &mut self.tmp,
            goes_left: &mut self.goes_left,
            feats: &mut self.feats,
            stride: n,
            d,
        };
        build_fast(&mut ctx, 0, u, n, 0, rng, &mut nodes);
        Tree { nodes }
    }
}

/// Borrowed working state for one tree build (splits the scratch fields so
/// the recursive builder can hold disjoint mutable views).
struct TreeCtx<'a> {
    m: &'a TrainMatrix,
    y: &'a [f64],
    cfg: &'a TreeConfig,
    counts: &'a [u32],
    arrays: &'a mut [u32],
    tmp: &'a mut [u32],
    goes_left: &'a mut [bool],
    feats: &'a mut [usize],
    stride: usize,
    d: usize,
}

fn push_leaf(nodes: &mut Vec<TreeNode>, mean: f64) -> u32 {
    let id = nodes.len() as u32;
    nodes.push(TreeNode {
        feature: u32::MAX,
        threshold: f64::INFINITY,
        left: id,
        right: id,
        value: mean,
    });
    id
}

/// Expand the node covering segment `[lo, hi)` (distinct member rows;
/// `n_samples` counts bootstrap duplicates). Mirrors the reference `build`
/// decision-for-decision: same leaf conditions, same RNG consumption, same
/// scan order, same floating-point expression sequence — returning the
/// same node ids in the same DFS pre-order.
#[allow(clippy::too_many_arguments)]
fn build_fast(
    ctx: &mut TreeCtx,
    lo: usize,
    hi: usize,
    n_samples: usize,
    depth: usize,
    rng: &mut Pcg64,
    nodes: &mut Vec<TreeNode>,
) -> u32 {
    let (m, y, cfg) = (ctx.m, ctx.y, ctx.cfg);
    let (stride, d) = (ctx.stride, ctx.d);
    let id_base = d * stride;

    // Node mean in canonical order (ascending row id, duplicates adjacent)
    // — the reference's `indices.iter().map(|&i| y[i]).sum()` sequence.
    let mut sum = 0.0;
    for k in lo..hi {
        let r = ctx.arrays[id_base + k] as usize;
        let yv = y[r];
        for _ in 0..ctx.counts[r] {
            sum += yv;
        }
    }
    let mean = sum / n_samples as f64;

    if depth >= cfg.max_depth
        || n_samples < cfg.min_samples_split
        || n_samples < 2 * cfg.min_samples_leaf
    {
        return push_leaf(nodes, mean);
    }

    // Candidate feature subset — replays `rng.sample_indices(d, k)`
    // draw-for-draw into the reusable shuffle buffer (and, like the
    // reference, consumes no randomness when every feature is a candidate).
    let n_candidates = cfg.max_features.unwrap_or(d).clamp(1, d);
    for (f, slot) in ctx.feats.iter_mut().enumerate() {
        *slot = f;
    }
    if n_candidates < d {
        for i in 0..n_candidates {
            let j = i + rng.gen_range(d - i);
            ctx.feats.swap(i, j);
        }
    }

    // Variance-minimising split: one forward scan per candidate over its
    // presorted segment. Ties (equal SSE, equal feature values) resolve
    // exactly as the reference's stable per-node sort does.
    let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, score)
    for ci in 0..n_candidates {
        let f = ctx.feats[ci];
        let col = m.col(f);
        let base = f * stride;

        // Totals accumulate in scan order, exactly like the reference
        // summing its per-candidate sorted index list.
        let mut total_sum = 0.0;
        let mut total_sq = 0.0;
        for k in lo..hi {
            let r = ctx.arrays[base + k] as usize;
            let yv = y[r];
            for _ in 0..ctx.counts[r] {
                total_sum += yv;
                total_sq += yv * yv;
            }
        }
        let nf = n_samples as f64;

        let mut left_sum = 0.0;
        let mut left_sq = 0.0;
        let mut taken = 0usize; // samples consumed, duplicates included
        for k in lo..hi {
            let r = ctx.arrays[base + k] as usize;
            let yv = y[r];
            for _ in 0..ctx.counts[r] {
                left_sum += yv;
                left_sq += yv * yv;
            }
            taken += ctx.counts[r] as usize;
            // Duplicates of one row share a feature value, so only the
            // last copy can host a split — the reference `continue`s
            // through the earlier copies on its equal-values check.
            if k + 1 == hi {
                break; // final sample: the reference breaks at nr == 0
            }
            if taken < cfg.min_samples_leaf || n_samples - taken < cfg.min_samples_leaf {
                continue;
            }
            let xv = col[r];
            let xn = col[ctx.arrays[base + k + 1] as usize];
            if xv == xn {
                continue; // can't split between equal feature values
            }
            // Weighted SSE of the two children — the reference's exact
            // expression sequence, term for term.
            let nl = taken as f64;
            let nr = nf - nl;
            let right_sum = total_sum - left_sum;
            let right_sq = total_sq - left_sq;
            let sse = (left_sq - left_sum * left_sum / nl)
                + (right_sq - right_sum * right_sum / nr);
            if best.map_or(true, |(_, _, s)| sse < s) {
                best = Some((f, 0.5 * (xv + xn), sse));
            }
        }
    }

    let Some((feature, threshold, _)) = best else {
        return push_leaf(nodes, mean);
    };

    // Mark split sides once per member row, counting rows and samples per
    // side; a one-sided split (midpoint rounding onto a boundary value)
    // degrades to a leaf exactly like the reference's empty-child check.
    let fcol = m.col(feature);
    let mut left_rows = 0usize;
    let mut left_samples = 0usize;
    for k in lo..hi {
        let r = ctx.arrays[id_base + k] as usize;
        let gl = fcol[r] <= threshold;
        ctx.goes_left[r] = gl;
        if gl {
            left_rows += 1;
            left_samples += ctx.counts[r] as usize;
        }
    }
    if left_samples == 0 || left_samples == n_samples {
        return push_leaf(nodes, mean);
    }

    // Stable-partition every segment (all features + identity) so both
    // children stay in presorted / ascending order.
    for a in 0..=d {
        let base = a * stride;
        let mut w = lo;
        let mut t = 0usize;
        for k in lo..hi {
            let r = ctx.arrays[base + k];
            if ctx.goes_left[r as usize] {
                ctx.arrays[base + w] = r; // w <= k: never clobbers unread slots
                w += 1;
            } else {
                ctx.tmp[t] = r;
                t += 1;
            }
        }
        ctx.arrays[base + w..base + hi].copy_from_slice(&ctx.tmp[..t]);
    }

    let id = nodes.len() as u32;
    nodes.push(TreeNode {
        feature: feature as u32,
        threshold,
        left: 0,
        right: 0,
        value: mean,
    });
    let mid = lo + left_rows;
    let l = build_fast(ctx, lo, mid, left_samples, depth + 1, rng, nodes);
    let r = build_fast(
        ctx,
        mid,
        hi,
        n_samples - left_samples,
        depth + 1,
        rng,
        nodes,
    );
    nodes[id as usize].left = l;
    nodes[id as usize].right = r;
    id
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_compiles_columns_and_presorted_order() {
        let x = vec![
            vec![3.0, 10.0],
            vec![1.0, 30.0],
            vec![2.0, 20.0],
            vec![1.0, 20.0],
        ];
        let m = TrainMatrix::from_rows(&x).unwrap();
        assert_eq!(m.n_rows(), 4);
        assert_eq!(m.n_features(), 2);
        assert_eq!(m.col(0), &[3.0, 1.0, 2.0, 1.0]);
        assert_eq!(m.col(1), &[10.0, 30.0, 20.0, 20.0]);
        // (value, row id) order: equal values keep ascending row ids.
        assert_eq!(m.order(0), &[1, 3, 2, 0]);
        assert_eq!(m.order(1), &[0, 2, 3, 1]);
    }

    #[test]
    fn matrix_rejects_malformed_input() {
        assert_eq!(
            TrainMatrix::from_rows(&[]).unwrap_err(),
            FitError::EmptyTrainingSet
        );
        assert_eq!(
            TrainMatrix::from_rows(&[vec![]]).unwrap_err(),
            FitError::NoFeatures
        );
        let ragged = vec![vec![1.0, 2.0], vec![1.0]];
        assert!(matches!(
            TrainMatrix::from_rows(&ragged).unwrap_err(),
            FitError::RaggedRow {
                row: 1,
                len: 1,
                expected: 2
            }
        ));
        let nan = vec![vec![1.0, f64::NAN]];
        assert!(matches!(
            TrainMatrix::from_rows(&nan).unwrap_err(),
            FitError::NonFiniteFeature {
                row: 0,
                feature: 1,
                ..
            }
        ));
        let m = TrainMatrix::from_rows(&[vec![1.0], vec![2.0]]).unwrap();
        assert!(matches!(
            m.validate_targets(&[1.0]).unwrap_err(),
            FitError::TargetLength {
                rows: 2,
                targets: 1
            }
        ));
        assert!(matches!(
            m.validate_targets(&[1.0, f64::INFINITY]).unwrap_err(),
            FitError::NonFiniteTarget { row: 1, .. }
        ));
    }

    #[test]
    fn fast_tree_matches_reference_tree_without_bootstrap() {
        // Direct Tree-level check; the forest-level oracle lives in
        // rust/tests/fit_equivalence.rs.
        let x: Vec<Vec<f64>> = (0..40)
            .map(|i| vec![(i % 7) as f64, (i % 3) as f64, i as f64 * 0.25])
            .collect();
        let y: Vec<f64> = x.iter().map(|r| 2.0 * r[0] - r[1] + r[2]).collect();
        let m = TrainMatrix::from_rows(&x).unwrap();
        let cfg = TreeConfig {
            max_depth: 6,
            max_features: Some(2),
            ..Default::default()
        };
        let mut scratch = FitScratch::new();
        let mut rng_fast = Pcg64::new(99);
        let fast = scratch.fit_tree(&m, &y, false, &cfg, &mut rng_fast);
        let idx: Vec<usize> = (0..x.len()).collect();
        let mut rng_ref = Pcg64::new(99);
        let reference = Tree::fit(&x, &y, &idx, &cfg, &mut rng_ref);
        assert_eq!(fast.nodes.len(), reference.nodes.len());
        for (a, b) in fast.nodes.iter().zip(&reference.nodes) {
            assert_eq!(a.feature, b.feature);
            assert_eq!(a.threshold.to_bits(), b.threshold.to_bits());
            assert_eq!(a.left, b.left);
            assert_eq!(a.right, b.right);
            assert_eq!(a.value.to_bits(), b.value.to_bits());
        }
        // Identical RNG consumption: both generators sit at the same point.
        assert_eq!(rng_fast.next_u64(), rng_ref.next_u64());
    }
}
