//! The serving layer's headline guarantee (see `rust/src/serve/`): N
//! concurrent evolutionary searches running as tenants of one shared
//! [`PredictionService`] produce results **byte-identical** to N serial
//! single-caller runs — whatever cross-tenant batch coalescing, in-flight
//! deduplication and cache sharing happened along the way. Plus the
//! cache-counter exactness the coalescing relies on: under concurrent
//! forked handles, `hits + misses` always equals the queries submitted
//! and counters only ever move forward.

use std::sync::atomic::{AtomicBool, Ordering};

use perf4sight::engine::{CacheStats, PredictionEngine};
use perf4sight::features::NUM_FEATURES;
use perf4sight::forest::{Forest, ForestConfig};
use perf4sight::ofa::{
    evolutionary_search, Constraints, EsConfig, GenerationOracle, SubnetConfig, Subset,
};
use perf4sight::serve::{PredictionService, ServeConfig, Tenant, TenantStats};
use perf4sight::util::rng::Pcg64;

/// One synthetic forest serving all three attribute roles — the serving
/// layer is attribute-agnostic; model quality is tested elsewhere.
fn tiny_forest() -> Forest {
    let mut rng = Pcg64::new(0x1de27);
    let x: Vec<Vec<f64>> = (0..40)
        .map(|_| (0..NUM_FEATURES).map(|_| rng.uniform(0.0, 1e6)).collect())
        .collect();
    let y: Vec<f64> = x.iter().map(|r| r[1] / 1e3 + r[3] / 1e4 + 100.0).collect();
    Forest::fit(
        &x,
        &y,
        &ForestConfig {
            n_trees: 8,
            max_depth: 6,
            ..Default::default()
        },
    )
    .unwrap()
}

fn engine_of(f: &Forest) -> PredictionEngine {
    PredictionEngine::new(f, f, f)
}

fn small_es(seed: u64) -> EsConfig {
    EsConfig {
        population: 10,
        iterations: 4,
        seed,
        ..Default::default()
    }
}

/// Serial references on fresh engines vs N concurrent tenants of one
/// service, compared through `EsResult::deterministic_bytes`.
fn assert_identity_for(n: usize) {
    let forest = tiny_forest();
    let cons = Constraints::unconstrained();
    let base_seed = 0x51d;
    let serial: Vec<Vec<u8>> = (0..n)
        .map(|i| {
            let mut engine = engine_of(&forest);
            let es = small_es(base_seed + i as u64);
            evolutionary_search(&cons, &es, Subset::City, &mut engine).deterministic_bytes()
        })
        .collect();
    // Deliberately awkward serving knobs: a tiny queue plus a coalesce
    // window that never fits all tenants forces generations to split and
    // mix across drains.
    let serve_cfg = ServeConfig {
        queue_capacity: 2,
        max_coalesce: 3,
    };
    let service = PredictionService::spawn(engine_of(&forest), &serve_cfg);
    let tenants: Vec<Tenant> = (0..n).map(|_| service.tenant()).collect();
    let served: Vec<Vec<u8>> = std::thread::scope(|scope| {
        let handles: Vec<_> = tenants
            .into_iter()
            .enumerate()
            .map(|(i, mut tenant)| {
                let es = small_es(base_seed + i as u64);
                scope.spawn(move || {
                    let r = evolutionary_search(&cons, &es, Subset::City, &mut tenant);
                    r.deterministic_bytes()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("search thread panicked"))
            .collect()
    });
    let stats = service.shutdown();
    assert_eq!(serial, served, "served results must be byte-identical to serial runs");
    assert_eq!(stats.len(), n);
    for s in &stats {
        assert!(s.queries > 0);
        assert_eq!(s.hits() + s.evaluated, s.queries);
    }
}

#[test]
fn one_tenant_is_bit_identical_to_serial() {
    assert_identity_for(1);
}

#[test]
fn four_tenants_are_bit_identical_to_serial() {
    assert_identity_for(4);
}

#[test]
fn eight_tenants_are_bit_identical_to_serial() {
    assert_identity_for(8);
}

#[test]
fn overlapping_tenants_share_every_evaluation() {
    // Four tenants run the *same* search (same seed): whatever the
    // interleaving, the shared cache + in-flight dedup must evaluate each
    // distinct candidate exactly once across the whole fleet.
    let forest = tiny_forest();
    let cons = Constraints::unconstrained();
    let es = small_es(0xabc);
    let mut reference = engine_of(&forest);
    let serial = evolutionary_search(&cons, &es, Subset::City, &mut reference);
    let service = PredictionService::spawn(engine_of(&forest), &ServeConfig::default());
    let tenants: Vec<Tenant> = (0..4).map(|_| service.tenant()).collect();
    let served: Vec<Vec<u8>> = std::thread::scope(|scope| {
        let handles: Vec<_> = tenants
            .into_iter()
            .map(|mut tenant| {
                let es = es.clone();
                scope.spawn(move || {
                    let r = evolutionary_search(&cons, &es, Subset::City, &mut tenant);
                    r.deterministic_bytes()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("search thread panicked"))
            .collect()
    });
    for bytes in &served {
        assert_eq!(*bytes, serial.deterministic_bytes());
    }
    let stats = service.shutdown();
    let agg = TenantStats::aggregate(&stats);
    assert_eq!(agg.queries, 4 * serial.samples as u64);
    assert_eq!(
        agg.evaluated,
        serial.unique_evaluations as u64,
        "each distinct candidate evaluated once across all four tenants"
    );
}

#[test]
fn cache_stats_exact_and_monotone_under_concurrent_forks() {
    const THREADS: usize = 6;
    const GENERATIONS: usize = 12;
    const GEN_SIZE: usize = 10;
    let total_queries = (THREADS * GENERATIONS * GEN_SIZE) as u64;
    // Small capacity so the workload (hundreds of mostly-distinct
    // configs) must evict.
    let forest = tiny_forest();
    let engine = engine_of(&forest).with_cache_capacity(32);
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let sampler_engine = engine.fork();
        let stop_ref = &stop;
        scope.spawn(move || {
            let mut last = CacheStats::default();
            while !stop_ref.load(Ordering::Relaxed) {
                let s = sampler_engine.stats();
                assert!(s.hits >= last.hits, "hits went backwards");
                assert!(s.misses >= last.misses, "misses went backwards");
                assert!(s.evictions >= last.evictions, "evictions went backwards");
                assert!(
                    s.hits + s.misses <= total_queries,
                    "counted more queries than were submitted"
                );
                last = s;
                std::thread::yield_now();
            }
        });
        let workers: Vec<_> = (0..THREADS)
            .map(|t| {
                let mut eng = engine.fork();
                scope.spawn(move || {
                    let mut rng = Pcg64::new(0xbeef + t as u64);
                    for _ in 0..GENERATIONS {
                        let generation: Vec<SubnetConfig> =
                            (0..GEN_SIZE).map(|_| SubnetConfig::sample(&mut rng)).collect();
                        let evals = eng.evaluate_generation(&generation);
                        assert_eq!(evals.len(), GEN_SIZE);
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().expect("worker panicked");
        }
        stop.store(true, Ordering::Relaxed);
    });
    let s = engine.stats();
    assert_eq!(
        s.hits + s.misses,
        total_queries,
        "every submitted query accounted as exactly one hit or miss"
    );
    assert!(s.evictions > 0, "capacity 32 must evict under this workload");
    assert!(s.entries <= 32);
}

#[test]
fn tenant_stats_attribute_cross_tenant_traffic() {
    // Sequential submissions (deterministic drains): tenant a evaluates,
    // tenant b rides the shared cache entirely.
    let forest = tiny_forest();
    let service = PredictionService::spawn(engine_of(&forest), &ServeConfig::default());
    let a = service.tenant();
    let b = service.tenant();
    let mut rng = Pcg64::new(9);
    let generation: Vec<SubnetConfig> = (0..20).map(|_| SubnetConfig::sample(&mut rng)).collect();
    a.submit(&generation);
    b.submit(&generation);
    let sa = a.stats();
    let sb = b.stats();
    assert_eq!(sa.queries, 20);
    assert!(sa.evaluated > 0);
    assert_eq!(sb.queries, 20);
    assert_eq!(sb.evaluated, 0, "tenant b must be served from tenant a's work");
    assert_eq!(sb.hits(), 20);
    let cache = service.cache_stats();
    assert_eq!(cache.hits + cache.misses, 40);
    service.shutdown();
}
