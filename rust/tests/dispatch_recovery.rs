//! Distributed dispatch recovery suite: real worker processes killed,
//! hung and muted mid-shard via the `PERF4SIGHT_FAULT` harness must not
//! cost a campaign — leases expire, shards are reclaimed and retried,
//! and the merged dataset stays bit-identical to the single-process
//! `profile()` path. Plus the local-driver robustness satellites (shard
//! retry with backoff, hung-worker wall-clock timeout).

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use perf4sight::campaign::dispatch::{lease_path, DispatchFile, Lease};
use perf4sight::campaign::{self, CampaignSpec, CoordinatorConfig, RetryPolicy, WorkerConfig};
use perf4sight::pruning::Strategy;
use perf4sight::util::fault::{FAULT_ENV, FAULT_EXIT_CODE};

const EXE: &str = env!("CARGO_BIN_EXE_perf4sight");

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "perf4sight-dispatch-{name}-{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn small_spec(seed: u64) -> CampaignSpec {
    CampaignSpec {
        networks: vec!["squeezenet".into()],
        strategies: vec![Strategy::Random],
        regimes: vec![perf4sight::device::TrainRegime::Vanilla],
        levels: vec![0.0, 0.4],
        batch_sizes: vec![4, 16],
        runs: 1,
        seed,
        device: "tx2".into(),
    }
}

/// Fast test-scale dispatch policy: tight heartbeats and lease timeouts
/// so reclaim paths exercise in milliseconds, with a generous retry
/// budget and idle guard so a slow CI box never flakes.
fn fast_retry() -> RetryPolicy {
    RetryPolicy {
        retries: 3,
        base_ms: 20,
        cap_ms: 200,
    }
}

fn fast_coordinator(shards: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        shards,
        lease_timeout: Duration::from_millis(300),
        poll: Duration::from_millis(25),
        retry: fast_retry(),
        idle_timeout: Some(Duration::from_secs(60)),
    }
}

/// Spawn a real `campaign --dispatch worker` process against `dir`, with
/// an optional fault-injection env. Never inherits a fault plan from the
/// test environment.
fn spawn_worker_cli(dir: &Path, id: &str, fault: Option<&str>) -> Child {
    let mut cmd = Command::new(EXE);
    cmd.arg("campaign")
        .arg("--dispatch")
        .arg("worker")
        .arg("--out-dir")
        .arg(dir)
        .arg("--worker-id")
        .arg(id)
        .arg("--heartbeat-ms")
        .arg("50")
        .arg("--poll-ms")
        .arg("25")
        .arg("--retries")
        .arg("3")
        .arg("--backoff-base-ms")
        .arg("20")
        .arg("--backoff-cap-ms")
        .arg("200")
        .arg("--idle-timeout-ms")
        .arg("60000")
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .env_remove(FAULT_ENV);
    if let Some(plans) = fault {
        cmd.env(FAULT_ENV, plans);
    }
    cmd.spawn().expect("spawning dispatch worker")
}

#[test]
fn lease_claim_is_exclusive_and_owner_checked() {
    let dir = tmpdir("lease");
    let fp = 0xfeed_beef_u64;
    let a = Lease::try_claim(&dir, 0, fp, "alice", 0).unwrap();
    assert!(a.is_some(), "first claim wins");
    let b = Lease::try_claim(&dir, 0, fp, "bob", 0).unwrap();
    assert!(b.is_none(), "second claim loses");

    // Refresh bumps the heartbeat for the owner …
    let mut a = a.unwrap();
    let before = a.beat_ms;
    std::thread::sleep(Duration::from_millis(5));
    assert!(a.refresh(&dir).unwrap());
    assert!(a.beat_ms >= before);
    assert!(!a.expired(Duration::from_secs(60), before + 10));
    assert!(a.expired(Duration::from_millis(1), a.beat_ms + 100));

    // … but a reclaimed lease is never resurrected by a slow heartbeat.
    std::fs::remove_file(lease_path(&dir, 0)).unwrap();
    assert!(!a.refresh(&dir).unwrap(), "reclaimed lease must not refresh");
    let c = Lease::try_claim(&dir, 0, fp, "carol", 1).unwrap().unwrap();
    // Alice's release is owner-checked: it must not evict Carol.
    a.release(&dir).unwrap();
    assert_eq!(
        Lease::load_if_present(&lease_path(&dir, 0)).unwrap(),
        Some(c)
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// THE acceptance scenario: one worker is killed mid-shard, the other
/// stops heartbeating (and stalls past its lease) — the campaign still
/// completes without manual intervention and the merged dataset is
/// byte-identical to single-process profiling.
#[test]
fn killed_and_muted_workers_recover_to_bit_identical_merge() {
    let spec = small_spec(21);
    let dir = tmpdir("acceptance");
    // Whoever first executes shard 0 dies mid-shard (once: its retry
    // passes). Whoever executes shard 1 goes silent and outlives its
    // lease, exercising reclaim of a live-but-unresponsive worker.
    let fault = "mid-shard:exit:once:shard=0,heartbeat:mute:shard=1,mid-shard:stall=700:shard=1";
    let mut workers = vec![
        spawn_worker_cli(&dir, "w0", Some(fault)),
        spawn_worker_cli(&dir, "w1", Some(fault)),
    ];
    let report = campaign::run_coordinator(&spec, &dir, &fast_coordinator(2)).unwrap();
    let statuses: Vec<_> = workers
        .iter_mut()
        .map(|w| w.wait().expect("waiting on worker"))
        .collect();

    assert!(!report.reclaimed.is_empty(), "{report:?}");
    assert!(
        statuses.iter().any(|s| s.code() == Some(FAULT_EXIT_CODE)),
        "one worker must have died of the injected fault: {statuses:?}"
    );
    assert!(
        dir.join("faults").join("mid-shard-shard-0.fired").exists(),
        "the :once marker records the injected kill"
    );
    let merged = campaign::merge(&spec, &dir).unwrap();
    let reference = campaign::profile_campaign(&spec).unwrap();
    assert_eq!(
        merged.to_json().to_string(),
        reference.to_json().to_string(),
        "recovered campaign must be bit-identical to single-process profiling"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Crash in the checkpoint gap (dataset written, manifest not): the shard
/// counts as incomplete, is reclaimed, and re-executes to identical bytes.
#[test]
fn pre_manifest_crash_is_reclaimed_and_reexecuted() {
    let spec = small_spec(23);
    let dir = tmpdir("premanifest");
    let mut workers = vec![
        spawn_worker_cli(&dir, "w0", Some("pre-manifest:exit:once")),
        spawn_worker_cli(&dir, "w1", Some("pre-manifest:exit:once")),
    ];
    let report = campaign::run_coordinator(&spec, &dir, &fast_coordinator(1)).unwrap();
    for w in &mut workers {
        w.wait().expect("waiting on worker");
    }
    assert_eq!(report.reclaimed, vec![0], "{report:?}");
    let merged = campaign::merge(&spec, &dir).unwrap();
    let reference = campaign::profile_campaign(&spec).unwrap();
    assert_eq!(merged.to_json().to_string(), reference.to_json().to_string());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stale_fingerprint_lease_is_a_hard_error() {
    let spec = small_spec(25);
    let dir = tmpdir("stale-lease");
    // A lease left behind by a *different* campaign in the same dir.
    Lease::try_claim(&dir, 0, spec.fingerprint() ^ 1, "ghost", 0)
        .unwrap()
        .unwrap();
    let err = campaign::run_coordinator(&spec, &dir, &fast_coordinator(1)).unwrap_err();
    assert!(err.contains("different campaign"), "{err}");
    assert!(err.contains("shard-0.lease.json"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// A deterministically-failing shard exhausts its retry budget: the
/// coordinator aborts loudly (naming the shard and the budget) and the
/// abort marker stops the worker fleet too.
#[test]
fn exhausted_retry_budget_aborts_campaign_and_fleet() {
    let spec = small_spec(27);
    let dir = tmpdir("budget");
    let mut cfg = fast_coordinator(1);
    cfg.retry.retries = 1; // 2 attempts, both doomed
    let mut worker = {
        let mut cmd = Command::new(EXE);
        cmd.arg("campaign")
            .arg("--dispatch")
            .arg("worker")
            .arg("--out-dir")
            .arg(&dir)
            .arg("--heartbeat-ms")
            .arg("50")
            .arg("--poll-ms")
            .arg("25")
            .arg("--retries")
            .arg("1")
            .arg("--backoff-base-ms")
            .arg("20")
            .arg("--backoff-cap-ms")
            .arg("200")
            .arg("--idle-timeout-ms")
            .arg("60000")
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .env(FAULT_ENV, "mid-shard:error:shard=0");
        cmd.spawn().expect("spawning doomed worker")
    };
    let err = campaign::run_coordinator(&spec, &dir, &cfg).unwrap_err();
    assert!(err.contains("retry budget"), "{err}");
    assert!(err.contains("shard 0"), "{err}");
    assert!(err.contains("injected fault"), "{err}");
    assert!(dir.join("dispatch-abort.json").exists());
    let status = worker.wait().expect("waiting on worker");
    assert!(!status.success(), "abort marker must stop the worker: {status}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Two in-process workers race a one-shard campaign: the lease arbitrates
/// — exactly one executes, both exit cleanly once the campaign drains.
#[test]
fn racing_workers_execute_each_shard_exactly_once() {
    let spec = small_spec(29);
    let dir = tmpdir("race");
    // Pre-announce the mailbox so both workers start claiming instantly.
    campaign::ensure_spec_file(&spec, &dir).unwrap();
    DispatchFile::ensure(&dir, spec.fingerprint(), 1).unwrap();
    let cfg = |id: &str| WorkerConfig {
        worker_id: id.to_string(),
        heartbeat: Duration::from_millis(50),
        poll: Duration::from_millis(5),
        retry: fast_retry(),
        idle_timeout: Some(Duration::from_secs(30)),
    };
    let (ra, rb) = std::thread::scope(|s| {
        let a = s.spawn(|| campaign::run_worker(&dir, &cfg("a")));
        let b = s.spawn(|| campaign::run_worker(&dir, &cfg("b")));
        (a.join().unwrap().unwrap(), b.join().unwrap().unwrap())
    });
    assert_eq!(
        ra.executed.len() + rb.executed.len(),
        1,
        "exactly one claimant executes: {ra:?} {rb:?}"
    );
    assert!(ra.failed.is_empty() && rb.failed.is_empty());
    let merged = campaign::merge(&spec, &dir).unwrap();
    assert_eq!(merged.len(), spec.total_units());
    std::fs::remove_dir_all(&dir).ok();
}

/// Resuming a fully-checkpointed campaign through the coordinator is a
/// no-op: no workers needed, every shard reported as resumed.
#[test]
fn coordinator_resume_of_complete_campaign_needs_no_workers() {
    let spec = small_spec(31);
    let dir = tmpdir("resume");
    let driver = campaign::DriverConfig {
        shards: 2,
        workers: 2,
        mode: campaign::ExecMode::InProcess,
        exe: None,
        worker_timeout: None,
        retry: RetryPolicy::default(),
    };
    campaign::run_campaign(&spec, &dir, &driver).unwrap();
    let mut cfg = fast_coordinator(2);
    cfg.idle_timeout = Some(Duration::from_secs(5));
    let report = campaign::run_coordinator(&spec, &dir, &cfg).unwrap();
    assert_eq!(report.resumed, vec![0, 1]);
    assert!(report.reclaimed.is_empty());
    assert_eq!(report.attempts, vec![0, 0]);
    std::fs::remove_dir_all(&dir).ok();
}

/// Local-driver satellite: `--retries` re-runs a transiently-failing
/// shard with backoff, and the result is still bit-identical.
#[test]
fn local_driver_retries_transient_shard_failure() {
    let spec = small_spec(33);
    let dir = tmpdir("driver-retry");
    let out = Command::new(EXE)
        .arg("campaign")
        .arg("--networks")
        .arg("squeezenet")
        .arg("--levels")
        .arg("0,0.4")
        .arg("--batch-sizes")
        .arg("4,16")
        .arg("--runs")
        .arg("1")
        .arg("--seed")
        .arg("33")
        .arg("--out-dir")
        .arg(&dir)
        .arg("--shards")
        .arg("2")
        .arg("--workers")
        .arg("2")
        .arg("--retries")
        .arg("2")
        .arg("--backoff-base-ms")
        .arg("10")
        .env(FAULT_ENV, "mid-shard:error:once:shard=0")
        .output()
        .expect("running campaign CLI");
    assert!(
        out.status.success(),
        "stdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("(1 retried)"), "{stdout}");
    let saved = std::fs::read_to_string(dir.join("dataset.json")).unwrap();
    let reference = campaign::profile_campaign(&spec).unwrap();
    assert_eq!(saved, reference.to_json().to_string());
    std::fs::remove_dir_all(&dir).ok();
}

/// Local-driver satellite: a hung worker process is killed at the
/// wall-clock timeout. With retries the campaign still completes; with a
/// permanent hang and no retries the error names the timeout.
#[test]
fn hung_worker_is_killed_at_wall_clock_timeout() {
    let dir = tmpdir("hang-recover");
    let grid = |dir: &Path| {
        let mut cmd = Command::new(EXE);
        cmd.arg("campaign")
            .arg("--networks")
            .arg("squeezenet")
            .arg("--levels")
            .arg("0,0.4")
            .arg("--batch-sizes")
            .arg("4")
            .arg("--runs")
            .arg("1")
            .arg("--seed")
            .arg("35")
            .arg("--out-dir")
            .arg(dir)
            .arg("--shards")
            .arg("2")
            .arg("--workers")
            .arg("2");
        cmd
    };
    // Transient hang (once): killed at 1.5 s, the retry completes.
    let out = grid(&dir)
        .arg("--worker-timeout-ms")
        .arg("1500")
        .arg("--retries")
        .arg("1")
        .arg("--backoff-base-ms")
        .arg("10")
        .env(FAULT_ENV, "shard-start:hang:once:shard=1")
        .output()
        .expect("running campaign CLI");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(dir.join("dataset.json").exists());
    std::fs::remove_dir_all(&dir).ok();

    // Permanent hang, no retries: the failure names the kill.
    let dir = tmpdir("hang-fatal");
    let out = grid(&dir)
        .arg("--worker-timeout-ms")
        .arg("400")
        .arg("--retries")
        .arg("0")
        .env(FAULT_ENV, "shard-start:hang:shard=1")
        .output()
        .expect("running campaign CLI");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("timed out"), "{stderr}");
    assert!(stderr.contains("killed"), "{stderr}");
    std::fs::remove_dir_all(&dir).ok();
}
