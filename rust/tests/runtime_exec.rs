//! Runtime integration: the Rust-fitted forest must produce *identical*
//! predictions through the XLA artifact (L1 Pallas kernel path) as through
//! the native Rust traversal, and the AOT train step must reduce the loss.
//! Requires `make artifacts` (skips cleanly if absent).

use perf4sight::forest::Forest;
use perf4sight::runtime::forest_exec::export_forest_config;
use perf4sight::runtime::{ForestExecutor, Runtime, TrainState, TrainStepExecutor};
use perf4sight::util::rng::Pcg64;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if Runtime::artifacts_present(&dir) {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn synth_forest() -> (Forest, Vec<Vec<f64>>) {
    let mut rng = Pcg64::new(42);
    let d = perf4sight::features::NUM_FEATURES;
    let n = 300;
    let x: Vec<Vec<f64>> = (0..n)
        .map(|_| (0..d).map(|_| rng.uniform(0.0, 1e6)).collect())
        .collect();
    let y: Vec<f64> = x
        .iter()
        .map(|r| 1000.0 + 2e-3 * r[3] + if r[10] > 5e5 { 400.0 } else { 0.0 })
        .collect();
    let f = Forest::fit(&x, &y, &export_forest_config()).unwrap();
    (f, x)
}

#[test]
fn forest_artifact_matches_rust_numerics() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu(&dir).unwrap();
    let (forest, x) = synth_forest();
    let exec = ForestExecutor::new(&rt, &forest).unwrap();

    // Batch path (chunked 256 with padding) vs native Rust.
    let rows: Vec<Vec<f64>> = x.iter().take(300).cloned().collect();
    let via_xla = exec.predict_batch(&rows).unwrap();
    for (row, got) in rows.iter().zip(&via_xla) {
        let want = forest.predict(row);
        let rel = (got - want).abs() / want.abs().max(1.0);
        assert!(rel < 1e-4, "xla {got} vs rust {want}");
    }

    // Single-row path.
    let one = exec.predict_one(&rows[0]).unwrap();
    let want = forest.predict(&rows[0]);
    assert!((one - want).abs() / want.abs() < 1e-4);
}

#[test]
fn train_step_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu(&dir).unwrap();
    let exec = TrainStepExecutor::new(&rt).unwrap();
    let mut state = TrainState::init(7);
    let mut rng = Pcg64::new(11);
    let mut first = 0.0;
    let mut last = 0.0;
    for i in 0..25 {
        let (x, y) = perf4sight::runtime::trainstep_exec::synthetic_batch(&mut rng);
        let loss = exec.step(&mut state, &x, &y, 0.1).unwrap();
        assert!(loss.is_finite(), "loss diverged at step {i}");
        if i == 0 {
            first = loss;
        }
        last = loss;
    }
    assert!(
        last < first * 0.8,
        "no descent through AOT artifact: first {first}, last {last}"
    );
}

#[test]
fn manifest_matches_rust_constants() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu(&dir).unwrap();
    let m = rt.manifest().unwrap();
    assert_eq!(
        m.get("num_features").and_then(|j| j.as_usize()),
        Some(perf4sight::features::NUM_FEATURES)
    );
    let forest = m.get("forest").unwrap();
    assert_eq!(forest.get("trees").and_then(|j| j.as_usize()), Some(64));
    assert_eq!(forest.get("nodes").and_then(|j| j.as_usize()), Some(2048));
}
