//! Campaign oracle + resilience suite: sharded, multi-process campaign
//! execution must reproduce the single-process `profile()` path bit for
//! bit (JSON bytes included) at any shard count; the driver must resume
//! after partial failure; and manifest-checked merging must fail loudly on
//! corrupt or stale shard state.

use std::path::PathBuf;

use perf4sight::campaign::{self, CampaignSpec, DriverConfig, ExecMode, RetryPolicy};
use perf4sight::device::Simulator;
use perf4sight::profiler::{profile_sequential, Dataset, ProfileJob};
use perf4sight::pruning::Strategy;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "perf4sight-campaign-{name}-{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn small_spec(networks: &[&str], seed: u64) -> CampaignSpec {
    CampaignSpec {
        networks: networks.iter().map(|s| s.to_string()).collect(),
        strategies: vec![Strategy::Random, Strategy::L1Norm],
        regimes: vec![perf4sight::device::TrainRegime::Vanilla],
        levels: vec![0.0, 0.4],
        batch_sizes: vec![4, 16],
        runs: 2,
        seed,
        device: "tx2".into(),
    }
}

fn json_of(ds: &Dataset) -> String {
    ds.to_json().to_string()
}

/// Fail-fast retry policy: these tests assert on first-error behaviour.
fn no_retry() -> RetryPolicy {
    RetryPolicy {
        retries: 0,
        base_ms: 0,
        cap_ms: 0,
    }
}

fn in_process(shards: usize) -> DriverConfig {
    DriverConfig {
        shards,
        workers: 2,
        mode: ExecMode::InProcess,
        exe: None,
        worker_timeout: None,
        retry: no_retry(),
    }
}

#[test]
fn merged_shards_bit_identical_for_shard_counts_1_3_7() {
    let spec = small_spec(&["squeezenet"], 5);
    let reference = campaign::profile_campaign(&spec).unwrap();

    // Chain the oracle all the way down: the campaign reference equals the
    // original sequential per-level implementation, concatenated in spec
    // order.
    let sim = Simulator::tx2();
    let graph = perf4sight::models::by_name("squeezenet").unwrap();
    let mut sequential = Dataset::default();
    for &strategy in &spec.strategies {
        sequential.extend(profile_sequential(
            &sim,
            &ProfileJob {
                network: "squeezenet",
                graph: &graph,
                strategy,
                regime: perf4sight::device::TrainRegime::Vanilla,
                levels: &spec.levels,
                batch_sizes: &spec.batch_sizes,
                runs: spec.runs,
                seed: spec.seed,
            },
        ));
    }
    assert_eq!(json_of(&reference), json_of(&sequential));

    for shards in [1, 3, 7] {
        let dir = tmpdir(&format!("oracle-{shards}"));
        let run = campaign::run_campaign(&spec, &dir, &in_process(shards)).unwrap();
        assert_eq!(run.executed.len(), run.shards, "shards={shards}");
        let merged = campaign::merge(&spec, &dir).unwrap();
        assert_eq!(json_of(&merged), json_of(&reference), "shards={shards}");
        // merge_dir picks the spec up from disk and agrees.
        let (loaded, merged2) = campaign::merge_dir(&dir).unwrap();
        assert_eq!(loaded.fingerprint(), spec.fingerprint());
        assert_eq!(json_of(&merged2), json_of(&reference));
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn multi_process_campaign_matches_single_process() {
    // ≥2 spawned worker processes over ≥2 zoo networks (the acceptance
    // criterion): the merged dataset is byte-identical JSON to the
    // single-process profile() path.
    let spec = small_spec(&["squeezenet", "mnasnet"], 7);
    let dir = tmpdir("procs");
    let cfg = DriverConfig {
        shards: 4,
        workers: 2,
        mode: ExecMode::Spawn,
        exe: Some(PathBuf::from(env!("CARGO_BIN_EXE_perf4sight"))),
        worker_timeout: None,
        retry: no_retry(),
    };
    let run = campaign::run_campaign(&spec, &dir, &cfg).unwrap();
    assert_eq!(run.executed, vec![0, 1, 2, 3]);
    assert!(run.skipped.is_empty());
    let merged = campaign::merge(&spec, &dir).unwrap();
    let reference = campaign::profile_campaign(&spec).unwrap();
    assert_eq!(merged.len(), spec.total_units());
    assert_eq!(json_of(&merged), json_of(&reference));

    // A second driver run is a no-op resume: everything checkpointed.
    let rerun = campaign::run_campaign(&spec, &dir, &cfg).unwrap();
    assert!(rerun.executed.is_empty());
    assert_eq!(rerun.skipped, vec![0, 1, 2, 3]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_refills_deleted_shard_and_merge_succeeds() {
    let spec = small_spec(&["squeezenet"], 9);
    let dir = tmpdir("resume");
    campaign::run_campaign(&spec, &dir, &in_process(3)).unwrap();

    // A later run can rediscover the checkpointed partition (the CLI's
    // auto-shard default uses this to resume under different parallelism).
    assert_eq!(campaign::existing_shard_count(&dir), Some(3));

    // Simulate a crash that lost one shard's dataset file.
    std::fs::remove_file(dir.join("shard-1.json")).unwrap();
    let run = campaign::run_campaign(&spec, &dir, &in_process(3)).unwrap();
    assert_eq!(run.executed, vec![1]);
    assert_eq!(run.skipped, vec![0, 2]);

    let merged = campaign::merge(&spec, &dir).unwrap();
    let reference = campaign::profile_campaign(&spec).unwrap();
    assert_eq!(json_of(&merged), json_of(&reference));

    // A missing shard (dataset + manifest) makes merge name the gap.
    std::fs::remove_file(dir.join("shard-2.json")).unwrap();
    std::fs::remove_file(dir.join("shard-2.manifest.json")).unwrap();
    let err = campaign::merge(&spec, &dir).unwrap_err();
    assert!(err.contains("incomplete"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_manifest_is_a_clear_error() {
    let spec = small_spec(&["squeezenet"], 11);
    let dir = tmpdir("corrupt");
    campaign::run_campaign(&spec, &dir, &in_process(2)).unwrap();
    std::fs::write(dir.join("shard-0.manifest.json"), "{definitely not json").unwrap();

    let err = campaign::merge(&spec, &dir).unwrap_err();
    assert!(err.contains("corrupt shard manifest"), "{err}");
    assert!(err.contains("shard-0.manifest.json"), "{err}");

    // The driver's resume check refuses to guess as well.
    let err = campaign::run_campaign(&spec, &dir, &in_process(2)).unwrap_err();
    assert!(err.contains("corrupt shard manifest"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stale_shards_from_a_different_spec_are_rejected() {
    let spec = small_spec(&["squeezenet"], 13);
    let dir = tmpdir("stale");
    campaign::run_campaign(&spec, &dir, &in_process(2)).unwrap();

    let mut other = spec.clone();
    other.seed ^= 1;
    // The campaign dir pins its spec: a different spec cannot reuse it …
    let err = campaign::run_campaign(&other, &dir, &in_process(2)).unwrap_err();
    assert!(err.contains("different spec"), "{err}");
    // … and merging against the wrong spec trips the fingerprint check.
    let err = campaign::merge(&other, &dir).unwrap_err();
    assert!(err.contains("fingerprint"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn partition_change_on_partial_dir_is_detected() {
    let spec = small_spec(&["squeezenet"], 17);
    let dir = tmpdir("partition");
    campaign::run_campaign(&spec, &dir, &in_process(3)).unwrap();
    // Same spec, different shard count: the checkpointed manifests no
    // longer line up with the requested partition.
    let err = campaign::run_campaign(&spec, &dir, &in_process(2)).unwrap_err();
    assert!(err.contains("different partition"), "{err}");
    // Merging still works — unit coverage is partition-independent.
    let merged = campaign::merge(&spec, &dir).unwrap();
    assert_eq!(merged.len(), spec.total_units());

    // Even a stale manifest whose index does NOT overlap the narrower
    // partition is caught up front (it would otherwise double-cover
    // units at merge time).
    for i in [0, 1] {
        std::fs::remove_file(dir.join(format!("shard-{i}.json"))).unwrap();
        std::fs::remove_file(dir.join(format!("shard-{i}.manifest.json"))).unwrap();
    }
    let err = campaign::run_campaign(&spec, &dir, &in_process(2)).unwrap_err();
    assert!(err.contains("different partition"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}
