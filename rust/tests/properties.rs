//! Property-based tests over the coordinator's core invariants, using the
//! in-repo `util::prop` harness (proptest is unavailable offline; see
//! DESIGN.md §1). Each property runs over many seeded random cases with
//! shrinking where meaningful.

use perf4sight::device::Simulator;
use perf4sight::features::{network_features, NUM_FEATURES};
use perf4sight::forest::{Forest, ForestConfig};
use perf4sight::ir::{Graph, GraphBuilder};
use perf4sight::models;
use perf4sight::ofa::SubnetConfig;
use perf4sight::pruning::{groups_consistent, prune, prune_groups, Strategy};
use perf4sight::util::prop::{check, check_no_shrink, ensure};
use perf4sight::util::rng::Pcg64;

/// Random zoo network + pruning parameters.
#[derive(Clone, Debug)]
struct PruneCase {
    network: &'static str,
    strategy: Strategy,
    level: f64,
    seed: u64,
}

fn gen_prune_case(rng: &mut Pcg64) -> PruneCase {
    let networks = models::ZOO;
    let strategies = [
        Strategy::Random,
        Strategy::L1Norm,
        Strategy::Weighted(perf4sight::pruning::Profile::EarlyHeavy),
        Strategy::Weighted(perf4sight::pruning::Profile::LateHeavy),
        Strategy::Weighted(perf4sight::pruning::Profile::Random),
    ];
    PruneCase {
        network: networks[rng.gen_range(networks.len())],
        strategy: strategies[rng.gen_range(strategies.len())],
        level: rng.uniform(0.0, 0.95),
        seed: rng.next_u64(),
    }
}

#[test]
fn prop_pruning_preserves_graph_validity_and_groups() {
    check(
        0x9121,
        60,
        gen_prune_case,
        |c| {
            // Shrink toward lower pruning levels.
            if c.level > 0.05 {
                vec![PruneCase {
                    level: c.level / 2.0,
                    ..c.clone()
                }]
            } else {
                vec![]
            }
        },
        |c| {
            let g = models::by_name(c.network).unwrap();
            let mut rng = Pcg64::new(c.seed);
            let p = prune(&g, c.strategy, c.level, &mut rng);
            p.infer_shapes().map_err(|e| format!("{c:?}: {e}"))?;
            let groups = prune_groups(&p, &[]);
            ensure(
                groups_consistent(&p, &groups),
                format!("{c:?}: group channel mismatch"),
            )?;
            // Output class dimension survives.
            let shapes = p.infer_shapes().unwrap();
            ensure(
                shapes[p.output].numel() == 1000,
                format!("{c:?}: classifier dim {}", shapes[p.output].numel()),
            )?;
            // Parameters never grow.
            ensure(
                p.param_count().unwrap() <= g.param_count().unwrap(),
                format!("{c:?}: params grew"),
            )
        },
    );
}

#[test]
fn prop_features_finite_nonneg_and_monotone_in_bs() {
    check_no_shrink(2, 40, gen_prune_case, |c| {
        let g = models::by_name(c.network).unwrap();
        let mut rng = Pcg64::new(c.seed);
        let p = prune(&g, c.strategy, c.level, &mut rng);
        let f8 = network_features(&p, 8).map_err(|e| e.to_string())?;
        let f32b = network_features(&p, 32).map_err(|e| e.to_string())?;
        ensure(f8.len() == NUM_FEATURES, "wrong feature count")?;
        for (i, (&a, &b)) in f8.iter().zip(&f32b).enumerate() {
            ensure(
                a.is_finite() && b.is_finite() && a >= 0.0,
                format!("{c:?}: feature {i} not finite/nonneg"),
            )?;
            ensure(
                b >= a - 1e-9,
                format!("{c:?}: feature {i} decreased with bs: {a} -> {b}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_monotone_in_batch_and_capacity() {
    check_no_shrink(3, 30, gen_prune_case, |c| {
        let g = models::by_name(c.network).unwrap();
        let mut rng = Pcg64::new(c.seed);
        let p = prune(&g, c.strategy, c.level, &mut rng);
        let sim = Simulator::tx2();
        let small = sim.train_step(&p, 8, None).map_err(|e| e.to_string())?;
        let big = sim.train_step(&p, 64, None).map_err(|e| e.to_string())?;
        ensure(
            big.gamma_mb > small.gamma_mb,
            format!("{c:?}: Γ not monotone in bs"),
        )?;
        ensure(
            big.phi_ms > small.phi_ms,
            format!("{c:?}: Φ not monotone in bs"),
        )?;
        // Pruned network never costs more than the original.
        let orig = sim.train_step(&g, 32, None).map_err(|e| e.to_string())?;
        let pr = sim.train_step(&p, 32, None).map_err(|e| e.to_string())?;
        ensure(
            pr.gamma_mb <= orig.gamma_mb + 1e-6,
            format!("{c:?}: pruning increased Γ"),
        )?;
        ensure(
            pr.phi_ms <= orig.phi_ms + 1e-6,
            format!("{c:?}: pruning increased Φ"),
        )
    });
}

#[test]
fn prop_forest_tensor_roundtrip_matches_native() {
    // For arbitrary synthetic regression problems, the padded-tensor
    // traversal must agree with the native recursive prediction.
    check_no_shrink(
        4,
        15,
        |rng| rng.next_u64(),
        |&seed| {
            let mut rng = Pcg64::new(seed);
            let d = 3 + rng.gen_range(6);
            let n = 40 + rng.gen_range(200);
            let x: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..d).map(|_| rng.uniform(-5.0, 5.0)).collect())
                .collect();
            let y: Vec<f64> = x
                .iter()
                .map(|r| r.iter().sum::<f64>() + if r[0] > 0.0 { 10.0 } else { 0.0 })
                .collect();
            let forest = Forest::fit(
                &x,
                &y,
                &ForestConfig {
                    n_trees: 8,
                    max_depth: 8,
                    seed,
                    ..Default::default()
                },
            )
            .unwrap();
            let t = forest.to_tensors();
            for row in x.iter().take(25) {
                let a = forest.predict(row);
                let b = t.predict(row, t.depth);
                ensure(
                    (a - b).abs() <= 1e-6 * a.abs().max(1.0),
                    format!("native {a} != tensors {b}"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_forest_json_roundtrip() {
    check_no_shrink(
        5,
        10,
        |rng| rng.next_u64(),
        |&seed| {
            let mut rng = Pcg64::new(seed);
            let x: Vec<Vec<f64>> = (0..60)
                .map(|_| vec![rng.uniform(0.0, 1e9), rng.uniform(0.0, 1.0)])
                .collect();
            let y: Vec<f64> = x.iter().map(|r| r[0] * 1e-6 + 100.0 * r[1]).collect();
            let f = Forest::fit(
                &x,
                &y,
                &ForestConfig {
                    n_trees: 4,
                    seed,
                    ..Default::default()
                },
            )
            .unwrap();
            let j = f.to_json().to_string();
            let f2 = Forest::from_json(&perf4sight::util::json::Json::parse(&j)?)?;
            for row in x.iter().take(10) {
                ensure(
                    (f.predict(row) - f2.predict(row)).abs() < 1e-9,
                    "json roundtrip changed predictions",
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ofa_subnets_always_valid() {
    check_no_shrink(
        6,
        60,
        |rng| {
            let mut c = SubnetConfig::sample(rng);
            for _ in 0..rng.gen_range(4) {
                c = c.mutate(rng, 0.4);
            }
            c
        },
        |c| {
            let g = c.build();
            g.infer_shapes().map_err(|e| format!("{c:?}: {e}"))?;
            let shapes = g.infer_shapes().unwrap();
            ensure(shapes[g.output].numel() == 1000, "class dim")?;
            // capacity is within bounds and accuracy proxy sane
            let cap = perf4sight::ofa::capacity(&g);
            ensure((0.0..=1.0).contains(&cap), format!("capacity {cap}"))?;
            for s in perf4sight::ofa::ALL_SUBSETS {
                let a = perf4sight::ofa::initial_accuracy(c, &g, s);
                let r = perf4sight::ofa::retrained_accuracy(c, &g, s);
                ensure((0.0..100.0).contains(&a), format!("acc {a}"))?;
                ensure(r >= a - 1.5, format!("retrain regressed: {a} -> {r}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_parser_roundtrips_random_values() {
    use perf4sight::util::json::Json;
    fn gen_value(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth > 2 { rng.gen_range(4) } else { rng.gen_range(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.normal() * 1e3 * 100.0).round() / 100.0),
            3 => Json::Str(
                (0..rng.gen_range(8))
                    .map(|_| ['a', '"', '\\', 'ü', '\n', 'z'][rng.gen_range(6)])
                    .collect(),
            ),
            4 => Json::Arr((0..rng.gen_range(4)).map(|_| gen_value(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.gen_range(4))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    check_no_shrink(
        7,
        200,
        |rng| gen_value(rng, 0),
        |v| {
            let text = v.to_string();
            let back = Json::parse(&text).map_err(|e| format!("{text}: {e}"))?;
            ensure(&back == v, format!("roundtrip mismatch: {text}"))
        },
    );
}

#[test]
fn failure_injection_invalid_graphs_rejected_not_panicking() {
    // The IR must return Err (not panic) on malformed graphs.
    use perf4sight::ir::{Act, Op};
    // channel mismatch at Add
    let mut g = Graph::new("bad1");
    let x = g.input(3, 8, 8);
    let a = g.conv("a", x, 4, 1, 1, 0);
    let b = g.conv("b", x, 6, 1, 1, 0);
    g.add_join("j", &[a, b]);
    assert!(g.infer_shapes().is_err());

    // linear over unflattened tensor
    let mut g2 = Graph::new("bad2");
    let x2 = g2.input(3, 8, 8);
    let c2 = g2.conv_bn_act("c", x2, 4, 3, 1, 1, Act::Relu);
    g2.add("fc", Op::Linear { out: 10, bias: true }, &[c2]);
    assert!(g2.infer_shapes().is_err());

    // spatial mismatch at Concat
    let mut g3 = Graph::new("bad3");
    let x3 = g3.input(3, 8, 8);
    let a3 = g3.conv("a", x3, 4, 1, 1, 0);
    let b3 = g3.conv("b", x3, 4, 3, 2, 1);
    g3.concat("cat", &[a3, b3]);
    assert!(g3.infer_shapes().is_err());
}

#[test]
fn failure_injection_runtime_errors_are_reported() {
    use perf4sight::runtime::Runtime;
    // Missing artifacts directory must produce a clean error.
    let rt = Runtime::cpu("/nonexistent-artifacts");
    if let Ok(rt) = rt {
        assert!(rt.load("forest_b1.hlo.txt").is_err());
        assert!(rt.manifest().is_err());
    }
    // Wrong-shape forests are rejected by the executor with a clear error.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if Runtime::artifacts_present(&dir) {
        let rt = Runtime::cpu(&dir).unwrap();
        let x = vec![vec![0.0f64; 3]; 10]; // 3 features != 57
        let y = vec![1.0f64; 10];
        let forest = Forest::fit(
            &x,
            &y,
            &perf4sight::runtime::forest_exec::export_forest_config(),
        )
        .unwrap();
        let err = perf4sight::runtime::ForestExecutor::new(&rt, &forest)
            .err()
            .expect("must reject 3-feature forest");
        assert!(err.to_string().contains("features"));
    }
}

/// Random (n, d, config) fits: the presorted-column fast path must equal
/// the per-node-sort reference node-for-node, bit for bit (see
/// `rust/tests/fit_equivalence.rs` for the structured grid; this sweeps
/// the shape/hyperparameter space randomly).
#[test]
fn prop_fit_fast_matches_reference_node_for_node() {
    check_no_shrink(
        0xf17,
        25,
        |rng| rng.next_u64(),
        |&seed| {
            let mut rng = Pcg64::new(seed);
            let n = 20 + rng.gen_range(120);
            let d = 1 + rng.gen_range(10);
            // Quantised values make equal-feature ties common.
            let quant = [1.0, 0.25, 1e-3][rng.gen_range(3)];
            let x: Vec<Vec<f64>> = (0..n)
                .map(|_| {
                    (0..d)
                        .map(|_| (rng.uniform(-50.0, 50.0) / quant).round() * quant)
                        .collect()
                })
                .collect();
            let y: Vec<f64> = x
                .iter()
                .map(|r| r.iter().enumerate().map(|(j, v)| v * (j + 1) as f64).sum())
                .collect();
            let cfg = ForestConfig {
                n_trees: 1 + rng.gen_range(6),
                max_depth: 2 + rng.gen_range(10),
                min_samples_leaf: 1 + rng.gen_range(3),
                min_samples_split: 2 + rng.gen_range(5),
                feature_fraction: [1.0 / 3.0, 0.5, 1.0][rng.gen_range(3)],
                bootstrap: rng.gen_range(2) == 0,
                seed: rng.next_u64(),
            };
            let fast = Forest::fit(&x, &y, &cfg).map_err(|e| e.to_string())?;
            let reference = Forest::fit_reference(&x, &y, &cfg).map_err(|e| e.to_string())?;
            ensure(fast.trees.len() == reference.trees.len(), "tree count")?;
            for (a, b) in fast.trees.iter().zip(&reference.trees) {
                ensure(a.nodes.len() == b.nodes.len(), "node count")?;
                for (na, nb) in a.nodes.iter().zip(&b.nodes) {
                    ensure(na.feature == nb.feature, "feature")?;
                    ensure(
                        na.threshold.to_bits() == nb.threshold.to_bits(),
                        format!("threshold {} != {}", na.threshold, nb.threshold),
                    )?;
                    ensure(na.left == nb.left && na.right == nb.right, "children")?;
                    ensure(
                        na.value.to_bits() == nb.value.to_bits(),
                        format!("value {} != {}", na.value, nb.value),
                    )?;
                }
            }
            Ok(())
        },
    );
}

/// Regression: tie-heavy and duplicate columns, where only the canonical
/// (value, row id) scan order keeps fast and reference aligned.
#[test]
fn fit_tie_and_duplicate_columns_regression() {
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..120 {
        // Constant column, binary column, 0.0/-0.0 mix, coarse grid.
        x.push(vec![
            7.0,
            (i % 2) as f64,
            if i % 3 == 0 { -0.0 } else { 0.0 },
            (i % 5) as f64,
        ]);
        y.push((i % 6) as f64 * 3.0 + (i % 2) as f64);
    }
    // Duplicate the back half of the rows verbatim.
    for i in 60..120 {
        x.push(x[i].clone());
        y.push(y[i]);
    }
    for bootstrap in [true, false] {
        let cfg = ForestConfig {
            n_trees: 9,
            max_depth: 8,
            bootstrap,
            feature_fraction: 0.5,
            seed: 0x71e5,
            ..Default::default()
        };
        let fast = Forest::fit(&x, &y, &cfg).unwrap();
        let reference = Forest::fit_reference(&x, &y, &cfg).unwrap();
        for (t, (a, b)) in fast.trees.iter().zip(&reference.trees).enumerate() {
            assert_eq!(a.nodes.len(), b.nodes.len(), "tree {t} size");
            for (i, (na, nb)) in a.nodes.iter().zip(&b.nodes).enumerate() {
                assert_eq!(na.feature, nb.feature, "tree {t} node {i}");
                assert_eq!(na.threshold.to_bits(), nb.threshold.to_bits(), "tree {t} node {i}");
                assert_eq!((na.left, na.right), (nb.left, nb.right), "tree {t} node {i}");
                assert_eq!(na.value.to_bits(), nb.value.to_bits(), "tree {t} node {i}");
            }
        }
    }
}
