//! Oracle suite for the batched-inference fast paths: the blocked
//! branch-free executor ([`perf4sight::engine::BlockedForest`]), the
//! fused Γ/Φ pair walk ([`perf4sight::engine::CompiledForestPair`]) and
//! the legacy slab walker (`Forest::compile`) must all stay **bitwise
//! identical** to the scalar `Forest::predict` reference — on zoo-trained
//! models and across a property sweep of random forest shapes, exact
//! threshold ties, ±0.0 features, degenerate tiles and NaN rows.

use perf4sight::device::Simulator;
use perf4sight::engine::CompiledForestPair;
use perf4sight::experiments::experiment_forest_config;
use perf4sight::forest::{Forest, ForestConfig};
use perf4sight::models;
use perf4sight::profiler::train_test_split;
use perf4sight::pruning::Strategy;
use perf4sight::util::rng::Pcg64;

fn assert_bits(a: f64, b: f64, ctx: &str) {
    assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: {a:?} vs {b:?}");
}

/// Every batched path must agree bitwise with per-row `Forest::predict`:
/// the blocked executor on both forests, the legacy slab walker, and
/// both halves of the fused pair walk.
fn assert_all_paths_scalar_identical(gamma: &Forest, phi: &Forest, rows: &[Vec<f64>], ctx: &str) {
    let blocked_g = gamma.compile_blocked().predict_rows(rows);
    let blocked_p = phi.compile_blocked().predict_rows(rows);
    let walker_g = gamma.compile().predict_rows(rows);
    let (fused_g, fused_p) = CompiledForestPair::compile(gamma, phi).predict_rows(rows);
    assert_eq!(blocked_g.len(), rows.len(), "{ctx}: output arity");
    for (i, row) in rows.iter().enumerate() {
        let sg = gamma.predict(row);
        let sp = phi.predict(row);
        assert_bits(blocked_g[i], sg, &format!("{ctx}: blocked Γ row {i}"));
        assert_bits(blocked_p[i], sp, &format!("{ctx}: blocked Φ row {i}"));
        assert_bits(walker_g[i], sg, &format!("{ctx}: slab walker row {i}"));
        assert_bits(fused_g[i], sg, &format!("{ctx}: fused Γ row {i}"));
        assert_bits(fused_p[i], sp, &format!("{ctx}: fused Φ row {i}"));
    }
}

#[test]
fn zoo_models_blocked_fused_and_walker_match_scalar() {
    let sim = Simulator::tx2();
    for (name, strategy) in [("resnet18", Strategy::Random), ("squeezenet", Strategy::L1Norm)] {
        let g = models::by_name(name).unwrap();
        let (train, test) = train_test_split(&sim, name, &g, strategy, 9);
        let cfg = experiment_forest_config();
        let fg = Forest::fit(&train.x(), &train.y_gamma(), &cfg).expect("Γ fit");
        let fp = Forest::fit(&train.x(), &train.y_phi(), &cfg).expect("Φ fit");
        assert_all_paths_scalar_identical(&fg, &fp, &test.x(), name);
    }
}

/// Training values live on a small discrete grid (including a signed
/// zero), so split thresholds land on predictable midpoints…
const POOL: [f64; 8] = [-2.0, -1.0, -0.0, 0.0, 0.25, 0.5, 1.0, 3.0];

/// …and these are exactly those midpoints: evaluation rows carrying them
/// sit *on* candidate thresholds, probing the `<=` tie bit-for-bit.
const TIE_PROBES: [f64; 6] = [-1.5, -0.5, 0.125, 0.375, 0.75, 2.0];

fn pool_row(rng: &mut Pcg64, n_features: usize) -> Vec<f64> {
    (0..n_features).map(|_| POOL[rng.gen_range(POOL.len())]).collect()
}

fn probe_row(rng: &mut Pcg64, n_features: usize) -> Vec<f64> {
    (0..n_features)
        .map(|_| {
            let k = rng.gen_range(POOL.len() + TIE_PROBES.len());
            if k < POOL.len() {
                POOL[k]
            } else {
                TIE_PROBES[k - POOL.len()]
            }
        })
        .collect()
}

#[test]
fn property_sweep_random_shapes_stay_bitwise_identical() {
    let mut rng = Pcg64::new(0x9e11);
    // Row counts straddle the ROW_TILE=32 boundary: single-row tiles,
    // exactly-one-tile, one-row-spills-a-second-tile, and a multi-tile
    // batch with a ragged tail.
    let row_counts = [1usize, 2, 31, 32, 33, 97];
    for case in 0u64..30 {
        let n_features = 2 + rng.gen_range(4);
        let cfg = ForestConfig {
            // 1..=16 trees straddles the TREE_BLOCK=8 boundary too:
            // partial single blocks, exactly one block, and two blocks.
            n_trees: 1 + rng.gen_range(16),
            max_depth: 1 + rng.gen_range(13),
            feature_fraction: if case % 3 == 0 { 1.0 } else { 0.6 },
            bootstrap: case % 2 == 0,
            seed: 7919 * case + 13,
            ..ForestConfig::default()
        };
        let train_x: Vec<Vec<f64>> = (0..64).map(|_| pool_row(&mut rng, n_features)).collect();
        let yg: Vec<f64> = (0..64).map(|_| rng.uniform(0.0, 10.0)).collect();
        let yp: Vec<f64> = (0..64).map(|_| rng.uniform(0.0, 5.0)).collect();
        let gamma = Forest::fit(&train_x, &yg, &cfg).expect("sweep Γ fit");
        let phi = Forest::fit(&train_x, &yp, &cfg).expect("sweep Φ fit");
        let n_rows = row_counts[case as usize % row_counts.len()];
        let rows: Vec<Vec<f64>> = (0..n_rows).map(|_| probe_row(&mut rng, n_features)).collect();
        assert_all_paths_scalar_identical(&gamma, &phi, &rows, &format!("case {case}"));
    }
}

#[test]
fn degenerate_single_leaf_and_single_tree_single_row() {
    let mut rng = Pcg64::new(0x51e9);
    let train_x: Vec<Vec<f64>> = (0..16).map(|_| pool_row(&mut rng, 3)).collect();
    let y: Vec<f64> = (0..16).map(|_| rng.uniform(1.0, 2.0)).collect();

    // max_depth 0 collapses every tree to a bare root leaf: zero
    // traversal steps, pure accumulate-and-divide.
    let leafy = ForestConfig {
        n_trees: 3,
        max_depth: 0,
        ..ForestConfig::default()
    };
    let fg = Forest::fit(&train_x, &y, &leafy).expect("leaf-only fit");

    // A single tree exercises the one-lane partial block; a single row
    // exercises the one-row partial tile.
    let lone = ForestConfig {
        n_trees: 1,
        max_depth: 6,
        ..ForestConfig::default()
    };
    let fp = Forest::fit(&train_x, &y, &lone).expect("single-tree fit");

    let one_row = vec![probe_row(&mut rng, 3)];
    assert_all_paths_scalar_identical(&fg, &fp, &one_row, "degenerate single row");
    let more: Vec<Vec<f64>> = (0..33).map(|_| probe_row(&mut rng, 3)).collect();
    assert_all_paths_scalar_identical(&fg, &fp, &more, "degenerate multi-row");
}

#[test]
fn nan_rows_take_the_reference_fallback_and_match_scalar() {
    let mut rng = Pcg64::new(0xa11a);
    let train_x: Vec<Vec<f64>> = (0..64).map(|_| pool_row(&mut rng, 3)).collect();
    let y: Vec<f64> = (0..64).map(|_| rng.uniform(0.0, 10.0)).collect();
    let cfg = ForestConfig {
        n_trees: 10,
        max_depth: 8,
        ..ForestConfig::default()
    };
    let gamma = Forest::fit(&train_x, &y, &cfg).expect("Γ fit");
    let phi = Forest::fit(&train_x, &y, &cfg).expect("Φ fit");
    // NaN features send the whole batch down the reference-semantics
    // walk (a fixed step count cannot traverse a NaN comparison); the
    // scalar path sees NaN-goes-right at every split, and the fallback
    // must reproduce it bitwise — for the NaN rows *and* the clean ones
    // sharing the batch.
    let mut rows: Vec<Vec<f64>> = (0..40).map(|_| probe_row(&mut rng, 3)).collect();
    rows[7][1] = f64::NAN;
    rows[33][0] = f64::NAN;
    assert_all_paths_scalar_identical(&gamma, &phi, &rows, "nan batch");
}
