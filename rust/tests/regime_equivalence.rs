//! Training-regime oracle suite.
//!
//! The regime axis is additive: `TrainRegime::Vanilla` must reproduce the
//! pre-regime simulator, profiler and dataset bytes bit for bit across the
//! whole zoo, while `Checkpointed` / `Frozen` move Γ and Φ in the
//! physically required directions. Also pins the v1 (regime-less) dataset
//! CSV schema via a checked-in fixture.

use perf4sight::device::{Simulator, TrainRegime};
use perf4sight::profiler::{profile, Dataset, ProfileJob};
use perf4sight::util::rng::Pcg64;

#[test]
fn vanilla_regime_is_bit_identical_across_the_zoo() {
    let sim = Simulator::tx2();
    for name in perf4sight::models::ZOO {
        let graph = perf4sight::models::by_name(name).unwrap();
        let plan = graph.plan().unwrap();
        for bs in [4usize, 32] {
            // Noise-free measurements.
            let a = sim.train_step_plan(&plan, bs, None);
            let b = sim.train_step_plan_regime(&plan, bs, TrainRegime::Vanilla, None);
            assert_eq!(a.gamma_mb.to_bits(), b.gamma_mb.to_bits(), "{name} bs={bs}");
            assert_eq!(a.phi_ms.to_bits(), b.phi_ms.to_bits(), "{name} bs={bs}");
            // Noisy measurements: identical draws from identical streams.
            let mut r1 = Pcg64::new(0x517e ^ bs as u64);
            let mut r2 = Pcg64::new(0x517e ^ bs as u64);
            let a = sim.train_step_plan(&plan, bs, Some(&mut r1));
            let b = sim.train_step_plan_regime(&plan, bs, TrainRegime::Vanilla, Some(&mut r2));
            assert_eq!(a.gamma_mb.to_bits(), b.gamma_mb.to_bits(), "{name} bs={bs}");
            assert_eq!(a.phi_ms.to_bits(), b.phi_ms.to_bits(), "{name} bs={bs}");
            // Both paths consumed the same number of draws.
            assert_eq!(r1.next_u64(), r2.next_u64(), "{name} bs={bs}");
        }
    }
}

#[test]
fn vanilla_profile_dataset_keeps_v1_bytes() {
    // A vanilla profiling job serialises without any regime markers: the
    // JSON has no "regime" key and the CSV keeps the historical header.
    let graph = perf4sight::models::by_name("squeezenet").unwrap();
    let ds = profile(
        &Simulator::tx2(),
        &ProfileJob {
            levels: &[0.0, 0.5],
            batch_sizes: &[4, 16],
            runs: 2,
            seed: 9,
            ..ProfileJob::new("squeezenet", &graph)
        },
    );
    assert!(!ds.is_empty());
    assert!(!ds.to_json().to_string().contains("regime"));
    assert!(ds.to_csv().starts_with("network,strategy,level,bs,gamma_mb,phi_ms,"));
}

#[test]
fn checkpointing_and_freezing_move_gamma_phi_in_the_right_directions() {
    let sim = Simulator::tx2();
    for name in ["resnet18", "mobilenetv2"] {
        let graph = perf4sight::models::by_name(name).unwrap();
        let plan = graph.plan().unwrap();
        let bs = 32;
        let vanilla = sim.train_step_plan(&plan, bs, None);
        for segments in [2usize, 4, 8] {
            let ckpt = sim.train_step_plan_regime(
                &plan,
                bs,
                TrainRegime::Checkpointed { segments },
                None,
            );
            assert!(
                ckpt.gamma_mb < vanilla.gamma_mb,
                "{name} ckpt:{segments}: Γ {} !< {}",
                ckpt.gamma_mb,
                vanilla.gamma_mb
            );
            assert!(
                ckpt.phi_ms > vanilla.phi_ms,
                "{name} ckpt:{segments}: Φ {} !> {}",
                ckpt.phi_ms,
                vanilla.phi_ms
            );
        }
        for suffix in [1usize, 3] {
            let frozen = sim.train_step_plan_regime(
                &plan,
                bs,
                TrainRegime::Frozen {
                    trainable_suffix: suffix,
                },
                None,
            );
            assert!(
                frozen.gamma_mb < vanilla.gamma_mb,
                "{name} frozen:{suffix}: Γ {} !< {}",
                frozen.gamma_mb,
                vanilla.gamma_mb
            );
            assert!(
                frozen.phi_ms < vanilla.phi_ms,
                "{name} frozen:{suffix}: Φ {} !< {}",
                frozen.phi_ms,
                vanilla.phi_ms
            );
        }
    }
}

#[test]
fn fully_trainable_frozen_suffix_degenerates_to_vanilla() {
    let sim = Simulator::tx2();
    let graph = perf4sight::models::by_name("squeezenet").unwrap();
    let plan = graph.plan().unwrap();
    let n_convs = plan.conv_infos().len();
    for suffix in [n_convs, n_convs + 10] {
        let v = sim.train_step_plan(&plan, 16, None);
        let f = sim.train_step_plan_regime(
            &plan,
            16,
            TrainRegime::Frozen {
                trainable_suffix: suffix,
            },
            None,
        );
        assert_eq!(v.gamma_mb.to_bits(), f.gamma_mb.to_bits(), "suffix={suffix}");
        assert_eq!(v.phi_ms.to_bits(), f.phi_ms.to_bits(), "suffix={suffix}");
    }
}

#[test]
fn v1_csv_fixture_loads_and_round_trips_bitwise() {
    // Checked-in pre-regime dump: must parse (regime defaulting to
    // vanilla) and re-serialise to the identical bytes — the v1 schema is
    // frozen forever.
    let fixture = include_str!("fixtures/dataset_v1.csv");
    let ds = Dataset::from_csv(fixture).unwrap();
    assert_eq!(ds.len(), 3);
    assert!(ds.points.iter().all(|p| p.regime == "vanilla"));
    assert_eq!(ds.points[0].network, "resnet18");
    assert_eq!(ds.points[2].strategy, "l1norm");
    assert_eq!(ds.to_csv(), fixture);
    // And the JSON round of the same dataset carries no regime key.
    assert!(!ds.to_json().to_string().contains("regime"));
}
