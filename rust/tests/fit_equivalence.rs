//! The training fast path's headline guarantee (see `rust/src/forest/train.rs`):
//! `Forest::fit` and `Forest::fit_sequential` — which both run on the
//! presorted-column `TrainMatrix` path — produce forests **node-for-node
//! bit-identical** to `Forest::fit_reference`, the retained seed algorithm
//! that re-sorts every candidate feature at every node. Every `TreeNode`
//! field is compared exactly (`f64::to_bits` on `threshold` and `value`),
//! across zoo-profiled datasets, bootstrap on/off, feature subsampling,
//! and tie-heavy/duplicate-value columns where only the canonical
//! (value, row id) scan order keeps the two paths aligned.

use perf4sight::device::Simulator;
use perf4sight::forest::{Forest, ForestConfig, TrainMatrix};
use perf4sight::models;
use perf4sight::profiler::{profile, ProfileJob};
use perf4sight::util::rng::Pcg64;

fn assert_bit_identical(fast: &Forest, reference: &Forest, what: &str) {
    assert_eq!(
        fast.trees.len(),
        reference.trees.len(),
        "{what}: tree count diverges"
    );
    assert_eq!(fast.n_features, reference.n_features, "{what}: n_features");
    for (t, (a, b)) in fast.trees.iter().zip(&reference.trees).enumerate() {
        assert_eq!(
            a.nodes.len(),
            b.nodes.len(),
            "{what}: tree {t} node count diverges"
        );
        for (i, (na, nb)) in a.nodes.iter().zip(&b.nodes).enumerate() {
            assert_eq!(na.feature, nb.feature, "{what}: tree {t} node {i} feature");
            assert_eq!(
                na.threshold.to_bits(),
                nb.threshold.to_bits(),
                "{what}: tree {t} node {i} threshold {} vs {}",
                na.threshold,
                nb.threshold
            );
            assert_eq!(na.left, nb.left, "{what}: tree {t} node {i} left");
            assert_eq!(na.right, nb.right, "{what}: tree {t} node {i} right");
            assert_eq!(
                na.value.to_bits(),
                nb.value.to_bits(),
                "{what}: tree {t} node {i} value {} vs {}",
                na.value,
                nb.value
            );
        }
    }
}

/// All three fast entry points (parallel, sequential, prebuilt matrix)
/// against the reference, on one problem.
fn check_all_paths(x: &[Vec<f64>], y: &[f64], cfg: &ForestConfig, what: &str) {
    let reference = Forest::fit_reference(x, y, cfg).unwrap();
    let par = Forest::fit(x, y, cfg).unwrap();
    let seq = Forest::fit_sequential(x, y, cfg).unwrap();
    let m = TrainMatrix::from_rows(x).unwrap();
    let via_matrix = Forest::fit_matrix(&m, y, cfg).unwrap();
    assert_bit_identical(&par, &reference, &format!("{what} [parallel]"));
    assert_bit_identical(&seq, &reference, &format!("{what} [sequential]"));
    assert_bit_identical(&via_matrix, &reference, &format!("{what} [matrix]"));
}

/// Bootstrap on/off × feature_fraction {1/3, 1.0} at a given seed.
fn check_grid(x: &[Vec<f64>], y: &[f64], n_trees: usize, seed: u64, what: &str) {
    for bootstrap in [true, false] {
        for ff in [1.0 / 3.0, 1.0] {
            let cfg = ForestConfig {
                n_trees,
                bootstrap,
                feature_fraction: ff,
                seed,
                ..Default::default()
            };
            check_all_paths(
                x,
                y,
                &cfg,
                &format!("{what} bootstrap={bootstrap} ff={ff:.2}"),
            );
        }
    }
}

#[test]
fn zoo_profiles_fit_bit_identical_across_paths() {
    // Real profiler datasets (5 pruning levels × 25 batch sizes, 57
    // analytical features) for two zoo networks — the exact workload
    // `cmd_fit` and the experiments run.
    let sim = Simulator::tx2();
    for (name, seed) in [("resnet18", 0x2001u64), ("squeezenet", 0x2002)] {
        let g = models::by_name(name).unwrap();
        let ds = profile(&sim, &ProfileJob::new(name, &g));
        check_grid(&ds.x(), &ds.y_gamma(), 8, seed, &format!("{name}/Γ"));
        // Φ on one config keeps the suite fast while covering both targets.
        let cfg = ForestConfig {
            n_trees: 6,
            seed: seed ^ 0xff,
            ..Default::default()
        };
        check_all_paths(&ds.x(), &ds.y_phi(), &cfg, &format!("{name}/Φ"));
    }
}

#[test]
fn tie_heavy_and_duplicate_columns_fit_bit_identical() {
    // Adversarial columns for the canonical-order contract: a constant
    // column, a two-value column, a 0.0/-0.0 mix, coarse discrete grids,
    // and every row duplicated — splits land between tied runs and the
    // scan order within ties is all that separates the two paths.
    let mut rng = Pcg64::new(0x7137);
    let mut x = Vec::new();
    let mut y = Vec::new();
    for i in 0..90 {
        let row = vec![
            (i % 4) as f64,
            3.0,
            if i % 2 == 0 { 0.0 } else { -0.0 },
            (rng.gen_range(3) as f64) * 0.5,
            rng.uniform(-2.0, 2.0),
            (i % 2) as f64,
        ];
        let target = (i % 8) as f64 + 2.0 * row[0] - row[3] + 0.25 * row[4];
        // Exact duplicate of every (row, target) pair.
        x.push(row.clone());
        y.push(target);
        x.push(row);
        y.push(target);
    }
    check_grid(&x, &y, 12, 0x3003, "tie-heavy");

    // min_samples_leaf / min_samples_split interact with duplicate runs in
    // the scan's integer guards — exercise them off their defaults.
    let cfg = ForestConfig {
        n_trees: 10,
        min_samples_leaf: 3,
        min_samples_split: 7,
        max_depth: 9,
        feature_fraction: 0.4,
        seed: 0x3004,
        ..Default::default()
    };
    check_all_paths(&x, &y, &cfg, "tie-heavy min_leaf=3 min_split=7");
}

#[test]
fn random_problems_fit_bit_identical() {
    // A spread of shapes: tall/thin, short/wide, single feature, and a
    // target with plateaus (equal-SSE score ties).
    let mut rng = Pcg64::new(0xabcd);
    for (case, (n, d)) in [(0usize, (250usize, 4usize)), (1, (40, 20)), (2, (64, 1))]
        .into_iter()
    {
        let x: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..d).map(|_| rng.uniform(-1e3, 1e3)).collect())
            .collect();
        let y: Vec<f64> = x
            .iter()
            .map(|r| (r[0] / 100.0).round() * 10.0 + r[d - 1] * 0.01)
            .collect();
        check_grid(&x, &y, 8, 0x4000 + case as u64, &format!("random shape {case}"));
    }
}

#[test]
fn fast_path_reuses_one_matrix_across_targets() {
    // The matrix is target-agnostic: fitting Γ then Φ from one presorted
    // matrix must equal fitting each from scratch.
    let sim = Simulator::tx2();
    let g = models::by_name("alexnet").unwrap();
    let ds = profile(&sim, &ProfileJob::new("alexnet", &g));
    let cfg = ForestConfig {
        n_trees: 6,
        seed: 0x5005,
        ..Default::default()
    };
    let m = ds.train_matrix().unwrap();
    let fg_shared = Forest::fit_matrix(&m, &ds.y_gamma(), &cfg).unwrap();
    let fp_shared = Forest::fit_matrix(&m, &ds.y_phi(), &cfg).unwrap();
    let fg_fresh = Forest::fit(&ds.x(), &ds.y_gamma(), &cfg).unwrap();
    let fp_fresh = Forest::fit(&ds.x(), &ds.y_phi(), &cfg).unwrap();
    assert_bit_identical(&fg_shared, &fg_fresh, "shared-matrix Γ");
    assert_bit_identical(&fp_shared, &fp_fresh, "shared-matrix Φ");
}
