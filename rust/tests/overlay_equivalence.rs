//! Oracle suite for the zero-allocation evaluation fast path: everything
//! computed through (GraphArena, PruneOverlay, incremental plan rebuilds)
//! must be **bit-identical** to the clone+rebuild reference path
//! (`prune` → `NetworkPlan::build` → features / simulator /
//! `graph_fingerprint`), across the model zoo × every pruning strategy ×
//! levels {0, 0.25, 0.75}, plus the OFA candidate path and a campaign
//! re-run.

use perf4sight::campaign::{self, CampaignSpec};
use perf4sight::device::Simulator;
use perf4sight::engine::{graph_fingerprint, PredictionEngine};
use perf4sight::features::{
    forward_masked, network_features_from_plan, network_features_into, NUM_FEATURES,
};
use perf4sight::forest::{Forest, ForestConfig};
use perf4sight::ir::{GraphArena, NetworkPlan, PlanBuffers, PlanView};
use perf4sight::models;
use perf4sight::ofa::{
    evolutionary_search, Constraints, EsConfig, PlanOracle, Subset, SubnetConfig,
};
use perf4sight::profiler::{profile_sequential, Dataset, ProfileJob};
use perf4sight::pruning::{prune, prune_overlay, Strategy, ALL_PROFILES};
use perf4sight::util::rng::Pcg64;

const LEVELS: [f64; 3] = [0.0, 0.25, 0.75];

fn all_strategies() -> Vec<Strategy> {
    let mut s = vec![Strategy::Random, Strategy::L1Norm];
    s.extend(ALL_PROFILES.iter().map(|&p| Strategy::Weighted(p)));
    s
}

/// Shapes, conv summaries, parameter counts, feature rows and structural
/// fingerprints agree between the overlay path and clone+rebuild, for the
/// whole zoo × all strategies × the issue's level set — with one shared
/// `PlanBuffers` so most rebuilds take the incremental route.
#[test]
fn overlay_analysis_bit_identical_across_zoo() {
    for name in models::ZOO {
        let g = models::by_name(name).unwrap();
        let arena = GraphArena::compile(&g).unwrap();
        let mut buffers = PlanBuffers::new();
        for (si, &strategy) in all_strategies().iter().enumerate() {
            for &level in &LEVELS {
                let mut rng_graph = Pcg64::new(0x5eed + si as u64);
                let mut rng_overlay = rng_graph.clone();
                let pruned = prune(&g, strategy, level, &mut rng_graph);
                let overlay = prune_overlay(&arena, strategy, level, &mut rng_overlay);
                assert_eq!(
                    rng_graph.next_u64(),
                    rng_overlay.next_u64(),
                    "{name}/{strategy:?}@{level}: RNG streams diverged"
                );
                let plan = NetworkPlan::build(&pruned).unwrap();
                arena.plan_into(&overlay, &mut buffers).unwrap();
                let view = arena.view_buffers(&buffers);
                let ctx = format!("{name}/{strategy:?}@{level}");
                assert_eq!(view.shapes(), PlanView::shapes(&plan), "{ctx}: shapes");
                assert_eq!(
                    view.conv_infos(),
                    PlanView::conv_infos(&plan),
                    "{ctx}: conv infos"
                );
                assert_eq!(
                    PlanView::param_count(&view),
                    PlanView::param_count(&plan),
                    "{ctx}: params"
                );
                assert_eq!(
                    arena.fingerprint(&overlay),
                    graph_fingerprint(&pruned),
                    "{ctx}: fingerprint"
                );
                // Feature rows, both allocating and scratch-buffer variants.
                let mut row = Vec::new();
                for bs in [1usize, 32] {
                    let reference = network_features_from_plan(&plan, bs);
                    network_features_into(view.conv_infos(), bs, &mut row);
                    assert_eq!(reference, row, "{ctx}: features bs={bs}");
                }
                // Materialized structure round-trips (names, ops, wiring).
                let back = arena.to_graph(&overlay);
                assert_eq!(back.output, pruned.output);
                for (a, b) in back.nodes.iter().zip(&pruned.nodes) {
                    assert_eq!((&a.name, &a.op, &a.inputs), (&b.name, &b.op, &b.inputs));
                }
            }
        }
    }
}

/// Simulated Γ/Φ/γ/φ — noise-free and with seeded measurement noise —
/// agree bitwise between an overlay view and the materialized plan.
#[test]
fn simulator_attributes_bit_identical_over_overlay() {
    let sim = Simulator::tx2();
    for name in ["squeezenet", "resnet18", "mobilenetv2"] {
        let g = models::by_name(name).unwrap();
        let arena = GraphArena::compile(&g).unwrap();
        let mut buffers = PlanBuffers::new();
        for &strategy in &[Strategy::Random, Strategy::L1Norm] {
            for &level in &LEVELS {
                let mut rng_a = Pcg64::new(77);
                let mut rng_b = rng_a.clone();
                let pruned = prune(&g, strategy, level, &mut rng_a);
                let overlay = prune_overlay(&arena, strategy, level, &mut rng_b);
                let plan = NetworkPlan::build(&pruned).unwrap();
                arena.plan_into(&overlay, &mut buffers).unwrap();
                let view = arena.view_buffers(&buffers);
                for bs in [1usize, 32] {
                    let t_ref = sim.train_step_plan(&plan, bs, None);
                    let t_ovl = sim.train_step_plan(&view, bs, None);
                    assert_eq!(t_ref.gamma_mb.to_bits(), t_ovl.gamma_mb.to_bits());
                    assert_eq!(t_ref.phi_ms.to_bits(), t_ovl.phi_ms.to_bits());
                    let i_ref = sim.inference_plan(&plan, bs, None);
                    let i_ovl = sim.inference_plan(&view, bs, None);
                    assert_eq!(i_ref.gamma_mb.to_bits(), i_ovl.gamma_mb.to_bits());
                    assert_eq!(i_ref.phi_ms.to_bits(), i_ovl.phi_ms.to_bits());
                }
                // Noise draws consume the identical stream.
                let mut n_a = Pcg64::new(9);
                let mut n_b = Pcg64::new(9);
                let t_ref = sim.train_step_plan(&plan, 16, Some(&mut n_a));
                let t_ovl = sim.train_step_plan(&view, 16, Some(&mut n_b));
                assert_eq!(t_ref.gamma_mb.to_bits(), t_ovl.gamma_mb.to_bits());
                assert_eq!(t_ref.phi_ms.to_bits(), t_ovl.phi_ms.to_bits());
            }
        }
    }
}

/// The OFA fast path: per-depth-key arenas + candidate width overlays
/// reproduce the clone+rebuild feature rows and capacities for a wide
/// random sample of the space.
#[test]
fn ofa_candidate_rows_match_clone_rebuild() {
    use perf4sight::ofa::capacity_from_convs;
    let mut rng = Pcg64::new(0x0fa5);
    let mut configs = vec![SubnetConfig::min(), SubnetConfig::max()];
    configs.extend((0..40).map(|_| SubnetConfig::sample(&mut rng)));
    let mut buffers = PlanBuffers::new();
    let mut row = Vec::new();
    for c in configs {
        // Clone+rebuild reference.
        let g = c.build();
        let plan = NetworkPlan::build(&g).unwrap();
        let ref_train = network_features_from_plan(&plan, 32);
        let ref_infer = forward_masked(&network_features_from_plan(&plan, 1));
        let ref_capacity = capacity_from_convs(PlanView::conv_infos(&plan));
        // Overlay fast path (what the engine's miss path runs).
        let rep = SubnetConfig::depth_representative(c.depth_key()).build();
        let arena = GraphArena::compile(&rep).unwrap();
        let mut overlay = arena.identity_overlay();
        c.fill_conv_widths(overlay.widths_mut());
        arena.plan_into(&overlay, &mut buffers).unwrap();
        let view = arena.view_buffers(&buffers);
        network_features_into(view.conv_infos(), 32, &mut row);
        assert_eq!(ref_train, row, "train row drifted for {c:?}");
        let mut infer = Vec::new();
        network_features_into(view.conv_infos(), 1, &mut infer);
        perf4sight::features::forward_mask_in_place(&mut infer);
        assert_eq!(ref_infer, infer, "infer row drifted for {c:?}");
        let capacity = capacity_from_convs(view.conv_infos());
        assert_eq!(ref_capacity.to_bits(), capacity.to_bits());
        assert_eq!(row.len(), NUM_FEATURES);
    }
}

/// End-to-end search: the engine (arena fast path, cache on) must return
/// an `EsResult` identical to the clone+rebuild `PlanOracle` reference
/// driven by the same forests.
#[test]
fn search_through_fast_path_is_bit_identical() {
    // A synthetic forest serving all three attribute roles (the serving
    // path is under test, not model quality).
    let mut rng = Pcg64::new(0xf0e5);
    let x: Vec<Vec<f64>> = (0..60)
        .map(|_| (0..NUM_FEATURES).map(|_| rng.uniform(0.0, 1e6)).collect())
        .collect();
    let y: Vec<f64> = x.iter().map(|r| r[1] / 1e3 + r[4] / 1e4 + 60.0).collect();
    let forest = Forest::fit(
        &x,
        &y,
        &ForestConfig {
            n_trees: 12,
            max_depth: 6,
            ..Default::default()
        },
    )
    .unwrap();
    let compiled = forest.compile();
    let cfg = EsConfig {
        population: 16,
        iterations: 5,
        seed: 0xabc,
        ..Default::default()
    };
    let cons = Constraints::unconstrained();
    let mut engine = PredictionEngine::new(&forest, &forest, &forest);
    let fast = evolutionary_search(&cons, &cfg, Subset::City, &mut engine);
    let mut reference = PlanOracle::new(|_c: &SubnetConfig, plan: &NetworkPlan| {
        let f_train = network_features_from_plan(plan, 32);
        let f_infer = forward_masked(&network_features_from_plan(plan, 1));
        perf4sight::ofa::Attributes {
            gamma_train_mb: compiled.predict_row(&f_train),
            gamma_infer_mb: compiled.predict_row(&f_infer),
            phi_infer_ms: compiled.predict_row(&f_infer),
        }
    });
    let slow = evolutionary_search(&cons, &cfg, Subset::City, &mut reference);
    assert_eq!(fast.best, slow.best);
    assert_eq!(fast.best_fitness.to_bits(), slow.best_fitness.to_bits());
    assert_eq!(
        fast.best_attrs.gamma_train_mb.to_bits(),
        slow.best_attrs.gamma_train_mb.to_bits()
    );
    assert_eq!(fast.samples, slow.samples);
    // The engine memoises; a repeated run over the same stream is all hits.
    let again = evolutionary_search(&cons, &cfg, Subset::City, &mut engine);
    assert_eq!(again.best, fast.best);
    assert_eq!(again.cache.unwrap().misses, 0);
}

/// Campaign re-run through the overlay path: the sharded executor and the
/// monolithic campaign both reproduce the sequential clone+rebuild oracle
/// byte for byte (dataset JSON).
#[test]
fn campaign_merge_bit_identical_through_overlays() {
    let spec = CampaignSpec {
        networks: vec!["squeezenet".into(), "mnasnet".into()],
        strategies: vec![Strategy::Random, Strategy::L1Norm],
        regimes: vec![perf4sight::device::TrainRegime::Vanilla],
        levels: vec![0.0, 0.25, 0.75],
        batch_sizes: vec![4, 16],
        runs: 2,
        seed: 0x9e1f,
        device: "tx2".into(),
    };
    // Reference: the original per-level sequential implementation (direct
    // graph paths, no arenas anywhere).
    let sim = spec.simulator().unwrap();
    let mut reference = Dataset::default();
    for network in &spec.networks {
        let graph = models::by_name(network).unwrap();
        for &strategy in &spec.strategies {
            let job = ProfileJob {
                network,
                graph: &graph,
                strategy,
                regime: perf4sight::device::TrainRegime::Vanilla,
                levels: &spec.levels,
                batch_sizes: &spec.batch_sizes,
                runs: spec.runs,
                seed: spec.seed,
            };
            reference.extend(profile_sequential(&sim, &job));
        }
    }
    let reference_json = reference.to_json().to_string();
    let monolithic = campaign::profile_campaign(&spec).unwrap();
    assert_eq!(reference_json, monolithic.to_json().to_string());
    let sharded = campaign::collect(&spec).unwrap();
    assert_eq!(reference_json, sharded.to_json().to_string());
}
