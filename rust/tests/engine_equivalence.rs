//! Equivalence suite for the PredictionEngine: the batched
//! `CompiledForest` path must be **bit-identical** to the scalar
//! `Forest::predict` reference on zoo-trained models, the padded-tensor
//! batched path must match its per-row reference, and an ES search with
//! the fingerprint cache enabled must return exactly the same result as a
//! cache-off run at the same seed.

use perf4sight::device::Simulator;
use perf4sight::experiments::{experiment_forest_config, ofa_models};
use perf4sight::features::{forward_masked, network_features_from_plan};
use perf4sight::forest::Forest;
use perf4sight::ir::NetworkPlan;
use perf4sight::models;
use perf4sight::ofa::{
    evolutionary_search, Constraints, EsConfig, GenerationOracle, PlanOracle, Subset,
    SubnetConfig,
};
use perf4sight::profiler::train_test_split;
use perf4sight::pruning::Strategy;
use perf4sight::runtime::forest_exec::compiled_fits_artifact;

#[test]
fn batched_predict_rows_bit_identical_to_scalar_on_zoo_models() {
    let sim = Simulator::tx2();
    for (name, strategy) in [("resnet18", Strategy::Random), ("squeezenet", Strategy::L1Norm)] {
        let g = models::by_name(name).unwrap();
        let (train, test) = train_test_split(&sim, name, &g, strategy, 21);
        let rows = test.x();
        for target in [train.y_gamma(), train.y_phi()] {
            let forest = Forest::fit(&train.x(), &target, &experiment_forest_config()).unwrap();
            let compiled = forest.compile();
            assert!(compiled_fits_artifact(&compiled), "{name}: artifact shape");
            let batched = compiled.predict_rows(&rows);
            assert_eq!(batched.len(), rows.len());
            for (row, &b) in rows.iter().zip(&batched) {
                let scalar = forest.predict(row);
                assert_eq!(
                    scalar.to_bits(),
                    b.to_bits(),
                    "{name}: batched prediction diverges from scalar"
                );
                assert_eq!(compiled.predict_row(row).to_bits(), scalar.to_bits());
            }
        }
    }
}

#[test]
fn padded_tensor_batched_path_matches_per_row_reference() {
    let sim = Simulator::tx2();
    let g = models::by_name("squeezenet").unwrap();
    let (train, test) = train_test_split(&sim, "squeezenet", &g, Strategy::Random, 22);
    let forest = Forest::fit(&train.x(), &train.y_gamma(), &experiment_forest_config()).unwrap();
    let t = forest.to_tensors();
    let rows = test.x();
    let batched = t.predict_rows(&rows, t.depth);
    for (row, &b) in rows.iter().zip(&batched) {
        assert_eq!(
            t.predict(row, t.depth).to_bits(),
            b.to_bits(),
            "padded batched traversal diverges"
        );
    }
}

#[test]
fn engine_generation_matches_scalar_plan_oracle_bitwise() {
    let sim = Simulator::tx2();
    let m = ofa_models::run(&sim, 10, 33);
    let mut engine = m.engine();
    // The scalar reference: per-candidate closure over the same forests.
    let mut reference = PlanOracle::new(|_c: &SubnetConfig, plan: &NetworkPlan| {
        let f_train = network_features_from_plan(plan, 32);
        let f_infer = forward_masked(&network_features_from_plan(plan, 1));
        perf4sight::ofa::Attributes {
            gamma_train_mb: m.gamma_train.predict(&f_train),
            gamma_infer_mb: m.gamma_infer.predict(&f_infer),
            phi_infer_ms: m.phi_infer.predict(&f_infer),
        }
    });
    let mut rng = perf4sight::util::rng::Pcg64::new(5);
    let mut generation: Vec<SubnetConfig> =
        (0..24).map(|_| SubnetConfig::sample(&mut rng)).collect();
    generation.push(SubnetConfig::max());
    generation.push(SubnetConfig::min());
    let via_engine = engine.evaluate_generation(&generation);
    let via_scalar = reference.evaluate_generation(&generation);
    for (e, s) in via_engine.iter().zip(&via_scalar) {
        assert_eq!(
            e.attrs.gamma_train_mb.to_bits(),
            s.attrs.gamma_train_mb.to_bits()
        );
        assert_eq!(
            e.attrs.gamma_infer_mb.to_bits(),
            s.attrs.gamma_infer_mb.to_bits()
        );
        assert_eq!(e.attrs.phi_infer_ms.to_bits(), s.attrs.phi_infer_ms.to_bits());
        assert_eq!(e.capacity.to_bits(), s.capacity.to_bits());
    }
}

#[test]
fn paper_default_population_search_hits_cache() {
    // Sec. 6.4 runs the ES at population 100; ES populations converge, so
    // children frequently repeat already-evaluated candidates and the
    // fingerprint cache must show a measurable hit rate.
    let sim = Simulator::tx2();
    let m = ofa_models::run(&sim, 10, 51);
    let mut engine = m.engine();
    let cfg = EsConfig {
        population: 100,
        iterations: 40,
        ..Default::default()
    };
    let r = evolutionary_search(
        &Constraints::unconstrained(),
        &cfg,
        Subset::City,
        &mut engine,
    );
    // Unconstrained: seed fill of 100 plus 40 refills of 75 children.
    assert_eq!(r.samples, 100 + 40 * 75);
    let cs = r.cache.expect("engine reports cache stats");
    assert!(cs.hits > 0, "no cache hits at population 100: {cs:?}");
    assert!(
        r.unique_evaluations < r.samples,
        "cache saved no work: {} of {}",
        r.unique_evaluations,
        r.samples
    );
    assert_eq!(cs.hits as usize + r.unique_evaluations, r.samples);
}

#[test]
fn cached_search_bit_identical_to_uncached_search() {
    let sim = Simulator::tx2();
    let m = ofa_models::run(&sim, 12, 31);
    // Constraints between the predicted extremes so rejection paths run too.
    let mut probe = m.engine();
    let anchors = probe.evaluate_generation(&[SubnetConfig::max(), SubnetConfig::min()]);
    let (hi, lo) = (anchors[0].attrs, anchors[1].attrs);
    let mid = |a: f64, b: f64| b + 0.6 * (a - b);
    let cons = Constraints {
        gamma_train_mb: mid(hi.gamma_train_mb, lo.gamma_train_mb),
        gamma_infer_mb: f64::INFINITY,
        phi_infer_ms: mid(hi.phi_infer_ms, lo.phi_infer_ms),
    };
    let cfg = EsConfig {
        population: 16,
        iterations: 8,
        seed: 77,
        ..Default::default()
    };

    let mut cached = m.engine();
    let mut uncached = m.engine().with_cache_capacity(0);
    let on = evolutionary_search(&cons, &cfg, Subset::City, &mut cached);
    let off = evolutionary_search(&cons, &cfg, Subset::City, &mut uncached);

    assert_eq!(on.best, off.best, "cache changed the selected sub-network");
    assert_eq!(
        on.best_fitness.to_bits(),
        off.best_fitness.to_bits(),
        "cache changed the fitness"
    );
    assert_eq!(on.best_attrs, off.best_attrs);
    assert_eq!(on.samples, off.samples);
    // Honest accounting: cache-off evaluates every sample; cache-on reports
    // misses as the unique work.
    assert_eq!(off.unique_evaluations, off.samples);
    let cs = on.cache.expect("engine reports cache stats");
    assert_eq!(cs.requests() as usize, on.samples);
    assert_eq!(on.unique_evaluations, cs.misses as usize);
    assert!(on.unique_evaluations <= on.samples);

    // A second identical search on the warm engine repeats every candidate:
    // zero predictor evaluations, bit-identical result.
    let warm = evolutionary_search(&cons, &cfg, Subset::City, &mut cached);
    assert_eq!(warm.best, on.best);
    assert_eq!(warm.best_fitness.to_bits(), on.best_fitness.to_bits());
    let warm_cs = warm.cache.unwrap();
    assert_eq!(warm_cs.misses, 0, "warm cache must answer everything");
    assert_eq!(warm_cs.hits as usize, warm.samples);
    assert_eq!(warm.unique_evaluations, 0);
}
