//! Integration tests over the whole modelling toolflow:
//! profile (simulated device) → analytical features → random forest →
//! held-out prediction error. These assert the *shape* of the paper's
//! headline results (single-digit Γ error, slightly higher Φ error).

use perf4sight::device::Simulator;
use perf4sight::forest::{Forest, ForestConfig};
use perf4sight::models;
use perf4sight::profiler::{profile, train_test_split, ProfileJob, PAPER_BATCH_SIZES};
use perf4sight::pruning::Strategy;

fn forest_cfg() -> ForestConfig {
    ForestConfig {
        n_trees: 40,
        max_depth: 14,
        ..Default::default()
    }
}

#[test]
fn same_network_prediction_errors_are_paper_like() {
    // Fig. 3 setting, one network: train on T={0,30,50,70,90}% random
    // pruning, test on the other 14 levels. Paper: Γ ≤ 9.15%, Φ ≤ 14.7%.
    let sim = Simulator::tx2();
    let g = models::squeezenet(1000);
    let (train, test) = train_test_split(&sim, "squeezenet", &g, Strategy::Random, 11);

    // One presorted matrix serves both target fits.
    let m = train.train_matrix().unwrap();
    let fg = Forest::fit_matrix(&m, &train.y_gamma(), &forest_cfg()).unwrap();
    let fp = Forest::fit_matrix(&m, &train.y_phi(), &forest_cfg()).unwrap();
    let gerr = fg.mape(&test.x(), &test.y_gamma());
    let perr = fp.mape(&test.x(), &test.y_phi());
    println!("squeezenet: gamma err {gerr:.2}%  phi err {perr:.2}%");
    assert!(gerr < 9.15, "Γ error {gerr:.2}% exceeds the paper's worst case");
    assert!(perr < 14.7, "Φ error {perr:.2}% exceeds the paper's worst case");
}

#[test]
fn l1_test_strategy_only_slightly_worse() {
    // Fig. 3 "L1" bars: train on random pruning, test on L1-norm pruning.
    let sim = Simulator::tx2();
    let g = models::resnet18(1000);
    let (train, test_rand) = train_test_split(&sim, "resnet18", &g, Strategy::Random, 13);
    let (_, test_l1) = train_test_split(&sim, "resnet18", &g, Strategy::L1Norm, 13);

    let fg = Forest::fit(&train.x(), &train.y_gamma(), &forest_cfg()).unwrap();
    let e_rand = fg.mape(&test_rand.x(), &test_rand.y_gamma());
    let e_l1 = fg.mape(&test_l1.x(), &test_l1.y_gamma());
    println!("resnet18 Γ: rand {e_rand:.2}%  l1 {e_l1:.2}%");
    assert!(e_l1 < 15.0, "L1 strategy generalisation broke: {e_l1:.2}%");
}

#[test]
fn single_level_training_set_is_much_worse() {
    // Sec. 6.1: T={0} gives 33–74% error; 5 levels give 3–6%.
    let sim = Simulator::tx2();
    let g = models::alexnet(1000);
    let one_level = ProfileJob {
        levels: &[0.0],
        batch_sizes: &PAPER_BATCH_SIZES,
        ..ProfileJob::new("alexnet", &g)
    };
    let five_levels = ProfileJob::new("alexnet", &g);
    let test_job = ProfileJob {
        levels: &[0.25, 0.45, 0.65, 0.85],
        seed: 999,
        ..ProfileJob::new("alexnet", &g)
    };
    let train1 = profile(&sim, &one_level);
    let train5 = profile(&sim, &five_levels);
    let test = profile(&sim, &test_job);

    let f1 = Forest::fit(&train1.x(), &train1.y_gamma(), &forest_cfg()).unwrap();
    let f5 = Forest::fit(&train5.x(), &train5.y_gamma(), &forest_cfg()).unwrap();
    let e1 = f1.mape(&test.x(), &test.y_gamma());
    let e5 = f5.mape(&test.x(), &test.y_gamma());
    println!("alexnet Γ: |T|=1 err {e1:.2}%  |T|=5 err {e5:.2}%");
    assert!(
        e1 > 2.0 * e5,
        "single-level training should be much worse: {e1:.2}% vs {e5:.2}%"
    );
}
