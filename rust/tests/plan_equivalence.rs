//! Equivalence suite for the compiled analysis layer: every
//! `NetworkPlan`-based result must be **bit-identical** to the direct
//! `Graph` analysis path, across the whole model zoo, before and after
//! pruning — and the parallel `Forest::fit` must reproduce the sequential
//! reference exactly.

use perf4sight::baselines::{
    estimate_training_memory_mb, estimate_training_memory_mb_plan, DnnMemConfig,
};
use perf4sight::device::Simulator;
use perf4sight::features::{network_features, network_features_from_plan};
use perf4sight::forest::{Forest, ForestConfig};
use perf4sight::ir::{Graph, NetworkPlan};
use perf4sight::models;
use perf4sight::pruning::{prune, Strategy};
use perf4sight::util::rng::Pcg64;

#[test]
fn plan_matches_graph_analyses_across_zoo() {
    for name in models::ZOO {
        let g = models::by_name(name).unwrap();
        let plan = NetworkPlan::build(&g).unwrap();
        assert_eq!(
            plan.shapes(),
            g.infer_shapes().unwrap().as_slice(),
            "{name}: shapes diverge"
        );
        assert_eq!(
            plan.conv_infos(),
            g.conv_infos().unwrap().as_slice(),
            "{name}: conv summaries diverge"
        );
        assert_eq!(
            plan.param_count(),
            g.param_count().unwrap(),
            "{name}: param count diverges"
        );
        assert_eq!(
            plan.model_size_mb(),
            g.model_size_mb().unwrap(),
            "{name}: model size diverges"
        );
    }
}

#[test]
fn plan_features_bit_identical_across_zoo() {
    for name in models::ZOO {
        let g = models::by_name(name).unwrap();
        let plan = g.plan().unwrap();
        for bs in [1usize, 8, 32, 128] {
            assert_eq!(
                network_features(&g, bs).unwrap(),
                network_features_from_plan(&plan, bs),
                "{name} bs={bs}: feature rows diverge"
            );
        }
    }
}

#[test]
fn plan_simulator_paths_bit_identical_across_zoo() {
    let sim = Simulator::tx2();
    for name in models::ZOO {
        let g = models::by_name(name).unwrap();
        let plan = NetworkPlan::build(&g).unwrap();
        for bs in [1usize, 32] {
            let t_g = sim.train_step(&g, bs, None).unwrap();
            let t_p = sim.train_step_plan(&plan, bs, None);
            assert_eq!(t_g.gamma_mb, t_p.gamma_mb, "{name} bs={bs}: Γ diverges");
            assert_eq!(t_g.phi_ms, t_p.phi_ms, "{name} bs={bs}: Φ diverges");
            let i_g = sim.inference(&g, bs, None).unwrap();
            let i_p = sim.inference_plan(&plan, bs, None);
            assert_eq!(i_g.gamma_mb, i_p.gamma_mb, "{name} bs={bs}: γ diverges");
            assert_eq!(i_g.phi_ms, i_p.phi_ms, "{name} bs={bs}: φ diverges");
        }
        // Noisy paths consume the RNG identically too.
        let mut r1 = Pcg64::new(7);
        let mut r2 = Pcg64::new(7);
        let n_g = sim.train_step(&g, 16, Some(&mut r1)).unwrap();
        let n_p = sim.train_step_plan(&plan, 16, Some(&mut r2));
        assert_eq!(n_g.gamma_mb, n_p.gamma_mb, "{name}: noisy Γ diverges");
        assert_eq!(n_g.phi_ms, n_p.phi_ms, "{name}: noisy Φ diverges");
    }
}

#[test]
fn plan_baselines_bit_identical() {
    let cfg = DnnMemConfig::default();
    for name in ["resnet18", "mobilenetv2", "squeezenet"] {
        let g = models::by_name(name).unwrap();
        let plan = NetworkPlan::build(&g).unwrap();
        for bs in [8usize, 64] {
            assert_eq!(
                estimate_training_memory_mb(&g, bs, &cfg).unwrap(),
                estimate_training_memory_mb_plan(&plan, bs, &cfg),
                "{name} bs={bs}: DNNMem estimate diverges"
            );
        }
    }
}

#[test]
fn plan_equivalence_survives_pruning() {
    // The invalidation rule in practice: prune, rebuild the plan, and the
    // rebuilt plan must agree with the pruned graph exactly.
    let sim = Simulator::tx2();
    for name in models::ZOO {
        let g = models::by_name(name).unwrap();
        let mut rng = Pcg64::new(0x9e1f);
        let pruned: Graph = prune(&g, Strategy::L1Norm, 0.5, &mut rng);
        let plan = NetworkPlan::build(&pruned).unwrap();
        assert_eq!(
            plan.param_count(),
            pruned.param_count().unwrap(),
            "{name}: pruned param count diverges"
        );
        assert_eq!(
            network_features(&pruned, 32).unwrap(),
            network_features_from_plan(&plan, 32),
            "{name}: pruned features diverge"
        );
        let t_g = sim.train_step(&pruned, 32, None).unwrap();
        let t_p = sim.train_step_plan(&plan, 32, None);
        assert_eq!(t_g.gamma_mb, t_p.gamma_mb, "{name}: pruned Γ diverges");
        assert_eq!(t_g.phi_ms, t_p.phi_ms, "{name}: pruned Φ diverges");
    }
}

#[test]
fn parallel_forest_fit_matches_sequential_reference() {
    // Synthetic regression problem large enough that trees differ if any
    // RNG stream is consumed out of order.
    let mut rng = Pcg64::new(42);
    let x: Vec<Vec<f64>> = (0..200)
        .map(|_| (0..8).map(|_| rng.uniform(0.0, 100.0)).collect())
        .collect();
    let y: Vec<f64> = x
        .iter()
        .map(|r| 2.0 * r[0] + r[3] + if r[5] > 50.0 { 25.0 } else { 0.0 })
        .collect();
    for n_trees in [1usize, 7, 24] {
        let cfg = ForestConfig {
            n_trees,
            seed: 0xf0e57 ^ n_trees as u64,
            ..Default::default()
        };
        let par = Forest::fit(&x, &y, &cfg).unwrap();
        let seq = Forest::fit_sequential(&x, &y, &cfg).unwrap();
        assert_eq!(par.trees.len(), seq.trees.len());
        for (i, (a, b)) in par.trees.iter().zip(&seq.trees).enumerate() {
            assert_eq!(a.nodes, b.nodes, "n_trees={n_trees}: tree {i} diverges");
        }
        for row in x.iter().take(25) {
            assert_eq!(par.predict(row), seq.predict(row));
        }
    }
}
