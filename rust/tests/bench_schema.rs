//! Shape test for the checked-in hot-path bench placeholder.
//!
//! `BENCH_hotpath.json` at the repo root is a seed placeholder: CI
//! regenerates the real numbers on every push (`cargo bench --bench
//! hotpath`) and gates on them, but the checked-in copy documents the
//! schema the gate script parses. This test pins that copy to the
//! constants the bench itself writes ([`HOTPATH_SCHEMA`] /
//! [`HOTPATH_SECTIONS`]) so the placeholder, the bench and the CI gate
//! cannot drift apart silently.

use perf4sight::util::bench_harness::{HOTPATH_SCHEMA, HOTPATH_SECTIONS};
use perf4sight::util::json::Json;

fn load_placeholder() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    let text = std::fs::read_to_string(path).expect("checked-in BENCH_hotpath.json");
    Json::parse(&text).expect("placeholder parses as JSON")
}

#[test]
fn placeholder_schema_tag_matches_bench_constant() {
    let j = load_placeholder();
    match j.get("schema") {
        Some(Json::Str(s)) => assert_eq!(s, HOTPATH_SCHEMA, "schema tag drifted"),
        other => panic!("schema must be a string, got {other:?}"),
    }
}

#[test]
fn placeholder_carries_every_section() {
    let j = load_placeholder();
    for key in HOTPATH_SECTIONS {
        match j.get(key) {
            // Null until someone copies a measured run in; Obj afterwards.
            Some(Json::Null) | Some(Json::Obj(_)) => {}
            other => panic!("section {key:?} must be null or an object, got {other:?}"),
        }
    }
}

#[test]
fn placeholder_has_no_unknown_keys() {
    let j = load_placeholder();
    let Json::Obj(map) = &j else {
        panic!("placeholder must be a JSON object");
    };
    for key in map.keys() {
        let known = key == "schema" || key == "note" || HOTPATH_SECTIONS.contains(&key.as_str());
        assert!(known, "unknown top-level key {key:?} — bump HOTPATH_SECTIONS + schema tag");
    }
}
