//! CLI integration: drives the coordinator's subcommands through the
//! library entry point with temp directories, covering the documented
//! profile → fit → predict workflow and error handling.

use std::path::PathBuf;

fn run(cmd: &str) -> Result<(), String> {
    perf4sight::coordinator::run(cmd.split_whitespace().map(String::from).collect())
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("perf4sight-cli-{name}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn help_and_zoo_succeed() {
    run("help").unwrap();
    run("zoo").unwrap();
}

#[test]
fn profile_fit_predict_roundtrip() {
    let dir = tmpdir("roundtrip");
    let data = dir.join("sq.json");
    let model = dir.join("gamma.json");
    run(&format!(
        "profile --network squeezenet --device tx2 --levels 0,0.5 \
         --batch-sizes 4,16,64 --runs 1 --seed 3 --out {}",
        data.display()
    ))
    .unwrap();
    assert!(data.exists());
    run(&format!(
        "fit --data {} --target gamma --out {}",
        data.display(),
        model.display()
    ))
    .unwrap();
    assert!(model.exists());
    run(&format!(
        "predict --model {} --network squeezenet --level 0.3 --bs 16 --truth",
        model.display()
    ))
    .unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn config_file_is_honoured() {
    let dir = tmpdir("config");
    let cfg = dir.join("p4s.toml");
    std::fs::write(
        &cfg,
        "device = \"xavier\"\nseed = 77\n[forest]\nn_trees = 8\nmax_depth = 6\n",
    )
    .unwrap();
    let data = dir.join("d.json");
    run(&format!(
        "profile --config {} --network squeezenet --levels 0 --batch-sizes 8 --runs 1 --out {}",
        cfg.display(),
        data.display()
    ))
    .unwrap();
    assert!(data.exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn errors_are_clean_not_panics() {
    assert!(run("frobnicate").is_err());
    assert!(run("profile --network nope --out /tmp/x.json").is_err());
    assert!(run("profile --out /tmp/x.json").is_err()); // missing --network
    assert!(run("fit --data /nonexistent.json --target gamma --out /tmp/m.json").is_err());
    assert!(run("experiment unknown-exp").is_err());
    assert!(run("predict --model /nonexistent.json --network resnet18").is_err());
    // malformed numeric option
    assert!(run("profile --network squeezenet --runs NaNish --out /tmp/x.json").is_err());
}

#[test]
fn quick_experiment_via_cli() {
    // The fastest experiment end-to-end through the CLI dispatch.
    run("experiment ablation --network squeezenet --seed 5").unwrap();
}
