//! CLI integration: drives the coordinator's subcommands through the
//! library entry point with temp directories, covering the documented
//! profile → fit → predict workflow and error handling.

use std::path::PathBuf;

fn run(cmd: &str) -> Result<(), String> {
    perf4sight::coordinator::run(cmd.split_whitespace().map(String::from).collect())
}

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("perf4sight-cli-{name}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn help_and_zoo_succeed() {
    run("help").unwrap();
    run("zoo").unwrap();
}

#[test]
fn profile_fit_predict_roundtrip() {
    let dir = tmpdir("roundtrip");
    let data = dir.join("sq.json");
    let model = dir.join("gamma.json");
    run(&format!(
        "profile --network squeezenet --device tx2 --levels 0,0.5 \
         --batch-sizes 4,16,64 --runs 1 --seed 3 --out {}",
        data.display()
    ))
    .unwrap();
    assert!(data.exists());
    run(&format!(
        "fit --data {} --target gamma --out {}",
        data.display(),
        model.display()
    ))
    .unwrap();
    assert!(model.exists());
    run(&format!(
        "predict --model {} --network squeezenet --level 0.3 --bs 16 --truth",
        model.display()
    ))
    .unwrap();
    // Fused two-target prediction: fit Φ from the same profile and answer
    // both models over a level × bs sweep in one fused Γ/Φ blocked pass.
    let phi_model = dir.join("phi.json");
    run(&format!(
        "fit --data {} --target phi --out {}",
        data.display(),
        phi_model.display()
    ))
    .unwrap();
    run(&format!(
        "predict --model {} --phi-model {} --network squeezenet \
         --level 0,0.5 --bs 4,16 --truth",
        model.display(),
        phi_model.display()
    ))
    .unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn config_file_is_honoured() {
    let dir = tmpdir("config");
    let cfg = dir.join("p4s.toml");
    std::fs::write(
        &cfg,
        "device = \"xavier\"\nseed = 77\n[forest]\nn_trees = 8\nmax_depth = 6\n",
    )
    .unwrap();
    let data = dir.join("d.json");
    run(&format!(
        "profile --config {} --network squeezenet --levels 0 --batch-sizes 8 --runs 1 --out {}",
        cfg.display(),
        data.display()
    ))
    .unwrap();
    assert!(data.exists());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn errors_are_clean_not_panics() {
    assert!(run("frobnicate").is_err());
    assert!(run("profile --network nope --out /tmp/x.json").is_err());
    assert!(run("profile --out /tmp/x.json").is_err()); // missing --network
    assert!(run("fit --data /nonexistent.json --target gamma --out /tmp/m.json").is_err());
    assert!(run("experiment unknown-exp").is_err());
    assert!(run("predict --model /nonexistent.json --network resnet18").is_err());
    // malformed numeric option
    assert!(run("profile --network squeezenet --runs NaNish --out /tmp/x.json").is_err());
}

#[test]
fn served_search_verifies_against_serial_via_cli() {
    // Two concurrent searches as tenants of one shared prediction
    // service; --verify-serial makes the command itself fail unless each
    // result is byte-identical to a serial single-caller run.
    run(
        "search --tenants 2 --verify-serial --subnets 8 --population 10 \
         --iterations 3 --seed 7 --queue-capacity 8 --coalesce 4",
    )
    .unwrap();
    // Invalid tenant counts are rejected before the model fit.
    assert!(run("search --tenants 0 --subnets 8").is_err());
}

#[test]
fn quick_experiment_via_cli() {
    // The fastest experiment end-to-end through the CLI dispatch.
    run("experiment ablation --network squeezenet --seed 5").unwrap();
}

#[test]
fn campaign_cli_runs_merges_and_fits() {
    // In-process mode: the test binary is not the perf4sight CLI, so
    // worker processes cannot be self-exec'd from here (the spawn path is
    // covered by tests/campaign_shards.rs and the CI smoke job).
    let dir = tmpdir("campaign");
    let out_dir = dir.join("camp");
    let merged = dir.join("merged.json");
    run(&format!(
        "campaign --networks squeezenet --strategies random --levels 0,0.5 \
         --batch-sizes 4,16 --runs 1 --seed 3 --shards 2 --workers 2 --in-process \
         --out-dir {} --out {}",
        out_dir.display(),
        merged.display()
    ))
    .unwrap();
    let ds = perf4sight::profiler::Dataset::load(&merged).unwrap();
    assert_eq!(ds.len(), 4);
    // The merged campaign output is byte-identical to plain `profile`.
    let mono = dir.join("mono.json");
    run(&format!(
        "profile --network squeezenet --strategy random --levels 0,0.5 \
         --batch-sizes 4,16 --runs 1 --seed 3 --out {}",
        mono.display()
    ))
    .unwrap();
    assert_eq!(
        std::fs::read_to_string(&merged).unwrap(),
        std::fs::read_to_string(&mono).unwrap()
    );
    // Resume + alternate output format without re-profiling.
    let csv = dir.join("merged.csv");
    run(&format!(
        "campaign --merge-only --out-dir {} --format csv --out {}",
        out_dir.display(),
        csv.display()
    ))
    .unwrap();
    let text = std::fs::read_to_string(&csv).unwrap();
    let back = perf4sight::profiler::Dataset::from_csv(&text).unwrap();
    assert_eq!(back.to_json().to_string(), ds.to_json().to_string());
    // The fitted-model step of the smoke flow.
    run(&format!(
        "fit --data {} --target phi --out {}",
        merged.display(),
        dir.join("phi.json").display()
    ))
    .unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn profile_shard_mode_feeds_campaign_merge() {
    let dir = tmpdir("shard-mode");
    let out_dir = dir.join("shards");
    for i in 0..2 {
        run(&format!(
            "profile --network squeezenet --levels 0,0.5 --batch-sizes 4 --runs 1 \
             --seed 3 --shards 2 --shard-index {i} --out-dir {}",
            out_dir.display()
        ))
        .unwrap();
    }
    let merged = dir.join("merged.json");
    run(&format!(
        "campaign --merge-only --out-dir {} --out {}",
        out_dir.display(),
        merged.display()
    ))
    .unwrap();
    let ds = perf4sight::profiler::Dataset::load(&merged).unwrap();
    assert_eq!(ds.len(), 2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn campaign_cli_errors_are_clean() {
    assert!(run("campaign --out-dir /tmp/p4s-no-spec-here --merge-only").is_err());
    assert!(run("campaign --networks nope --out-dir /tmp/p4s-bad-net --in-process").is_err());
    assert!(run("profile --network squeezenet --shards 2 --out /tmp/x.json").is_err());
    let dir = tmpdir("bad-format");
    assert!(run(&format!(
        "campaign --networks squeezenet --levels 0 --batch-sizes 4 --runs 1 \
         --shards 1 --workers 1 --in-process --out-dir {} --format yaml",
        dir.join("c").display()
    ))
    .is_err());
    std::fs::remove_dir_all(&dir).ok();
}
