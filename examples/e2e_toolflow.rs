//! End-to-end toolflow driver (DESIGN.md §6): exercises every layer of the
//! system on a real small workload and proves they compose.
//!
//!   1. run a merged profiling campaign over two networks on the simulated
//!      TX2 (sharded work-stealing execution, bit-identical to
//!      single-process profiling),
//!   2. fit Γ/Φ random forests (L3),
//!   3. evaluate on held-out pruned topologies (paper-shape errors),
//!   4. export the Γ forest as tensors, load `forest_b*.hlo.txt` through
//!      PJRT and cross-check XLA (L1 Pallas kernel) vs native numerics,
//!   5. run a constrained OFA evolutionary search with model-predicted
//!      attributes through the XLA path.
//!
//! Run after `make artifacts`: `cargo run --release --example e2e_toolflow`

use perf4sight::campaign::{self, CampaignSpec};
use perf4sight::device::{Simulator, PROFILE_COST_S};
use perf4sight::experiments::ofa_models::forward_masked;
use perf4sight::features::network_features_from_plan;
use perf4sight::forest::Forest;
use perf4sight::ir::NetworkPlan;
use perf4sight::ofa::{
    evolutionary_search, Attributes, Constraints, EsConfig, PlanOracle, Subset,
};
use perf4sight::profiler::{test_levels, PAPER_BATCH_SIZES, TRAIN_LEVELS};
use perf4sight::pruning::Strategy;
use perf4sight::runtime::{forest_exec::export_forest_config, ForestExecutor, Runtime};

fn main() -> anyhow::Result<()> {
    let sim = Simulator::tx2();
    println!(
        "=== 1. profiling campaign (simulated {}) ===",
        sim.spec.name
    );
    // One merged campaign covers both training networks; the sharded
    // work-stealing execution is bit-identical to per-network profile()
    // calls (rust/tests/campaign_shards.rs holds the oracle).
    let base = CampaignSpec {
        networks: vec!["resnet18".into(), "squeezenet".into()],
        strategies: vec![Strategy::Random],
        regimes: vec![perf4sight::device::TrainRegime::Vanilla],
        levels: TRAIN_LEVELS.to_vec(),
        batch_sizes: PAPER_BATCH_SIZES.to_vec(),
        runs: 3,
        seed: 11,
        device: "tx2".into(),
    };
    let train = campaign::collect(&base).map_err(anyhow::Error::msg)?;
    let held_out = test_levels();
    let test_spec = |network: &str, strategy: Strategy, seed: u64| CampaignSpec {
        networks: vec![network.into()],
        strategies: vec![strategy],
        levels: held_out.clone(),
        seed,
        ..base.clone()
    };
    let test_a = campaign::collect(&test_spec("resnet18", Strategy::Random, 11 ^ 0xdead_beef))
        .map_err(anyhow::Error::msg)?;
    let test_b = campaign::collect(&test_spec("squeezenet", Strategy::L1Norm, 13 ^ 0xdead_beef))
        .map_err(anyhow::Error::msg)?;
    println!(
        "  {} merged train points ({} networks), {} + {} held-out test points",
        train.len(),
        base.networks.len(),
        test_a.len(),
        test_b.len()
    );

    println!("\n=== 2. fit Γ/Φ forests ===");
    let cfg = export_forest_config();
    // Presort the merged campaign once; both target fits share the matrix.
    let m = train.train_matrix().unwrap();
    let fg = Forest::fit_matrix(&m, &train.y_gamma(), &cfg).unwrap();
    let fp = Forest::fit_matrix(&m, &train.y_phi(), &cfg).unwrap();

    println!("\n=== 3. held-out evaluation ===");
    for (name, test) in [("resnet18/rand", &test_a), ("squeezenet/L1", &test_b)] {
        println!(
            "  {name}: Γ err {:.2}%  Φ err {:.2}%  (paper worst-case: 9.15% / 14.7%)",
            fg.mape(&test.x(), &test.y_gamma()),
            fp.mape(&test.x(), &test.y_phi())
        );
    }

    println!("\n=== 4. XLA runtime cross-check (L1 pallas forest kernel) ===");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        Runtime::artifacts_present(&dir),
        "artifacts missing — run `make artifacts` first"
    );
    let rt = Runtime::cpu(&dir)?;
    let exec = ForestExecutor::new(&rt, &fg)?;
    let rows: Vec<Vec<f64>> = test_a.x().into_iter().take(64).collect();
    let native: Vec<f64> = rows.iter().map(|r| fg.predict(r)).collect();
    let via_xla = exec.predict_batch(&rows)?;
    let max_rel = native
        .iter()
        .zip(&via_xla)
        .map(|(a, b)| ((a - b) / a).abs())
        .fold(0.0f64, f64::max);
    println!("  64 predictions: max |native - xla| / native = {max_rel:.2e}");
    anyhow::ensure!(max_rel < 1e-4, "XLA path diverged from native forest");

    println!("\n=== 5. constrained OFA search with model-predicted attributes ===");
    let predict = |_c: &perf4sight::ofa::SubnetConfig, plan: &NetworkPlan| {
        // Γ through the XLA artifact (the deployed path); γ/φ natively.
        // One compiled plan per candidate serves both feature rows.
        let ft = network_features_from_plan(plan, 32);
        let fi = forward_masked(&network_features_from_plan(plan, 1));
        Attributes {
            gamma_train_mb: exec.predict_one(&ft).unwrap(),
            gamma_infer_mb: fg.predict(&fi).max(1500.0), // coarse reuse for the demo
            phi_infer_ms: fp.predict(&fi).max(5.0) / 20.0,
        }
    };
    let cons = Constraints {
        gamma_train_mb: 5200.0,
        gamma_infer_mb: f64::INFINITY,
        phi_infer_ms: f64::INFINITY,
    };
    let es = EsConfig {
        population: 24,
        iterations: 8,
        ..Default::default()
    };
    // The XLA-backed closure plugs into the same oracle seam the batched
    // PredictionEngine implements.
    let result = evolutionary_search(&cons, &es, Subset::City, &mut PlanOracle::new(predict));
    let naive_h = result.samples as f64 * PROFILE_COST_S / 3600.0;
    println!(
        "  best {:?}\n  predicted acc {:.1}%  attrs {:?}",
        result.best, result.best_fitness, result.best_attrs
    );
    println!(
        "  {} candidates in {:.2?}; naive profiling would need {:.1} h ({:.0}x slower)",
        result.samples,
        result.elapsed,
        naive_h,
        naive_h * 3600.0 / result.elapsed.as_secs_f64().max(1e-9)
    );
    println!("\nall five stages composed — toolflow OK");
    Ok(())
}
